//! Versioned weight artifacts (DESIGN.md §13): round-trip properties
//! and the fault-injection matrix.
//!
//! The round-trip contract: `save → load → save` is byte-identical on
//! disk for every synthesized family × dtype, a loaded artifact's
//! streaming outputs are bit-identical to the in-memory original, and a
//! manifest listing its tensors in any permutation loads equivalently
//! (weights reassemble in canonical parameter order).  The corruption
//! matrix proves the loader is a real trust boundary: a truncated blob,
//! a single flipped byte, a manifest/blob length skew, an unknown
//! format version, and a missing tensor each yield their matching typed
//! [`ArtifactError`] — and the pristine generation next to them keeps
//! loading, because `Artifact::load` is pure and constructs nothing on
//! failure.  The env-gated cross-check (`SOI_EXTERNAL_ARTIFACT` /
//! `SOI_EXTERNAL_CORRUPT`) runs the same reader against artifacts the
//! python exporter wrote, which is what CI wires up.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use soi::coordinator::StreamSession;
use soi::runtime::{
    synth, Artifact, ArtifactError, CompiledVariant, Dtype, Manifest, ModelConfig, Runtime,
    Weights,
};
use soi::util::json::{self, Json};
use soi::util::rng::Rng;

fn cfg(scc: Vec<usize>, shift_pos: Option<usize>, tconv: bool) -> ModelConfig {
    ModelConfig {
        feat: 4,
        channels: vec![5, 6, 7],
        kernel: 3,
        extrap: vec![if tconv { "tconv" } else { "duplicate" }.into(); scc.len()],
        scc,
        shift_pos,
        shift: 1,
        interp: None,
    }
}

/// Every synthesized family the format must carry: plain STMC, single
/// and double S-CC, FP, and tconv extrapolation (extra `up*` tensors).
fn families() -> Vec<(&'static str, ModelConfig)> {
    vec![
        ("stmc", cfg(vec![], None, false)),
        ("scc2", cfg(vec![2], None, false)),
        ("sscc2", cfg(vec![2], Some(2), false)),
        ("scc1_3", cfg(vec![1, 3], None, false)),
        ("scc2_tconv", cfg(vec![2], None, true)),
    ]
}

fn make(name: &str, c: &ModelConfig, dtype: Dtype, generation: u64, seed: u64) -> Artifact {
    let mut m = synth::manifest(c, name, 256);
    let w = synth::he_weights(&m, seed);
    if dtype == Dtype::Int8 {
        m.dtype = Dtype::Int8;
        m.quant = Some(soi::quant::calibrate(&m, &w, 64, seed ^ 0x5EED).unwrap());
    }
    Artifact::new(m, w, generation).unwrap()
}

fn tmp_root(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("soi_artifact_rt_{tag}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&p);
    fs::create_dir_all(&p).unwrap();
    p
}

fn copy_generation(src: &Path, dst: &Path) {
    fs::create_dir_all(dst).unwrap();
    for f in ["artifact.json", "weights.bin"] {
        fs::copy(src.join(f), dst.join(f)).unwrap();
    }
}

fn random_frames(feat: usize, t: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..t)
        .map(|_| (0..feat).map(|_| rng.normal() as f32 * 0.3).collect())
        .collect()
}

/// Serve `frames` through one fresh session and collect every output.
fn stream_outputs(
    rt: &Arc<Runtime>,
    manifest: Manifest,
    weights: Weights,
    frames: &[Vec<f32>],
) -> Vec<Vec<f32>> {
    let cv = Arc::new(CompiledVariant::with_weights(rt.clone(), manifest, weights).unwrap());
    let dw = Arc::new(cv.device_weights().unwrap());
    let mut sess = StreamSession::new(0, cv, dw);
    frames.iter().map(|f| sess.on_frame(f).unwrap()).collect()
}

#[test]
fn save_load_save_is_byte_identical_for_every_family_and_dtype() {
    let root = tmp_root("families");
    for (name, c) in families() {
        for dtype in [Dtype::F32, Dtype::Int8] {
            let spec = format!("{name}:{}", dtype.as_str());
            let art = make(name, &c, dtype, 7, 0xFEED ^ name.len() as u64);
            let d1 = root.join(&spec).join("gen-000007");
            let d2 = root.join(&spec).join("resave");
            art.save(&d1).unwrap();
            let back = Artifact::load(&d1).unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert_eq!(back.generation, 7, "{spec}");
            assert_eq!(back.manifest.config, art.manifest.config, "{spec}");
            assert_eq!(back.manifest.dtype, dtype, "{spec}");
            assert_eq!(back.manifest.quant, art.manifest.quant, "{spec}");
            assert_eq!(back.manifest.params, art.manifest.params, "{spec}");
            assert_eq!(back.weights.tensors, art.weights.tensors, "{spec}: weights");
            back.save(&d2).unwrap();
            for f in ["artifact.json", "weights.bin"] {
                assert_eq!(
                    fs::read(d1.join(f)).unwrap(),
                    fs::read(d2.join(f)).unwrap(),
                    "{spec}: {f} not byte-identical across save→load→save"
                );
            }
        }
    }
    let _ = fs::remove_dir_all(&root);
}

#[test]
fn loaded_artifact_streams_bit_identically_to_the_original() {
    let root = tmp_root("stream_equiv");
    let rt = Arc::new(Runtime::native());
    for (name, c) in families() {
        for dtype in [Dtype::F32, Dtype::Int8] {
            let spec = format!("{name}:{}", dtype.as_str());
            let art = make(name, &c, dtype, 1, 0xAB);
            let dir = root.join(&spec);
            art.save(&dir).unwrap();
            let back = Artifact::load(&dir).unwrap();
            let frames = random_frames(c.feat, 3 * art.manifest.period.max(4), 0x51D);
            let want = stream_outputs(&rt, art.manifest.clone(), art.weights.clone(), &frames);
            let got = stream_outputs(&rt, back.manifest, back.weights, &frames);
            assert_eq!(got, want, "{spec}: loaded weights changed streaming outputs");
        }
    }
    let _ = fs::remove_dir_all(&root);
}

#[test]
fn permuted_tensor_table_loads_equivalently() {
    let root = tmp_root("permuted");
    let art = make("scc2", &cfg(vec![2], None, false), Dtype::F32, 1, 0xCAFE);
    let dir = root.join("canonical");
    art.save(&dir).unwrap();

    // rewrite the generation with its tensor table (and blob) reversed
    let v = json::parse(&fs::read_to_string(dir.join("artifact.json")).unwrap()).unwrap();
    let table = v.get("tensors").and_then(|t| t.as_arr()).unwrap().to_vec();
    let blob = fs::read(dir.join("weights.bin")).unwrap();
    let mut slices = Vec::new();
    let mut off = 0usize;
    for e in &table {
        let len = e.get("byte_len").and_then(|b| b.as_usize()).unwrap();
        slices.push(blob[off..off + len].to_vec());
        off += len;
    }
    let Json::Obj(pairs) = v else { panic!("manifest is not an object") };
    let permuted = Json::Obj(
        pairs
            .into_iter()
            .map(|(k, val)| {
                if k == "tensors" {
                    (k, Json::Arr(table.iter().rev().cloned().collect()))
                } else {
                    (k, val)
                }
            })
            .collect(),
    );
    let pdir = root.join("permuted");
    fs::create_dir_all(&pdir).unwrap();
    fs::write(pdir.join("artifact.json"), permuted.to_string_pretty()).unwrap();
    let reordered: Vec<u8> = slices.iter().rev().flat_map(|s| s.iter().copied()).collect();
    fs::write(pdir.join("weights.bin"), reordered).unwrap();

    let back = Artifact::load(&pdir).expect("permuted table must load");
    assert_eq!(back.manifest.params, art.manifest.params, "canonical spec order");
    assert_eq!(back.weights.tensors, art.weights.tensors, "canonical reassembly");
    let _ = fs::remove_dir_all(&root);
}

#[test]
fn corruption_matrix_yields_typed_errors_and_spares_the_pristine() {
    let root = tmp_root("matrix");
    let art = make("scc2", &cfg(vec![2], None, false), Dtype::F32, 1, 0xBADC0DE);
    let pristine = root.join("pristine");
    art.save(&pristine).unwrap();
    let first_tensor = art.manifest.params[0].name.clone();
    let total: u64 = art.weights.tensors.iter().map(|t| t.bytes() as u64).sum();

    // 1. truncated blob
    let d = root.join("truncated");
    copy_generation(&pristine, &d);
    let mut blob = fs::read(d.join("weights.bin")).unwrap();
    blob.truncate(blob.len() - 5);
    fs::write(d.join("weights.bin"), &blob).unwrap();
    match Artifact::load(&d) {
        Err(ArtifactError::Truncated { want, got }) => {
            assert_eq!(want, total);
            assert_eq!(got, total - 5);
        }
        other => panic!("truncated blob: expected Truncated, got {other:?}"),
    }

    // 2. one flipped byte — digest mismatch naming the damaged tensor
    let d = root.join("flipped");
    copy_generation(&pristine, &d);
    let mut blob = fs::read(d.join("weights.bin")).unwrap();
    blob[3] ^= 0xFF;
    fs::write(d.join("weights.bin"), &blob).unwrap();
    match Artifact::load(&d) {
        Err(ArtifactError::DigestMismatch { tensor, want, got }) => {
            assert_eq!(tensor, first_tensor);
            assert_ne!(want, got);
        }
        other => panic!("flipped byte: expected DigestMismatch, got {other:?}"),
    }

    // 3a. manifest/blob length skew: blob longer than the table declares
    let d = root.join("overlong");
    copy_generation(&pristine, &d);
    let mut blob = fs::read(d.join("weights.bin")).unwrap();
    blob.extend_from_slice(&[0u8; 4]);
    fs::write(d.join("weights.bin"), &blob).unwrap();
    match Artifact::load(&d) {
        Err(ArtifactError::Truncated { want, got }) => {
            assert_eq!(want, total);
            assert_eq!(got, total + 4);
        }
        other => panic!("overlong blob: expected Truncated, got {other:?}"),
    }

    // 3b. a byte_len that disagrees with its declared shape
    let d = root.join("byte_len");
    copy_generation(&pristine, &d);
    let v = json::parse(&fs::read_to_string(d.join("artifact.json")).unwrap()).unwrap();
    let Json::Obj(pairs) = v else { panic!() };
    let edited = Json::Obj(
        pairs
            .into_iter()
            .map(|(k, val)| {
                if k != "tensors" {
                    return (k, val);
                }
                let Json::Arr(mut entries) = val else { panic!() };
                let Json::Obj(fields) = &mut entries[0] else { panic!() };
                for (fk, fv) in fields.iter_mut() {
                    if fk == "byte_len" {
                        let n = fv.as_f64().unwrap();
                        *fv = Json::Num(n + 4.0);
                    }
                }
                (k, Json::Arr(entries))
            })
            .collect(),
    );
    fs::write(d.join("artifact.json"), edited.to_string_pretty()).unwrap();
    match Artifact::load(&d) {
        Err(ArtifactError::Malformed { reason }) => {
            assert!(reason.contains("byte_len"), "reason: {reason}");
        }
        other => panic!("byte_len skew: expected Malformed, got {other:?}"),
    }

    // 4. unknown format version
    let d = root.join("skew");
    copy_generation(&pristine, &d);
    let text = fs::read_to_string(d.join("artifact.json"))
        .unwrap()
        .replace("soi.artifact.v1", "soi.artifact.v9");
    fs::write(d.join("artifact.json"), text).unwrap();
    match Artifact::load(&d) {
        Err(ArtifactError::VersionSkew { found }) => assert_eq!(found, "soi.artifact.v9"),
        other => panic!("version skew: expected VersionSkew, got {other:?}"),
    }

    // 5. missing tensor: drop the first table entry and its blob slice
    let d = root.join("missing");
    copy_generation(&pristine, &d);
    let v = json::parse(&fs::read_to_string(d.join("artifact.json")).unwrap()).unwrap();
    let first_len = v.get("tensors").and_then(|t| t.as_arr()).unwrap()[0]
        .get("byte_len")
        .and_then(|b| b.as_usize())
        .unwrap();
    let Json::Obj(pairs) = v else { panic!() };
    let edited = Json::Obj(
        pairs
            .into_iter()
            .map(|(k, val)| {
                if k != "tensors" {
                    return (k, val);
                }
                let Json::Arr(entries) = val else { panic!() };
                (k, Json::Arr(entries.into_iter().skip(1).collect()))
            })
            .collect(),
    );
    fs::write(d.join("artifact.json"), edited.to_string_pretty()).unwrap();
    let blob = fs::read(d.join("weights.bin")).unwrap();
    fs::write(d.join("weights.bin"), &blob[first_len..]).unwrap();
    match Artifact::load(&d) {
        Err(ArtifactError::MissingTensor { tensor }) => assert_eq!(tensor, first_tensor),
        other => panic!("missing tensor: expected MissingTensor, got {other:?}"),
    }

    // the loader is pure: after five rejections next door, the pristine
    // generation still verifies and matches the original bit for bit
    let back = Artifact::load(&pristine).expect("pristine generation still loads");
    assert_eq!(back.weights.tensors, art.weights.tensors);
    let _ = fs::remove_dir_all(&root);
}

/// Cross-check against the python exporter (CI wires the env vars):
/// `SOI_EXTERNAL_ARTIFACT` must load, compile, and serve; the
/// byte-flipped `SOI_EXTERNAL_CORRUPT` copy must be rejected with the
/// typed digest error.
#[test]
fn external_python_artifact_cross_check() {
    let Ok(dir) = std::env::var("SOI_EXTERNAL_ARTIFACT") else {
        eprintln!("SOI_EXTERNAL_ARTIFACT unset — cross-check skipped");
        return;
    };
    let art = Artifact::load(Path::new(&dir)).expect("python-written artifact must verify");
    let rt = Arc::new(Runtime::native());
    let feat = art.manifest.config.feat;
    let period = art.manifest.period.max(2);
    let frames = random_frames(feat, 4 * period, 0xE77);
    let outs = stream_outputs(&rt, art.manifest.clone(), art.weights.clone(), &frames);
    assert_eq!(outs.len(), frames.len(), "every frame served");
    assert!(
        outs.iter().flatten().all(|v| v.is_finite()),
        "python-exported weights produced non-finite output"
    );
    if let Ok(bad) = std::env::var("SOI_EXTERNAL_CORRUPT") {
        match Artifact::load(Path::new(&bad)) {
            Err(ArtifactError::DigestMismatch { .. }) => {}
            other => panic!("corrupt python artifact: expected DigestMismatch, got {other:?}"),
        }
    }
}
