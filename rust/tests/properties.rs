//! Property-based tests over the pure substrates (seeded xorshift cases
//! via `util::prop` — the offline stand-in for proptest).

use soi::complexity::unet;
use soi::dsp::{metrics, resample, siggen};
use soi::kernels::{gemm_f32, gemm_f32_on, gemm_i8, gemm_i8_on, Isa, PackedF32, PackedI8};
use soi::quant::kernels::{conv_win_batch_q, tconv_phase_batch_q};
use soi::quant::{quantize_groups, quantize_per_channel, quantize_weights, EluLut};
use soi::runtime::{synth, Artifact, ArtifactError, ModelConfig};
use soi::util::json::{self, Json};
use soi::util::prop;
use soi::util::rng::Rng;
use soi::util::sha256::{hex_digest, Sha256};
use soi::util::tensor::Tensor;

#[test]
fn prop_json_roundtrip_random_documents() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
            3 => {
                let n = rng.below(8);
                Json::Str((0..n).map(|_| ['a', 'ż', '"', '\\', '\n', 'x'][rng.below(6)]).collect())
            }
            4 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    prop::check("json roundtrip", 200, 0xD0C, |rng, _| {
        let doc = random_json(rng, 3);
        let text = doc.to_string();
        let back = json::parse(&text).map_err(|e| format!("{e} in {text}"))?;
        if back != doc {
            return Err(format!("roundtrip mismatch: {text}"));
        }
        let pretty = doc.to_string_pretty();
        let back2 = json::parse(&pretty).map_err(|e| format!("{e} in pretty"))?;
        if back2 != doc {
            return Err("pretty roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_si_snr_scale_and_shift_invariant() {
    prop::check("si_snr invariance", 40, 0x51, |rng, _| {
        let n = 200 + rng.below(500);
        let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let noisy: Vec<f32> = x.iter().map(|&v| v + 0.5 * rng.normal() as f32).collect();
        let base = metrics::si_snr(&noisy, &x);
        let g = rng.range(0.1, 10.0) as f32;
        let off = rng.range(-1.0, 1.0) as f32;
        let transformed: Vec<f32> = noisy.iter().map(|&v| g * v + off).collect();
        let got = metrics::si_snr(&transformed, &x);
        prop::close(got, base, 1e-3, 1e-3)
    });
}

#[test]
fn prop_resamplers_linear_in_input() {
    // resampling is a linear operator: R(a x) == a R(x)
    prop::check("resample linearity", 20, 0x2e5, |rng, _| {
        let n = 512 + 2 * rng.below(256);
        let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let a = rng.range(0.2, 3.0) as f32;
        let xa: Vec<f32> = x.iter().map(|&v| a * v).collect();
        for m in resample::Method::ALL {
            let y1: Vec<f32> = resample::roundtrip(&x, m).iter().map(|&v| a * v).collect();
            let y2 = resample::roundtrip(&xa, m);
            prop::slices_close(&y2, &y1, 1e-4, 1e-4)
                .map_err(|e| format!("{}: {e}", m.name()))?;
        }
        Ok(())
    });
}

#[test]
fn prop_unet_compound_rate_rule() {
    // For any S-CC position set, retain == 1 - Σ_l cost_l (1 - 1/2^{k(l)})
    // where k(l) counts compression stages at or above l — the engine's
    // semantics must satisfy the closed-form compounding identity used to
    // validate against the paper (DESIGN.md §3).
    prop::check("compound rate rule", 60, 0xABCD, |rng, _| {
        let mut ps: Vec<usize> = Vec::new();
        for p in 1..=7usize {
            if rng.chance(0.3) {
                ps.push(p);
            }
        }
        let cfg = unet::default_config(ps.clone(), None);
        let net = unet::network(&cfg, 256, 1000.0);
        let total: f64 = net.layers.iter().map(|l| l.macs_per_out as f64).sum();
        let expect: f64 = net
            .layers
            .iter()
            .map(|l| l.macs_per_out as f64 / l.rate_div as f64)
            .sum();
        prop::close(net.soi_macs_per_frame(), expect, 1e-12, 0.0)?;
        if ps.is_empty() {
            prop::close(net.soi_macs_per_frame(), total, 1e-12, 0.0)?;
        } else if net.soi_macs_per_frame() >= total {
            return Err("SOI not cheaper with compression stages".into());
        }
        Ok(())
    });
}

#[test]
fn prop_mix_monotone_in_snr() {
    // higher requested SNR => noisy signal closer to clean
    prop::check("mix monotone", 20, 0x111, |rng, _| {
        let clean = siggen::speech(rng, 4000, siggen::FS);
        let noise = siggen::noise(rng, 4000, siggen::FS);
        let lo = siggen::mix(&clean, &noise, 0.0);
        let hi = siggen::mix(&clean, &noise, 10.0);
        let s_lo = metrics::si_snr(&lo, &clean);
        let s_hi = metrics::si_snr(&hi, &clean);
        if s_hi > s_lo {
            Ok(())
        } else {
            Err(format!("snr10 {s_hi} <= snr0 {s_lo}"))
        }
    });
}

#[test]
fn prop_histogram_quantiles_bounded_error() {
    prop::check("histogram quantile error", 30, 0x9a9, |rng, _| {
        let mut h = soi::util::stats::Histogram::new();
        let mut vals: Vec<u64> = (0..2000)
            .map(|_| (rng.uniform() * rng.uniform() * 1e9) as u64 + 1)
            .collect();
        for &v in &vals {
            h.record(v);
        }
        vals.sort_unstable();
        for q in [0.5, 0.9, 0.99] {
            let exact = vals[((q * (vals.len() - 1) as f64) as usize).min(vals.len() - 1)] as f64;
            let got = h.quantile(q) as f64;
            // log-bucketed: must be within one bucket (~1%) + ordering slop
            if (got - exact).abs() / exact.max(1.0) > 0.05 {
                return Err(format!("q{q}: {got} vs {exact}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_quant_roundtrip_error_bounded() {
    // quantize → dequantize is within half an LSB of each group's scale,
    // codes stay in ±127, and group maxima hit the grid ends — for both
    // the per-(out, in)-group and per-channel granularities.
    prop::check("quant roundtrip", 60, 0x8B17, |rng, _| {
        let co = 1 + rng.below(5);
        let ci = 1 + rng.below(5);
        let k = 1 + rng.below(4);
        let t = Tensor::new(
            vec![co, ci, k],
            (0..co * ci * k)
                .map(|_| (rng.normal() * rng.range(0.01, 3.0)) as f32)
                .collect(),
        );
        for group in [k, ci * k] {
            let q = quantize_groups(&t, group).map_err(|e| e.to_string())?;
            if q.scales.len() != co * ci * k / group {
                return Err("wrong group count".into());
            }
            let deq = q.dequantize();
            for (i, (&a, &b)) in t.data.iter().zip(&deq.data).enumerate() {
                let s = q.scale_of(i);
                if (a - b).abs() > 0.5 * s + 1e-6 {
                    return Err(format!("[{i}] |{a} - {b}| > {}/2", s));
                }
            }
            if q.data.iter().any(|&c| c == i8::MIN) {
                return Err("code -128 escapes the symmetric ±127 grid".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_quant_scales_monotone_and_scale_equivariant() {
    // scaling a kernel by a power of two scales every group scale
    // *exactly* by it and leaves the codes untouched (exact in binary
    // floating point); any gain > 1 never shrinks a scale.
    prop::check("quant scale monotone", 60, 0x5CA1E, |rng, _| {
        let n = 3 * (1 + rng.below(6));
        let t = Tensor::new(
            vec![n / 3, 3],
            (0..n).map(|_| rng.normal() as f32).collect(),
        );
        let q1 = quantize_groups(&t, 3).map_err(|e| e.to_string())?;
        let pow2 = [2.0f32, 4.0, 0.5][rng.below(3)];
        let t2 = Tensor::new(t.shape.clone(), t.data.iter().map(|v| v * pow2).collect());
        let q2 = quantize_groups(&t2, 3).map_err(|e| e.to_string())?;
        for (gi, (&s1, &s2)) in q1.scales.iter().zip(&q2.scales).enumerate() {
            let grp = &t.data[gi * 3..(gi + 1) * 3];
            let zero = grp.iter().all(|&v| v == 0.0);
            if zero {
                continue; // all-zero groups pin their scale to 1.0
            }
            if s2 != s1 * pow2 {
                return Err(format!("group {gi}: {s2} != {s1} * {pow2}"));
            }
        }
        if q1.data != q2.data {
            return Err("power-of-two gain changed the codes".into());
        }
        // general monotonicity: a gain > 1 never shrinks any scale
        let g = rng.range(1.0, 5.0) as f32;
        let t3 = Tensor::new(t.shape.clone(), t.data.iter().map(|v| v * g).collect());
        let q3 = quantize_groups(&t3, 3).map_err(|e| e.to_string())?;
        for (&s1, &s3) in q1.scales.iter().zip(&q3.scales) {
            if s3 < s1 {
                return Err(format!("gain {g} shrank a scale: {s3} < {s1}"));
            }
        }
        let _ = quantize_per_channel(&t).map_err(|e| e.to_string())?;
        Ok(())
    });
}

#[test]
fn prop_elu_lut_error_within_bound() {
    // |LUT(q)·s − ELU(q·s)| ≤ 1.5 s for calibration-realistic scales:
    // ≤ 0.5 LSB knot rounding + ≤ 0.5 LSB interpolation rounding +
    // 128 s LSB curvature (negligible at these scales, DESIGN.md §10).
    prop::check("elu lut error", 30, 0xE1, |rng, _| {
        let s = rng.range(1e-5, 1e-3) as f32;
        let lut = EluLut::new(s);
        for _ in 0..64 {
            let q = -(rng.below(32767) as i32) - 1 + rng.below(2) as i32; // [-32768+1, 0]
            let q = q.max(-32767);
            let got = lut.apply(q) as f64 * s as f64;
            let want = ((q as f64) * s as f64).exp_m1();
            if (got - want).abs() > 1.5 * s as f64 {
                return Err(format!("q={q} s={s}: |{got} - {want}| > 1.5s"));
            }
            if lut.apply(q) > 0 || lut.apply(q) < -32767 {
                return Err("post-activation code out of range".into());
            }
        }
        // positive identity
        let qp = rng.below(32767) as i32;
        if lut.apply(qp) != qp {
            return Err("positive codes must pass through".into());
        }
        Ok(())
    });
}

#[test]
fn prop_packed_panels_roundtrip() {
    // Packing a (c_out, n) matrix into MR-lane panels and unpacking it
    // reproduces the matrix exactly, for full and partial last panels.
    prop::check("packed panel roundtrip", 60, 0x9AC4, |rng, _| {
        let c_out = 1 + rng.below(20);
        let n = 1 + rng.below(24);
        let w: Vec<f32> = (0..c_out * n).map(|_| rng.normal() as f32).collect();
        let p = PackedF32::pack(&w, c_out, n);
        if p.unpack() != w {
            return Err(format!("({c_out}, {n}) panel roundtrip mismatch"));
        }
        Ok(())
    });
}

#[test]
fn prop_gemm_f32_simd_within_ulp_envelope_of_scalar() {
    // DESIGN.md §11 ULP policy: the dispatched f32 kernel may differ
    // from the scalar oracle only by FMA's fused rounding.  Per output
    // element the envelope is 2 · (n + 2) · ε · (|bias| + Σ|w·x|) —
    // the scalar path makes ~2n roundings and the fused path n, each
    // bounded by ε/2 of the partial-sum magnitude, which Σ|w·x| + |bias|
    // dominates; the ELU epilogue is 1-Lipschitz, so the bound survives
    // it.  On machines without SIMD both paths are the scalar kernel
    // and the diff is 0.
    prop::check("gemm f32 ulp envelope", 40, 0xF3A, |rng, _| {
        let c_out = 1 + rng.below(24);
        let n = 1 + rng.below(64);
        let bsz = 1 + rng.below(9);
        let w: Vec<f32> = (0..c_out * n).map(|_| rng.normal() as f32).collect();
        let bias: Vec<f32> = (0..c_out).map(|_| rng.normal() as f32 * 0.1).collect();
        let x: Vec<f32> = (0..n * bsz).map(|_| rng.normal() as f32).collect();
        let p = PackedF32::pack(&w, c_out, n);
        let elu = rng.chance(0.5);
        let mut simd = vec![0.0f32; c_out * bsz];
        let mut sc = vec![0.0f32; c_out * bsz];
        gemm_f32(&p, &bias, &x, bsz, &mut simd, elu);
        gemm_f32_on(Isa::Scalar, &p, &bias, &x, bsz, &mut sc, elu);
        for o in 0..c_out {
            for b in 0..bsz {
                let mut mag = bias[o].abs();
                for j in 0..n {
                    mag += (w[o * n + j] * x[j * bsz + b]).abs();
                }
                let tol = 2.0 * (n + 2) as f32 * f32::EPSILON * mag;
                let (a, r) = (simd[o * bsz + b], sc[o * bsz + b]);
                if (a - r).abs() > tol {
                    return Err(format!("[{o},{b}] |{a} - {r}| > {tol}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_gemm_f32_batch_invariant_bitwise() {
    // Per-stream accumulation order must not depend on the batch width:
    // the dispatched kernel at width B equals B single-column calls
    // bit-for-bit (the §8 batched == sequential guarantee, at kernel
    // granularity).
    prop::check("gemm f32 batch invariance", 40, 0xBA7C, |rng, _| {
        let c_out = 1 + rng.below(20);
        let n = 1 + rng.below(48);
        let bsz = 2 + rng.below(10);
        let w: Vec<f32> = (0..c_out * n).map(|_| rng.normal() as f32).collect();
        let bias: Vec<f32> = (0..c_out).map(|_| rng.normal() as f32 * 0.1).collect();
        let x: Vec<f32> = (0..n * bsz).map(|_| rng.normal() as f32).collect();
        let p = PackedF32::pack(&w, c_out, n);
        let mut batched = vec![0.0f32; c_out * bsz];
        gemm_f32(&p, &bias, &x, bsz, &mut batched, true);
        let mut one = vec![0.0f32; c_out];
        let mut col = vec![0.0f32; n];
        for b in 0..bsz {
            for j in 0..n {
                col[j] = x[j * bsz + b];
            }
            gemm_f32(&p, &bias, &col, 1, &mut one, true);
            for o in 0..c_out {
                if one[o].to_bits() != batched[o * bsz + b].to_bits() {
                    return Err(format!("[{o},{b}] batch-width-dependent result"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_gemm_i8_bit_identical_to_reference() {
    // The packed int8 kernel must reproduce the scalar reference
    // (`quant::kernels::conv_win_batch_q`, pinned by the python golden
    // vectors) bit-for-bit on every ISA — the int8 determinism contract
    // warm migration relies on.
    prop::check("gemm i8 vs reference", 40, 0x18B1, |rng, _| {
        let c_out = 1 + rng.below(20);
        let c_in = 1 + rng.below(8);
        let k = 1 + rng.below(4);
        let bsz = 1 + rng.below(7);
        let wt = Tensor::new(
            vec![c_out, c_in, k],
            (0..c_out * c_in * k).map(|_| rng.normal() as f32).collect(),
        );
        let qw = quantize_weights(&wt).map_err(|e| e.to_string())?;
        let g: Vec<f32> = qw
            .scales
            .iter()
            .map(|&sw| sw * rng.range(1e-5, 1e-3) as f32)
            .collect();
        let bias: Vec<f32> = (0..c_out).map(|_| rng.normal() as f32 * 0.1).collect();
        let x: Vec<i32> = (0..c_in * k * bsz)
            .map(|_| rng.below(2 * 32767 + 1) as i32 - 32767)
            .collect();
        let mut want = vec![0.0f32; c_out * bsz];
        let (mut acc, mut pre) = (vec![0i32; bsz], vec![0.0f32; bsz]);
        conv_win_batch_q(&qw, &g, &bias, &x, bsz, &mut acc, &mut pre, &mut want);
        let p = PackedI8::pack(&qw.data, c_out, c_in, k, &g, &bias);
        for isa in [None, Some(Isa::Scalar)] {
            let mut got = vec![0.0f32; c_out * bsz];
            match isa {
                None => gemm_i8(&p, &x, bsz, &mut got),
                Some(i) => gemm_i8_on(i, &p, &x, bsz, &mut got),
            }
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("[{i}] {a} != {b} (isa {isa:?})"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_gemm_i8_tconv_phase_panels_match_reference() {
    // Per-phase 1-tap panels of a quantized stride-2 transposed conv
    // must match `tconv_phase_batch_q` bit-for-bit, both phases.
    prop::check("gemm i8 tconv phases", 30, 0x7C0F, |rng, _| {
        let c = 1 + rng.below(16);
        let bsz = 1 + rng.below(6);
        let wt = Tensor::new(
            vec![c, c, 2],
            (0..c * c * 2).map(|_| rng.normal() as f32).collect(),
        );
        let qw = quantize_weights(&wt).map_err(|e| e.to_string())?;
        let g: Vec<f32> = qw
            .scales
            .iter()
            .map(|&sw| sw * rng.range(1e-5, 1e-3) as f32)
            .collect();
        let bias: Vec<f32> = (0..c).map(|_| rng.normal() as f32 * 0.1).collect();
        let x: Vec<i32> = (0..c * bsz)
            .map(|_| rng.below(2 * 32767 + 1) as i32 - 32767)
            .collect();
        for ph in 0..2usize {
            let mut want = vec![0.0f32; c * bsz];
            let mut pre = vec![0.0f32; bsz];
            tconv_phase_batch_q(&qw, &g, &bias, ph, &x, bsz, &mut pre, &mut want);
            let p = PackedI8::pack_tap(&qw.data, c, c, 2, ph, &g, &bias);
            let mut got = vec![0.0f32; c * bsz];
            gemm_i8(&p, &x, bsz, &mut got);
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("phase {ph} [{i}] {a} != {b}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pruning_never_increases_magnitude_sum() {
    prop::check("pruning magnitude", 30, 0x777, |rng, _| {
        let n = 100 + rng.below(400);
        let mut w = soi::runtime::Weights {
            tensors: vec![soi::util::tensor::Tensor::new(
                vec![n],
                (0..n).map(|_| rng.normal() as f32).collect(),
            )],
        };
        let sum = |w: &soi::runtime::Weights| -> f64 {
            w.tensors[0].data.iter().map(|v| v.abs() as f64).sum()
        };
        let before = sum(&w);
        let k = rng.below(n);
        soi::pruning::prune_global_magnitude(&mut w, k);
        let after = sum(&w);
        if after > before + 1e-6 {
            return Err("magnitude sum grew".into());
        }
        // pruned count correct
        if soi::pruning::zeros(&w) < k {
            return Err(format!("pruned fewer than {k}"));
        }
        Ok(())
    });
}

#[test]
fn prop_sha256_chunking_invariant() {
    // the digest is a function of the byte stream alone: any split of
    // the input into update() calls — including empty and unaligned
    // chunks straddling the 64-byte block boundary — matches one-shot
    prop::check("sha256 chunking", 120, 0x5A256, |rng, _| {
        let n = rng.below(300);
        let data: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let want = hex_digest(&data);
        if want.len() != 64 || !want.bytes().all(|b| b.is_ascii_hexdigit() && !b.is_ascii_uppercase())
        {
            return Err(format!("not lowercase 64-hex: {want}"));
        }
        let mut h = Sha256::new();
        let mut off = 0;
        while off < n {
            let step = rng.below(80); // 0 is a legal (empty) update
            let end = (off + step).min(n);
            h.update(&data[off..end]);
            off = end;
        }
        let got = Sha256::to_hex(&h.finish());
        if got != want {
            return Err(format!("chunked {got} != one-shot {want} over {n} bytes"));
        }
        Ok(())
    });
}

#[test]
fn prop_artifact_roundtrip_and_flip_detection() {
    // for random small model families: save → load preserves every
    // tensor bit-for-bit, and any single flipped blob byte is caught by
    // the digest gate as a typed error naming the damaged tensor
    let root = std::env::temp_dir().join(format!("soi_prop_artifact_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();
    prop::check("artifact roundtrip", 12, 0xA27, |rng, case| {
        let depth = 2 + rng.below(2);
        let channels: Vec<usize> = (0..depth).map(|_| 3 + rng.below(4)).collect();
        let scc = if rng.chance(0.5) { vec![1 + rng.below(depth)] } else { vec![] };
        let c = ModelConfig {
            feat: 1 + rng.below(4),
            channels,
            kernel: 3,
            extrap: vec!["duplicate".into(); scc.len()],
            scc,
            shift_pos: None,
            shift: 1,
            interp: None,
        };
        let m = synth::manifest(&c, "p", 16);
        let w = synth::he_weights(&m, 0xBEEF ^ case as u64);
        let art = Artifact::new(m, w, 1 + case as u64).map_err(|e| e.to_string())?;
        let dir = root.join(format!("case{case}"));
        art.save(&dir).map_err(|e| e.to_string())?;
        let back = Artifact::load(&dir).map_err(|e| e.to_string())?;
        if back.weights.tensors != art.weights.tensors {
            return Err("loaded tensors differ from saved".into());
        }
        if back.generation != art.generation {
            return Err("generation did not round-trip".into());
        }
        // flip one random blob byte; the load must fail naming the
        // tensor whose byte range covers the flipped offset
        let blob_path = dir.join("weights.bin");
        let mut blob = std::fs::read(&blob_path).map_err(|e| e.to_string())?;
        let at = rng.below(blob.len());
        blob[at] ^= 1 + rng.below(255) as u8;
        std::fs::write(&blob_path, &blob).map_err(|e| e.to_string())?;
        let mut off = 0usize;
        let mut damaged = String::new();
        for (spec, t) in art.manifest.params.iter().zip(&art.weights.tensors) {
            if at < off + t.bytes() {
                damaged = spec.name.clone();
                break;
            }
            off += t.bytes();
        }
        match Artifact::load(&dir) {
            Err(ArtifactError::DigestMismatch { tensor, .. }) if tensor == damaged => Ok(()),
            Err(e) => Err(format!("flip at {at}: expected DigestMismatch in '{damaged}', got {e}")),
            Ok(_) => Err(format!("flip at {at} in '{damaged}' went undetected")),
        }
    });
    let _ = std::fs::remove_dir_all(&root);
}
