//! Integration tests over real artifacts: load a built variant through
//! the runtime facade (native backend by default; PJRT with
//! `--features pjrt` + `SOI_BACKEND=pjrt`) and check the
//! streaming/offline equivalence *through the rust runtime* (the
//! cross-layer golden test of DESIGN.md §7).
//!
//! Tests are skipped (not failed) when `artifacts/` has not been built yet
//! so `cargo test` stays green before `make artifacts`.  The same
//! equivalences run artifact-free in `tests/native_backend.rs`.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use soi::runtime::{CompiledVariant, Runtime};
use soi::util::rng::Rng;
use soi::util::tensor::Tensor;

fn artifacts_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn variant_dir(name: &str) -> Option<PathBuf> {
    let d = artifacts_root().join(name);
    if d.join("manifest.json").exists() {
        Some(d)
    } else {
        eprintln!("SKIP: artifacts/{name} not built (run `make artifacts`)");
        None
    }
}

fn load(name: &str) -> Option<CompiledVariant> {
    let dir = variant_dir(name)?;
    let rt = Arc::new(Runtime::cpu().expect("runtime backend"));
    Some(CompiledVariant::load(rt, &dir).expect("compile variant"))
}

fn random_frames(feat: usize, t: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let data: Vec<f32> = (0..feat * t).map(|_| rng.normal() as f32 * 0.3).collect();
    Tensor::new(vec![feat, t], data)
}

/// Stream frame-by-frame through the step executables.
fn stream_through(cv: &CompiledVariant, x: &Tensor) -> Vec<f32> {
    let feat = cv.manifest.config.feat;
    let t = x.shape[1];
    let dw = cv.device_weights().unwrap();
    let mut states = cv.init_states();
    let mut out = Vec::with_capacity(feat * t);
    let mut frame = vec![0.0f32; feat];
    for tt in 0..t {
        for i in 0..feat {
            frame[i] = x.at2(i, tt);
        }
        let phase = tt % cv.manifest.period;
        let o = cv.step(phase, &frame, &mut states, &dw).unwrap();
        out.extend_from_slice(&o);
    }
    out // laid out as t blocks of feat
}

/// Same, but exercising the FP pre/rest split.
fn stream_through_split(cv: &CompiledVariant, x: &Tensor) -> Vec<f32> {
    let feat = cv.manifest.config.feat;
    let t = x.shape[1];
    let dw = cv.device_weights().unwrap();
    let mut states = cv.init_states();
    let mut out = Vec::with_capacity(feat * t);
    let mut frame = vec![0.0f32; feat];
    for tt in 0..t {
        for i in 0..feat {
            frame[i] = x.at2(i, tt);
        }
        let phase = tt % cv.manifest.period;
        cv.precompute(phase, &mut states, &dw).unwrap();
        let o = cv.step_rest(phase, &frame, &mut states, &dw).unwrap();
        out.extend_from_slice(&o);
    }
    out
}

fn assert_stream_matches_offline(name: &str, use_split: bool) {
    let Some(cv) = load(name) else { return };
    let feat = cv.manifest.config.feat;
    let t = cv.manifest.offline_t;
    let x = random_frames(feat, t, 42);
    let dw = cv.device_weights().unwrap();
    let off = cv.offline(&x, &dw).unwrap();

    let streamed = if use_split {
        stream_through_split(&cv, &x)
    } else {
        stream_through(&cv, &x)
    };
    // streamed is t blocks of feat; offline is (feat, t) row-major
    let mut max_err = 0.0f32;
    for tt in 0..t {
        for i in 0..feat {
            let a = streamed[tt * feat + i];
            let b = off.at2(i, tt);
            max_err = max_err.max((a - b).abs());
        }
    }
    assert!(
        max_err < 1e-4,
        "{name}: streaming vs offline max err {max_err}"
    );
}

#[test]
fn stmc_streaming_equals_offline() {
    assert_stream_matches_offline("stmc", false);
}

#[test]
fn scc2_pp_streaming_equals_offline() {
    assert_stream_matches_offline("scc2", false);
}

#[test]
fn scc5_pp_streaming_equals_offline() {
    assert_stream_matches_offline("scc5", false);
}

#[test]
fn double_scc_streaming_equals_offline() {
    assert_stream_matches_offline("scc2_5", false);
}

#[test]
fn sscc5_fp_monolithic_equals_offline() {
    assert_stream_matches_offline("sscc5", false);
}

#[test]
fn sscc5_fp_split_equals_offline() {
    assert_stream_matches_offline("sscc5", true);
}

#[test]
fn fp_hybrid_split_equals_offline() {
    assert_stream_matches_offline("fp2_5", true);
}

#[test]
fn precompute_does_not_touch_frame() {
    // The pre pass has no frame argument at all (manifest signature), so
    // this asserts it is runnable before any frame exists.
    let Some(cv) = load("sscc5") else { return };
    let dw = cv.device_weights().unwrap();
    let mut states = cv.init_states();
    cv.precompute(0, &mut states, &dw).unwrap();
}

#[test]
fn manifest_macs_positive_and_monotone() {
    let Some(stmc) = variant_dir("stmc") else { return };
    let Some(scc2) = variant_dir("scc2") else { return };
    let m0 = soi::runtime::Manifest::load(&stmc).unwrap();
    let m2 = soi::runtime::Manifest::load(&scc2).unwrap();
    assert!(m0.macs_per_frame > 0.0);
    // SOI must strictly reduce average complexity
    assert!(m2.macs_per_frame < m0.macs_per_frame);
}

#[test]
fn weights_match_param_count() {
    let Some(cv) = load("stmc") else { return };
    assert_eq!(cv.weights.total_params(), cv.manifest.param_count);
}

#[test]
fn list_variants_sees_built_artifacts() {
    let root = artifacts_root();
    if !root.exists() {
        return;
    }
    let names = soi::runtime::list_variants(&root).unwrap();
    if Path::new(&root.join("stmc/manifest.json")).exists() {
        assert!(names.contains(&"stmc".to_string()));
    }
}
