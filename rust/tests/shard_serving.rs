//! Sharded serving end-to-end (DESIGN.md §14): a front-end over
//! loopback shards must serve bit-identically to a single process.
//!
//! Covered here, all over the deterministic loopback transport:
//! sharded output equality with [`Server::run`], planned cross-shard
//! warm migration with zero dropped frames, shard-loss containment
//! (orphans resume bit-identically on a survivor while siblings never
//! notice; losing the *only* shard yields exactly
//! `ErrCode::ShardLost`), typed admission denial that spares the
//! admitted session, and an in-band version-skewed hello answered
//! with a typed error on a connection that then recovers.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::{self, JoinHandle};

use soi::coordinator::Server;
use soi::net::wire::{role, write_msg};
use soi::net::{
    run_shard, spawn_front, ErrCode, FrameReader, FrontHandle, FrontPolicy, FrontReport, Listener,
    LoopbackHub, Msg, ShardConfig, ShardLink, ShardReport, Transport, WireClient, WireRead,
    WireWrite, WIRE_VERSION,
};
use soi::runtime::{synth, CompiledVariant, ModelConfig, Runtime};
use soi::util::rng::Rng;

fn cfg(scc: Vec<usize>, shift_pos: Option<usize>) -> ModelConfig {
    ModelConfig {
        feat: 4,
        channels: vec![5, 6, 7],
        kernel: 3,
        extrap: vec!["duplicate".into(); scc.len()],
        scc,
        shift_pos,
        shift: 1,
        interp: None,
    }
}

fn variant(rt: &Arc<Runtime>, c: &ModelConfig, name: &str) -> Arc<CompiledVariant> {
    let m = synth::manifest(c, name, 32);
    let w = synth::he_weights(&m, 0xFEED);
    Arc::new(CompiledVariant::with_weights(rt.clone(), m, w).expect("compile native variant"))
}

fn random_frames(feat: usize, t: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..t)
        .map(|_| (0..feat).map(|_| rng.normal() as f32 * 0.3).collect())
        .collect()
}

fn random_streams(feat: usize, n: usize, t: usize, seed: u64) -> Vec<Vec<Vec<f32>>> {
    (0..n)
        .map(|i| random_frames(feat, t, seed ^ (i as u64 + 1)))
        .collect()
}

/// The exact outputs the fleet must reproduce: the same streams served
/// by one in-process worker pool.
fn reference_outputs(cv: &Arc<CompiledVariant>, streams: &[Vec<Vec<f32>>]) -> Vec<Vec<Vec<f32>>> {
    let server = Server::new(cv.clone(), 2);
    let report = server.run(streams).expect("reference serve");
    (0..streams.len() as u64)
        .map(|sid| report.outputs.get(&sid).cloned().unwrap_or_default())
        .collect()
}

/// One real shard (worker pool + wire endpoint) on its own loopback
/// hub, running until the front drains it.
fn real_shard(
    cv: &Arc<CompiledVariant>,
    name: &str,
    shard_id: u64,
) -> (ShardLink, JoinHandle<ShardReport>) {
    let hub = LoopbackHub::new();
    let server = Server::new(cv.clone(), 2);
    let shard_hub = hub.clone();
    let join = thread::spawn(move || {
        run_shard(&server, &shard_hub, ShardConfig { shard_id }).expect("shard serves")
    });
    (
        ShardLink {
            name: name.to_string(),
            transport: Box::new(hub),
        },
        join,
    )
}

/// A byte-copying man-in-the-middle between the front and a real
/// shard.  Flipping the returned switch severs both directions at the
/// next byte — the loopback equivalent of the shard process dying
/// mid-stream.
fn crashable_shard(
    cv: &Arc<CompiledVariant>,
    name: &str,
    shard_id: u64,
) -> (ShardLink, Arc<AtomicBool>, JoinHandle<ShardReport>) {
    let inner = LoopbackHub::new();
    let outer = LoopbackHub::new();
    let server = Server::new(cv.clone(), 2);
    let shard_hub = inner.clone();
    let join = thread::spawn(move || {
        run_shard(&server, &shard_hub, ShardConfig { shard_id }).expect("shard serves")
    });
    let kill = Arc::new(AtomicBool::new(false));
    let proxy_kill = kill.clone();
    let accept_hub = outer.clone();
    thread::spawn(move || {
        let Ok((mut from_front, mut to_front)) = accept_hub.accept() else {
            return;
        };
        let Ok((mut from_shard, mut to_shard)) = inner.connect() else {
            return;
        };
        let back = thread::spawn(move || {
            let mut buf = [0u8; 4096];
            loop {
                match from_shard.recv(&mut buf) {
                    Ok(0) | Err(_) => return,
                    Ok(n) => {
                        if to_front.send(&buf[..n]).is_err() {
                            return;
                        }
                    }
                }
            }
        });
        let mut buf = [0u8; 4096];
        loop {
            match from_front.recv(&mut buf) {
                Ok(0) | Err(_) => break,
                Ok(n) => {
                    if proxy_kill.load(Ordering::SeqCst) {
                        break;
                    }
                    if to_shard.send(&buf[..n]).is_err() {
                        break;
                    }
                }
            }
        }
        // Severing both pipe pairs here cascades: the shard sees EOF
        // and drops its sessions; the front sees EOF and re-homes them.
        drop(from_front);
        drop(to_shard);
        let _ = back.join();
        inner.close();
    });
    (
        ShardLink {
            name: name.to_string(),
            transport: Box::new(outer),
        },
        kill,
        join,
    )
}

struct Fleet {
    front: FrontHandle,
    hub: LoopbackHub,
    shards: Vec<JoinHandle<ShardReport>>,
}

fn boot_front(links: Vec<ShardLink>, policy: FrontPolicy) -> (FrontHandle, LoopbackHub) {
    let hub = LoopbackHub::new();
    let front = spawn_front(Box::new(hub.clone()), links, policy).expect("front boots");
    (front, hub)
}

fn boot_fleet(cv: &Arc<CompiledVariant>, n_shards: usize, policy: FrontPolicy) -> Fleet {
    let mut links = Vec::new();
    let mut shards = Vec::new();
    for i in 0..n_shards {
        let (link, join) = real_shard(cv, &format!("shard{i}"), i as u64 + 1);
        links.push(link);
        shards.push(join);
    }
    let (front, hub) = boot_front(links, policy);
    Fleet { front, hub, shards }
}

impl Fleet {
    /// Drain the fleet: the front sends whole-shard `Drain`s, so every
    /// shard thread exits with its report.
    fn stop(self) -> (FrontReport, Vec<ShardReport>) {
        let report = self.front.stop().expect("front stops");
        let shard_reports = self
            .shards
            .into_iter()
            .map(|j| j.join().expect("shard joins"))
            .collect();
        (report, shard_reports)
    }
}

fn send_frame(client: &mut WireClient, session: u64, seq: usize, last: bool, f: &[f32]) {
    client
        .send(&Msg::Frame {
            session,
            seq: seq as u64,
            last,
            samples: f.to_vec(),
            trace: None,
            deadline_us: None,
        })
        .expect("send frame");
}

/// Send frames `from..to` of every stream, round-robin per round —
/// the same interleaving single-process serving dispatches in.
fn send_rr(client: &mut WireClient, streams: &[Vec<Vec<f32>>], from: usize, to: usize) {
    for seq in from..to {
        for (sid, frames) in streams.iter().enumerate() {
            send_frame(client, sid as u64, seq, seq + 1 == frames.len(), &frames[seq]);
        }
    }
}

/// Receive `FrameOut`s until each session `i` holds `targets[i]`
/// outputs; anything other than an output frame fails the test.
fn collect_until(client: &mut WireClient, outs: &mut [Vec<Vec<f32>>], targets: &[usize]) {
    while outs.iter().zip(targets).any(|(o, t)| o.len() < *t) {
        match client.recv() {
            Ok(Some(Msg::FrameOut {
                session, samples, ..
            })) => {
                let sid = session as usize;
                assert!(sid < outs.len(), "output for unknown session {session}");
                outs[sid].push(samples);
            }
            other => panic!("expected FrameOut, got {other:?}"),
        }
    }
}

#[test]
fn sharded_serving_is_bit_identical_to_single_process() {
    let rt = Arc::new(Runtime::native());
    let cv = variant(&rt, &cfg(vec![2], None), "scc2");
    let streams = random_streams(4, 4, 32, 0xD15C);
    let reference = reference_outputs(&cv, &streams);

    let fleet = boot_fleet(&cv, 2, FrontPolicy::default());
    let mut client = WireClient::connect(&fleet.hub).expect("connect");
    assert_eq!(client.feat(), 4, "handshake reports the model shape");
    let outs = client.serve_streams(&streams).expect("sharded serve");
    assert_eq!(outs, reference, "sharded outputs must be bit-identical");
    client.shutdown();

    let (front, shards) = fleet.stop();
    assert_eq!(front.admitted, 4);
    assert_eq!(front.denied, 0);
    assert_eq!(front.migrations, 0);
    assert_eq!(front.frames_out, 4 * 32, "every input produced one forwarded output");
    for (i, s) in shards.iter().enumerate() {
        assert!(s.frames_in > 0, "shard {i} served nothing — affinity never spread");
    }
    let total: u64 = shards.iter().map(|s| s.frames_in).sum();
    assert_eq!(total, 4 * 32, "no frame was duplicated or lost across the fleet");
}

#[test]
fn planned_migration_drops_nothing_and_is_bit_identical() {
    let rt = Arc::new(Runtime::native());
    let cv = variant(&rt, &cfg(vec![2], None), "scc2");
    let total = 24usize;
    let frames = random_frames(4, total, 0x316);
    let reference = reference_outputs(&cv, std::slice::from_ref(&frames));

    let fleet = boot_fleet(&cv, 2, FrontPolicy::default());
    let mut client = WireClient::connect(&fleet.hub).expect("connect");
    let half = total / 2;
    for (i, f) in frames[..half].iter().enumerate() {
        send_frame(&mut client, 0, i, false, f);
    }
    let mut outs = vec![Vec::new()];
    collect_until(&mut client, &mut outs, &[half]);

    // The session is quiet (everything acked) and deterministically
    // homed on shard 0, so nominating shard 0 is ignored and shard 1
    // is exactly one real warm move.
    fleet.front.migrate(0, 0).expect("no-op nomination");
    fleet.front.migrate(0, 1).expect("nominate shard 1");
    for (i, f) in frames[half..].iter().enumerate() {
        let seq = half + i;
        send_frame(&mut client, 0, seq, seq + 1 == total, f);
    }
    collect_until(&mut client, &mut outs, &[total]);
    assert_eq!(outs[0], reference[0], "migrated session must be bit-identical");
    client.shutdown();

    let (front, shards) = fleet.stop();
    assert_eq!(front.migrations, 1, "exactly one real warm move");
    assert_eq!(front.frames_out, total as u64, "zero dropped frames");
    assert_eq!(shards[1].resumes, 1, "target admitted the replay");
    assert_eq!(shards[0].drains, 1, "old home retired the session");
    assert_eq!(
        shards[0].frames_in + shards[1].frames_in,
        total as u64,
        "planned migration re-sends nothing"
    );
}

#[test]
fn shard_loss_is_contained_and_orphans_resume_bit_identically() {
    let rt = Arc::new(Runtime::native());
    let cv = variant(&rt, &cfg(vec![2], None), "scc2");
    let total = 24usize;
    let streams = random_streams(4, 2, total, 0xC4A5);
    let reference = reference_outputs(&cv, &streams);

    let (victim_link, kill, victim_join) = crashable_shard(&cv, "victim", 1);
    let (survivor_link, survivor_join) = real_shard(&cv, "survivor", 2);
    let (front, hub) = boot_front(vec![victim_link, survivor_link], FrontPolicy::default());
    let mut client = WireClient::connect(&hub).expect("connect");

    // Session 0 lands on the (crashable) shard 0, session 1 on shard 1.
    let half = total / 2;
    send_rr(&mut client, &streams, 0, half);
    let mut outs = vec![Vec::new(), Vec::new()];
    collect_until(&mut client, &mut outs, &[half, half]);

    // Kill the shard hosting session 0: the next byte severs it, the
    // front re-homes the orphan by §9 replay and re-sends the unacked
    // tail.  The sibling on the survivor never notices.
    kill.store(true, Ordering::SeqCst);
    send_rr(&mut client, &streams, half, total);
    collect_until(&mut client, &mut outs, &[total, total]);
    assert_eq!(outs, reference, "orphan and sibling must both be bit-identical");
    client.shutdown();

    let report = front.stop().expect("front stops");
    assert_eq!(report.shard_losses, 1);
    assert!(report.migrations >= 1, "crash re-home is a warm migration");
    assert_eq!(report.frames_out, 2 * total as u64, "zero dropped frames");
    let victim = victim_join.join().expect("victim joins");
    let survivor = survivor_join.join().expect("survivor joins");
    assert_eq!(victim.conns, 1);
    assert_eq!(victim.frames_in, half as u64, "victim saw nothing after the crash");
    assert!(survivor.resumes >= 1, "survivor admitted the replay");
}

#[test]
fn losing_the_only_shard_yields_exact_shard_lost_error() {
    let rt = Arc::new(Runtime::native());
    let cv = variant(&rt, &cfg(vec![2], None), "scc2");
    let frames = random_frames(4, 4, 0x10E);

    let (link, kill, victim_join) = crashable_shard(&cv, "only", 1);
    let (front, hub) = boot_front(vec![link], FrontPolicy::default());
    let mut client = WireClient::connect(&hub).expect("connect");
    send_frame(&mut client, 0, 0, false, &frames[0]);
    let mut outs = vec![Vec::new()];
    collect_until(&mut client, &mut outs, &[1]);

    kill.store(true, Ordering::SeqCst);
    send_frame(&mut client, 0, 1, false, &frames[1]);
    match client.recv() {
        Ok(Some(Msg::Err { code, session, .. })) => {
            assert_eq!(code, ErrCode::ShardLost, "exact typed error");
            assert_eq!(session, 0, "error names the affected session");
        }
        other => panic!("expected ShardLost, got {other:?}"),
    }
    client.shutdown();

    let report = front.stop().expect("front stops");
    assert_eq!(report.shard_losses, 1);
    assert_eq!(report.migrations, 0, "nowhere to re-home");
    victim_join.join().expect("victim joins");
}

#[test]
fn admission_denial_is_typed_and_spares_the_admitted_session() {
    let rt = Arc::new(Runtime::native());
    let cv = variant(&rt, &cfg(vec![2], None), "scc2");
    let total = 12usize;
    let frames = random_frames(4, total, 0xAD31);
    let reference = reference_outputs(&cv, std::slice::from_ref(&frames));

    let fleet = boot_fleet(
        &cv,
        1,
        FrontPolicy {
            max_sessions: 1,
            ..FrontPolicy::default()
        },
    );
    let mut client = WireClient::connect(&fleet.hub).expect("connect");
    send_frame(&mut client, 0, 0, false, &frames[0]);
    send_frame(&mut client, 1, 0, false, &frames[0]);

    // Exactly one denial for session 1; session 0's output arrives in
    // either order relative to it.
    let mut outs = vec![Vec::new()];
    let mut denied = false;
    while outs[0].is_empty() || !denied {
        match client.recv() {
            Ok(Some(Msg::FrameOut {
                session: 0,
                samples,
                ..
            })) => outs[0].push(samples),
            Ok(Some(Msg::Err { code, session, .. })) => {
                assert_eq!(code, ErrCode::AdmissionDenied, "exact typed error");
                assert_eq!(session, 1, "denial names the refused session");
                denied = true;
            }
            other => panic!("expected FrameOut or AdmissionDenied, got {other:?}"),
        }
    }
    for (i, f) in frames[1..].iter().enumerate() {
        let seq = i + 1;
        send_frame(&mut client, 0, seq, seq + 1 == total, f);
    }
    collect_until(&mut client, &mut outs, &[total]);
    assert_eq!(outs[0], reference[0], "admitted session is unharmed by the denial");
    client.shutdown();

    let (front, _) = fleet.stop();
    assert_eq!(front.admitted, 1);
    assert_eq!(front.denied, 1);
}

#[test]
fn version_skewed_hello_gets_typed_reply_and_connection_recovers() {
    let rt = Arc::new(Runtime::native());
    let cv = variant(&rt, &cfg(vec![2], None), "scc2");
    let fleet = boot_fleet(&cv, 1, FrontPolicy::default());

    let (r, mut w) = fleet.hub.connect().expect("dial front");
    let mut reader = FrameReader::new(r);
    let skewed = Msg::Hello {
        version: WIRE_VERSION + 1,
        role: role::CLIENT,
        feat: 0,
        period: 0,
        warmup: 0,
    };
    write_msg(&mut w, &skewed).expect("send skewed hello");
    match reader.next_msg() {
        Ok(Some(Msg::Err { code, session, .. })) => {
            assert_eq!(code, ErrCode::VersionSkew, "exact typed error");
            assert_eq!(session, 0, "no session was constructed");
        }
        other => panic!("expected VersionSkew err, got {other:?}"),
    }
    // The skew was in-band (a well-delimited frame), so the same
    // connection may greet properly and is then served normally.
    let hello = Msg::Hello {
        version: WIRE_VERSION,
        role: role::CLIENT,
        feat: 0,
        period: 0,
        warmup: 0,
    };
    write_msg(&mut w, &hello).expect("send valid hello");
    match reader.next_msg() {
        Ok(Some(Msg::Hello { feat, .. })) => assert_eq!(feat, 4, "ack carries the model shape"),
        other => panic!("expected hello ack, got {other:?}"),
    }
    w.shutdown();

    let (front, _) = fleet.stop();
    assert!(front.wire_errs >= 1, "the skew was counted");
    assert_eq!(front.admitted, 0);
}
