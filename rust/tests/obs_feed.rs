//! The NDJSON health feed, end to end (DESIGN.md §12, appendix A).
//!
//! Two layers of proof.  First, the feed round-trips: every rendered
//! line parses back, the snapshot's counters are the exact sum of the
//! per-worker registries, and a histogram reconstructed from the sparse
//! `buckets` pairs reproduces the printed count, quantiles, and mean
//! bucket-for-bucket — the merge/re-ingest identities that make
//! per-worker feeds foldable into fleet views.  Second, a real adaptive
//! server run with telemetry enabled produces a feed the shared
//! validator (`soi validate-feed`, CI) accepts, carrying migration and
//! controller-decision events, per-(rung × phase) exec histograms, and
//! a live arena-peak gauge; the `ServeReport` carries the matching
//! per-variant arena peaks.

use std::collections::BTreeSet;
use std::sync::Arc;

use soi::coordinator::{AdaptivePolicy, Server};
use soi::obs::{schema, take_snapshot, Counter, Exporter, ObsConfig, Snapshot, Telemetry};
use soi::runtime::{synth, CompiledVariant, ModelConfig, Runtime, VariantLadder};
use soi::util::json::{self, Json};
use soi::util::rng::Rng;
use soi::util::stats::Histogram;

fn cfg(scc: Vec<usize>, shift_pos: Option<usize>) -> ModelConfig {
    ModelConfig {
        feat: 4,
        channels: vec![5, 6, 7],
        kernel: 3,
        extrap: vec!["duplicate".into(); scc.len()],
        scc,
        shift_pos,
        shift: 1,
        interp: None,
    }
}

fn variant(rt: &Arc<Runtime>, c: &ModelConfig, name: &str) -> Arc<CompiledVariant> {
    let m = synth::manifest(c, name, 32);
    let w = synth::he_weights(&m, 0xFEED);
    Arc::new(CompiledVariant::with_weights(rt.clone(), m, w).expect("compile native variant"))
}

fn random_streams(feat: usize, n: usize, t: usize, seed: u64) -> Vec<Vec<Vec<f32>>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            (0..t)
                .map(|_| (0..feat).map(|_| rng.normal() as f32 * 0.3).collect())
                .collect()
        })
        .collect()
}

fn num(v: &Json, key: &str) -> u64 {
    v.get(key)
        .and_then(|n| n.as_f64())
        .unwrap_or_else(|| panic!("missing numeric field '{key}'")) as u64
}

fn kind<'a>(v: &'a Json, key: &str) -> Option<&'a str> {
    v.get(key).and_then(|s| s.as_str())
}

/// The merged `(rung, phase)` exec histogram out of a snapshot.
fn find_exec(snap: &Snapshot, r: usize, p: usize) -> &Histogram {
    snap.exec_ns
        .iter()
        .find_map(|(sr, sp, h)| ((*sr, *sp) == (r, p)).then_some(h))
        .expect("snapshot has the (rung, phase) histogram")
}

/// The first event record of kind `k`.
fn event<'a>(events: &[&'a Json], k: &str) -> &'a Json {
    events
        .iter()
        .find(|v| kind(v, "kind") == Some(k))
        .copied()
        .unwrap_or_else(|| panic!("no '{k}' event in the feed"))
}

/// Rebuild a [`Histogram`] from a hist record's sparse `buckets` pairs.
fn rebuild(v: &Json) -> Histogram {
    let mut h = Histogram::new();
    let buckets = v
        .get("buckets")
        .and_then(|b| b.as_arr())
        .expect("hist record has a buckets array");
    for pair in buckets {
        let p = pair.as_arr().expect("[index, count] pair");
        h.add_bucket(p[0].as_f64().unwrap() as usize, p[1].as_f64().unwrap() as u64);
    }
    h
}

#[test]
fn feed_round_trips_counters_and_histograms_exactly() {
    // ring sized to hold every event below: the ring drops *newest* on
    // overflow, which would silently eat the migration pushed after the
    // 100-exec burst
    let tel = Telemetry::new(ObsConfig { ring_capacity: 256 });
    let (w0, w1) = (tel.worker(0), tel.worker(1));
    // known data spread across two workers and the shared handle,
    // including a wide latency spread so quantiles are non-trivial
    w0.exec(0, 1, 4, 1_000);
    w0.exec(0, 1, 2, 250_000);
    w1.exec(0, 1, 1, 9_000);
    for i in 0..100u64 {
        w1.exec(2, 0, 1, 1_000 + i * 400);
    }
    w0.fp_pre(3, 1, true, 500);
    w1.migration(3, 0, 2, 12, 40_000);
    tel.shared().quant_repack(9, 1 << 16, 123_456);
    w0.count(Counter::Rounds, 5);
    w1.count(Counter::Rounds, 7);
    let per_worker_frames: u64 = [&w0, &w1, &tel.shared()]
        .iter()
        .map(|h| h.with(|w| w.counter(Counter::Frames)))
        .sum();

    let snap = take_snapshot(&tel);
    let mut text = String::new();
    snap.render_ndjson(0, 0, &mut text);
    let lines: Vec<Json> = text
        .lines()
        .map(|l| json::parse(l).expect("feed line parses"))
        .collect();

    // --- snapshot record: counters are the exact cross-worker sums ---
    let head = &lines[0];
    assert_eq!(kind(head, "type"), Some("snapshot"));
    let counters = head.get("counters").expect("counters object");
    assert_eq!(num(counters, "rounds"), 12, "5 + 7 across workers");
    assert_eq!(num(counters, "frames"), per_worker_frames);
    assert_eq!(num(counters, "execs"), 103);
    assert_eq!(num(counters, "migrations"), 1);
    assert_eq!(num(counters, "quant_repacks"), 1, "shared handle folded in");

    // --- hist records: sparse buckets rebuild the histogram exactly ---
    let mut seen_hists = 0;
    for v in lines.iter().filter(|v| kind(v, "type") == Some("hist")) {
        let h = rebuild(v);
        assert_eq!(h.count(), num(v, "count"));
        assert_eq!(h.p50(), num(v, "p50"));
        assert_eq!(h.p95(), num(v, "p95"));
        assert_eq!(h.p99(), num(v, "p99"));
        let mean = v.get("mean").and_then(|n| n.as_f64()).unwrap();
        assert!((h.mean() - mean).abs() <= 1e-9 * mean.abs().max(1.0));
        // ...and matches the merged source histogram bucket-for-bucket
        let orig: &Histogram = match kind(v, "name") {
            Some("exec_ns") => {
                find_exec(&snap, num(v, "rung") as usize, num(v, "phase") as usize)
            }
            Some("batch_width") => &snap.batch_width,
            other => panic!("unexpected hist name {other:?}"),
        };
        let a: Vec<(usize, u64)> = h.nonzero().collect();
        let b: Vec<(usize, u64)> = orig.nonzero().collect();
        assert_eq!(a, b, "reconstruction is bucket-exact");
        seen_hists += 1;
    }
    // (0,1) merged across both workers, (2,0), plus batch_width
    assert_eq!(seen_hists, 3);
    let h01 = find_exec(&snap, 0, 1);
    assert_eq!(h01.count(), 3, "worker 0's two execs merged with worker 1's one");

    // --- event records: payloads survive with their kind fields ---
    let events: Vec<&Json> = lines
        .iter()
        .filter(|v| kind(v, "type") == Some("event"))
        .collect();
    let mig = event(&events, "migration");
    assert_eq!(
        (num(mig, "stream"), num(mig, "from_rung"), num(mig, "to_rung")),
        (3, 0, 2)
    );
    assert_eq!(num(mig, "replay_frames"), 12);
    let qr = event(&events, "quant_repack");
    assert!(qr.get("worker").unwrap().is_null(), "shared handle exports worker: null");
    assert_eq!(num(qr, "bytes"), 1 << 16);
    let pre = event(&events, "fp_pre");
    assert_eq!(pre.get("inline").and_then(|b| b.as_bool()), Some(true));

    // the whole rendered feed passes the shared validator
    schema::validate_feed(&text).expect("round-trip feed validates");
}

#[test]
fn adaptive_server_run_emits_a_validating_live_feed() {
    let rt = Arc::new(Runtime::native());
    let ladder = Arc::new(
        VariantLadder::new(vec![
            variant(&rt, &cfg(vec![], None), "stmc"),
            variant(&rt, &cfg(vec![2], None), "scc2"),
            variant(&rt, &cfg(vec![2], Some(2)), "sscc2"),
        ])
        .unwrap(),
    );
    let mut server = Server::with_ladder(ladder, 2);
    // any traffic is overload: forces migrations + controller verdicts
    server.adaptive = Some(AdaptivePolicy {
        target_p99_us: 0,
        queue_high: 1,
        queue_low: 0,
        patience_down: 1,
        patience_up: 1_000_000,
        cooldown: 0,
        window: 8,
        headroom: 0.5,
    });
    let tel = Telemetry::new(ObsConfig::default());
    let path = std::env::temp_dir().join(format!(
        "soi_obs_feed_e2e_{}.ndjson",
        std::process::id()
    ));
    let exporter = Exporter::start(tel.clone(), &path, 5).unwrap();
    server.telemetry = Some(tel);

    let streams = random_streams(4, 6, 48, 0xD0);
    let report = server.run(&streams).unwrap();
    let stats = exporter.finish().unwrap();

    // report-side arena accounting (satellite: arena_peak_bytes).  A
    // rung only gets an entry on workers that actually stepped it, and
    // a worker may leapfrog a middle rung — but the downgrade sweep
    // guarantees traffic on at least the top and bottom of the ladder.
    assert!(report.arena_peak_bytes > 0, "scratch high-water recorded");
    assert!(
        report.arena_peak_by_variant.len() >= 2,
        "peaks for every executed rung: {:?}",
        report.arena_peak_by_variant
    );
    assert!(
        report.arena_peak_by_variant.values().all(|&b| b > 0),
        "executed variants report non-zero peaks: {:?}",
        report.arena_peak_by_variant
    );

    // the feed passes the same validator CI runs (no jq needed)
    assert!(stats.snapshots >= 1);
    let text = std::fs::read_to_string(&path).unwrap();
    let summary = schema::validate_feed(&text).expect("live feed validates");
    assert!(summary.snapshots >= 1 && summary.hists >= 1 && summary.events >= 1);

    // the records the dashboards care about are actually present
    let mut kinds: BTreeSet<String> = BTreeSet::new();
    let mut exec_rungs: BTreeSet<u64> = BTreeSet::new();
    let mut last_peak_gauge = 0u64;
    for line in text.lines() {
        let v = json::parse(line).unwrap();
        match kind(&v, "type") {
            Some("event") => {
                kinds.insert(kind(&v, "kind").unwrap().to_string());
            }
            Some("hist") if kind(&v, "name") == Some("exec_ns") => {
                // per-(rung × phase) attribution: keys are non-null
                exec_rungs.insert(num(&v, "rung"));
                let _ = num(&v, "phase");
            }
            Some("snapshot") => {
                let gauges = v.get("gauges").expect("gauges object");
                last_peak_gauge = num(gauges, "arena_peak_bytes");
            }
            _ => {}
        }
    }
    for k in ["round", "exec", "migration", "ctl_decision"] {
        assert!(kinds.contains(k), "feed missing '{k}' events (saw {kinds:?})");
    }
    assert!(
        exec_rungs.len() >= 2,
        "exec latency attributed across rungs: {exec_rungs:?}"
    );
    assert!(last_peak_gauge > 0, "arena peak gauge is live in the feed");

    std::fs::remove_file(&path).ok();
}
