//! `soi.wire.v1` fault matrix over real transport pipes (DESIGN.md §14).
//!
//! Companion to the unit tests inside `net::wire`: these drive the
//! `FrameReader` over the deterministic loopback pipes, scripting the
//! byte-level faults the protocol must convert into exactly one typed
//! `WireError` each — truncated header, truncated body, oversize
//! prefix, unknown tag, mid-stream version skew, fail-fast
//! backpressure — and asserting that a fault on one message never
//! corrupts or drops its well-formed neighbours.

use soi::net::loopback::pipe;
use soi::net::wire::{role, write_msg};
use soi::net::{ErrCode, FrameReader, Msg, WireError, WireWrite, MAX_FRAME, WIRE_VERSION};
use soi::obs::{SpanKind, TraceCtx};
use soi::util::prop;
use soi::util::rng::Rng;

/// Largest sample count a `Frame` can carry: the body is
/// tag(1) + session(8) + seq(8) + last(1) + n(4) + 4·n bytes and the
/// prefix must not exceed [`MAX_FRAME`].
const MAX_SAMPLES: usize = (MAX_FRAME - 22) / 4;

const CODES: [ErrCode; 7] = [
    ErrCode::VersionSkew,
    ErrCode::AdmissionDenied,
    ErrCode::BadFrame,
    ErrCode::Protocol,
    ErrCode::ShardLost,
    ErrCode::Backpressure,
    ErrCode::Overloaded,
];

fn samples(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

/// Half the time, a trace context with a random (valid) hop; traced
/// and untraced encodings of every frame-bearing message both ride
/// through the whole fault matrix below.
fn random_trace(rng: &mut Rng) -> Option<TraceCtx> {
    if rng.chance(0.5) {
        return None;
    }
    let kind = SpanKind::ALL[rng.below(SpanKind::ALL.len())];
    Some(TraceCtx {
        trace_id: rng.next_u64() | 1, // nonzero by construction
        kind: kind as u8,
        parent: rng.below(8) as u8,
    })
}

/// Half the time, a recovery deadline (DESIGN.md §16); nonzero by
/// construction — a zero deadline is Malformed on the wire.
fn random_deadline(rng: &mut Rng) -> Option<u64> {
    rng.chance(0.5).then(|| rng.next_u64() | 1)
}

fn random_msg(rng: &mut Rng) -> Msg {
    match rng.below(8) {
        0 => Msg::Hello {
            version: WIRE_VERSION,
            role: [role::CLIENT, role::FRONT, role::SHARD][rng.below(3)],
            feat: rng.below(16) as u32,
            period: 1u32 << rng.below(4),
            warmup: rng.below(8) as u32,
        },
        1 => Msg::Frame {
            session: rng.next_u64(),
            seq: rng.next_u64() >> 1,
            last: rng.chance(0.2),
            // below(33) includes 0: the empty-payload edge case.
            samples: samples(rng, rng.below(33)),
            trace: random_trace(rng),
            deadline_us: random_deadline(rng),
        },
        2 => Msg::FrameOut {
            session: rng.next_u64(),
            seq: rng.next_u64() >> 1,
            samples: samples(rng, rng.below(33)),
            trace: random_trace(rng),
        },
        3 => {
            let feat = rng.below(6) + 1;
            let h = rng.below(5);
            Msg::Migrate {
                session: rng.next_u64(),
                t: rng.below(1000) as u64,
                feat: feat as u32,
                history: (0..h).map(|_| samples(rng, feat)).collect(),
                trace: random_trace(rng),
            }
        }
        4 => Msg::Drain {
            session: rng.next_u64(),
        },
        5 => Msg::Err {
            code: CODES[rng.below(CODES.len())],
            session: rng.next_u64(),
            detail: "d".repeat(rng.below(24)),
        },
        6 => Msg::Ping { seq: rng.next_u64() },
        _ => Msg::Pong { seq: rng.next_u64() },
    }
}

#[test]
fn random_messages_roundtrip_bit_exact() {
    prop::check("wire roundtrip", 200, 0x31BE, |rng, _| {
        let m = random_msg(rng);
        let mut buf = Vec::new();
        m.encode(&mut buf).map_err(|e| e.to_string())?;
        let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
        if len != buf.len() - 4 {
            return Err(format!("prefix {len} but body is {} bytes", buf.len() - 4));
        }
        let back = Msg::decode(&buf[4..]).map_err(|e| e.to_string())?;
        if back != m {
            return Err(format!("{} did not roundtrip", m.kind()));
        }
        Ok(())
    });
}

#[test]
fn max_frame_boundary_roundtrips_and_one_more_is_oversize() {
    let mut rng = Rng::new(0xB16);
    let m = Msg::Frame {
        session: 1,
        seq: 0,
        last: false,
        samples: samples(&mut rng, MAX_SAMPLES),
        trace: None,
        deadline_us: None,
    };
    let mut buf = Vec::new();
    m.encode(&mut buf).expect("max-size frame encodes");
    assert_eq!(buf.len() - 4, MAX_FRAME - 2, "2 spare bytes below the ceiling");

    // The largest legal frame crosses a real pipe in one piece.
    let (r, mut w) = pipe(buf.len(), false);
    w.send(&buf).expect("send");
    w.shutdown();
    let mut reader = FrameReader::new(r);
    assert_eq!(reader.next_msg().expect("read"), Some(m.clone()));
    assert_eq!(reader.next_msg().expect("eof"), None);

    // One more sample pushes the body past MAX_FRAME: typed refusal,
    // no partial bytes.
    let over = match m {
        Msg::Frame {
            session,
            seq,
            last,
            mut samples,
            ..
        } => {
            samples.push(0.0);
            Msg::Frame {
                session,
                seq,
                last,
                samples,
                trace: None,
                deadline_us: None,
            }
        }
        _ => unreachable!(),
    };
    let mut buf = Vec::new();
    match over.encode(&mut buf) {
        Err(WireError::Oversize { len, max }) => {
            assert_eq!(len, MAX_FRAME + 2);
            assert_eq!(max, MAX_FRAME);
        }
        other => panic!("expected Oversize, got {other:?}"),
    }
    assert!(buf.is_empty(), "refused encode leaves nothing behind");
}

#[test]
fn reader_streams_batches_then_clean_eof() {
    let mut rng = Rng::new(0x5EED);
    let msgs: Vec<Msg> = (0..16).map(|_| random_msg(&mut rng)).collect();
    let (r, mut w) = pipe(1 << 16, false);
    for m in &msgs {
        write_msg(&mut w, m).expect("send");
    }
    w.shutdown();
    let mut reader = FrameReader::new(r);
    for (i, want) in msgs.iter().enumerate() {
        let got = reader.next_msg().expect("read").expect("message present");
        assert_eq!(&got, want, "message {i}");
    }
    assert_eq!(reader.next_msg().expect("eof"), None);
    assert_eq!(reader.next_msg().expect("eof"), None, "EOF is sticky");
}

#[test]
fn eof_mid_header_is_truncated_header() {
    for cut in 1..4usize {
        let (r, mut w) = pipe(64, false);
        w.send(&[0x11, 0x22, 0x33][..cut]).expect("send");
        w.shutdown();
        let mut reader = FrameReader::new(r);
        match reader.next_msg() {
            Err(WireError::TruncatedHeader { got }) => assert_eq!(got, cut),
            other => panic!("cut {cut}: expected TruncatedHeader, got {other:?}"),
        }
    }
}

#[test]
fn disconnect_mid_body_is_truncated_body() {
    let m = Msg::Drain { session: 5 };
    let mut bytes = Vec::new();
    m.encode(&mut bytes).unwrap();
    let body = bytes.len() - 4;
    for cut in 0..body {
        let (r, mut w) = pipe(64, false);
        w.send(&bytes[..4 + cut]).expect("send");
        // Dropping the writer (peer vanishes) is equivalent to a clean
        // shutdown of the write half: drain, then EOF mid-body.
        drop(w);
        let mut reader = FrameReader::new(r);
        match reader.next_msg() {
            Err(WireError::TruncatedBody { want, got }) => {
                assert_eq!(want, body, "cut {cut}");
                assert_eq!(got, cut, "cut {cut}");
            }
            other => panic!("cut {cut}: expected TruncatedBody, got {other:?}"),
        }
    }
}

#[test]
fn oversize_prefix_is_rejected_from_the_prefix_alone() {
    let (r, mut w) = pipe(64, false);
    w.send(&((MAX_FRAME + 1) as u32).to_le_bytes()).expect("send");
    w.shutdown();
    let mut reader = FrameReader::new(r);
    match reader.next_msg() {
        // Oversize, not TruncatedBody: the claimed body was never read.
        Err(WireError::Oversize { len, max }) => {
            assert_eq!(len, MAX_FRAME + 1);
            assert_eq!(max, MAX_FRAME);
        }
        other => panic!("expected Oversize, got {other:?}"),
    }
}

#[test]
fn zero_length_frame_is_malformed() {
    let (r, mut w) = pipe(64, false);
    w.send(&[0, 0, 0, 0]).expect("send");
    w.shutdown();
    let mut reader = FrameReader::new(r);
    match reader.next_msg() {
        Err(WireError::Malformed { reason }) => assert!(reason.contains("zero"), "{reason}"),
        other => panic!("expected Malformed, got {other:?}"),
    }
}

#[test]
fn invalid_frame_is_consumed_and_the_stream_continues() {
    // A well-delimited frame with garbage inside must cost exactly one
    // typed error; the next message on the connection still decodes, so
    // sibling sessions multiplexed on the same duplex are unharmed.
    let sibling = Msg::Drain { session: 7 };
    let (r, mut w) = pipe(256, false);
    w.send(&[1, 0, 0, 0, 0xEE]).expect("send bad frame");
    write_msg(&mut w, &sibling).expect("send sibling");
    w.shutdown();
    let mut reader = FrameReader::new(r);
    match reader.next_msg() {
        Err(WireError::UnknownTag { tag }) => assert_eq!(tag, 0xEE),
        other => panic!("expected UnknownTag, got {other:?}"),
    }
    assert_eq!(reader.next_msg().expect("read"), Some(sibling));
    assert_eq!(reader.next_msg().expect("eof"), None);
}

#[test]
fn version_skew_mid_stream_is_typed_and_non_fatal() {
    let skewed = Msg::Hello {
        version: WIRE_VERSION + 98,
        role: role::CLIENT,
        feat: 4,
        period: 2,
        warmup: 1,
    };
    let sibling = Msg::FrameOut {
        session: 3,
        seq: 9,
        samples: vec![0.5, -0.5],
        trace: None,
    };
    let (r, mut w) = pipe(256, false);
    write_msg(&mut w, &skewed).expect("send skewed hello");
    write_msg(&mut w, &sibling).expect("send sibling");
    w.shutdown();
    let mut reader = FrameReader::new(r);
    match reader.next_msg() {
        Err(WireError::VersionSkew { found }) => assert_eq!(found, WIRE_VERSION + 98),
        other => panic!("expected VersionSkew, got {other:?}"),
    }
    assert_eq!(reader.next_msg().expect("read"), Some(sibling));
}

#[test]
fn backpressure_fails_whole_messages_never_partial() {
    let (r, mut w) = pipe(64, true);
    let first = Msg::Drain { session: 1 };
    write_msg(&mut w, &first).expect("first fits");
    let big = Msg::Frame {
        session: 2,
        seq: 0,
        last: false,
        samples: vec![0.0; 32],
        trace: None,
        deadline_us: None,
    };
    match write_msg(&mut w, &big) {
        Err(WireError::Backpressure { capacity }) => assert_eq!(capacity, 64),
        other => panic!("expected Backpressure, got {other:?}"),
    }
    // All-or-nothing: the stream carries no fragment of the refused
    // message, so later messages still parse.
    let second = Msg::Drain { session: 3 };
    write_msg(&mut w, &second).expect("second fits");
    w.shutdown();
    let mut reader = FrameReader::new(r);
    assert_eq!(reader.next_msg().expect("read"), Some(first));
    assert_eq!(reader.next_msg().expect("read"), Some(second));
    assert_eq!(reader.next_msg().expect("eof"), None);
}

#[test]
fn survival_extensions_off_are_byte_identical_to_v1() {
    // DESIGN.md §16's additive-encoding contract, checked at the byte
    // level against a hand-rolled v1 frame: with heartbeats and
    // deadlines off, a Frame encodes the exact v1 layout
    // [len u32][tag=2][session u64][seq u64][last u8][n u32][f32·n],
    // and each optional suffix appends after those bytes without
    // disturbing one of them.
    let m = Msg::Frame {
        session: 0x0123_4567_89AB_CDEF,
        seq: 42,
        last: true,
        samples: vec![1.5, -2.0],
        trace: None,
        deadline_us: None,
    };
    let mut got = Vec::new();
    m.encode(&mut got).unwrap();

    let mut v1 = vec![30u8, 0, 0, 0, 2];
    v1.extend_from_slice(&0x0123_4567_89AB_CDEFu64.to_le_bytes());
    v1.extend_from_slice(&42u64.to_le_bytes());
    v1.push(1);
    v1.extend_from_slice(&2u32.to_le_bytes());
    v1.extend_from_slice(&1.5f32.to_le_bytes());
    v1.extend_from_slice(&(-2.0f32).to_le_bytes());
    assert_eq!(got, v1, "feature-off Frame is exactly the v1 encoding");

    // Deadline on: the same v1 bytes, one 8-byte suffix, prefix +8.
    let budgeted = Msg::Frame {
        session: 0x0123_4567_89AB_CDEF,
        seq: 42,
        last: true,
        samples: vec![1.5, -2.0],
        trace: None,
        deadline_us: Some(500),
    };
    let mut got_b = Vec::new();
    budgeted.encode(&mut got_b).unwrap();
    assert_eq!(got_b[..4], 38u32.to_le_bytes());
    assert_eq!(got_b[4..34], v1[4..], "v1 bytes undisturbed by deadline");
    assert_eq!(got_b[34..], 500u64.to_le_bytes());

    // Trace + deadline: v1 bytes, 10-byte trace, then the deadline —
    // suffix order is fixed so the region length is unambiguous.
    let both = Msg::Frame {
        session: 0x0123_4567_89AB_CDEF,
        seq: 42,
        last: true,
        samples: vec![1.5, -2.0],
        trace: Some(TraceCtx {
            trace_id: 0x5EED,
            kind: SpanKind::ALL[0] as u8,
            parent: 3,
        }),
        deadline_us: Some(500),
    };
    let mut got_t = Vec::new();
    both.encode(&mut got_t).unwrap();
    assert_eq!(got_t[..4], 48u32.to_le_bytes());
    assert_eq!(got_t[4..34], v1[4..], "v1 bytes undisturbed by both suffixes");
    assert_eq!(got_t[34..42], 0x5EEDu64.to_le_bytes());
    assert_eq!(got_t[42], SpanKind::ALL[0] as u8);
    assert_eq!(got_t[43], 3);
    assert_eq!(got_t[44..], 500u64.to_le_bytes());
}

#[test]
fn ping_pong_are_fixed_nine_byte_frames_and_roundtrip() {
    // Heartbeat probes (DESIGN.md §16) are the smallest frames on the
    // wire: tag + echoed u64, nothing else. Pin the layout so a v1
    // peer that never sends them also never has to parse them.
    let ping = Msg::Ping { seq: 0xFEED };
    let mut buf = Vec::new();
    ping.encode(&mut buf).unwrap();
    let mut want = vec![9u8, 0, 0, 0, 7];
    want.extend_from_slice(&0xFEEDu64.to_le_bytes());
    assert_eq!(buf, want);

    let pong = Msg::Pong { seq: 0xFEED };
    let mut buf = Vec::new();
    pong.encode(&mut buf).unwrap();
    assert_eq!(buf[..5], [9, 0, 0, 0, 8]);
    assert_eq!(buf[5..], 0xFEEDu64.to_le_bytes());

    // A heartbeat exchange crosses a real pipe intact between frames.
    let frame = Msg::Frame {
        session: 1,
        seq: 0,
        last: false,
        samples: vec![0.25],
        trace: None,
        deadline_us: None,
    };
    let (r, mut w) = pipe(256, false);
    write_msg(&mut w, &ping).expect("send ping");
    write_msg(&mut w, &frame).expect("send frame");
    write_msg(&mut w, &pong).expect("send pong");
    w.shutdown();
    let mut reader = FrameReader::new(r);
    assert_eq!(reader.next_msg().expect("read"), Some(ping));
    assert_eq!(reader.next_msg().expect("read"), Some(frame));
    assert_eq!(reader.next_msg().expect("read"), Some(pong));
    assert_eq!(reader.next_msg().expect("eof"), None);
}

#[test]
fn reader_resynchronizes_across_interleaved_junk_frames() {
    // A reader fed a random interleaving of well-formed messages
    // (traced and untraced, with and without deadlines) and
    // well-delimited junk frames must charge exactly one survivable
    // typed error per junk frame and deliver every good message
    // intact and in order — resynchronization is what lets a front
    // keep a connection alive through one peer's bad frame.
    enum Item {
        Good(Msg),
        UnknownTag(u8),
        Skewed(u16),
    }
    prop::check("reader resync", 120, 0x2E57, |rng, _| {
        let n = rng.below(10) + 2;
        let mut bytes = Vec::new();
        let mut script = Vec::new();
        for _ in 0..n {
            match rng.below(4) {
                0 => {
                    // Unknown-tag frame: correctly delimited, garbage
                    // inside. 0xE0.. is far above any assigned tag.
                    let tag = 0xE0 + rng.below(16) as u8;
                    let pad = rng.below(8);
                    bytes.extend_from_slice(&((1 + pad) as u32).to_le_bytes());
                    bytes.push(tag);
                    bytes.extend(std::iter::repeat(0u8).take(pad));
                    script.push(Item::UnknownTag(tag));
                }
                1 => {
                    let found = WIRE_VERSION + 1 + rng.below(100) as u16;
                    let skewed = Msg::Hello {
                        version: found,
                        role: role::CLIENT,
                        feat: 1,
                        period: 1,
                        warmup: 0,
                    };
                    skewed.encode(&mut bytes).map_err(|e| e.to_string())?;
                    script.push(Item::Skewed(found));
                }
                _ => {
                    let m = random_msg(rng);
                    m.encode(&mut bytes).map_err(|e| e.to_string())?;
                    script.push(Item::Good(m));
                }
            }
        }
        let (r, mut w) = pipe(bytes.len() + 8, false);
        w.send(&bytes).map_err(|e| e.to_string())?;
        w.shutdown();
        let mut reader = FrameReader::new(r);
        for (i, item) in script.iter().enumerate() {
            match (item, reader.next_msg()) {
                (Item::Good(want), Ok(Some(got))) => {
                    if &got != want {
                        return Err(format!("item {i}: {} corrupted", want.kind()));
                    }
                }
                (Item::UnknownTag(t), Err(WireError::UnknownTag { tag })) if tag == *t => {}
                (Item::Skewed(v), Err(WireError::VersionSkew { found })) if found == *v => {}
                (_, other) => return Err(format!("item {i}: unexpected result {other:?}")),
            }
        }
        match reader.next_msg() {
            Ok(None) => Ok(()),
            other => Err(format!("expected clean EOF after script, got {other:?}")),
        }
    });
}

#[test]
fn truncation_at_any_byte_yields_one_exact_typed_error() {
    prop::check("truncate anywhere", 80, 0x71C0, |rng, _| {
        let msgs: Vec<Msg> = (0..rng.below(4) + 1).map(|_| random_msg(rng)).collect();
        let mut bytes = Vec::new();
        let mut bounds = vec![0usize];
        for m in &msgs {
            m.encode(&mut bytes).map_err(|e| e.to_string())?;
            bounds.push(bytes.len());
        }
        let cut = rng.below(bytes.len() + 1);
        let (r, mut w) = pipe(bytes.len() + 8, false);
        w.send(&bytes[..cut]).map_err(|e| e.to_string())?;
        w.shutdown();
        let mut reader = FrameReader::new(r);
        let mut idx = 0usize;
        loop {
            match reader.next_msg() {
                Ok(Some(m)) => {
                    if m != msgs[idx] {
                        return Err(format!("message {idx} corrupted: {:?}", m.kind()));
                    }
                    idx += 1;
                }
                Ok(None) => {
                    // Clean EOF is only legal exactly on a boundary.
                    if bounds[idx] != cut {
                        return Err(format!("EOF at {cut}, boundary is {}", bounds[idx]));
                    }
                    return Ok(());
                }
                Err(e) => {
                    let into = cut - bounds[idx];
                    let want = bounds[idx + 1] - bounds[idx] - 4;
                    return match e {
                        WireError::TruncatedHeader { got } if into < 4 && got == into => Ok(()),
                        WireError::TruncatedBody { want: tw, got }
                            if into >= 4 && tw == want && got == into - 4 =>
                        {
                            Ok(())
                        }
                        other => Err(format!("cut {into} bytes into message {idx}: {other:?}")),
                    };
                }
            }
        }
    });
}
