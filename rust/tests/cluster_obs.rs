//! Cluster observability end-to-end (DESIGN.md §15): a traced frame
//! served by a real two-shard fleet must reassemble into a causally
//! complete span tree from the aggregated `soi.cluster.v1` feed, the
//! cluster-wide exec histograms must merge bucket-exactly, and the
//! merged drop accounting must equal the per-shard exporter gauges —
//! a property held under randomized ring overflow.

use std::sync::Arc;
use std::thread::{self, JoinHandle};

use soi::coordinator::Server;
use soi::net::{
    run_shard, spawn_front_with, FrontPolicy, LoopbackHub, Msg, ShardConfig, ShardLink,
    ShardReport, WireClient,
};
use soi::obs::{
    aggregate, schema, take_snapshot, Counter, Exporter, Gauge, ObsConfig, SpanKind, Telemetry,
};
use soi::runtime::{synth, CompiledVariant, ModelConfig, Runtime};
use soi::util::json;
use soi::util::prop;
use soi::util::rng::Rng;
use soi::util::stats::Histogram;

fn cfg(scc: Vec<usize>) -> ModelConfig {
    ModelConfig {
        feat: 4,
        channels: vec![5, 6, 7],
        kernel: 3,
        extrap: vec!["duplicate".into(); scc.len()],
        scc,
        shift_pos: None,
        shift: 1,
        interp: None,
    }
}

fn variant(rt: &Arc<Runtime>, c: &ModelConfig, name: &str) -> Arc<CompiledVariant> {
    let m = synth::manifest(c, name, 32);
    let w = synth::he_weights(&m, 0xFEED);
    Arc::new(CompiledVariant::with_weights(rt.clone(), m, w).expect("compile native variant"))
}

fn random_frames(feat: usize, t: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..t)
        .map(|_| (0..feat).map(|_| rng.normal() as f32 * 0.3).collect())
        .collect()
}

/// One real shard with its own [`Telemetry`] root, so its feed can be
/// aggregated with the front's after the fleet drains.
fn obs_shard(
    cv: &Arc<CompiledVariant>,
    name: &str,
    shard_id: u64,
    tel: Arc<Telemetry>,
) -> (ShardLink, JoinHandle<ShardReport>) {
    let hub = LoopbackHub::new();
    let mut server = Server::new(cv.clone(), 2);
    server.telemetry = Some(tel);
    let shard_hub = hub.clone();
    let join = thread::spawn(move || {
        run_shard(&server, &shard_hub, ShardConfig { shard_id }).expect("shard serves")
    });
    (
        ShardLink {
            name: name.to_string(),
            transport: Box::new(hub),
        },
        join,
    )
}

fn send_frame(client: &mut WireClient, session: u64, seq: usize, last: bool, f: &[f32]) {
    client
        .send(&Msg::Frame {
            session,
            seq: seq as u64,
            last,
            samples: f.to_vec(),
            trace: None,
            deadline_us: None,
        })
        .expect("send frame");
}

fn collect_n(client: &mut WireClient, n: usize) {
    let mut got = 0;
    while got < n {
        match client.recv() {
            Ok(Some(Msg::FrameOut { .. })) => got += 1,
            other => panic!("expected FrameOut, got {other:?}"),
        }
    }
}

/// The frame-trace hop chain in causal order (DESIGN.md §15); span
/// discriminants encode the order, so this is also ascending-id order.
const FRAME_CHAIN: [SpanKind; 5] = [
    SpanKind::FrontAdmit,
    SpanKind::ShardDispatch,
    SpanKind::WorkerRound,
    SpanKind::PhaseExec,
    SpanKind::FrontReply,
];

#[test]
fn traced_frames_reassemble_causally_across_a_two_shard_fleet() {
    let rt = Arc::new(Runtime::native());
    let cv = variant(&rt, &cfg(vec![2]), "scc2");
    let total = 24usize;
    let frames = random_frames(4, total, 0x7_12ACE);
    let half = total / 2;

    let tel_front = Telemetry::new(ObsConfig::default());
    let tel_a = Telemetry::new(ObsConfig::default());
    let tel_b = Telemetry::new(ObsConfig::default());

    let (link_a, join_a) = obs_shard(&cv, "shard-a", 1, tel_a.clone());
    let (link_b, join_b) = obs_shard(&cv, "shard-b", 2, tel_b.clone());
    let hub = LoopbackHub::new();
    let front = spawn_front_with(
        Box::new(hub.clone()),
        vec![link_a, link_b],
        FrontPolicy {
            max_sessions: 8,
            trace_sample_n: 1,
            ..FrontPolicy::default()
        },
        Some(tel_front.clone()),
    )
    .expect("front boots");

    // Serve half the stream (homed on shard 0), warm-migrate to shard
    // 1, serve the rest: frame traces land on both shards and the
    // migration opens its own forced trace.
    let mut client = WireClient::connect(&hub).expect("connect");
    for (i, f) in frames[..half].iter().enumerate() {
        send_frame(&mut client, 0, i, false, f);
    }
    collect_n(&mut client, half);
    front.migrate(0, 1).expect("nominate shard 1");
    for (i, f) in frames[half..].iter().enumerate() {
        let seq = half + i;
        send_frame(&mut client, 0, seq, seq + 1 == total, f);
    }
    collect_n(&mut client, half);
    client.shutdown();
    let report = front.stop().expect("front stops");
    assert_eq!(report.migrations, 1);
    join_a.join().expect("shard-a joins");
    join_b.join().expect("shard-b joins");

    // Render each process's own soi.obs.v1 feed and aggregate.
    let snap_front = take_snapshot(&tel_front);
    let snap_a = take_snapshot(&tel_a);
    let snap_b = take_snapshot(&tel_b);
    let mut feeds = Vec::new();
    for (name, snap) in [
        ("front", &snap_front),
        ("shard-a", &snap_a),
        ("shard-b", &snap_b),
    ] {
        let mut text = String::new();
        snap.render_ndjson(0, 0, &mut text);
        schema::validate_feed(&text).expect("per-process feed validates");
        feeds.push((name.to_string(), text));
    }
    let cluster = aggregate(&feeds).expect("aggregate");

    // Every directly-forwarded frame was sampled (n = 1); at least the
    // pre-migration half must reassemble into the complete causal
    // chain: admit and reply on the front, the serving hops all on one
    // shard, each span parented by its predecessor.
    let mut complete = 0usize;
    let mut shards_seen: Vec<String> = Vec::new();
    let mut migration_traces = 0usize;
    for id in cluster.trace_ids() {
        let spans = cluster.trace_spans(id);
        let kinds: Vec<SpanKind> = spans.iter().map(|(_, r)| r.span).collect();
        if kinds == FRAME_CHAIN {
            for (i, (shard, r)) in spans.iter().enumerate() {
                let want_parent = if i == 0 { None } else { Some(FRAME_CHAIN[i - 1]) };
                assert_eq!(r.parent, want_parent, "span {:?} of trace {id}", r.span);
                match r.span {
                    SpanKind::FrontAdmit | SpanKind::FrontReply => {
                        assert_eq!(*shard, "front", "trace {id}")
                    }
                    _ => assert_eq!(*shard, spans[1].0, "one shard serves trace {id}"),
                }
            }
            shards_seen.push(spans[1].0.to_string());
            complete += 1;
        } else if kinds == [SpanKind::MigrateFront, SpanKind::MigrateReplay] {
            assert_eq!(spans[0].0, "front");
            assert_eq!(spans[1].0, "shard-b", "replay lands on the migration target");
            assert_eq!(spans[1].1.parent, Some(SpanKind::MigrateFront));
            migration_traces += 1;
        }
    }
    assert!(
        complete >= half,
        "at least the pre-migration frames trace end to end (got {complete})"
    );
    assert_eq!(migration_traces, 1, "the warm move opened one forced trace");
    assert!(
        shards_seen.iter().any(|s| s == "shard-a") && shards_seen.iter().any(|s| s == "shard-b"),
        "frame traces attribute to both homes across the migration: {shards_seen:?}"
    );

    // Bucket-exact aggregation: the cluster-wide exec histograms
    // rebuilt from NDJSON must equal a hand-merge of the in-process
    // registry snapshots — no re-binning, no loss.
    let mut hand: Vec<(usize, usize, Histogram)> = Vec::new();
    for snap in [&snap_front, &snap_a, &snap_b] {
        for (rung, phase, h) in &snap.exec_ns {
            match hand.iter_mut().find(|(r, p, _)| (*r, *p) == (*rung, *phase)) {
                Some((_, _, m)) => m.merge(h),
                None => hand.push((*rung, *phase, h.clone())),
            }
        }
    }
    hand.sort_by_key(|(r, p, _)| (*r, *p));
    let got = cluster.cluster_exec();
    assert!(!got.is_empty(), "the shards executed phases");
    assert_eq!(got.len(), hand.len());
    for ((gr, gp, gh), (hr, hp, hh)) in got.iter().zip(&hand) {
        assert_eq!((gr, gp), (hr, hp));
        let gb: Vec<(usize, u64)> = gh.nonzero().collect();
        let hb: Vec<(usize, u64)> = hh.nonzero().collect();
        assert_eq!(gb, hb, "buckets for rung {gr} phase {gp}");
        assert_eq!(gh.p99(), hh.p99());
    }

    // The rendered summary is versioned, self-consistent, and the
    // admit/dispatch/reply records of one trace agree on frame_seq.
    let mut out = String::new();
    cluster.render_ndjson(&mut out);
    let summary = schema::validate_cluster_feed(&out).expect("cluster feed validates");
    assert_eq!(summary.clusters, 1);
    assert_eq!(summary.shards, 3);
    assert_eq!(summary.spans, cluster.spans().count() as u64);
    let probe = cluster
        .trace_ids()
        .into_iter()
        .find(|id| {
            cluster.trace_spans(*id)
                .iter()
                .map(|(_, r)| r.span)
                .eq(FRAME_CHAIN)
        })
        .expect("a complete trace exists");
    let mut seqs = Vec::new();
    for line in out.lines() {
        let Ok(v) = json::parse(line) else { continue };
        if v.get("type").and_then(|t| t.as_str()) != Some("span") {
            continue;
        }
        if v.get("trace_id").and_then(|n| n.as_f64()) != Some(probe as f64) {
            continue;
        }
        if let Some(s) = v.get("frame_seq").and_then(|n| n.as_f64()) {
            seqs.push(s as u64);
        }
    }
    assert_eq!(seqs.len(), 3, "admit, dispatch and reply carry frame_seq");
    assert!(
        seqs.windows(2).all(|w| w[0] == w[1]),
        "one trace names one frame: {seqs:?}"
    );
}

#[test]
fn merged_drop_accounting_is_exact_under_ring_overflow() {
    // Satellite property (DESIGN.md §15): aggregating feeds whose
    // rings overflowed yields exact counter identities — the cluster
    // total of every counter is the sum of the per-shard feeds, the
    // cluster's dropped.events equals the sum of each exporter's
    // obs_dropped_events gauge, and each shard record attributes its
    // own loss.  Deterministic despite real Exporter threads: the ring
    // is drop-newest, so recording E events into capacity C drops
    // exactly E - C, and `finish()` always emits one final snapshot.
    const CAP: usize = 16;
    prop::check("cluster drop accounting", 6, 0xD20B5EED, |rng, case| {
        let n_shards = 2 + rng.below(2);
        let mut feeds = Vec::new();
        let mut want_drops = Vec::new();
        let mut want_frames = 0u64;
        let mut want_spans = 0u64;
        let mut paths = Vec::new();
        for s in 0..n_shards {
            let tel = Telemetry::new(ObsConfig { ring_capacity: CAP });
            let h = tel.worker(0);
            let events = rng.below(3 * CAP + 1) as u64;
            for i in 0..events {
                h.span(i + 1, SpanKind::FrontAdmit, 0, 1, i, 0);
            }
            let frames = rng.below(1000) as u64;
            h.count(Counter::Frames, frames);
            want_frames += frames;
            want_drops.push(events.saturating_sub(CAP as u64));
            want_spans += events.min(CAP as u64);
            let path = std::env::temp_dir().join(format!(
                "soi-cluster-obs-{}-{case}-{s}.ndjson",
                std::process::id()
            ));
            let exporter = Exporter::start(tel, &path, 3_600_000)
                .map_err(|e| format!("exporter start: {e}"))?;
            exporter.finish().map_err(|e| format!("exporter finish: {e}"))?;
            let text = std::fs::read_to_string(&path).map_err(|e| format!("read feed: {e}"))?;
            paths.push(path);
            feeds.push((format!("shard-{s}"), text));
        }
        let cluster = aggregate(&feeds).map_err(|e| format!("aggregate: {e}"))?;
        for p in paths {
            let _ = std::fs::remove_file(p);
        }

        let total_drops: u64 = want_drops.iter().sum();
        if cluster.counter_total(Counter::Frames) != want_frames {
            return Err(format!(
                "cluster frames {} != sum of shard feeds {want_frames}",
                cluster.counter_total(Counter::Frames)
            ));
        }
        if cluster.gauge_total(Gauge::ObsDroppedEvents) != total_drops {
            return Err(format!(
                "cluster dropped events {} != expected {total_drops}",
                cluster.gauge_total(Gauge::ObsDroppedEvents)
            ));
        }
        if cluster.spans().count() as u64 != want_spans {
            return Err(format!(
                "cluster spans {} != surviving events {want_spans}",
                cluster.spans().count()
            ));
        }
        for (shard, want) in cluster.shards.iter().zip(&want_drops) {
            if shard.gauge(Gauge::ObsDroppedEvents) != *want {
                return Err(format!(
                    "shard '{}' attributes {} drops, expected {want}",
                    shard.name,
                    shard.gauge(Gauge::ObsDroppedEvents)
                ));
            }
            if shard.gauge(Gauge::ObsDroppedSnapshots) != 0 {
                return Err(format!(
                    "shard '{}' reports snapshot drops on an idle exporter",
                    shard.name
                ));
            }
        }

        // The rendered head record carries the same accounting.
        let mut out = String::new();
        cluster.render_ndjson(&mut out);
        schema::validate_cluster_feed(&out).map_err(|e| format!("cluster feed: {e}"))?;
        let head = json::parse(out.lines().next().unwrap_or(""))
            .map_err(|e| format!("head parses: {e}"))?;
        let dropped = head
            .get("dropped")
            .and_then(|d| d.get("events"))
            .and_then(|n| n.as_f64())
            .ok_or("head has dropped.events")? as u64;
        if dropped != total_drops {
            return Err(format!("rendered dropped.events {dropped} != {total_drops}"));
        }
        Ok(())
    });
}
