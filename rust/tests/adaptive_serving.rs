//! Adaptive serving (DESIGN.md §9): warm variant migration and the
//! load controller.
//!
//! The load-bearing guarantee is *migration equivalence*: after a
//! session switches rungs at a phase-0 boundary, every subsequent
//! output must be bit-identical to a session that served the stream's
//! entire life on the new variant — the re-priming replay (from the
//! retained receptive-field history, see `runtime::ladder::warmup_frames`)
//! reconstructs the target's partial states exactly.  Also covered: the
//! controller's hysteresis through a synthetic load spike, the adaptive
//! server end-to-end (downgrades under pressure, no-op under calm
//! policies, batching intact), ladder validation, and paced dispatch.

use std::sync::Arc;

use soi::coordinator::{AdaptivePolicy, Decision, LoadController, Server, StreamSession, Trigger};
use soi::runtime::{synth, warmup_frames, CompiledVariant, ModelConfig, Runtime, VariantLadder};
use soi::util::rng::Rng;

fn cfg(scc: Vec<usize>, shift_pos: Option<usize>) -> ModelConfig {
    ModelConfig {
        feat: 4,
        channels: vec![5, 6, 7],
        kernel: 3,
        extrap: vec!["duplicate".into(); scc.len()],
        scc,
        shift_pos,
        shift: 1,
        interp: None,
    }
}

/// Compile a variant on `rt` with the shared deterministic weight set
/// (same seed + identical param inventories ⇒ identical tensors, the
/// ladder's weight-compatibility contract).
fn variant(rt: &Arc<Runtime>, c: &ModelConfig, name: &str) -> Arc<CompiledVariant> {
    let m = synth::manifest(c, name, 32);
    let w = synth::he_weights(&m, 0xFEED);
    Arc::new(CompiledVariant::with_weights(rt.clone(), m, w).expect("compile native variant"))
}

fn random_frames(feat: usize, t: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..t)
        .map(|_| (0..feat).map(|_| rng.normal() as f32 * 0.3).collect())
        .collect()
}

fn random_streams(feat: usize, n: usize, t: usize, seed: u64) -> Vec<Vec<Vec<f32>>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            (0..t)
                .map(|_| (0..feat).map(|_| rng.normal() as f32 * 0.3).collect())
                .collect()
        })
        .collect()
}

#[test]
fn migration_matches_fresh_session_bit_exactly() {
    let rt = Arc::new(Runtime::native());
    // (from, to) across families: compression deepened, removed,
    // into FP, FP to deeper period — both directions of the ladder.
    let pairs = [
        ("stmc", cfg(vec![], None), "scc2", cfg(vec![2], None)),
        ("scc2", cfg(vec![2], None), "stmc", cfg(vec![], None)),
        ("scc2", cfg(vec![2], None), "sscc2", cfg(vec![2], Some(2))),
        ("sscc2", cfg(vec![2], Some(2)), "scc1_3", cfg(vec![1, 3], None)),
    ];
    for (na, ca, nb, cb) in pairs {
        let a = variant(&rt, &ca, na);
        let b = variant(&rt, &cb, nb);
        let dw = Arc::new(a.device_weights().unwrap());
        let warm = warmup_frames(&cb);
        let pb = b.manifest.period as u64;
        // long: the stream outlived the retention cap (replay covers
        // exactly `warm` frames); short: full history still retained
        let long = {
            let raw = warm as u64 + 9;
            raw.div_ceil(pb) * pb
        };
        for t_switch in [long, 2 * pb] {
            let t_switch = t_switch as usize;
            let total = t_switch + 16;
            let frames = random_frames(4, total, 0xA11CE ^ t_switch as u64);

            let mut sess = StreamSession::new(0, a.clone(), dw.clone());
            sess.set_history_cap(warm);
            for f in &frames[..t_switch] {
                sess.on_frame(f).unwrap();
            }
            sess.migrate_to(&b).unwrap();
            assert_eq!(sess.variant_name(), nb, "{na}->{nb}");
            assert_eq!(sess.frames_seen(), t_switch as u64, "migration keeps t");
            let mut migrated = Vec::new();
            for f in &frames[t_switch..] {
                migrated.push(sess.on_frame(f).unwrap());
            }

            let mut fresh = StreamSession::new(1, b.clone(), dw.clone());
            let mut reference = Vec::new();
            for (tt, f) in frames.iter().enumerate() {
                let out = fresh.on_frame(f).unwrap();
                if tt >= t_switch {
                    reference.push(out);
                }
            }
            assert_eq!(
                migrated, reference,
                "{na}->{nb} at t={t_switch}: post-migration outputs diverged"
            );
            assert_eq!(sess.metrics.migrations, 1, "{na}->{nb}");
            assert!(sess.metrics.macs_migration > 0.0, "{na}->{nb}");
        }
    }
}

#[test]
fn migration_requires_boundary_and_history() {
    let rt = Arc::new(Runtime::native());
    let a = variant(&rt, &cfg(vec![], None), "stmc");
    let b = variant(&rt, &cfg(vec![2], None), "scc2");
    let dw = Arc::new(a.device_weights().unwrap());
    let f = vec![0.1f32; 4];

    // not at a phase-0 boundary of the target's period-2 schedule
    let mut sess = StreamSession::new(0, a.clone(), dw.clone());
    sess.set_history_cap(64);
    sess.on_frame(&f).unwrap();
    assert!(sess.migrate_to(&b).is_err(), "t = 1 is mid-cycle for period 2");
    sess.on_frame(&f).unwrap();
    sess.migrate_to(&b).unwrap(); // t = 2 is a boundary

    // no retained history on a stream past its warmup: refuse rather
    // than glitch
    let warm = warmup_frames(&b.manifest.config);
    let mut bare = StreamSession::new(1, a.clone(), dw.clone());
    for _ in 0..2 * warm {
        bare.on_frame(&f).unwrap();
    }
    assert!(bare.migrate_to(&b).is_err(), "history retention was off");

    // request/try: the switch waits for the boundary, then lands
    let mut deferred = StreamSession::new(2, a, dw);
    deferred.set_history_cap(warm);
    deferred.on_frame(&f).unwrap();
    deferred.request_switch(b.clone());
    assert!(!deferred.try_switch().unwrap(), "t = 1: must wait");
    assert!(deferred.switch_pending());
    deferred.on_frame(&f).unwrap();
    assert!(deferred.try_switch().unwrap(), "t = 2: boundary reached");
    assert!(!deferred.switch_pending());
    assert_eq!(deferred.variant_name(), "scc2");
}

#[test]
fn controller_rides_a_load_spike_with_hysteresis() {
    let policy = AdaptivePolicy {
        target_p99_us: 1_000,
        queue_high: 4,
        queue_low: 0,
        patience_down: 2,
        patience_up: 3,
        cooldown: 2,
        window: 16,
        headroom: 0.5,
    };
    let mut ctl = LoadController::new(policy);
    let max_rung = 2;
    let mut rung = 0usize;
    let mut trace: Vec<Decision> = Vec::new();
    // calm → spike (flooded queue) → calm again
    let mut depths = vec![0usize; 10];
    depths.extend(vec![50; 20]);
    depths.extend(vec![0; 40]);
    for depth in depths {
        ctl.record_latency_ns(100_000); // 100 µs, well under target
        if let Some(d) = ctl.observe_round(depth, rung, max_rung) {
            assert_eq!(d.from, rung, "decision evidence names the source rung");
            rung = d.to;
            trace.push(d);
        }
    }
    // degraded stepwise to the bottom during the spike, recovered
    // stepwise to rung 0 after it
    let steps: Vec<(usize, usize)> = trace.iter().map(|d| (d.from, d.to)).collect();
    assert_eq!(steps, vec![(0, 1), (1, 2), (2, 1), (1, 0)]);
    assert_eq!(rung, 0, "recovered to the quality anchor");
    // the decision trace carries its evidence: both downgrades were
    // queue-triggered (depth 50 with the p99 at ~100 µs, far under the
    // 1 ms target), both recoveries fired on calm
    for d in &trace[..2] {
        assert!(d.is_degrade());
        assert_eq!(d.trigger, Trigger::Queue, "{d:?}");
        assert_eq!(d.backlog, 50, "{d:?}");
    }
    for d in &trace[2..] {
        assert!(!d.is_degrade());
        assert_eq!(d.trigger, Trigger::Calm, "{d:?}");
        assert_eq!(d.backlog, 0, "{d:?}");
    }
    for d in &trace {
        assert!(
            d.p99_us > 0 && d.p99_us < 1_000,
            "p99 evidence at decision time: {d:?}"
        );
    }
}

#[test]
fn ladder_validation_rejects_incompatible_rungs() {
    let rt = Arc::new(Runtime::native());
    let stmc = variant(&rt, &cfg(vec![], None), "stmc");
    let scc2 = variant(&rt, &cfg(vec![2], None), "scc2");
    let sscc2 = variant(&rt, &cfg(vec![2], Some(2)), "sscc2");

    // different frame size
    let mut wide = cfg(vec![], None);
    wide.feat = 8;
    let wide = variant(&rt, &wide, "wide");
    assert!(VariantLadder::new(vec![stmc.clone(), wide]).is_err());

    // different parameter inventory (tconv extrapolation adds up2.*)
    let mut tc = cfg(vec![2], None);
    tc.extrap = vec!["tconv".into()];
    let tc = variant(&rt, &tc, "scc2_tconv");
    assert!(VariantLadder::new(vec![stmc.clone(), tc]).is_err());

    // duplicate names
    assert!(VariantLadder::new(vec![stmc.clone(), stmc.clone()]).is_err());

    // a compatible ladder validates and exposes the warmup bound
    let ladder = VariantLadder::new(vec![stmc, scc2.clone(), sscc2]).unwrap();
    assert_eq!(ladder.len(), 3);
    assert!(ladder.max_warmup() >= warmup_frames(&scc2.manifest.config));
}

#[test]
fn adaptive_server_downgrades_under_pressure() {
    let rt = Arc::new(Runtime::native());
    let ladder = Arc::new(
        VariantLadder::new(vec![
            variant(&rt, &cfg(vec![], None), "stmc"),
            variant(&rt, &cfg(vec![2], None), "scc2"),
            variant(&rt, &cfg(vec![2], Some(2)), "sscc2"),
        ])
        .unwrap(),
    );
    let mut server = Server::with_ladder(ladder.clone(), 2);
    // any traffic is overload: downgrade all the way, immediately
    server.adaptive = Some(AdaptivePolicy {
        target_p99_us: 0,
        queue_high: 1,
        queue_low: 0,
        patience_down: 1,
        patience_up: 1_000_000,
        cooldown: 0,
        window: 8,
        headroom: 0.5,
    });
    let n_streams = 6;
    let n_frames = 48;
    let streams = random_streams(4, n_streams, n_frames, 0xD0);
    let report = server.run(&streams).unwrap();

    assert_eq!(report.frames, (n_streams * n_frames) as u64, "every frame served");
    for sid in 0..n_streams as u64 {
        assert_eq!(report.outputs[&sid].len(), n_frames, "stream {sid} complete");
    }
    assert!(report.metrics.migrations > 0, "streams migrated under load");
    assert!(report.metrics.macs_migration > 0.0, "replay cost recorded");
    assert!(
        report.metrics.variant_frames.len() >= 2,
        "traffic ran on more than one rung: {:?}",
        report.metrics.variant_frames
    );
    assert!(
        report.final_levels.values().all(|&l| l == 2),
        "every stream ended on the cheapest rung: {:?}",
        report.final_levels
    );
    // batching survived the ladder split: grouped by (rung, phase)
    assert!(report.metrics.batch_size.count() > 0, "no batched frames");
}

#[test]
fn calm_adaptive_server_matches_pinned_serving_bit_exactly() {
    let rt = Arc::new(Runtime::native());
    let stmc = variant(&rt, &cfg(vec![], None), "stmc");
    let ladder = Arc::new(
        VariantLadder::new(vec![
            stmc.clone(),
            variant(&rt, &cfg(vec![2], None), "scc2"),
        ])
        .unwrap(),
    );
    let streams = random_streams(4, 5, 30, 0xCA1);

    let pinned = Server::new(stmc, 2).run(&streams).unwrap();

    // a policy that can never fire: nothing is overload, upgrades from
    // rung 0 are a no-op
    let mut calm = Server::with_ladder(ladder.clone(), 2);
    calm.adaptive = Some(AdaptivePolicy {
        target_p99_us: u64::MAX / 2,
        queue_high: usize::MAX,
        queue_low: usize::MAX,
        patience_down: 1_000_000,
        patience_up: 1_000_000,
        cooldown: 0,
        window: 8,
        headroom: 0.5,
    });
    let calm_report = calm.run(&streams).unwrap();

    // a multi-rung ladder with adaptive off must also stay pinned
    let off = Server::with_ladder(ladder, 2).run(&streams).unwrap();

    for r in [&calm_report, &off] {
        assert_eq!(r.metrics.migrations, 0);
        assert!(r.final_levels.values().all(|&l| l == 0));
        for sid in 0..5u64 {
            assert_eq!(
                r.outputs[&sid], pinned.outputs[&sid],
                "stream {sid} diverged from pinned serving"
            );
        }
    }
}

#[test]
fn paced_dispatch_serves_every_frame_identically() {
    let rt = Arc::new(Runtime::native());
    let cv = variant(&rt, &cfg(vec![2], None), "scc2");
    let streams = random_streams(4, 4, 24, 0xBEEF);
    let server = Server::new(cv, 2);
    let flooded = server.run(&streams).unwrap();
    let paced = server.run_paced(&streams, &[200]).unwrap();
    assert_eq!(paced.frames, flooded.frames);
    for sid in 0..4u64 {
        assert_eq!(paced.outputs[&sid], flooded.outputs[&sid]);
    }
}
