//! Batched == sequential equivalence (DESIGN.md §8).
//!
//! The native backend's `step_batch` must be *bit-identical* to N
//! independent `step` calls — outputs and every per-stream state tensor —
//! for every variant family `runtime::synth` can produce (pure STMC,
//! single/double S-CC, tconv extrapolation, SS-CC, hybrid FP both ways,
//! predictive).  Also covered: the batched FP rest pass against
//! per-session precompute + step_rest, mixed-phase session groups batched
//! through `StreamSession::on_frame_batch`, phase-mismatch rejection, and
//! the server with batching on vs off.

use std::sync::Arc;

use soi::coordinator::{Server, StreamSession};
use soi::runtime::{synth, CompiledVariant, ModelConfig, Runtime, StateSet};
use soi::util::rng::Rng;

fn rt() -> Arc<Runtime> {
    Arc::new(Runtime::native())
}

fn cfg(
    feat: usize,
    channels: Vec<usize>,
    scc: Vec<usize>,
    shift_pos: Option<usize>,
) -> ModelConfig {
    ModelConfig {
        feat,
        channels,
        kernel: 3,
        extrap: vec!["duplicate".into(); scc.len()],
        scc,
        shift_pos,
        shift: 1,
        interp: None,
    }
}

fn variant(c: &ModelConfig, name: &str) -> CompiledVariant {
    let m = synth::manifest(c, name, 32);
    let w = synth::he_weights(&m, 0xFEED);
    CompiledVariant::with_weights(rt(), m, w).expect("compile native variant")
}

/// One small config per variant family the synthesizer knows.
fn families() -> Vec<(&'static str, ModelConfig)> {
    let mut tconv = cfg(4, vec![6, 8], vec![2], None);
    tconv.extrap = vec!["tconv".into()];
    let mut pred2 = cfg(4, vec![6, 8], vec![], Some(1));
    pred2.shift = 2;
    let mut spred = cfg(4, vec![5, 6, 7], vec![2], Some(1));
    spred.shift = 2;
    vec![
        ("stmc", cfg(4, vec![6, 8], vec![], None)),
        ("scc2", cfg(4, vec![5, 6, 7], vec![2], None)),
        ("scc1_3", cfg(4, vec![5, 6, 7], vec![1, 3], None)),
        ("scc2_tconv", tconv),
        ("sscc2", cfg(4, vec![5, 6, 7], vec![2], Some(2))),
        ("fp1_3", cfg(4, vec![5, 6, 7], vec![1], Some(3))),
        ("shift_below", cfg(4, vec![5, 6, 7], vec![3], Some(1))),
        ("pred2", pred2),
        ("spred2", spred),
    ]
}

fn random_streams(feat: usize, n: usize, t: usize, seed: u64) -> Vec<Vec<Vec<f32>>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            (0..t)
                .map(|_| (0..feat).map(|_| rng.normal() as f32 * 0.3).collect())
                .collect()
        })
        .collect()
}

fn assert_states_identical(name: &str, a: &[StateSet], b: &[StateSet]) {
    for (si, (sa, sb)) in a.iter().zip(b).enumerate() {
        for (ta, tb) in sa.tensors.iter().zip(&sb.tensors) {
            assert_eq!(ta.data, tb.data, "{name}: stream {si} state diverged");
        }
    }
}

#[test]
fn step_batch_is_bit_identical_to_sequential() {
    for (name, c) in families() {
        let cv = variant(&c, name);
        let dw = cv.device_weights().unwrap();
        let n = 5usize;
        let t = 4 * cv.manifest.period;
        let streams = random_streams(c.feat, n, t, 0xBA7C4);

        // sequential reference
        let mut seq_states: Vec<StateSet> = (0..n).map(|_| cv.init_states()).collect();
        let mut seq_out: Vec<Vec<Vec<f32>>> = vec![Vec::new(); n];
        for tt in 0..t {
            for si in 0..n {
                let o = cv
                    .step(tt, &streams[si][tt], &mut seq_states[si], &dw)
                    .unwrap();
                seq_out[si].push(o);
            }
        }

        // batched
        let mut bat_states: Vec<StateSet> = (0..n).map(|_| cv.init_states()).collect();
        for tt in 0..t {
            let frame_refs: Vec<&[f32]> = (0..n).map(|si| streams[si][tt].as_slice()).collect();
            let mut st_refs: Vec<&mut StateSet> = bat_states.iter_mut().collect();
            let outs = cv.step_batch(tt, &frame_refs, &mut st_refs, &dw).unwrap();
            assert_eq!(outs.len(), n);
            for (si, out) in outs.iter().enumerate() {
                assert_eq!(
                    out, &seq_out[si][tt],
                    "{name}: stream {si} frame {tt} diverged"
                );
            }
        }
        assert_states_identical(name, &seq_states, &bat_states);
    }
}

#[test]
fn step_rest_batch_matches_sequential_fp_split() {
    for (name, c) in families() {
        let cv = variant(&c, name);
        if !cv.has_fp_split() {
            continue;
        }
        let dw = cv.device_weights().unwrap();
        let n = 4usize;
        let t = 3 * cv.manifest.period;
        let streams = random_streams(c.feat, n, t, 0xF00D);

        let mut seq_states: Vec<StateSet> = (0..n).map(|_| cv.init_states()).collect();
        let mut seq_out: Vec<Vec<Vec<f32>>> = vec![Vec::new(); n];
        for tt in 0..t {
            for si in 0..n {
                cv.precompute(tt, &mut seq_states[si], &dw).unwrap();
                let o = cv
                    .step_rest(tt, &streams[si][tt], &mut seq_states[si], &dw)
                    .unwrap();
                seq_out[si].push(o);
            }
        }

        let mut bat_states: Vec<StateSet> = (0..n).map(|_| cv.init_states()).collect();
        for tt in 0..t {
            // precompute stays per-session (idle-time work)...
            for st in bat_states.iter_mut() {
                cv.precompute(tt, st, &dw).unwrap();
            }
            // ...the on-arrival rest pass runs batched
            let frame_refs: Vec<&[f32]> = (0..n).map(|si| streams[si][tt].as_slice()).collect();
            let mut st_refs: Vec<&mut StateSet> = bat_states.iter_mut().collect();
            let outs = cv
                .step_rest_batch(tt, &frame_refs, &mut st_refs, &dw)
                .unwrap();
            for (si, out) in outs.iter().enumerate() {
                assert_eq!(
                    out, &seq_out[si][tt],
                    "{name}: rest pass stream {si} frame {tt} diverged"
                );
            }
        }
        assert_states_identical(name, &seq_states, &bat_states);
    }
}

#[test]
fn mixed_phase_groups_match_per_session_serving() {
    // Sessions staggered to different schedule phases: grouping by
    // next_plan().phase and batching each group must reproduce the
    // per-session path exactly (this is what the server's worker does).
    for (name, c) in [
        ("scc1_3", cfg(4, vec![5, 6, 7], vec![1, 3], None)),
        ("sscc2", cfg(4, vec![5, 6, 7], vec![2], Some(2))),
    ] {
        let cv = Arc::new(variant(&c, name));
        let dw = Arc::new(cv.device_weights().unwrap());
        let n = 5usize;
        let t = 8usize;
        let streams = random_streams(c.feat, n, t + n, 0x517A);

        // reference: per-session serving, stream si offset by si frames
        let mut ref_sessions: Vec<StreamSession> = (0..n)
            .map(|si| StreamSession::new(si as u64, cv.clone(), dw.clone()))
            .collect();
        let mut ref_out: Vec<Vec<Vec<f32>>> = vec![Vec::new(); n];
        for (si, sess) in ref_sessions.iter_mut().enumerate() {
            for tt in 0..si {
                sess.on_frame(&streams[si][tt]).unwrap(); // warmup offset
            }
        }
        for tt in 0..t {
            for (si, sess) in ref_sessions.iter_mut().enumerate() {
                ref_out[si].push(sess.on_frame(&streams[si][si + tt]).unwrap());
            }
        }

        // batched: same stagger, grouped by phase each round
        let mut sessions: Vec<StreamSession> = (0..n)
            .map(|si| StreamSession::new(si as u64, cv.clone(), dw.clone()))
            .collect();
        for (si, sess) in sessions.iter_mut().enumerate() {
            for tt in 0..si {
                sess.on_frame(&streams[si][tt]).unwrap();
            }
        }
        let period = cv.manifest.period;
        for tt in 0..t {
            // snapshot the phase groups BEFORE executing any batch — a
            // served group advances its sessions' schedulers, and
            // re-evaluating next_plan() mid-round would serve them twice
            let mut groups: Vec<Vec<usize>> = vec![Vec::new(); period];
            for si in 0..n {
                groups[sessions[si].next_plan().phase].push(si);
            }
            for group in groups {
                if group.is_empty() {
                    continue;
                }
                let frames: Vec<&[f32]> = group
                    .iter()
                    .map(|&si| streams[si][si + tt].as_slice())
                    .collect();
                let mut sess_refs: Vec<&mut StreamSession> = sessions
                    .iter_mut()
                    .enumerate()
                    .filter(|(si, _)| group.contains(si))
                    .map(|(_, sess)| sess)
                    .collect();
                let outs = StreamSession::on_frame_batch(&mut sess_refs, &frames).unwrap();
                drop(sess_refs);
                for (&si, out) in group.iter().zip(outs) {
                    assert_eq!(
                        out, ref_out[si][tt],
                        "{name}: staggered stream {si} round {tt} diverged"
                    );
                }
            }
        }
    }
}

#[test]
fn on_frame_batch_rejects_phase_mismatch() {
    let c = cfg(4, vec![5, 6, 7], vec![2], None); // period 2
    let cv = Arc::new(variant(&c, "scc2"));
    let dw = Arc::new(cv.device_weights().unwrap());
    let mut a = StreamSession::new(0, cv.clone(), dw.clone());
    let mut b = StreamSession::new(1, cv.clone(), dw.clone());
    let f = vec![0.1f32; 4];
    a.on_frame(&f).unwrap(); // a now at phase 1, b at phase 0
    let frames: Vec<&[f32]> = vec![&f, &f];
    let mut sessions = [&mut a, &mut b];
    assert!(StreamSession::on_frame_batch(&mut sessions[..], &frames).is_err());
}

#[test]
fn server_batching_on_and_off_produce_identical_outputs() {
    for (name, c) in [
        ("scc2", cfg(4, vec![5, 6, 7], vec![2], None)),
        ("sscc2", cfg(4, vec![5, 6, 7], vec![2], Some(2))),
    ] {
        let cv = Arc::new(variant(&c, name));
        let n_streams = 6usize;
        // unequal lengths so worker shards drift out of phase alignment
        let mut rng = Rng::new(0x5EED);
        let streams: Vec<Vec<Vec<f32>>> = (0..n_streams)
            .map(|si| {
                (0..(20 + 3 * si))
                    .map(|_| (0..4).map(|_| rng.normal() as f32 * 0.3).collect())
                    .collect()
            })
            .collect();

        let mut batched = Server::new(cv.clone(), 2);
        batched.batching = true;
        let rb = batched.run(&streams).unwrap();

        let mut sequential = Server::new(cv.clone(), 2);
        sequential.batching = false;
        let rs = sequential.run(&streams).unwrap();

        assert_eq!(rb.frames, rs.frames);
        for sid in 0..n_streams as u64 {
            assert_eq!(
                rb.outputs[&sid], rs.outputs[&sid],
                "{name}: stream {sid} diverged between batched and sequential serving"
            );
        }
        // the batched run actually batched something
        assert!(rb.metrics.batch_size.count() > 0, "{name}: no batched frames");
        assert_eq!(rs.metrics.batch_size.count(), 0);
    }
}
