//! Quantized execution (DESIGN.md §10): fidelity, bit-exactness and
//! cross-precision serving.
//!
//! The acceptance gates of the quant subsystem:
//!
//! * output SNR vs the f32 reference ≥ 40 dB on every synthesized
//!   variant family (stmc, scc2, sscc5 — full-size presets, denoise
//!   distribution, calibration on a *different* signal seed);
//! * batched == sequential bit-identity for `QuantExec` (outputs and
//!   every state tensor), mirroring `tests/batch_equivalence.rs`;
//! * the FP precompute/rest split equals the monolithic step;
//! * a mixed-precision ladder validates, and a migration across
//!   precisions (f32 → int8 and back) is bit-identical to a fresh
//!   session under the int8 path's own determinism contract
//!   (mirroring `tests/adaptive_serving.rs`);
//! * executed int8 MACs match the scheduler's analytic accounting, and
//!   the server's `macs_int8` attribution sees them.

use std::sync::Arc;

use soi::coordinator::stream::{macs_at_phase, StreamSession};
use soi::coordinator::{AdaptivePolicy, Server};
use soi::dsp::{frames, siggen};
use soi::runtime::{
    synth, warmup_frames, CompiledVariant, Dtype, ModelConfig, Runtime, StateSet, VariantLadder,
};
use soi::util::rng::Rng;

fn rt() -> Arc<Runtime> {
    Arc::new(Runtime::native())
}

fn cfg(
    feat: usize,
    channels: Vec<usize>,
    scc: Vec<usize>,
    shift_pos: Option<usize>,
) -> ModelConfig {
    ModelConfig {
        feat,
        channels,
        kernel: 3,
        extrap: vec!["duplicate".into(); scc.len()],
        scc,
        shift_pos,
        shift: 1,
        interp: None,
    }
}

/// Compile a variant at the requested precision over the shared
/// deterministic weight set (same seed ⇒ identical f32 tensors for both
/// precisions — the cross-precision ladder contract).
fn variant(rt: &Arc<Runtime>, c: &ModelConfig, name: &str, dtype: Dtype) -> Arc<CompiledVariant> {
    let mut m = synth::manifest(c, name, 32);
    let w = synth::he_weights(&m, 0xFEED);
    if dtype == Dtype::Int8 {
        m.dtype = Dtype::Int8;
        m.quant = Some(soi::quant::calibrate(&m, &w, 128, 0xCA1).expect("calibration"));
    }
    Arc::new(CompiledVariant::with_weights(rt.clone(), m, w).expect("compile"))
}

/// One small config per variant family (the `batch_equivalence` set).
fn families() -> Vec<(&'static str, ModelConfig)> {
    let mut tconv = cfg(4, vec![6, 8], vec![2], None);
    tconv.extrap = vec!["tconv".into()];
    let mut pred2 = cfg(4, vec![6, 8], vec![], Some(1));
    pred2.shift = 2;
    vec![
        ("stmc", cfg(4, vec![6, 8], vec![], None)),
        ("scc2", cfg(4, vec![5, 6, 7], vec![2], None)),
        ("scc1_3", cfg(4, vec![5, 6, 7], vec![1, 3], None)),
        ("scc2_tconv", tconv),
        ("sscc2", cfg(4, vec![5, 6, 7], vec![2], Some(2))),
        ("fp1_3", cfg(4, vec![5, 6, 7], vec![1], Some(3))),
        ("shift_below", cfg(4, vec![5, 6, 7], vec![3], Some(1))),
        ("pred2", pred2),
    ]
}

fn random_streams(feat: usize, n: usize, t: usize, seed: u64) -> Vec<Vec<Vec<f32>>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            (0..t)
                .map(|_| (0..feat).map(|_| rng.normal() as f32 * 0.3).collect())
                .collect()
        })
        .collect()
}

fn assert_states_identical(name: &str, a: &[StateSet], b: &[StateSet]) {
    for (si, (sa, sb)) in a.iter().zip(b).enumerate() {
        for (ta, tb) in sa.tensors.iter().zip(&sb.tensors) {
            assert_eq!(ta.data, tb.data, "{name}: stream {si} state diverged");
        }
    }
}

#[test]
fn quant_snr_exceeds_40db_on_all_families() {
    // Full-size presets with the CLI/bench seed path: calibration runs
    // on its own synthesized signal, evaluation on a different seed of
    // the same denoise distribution.
    let rt = rt();
    let n_frames = 256usize;
    for name in ["stmc", "scc2", "sscc5"] {
        let c = synth::preset(name).unwrap();
        let f32_cv = synth::variant_with_dtype(rt.clone(), &c, name, 11, Dtype::F32).unwrap();
        let int8_cv = synth::variant_with_dtype(
            rt.clone(),
            &c,
            &format!("{name}:int8"),
            11,
            Dtype::Int8,
        )
        .unwrap();
        let feat = c.feat;
        let mut rng = Rng::new(0xE7A1);
        let (noisy, _) = siggen::denoise_pair(&mut rng, feat * n_frames, siggen::FS);
        let (cols, _) = frames(&noisy, feat);

        let dw_f = f32_cv.device_weights().unwrap();
        let dw_q = int8_cv.device_weights().unwrap();
        let mut st_f = f32_cv.init_states();
        let mut st_q = int8_cv.init_states();
        let mut sig = 0.0f64;
        let mut err = 0.0f64;
        for (t, col) in cols.iter().enumerate() {
            let yf = f32_cv.step(t, col, &mut st_f, &dw_f).unwrap();
            let yq = int8_cv.step(t, col, &mut st_q, &dw_q).unwrap();
            for (a, b) in yf.iter().zip(&yq) {
                sig += (*a as f64) * (*a as f64);
                let e = *a as f64 - *b as f64;
                err += e * e;
            }
        }
        let snr = 10.0 * (sig / err.max(1e-30)).log10();
        assert!(
            snr >= 40.0,
            "{name}: int8 output SNR {snr:.2} dB below the 40 dB acceptance bar"
        );
    }
}

#[test]
fn quant_step_batch_is_bit_identical_to_sequential() {
    let rt = rt();
    for (name, c) in families() {
        let cv = variant(&rt, &c, name, Dtype::Int8);
        let dw = cv.device_weights().unwrap();
        let n = 5usize;
        let t = 4 * cv.manifest.period;
        let streams = random_streams(c.feat, n, t, 0xBA7C4);

        let mut seq_states: Vec<StateSet> = (0..n).map(|_| cv.init_states()).collect();
        let mut seq_out: Vec<Vec<Vec<f32>>> = vec![Vec::new(); n];
        for tt in 0..t {
            for si in 0..n {
                let o = cv
                    .step(tt, &streams[si][tt], &mut seq_states[si], &dw)
                    .unwrap();
                seq_out[si].push(o);
            }
        }

        let mut bat_states: Vec<StateSet> = (0..n).map(|_| cv.init_states()).collect();
        for tt in 0..t {
            let frame_refs: Vec<&[f32]> = (0..n).map(|si| streams[si][tt].as_slice()).collect();
            let mut st_refs: Vec<&mut StateSet> = bat_states.iter_mut().collect();
            let outs = cv.step_batch(tt, &frame_refs, &mut st_refs, &dw).unwrap();
            for (si, out) in outs.iter().enumerate() {
                assert_eq!(
                    out, &seq_out[si][tt],
                    "{name}: stream {si} frame {tt} diverged"
                );
            }
        }
        assert_states_identical(name, &seq_states, &bat_states);
    }
}

#[test]
fn quant_fp_split_matches_monolithic_step() {
    let rt = rt();
    for (name, c) in families() {
        let cv = variant(&rt, &c, name, Dtype::Int8);
        if !cv.has_fp_split() {
            continue;
        }
        let dw = cv.device_weights().unwrap();
        let t = 4 * cv.manifest.period.max(2);
        let frames = random_streams(c.feat, 1, t, 0xF00D).remove(0);

        let mut st_all = cv.init_states();
        let mut st_split = cv.init_states();
        for (tt, f) in frames.iter().enumerate() {
            let a = cv.step(tt, f, &mut st_all, &dw).unwrap();
            cv.precompute(tt, &mut st_split, &dw).unwrap();
            let b = cv.step_rest(tt, f, &mut st_split, &dw).unwrap();
            assert_eq!(a, b, "{name}: frame {tt} split output diverged");
        }
        assert_states_identical(name, &[st_all], &[st_split]);
    }
}

#[test]
fn quant_offline_matches_streaming() {
    let rt = rt();
    for (name, c) in [
        ("stmc", cfg(4, vec![6, 8], vec![], None)),
        ("scc2", cfg(4, vec![5, 6, 7], vec![2], None)),
    ] {
        let cv = variant(&rt, &c, name, Dtype::Int8);
        let dw = cv.device_weights().unwrap();
        let t = 4 * cv.manifest.period.max(2);
        let frames = random_streams(c.feat, 1, t, 0x0FF1).remove(0);
        let mut x = soi::util::tensor::Tensor::zeros(vec![c.feat, t]);
        for (tt, f) in frames.iter().enumerate() {
            for (i, &v) in f.iter().enumerate() {
                x.set2(i, tt, v);
            }
        }
        let off = cv.offline(&x, &dw).unwrap();
        let mut st = cv.init_states();
        for (tt, f) in frames.iter().enumerate() {
            let y = cv.step(tt, f, &mut st, &dw).unwrap();
            for (i, &v) in y.iter().enumerate() {
                assert_eq!(v, off.at2(i, tt), "{name}: offline diverged at t={tt}");
            }
        }
    }
}

#[test]
fn quant_executed_macs_match_scheduler_accounting() {
    let rt = rt();
    for (name, c) in [
        ("stmc", cfg(4, vec![6, 8], vec![], None)),
        ("scc2", cfg(4, vec![5, 6, 7], vec![2], None)),
        ("sscc2", cfg(4, vec![5, 6, 7], vec![2], Some(2))),
    ] {
        let cv = variant(&rt, &c, name, Dtype::Int8);
        let dw = cv.device_weights().unwrap();
        let t = 4 * cv.manifest.period;
        let frames = random_streams(c.feat, 1, t, 0x3AC5).remove(0);
        let mut st = cv.init_states();
        cv.reset_executed_macs();
        for (tt, f) in frames.iter().enumerate() {
            cv.step(tt, f, &mut st, &dw).unwrap();
        }
        let analytic: f64 = (0..t).map(|tt| macs_at_phase(&cv.manifest, tt)).sum();
        assert_eq!(
            cv.executed_macs().unwrap() as f64,
            analytic,
            "{name}: measured int8 MACs != scheduler accounting"
        );
    }
}

#[test]
fn cross_precision_migration_is_bit_exact() {
    let rt = rt();
    // (from cfg/dtype, to cfg/dtype): f32 → int8 at both unchanged and
    // deepened compression, and int8 → f32 back up the ladder.
    let pairs = [
        (
            ("stmc", cfg(4, vec![5, 6, 7], vec![], None), Dtype::F32),
            ("stmc:int8", cfg(4, vec![5, 6, 7], vec![], None), Dtype::Int8),
        ),
        (
            ("stmc", cfg(4, vec![5, 6, 7], vec![], None), Dtype::F32),
            ("scc2:int8", cfg(4, vec![5, 6, 7], vec![2], None), Dtype::Int8),
        ),
        (
            ("scc2:int8", cfg(4, vec![5, 6, 7], vec![2], None), Dtype::Int8),
            ("stmc", cfg(4, vec![5, 6, 7], vec![], None), Dtype::F32),
        ),
        (
            ("stmc:int8", cfg(4, vec![5, 6, 7], vec![], None), Dtype::Int8),
            ("sscc2:int8", cfg(4, vec![5, 6, 7], vec![2], Some(2)), Dtype::Int8),
        ),
    ];
    for ((na, ca, da), (nb, cb, db)) in pairs {
        let a = variant(&rt, &ca, na, da);
        let b = variant(&rt, &cb, nb, db);
        let dw = Arc::new(a.device_weights().unwrap());
        let warm = warmup_frames(&cb);
        let pb = b.manifest.period as u64;
        let long = (warm as u64 + 9).div_ceil(pb) * pb;
        for t_switch in [long as usize, 2 * pb as usize] {
            let total = t_switch + 16;
            let frames = random_streams(4, 1, total, 0xA11CE ^ t_switch as u64).remove(0);

            let mut sess = StreamSession::new(0, a.clone(), dw.clone());
            sess.set_history_cap(warm);
            for f in &frames[..t_switch] {
                sess.on_frame(f).unwrap();
            }
            sess.migrate_to(&b).unwrap();
            assert_eq!(sess.variant_name(), nb);
            assert_eq!(sess.dtype(), db, "{na}->{nb}: dtype follows the engine");
            let mut migrated = Vec::new();
            for f in &frames[t_switch..] {
                migrated.push(sess.on_frame(f).unwrap());
            }

            let mut fresh = StreamSession::new(1, b.clone(), dw.clone());
            let mut reference = Vec::new();
            for (tt, f) in frames.iter().enumerate() {
                let out = fresh.on_frame(f).unwrap();
                if tt >= t_switch {
                    reference.push(out);
                }
            }
            assert_eq!(
                migrated, reference,
                "{na}->{nb} at t={t_switch}: post-migration outputs diverged"
            );
            if db == Dtype::Int8 {
                assert!(
                    sess.metrics.macs_int8 > 0.0,
                    "{na}->{nb}: replay into int8 attributes int8 MACs"
                );
            }
        }
    }
}

#[test]
fn adaptive_server_reaches_int8_rungs() {
    let rt = rt();
    let ladder = Arc::new(
        VariantLadder::new(vec![
            variant(&rt, &cfg(4, vec![5, 6, 7], vec![], None), "stmc", Dtype::F32),
            variant(&rt, &cfg(4, vec![5, 6, 7], vec![], None), "stmc:int8", Dtype::Int8),
            variant(&rt, &cfg(4, vec![5, 6, 7], vec![2], None), "scc2:int8", Dtype::Int8),
        ])
        .unwrap(),
    );
    assert!(ladder.has_int8());
    let mut server = Server::with_ladder(ladder.clone(), 2);
    // any traffic is overload: downgrade all the way, immediately
    server.adaptive = Some(AdaptivePolicy {
        target_p99_us: 0,
        queue_high: 1,
        queue_low: 0,
        patience_down: 1,
        patience_up: 1_000_000,
        cooldown: 0,
        window: 8,
        headroom: 0.5,
    });
    let n_streams = 6;
    let n_frames = 48;
    let streams = random_streams(4, n_streams, n_frames, 0xD0);
    let report = server.run(&streams).unwrap();

    assert_eq!(report.frames, (n_streams * n_frames) as u64, "every frame served");
    assert!(report.metrics.migrations > 0, "streams migrated under load");
    assert!(
        report.final_levels.values().all(|&l| l == 2),
        "every stream ended on the cheapest (int8) rung: {:?}",
        report.final_levels
    );
    assert!(
        report.metrics.macs_int8 > 0.0,
        "int8 MAC attribution saw quantized traffic"
    );
    assert!(
        report.metrics.int8_fraction() > 0.0 && report.metrics.int8_fraction() <= 1.0,
        "int8 fraction in (0, 1]: {}",
        report.metrics.int8_fraction()
    );
    assert!(
        report.metrics.variant_frames.keys().any(|k| k.ends_with(":int8")),
        "per-variant frame counts name the int8 rungs: {:?}",
        report.metrics.variant_frames
    );
    // batching survived the mixed-precision split: grouped by (rung, phase)
    assert!(report.metrics.batch_size.count() > 0, "no batched frames");
}

#[test]
fn pinned_int8_server_batching_on_off_identical() {
    let rt = rt();
    let cv = variant(&rt, &cfg(4, vec![5, 6, 7], vec![2], None), "scc2:int8", Dtype::Int8);
    let mut rng = Rng::new(0x5EED);
    let streams: Vec<Vec<Vec<f32>>> = (0..5)
        .map(|si| {
            (0..(20 + 3 * si))
                .map(|_| (0..4).map(|_| rng.normal() as f32 * 0.3).collect())
                .collect()
        })
        .collect();
    let mut batched = Server::new(cv.clone(), 2);
    batched.batching = true;
    let rb = batched.run(&streams).unwrap();
    let mut sequential = Server::new(cv, 2);
    sequential.batching = false;
    let rs = sequential.run(&streams).unwrap();
    assert_eq!(rb.frames, rs.frames);
    for sid in 0..5u64 {
        assert_eq!(
            rb.outputs[&sid], rs.outputs[&sid],
            "stream {sid} diverged between batched and sequential int8 serving"
        );
    }
    assert!(rb.metrics.batch_size.count() > 0);
    // the whole run was quantized
    assert!((rb.metrics.int8_fraction() - 1.0).abs() < 1e-12);
}
