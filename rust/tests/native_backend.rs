//! Native-backend cross-checks (no artifacts, no Python, no network):
//!
//! * streaming step == offline forward for every variant family (pure
//!   STMC, single/double S-CC, tconv extrapolation, SS-CC, hybrid FP,
//!   predictive) — the paper's core exactness guarantee (eq. 3–7);
//! * the FP pre/rest split reproduces the monolithic step bit-for-bit;
//! * outputs match reference values computed independently from the
//!   python reference kernels (`python/compile/kernels/ref.py`
//!   semantics), baked in for a tiny 2-layer STMC conv manifest with
//!   fully deterministic weights;
//! * measured MACs at phase p equal the scheduler's analytic
//!   `macs_at_phase(manifest, p)` — accounting is not just a formula;
//! * the multi-stream server produces the same outputs as a
//!   single-stream session on the native backend.

use std::sync::Arc;

use soi::coordinator::stream::{macs_at_phase, macs_stmc};
use soi::coordinator::{Server, StreamSession};
use soi::runtime::{synth, CompiledVariant, Manifest, ModelConfig, Runtime, Weights};
use soi::util::rng::Rng;
use soi::util::tensor::Tensor;

fn rt() -> Arc<Runtime> {
    Arc::new(Runtime::native())
}

fn cfg(
    feat: usize,
    channels: Vec<usize>,
    scc: Vec<usize>,
    shift_pos: Option<usize>,
) -> ModelConfig {
    ModelConfig {
        feat,
        channels,
        kernel: 3,
        extrap: vec!["duplicate".into(); scc.len()],
        scc,
        shift_pos,
        shift: 1,
        interp: None,
    }
}

fn variant(c: &ModelConfig, name: &str) -> CompiledVariant {
    let m = synth::manifest(c, name, 32);
    let w = synth::he_weights(&m, 0xFEED);
    CompiledVariant::with_weights(rt(), m, w).expect("compile native variant")
}

fn random_input(feat: usize, t: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let data: Vec<f32> = (0..feat * t).map(|_| rng.normal() as f32 * 0.3).collect();
    Tensor::new(vec![feat, t], data)
}

/// Stream frame-by-frame through the step path; returns t blocks of feat.
fn stream_through(cv: &CompiledVariant, x: &Tensor, split: bool) -> Vec<f32> {
    let feat = cv.manifest.config.feat;
    let t = x.shape[1];
    let dw = cv.device_weights().unwrap();
    let mut states = cv.init_states();
    let mut out = Vec::with_capacity(feat * t);
    let mut frame = vec![0.0f32; feat];
    for tt in 0..t {
        for (i, f) in frame.iter_mut().enumerate() {
            *f = x.at2(i, tt);
        }
        let phase = tt % cv.manifest.period;
        let o = if split {
            cv.precompute(phase, &mut states, &dw).unwrap();
            cv.step_rest(phase, &frame, &mut states, &dw).unwrap()
        } else {
            cv.step(phase, &frame, &mut states, &dw).unwrap()
        };
        out.extend_from_slice(&o);
    }
    out
}

fn assert_stream_matches_offline(c: &ModelConfig, name: &str, split: bool) {
    let cv = variant(c, name);
    let t = 16;
    let x = random_input(c.feat, t, 42);
    let dw = cv.device_weights().unwrap();
    let off = cv.offline(&x, &dw).unwrap();
    let streamed = stream_through(&cv, &x, split);
    let mut max_err = 0.0f32;
    for tt in 0..t {
        for i in 0..c.feat {
            let a = streamed[tt * c.feat + i];
            let b = off.at2(i, tt);
            max_err = max_err.max((a - b).abs());
        }
    }
    assert!(
        max_err < 1e-5,
        "{name} (split={split}): streaming vs offline max err {max_err}"
    );
}

#[test]
fn stmc_streaming_equals_offline() {
    assert_stream_matches_offline(&cfg(4, vec![6, 8], vec![], None), "stmc", false);
}

#[test]
fn scc_streaming_equals_offline() {
    assert_stream_matches_offline(&cfg(4, vec![5, 6, 7], vec![2], None), "scc2", false);
}

#[test]
fn double_scc_streaming_equals_offline() {
    assert_stream_matches_offline(&cfg(4, vec![5, 6, 7], vec![1, 3], None), "scc1_3", false);
}

#[test]
fn tconv_streaming_equals_offline() {
    let mut c = cfg(4, vec![6, 8], vec![2], None);
    c.extrap = vec!["tconv".into()];
    assert_stream_matches_offline(&c, "scc2_tconv", false);
}

#[test]
fn sscc_monolithic_and_split_equal_offline() {
    let c = cfg(4, vec![5, 6, 7], vec![2], Some(2));
    assert_stream_matches_offline(&c, "sscc2", false);
    assert_stream_matches_offline(&c, "sscc2", true);
}

#[test]
fn hybrid_fp_shift_below_scc_equals_offline() {
    // FP shift below the S-CC position: exercises the handoff slot.
    let c = cfg(4, vec![5, 6, 7], vec![3], Some(1));
    assert_stream_matches_offline(&c, "shift_below", false);
    assert_stream_matches_offline(&c, "shift_below", true);
}

#[test]
fn hybrid_fp_shift_above_scc_equals_offline() {
    // The aot.py fp<p>_<q> family: S-CC at p, shift above it at q — the
    // delay-line FIFO then lives in a rate-divided (compressed) domain.
    let c = cfg(4, vec![5, 6, 7], vec![1], Some(3)); // fp1_3
    assert_stream_matches_offline(&c, "fp1_3", false);
    assert_stream_matches_offline(&c, "fp1_3", true);
    let c2 = cfg(4, vec![5, 6, 7], vec![2], Some(3));
    assert_stream_matches_offline(&c2, "fp2_3", false);
    assert_stream_matches_offline(&c2, "fp2_3", true);
}

#[test]
fn hybrid_fp_preset_is_splittable() {
    // The synthesized fp presets must actually run the pre/rest split
    // (fp1_3 == scc=[1], shift at 3 — shift_pos not in scc).
    let c = synth::preset("fp1_3").unwrap();
    assert_eq!(c.scc, vec![1]);
    assert_eq!(c.shift_pos, Some(3));
    let cv = variant(&c, "fp1_3");
    assert!(cv.has_fp_split());
}

#[test]
fn predictive_split_equals_offline() {
    let mut c = cfg(4, vec![6, 8], vec![], Some(1));
    c.shift = 2;
    assert_stream_matches_offline(&c, "pred2", false);
    assert_stream_matches_offline(&c, "pred2", true);
}

#[test]
fn precompute_runs_before_any_frame() {
    let c = cfg(4, vec![5, 6, 7], vec![2], Some(2));
    let cv = variant(&c, "sscc2");
    let dw = cv.device_weights().unwrap();
    let mut states = cv.init_states();
    cv.precompute(0, &mut states, &dw).unwrap();
}

#[test]
fn non_fp_variant_refuses_precompute() {
    let cv = variant(&cfg(4, vec![6, 8], vec![], None), "stmc");
    let dw = cv.device_weights().unwrap();
    let mut states = cv.init_states();
    assert!(cv.precompute(0, &mut states, &dw).is_err());
    assert!(!cv.has_fp_split());
}

#[test]
fn interp_is_offline_only() {
    let mut c = cfg(4, vec![6, 8], vec![2], None);
    c.interp = Some("linear".into());
    let cv = variant(&c, "scc2_ilinear");
    let dw = cv.device_weights().unwrap();
    let x = random_input(4, 16, 5);
    let out = cv.offline(&x, &dw).unwrap();
    assert_eq!(out.shape, vec![4, 16]);
    let mut states = cv.init_states();
    let frame = vec![0.0f32; 4];
    assert!(cv.step(0, &frame, &mut states, &dw).is_err());
}

#[test]
fn offline_rejects_partial_period() {
    let cv = variant(&cfg(4, vec![5, 6, 7], vec![1, 3], None), "scc1_3");
    let dw = cv.device_weights().unwrap();
    let x = random_input(4, 6, 1); // 6 % 4 != 0
    assert!(cv.offline(&x, &dw).is_err());
}

// ---------------------------------------------------------------------------
// Reference-kernel cross-check: outputs baked from an independent
// implementation of python/compile/kernels/ref.py + model.py semantics
// (f64), for fully deterministic pattern weights:
//   kernel tensor ti, element j: (((j*7 + ti*3) % 11) - 5) / 16
//   bias, element j:             ((j % 5) - 2) / 32
//   input sample j:              (((j*5) % 17) - 8) / 16
// ---------------------------------------------------------------------------

const EXPECTED_STMC: [f32; 32] = [
    -0.07473192, 0.04143375, -0.01161698, 0.03192598,
    -0.01157201, 0.08705511, 0.0316568, -0.01577427,
    -0.0931153, -0.1220912, 0.02783043, 0.06811045,
    -0.1173613, 0.007374842, 0.06678371, -0.02625506,
    0.002537131, 0.04184413, -0.1187127, -0.01305773,
    -0.005254611, 0.03047984, -0.1168691, 0.07891243,
    -0.1754361, 0.04537053, 0.04593579, 0.1323277,
    0.04192133, 0.1145318, 0.03865359, -0.09356854,
];

const EXPECTED_SCC2: [f32; 32] = [
    -0.07473192, 0.04143375, -0.01161698, 0.03192598,
    -0.01216716, 0.08837815, 0.02393687, -0.008534885,
    -0.04618491, -0.1130169, -0.003858703, 0.04135305,
    -0.116992, -0.01180475, 0.03209389, 0.002280347,
    -0.1260398, 0.1071763, -0.006210243, 0.02529562,
    0.002992927, 0.1093491, -0.03045442, -0.01550423,
    -0.04093235, 0.003577901, -0.07013121, 0.07527115,
    0.02495972, 0.02799381, -0.05485172, 0.02055001,
];

fn pattern_weights(m: &Manifest) -> Weights {
    let tensors = m
        .params
        .iter()
        .enumerate()
        .map(|(ti, spec)| {
            let n = spec.elements();
            let data: Vec<f32> = if spec.shape.len() == 1 {
                (0..n).map(|j| ((j % 5) as f32 - 2.0) / 32.0).collect()
            } else {
                (0..n)
                    .map(|j| (((j * 7 + ti * 3) % 11) as f32 - 5.0) / 16.0)
                    .collect()
            };
            Tensor::new(spec.shape.clone(), data)
        })
        .collect();
    Weights { tensors }
}

fn pattern_input(feat: usize, t: usize) -> Tensor {
    let mut x = Tensor::zeros(vec![feat, t]);
    for tt in 0..t {
        for i in 0..feat {
            let j = tt * feat + i;
            x.set2(i, tt, (((j * 5) % 17) as f32 - 8.0) / 16.0);
        }
    }
    x
}

fn assert_matches_reference(c: &ModelConfig, name: &str, expected: &[f32]) {
    let m = synth::manifest(c, name, 8);
    let w = pattern_weights(&m);
    let cv = CompiledVariant::with_weights(rt(), m, w).unwrap();
    let x = pattern_input(c.feat, 8);
    let dw = cv.device_weights().unwrap();

    let off = cv.offline(&x, &dw).unwrap();
    let streamed = stream_through(&cv, &x, false);
    for tt in 0..8 {
        for i in 0..c.feat {
            let want = expected[tt * c.feat + i];
            let got_off = off.at2(i, tt);
            let got_stream = streamed[tt * c.feat + i];
            assert!(
                (got_off - want).abs() < 2e-3,
                "{name} offline[{i},{tt}] = {got_off}, reference {want}"
            );
            assert!(
                (got_stream - want).abs() < 2e-3,
                "{name} stream[{i},{tt}] = {got_stream}, reference {want}"
            );
        }
    }
}

#[test]
fn native_matches_reference_kernels_stmc() {
    assert_matches_reference(&cfg(4, vec![6, 8], vec![], None), "stmc", &EXPECTED_STMC);
}

#[test]
fn native_matches_reference_kernels_scc2() {
    assert_matches_reference(&cfg(4, vec![6, 8], vec![2], None), "scc2", &EXPECTED_SCC2);
}

// ---------------------------------------------------------------------------
// MAC accounting: the native backend's counted work must equal the
// scheduler's analytic per-phase sum.
// ---------------------------------------------------------------------------

fn assert_macs_match(c: &ModelConfig, name: &str) {
    let cv = variant(c, name);
    let dw = cv.device_weights().unwrap();
    let mut states = cv.init_states();
    let frame = vec![0.1f32; c.feat];
    let period = cv.manifest.period;
    let mut total = 0u64;
    for phase in 0..period {
        cv.reset_executed_macs();
        cv.step(phase, &frame, &mut states, &dw).unwrap();
        let measured = cv.executed_macs().expect("native counts MACs");
        let analytic = macs_at_phase(&cv.manifest, phase);
        assert_eq!(
            measured as f64, analytic,
            "{name}: phase {phase} measured {measured} vs analytic {analytic}"
        );
        total += measured;
    }
    let avg = total as f64 / period as f64;
    assert!(
        (avg - cv.manifest.macs_per_frame).abs() < 1e-9,
        "{name}: average {avg} vs manifest {}",
        cv.manifest.macs_per_frame
    );
    assert!(macs_stmc(&cv.manifest) >= cv.manifest.macs_per_frame);
}

#[test]
fn measured_macs_equal_scheduler_accounting() {
    assert_macs_match(&cfg(4, vec![6, 8], vec![], None), "stmc");
    assert_macs_match(&cfg(4, vec![5, 6, 7], vec![2], None), "scc2");
    assert_macs_match(&cfg(4, vec![5, 6, 7], vec![1, 3], None), "scc1_3");
}

#[test]
fn measured_macs_equal_scheduler_accounting_tconv() {
    let mut c = cfg(4, vec![5, 6, 7], vec![2], None);
    c.extrap = vec!["tconv".into()];
    assert_macs_match(&c, "scc2_tconv");
}

#[test]
fn fp_split_preserves_total_macs() {
    // pre + rest must execute exactly what the monolithic step would.
    let c = cfg(4, vec![5, 6, 7], vec![2], Some(2));
    let cv = variant(&c, "sscc2");
    let dw = cv.device_weights().unwrap();
    let frame = vec![0.1f32; 4];
    for phase in 0..cv.manifest.period {
        let mut s1 = cv.init_states();
        cv.reset_executed_macs();
        cv.step(phase, &frame, &mut s1, &dw).unwrap();
        let mono = cv.executed_macs().unwrap();

        let mut s2 = cv.init_states();
        cv.reset_executed_macs();
        cv.precompute(phase, &mut s2, &dw).unwrap();
        cv.step_rest(phase, &frame, &mut s2, &dw).unwrap();
        let split = cv.executed_macs().unwrap();
        assert_eq!(mono, split, "phase {phase}: split changed executed MACs");
        assert_eq!(mono as f64, macs_at_phase(&cv.manifest, phase));
    }
}

// ---------------------------------------------------------------------------
// Coordinator end-to-end on the native backend.
// ---------------------------------------------------------------------------

#[test]
fn server_matches_single_session_outputs() {
    let c = cfg(4, vec![5, 6, 7], vec![2], None);
    let cv = Arc::new(variant(&c, "scc2"));
    let n_streams = 4;
    let n_frames = 24;
    let mut rng = Rng::new(77);
    let streams: Vec<Vec<Vec<f32>>> = (0..n_streams)
        .map(|_| {
            (0..n_frames)
                .map(|_| (0..4).map(|_| rng.normal() as f32 * 0.3).collect())
                .collect()
        })
        .collect();

    let server = Server::new(cv.clone(), 2);
    let report = server.run(&streams).unwrap();
    assert_eq!(report.frames, (n_streams * n_frames) as u64);

    // Replay each stream through a fresh single session; outputs must be
    // identical (native execution is deterministic).
    let dw = Arc::new(cv.device_weights().unwrap());
    for (sid, frames) in streams.iter().enumerate() {
        let mut sess = StreamSession::new(sid as u64, cv.clone(), dw.clone());
        let served = &report.outputs[&(sid as u64)];
        assert_eq!(served.len(), n_frames);
        for (t, frame) in frames.iter().enumerate() {
            let out = sess.on_frame(frame).unwrap();
            assert_eq!(out, served[t], "stream {sid} frame {t} diverged");
        }
    }
}

#[test]
fn session_state_bytes_match_manifest() {
    let c = cfg(4, vec![5, 6, 7], vec![2], Some(2));
    let cv = Arc::new(variant(&c, "sscc2"));
    let dw = Arc::new(cv.device_weights().unwrap());
    let manifest_bytes = cv.manifest.state_bytes;
    let sess = StreamSession::new(0, cv, dw);
    assert_eq!(sess.state_bytes(), manifest_bytes);
}
