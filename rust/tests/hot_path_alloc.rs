//! Zero-allocation steady state (DESIGN.md §11).
//!
//! A counting `#[global_allocator]` shim wraps the system allocator;
//! after a warm-up pass (which populates the per-variant `StepArena`,
//! the output buffers' capacity, and — for int8 — the packed quantized
//! plan), every `step`/`step_rest`/`precompute`/`step_batch` through the
//! `_into` entry points must perform **zero** heap allocations, for
//! every variant family at both execution precisions.
//!
//! A second leg re-proves the guarantee with **telemetry enabled**
//! (DESIGN.md §12): exec spans, FP pre/rest spans, counters, gauges,
//! round events and cross-shard trace spans (DESIGN.md §15) recorded
//! through a real `ObsHandle` — with a ring tiny enough that the
//! overflow (drop-newest) path runs inside the measured window.  The
//! first leg runs with the trace plumbing compiled in but telemetry
//! off, pinning the zero-overhead-when-off claim.
//!
//! Everything lives in ONE `#[test]` on purpose: the counter is global,
//! and the standard harness runs separate tests on separate threads —
//! parallel tests would pollute each other's windows.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use soi::backend::VariantExec;
use soi::quant::calibrate;
use soi::runtime::{synth, Dtype, Runtime, StateSet};

/// System allocator with an allocation-event counter (alloc, realloc
/// and alloc_zeroed all count; frees do not — we gate on *new* memory).
struct Counting;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: pure delegation to `System`; the counter has no side effects
// on allocation behaviour.
unsafe impl GlobalAlloc for Counting {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static COUNTER: Counting = Counting;

const BATCH: usize = 3;

/// Drive `rounds` full schedule periods of single-stream + batched
/// steps (FP variants run precompute + rest, mirroring the serving
/// loop).  Reuses every caller-side buffer, so with a warm arena the
/// exec layer is the only possible allocation source.
#[allow(clippy::too_many_arguments)]
fn drive(
    exec: &dyn VariantExec,
    dw: &soi::runtime::DeviceWeights,
    period: usize,
    feat: usize,
    t0: &mut usize,
    st: &mut StateSet,
    stb: &mut [StateSet; BATCH],
    out: &mut Vec<f32>,
    outs: &mut Vec<Vec<f32>>,
    frame: &[f32],
    rounds: usize,
) {
    assert_eq!(frame.len(), feat);
    let fp = exec.has_fp_split();
    for _ in 0..rounds * period {
        let t = *t0;
        *t0 += 1;
        // single stream
        if fp {
            exec.precompute(t, st, dw).unwrap();
            exec.step_rest_into(t, frame, st, dw, out).unwrap();
        } else {
            exec.step_into(t, frame, st, dw, out).unwrap();
        }
        assert_eq!(out.len(), feat);
        // phase-aligned batch of BATCH streams
        let fr: [&[f32]; BATCH] = [frame, frame, frame];
        if fp {
            for s in stb.iter_mut() {
                exec.precompute(t, s, dw).unwrap();
            }
            let mut it = stb.iter_mut();
            let mut refs: [&mut StateSet; BATCH] =
                [it.next().unwrap(), it.next().unwrap(), it.next().unwrap()];
            exec.step_rest_batch_into(t, &fr, &mut refs, dw, outs).unwrap();
        } else {
            let mut it = stb.iter_mut();
            let mut refs: [&mut StateSet; BATCH] =
                [it.next().unwrap(), it.next().unwrap(), it.next().unwrap()];
            exec.step_batch_into(t, &fr, &mut refs, dw, outs).unwrap();
        }
        assert_eq!(outs.len(), BATCH);
    }
}

/// Like [`drive`], but recording telemetry the way the serving worker
/// does (DESIGN.md §12): an exec span per dispatch, FP pre/rest spans
/// for split variants, and one compound round record (counter + gauges
/// + a round event) per frame.
#[allow(clippy::too_many_arguments)]
fn drive_obs(
    exec: &dyn VariantExec,
    dw: &soi::runtime::DeviceWeights,
    period: usize,
    feat: usize,
    t0: &mut usize,
    st: &mut StateSet,
    stb: &mut [StateSet; BATCH],
    out: &mut Vec<f32>,
    outs: &mut Vec<Vec<f32>>,
    frame: &[f32],
    rounds: usize,
    obs: &soi::obs::ObsHandle,
) {
    use soi::obs::{Counter, EventKind, Gauge, SpanKind, TraceCtx};
    use std::time::Instant;
    assert_eq!(frame.len(), feat);
    let fp = exec.has_fp_split();
    for _ in 0..rounds * period {
        let t = *t0;
        *t0 += 1;
        let phase = t % period;
        let t_round = Instant::now();
        // single stream
        if fp {
            let t_pre = Instant::now();
            exec.precompute(t, st, dw).unwrap();
            obs.fp_pre(0, phase, false, t_pre.elapsed().as_nanos() as u64);
            let t_rest = Instant::now();
            exec.step_rest_into(t, frame, st, dw, out).unwrap();
            obs.fp_rest(phase, 1, t_rest.elapsed().as_nanos() as u64);
        } else {
            let t_exec = Instant::now();
            exec.step_into(t, frame, st, dw, out).unwrap();
            obs.exec(0, phase, 1, t_exec.elapsed().as_nanos() as u64);
        }
        // phase-aligned batch of BATCH streams
        let fr: [&[f32]; BATCH] = [frame, frame, frame];
        let t_exec = Instant::now();
        if fp {
            for s in stb.iter_mut() {
                exec.precompute(t, s, dw).unwrap();
            }
            let mut it = stb.iter_mut();
            let mut refs: [&mut StateSet; BATCH] =
                [it.next().unwrap(), it.next().unwrap(), it.next().unwrap()];
            exec.step_rest_batch_into(t, &fr, &mut refs, dw, outs).unwrap();
        } else {
            let mut it = stb.iter_mut();
            let mut refs: [&mut StateSet; BATCH] =
                [it.next().unwrap(), it.next().unwrap(), it.next().unwrap()];
            exec.step_batch_into(t, &fr, &mut refs, dw, outs).unwrap();
        }
        obs.exec(0, phase, BATCH, t_exec.elapsed().as_nanos() as u64);
        // cross-shard trace plumbing (DESIGN.md §15): treat every frame
        // in the window as sampled — context derivation is pure stack
        // math and span records ride the same preallocated ring, so
        // tracing must add zero allocations too
        let ctx = TraceCtx::root(t as u64 + 1, SpanKind::ShardDispatch);
        let leaf = ctx.child(SpanKind::WorkerRound).child(SpanKind::PhaseExec);
        obs.with(|w| {
            w.count(Counter::Rounds, 1);
            w.push_event(
                EventKind::Round,
                1 + BATCH as u64,
                0,
                1 + BATCH as u64,
                t_round.elapsed().as_nanos() as u64,
                0,
            );
            w.span(
                ctx.trace_id,
                SpanKind::WorkerRound,
                ctx.kind,
                0,
                1 + BATCH as u64,
                t_round.elapsed().as_nanos() as u64,
            );
            w.span(
                leaf.trace_id,
                SpanKind::PhaseExec,
                leaf.parent,
                phase as u64,
                BATCH as u64,
                t_round.elapsed().as_nanos() as u64,
            );
            w.gauge_set(Gauge::QueueDepth, 0);
            w.gauge_set(Gauge::StreamsLive, 1 + BATCH as u64);
            w.gauge_max(Gauge::ArenaPeakBytes, soi::kernels::thread_peak_bytes() as u64);
        });
    }
}

#[test]
fn zero_steady_state_allocations_for_all_families_and_dtypes() {
    // Family coverage: pure STMC, single/double S-CC, SS-CC (shift at
    // the S-CC position), hybrid FP, whole-network FP (shift at 1, the
    // f32-valued handoff), and a learned-tconv extrapolation variant.
    let presets = ["stmc", "scc2", "scc2_5", "sscc5", "fp1_3", "pred2"];
    let rt = Runtime::native();
    for dtype in [Dtype::F32, Dtype::Int8] {
        let mut cases: Vec<(String, soi::runtime::Manifest)> = Vec::new();
        for base in presets {
            let cfg = synth::preset(base).unwrap();
            cases.push((base.to_string(), synth::manifest(&cfg, base, 32)));
        }
        // learned-tconv extrapolation (presets default to duplication)
        let mut tcfg = synth::preset("scc3").unwrap();
        tcfg.extrap = vec!["tconv".into()];
        cases.push(("scc3tconv".to_string(), synth::manifest(&tcfg, "scc3tconv", 32)));

        for (name, mut m) in cases {
            let w = synth::he_weights(&m, 0xA110C);
            if dtype == Dtype::Int8 {
                m.dtype = Dtype::Int8;
                m.quant = Some(calibrate(&m, &w, 64, 7).unwrap());
            }
            let exec = rt.compile_variant(&m).unwrap();
            let dw = rt.upload_weights(&w).unwrap();
            let feat = m.config.feat;
            let period = m.period;
            let frame: Vec<f32> = (0..feat).map(|i| ((i * 7) as f32 * 0.07).sin() * 0.4).collect();
            let mut st = exec.init_states();
            let mut stb: [StateSet; BATCH] =
                [exec.init_states(), exec.init_states(), exec.init_states()];
            let mut out: Vec<f32> = Vec::new();
            let mut outs: Vec<Vec<f32>> = Vec::new();
            let mut t0 = 0usize;

            // Warm-up: arena slabs, output capacity, quantized plan.
            drive(
                exec.as_ref(),
                &dw,
                period,
                feat,
                &mut t0,
                &mut st,
                &mut stb,
                &mut out,
                &mut outs,
                &frame,
                2,
            );

            // Steady state: two more full periods, zero allocations.
            let before = ALLOCS.load(Ordering::Relaxed);
            drive(
                exec.as_ref(),
                &dw,
                period,
                feat,
                &mut t0,
                &mut st,
                &mut stb,
                &mut out,
                &mut outs,
                &frame,
                2,
            );
            let after = ALLOCS.load(Ordering::Relaxed);
            assert_eq!(
                after - before,
                0,
                "{name} ({}) allocated {} times in the steady state",
                dtype.as_str(),
                after - before
            );
        }
    }

    // --- telemetry-enabled leg (DESIGN.md §12) ---
    // The same guarantee must hold with spans + registry active, for one
    // FP f32 family and one int8 family.  ring_capacity is tiny on
    // purpose: the measured window overflows it, so the drop-newest path
    // is proven allocation-free too.
    let tel = soi::obs::Telemetry::new(soi::obs::ObsConfig { ring_capacity: 8 });
    tel.install_global(); // routes the int8 warm-up's quant repack here
    for (base, dtype) in [("fp1_3", Dtype::F32), ("scc2", Dtype::Int8)] {
        let cfg = synth::preset(base).unwrap();
        let mut m = synth::manifest(&cfg, base, 32);
        let w = synth::he_weights(&m, 0xA110C);
        if dtype == Dtype::Int8 {
            m.dtype = Dtype::Int8;
            m.quant = Some(calibrate(&m, &w, 64, 7).unwrap());
        }
        let exec = rt.compile_variant(&m).unwrap();
        let dw = rt.upload_weights(&w).unwrap();
        let feat = m.config.feat;
        let period = m.period;
        let frame: Vec<f32> = (0..feat).map(|i| ((i * 7) as f32 * 0.07).sin() * 0.4).collect();
        let mut st = exec.init_states();
        let mut stb: [StateSet; BATCH] =
            [exec.init_states(), exec.init_states(), exec.init_states()];
        let mut out: Vec<f32> = Vec::new();
        let mut outs: Vec<Vec<f32>> = Vec::new();
        let mut t0 = 0usize;
        let obs = tel.worker(0);

        // Warm-up: arena + plan as before, plus the registry's lazy
        // per-(rung, phase) histogram inserts (one per live key).
        drive_obs(
            exec.as_ref(),
            &dw,
            period,
            feat,
            &mut t0,
            &mut st,
            &mut stb,
            &mut out,
            &mut outs,
            &frame,
            2,
            &obs,
        );

        let before = ALLOCS.load(Ordering::Relaxed);
        drive_obs(
            exec.as_ref(),
            &dw,
            period,
            feat,
            &mut t0,
            &mut st,
            &mut stb,
            &mut out,
            &mut outs,
            &frame,
            2,
            &obs,
        );
        let after = ALLOCS.load(Ordering::Relaxed);
        assert_eq!(
            after - before,
            0,
            "{base} ({}) allocated {} times in the telemetry-enabled steady state",
            dtype.as_str(),
            after - before
        );
    }
    // telemetry really recorded: counters advanced, the quant repack
    // reached the shared handle through the global hook, and the tiny
    // ring exercised its counted-drop overflow path
    tel.worker(0)
        .with(|w| assert!(w.counter(soi::obs::Counter::Rounds) > 0));
    tel.shared()
        .with(|w| assert!(w.counter(soi::obs::Counter::QuantRepacks) >= 1));
    let mut drained = Vec::new();
    let dropped = tel.worker(0).with(|w| w.drain_events(&mut drained));
    assert!(!drained.is_empty());
    assert!(dropped > 0, "the 8-slot ring should have overflowed");
    assert!(
        drained
            .iter()
            .any(|e| e.kind == soi::obs::EventKind::Span),
        "trace spans reached the ring alongside round events"
    );
    soi::obs::Telemetry::uninstall_global();
}
