//! Zero-allocation steady state (DESIGN.md §11).
//!
//! A counting `#[global_allocator]` shim wraps the system allocator;
//! after a warm-up pass (which populates the per-variant `StepArena`,
//! the output buffers' capacity, and — for int8 — the packed quantized
//! plan), every `step`/`step_rest`/`precompute`/`step_batch` through the
//! `_into` entry points must perform **zero** heap allocations, for
//! every variant family at both execution precisions.
//!
//! Everything lives in ONE `#[test]` on purpose: the counter is global,
//! and the standard harness runs separate tests on separate threads —
//! parallel tests would pollute each other's windows.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use soi::backend::VariantExec;
use soi::quant::calibrate;
use soi::runtime::{synth, Dtype, Runtime, StateSet};

/// System allocator with an allocation-event counter (alloc, realloc
/// and alloc_zeroed all count; frees do not — we gate on *new* memory).
struct Counting;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: pure delegation to `System`; the counter has no side effects
// on allocation behaviour.
unsafe impl GlobalAlloc for Counting {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static COUNTER: Counting = Counting;

const BATCH: usize = 3;

/// Drive `rounds` full schedule periods of single-stream + batched
/// steps (FP variants run precompute + rest, mirroring the serving
/// loop).  Reuses every caller-side buffer, so with a warm arena the
/// exec layer is the only possible allocation source.
#[allow(clippy::too_many_arguments)]
fn drive(
    exec: &dyn VariantExec,
    dw: &soi::runtime::DeviceWeights,
    period: usize,
    feat: usize,
    t0: &mut usize,
    st: &mut StateSet,
    stb: &mut [StateSet; BATCH],
    out: &mut Vec<f32>,
    outs: &mut Vec<Vec<f32>>,
    frame: &[f32],
    rounds: usize,
) {
    assert_eq!(frame.len(), feat);
    let fp = exec.has_fp_split();
    for _ in 0..rounds * period {
        let t = *t0;
        *t0 += 1;
        // single stream
        if fp {
            exec.precompute(t, st, dw).unwrap();
            exec.step_rest_into(t, frame, st, dw, out).unwrap();
        } else {
            exec.step_into(t, frame, st, dw, out).unwrap();
        }
        assert_eq!(out.len(), feat);
        // phase-aligned batch of BATCH streams
        let fr: [&[f32]; BATCH] = [frame, frame, frame];
        if fp {
            for s in stb.iter_mut() {
                exec.precompute(t, s, dw).unwrap();
            }
            let mut it = stb.iter_mut();
            let mut refs: [&mut StateSet; BATCH] =
                [it.next().unwrap(), it.next().unwrap(), it.next().unwrap()];
            exec.step_rest_batch_into(t, &fr, &mut refs, dw, outs).unwrap();
        } else {
            let mut it = stb.iter_mut();
            let mut refs: [&mut StateSet; BATCH] =
                [it.next().unwrap(), it.next().unwrap(), it.next().unwrap()];
            exec.step_batch_into(t, &fr, &mut refs, dw, outs).unwrap();
        }
        assert_eq!(outs.len(), BATCH);
    }
}

#[test]
fn zero_steady_state_allocations_for_all_families_and_dtypes() {
    // Family coverage: pure STMC, single/double S-CC, SS-CC (shift at
    // the S-CC position), hybrid FP, whole-network FP (shift at 1, the
    // f32-valued handoff), and a learned-tconv extrapolation variant.
    let presets = ["stmc", "scc2", "scc2_5", "sscc5", "fp1_3", "pred2"];
    let rt = Runtime::native();
    for dtype in [Dtype::F32, Dtype::Int8] {
        let mut cases: Vec<(String, soi::runtime::Manifest)> = Vec::new();
        for base in presets {
            let cfg = synth::preset(base).unwrap();
            cases.push((base.to_string(), synth::manifest(&cfg, base, 32)));
        }
        // learned-tconv extrapolation (presets default to duplication)
        let mut tcfg = synth::preset("scc3").unwrap();
        tcfg.extrap = vec!["tconv".into()];
        cases.push(("scc3tconv".to_string(), synth::manifest(&tcfg, "scc3tconv", 32)));

        for (name, mut m) in cases {
            let w = synth::he_weights(&m, 0xA110C);
            if dtype == Dtype::Int8 {
                m.dtype = Dtype::Int8;
                m.quant = Some(calibrate(&m, &w, 64, 7).unwrap());
            }
            let exec = rt.compile_variant(&m).unwrap();
            let dw = rt.upload_weights(&w).unwrap();
            let feat = m.config.feat;
            let period = m.period;
            let frame: Vec<f32> = (0..feat).map(|i| ((i * 7) as f32 * 0.07).sin() * 0.4).collect();
            let mut st = exec.init_states();
            let mut stb: [StateSet; BATCH] =
                [exec.init_states(), exec.init_states(), exec.init_states()];
            let mut out: Vec<f32> = Vec::new();
            let mut outs: Vec<Vec<f32>> = Vec::new();
            let mut t0 = 0usize;

            // Warm-up: arena slabs, output capacity, quantized plan.
            drive(
                exec.as_ref(),
                &dw,
                period,
                feat,
                &mut t0,
                &mut st,
                &mut stb,
                &mut out,
                &mut outs,
                &frame,
                2,
            );

            // Steady state: two more full periods, zero allocations.
            let before = ALLOCS.load(Ordering::Relaxed);
            drive(
                exec.as_ref(),
                &dw,
                period,
                feat,
                &mut t0,
                &mut st,
                &mut stb,
                &mut out,
                &mut outs,
                &frame,
                2,
            );
            let after = ALLOCS.load(Ordering::Relaxed);
            assert_eq!(
                after - before,
                0,
                "{name} ({}) allocated {} times in the steady state",
                dtype.as_str(),
                after - before
            );
        }
    }
}
