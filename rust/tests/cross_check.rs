//! Cross-layer consistency: the rust complexity engine must agree exactly
//! with the `layer_macs` tables python embeds in every artifact manifest —
//! two independent implementations of the paper's cost semantics.

use std::path::PathBuf;

use soi::complexity::unet;
use soi::runtime::{list_variants, Manifest};

fn artifacts_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn rust_engine_matches_python_layer_macs() {
    let root = artifacts_root();
    if !root.exists() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let mut checked = 0;
    for name in list_variants(&root).unwrap() {
        let m = Manifest::load(&root.join(&name)).unwrap();
        let net = unet::network(&m.config, m.offline_t as u64, 1000.0);
        // Per-layer: every python entry must exist in the rust model with
        // identical MACs and rate divisor.
        for py in &m.layer_macs {
            let rs = net
                .layers
                .iter()
                .find(|l| l.name == py.name)
                .unwrap_or_else(|| panic!("{name}: rust engine missing layer {}", py.name));
            assert_eq!(
                rs.macs_per_out, py.macs,
                "{name}/{}: macs {} vs {}",
                py.name, rs.macs_per_out, py.macs
            );
            assert_eq!(
                rs.rate_div, py.rate_div,
                "{name}/{}: rate {} vs {}",
                py.name, rs.rate_div, py.rate_div
            );
        }
        assert_eq!(net.layers.len(), m.layer_macs.len(), "{name}: layer count");
        // Aggregate: average MACs/frame must match python's number.
        let diff = (net.soi_macs_per_frame() - m.macs_per_frame).abs();
        assert!(diff < 1e-6, "{name}: macs/frame {diff}");
        checked += 1;
    }
    assert!(checked > 0, "no variants checked");
    eprintln!("cross-checked {checked} variants");
}

#[test]
fn precomputed_fraction_matches_python() {
    let root = artifacts_root();
    if !root.exists() {
        return;
    }
    for name in list_variants(&root).unwrap() {
        let m = Manifest::load(&root.join(&name)).unwrap();
        let net = unet::network(&m.config, m.offline_t as u64, 1000.0);
        let rs = net.precomputed_pct() / 100.0;
        let py = m.precomputed_fraction;
        assert!(
            (rs - py).abs() < 1e-9,
            "{name}: precomputed {rs} vs python {py}"
        );
    }
}

#[test]
fn state_bytes_match_manifest() {
    let root = artifacts_root();
    if !root.exists() {
        return;
    }
    for name in list_variants(&root).unwrap() {
        let m = Manifest::load(&root.join(&name)).unwrap();
        let computed: usize = m.states.iter().map(|s| s.elements() * 4).sum();
        assert_eq!(computed, m.state_bytes, "{name}: state bytes");
    }
}

#[test]
fn soi_variants_have_strictly_lower_average_cost() {
    let root = artifacts_root();
    if !root.exists() {
        return;
    }
    let Ok(base) = Manifest::load(&root.join("stmc")) else { return };
    for name in list_variants(&root).unwrap() {
        let m = Manifest::load(&root.join(&name)).unwrap();
        if m.config.scc.is_empty() {
            // no compression: cost must equal STMC's
            assert!(
                (m.macs_per_frame - base.macs_per_frame).abs() < 1e-6,
                "{name}: non-SOI variant with different cost"
            );
        } else {
            assert!(
                m.macs_per_frame < base.macs_per_frame,
                "{name}: SOI variant not cheaper"
            );
        }
    }
}
