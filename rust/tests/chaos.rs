//! Fleet survival under deterministic chaos (DESIGN.md §16): a
//! front-end whose shards fail by plan — killed, stalled, partitioned,
//! corrupted — must keep every accepted stream bit-identical to a
//! single-process serve, answer everything it sheds with the exact
//! typed error, re-admit recovered shards, and account for all of it
//! exactly in the `soi.obs.v1` → `soi.cluster.v1` feed chain.
//!
//! The faults ride the [`ChaosPlan`] tick clock (one tick per
//! front→shard frame fleet-wide) or are applied by script at points
//! the test controls; either way the same run always sees the same
//! fault sequence at the same protocol step.

use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use soi::coordinator::Server;
use soi::net::{
    run_shard, spawn_front_with, ChaosFleet, ChaosPlan, ErrCode, Fault, FrontHandle, FrontPolicy,
    FrontReport, LoopbackHub, Msg, ShardConfig, ShardLink, ShardReport, Transport, WireClient,
};
use soi::obs::{aggregate, schema, take_snapshot, Counter, ObsConfig, Telemetry};
use soi::runtime::{synth, CompiledVariant, ModelConfig, Runtime};
use soi::util::rng::Rng;

fn cfg(scc: Vec<usize>) -> ModelConfig {
    ModelConfig {
        feat: 4,
        channels: vec![5, 6, 7],
        kernel: 3,
        extrap: vec!["duplicate".into(); scc.len()],
        scc,
        shift_pos: None,
        shift: 1,
        interp: None,
    }
}

fn variant(rt: &Arc<Runtime>, c: &ModelConfig, name: &str) -> Arc<CompiledVariant> {
    let m = synth::manifest(c, name, 32);
    let w = synth::he_weights(&m, 0xFEED);
    Arc::new(CompiledVariant::with_weights(rt.clone(), m, w).expect("compile native variant"))
}

fn random_frames(feat: usize, t: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..t)
        .map(|_| (0..feat).map(|_| rng.normal() as f32 * 0.3).collect())
        .collect()
}

fn random_streams(feat: usize, n: usize, t: usize, seed: u64) -> Vec<Vec<Vec<f32>>> {
    (0..n)
        .map(|i| random_frames(feat, t, seed ^ (i as u64 + 1)))
        .collect()
}

/// The exact outputs the chaos fleet must reproduce: the same streams
/// served by one in-process worker pool.
fn reference_outputs(cv: &Arc<CompiledVariant>, streams: &[Vec<Vec<f32>>]) -> Vec<Vec<Vec<f32>>> {
    let server = Server::new(cv.clone(), 2);
    let report = server.run(streams).expect("reference serve");
    (0..streams.len() as u64)
        .map(|sid| report.outputs.get(&sid).cloned().unwrap_or_default())
        .collect()
}

/// A front over N real shards, every shard behind its own chaos
/// switch, and a [`Telemetry`] root per process for feed assertions.
struct ChaosTestFleet {
    front: FrontHandle,
    hub: LoopbackHub,
    fleet: ChaosFleet,
    shard_hubs: Vec<LoopbackHub>,
    shards: Vec<JoinHandle<ShardReport>>,
    tel_front: Arc<Telemetry>,
    tels: Vec<Arc<Telemetry>>,
}

fn boot(
    cv: &Arc<CompiledVariant>,
    n_shards: usize,
    plan: &ChaosPlan,
    policy: FrontPolicy,
) -> ChaosTestFleet {
    let mut shard_hubs = Vec::new();
    let mut shards = Vec::new();
    let mut tels = Vec::new();
    for i in 0..n_shards {
        let hub = LoopbackHub::new();
        let tel = Telemetry::new(ObsConfig::default());
        let mut server = Server::new(cv.clone(), 2);
        server.telemetry = Some(tel.clone());
        let shard_hub = hub.clone();
        let shard_id = i as u64 + 1;
        shards.push(thread::spawn(move || {
            run_shard(&server, &shard_hub, ShardConfig { shard_id }).expect("shard serves")
        }));
        shard_hubs.push(hub);
        tels.push(tel);
    }
    let backends: Vec<Arc<dyn Transport>> = shard_hubs
        .iter()
        .map(|h| Arc::new(h.clone()) as Arc<dyn Transport>)
        .collect();
    let (proxy_hubs, fleet) = ChaosFleet::wrap(backends, plan);
    let links = proxy_hubs
        .into_iter()
        .enumerate()
        .map(|(i, h)| ShardLink {
            name: format!("shard-{i}"),
            transport: Box::new(h),
        })
        .collect();
    let hub = LoopbackHub::new();
    let tel_front = Telemetry::new(ObsConfig::default());
    let front = spawn_front_with(Box::new(hub.clone()), links, policy, Some(tel_front.clone()))
        .expect("front boots");
    ChaosTestFleet {
        front,
        hub,
        fleet,
        shard_hubs,
        shards,
        tel_front,
        tels,
    }
}

impl ChaosTestFleet {
    /// Quiesce and tear down in the one order that cannot hang: heal
    /// every switch (so the front's shutdown `Drain`s pass), stop the
    /// front, sever the proxies, close the shard hubs (their accept
    /// loops return), then join the shard threads.
    fn stop(self) -> (FrontReport, Vec<ShardReport>) {
        for i in 0..self.shard_hubs.len() {
            self.fleet.switch(i).apply(Fault::Heal);
        }
        let report = self.front.stop().expect("front stops");
        self.fleet.close();
        for h in &self.shard_hubs {
            h.close();
        }
        let shard_reports = self
            .shards
            .into_iter()
            .map(|j| j.join().expect("shard joins"))
            .collect();
        (report, shard_reports)
    }
}

fn send_frame(client: &mut WireClient, session: u64, seq: usize, last: bool, f: &[f32]) {
    client
        .send(&Msg::Frame {
            session,
            seq: seq as u64,
            last,
            samples: f.to_vec(),
            trace: None,
            deadline_us: None,
        })
        .expect("send frame");
}

/// Send frames `from..to` of every stream, round-robin per round —
/// the same interleaving single-process serving dispatches in.
fn send_rr(client: &mut WireClient, streams: &[Vec<Vec<f32>>], from: usize, to: usize) {
    for seq in from..to {
        for (sid, frames) in streams.iter().enumerate() {
            send_frame(client, sid as u64, seq, seq + 1 == frames.len(), &frames[seq]);
        }
    }
}

/// Receive `FrameOut`s until each session `i` holds `targets[i]`
/// outputs; anything other than an output frame fails the test.
fn collect_until(client: &mut WireClient, outs: &mut [Vec<Vec<f32>>], targets: &[usize]) {
    while outs.iter().zip(targets).any(|(o, t)| o.len() < *t) {
        match client.recv() {
            Ok(Some(Msg::FrameOut {
                session, samples, ..
            })) => {
                let sid = session as usize;
                assert!(sid < outs.len(), "output for unknown session {session}");
                outs[sid].push(samples);
            }
            other => panic!("expected FrameOut, got {other:?}"),
        }
    }
}

fn counter(tel: &Telemetry, c: Counter) -> u64 {
    let snap = take_snapshot(tel);
    snap.counters[Counter::ALL.iter().position(|x| *x == c).expect("known counter")]
}

/// Poll the front's live registry until `c` reaches `want`.  The
/// heartbeat loop keeps pinging in the background — each ping is a
/// chaos tick, so the plan's tail keeps firing even with no client
/// traffic — and the deadline only trips if recovery truly wedged.
fn await_counter(tel: &Telemetry, c: Counter, want: u64, secs: u64) {
    let deadline = Instant::now() + Duration::from_secs(secs);
    while counter(tel, c) < want {
        assert!(
            Instant::now() < deadline,
            "timed out waiting for {} >= {want}",
            c.name()
        );
        thread::sleep(Duration::from_millis(2));
    }
}

#[test]
fn fleet_survives_scripted_stall_kill_and_partition_bit_identically() {
    let rt = Arc::new(Runtime::native());
    let cv = variant(&rt, &cfg(vec![2]), "scc2");
    let total = 24usize;
    let streams = random_streams(4, 4, total, 0xFA117);
    let reference = reference_outputs(&cv, &streams);

    // One scripted episode per failure mode, each applied at a point
    // the test controls so detection is deterministic: the stall hits
    // while its shard holds live traffic (guaranteeing an unacked
    // tail to retry), the kill hits a quiet shard (EOF-detected, pure
    // replay re-home), and the partition hits an idle fleet
    // (detectable only by the miss budget).  Tick-driven plans are
    // exercised by the seeded test below.
    // A generous miss budget (~16 ms of silence) keeps detection
    // deterministic under scheduler noise: the stalled traffic of
    // phase 2 is guaranteed to be in flight before the verdict fires.
    let fleet = boot(
        &cv,
        3,
        &ChaosPlan::default(),
        FrontPolicy {
            max_sessions: 8,
            heartbeat_ms: 2,
            miss_budget: 8,
            ..FrontPolicy::default()
        },
    );
    let mut client = WireClient::connect(&fleet.hub).expect("connect");
    let mut outs = vec![Vec::new(); streams.len()];

    // Phase 1: a clean third of the traffic, fully acked, so the
    // stall's trapped window is exactly what phase 2 sends.
    send_rr(&mut client, &streams, 0, 8);
    collect_until(&mut client, &mut outs, &[8; 4]);

    // Phase 2: stall shard 1 mid-stream.  Its session's frames keep
    // being forwarded but every ack is withheld, so the miss budget —
    // not EOF — must declare it suspect and re-home the session with
    // its unacked tail re-sent; collection only completes if it does.
    fleet.fleet.switch(1).apply(Fault::Stall);
    send_rr(&mut client, &streams, 8, 16);
    collect_until(&mut client, &mut outs, &[16; 4]);
    fleet.fleet.switch(1).apply(Fault::Heal);
    // The heal flushes the stale trapped frames; the rejoin handshake
    // swallows them on a cleanly failed first attempt and retries.
    await_counter(&fleet.tel_front, Counter::ShardRejoin, 1, 60);

    // Phase 3: kill shard 2 (quiet: all inflight acked), then finish
    // the streams.  EOF re-homes its sessions by §9 history replay.
    fleet.fleet.switch(2).apply(Fault::Kill);
    send_rr(&mut client, &streams, 16, total);
    collect_until(&mut client, &mut outs, &[total; 4]);
    assert_eq!(outs, reference, "surviving streams must be bit-identical");
    fleet.fleet.switch(2).apply(Fault::Heal);
    await_counter(&fleet.tel_front, Counter::ShardRejoin, 2, 60);

    // Phase 4: partition shard 0 with every session retired — nothing
    // but the heartbeat can notice the silence.  Hold the partition
    // until the suspect verdict lands (healing earlier would mask the
    // fault), then heal and wait for the held rejoin dial to land.
    fleet.fleet.switch(0).apply(Fault::Partition);
    await_counter(&fleet.tel_front, Counter::ShardSuspect, 2, 60);
    fleet.fleet.switch(0).apply(Fault::Heal);
    await_counter(&fleet.tel_front, Counter::ShardRejoin, 3, 60);
    client.shutdown();
    let tel_front = fleet.tel_front.clone();
    let tels = fleet.tels.clone();
    let (front, shard_reports) = fleet.stop();

    assert_eq!(front.shed, 0, "nothing was shed");
    assert_eq!(
        front.frames_out,
        (streams.len() * total) as u64,
        "every accepted frame was answered exactly once"
    );
    assert!(front.shard_losses >= 3, "each episode lost its shard once");
    assert!(
        front.shard_suspects >= 2,
        "stall and partition were caught by the miss budget, not by EOF"
    );
    assert!(front.heartbeat_misses >= 1);
    assert!(front.shard_rejoins >= 3, "every faulted shard was re-admitted");
    assert!(front.migrations >= 1, "recovery re-homes are warm migrations");
    assert!(front.frames_retried >= 1, "the unacked tail was re-sent");
    let served: u64 = shard_reports.iter().map(|s| s.frames_in).sum();
    assert!(
        served >= (streams.len() * total) as u64,
        "every answered frame was executed at least once"
    );

    // The same story through the feed chain: each process's
    // soi.obs.v1 feed validates, they aggregate, and the cluster
    // totals of the survival counters equal the front's report — the
    // exact-accounting contract of DESIGN.md §16.
    let mut feeds = Vec::new();
    let mut text = String::new();
    take_snapshot(&tel_front).render_ndjson(0, 0, &mut text);
    schema::validate_feed(&text).expect("front feed validates");
    feeds.push(("front".to_string(), text));
    for (i, tel) in tels.iter().enumerate() {
        let mut text = String::new();
        take_snapshot(tel).render_ndjson(0, 0, &mut text);
        schema::validate_feed(&text).expect("shard feed validates");
        feeds.push((format!("shard-{i}"), text));
    }
    let cluster = aggregate(&feeds).expect("aggregate");
    assert_eq!(cluster.counter_total(Counter::ShardRejoin), front.shard_rejoins);
    assert_eq!(cluster.counter_total(Counter::ShardSuspect), front.shard_suspects);
    assert_eq!(cluster.counter_total(Counter::HeartbeatMiss), front.heartbeat_misses);
    assert_eq!(cluster.counter_total(Counter::FramesRetried), front.frames_retried);
    assert_eq!(cluster.counter_total(Counter::AdmissionShed), 0);
    let mut out = String::new();
    cluster.render_ndjson(&mut out);
    schema::validate_cluster_feed(&out).expect("cluster feed validates");
}

#[test]
fn seeded_chaos_plan_preserves_every_accepted_stream() {
    let rt = Arc::new(Runtime::native());
    let cv = variant(&rt, &cfg(vec![2]), "scc2");
    let total = 24usize;
    let streams = random_streams(4, 3, total, 0x5EED5);
    let reference = reference_outputs(&cv, &streams);

    // Non-overlapping seeded episodes (kill/stall/partition/corrupt):
    // at most one shard is down at a time, so nothing is ever shed
    // and the outputs must be exactly the single-process serve.
    let plan = ChaosPlan::seeded(0xC4A05, 3, 30, 4);
    let fleet = boot(
        &cv,
        3,
        &plan,
        FrontPolicy {
            max_sessions: 8,
            heartbeat_ms: 2,
            miss_budget: 2,
            ..FrontPolicy::default()
        },
    );
    let mut client = WireClient::connect(&fleet.hub).expect("connect");
    send_rr(&mut client, &streams, 0, total);
    let mut outs = vec![Vec::new(); streams.len()];
    collect_until(&mut client, &mut outs, &[total; 3]);
    assert_eq!(outs, reference, "streams must survive the seeded plan bit-identically");

    // Heartbeat pings keep the clock moving, so the whole plan fires
    // even after client traffic ends — including the final heals.
    let deadline = Instant::now() + Duration::from_secs(60);
    while fleet.fleet.unfired() > 0 {
        assert!(Instant::now() < deadline, "plan stopped firing");
        thread::sleep(Duration::from_millis(2));
    }
    client.shutdown();
    let reports = fleet.fleet.reports();
    let (front, _) = fleet.stop();
    assert_eq!(front.shed, 0, "non-overlapping episodes never degrade the fleet");
    assert_eq!(front.frames_out, (streams.len() * total) as u64);
    let switch_ticks: u64 = reports.iter().map(|r| r.ticks).sum();
    assert!(switch_ticks > 0, "the plan's clock was driven by real traffic");
}

#[test]
fn degraded_fleet_sheds_with_typed_overloaded_until_rejoin() {
    let rt = Arc::new(Runtime::native());
    let cv = variant(&rt, &cfg(vec![2]), "scc2");
    let frames = random_frames(4, 1, 0xDE6);

    // Two shards, and policy demands both for new admissions.
    let fleet = boot(
        &cv,
        2,
        &ChaosPlan::default(),
        FrontPolicy {
            max_sessions: 1024,
            heartbeat_ms: 2,
            miss_budget: 2,
            min_live_shards: 2,
            ..FrontPolicy::default()
        },
    );
    let mut client = WireClient::connect(&fleet.hub).expect("connect");

    // Healthy fleet admits and serves a one-frame session.
    send_frame(&mut client, 0, 0, true, &frames[0]);
    match client.recv() {
        Ok(Some(Msg::FrameOut { session: 0, .. })) => {}
        other => panic!("expected FrameOut for session 0, got {other:?}"),
    }

    // Kill one shard: the front sees EOF, the live count drops below
    // the floor, and the next new session is shed with the exact
    // typed error.  A first attempt may race the loss event and be
    // admitted — that session is served normally, never half-served.
    fleet.fleet.switch(1).apply(Fault::Kill);
    let mut sid = 1u64;
    let mut shed = false;
    for _ in 0..1000 {
        send_frame(&mut client, sid, 0, true, &frames[0]);
        match client.recv() {
            Ok(Some(Msg::FrameOut { session, .. })) => {
                assert_eq!(session, sid, "raced admission still serves exactly once");
                sid += 1;
            }
            Ok(Some(Msg::Err {
                code,
                session,
                detail,
            })) => {
                assert_eq!(code, ErrCode::Overloaded, "exact typed error ({detail})");
                assert_eq!(session, sid, "the shed names the refused session");
                shed = true;
                break;
            }
            other => panic!("expected FrameOut or Overloaded, got {other:?}"),
        }
    }
    assert!(shed, "the degraded fleet never shed an admission");

    // Heal: the rejoin loop re-dials (the held dial completes now),
    // the shard is re-admitted, and new sessions are served again.
    fleet.fleet.switch(1).apply(Fault::Heal);
    sid += 1;
    let mut admitted = false;
    for _ in 0..5000 {
        send_frame(&mut client, sid, 0, true, &frames[0]);
        match client.recv() {
            Ok(Some(Msg::FrameOut { session, .. })) => {
                assert_eq!(session, sid);
                admitted = true;
                break;
            }
            Ok(Some(Msg::Err { code, .. })) => {
                assert_eq!(code, ErrCode::Overloaded, "still degraded while rejoining");
                sid += 1;
                thread::sleep(Duration::from_millis(1));
            }
            other => panic!("expected FrameOut or Overloaded, got {other:?}"),
        }
    }
    assert!(admitted, "the fleet never recovered after heal");
    client.shutdown();
    let (front, _) = fleet.stop();
    assert!(front.shed >= 1, "sheds were counted");
    assert!(front.shard_rejoins >= 1, "the healed shard rejoined");
    assert_eq!(front.denied, 0, "shedding is not admission denial");
}

#[test]
fn target_death_during_pending_migration_drops_nothing() {
    // Regression for the drain-vs-migration race: frames held behind
    // a pending migration exist nowhere else once the old home is
    // drained.  If the target dies around the handoff, every held and
    // in-flight frame must still be answered — the front stages the
    // full tail as in-flight before flushing, so shard loss re-homes
    // it instead of dropping whatever a local buffer still held.
    let rt = Arc::new(Runtime::native());
    let cv = variant(&rt, &cfg(vec![2]), "scc2");
    let total = 24usize;
    let frames = random_frames(4, total, 0x9A3E);
    let reference = reference_outputs(&cv, std::slice::from_ref(&frames));

    let fleet = boot(&cv, 2, &ChaosPlan::default(), FrontPolicy::default());
    let mut client = WireClient::connect(&fleet.hub).expect("connect");
    let half = total / 2;
    for (i, f) in frames[..half].iter().enumerate() {
        send_frame(&mut client, 0, i, false, f);
    }
    let mut outs = vec![Vec::new()];
    collect_until(&mut client, &mut outs, &[half]);

    // One unacked frame keeps the nomination pending; the frames sent
    // behind it are held by the front.
    send_frame(&mut client, 0, half, false, &frames[half]);
    fleet.front.migrate(0, 1).expect("nominate shard 1");
    for (i, f) in frames[half + 1..half + 5].iter().enumerate() {
        send_frame(&mut client, 0, half + 1 + i, false, f);
    }
    // The target dies around the handoff — before it, at it, or just
    // after, depending on scheduling; all three orderings must be
    // zero-drop.
    fleet.fleet.switch(1).apply(Fault::Kill);
    for (i, f) in frames[half + 5..].iter().enumerate() {
        let seq = half + 5 + i;
        send_frame(&mut client, 0, seq, seq + 1 == total, f);
    }
    collect_until(&mut client, &mut outs, &[total]);
    assert_eq!(outs[0], reference[0], "the full stream is bit-identical");
    client.shutdown();

    let (front, _) = fleet.stop();
    assert_eq!(front.frames_out, total as u64, "zero dropped frames");
    assert!(front.shard_losses >= 1, "the dead target was noticed");
    assert_eq!(front.shed, 0, "recovery needed no shedding");
}
