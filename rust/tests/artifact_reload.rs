//! Zero-downtime weight-generation hot reload (DESIGN.md §13).
//!
//! Three proofs over a live batched server.  (1) Equivalence: when a
//! new generation is published mid-run, no stream drops a frame, the
//! run ends on the new generation, and every stream's output is a clean
//! split — a prefix bit-identical to a cold session on the old weights
//! and a suffix bit-identical to a cold session on the new weights,
//! with the cut on a phase-0 boundary (§9 history replay makes the
//! migrated state indistinguishable from a cold start).  The telemetry
//! feed carries the `gen_reload` event and passes the shared validator.
//! (2) Fault containment: a [`GenerationWatcher`] that finds a corrupt
//! candidate on disk rejects it and the server keeps serving the old
//! generation, bit-for-bit.  (3) The full disk path: a valid artifact
//! saved mid-run is picked up by the watcher and swapped in live.

use std::fs;
use std::path::PathBuf;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use soi::coordinator::{Generation, GenerationWatcher, Server, StreamSession};
use soi::obs::{schema, Exporter, ObsConfig, Telemetry};
use soi::runtime::{
    synth, Artifact, CompiledVariant, ModelConfig, Runtime, VariantLadder, Weights,
};
use soi::util::rng::Rng;

fn cfg() -> ModelConfig {
    ModelConfig {
        feat: 4,
        channels: vec![5, 6, 7],
        kernel: 3,
        extrap: vec!["duplicate".into()],
        scc: vec![2],
        shift_pos: None,
        shift: 1,
        interp: None,
    }
}

/// Compile the single `scc2` rung over `weights` exactly the way the
/// watcher does, so cold references are bit-comparable to served output.
fn rung_over(rt: &Arc<Runtime>, c: &ModelConfig, weights: &Weights) -> Arc<CompiledVariant> {
    VariantLadder::over_weights(rt.clone(), c, weights, &["scc2"], 0xFEED)
        .expect("compile scc2 over weights")
        .level(0)
        .clone()
}

fn random_streams(feat: usize, n: usize, t: usize, seed: u64) -> Vec<Vec<Vec<f32>>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            (0..t)
                .map(|_| (0..feat).map(|_| rng.normal() as f32 * 0.3).collect())
                .collect()
        })
        .collect()
}

/// Cold-start outputs: one fresh session per stream over `cv`.
fn cold_outputs(cv: &Arc<CompiledVariant>, streams: &[Vec<Vec<f32>>]) -> Vec<Vec<Vec<f32>>> {
    let dw = Arc::new(cv.device_weights().unwrap());
    streams
        .iter()
        .enumerate()
        .map(|(id, frames)| {
            let mut sess = StreamSession::new(id as u64, cv.clone(), dw.clone());
            frames.iter().map(|f| sess.on_frame(f).unwrap()).collect()
        })
        .collect()
}

/// The swap point of one served stream: the largest `k` such that
/// `served[..k] == old[..k]` and `served[k..] == new[k..]` — panics if
/// no such clean split exists (a glitched frame matching neither).
fn split_index(served: &[Vec<f32>], old: &[Vec<f32>], new: &[Vec<f32>]) -> usize {
    let k = served
        .iter()
        .zip(old)
        .take_while(|(s, o)| s == o)
        .count();
    assert_eq!(
        &served[k..],
        &new[k..],
        "outputs after the swap at frame {k} must be bit-identical to a \
         cold start on the new generation"
    );
    k
}

fn tmp_root(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("soi_reload_{tag}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&p);
    fs::create_dir_all(&p).unwrap();
    p
}

fn save_generation(root: &PathBuf, c: &ModelConfig, seed: u64, generation: u64) -> Artifact {
    let m = synth::manifest(c, "scc2", 256);
    let w = synth::he_weights(&m, seed);
    let art = Artifact::new(m, w, generation).unwrap();
    art.save(&root.join(format!("gen-{generation:06}"))).unwrap();
    art
}

#[test]
fn published_generation_swaps_in_with_zero_drops_and_split_equivalence() {
    let rt = Arc::new(Runtime::native());
    let c = cfg();
    let m = synth::manifest(&c, "scc2", 256);
    let w_old = synth::he_weights(&m, 0xA11CE);
    let w_new = synth::he_weights(&m, 0xB0B);
    let cv_old = rung_over(&rt, &c, &w_old);
    let cv_new = rung_over(&rt, &c, &w_new);
    let period = cv_old.manifest.period;

    let streams = random_streams(c.feat, 4, 64, 0xD1CE);
    let old_ref = cold_outputs(&cv_old, &streams);
    let new_ref = cold_outputs(&cv_new, &streams);
    assert_ne!(old_ref, new_ref, "generations must be distinguishable");

    let mut server = Server::with_ladder(Arc::new(VariantLadder::single(cv_old)), 2);
    let handle = server.enable_reload(1);
    let tel = Telemetry::new(ObsConfig::default());
    let feed = std::env::temp_dir().join(format!("soi_reload_feed_{}.ndjson", std::process::id()));
    let exporter = Exporter::start(tel.clone(), &feed, 5).unwrap();
    server.telemetry = Some(tel);

    // publish generation 2 roughly a third of the way into the paced run
    let publisher = {
        let handle = handle.clone();
        let ladder = Arc::new(VariantLadder::single(cv_new.clone()));
        thread::spawn(move || {
            thread::sleep(Duration::from_millis(60));
            handle.publish(Generation { seq: 2, ladder });
        })
    };
    // 64 rounds × 3 ms pacing ≈ 192 ms wall: the publish lands mid-run
    let report = server.run_paced(&streams, &[3000]).unwrap();
    publisher.join().unwrap();
    let stats = exporter.finish().unwrap();

    // zero-downtime: every frame of every stream was served
    assert_eq!(report.frames, 4 * 64);
    for (id, frames) in streams.iter().enumerate() {
        let out = &report.outputs[&(id as u64)];
        assert_eq!(out.len(), frames.len(), "stream {id} dropped frames");
    }
    assert_eq!(report.generation, 2, "run ends on the published generation");
    assert_eq!(handle.current().seq, 2);

    // split equivalence: prefix == cold old, suffix == cold new, cut on
    // a phase-0 boundary; the swap is visible mid-stream somewhere
    let mut mid_swap = 0;
    for id in 0..streams.len() {
        let served = &report.outputs[&(id as u64)];
        let k = split_index(served, &old_ref[id], &new_ref[id]);
        assert_eq!(k % period, 0, "stream {id} swapped off a phase boundary");
        if k > 0 && k < served.len() {
            mid_swap += 1;
        }
    }
    assert!(mid_swap > 0, "no stream swapped mid-run — pacing too short?");

    // the reload shows up in the health feed and the feed still validates
    assert!(stats.snapshots >= 1);
    let text = fs::read_to_string(&feed).unwrap();
    let summary = schema::validate_feed(&text).expect("live feed validates");
    assert!(summary.events >= 1);
    assert!(
        text.lines().any(|l| l.contains("\"gen_reload\"")),
        "feed is missing the gen_reload event"
    );
    fs::remove_file(&feed).ok();
}

#[test]
fn watcher_rejects_corrupt_candidate_and_old_generation_keeps_serving() {
    let rt = Arc::new(Runtime::native());
    let c = cfg();
    let root = tmp_root("reject");
    let art1 = save_generation(&root, &c, 0xA11CE, 1);
    // generation 2 exists on disk but one blob byte is flipped
    save_generation(&root, &c, 0xB0B, 2);
    let bad = root.join("gen-000002").join("weights.bin");
    let mut blob = fs::read(&bad).unwrap();
    blob[7] ^= 0x01;
    fs::write(&bad, &blob).unwrap();

    let cv1 = rung_over(&rt, &c, &art1.weights);
    let streams = random_streams(c.feat, 4, 48, 0xD2);
    let want = cold_outputs(&cv1, &streams);

    let mut server = Server::with_ladder(Arc::new(VariantLadder::single(cv1)), 2);
    let handle = server.enable_reload(1);
    let watcher = GenerationWatcher::spawn(
        rt.clone(),
        root.clone(),
        vec!["scc2".into()],
        0xFEED,
        handle.clone(),
        10,
    );
    // give the watcher time to find — and reject — the corrupt candidate
    thread::sleep(Duration::from_millis(60));
    let report = server.run_paced(&streams, &[1500]).unwrap();
    watcher.stop();

    assert_eq!(handle.current().seq, 1, "corrupt candidate must not publish");
    assert_eq!(report.generation, 1);
    for (id, frames) in streams.iter().enumerate() {
        let out = &report.outputs[&(id as u64)];
        assert_eq!(out.len(), frames.len());
        assert_eq!(
            out, &want[id],
            "stream {id}: old generation's outputs changed under a rejected reload"
        );
    }
    let _ = fs::remove_dir_all(&root);
}

#[test]
fn watcher_picks_up_valid_generation_saved_mid_run() {
    let rt = Arc::new(Runtime::native());
    let c = cfg();
    let root = tmp_root("live");
    let art1 = save_generation(&root, &c, 0xA11CE, 1);
    let cv1 = rung_over(&rt, &c, &art1.weights);
    let period = cv1.manifest.period;

    let streams = random_streams(c.feat, 4, 64, 0xD3);
    let old_ref = cold_outputs(&cv1, &streams);

    let mut server = Server::with_ladder(Arc::new(VariantLadder::single(cv1)), 2);
    let handle = server.enable_reload(1);
    let watcher = GenerationWatcher::spawn(
        rt.clone(),
        root.clone(),
        vec!["scc2".into()],
        0xFEED,
        handle.clone(),
        10,
    );

    // save generation 2 through the atomic stage-and-rename saver while
    // the paced run is in flight; the watcher must find and publish it
    let saver = {
        let (root, c) = (root.clone(), c.clone());
        thread::spawn(move || {
            thread::sleep(Duration::from_millis(40));
            save_generation(&root, &c, 0xB0B, 2)
        })
    };
    let report = server.run_paced(&streams, &[3000]).unwrap();
    let art2 = saver.join().unwrap();
    watcher.stop();

    let cv2 = rung_over(&rt, &c, &art2.weights);
    let new_ref = cold_outputs(&cv2, &streams);

    assert_eq!(report.generation, 2, "saved artifact never went live");
    assert_eq!(handle.current().seq, 2);
    let mut mid_swap = 0;
    for id in 0..streams.len() {
        let served = &report.outputs[&(id as u64)];
        assert_eq!(served.len(), streams[id].len(), "stream {id} dropped frames");
        let k = split_index(served, &old_ref[id], &new_ref[id]);
        assert_eq!(k % period, 0, "stream {id} swapped off a phase boundary");
        if k > 0 && k < served.len() {
            mid_swap += 1;
        }
    }
    assert!(mid_swap > 0, "swap never landed mid-run");
    let _ = fs::remove_dir_all(&root);
}
