//! Offline stand-in for the `anyhow` crate.
//!
//! crates.io is unavailable in this environment (DESIGN.md §5), so this
//! vendored crate provides the subset of anyhow's API the repo uses:
//! [`Error`], [`Result`], the [`Context`] extension trait (on both
//! `Result` and `Option`), and the [`anyhow!`] / [`bail!`] macros.
//!
//! Semantics mirror anyhow where it matters here:
//!
//! * `Error` carries a context chain, outermost message first.
//! * `{e}` displays the outermost message; `{e:#}` displays the whole
//!   chain joined by `: ` (what `main` prints on failure).
//! * A blanket `From<E: std::error::Error>` lets `?` convert concrete
//!   errors; `Error` itself deliberately does **not** implement
//!   `std::error::Error` (the same trick anyhow uses to keep the blanket
//!   impl coherent).

use std::fmt;

/// A context-chained error. Outermost (most recently attached) message
/// first, root cause last.
pub struct Error {
    msgs: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            msgs: vec![m.to_string()],
        }
    }

    /// Attach an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.msgs.insert(0, c.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.msgs.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.msgs.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.msgs.join(": "))
        } else {
            write!(f, "{}", self.msgs.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msgs.join(": "))
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        Error { msgs }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod private {
    /// Sealed conversion into [`crate::Error`]; implemented for all
    /// `std::error::Error` types *and* for `Error` itself (which is not a
    /// `std::error::Error`, so the two impls cannot overlap).
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E: private::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_and_formats() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn with_context_on_option() {
        let e = None::<u32>.with_context(|| "missing field").unwrap_err();
        assert_eq!(format!("{e}"), "missing field");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", inner().unwrap_err()), "gone");
    }

    #[test]
    fn macros_build_errors() {
        fn fails(n: usize) -> Result<()> {
            if n > 0 {
                bail!("bad count {n}");
            }
            Ok(())
        }
        assert_eq!(format!("{}", fails(3).unwrap_err()), "bad count 3");
        let e = anyhow!("worker {} died", 7);
        assert_eq!(format!("{e}"), "worker 7 died");
        let owned = anyhow!(String::from("plain"));
        assert_eq!(format!("{owned}"), "plain");
    }
}
