//! Compile-time stub of the `xla` (PJRT) crate.
//!
//! The real crate links against a PJRT plugin and cannot be fetched or
//! built in this offline environment (DESIGN.md §5).  This stub mirrors
//! the API surface `soi::backend::pjrt` uses so that
//! `cargo build --features pjrt` still type-checks everywhere; every
//! entry point returns [`XlaError`] at runtime, and `Runtime::cpu()`
//! therefore falls back cleanly when asked for the pjrt backend.
//!
//! To use a real PJRT runtime, replace this directory with the actual
//! `xla` crate (same API) and rebuild with `--features pjrt`.

use std::fmt;
use std::path::Path;

/// Error returned by every stub entry point.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "xla stub: {what} unavailable (the real PJRT crate is not vendored; \
         see rust/vendor/xla/src/lib.rs)"
    ))
}

/// Stub PJRT client; [`PjRtClient::cpu`] always fails.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("buffer_from_host_buffer"))
    }
}

/// Stub HLO module proto.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stub XLA computation.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Stub loaded executable.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute_b"))
    }
}

/// Stub device buffer.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

/// Stub host literal.
pub struct Literal(());

impl Literal {
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(unavailable("decompose_tuple"))
    }

    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>> {
        Err(unavailable("to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("xla stub"));
    }
}
