//! Unstructured global magnitude pruning (paper §3.1 "Pruning" / Fig. 6,
//! after Han et al. 2015): at each step the `chunk` smallest-magnitude
//! weights across the whole model are zeroed; SOI and pruning compose —
//! the experiment shows SOI+pruning dominating pruning alone.

use crate::runtime::Weights;

/// Count currently-zero weights.
pub fn zeros(w: &Weights) -> usize {
    w.tensors
        .iter()
        .map(|t| t.data.iter().filter(|v| **v == 0.0).count())
        .sum()
}

/// Sparsity in [0, 1].
pub fn sparsity(w: &Weights) -> f64 {
    let total = w.total_params();
    if total == 0 {
        return 0.0;
    }
    zeros(w) as f64 / total as f64
}

/// Zero the `n` smallest-magnitude *nonzero* weights globally.
///
/// Returns how many weights were actually zeroed (may be < n when fewer
/// nonzero weights remain).  Biases are pruned too — the paper prunes
/// "weights from model" globally.
pub fn prune_global_magnitude(w: &mut Weights, n: usize) -> usize {
    // collect (|w|, tensor index, element index) for all nonzero weights
    let mut mags: Vec<(f32, u32, u32)> = Vec::new();
    for (ti, t) in w.tensors.iter().enumerate() {
        for (ei, &v) in t.data.iter().enumerate() {
            if v != 0.0 {
                mags.push((v.abs(), ti as u32, ei as u32));
            }
        }
    }
    let k = n.min(mags.len());
    if k == 0 {
        return 0;
    }
    mags.select_nth_unstable_by(k - 1, |a, b| a.0.partial_cmp(&b.0).unwrap());
    for &(_, ti, ei) in &mags[..k] {
        w.tensors[ti as usize].data[ei as usize] = 0.0;
    }
    k
}

/// Effective MACs per frame after pruning: zero weights cost nothing on a
/// sparse kernel, so the effective complexity scales with density.
/// (The paper notes SOI needs no sparse kernels while pruning does; we
/// report both the dense and the idealized sparse cost.)
pub fn effective_macs(dense_macs: f64, w: &Weights) -> f64 {
    dense_macs * (1.0 - sparsity(w))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tensor::Tensor;

    fn weights(vals: Vec<Vec<f32>>) -> Weights {
        Weights {
            tensors: vals
                .into_iter()
                .map(|v| {
                    let n = v.len();
                    Tensor::new(vec![n], v)
                })
                .collect(),
        }
    }

    #[test]
    fn prunes_smallest_first() {
        let mut w = weights(vec![vec![0.5, -0.1, 3.0], vec![-0.2, 1.0]]);
        let pruned = prune_global_magnitude(&mut w, 2);
        assert_eq!(pruned, 2);
        assert_eq!(w.tensors[0].data, vec![0.5, 0.0, 3.0]);
        assert_eq!(w.tensors[1].data, vec![0.0, 1.0]);
    }

    #[test]
    fn idempotent_on_zeros() {
        let mut w = weights(vec![vec![0.0, 0.0, 1.0]]);
        assert_eq!(prune_global_magnitude(&mut w, 2), 1);
        assert_eq!(w.tensors[0].data, vec![0.0, 0.0, 0.0]);
        assert_eq!(prune_global_magnitude(&mut w, 5), 0);
    }

    #[test]
    fn sparsity_tracking() {
        let mut w = weights(vec![vec![1.0, 2.0, 3.0, 4.0]]);
        assert_eq!(sparsity(&w), 0.0);
        prune_global_magnitude(&mut w, 2);
        assert_eq!(sparsity(&w), 0.5);
        assert_eq!(effective_macs(100.0, &w), 50.0);
    }

    #[test]
    fn prune_across_tensor_boundaries() {
        let mut w = weights(vec![vec![10.0, 0.01], vec![0.02, 20.0]]);
        prune_global_magnitude(&mut w, 2);
        assert_eq!(w.tensors[0].data, vec![10.0, 0.0]);
        assert_eq!(w.tensors[1].data, vec![0.0, 20.0]);
    }
}
