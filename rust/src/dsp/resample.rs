//! Resampler bank — the Table 3 baselines SOI is compared against.
//!
//! All four methods implement 2:1 decimation (16 kHz → 8 kHz) and 1:2
//! interpolation (8 kHz → 16 kHz), matching the paper's setup:
//!
//! * `Linear`    — first-order interpolation, no anti-alias filter (the
//!   paper's weakest baseline).
//! * `Polyphase` — windowed-sinc FIR (Hamming) in a polyphase structure.
//! * `Kaiser`    — windowed-sinc FIR with a Kaiser window (β = 8.6,
//!   ~90 dB stopband).
//! * `SoxLike`   — long windowed-sinc with a Blackman–Harris window, akin
//!   to SoX's VHQ sinc resampler (Soras 2004 lineage).

/// Resampling method selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// First-order interpolation, no anti-alias filter.
    Linear,
    /// Windowed-sinc FIR (Hamming) in a polyphase structure.
    Polyphase,
    /// Windowed-sinc FIR with a Kaiser window (β = 8.6).
    Kaiser,
    /// Long windowed-sinc with a Blackman–Harris window (SoX VHQ-like).
    SoxLike,
}

impl Method {
    /// Every method, in Table 3 row order.
    pub const ALL: [Method; 4] = [
        Method::Linear,
        Method::Polyphase,
        Method::Kaiser,
        Method::SoxLike,
    ];

    /// Display name used in tables.
    pub fn name(&self) -> &'static str {
        match self {
            Method::Linear => "Linear",
            Method::Polyphase => "Polyphase",
            Method::Kaiser => "Kaiser",
            Method::SoxLike => "SoX-like",
        }
    }
}

/// Zeroth-order modified Bessel function (for the Kaiser window).
fn bessel_i0(x: f64) -> f64 {
    let mut sum = 1.0;
    let mut term = 1.0;
    let half = x / 2.0;
    for k in 1..32 {
        term *= (half / k as f64) * (half / k as f64);
        sum += term;
        if term < 1e-16 * sum {
            break;
        }
    }
    sum
}

fn sinc(x: f64) -> f64 {
    if x.abs() < 1e-12 {
        1.0
    } else {
        (std::f64::consts::PI * x).sin() / (std::f64::consts::PI * x)
    }
}

/// Half-band lowpass FIR (cutoff 0.5 Nyquist) of length `2*half+1`.
fn halfband_taps(half: usize, window: fn(f64) -> f64) -> Vec<f64> {
    let n = 2 * half + 1;
    let mut taps = Vec::with_capacity(n);
    let mut sum = 0.0;
    for i in 0..n {
        let x = i as f64 - half as f64;
        let w = window(i as f64 / (n - 1) as f64);
        let t = 0.5 * sinc(0.5 * x) * w;
        taps.push(t);
        sum += t;
    }
    // normalize to unity DC gain
    for t in &mut taps {
        *t /= sum;
    }
    taps
}

fn hamming(u: f64) -> f64 {
    0.54 - 0.46 * (std::f64::consts::TAU * u).cos()
}

fn blackman_harris(u: f64) -> f64 {
    let a = std::f64::consts::TAU * u;
    0.35875 - 0.48829 * a.cos() + 0.14128 * (2.0 * a).cos() - 0.01168 * (3.0 * a).cos()
}

fn kaiser_taps(half: usize, beta: f64) -> Vec<f64> {
    let n = 2 * half + 1;
    let denom = bessel_i0(beta);
    let mut taps = Vec::with_capacity(n);
    let mut sum = 0.0;
    for i in 0..n {
        let x = i as f64 - half as f64;
        let r = 2.0 * i as f64 / (n - 1) as f64 - 1.0;
        let w = bessel_i0(beta * (1.0 - r * r).max(0.0).sqrt()) / denom;
        let t = 0.5 * sinc(0.5 * x) * w;
        taps.push(t);
        sum += t;
    }
    for t in &mut taps {
        *t /= sum;
    }
    taps
}

fn taps_for(method: Method) -> Option<Vec<f64>> {
    match method {
        Method::Linear => None,
        Method::Polyphase => Some(halfband_taps(16, hamming)),
        Method::Kaiser => Some(kaiser_taps(24, 8.6)),
        Method::SoxLike => Some(halfband_taps(64, blackman_harris)),
    }
}

fn convolve_same(x: &[f32], taps: &[f64]) -> Vec<f32> {
    let half = taps.len() / 2;
    let n = x.len();
    let mut out = vec![0.0f32; n];
    for i in 0..n {
        let mut acc = 0.0f64;
        for (j, &t) in taps.iter().enumerate() {
            let k = i as isize + j as isize - half as isize;
            if k >= 0 && (k as usize) < n {
                acc += t * x[k as usize] as f64;
            }
        }
        out[i] = acc as f32;
    }
    out
}

/// Decimate 2:1 (anti-alias filter first, except Linear).
pub fn downsample2(x: &[f32], method: Method) -> Vec<f32> {
    match taps_for(method) {
        None => {
            // linear: average of each sample pair (first-order anti-alias)
            x.chunks(2)
                .map(|c| if c.len() == 2 { (c[0] + c[1]) * 0.5 } else { c[0] })
                .collect()
        }
        Some(taps) => {
            let filtered = convolve_same(x, &taps);
            filtered.iter().step_by(2).copied().collect()
        }
    }
}

/// Interpolate 1:2 (zero-stuff then image-reject filter, except Linear).
pub fn upsample2(x: &[f32], method: Method) -> Vec<f32> {
    let n = x.len();
    match taps_for(method) {
        None => {
            let mut out = Vec::with_capacity(2 * n);
            for i in 0..n {
                let a = x[i];
                let b = if i + 1 < n { x[i + 1] } else { x[i] };
                out.push(a);
                out.push(0.5 * (a + b));
            }
            out
        }
        Some(taps) => {
            let mut stuffed = vec![0.0f32; 2 * n];
            for i in 0..n {
                stuffed[2 * i] = x[i];
            }
            // gain 2 restores amplitude after zero-stuffing
            convolve_same(&stuffed, &taps)
                .iter()
                .map(|&v| 2.0 * v)
                .collect()
        }
    }
}

/// Round-trip 16k → 8k → 16k (what Table 3 applies around the model).
pub fn roundtrip(x: &[f32], method: Method) -> Vec<f32> {
    upsample2(&downsample2(x, method), method)
}

/// Group delay (in samples at the original rate) of the round trip.
///
/// All FIR paths use zero-centered ("same") convolution, so the linear
/// phase delay cancels and the round trip is alignment-free; kept as an
/// explicit function (and tested) because a causal implementation would
/// need `taps.len() - 1` here.
pub fn roundtrip_delay(_method: Method) -> usize {
    0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tone(f: f64, n: usize, fs: f64) -> Vec<f32> {
        (0..n)
            .map(|i| (std::f64::consts::TAU * f * i as f64 / fs).sin() as f32)
            .collect()
    }

    fn rms(x: &[f32]) -> f64 {
        (x.iter().map(|&v| v as f64 * v as f64).sum::<f64>() / x.len() as f64).sqrt()
    }

    #[test]
    fn dc_preserved_by_all_methods() {
        let x = vec![1.0f32; 4000];
        for m in Method::ALL {
            let y = roundtrip(&x, m);
            let mid = &y[1000..3000];
            let mean: f64 = mid.iter().map(|&v| v as f64).sum::<f64>() / mid.len() as f64;
            assert!((mean - 1.0).abs() < 0.02, "{}: DC {mean}", m.name());
        }
    }

    #[test]
    fn low_tone_survives_roundtrip() {
        // 500 Hz is far below the 4 kHz cutoff: every filtered method must
        // pass it with less than 1 dB of loss.
        let x = tone(500.0, 8000, 16_000.0);
        for m in [Method::Polyphase, Method::Kaiser, Method::SoxLike] {
            let y = roundtrip(&x, m);
            let d = roundtrip_delay(m);
            let n = 4000;
            let a = &x[1000..1000 + n];
            let b = &y[1000 + d..1000 + d + n];
            let ratio = rms(b) / rms(a);
            assert!(
                (0.89..1.12).contains(&ratio),
                "{}: rms ratio {ratio}",
                m.name()
            );
        }
    }

    #[test]
    fn high_tone_removed_by_good_filters() {
        // 6 kHz is above the 4 kHz Nyquist of the 8 kHz midpoint: it must
        // be strongly attenuated by Kaiser/SoX (anti-alias).
        let x = tone(6000.0, 8000, 16_000.0);
        for m in [Method::Kaiser, Method::SoxLike] {
            let y = roundtrip(&x, m);
            let ratio = rms(&y[1000..7000]) / rms(&x[1000..7000]);
            assert!(ratio < 0.12, "{}: leak {ratio}", m.name());
        }
    }

    #[test]
    fn linear_aliases_high_tone() {
        // the linear method has no proper anti-alias filter: a 6 kHz tone
        // survives (aliased) with substantial energy — exactly why the
        // paper's Linear row is so much worse.
        let x = tone(6000.0, 8000, 16_000.0);
        let y = roundtrip(&x, Method::Linear);
        let ratio = rms(&y[1000..7000]) / rms(&x[1000..7000]);
        assert!(ratio > 0.1, "linear unexpectedly clean: {ratio}");
    }

    #[test]
    fn lengths() {
        let x = vec![0.0f32; 1001];
        for m in Method::ALL {
            assert_eq!(downsample2(&x, m).len(), 501);
            assert_eq!(upsample2(&downsample2(&x, m), m).len(), 1002);
        }
    }

    #[test]
    fn quality_ordering_on_speech() {
        // On speech-shaped material (energy concentrated below 4 kHz) the
        // round-trip error must be far worse for Linear than for the
        // filtered methods — the paper's qualitative ordering in Table 3.
        let mut rng = Rng::new(5);
        let x = crate::dsp::siggen::speech(&mut rng, 16000, 16_000.0);
        let err = |m: Method| {
            let y = roundtrip(&x, m);
            let d = roundtrip_delay(m);
            let n = 8000;
            let a = &x[2000..2000 + n];
            let b = &y[2000 + d..2000 + d + n];
            crate::dsp::metrics::si_snr(b, a)
        };
        let lin = err(Method::Linear);
        let kai = err(Method::Kaiser);
        let sox = err(Method::SoxLike);
        let pol = err(Method::Polyphase);
        assert!(kai > lin + 3.0, "kaiser {kai} vs linear {lin}");
        assert!(sox > lin + 3.0, "sox {sox} vs linear {lin}");
        assert!(pol > lin, "polyphase {pol} vs linear {lin}");
    }
}
