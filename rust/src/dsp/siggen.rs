//! Synthetic signal generators — the rust twin of `python/compile/data.py`
//! (same family/parameters so both layers evaluate the same distribution;
//! see DESIGN.md §5 for the DNS/TAU substitution rationale).

use crate::util::rng::Rng;

/// Sample rate shared by every synthetic generator, Hz.
pub const FS: f64 = 16_000.0;

/// Speech-like clean source: harmonic stack with a log-domain pitch random
/// walk, two formant-like resonators, and a smoothed voicing gate.
pub fn speech(rng: &mut Rng, n: usize, fs: f64) -> Vec<f32> {
    // pitch contour
    let mut logf0 = 120.0f64.ln();
    let (lo, hi) = (80.0f64.ln(), 300.0f64.ln());
    let mut phase = 0.0f64;
    let mut harm_phase = [0.0f64; 12];
    let mut amps = [0.0f64; 12];
    for (h, a) in amps.iter_mut().enumerate() {
        *a = (1.0 / (h + 1) as f64) * (0.5 + rng.uniform());
    }
    for (h, p) in harm_phase.iter_mut().enumerate() {
        let _ = h;
        *p = rng.uniform() * std::f64::consts::TAU;
    }
    let mut sig = vec![0.0f64; n];
    for (i, s) in sig.iter_mut().enumerate() {
        logf0 = (logf0 + rng.normal() * 0.0006).clamp(lo, hi);
        let f0 = logf0.exp();
        phase += std::f64::consts::TAU * f0 / fs;
        let mut v = 0.0;
        for h in 0..12 {
            v += amps[h] * ((h + 1) as f64 * phase + harm_phase[h]).sin();
        }
        let _ = i;
        *s = v;
    }
    // two fixed-frequency resonators (biquad two-pole, like the python side)
    for (fc, bw) in [(500.0f64, 120.0f64), (1500.0, 200.0)] {
        let r = (-std::f64::consts::PI * bw / fs).exp();
        let w = std::f64::consts::TAU * fc / fs;
        let (a1, a2) = (-2.0 * r * w.cos(), r * r);
        let b0 = 1.0 - r;
        let (mut y1, mut y2) = (0.0f64, 0.0f64);
        for s in sig.iter_mut() {
            let y0 = b0 * *s - a1 * y1 - a2 * y2;
            y2 = y1;
            y1 = y0;
            *s = 0.5 * *s + 0.5 * y0;
        }
    }
    // voicing gate: 100 ms segments on/off, smoothed by a 50 ms ramp
    let seg = (fs * 0.1) as usize;
    let ramp = (fs * 0.05) as usize;
    let n_seg = n / seg + 2;
    let gates: Vec<f64> = (0..n_seg)
        .map(|_| if rng.chance(0.7) { 1.0 } else { 0.0 })
        .collect();
    let mut env = vec![0.0f64; n];
    for (i, e) in env.iter_mut().enumerate() {
        *e = gates[i / seg];
    }
    // moving-average smoothing
    let mut smooth = vec![0.0f64; n];
    let mut acc = 0.0;
    for i in 0..n {
        acc += env[i];
        if i >= ramp {
            acc -= env[i - ramp];
        }
        smooth[i] = acc / ramp.min(i + 1) as f64;
    }
    let mut peak = 1e-9f64;
    for i in 0..n {
        sig[i] *= smooth[i];
        peak = peak.max(sig[i].abs());
    }
    sig.iter().map(|&v| (v / peak * 0.7) as f32).collect()
}

/// Colored noise: white noise shaped by a one-pole tilt filter plus slow
/// amplitude modulation (street/babble-like energy fluctuation).
pub fn noise(rng: &mut Rng, n: usize, fs: f64) -> Vec<f32> {
    let tilt = rng.range(-1.2, 0.2);
    // approximate the python FFT tilt with a one-pole lowpass/highpass mix
    let alpha = 0.98f64.powf(-tilt); // more tilt -> heavier lowpass
    let a = alpha.clamp(0.5, 0.999);
    let mut state = 0.0f64;
    let mod_rate = rng.range(0.3, 2.0);
    let mod_phase = rng.uniform() * std::f64::consts::TAU;
    let mut out = vec![0.0f64; n];
    let mut peak = 1e-9f64;
    for (i, o) in out.iter_mut().enumerate() {
        let w = rng.normal();
        state = a * state + (1.0 - a) * w;
        let lp = state;
        let hp = w - lp;
        // tilt in [-1.2, .2]: negative -> favour lowpass
        let mix = ((tilt + 1.2) / 1.4).clamp(0.0, 1.0);
        let mut v = lp * (1.0 - mix) + (0.3 * hp + 0.7 * w) * mix;
        let t = i as f64 / fs;
        v *= 1.0 + 0.5 * (std::f64::consts::TAU * mod_rate * t + mod_phase).sin();
        *o = v;
        peak = peak.max(v.abs());
    }
    out.iter().map(|&v| (v / peak * 0.7) as f32).collect()
}

/// Scale `noise` to the requested SNR (dB) against `clean` and mix.
pub fn mix(clean: &[f32], nse: &[f32], snr_db: f64) -> Vec<f32> {
    let pc: f64 = clean.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
        / clean.len() as f64
        + 1e-12;
    let pn: f64 =
        nse.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / nse.len() as f64 + 1e-12;
    let g = (pc / pn / 10f64.powf(snr_db / 10.0)).sqrt();
    clean
        .iter()
        .zip(nse)
        .map(|(&c, &w)| c + (g * w as f64) as f32)
        .collect()
}

/// One (noisy, clean) evaluation utterance at a random SNR in [-5, 10] dB.
pub fn denoise_pair(rng: &mut Rng, n: usize, fs: f64) -> (Vec<f32>, Vec<f32>) {
    let clean = speech(rng, n, fs);
    let nse = noise(rng, n, fs);
    let snr = rng.range(-5.0, 10.0);
    (mix(&clean, &nse, snr), clean)
}

/// Number of synthetic ASC classes (TAU Urban has 10).
pub const N_SCENES: usize = 10;

/// One synthetic acoustic scene of class `label`: class-specific band
/// emphasis (resonator at a class center frequency) + class-specific
/// impulsive event train.
pub fn scene(rng: &mut Rng, label: usize, n: usize, fs: f64) -> Vec<f32> {
    assert!(label < N_SCENES);
    let base = noise(rng, n, fs);
    let fc = 200.0 + (6000.0 - 200.0) * label as f64 / (N_SCENES - 1) as f64;
    let bw = 0.35 * fc + 200.0;
    let r = (-std::f64::consts::PI * bw / fs).exp();
    let w = std::f64::consts::TAU * fc / fs;
    let (a1, a2) = (-2.0 * r * w.cos(), r * r);
    let b0 = 1.0 - r;
    let (mut y1, mut y2) = (0.0f64, 0.0f64);
    let mut sig = vec![0.0f64; n];
    for i in 0..n {
        let x = base[i] as f64;
        let y0 = b0 * x - a1 * y1 - a2 * y2;
        y2 = y1;
        y1 = y0;
        sig[i] = x + 2.5 * y0;
    }
    // impulsive events
    let n_events = 1 + (label * 3) / 2;
    for _ in 0..n_events {
        if n < 500 {
            break;
        }
        let pos = rng.below(n - 400);
        let len = 100 + rng.below(300);
        for j in 0..len {
            let hann = 0.5 - 0.5 * (std::f64::consts::TAU * j as f64 / len as f64).cos();
            let tone = (std::f64::consts::TAU * fc * 1.5 * j as f64 / fs).sin();
            sig[pos + j] += 1.5 * rng.normal() * hann * tone;
        }
    }
    let peak = sig.iter().fold(1e-9f64, |m, &v| m.max(v.abs()));
    sig.iter().map(|&v| (v / peak * 0.7) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speech_is_bounded_and_nonzero() {
        let mut rng = Rng::new(1);
        let s = speech(&mut rng, 8000, FS);
        assert_eq!(s.len(), 8000);
        let peak = s.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        assert!(peak > 0.3 && peak <= 0.71, "peak {peak}");
    }

    #[test]
    fn noise_is_bounded() {
        let mut rng = Rng::new(2);
        let s = noise(&mut rng, 4000, FS);
        let peak = s.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        assert!(peak > 0.3 && peak <= 0.71);
    }

    #[test]
    fn mix_hits_requested_snr() {
        let mut rng = Rng::new(3);
        let c = speech(&mut rng, 16000, FS);
        let w = noise(&mut rng, 16000, FS);
        for snr in [-5.0, 0.0, 10.0] {
            let m = mix(&c, &w, snr);
            let e: Vec<f32> = m.iter().zip(&c).map(|(a, b)| a - b).collect();
            let pc: f64 = c.iter().map(|&v| v as f64 * v as f64).sum();
            let pe: f64 = e.iter().map(|&v| v as f64 * v as f64).sum();
            let got = 10.0 * (pc / pe).log10();
            assert!((got - snr).abs() < 0.1, "snr {snr} got {got}");
        }
    }

    #[test]
    fn scenes_are_distinguishable_by_spectrum() {
        // class 0 (low band) should carry more low-frequency energy than
        // class 9 (high band): compare lag-1 autocorrelation.
        let ac = |xs: &[f32]| {
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for i in 1..xs.len() {
                num += xs[i] as f64 * xs[i - 1] as f64;
                den += xs[i] as f64 * xs[i] as f64;
            }
            num / den
        };
        let mut r0 = Rng::new(4);
        let mut r9 = Rng::new(4);
        let s0 = scene(&mut r0, 0, 16000, FS);
        let s9 = scene(&mut r9, 9, 16000, FS);
        assert!(ac(&s0) > ac(&s9) + 0.1, "{} vs {}", ac(&s0), ac(&s9));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = speech(&mut Rng::new(7), 1000, FS);
        let b = speech(&mut Rng::new(7), 1000, FS);
        assert_eq!(a, b);
    }
}
