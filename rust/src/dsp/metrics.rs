//! Quality metrics: SI-SNR / SI-SNRi (speech separation) and top-1
//! accuracy (classification) — the paper's evaluation metrics.

/// Scale-invariant SNR in dB (both signals are mean-removed; the target
/// projection removes any global gain difference).
pub fn si_snr(est: &[f32], target: &[f32]) -> f64 {
    assert_eq!(est.len(), target.len(), "si_snr: length mismatch");
    let n = est.len() as f64;
    let me: f64 = est.iter().map(|&v| v as f64).sum::<f64>() / n;
    let mt: f64 = target.iter().map(|&v| v as f64).sum::<f64>() / n;
    let mut dot = 0.0f64;
    let mut tt = 0.0f64;
    for (&e, &t) in est.iter().zip(target) {
        let (e, t) = (e as f64 - me, t as f64 - mt);
        dot += e * t;
        tt += t * t;
    }
    let eps = 1e-8;
    let scale = dot / (tt + eps);
    let mut ps = 0.0f64;
    let mut pn = 0.0f64;
    for (&e, &t) in est.iter().zip(target) {
        let (e, t) = (e as f64 - me, t as f64 - mt);
        let s = scale * t;
        ps += s * s;
        pn += (e - s) * (e - s);
    }
    10.0 * ((ps + eps) / (pn + eps)).log10()
}

/// SI-SNR improvement: si_snr(est, clean) - si_snr(noisy, clean).
pub fn si_snr_improvement(noisy: &[f32], est: &[f32], clean: &[f32]) -> f64 {
    si_snr(est, clean) - si_snr(noisy, clean)
}

/// Plain (non-scale-invariant) output SNR of an estimate against a
/// reference signal, in dB, over the overlapping prefix — the fidelity
/// number quantized execution reports against its f32 twin
/// (DESIGN.md §10).  Capped at 120 dB so bit-exact runs stay finite in
/// JSON summaries; degenerate inputs (empty, all-zero reference)
/// report the cap.
pub fn output_snr_db(reference: &[f32], estimate: &[f32]) -> f64 {
    let n = reference.len().min(estimate.len());
    let mut sig = 0.0f64;
    let mut err = 0.0f64;
    for i in 0..n {
        let r = reference[i] as f64;
        sig += r * r;
        let e = r - estimate[i] as f64;
        err += e * e;
    }
    if err <= 0.0 || sig <= 0.0 {
        return 120.0;
    }
    (10.0 * (sig / err).log10()).min(120.0)
}

/// Top-1 accuracy over (prediction, label) pairs.
pub fn top1_accuracy(pred: &[usize], labels: &[usize]) -> f64 {
    assert_eq!(pred.len(), labels.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hits = pred.iter().zip(labels).filter(|(p, l)| p == l).count();
    hits as f64 / pred.len() as f64
}

/// Argmax helper for logits.
pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn perfect_estimate_is_very_high() {
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
        assert!(si_snr(&x, &x) > 60.0);
    }

    #[test]
    fn scale_invariance() {
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
        let scaled: Vec<f32> = x.iter().map(|&v| v * 3.7).collect();
        assert!(si_snr(&scaled, &x) > 60.0);
    }

    #[test]
    fn noise_lowers_si_snr() {
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
        let noisy: Vec<f32> = x.iter().map(|&v| v + rng.normal() as f32).collect();
        let s = si_snr(&noisy, &x);
        assert!((-2.0..2.0).contains(&s), "0 dB-ish expected, got {s}");
    }

    #[test]
    fn improvement_of_identity_denoiser_is_zero() {
        let mut rng = Rng::new(4);
        let clean: Vec<f32> = (0..500).map(|_| rng.normal() as f32).collect();
        let noisy: Vec<f32> = clean.iter().map(|&v| v + 0.3 * rng.normal() as f32).collect();
        let imp = si_snr_improvement(&noisy, &noisy, &clean);
        assert!(imp.abs() < 1e-9);
    }

    #[test]
    fn accuracy_and_argmax() {
        assert_eq!(top1_accuracy(&[1, 2, 3], &[1, 2, 0]), 2.0 / 3.0);
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
    }

    #[test]
    fn output_snr_caps_and_measures() {
        let r = vec![1.0f32, -2.0, 3.0, 0.5];
        assert_eq!(output_snr_db(&r, &r), 120.0, "bit-exact caps at 120");
        assert_eq!(output_snr_db(&[], &[]), 120.0, "degenerate caps");
        let e: Vec<f32> = r.iter().map(|v| v + 0.01).collect();
        let snr = output_snr_db(&r, &e);
        assert!((20.0..60.0).contains(&snr), "plausible mid-range: {snr}");
        // scale-variant on purpose: a 2x gain error is a real error
        let g: Vec<f32> = r.iter().map(|v| v * 2.0).collect();
        assert!(output_snr_db(&r, &g) < 1.0);
    }
}
