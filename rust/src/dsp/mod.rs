//! Signal-processing substrates: synthetic data generation (the DNS/TAU
//! substitution of DESIGN.md §5), quality metrics, resampler bank
//! (Table 3 baselines) and framing helpers.

pub mod metrics;
pub mod resample;
pub mod siggen;

/// Slice a waveform into non-overlapping frames of `feat` samples,
/// returning (frames-as-columns data, n_frames): column t holds samples
/// `x[t*feat .. (t+1)*feat]` — the layout the U-Net artifacts expect.
pub fn frames(x: &[f32], feat: usize) -> (Vec<Vec<f32>>, usize) {
    let t = x.len() / feat;
    let mut out = Vec::with_capacity(t);
    for i in 0..t {
        out.push(x[i * feat..(i + 1) * feat].to_vec());
    }
    (out, t)
}

/// Reassemble frames back into a waveform.
pub fn deframe(frames: &[Vec<f32>]) -> Vec<f32> {
    let mut out = Vec::with_capacity(frames.len() * frames.first().map_or(0, |f| f.len()));
    for f in frames {
        out.extend_from_slice(f);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let x: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let (fr, t) = frames(&x, 16);
        assert_eq!(t, 4);
        assert_eq!(deframe(&fr), x);
    }

    #[test]
    fn frame_truncates_tail() {
        let x = vec![0.0f32; 70];
        let (fr, t) = frames(&x, 16);
        assert_eq!(t, 4);
        assert_eq!(fr.len(), 4);
    }
}
