//! # SOI: Scattered Online Inference
//!
//! Production-quality reproduction of *"SOI: Scaling Down Computational
//! Complexity by Estimating Partial States of the Model"* (NeurIPS 2024)
//! as a three-layer rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`) — Pallas streaming-conv kernels,
//! * **L2** (`python/compile/model.py`) — the causal U-Net and its SOI
//!   variants, AOT-lowered to HLO text at build time,
//! * **L3** (this crate) — the streaming serving coordinator: SOI phase
//!   scheduling, FP precompute overlap, per-stream partial-state caches,
//!   multi-stream workers, load-adaptive variant ladders with warm state
//!   migration (DESIGN.md §9), metrics, plus every substrate the paper's
//!   evaluation needs (complexity accounting, resamplers, pruning,
//!   synthetic signal generation, SI-SNR).
//!
//! Execution is multi-backend ([`backend`]): the default **native**
//! backend is a dependency-free pure-Rust interpreter of variant
//! manifests (runs anywhere Rust compiles — the paper's MCU-class
//! deployment story), and the optional **pjrt** backend
//! (`--features pjrt`) executes AOT-compiled HLO-text artifacts.
//! Precision is a second execution axis ([`quant`], DESIGN.md §10): any
//! variant also compiles as a quantized int8/s16 executable, and a
//! serving ladder may mix precisions (`stmc:f32 → stmc:int8 → …`).
//! Both interpreters execute on one compute substrate ([`kernels`],
//! DESIGN.md §11): runtime-dispatched SIMD microkernels (AVX2/FMA,
//! NEON, scalar oracle) over weight panels packed once at upload time,
//! with per-variant scratch arenas keeping the serving steady state
//! allocation-free.  The whole serving stack is observable ([`obs`],
//! DESIGN.md §12): phase-attributed tracing spans, a mergeable metrics
//! registry, and a versioned NDJSON health feed
//! (`serve --telemetry`) — recorded into preallocated storage so the
//! zero-allocation steady state holds with telemetry enabled.
//! Scale-out ([`net`], DESIGN.md §14) puts that stack behind a
//! versioned wire protocol (`soi.wire.v1`): a front-end with admission
//! control and session affinity over N backend shards, zero-drop warm
//! cross-shard migration via the §9 replay path, and a deterministic
//! loopback transport for byte-level fault injection in tests.
//!
//! See DESIGN.md for the full system inventory and experiment index.

#![warn(missing_docs)]

pub mod backend;
pub mod complexity;
pub mod coordinator;
pub mod dsp;
pub mod experiments;
pub mod kernels;
pub mod net;
pub mod obs;
pub mod pruning;
pub mod quant;
pub mod runtime;
pub mod util;
