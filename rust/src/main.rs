//! `soi` — the SOI streaming-inference coordinator CLI.
//!
//! Subcommands:
//!   list                         list built artifact variants
//!   info     <variant>           manifest summary for one variant
//!   exp      <table|fig|all>     regenerate a paper table/figure (results/)
//!   serve    <variant> [opts]    multi-stream serving benchmark
//!   denoise  <variant> [opts]    stream one synthetic utterance, report SI-SNRi
//!   validate-feed <path>         schema-check a telemetry health feed
//!   export-artifact <spec>       save weights as a versioned soi.artifact.v1 dir
//!   inspect-artifact <dir>       verify every artifact digest, print a summary
//!   serve-shard <variant>        run one backend shard over TCP (soi.wire.v1)
//!   serve-front --shards a,b     run the front-end over a shard fleet
//!   wire-smoke [variant]         front + 2 loopback shards vs single-process serve
//!   chaos-smoke [variant]        fleet survival under a seeded fault plan (DESIGN.md §16)
//!   aggregate-feeds --feeds a,b  merge soi.obs.v1 feeds into one soi.cluster.v1
//!   top --feeds a,b              live cluster console over health feeds
//!
//! Common options: --artifacts DIR (default ./artifacts), --results DIR
//! (default ./results), --n-eval N (default 6), --seed S, --streams N,
//! --frames N, --workers N, --dtype f32|int8 (serve/denoise; DESIGN.md §10).
//! Observability (DESIGN.md §12): serve accepts --telemetry[=PATH] and
//! --snapshot-ms N to stream a live NDJSON health feed while serving.
//! Versioned weights (DESIGN.md §13): serve accepts --artifact-dir DIR
//! [--watch-generations [--watch-ms N]] to serve rungs compiled over the
//! newest verified artifact generation and hot-reload newer ones live.

use std::path::PathBuf;
use std::process::ExitCode;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use soi::coordinator::{AdaptivePolicy, GenerationWatcher, Server, StreamSession};
use soi::dsp::{frames, metrics, siggen};
use soi::experiments::{self, Ctx};
use soi::net::{
    health_from_feed, run_shard, spawn_front_with, ChaosFleet, ChaosPlan, ClusterController,
    ClusterPolicy, ErrCode, Fault, FrontPolicy, LoopbackHub, Msg, ShardConfig, ShardHealth,
    ShardLink, TcpConnector, TcpPort, Transport, WireClient,
};
use soi::obs::{self, Exporter, ObsConfig, Telemetry};
use soi::runtime::{
    artifact, list_variants, synth, Artifact, CompiledVariant, Dtype, Manifest, Runtime,
    VariantLadder,
};
use soi::util::cli::Args;
use soi::util::json::Json;
use soi::util::rng::Rng;

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(
        argv,
        &[
            "help",
            "no-idle-precompute",
            "no-batching",
            "adaptive",
            "telemetry",
            "watch-generations",
        ],
    )
    .map_err(anyhow::Error::msg)?;
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");

    match cmd {
        "help" => {
            println!("{}", HELP);
            Ok(())
        }
        "list" => {
            let names = list_variants(&artifacts)
                .with_context(|| format!("listing {}", artifacts.display()))?;
            println!("{:<16} {:>9} {:>10} {:>8} {:>9} {:>8}", "variant", "period",
                     "MAC/frame", "retain%", "SI-SNRi", "FP");
            let base = Manifest::load(&artifacts.join("stmc")).ok();
            for n in names {
                let m = Manifest::load(&artifacts.join(&n))?;
                let retain = base
                    .as_ref()
                    .map(|b| 100.0 * m.macs_per_frame / b.macs_per_frame)
                    .unwrap_or(f64::NAN);
                println!(
                    "{:<16} {:>9} {:>10.0} {:>8.1} {:>9.2} {:>8}",
                    m.name,
                    m.period,
                    m.macs_per_frame,
                    retain,
                    m.si_snri().unwrap_or(f64::NAN),
                    if m.has_fp_split() { "yes" } else { "-" },
                );
            }
            Ok(())
        }
        "info" => {
            let name = args.positional().get(1).context("info needs a variant name")?;
            let m = Manifest::load(&artifacts.join(name))?;
            println!("name            {}", m.name);
            println!("config          feat={} channels={:?} k={}", m.config.feat,
                     m.config.channels, m.config.kernel);
            println!("scc             {:?}  shift_pos={:?} shift={}", m.config.scc,
                     m.config.shift_pos, m.config.shift);
            println!("period          {}", m.period);
            println!("macs/frame      {:.0}", m.macs_per_frame);
            println!("precomputed     {:.1}%", 100.0 * m.precomputed_fraction);
            println!("params          {}", m.param_count);
            println!("state bytes     {}", m.state_bytes);
            println!("states          {}", m.states.len());
            println!("executables     {:?}", m.executables.keys().collect::<Vec<_>>());
            println!("train SI-SNRi   {:?}", m.si_snri());
            Ok(())
        }
        "exp" => {
            let what = args.positional().get(1).map(|s| s.as_str()).unwrap_or("all");
            let results = PathBuf::from(args.str_or("results", "results"));
            let ctx = Ctx::new(
                &artifacts,
                &results,
                args.usize_or("n-eval", 6).map_err(anyhow::Error::msg)?,
                args.u64_or("seed", 42).map_err(anyhow::Error::msg)?,
            )?;
            experiments::run(&ctx, what)
        }
        "serve" => {
            let opts = ServeOpts {
                variant: args.positional().get(1).cloned(),
                streams: args.usize_or("streams", 8).map_err(anyhow::Error::msg)?,
                frames: args.usize_or("frames", 500).map_err(anyhow::Error::msg)?,
                workers: args.usize_or("workers", 4).map_err(anyhow::Error::msg)?,
                seed: args.u64_or("seed", 42).map_err(anyhow::Error::msg)?,
                idle_precompute: !args.flag("no-idle-precompute"),
                batching: !args.flag("no-batching"),
                adaptive: args.flag("adaptive"),
                dtype: Dtype::parse(&args.str_or("dtype", "f32"))?,
                ladder: args
                    .str_or("ladder", "stmc,scc2,sscc5")
                    .split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect(),
                target_p99_us: args.u64_or("target-p99-us", 500).map_err(anyhow::Error::msg)?,
                pace_us: args.u64_or("pace-us", 0).map_err(anyhow::Error::msg)?,
                // boolean-style flag that also accepts a value:
                // `--telemetry` -> default path, `--telemetry=PATH` -> PATH
                telemetry: args.get("telemetry").map(|v| {
                    if v == "true" {
                        "soi-feed.ndjson".to_string()
                    } else {
                        v.to_string()
                    }
                }),
                snapshot_ms: args.u64_or("snapshot-ms", 200).map_err(anyhow::Error::msg)?,
                artifact_dir: args.get("artifact-dir").map(PathBuf::from),
                watch: args.flag("watch-generations"),
                watch_ms: args.u64_or("watch-ms", 200).map_err(anyhow::Error::msg)?,
                idle_poll_ms: args.u64_or("idle-poll-ms", 2).map_err(anyhow::Error::msg)?,
            };
            if opts.watch && opts.artifact_dir.is_none() {
                bail!("--watch-generations needs --artifact-dir DIR to watch");
            }
            serve_bench(&artifacts, opts)
        }
        "export-artifact" => {
            let spec = args
                .positional()
                .get(1)
                .context("export-artifact needs a variant spec (e.g. scc2 or scc2:int8)")?;
            let generation = args.u64_or("generation", 1).map_err(anyhow::Error::msg)?;
            let seed = args.u64_or("seed", 0xC0DE).map_err(anyhow::Error::msg)?;
            let out = args.get("out").map(PathBuf::from).unwrap_or_else(|| {
                artifacts.join(format!("{}-gen{generation:06}", spec.replace(':', "-")))
            });
            export_artifact(&artifacts, spec, generation, seed, &out)
        }
        "inspect-artifact" => {
            let dir = args
                .positional()
                .get(1)
                .context("inspect-artifact needs an artifact directory")?;
            inspect_artifact(std::path::Path::new(dir))
        }
        "validate-feed" => {
            let path = args
                .positional()
                .get(1)
                .context("validate-feed needs the path of an NDJSON health feed")?;
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading feed {path}"))?;
            // Per-process and aggregated cluster feeds share the
            // command; the schema field of the first line decides.
            match obs::schema::detect_schema(&text) {
                Some(s) if s == obs::CLUSTER_SCHEMA => {
                    let s = obs::schema::validate_cluster_feed(&text).map_err(anyhow::Error::msg)?;
                    println!(
                        "{path}: valid {} feed — {} lines ({} cluster, {} shards, {} hists, {} spans)",
                        obs::CLUSTER_SCHEMA,
                        s.lines,
                        s.clusters,
                        s.shards,
                        s.hists,
                        s.spans
                    );
                }
                _ => {
                    let s = obs::schema::validate_feed(&text).map_err(anyhow::Error::msg)?;
                    println!(
                        "{path}: valid {} feed — {} lines ({} snapshots, {} hists, {} events)",
                        obs::FEED_SCHEMA,
                        s.lines,
                        s.snapshots,
                        s.hists,
                        s.events
                    );
                }
            }
            Ok(())
        }
        "aggregate-feeds" => {
            let feeds = feed_list(&args, "aggregate-feeds")?;
            let summary = obs::aggregate(&feeds).map_err(anyhow::Error::msg)?;
            let mut out = String::new();
            summary.render_ndjson(&mut out);
            match args.get("out") {
                Some(path) => {
                    std::fs::write(path, &out)
                        .with_context(|| format!("writing cluster feed {path}"))?;
                    eprintln!(
                        "aggregated {} shard feeds -> {path} ({} spans)",
                        summary.shards.len(),
                        summary.spans().count()
                    );
                }
                None => print!("{out}"),
            }
            Ok(())
        }
        "top" => {
            let interval = args.u64_or("interval-ms", 1000).map_err(anyhow::Error::msg)?;
            let iterations = args.u64_or("iterations", 0).map_err(anyhow::Error::msg)?;
            cmd_top(&args, interval, iterations)
        }
        "serve-shard" => {
            let name = args
                .positional()
                .get(1)
                .context("serve-shard needs a variant name")?;
            let dtype = Dtype::parse(&args.str_or("dtype", "f32"))?;
            let opts = ShardOpts {
                listen: args.str_or("listen", "127.0.0.1:7071"),
                workers: args.usize_or("workers", 4).map_err(anyhow::Error::msg)?,
                shard_id: args.u64_or("shard-id", 1).map_err(anyhow::Error::msg)?,
                telemetry: args.get("telemetry").map(|v| {
                    if v == "true" {
                        "soi-shard-feed.ndjson".to_string()
                    } else {
                        v.to_string()
                    }
                }),
                snapshot_ms: args.u64_or("snapshot-ms", 200).map_err(anyhow::Error::msg)?,
                idle_poll_ms: args.u64_or("idle-poll-ms", 2).map_err(anyhow::Error::msg)?,
            };
            serve_shard(&artifacts, &spec_with_dtype(name, dtype), opts)
        }
        "serve-front" => {
            let shards: Vec<String> = args
                .str_or("shards", "")
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            if shards.is_empty() {
                bail!("serve-front needs --shards host:port[,host:port..]");
            }
            let feeds: Vec<String> = args
                .str_or("feeds", "")
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            let opts = FrontOpts {
                listen: args.str_or("listen", "127.0.0.1:7070"),
                max_sessions: args.usize_or("max-sessions", 64).map_err(anyhow::Error::msg)?,
                balance_ms: args.u64_or("balance-ms", 500).map_err(anyhow::Error::msg)?,
                trace_sample_n: args.u64_or("trace-sample-n", 0).map_err(anyhow::Error::msg)?,
                heartbeat_ms: args.u64_or("heartbeat-ms", 0).map_err(anyhow::Error::msg)?,
                miss_budget: args.u64_or("miss-budget", 3).map_err(anyhow::Error::msg)? as u32,
                retry_budget: args.u64_or("retry-budget", 1024).map_err(anyhow::Error::msg)?,
                min_live_shards: args
                    .usize_or("min-live-shards", 1)
                    .map_err(anyhow::Error::msg)?,
                telemetry: args.get("telemetry").map(|v| {
                    if v == "true" {
                        "soi-front-feed.ndjson".to_string()
                    } else {
                        v.to_string()
                    }
                }),
                snapshot_ms: args.u64_or("snapshot-ms", 200).map_err(anyhow::Error::msg)?,
            };
            serve_front(shards, feeds, opts)
        }
        "wire-smoke" => {
            let variant = args
                .positional()
                .get(1)
                .map(|s| s.as_str())
                .unwrap_or("scc2")
                .to_string();
            let feeds: Vec<String> = args
                .str_or("feeds", "")
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            let opts = SmokeOpts {
                streams: args.usize_or("streams", 4).map_err(anyhow::Error::msg)?,
                frames: args.usize_or("frames", 96).map_err(anyhow::Error::msg)?,
                workers: args.usize_or("workers", 2).map_err(anyhow::Error::msg)?,
                seed: args.u64_or("seed", 42).map_err(anyhow::Error::msg)?,
                snapshot_ms: args.u64_or("snapshot-ms", 50).map_err(anyhow::Error::msg)?,
                trace_sample_n: args.u64_or("trace-sample-n", 0).map_err(anyhow::Error::msg)?,
                front_feed: args.get("front-feed").map(|s| s.to_string()),
                feeds,
            };
            wire_smoke(&artifacts, &variant, opts)
        }
        "chaos-smoke" => {
            let variant = args
                .positional()
                .get(1)
                .map(|s| s.as_str())
                .unwrap_or("scc2")
                .to_string();
            let opts = ChaosSmokeOpts {
                streams: args.usize_or("streams", 4).map_err(anyhow::Error::msg)?,
                frames: args.usize_or("frames", 96).map_err(anyhow::Error::msg)?,
                workers: args.usize_or("workers", 2).map_err(anyhow::Error::msg)?,
                seed: args.u64_or("seed", 42).map_err(anyhow::Error::msg)?,
                chaos_seed: args.u64_or("chaos-seed", 7).map_err(anyhow::Error::msg)?,
                events: args.usize_or("events", 3).map_err(anyhow::Error::msg)?,
                span: args.u64_or("span", 40).map_err(anyhow::Error::msg)?,
                heartbeat_ms: args.u64_or("heartbeat-ms", 20).map_err(anyhow::Error::msg)?,
                miss_budget: args.u64_or("miss-budget", 3).map_err(anyhow::Error::msg)? as u32,
                retry_budget: args.u64_or("retry-budget", 4096).map_err(anyhow::Error::msg)?,
                front_feed: args.get("front-feed").map(|s| s.to_string()),
                snapshot_ms: args.u64_or("snapshot-ms", 50).map_err(anyhow::Error::msg)?,
            };
            chaos_smoke(&artifacts, &variant, opts)
        }
        "denoise" => {
            let name = args.positional().get(1).context("denoise needs a variant name")?;
            let dtype = Dtype::parse(&args.str_or("dtype", "f32"))?;
            let spec = spec_with_dtype(name, dtype);
            let n_frames = args.usize_or("frames", 1000).map_err(anyhow::Error::msg)?;
            let seed = args.u64_or("seed", 42).map_err(anyhow::Error::msg)?;
            denoise_once(&artifacts, &spec, n_frames, seed)
        }
        other => bail!("unknown command '{other}'\n{HELP}"),
    }
}

/// Load `artifacts/<name>` when built, else synthesize the preset.
fn load_variant(
    rt: Arc<Runtime>,
    artifacts: &std::path::Path,
    name: &str,
) -> Result<CompiledVariant> {
    let (cv, synthesized) = synth::load_or_synth(rt, artifacts, name, 0xC0DE)?;
    if synthesized {
        eprintln!(
            "note: artifacts/{name} not built — synthesized untrained weights \
             (timing/complexity meaningful, quality numbers are not)"
        );
    }
    Ok(cv)
}

/// Build `<spec>` (trained build when present, synthesized otherwise)
/// and save it as a versioned `soi.artifact.v1` directory (DESIGN.md
/// §13): `artifact.json` with per-tensor sha-256 + raw f32 `weights.bin`.
fn export_artifact(
    artifacts: &std::path::Path,
    spec: &str,
    generation: u64,
    seed: u64,
    out: &std::path::Path,
) -> Result<()> {
    let rt = Arc::new(Runtime::cpu()?);
    let (cv, synthesized) = synth::load_or_synth(rt, artifacts, spec, seed)?;
    if synthesized {
        eprintln!(
            "note: artifacts/{spec} not built — exporting synthesized untrained \
             weights (format/integrity meaningful, quality numbers are not)"
        );
    }
    let art = Artifact::new(cv.manifest.clone(), cv.weights.clone(), generation)?;
    art.save(out)?;
    let bytes: usize = art.weights.tensors.iter().map(|t| t.bytes()).sum();
    println!(
        "exported '{}' generation {} -> {} ({} tensors, {} weight bytes, \
         every tensor sha-256 digested)",
        art.name(),
        art.generation,
        out.display(),
        art.weights.tensors.len(),
        bytes,
    );
    Ok(())
}

/// Verify an artifact end to end (every digest, the full manifest) and
/// print a summary; any corruption exits nonzero with the typed error.
fn inspect_artifact(dir: &std::path::Path) -> Result<()> {
    let art = Artifact::load(dir)
        .map_err(anyhow::Error::from)
        .with_context(|| format!("inspecting {}", dir.display()))?;
    let m = &art.manifest;
    let bytes: usize = art.weights.tensors.iter().map(|t| t.bytes()).sum();
    println!("artifact        {}", dir.display());
    println!("schema          {}", soi::runtime::ARTIFACT_SCHEMA);
    println!("name            {}", art.name());
    println!("generation      {}", art.generation);
    println!("config          feat={} channels={:?} k={}", m.config.feat,
             m.config.channels, m.config.kernel);
    println!("scc             {:?}  shift_pos={:?} shift={}", m.config.scc,
             m.config.shift_pos, m.config.shift);
    println!("dtype           {}", m.dtype.as_str());
    println!("period          {}", m.period);
    println!("params          {}", m.param_count);
    println!(
        "weights         {} tensors / {} bytes — all sha-256 digests verified",
        art.weights.tensors.len(),
        bytes
    );
    println!("train SI-SNRi   {:?}", m.si_snri());
    Ok(())
}

/// Apply a `--dtype` default to a variant spec lacking an explicit
/// `:<dtype>` suffix ("scc2" + int8 → "scc2:int8"; "scc2:f32" wins).
fn spec_with_dtype(spec: &str, dtype: Dtype) -> String {
    if spec.contains(':') || dtype == Dtype::F32 {
        spec.to_string()
    } else {
        format!("{spec}:{}", dtype.as_str())
    }
}


/// Options of the `serve` subcommand.
struct ServeOpts {
    /// Pinned variant name (required unless `adaptive`).
    variant: Option<String>,
    streams: usize,
    frames: usize,
    workers: usize,
    seed: u64,
    idle_precompute: bool,
    batching: bool,
    /// Load-adaptive ladder serving (DESIGN.md §9).
    adaptive: bool,
    /// Default execution precision (`--dtype f32|int8`, DESIGN.md §10):
    /// applied to the pinned variant / every ladder entry without an
    /// explicit `:<dtype>` suffix.
    dtype: Dtype,
    /// Ladder rung names, best quality first (`--ladder a,b,c`; entries
    /// may carry `:<dtype>` suffixes for mixed-precision ladders).
    ladder: Vec<String>,
    /// Controller p99 target, µs (`--target-p99-us`).
    target_p99_us: u64,
    /// Dispatcher gap per round, µs (`--pace-us`; 0 floods).
    pace_us: u64,
    /// NDJSON health-feed path (`--telemetry[=PATH]`, DESIGN.md §12);
    /// `None` serves unobserved.
    telemetry: Option<String>,
    /// Feed snapshot interval, ms (`--snapshot-ms`).
    snapshot_ms: u64,
    /// Versioned-artifact root (`--artifact-dir`, DESIGN.md §13): serve
    /// rungs compiled over the newest verified generation's shipped
    /// weights instead of per-spec load/synth; `None` serves as before.
    artifact_dir: Option<PathBuf>,
    /// Poll the artifact root for newer generations and hot-reload them
    /// mid-run with zero dropped streams (`--watch-generations`).
    watch: bool,
    /// Generation poll interval, ms (`--watch-ms`).
    watch_ms: u64,
    /// Idle-worker queue-poll step, ms (`--idle-poll-ms`; only used
    /// while hot reload is enabled).
    idle_poll_ms: u64,
}

/// Load the newest verified generation under `root` (serve boot,
/// DESIGN.md §13).  Every candidate the verifying loader rejects is
/// reported and skipped — boot succeeds on the newest loadable one.
fn newest_generation(root: &std::path::Path) -> Result<(u64, Artifact)> {
    let gens = artifact::list_generations(root)
        .with_context(|| format!("listing artifact generations under {}", root.display()))?;
    for (seq, dir) in gens.into_iter().rev() {
        match Artifact::load(&dir) {
            Ok(art) => return Ok((seq, art)),
            Err(e) => eprintln!(
                "soi: skipping artifact generation {seq} at {}: {e}",
                dir.display()
            ),
        }
    }
    bail!("no loadable artifact generation under {}", root.display())
}

/// Multi-stream serving benchmark over synthetic utterances.
fn serve_bench(artifacts: &std::path::Path, opts: ServeOpts) -> Result<()> {
    let rt = Arc::new(Runtime::cpu()?);
    // Versioned-artifact serving (DESIGN.md §13): boot on the newest
    // verified generation under --artifact-dir; every rung then compiles
    // over that generation's shipped tensors.
    let boot = opts.artifact_dir.as_deref().map(newest_generation).transpose()?;
    // (server, rung names, frame size, dtype label for the summary, and —
    // for pinned int8 serving — the base spec of the f32 reference twin)
    let (mut server, names, feat, dtype_label, int8_base) = if opts.adaptive {
        if let Some(name) = &opts.variant {
            bail!(
                "serve --adaptive takes its variants from --ladder (got positional \
                 variant '{name}'); drop it or list it in --ladder"
            );
        }
        let specs: Vec<String> = opts
            .ladder
            .iter()
            .map(|n| spec_with_dtype(n, opts.dtype))
            .collect();
        let ladder = match &boot {
            Some((seq, art)) => {
                let refs: Vec<&str> = specs.iter().map(|s| s.as_str()).collect();
                println!("booting on artifact generation {seq} ('{}')", art.name());
                Arc::new(VariantLadder::over_weights(
                    rt.clone(),
                    &art.manifest.config,
                    &art.weights,
                    &refs,
                    opts.seed,
                )?)
            }
            None => {
                let mut variants = Vec::with_capacity(specs.len());
                for spec in &specs {
                    variants.push(Arc::new(load_variant(rt.clone(), artifacts, spec)?));
                }
                Arc::new(VariantLadder::new(variants)?)
            }
        };
        let names: Vec<String> = ladder.names().iter().map(|s| s.to_string()).collect();
        let feat = ladder.level(0).manifest.config.feat;
        let dtypes = ladder.dtypes();
        let dtype_label = if dtypes.iter().all(|&d| d == dtypes[0]) {
            dtypes[0].as_str().to_string()
        } else {
            "mixed".to_string()
        };
        println!(
            "adaptive serving on the {} backend: ladder {:?}, target p99 {} \u{3bc}s, \
             warmup \u{2264} {} frames, {} streams x {} frames, {} workers",
            rt.platform(),
            names,
            opts.target_p99_us,
            ladder.max_warmup(),
            opts.streams,
            opts.frames,
            opts.workers,
        );
        let mut server = Server::with_ladder(ladder, opts.workers);
        server.adaptive = Some(AdaptivePolicy::with_target_us(opts.target_p99_us));
        (server, names, feat, dtype_label, None)
    } else {
        let name = opts
            .variant
            .as_deref()
            .context("serve needs a variant name (or --adaptive with --ladder)")?;
        let spec = spec_with_dtype(name, opts.dtype);
        let cv = match &boot {
            Some((seq, art)) => {
                println!("booting on artifact generation {seq} ('{}')", art.name());
                VariantLadder::over_weights(
                    rt.clone(),
                    &art.manifest.config,
                    &art.weights,
                    &[spec.as_str()],
                    opts.seed,
                )?
                .level(0)
                .clone()
            }
            None => Arc::new(load_variant(rt.clone(), artifacts, &spec)?),
        };
        let feat = cv.manifest.config.feat;
        let dtype_label = cv.manifest.dtype.as_str().to_string();
        let int8_base = if cv.manifest.dtype == Dtype::Int8 {
            Some(synth::parse_spec(&spec)?.0.to_string())
        } else {
            None
        };
        println!(
            "serving '{spec}' on the {} backend: {} streams x {} frames, \
             {} workers, period {}, dtype {}, FP split: {}",
            rt.platform(),
            opts.streams,
            opts.frames,
            opts.workers,
            cv.manifest.period,
            dtype_label,
            cv.has_fp_split()
        );
        (
            Server::new(cv, opts.workers),
            vec![spec],
            feat,
            dtype_label,
            int8_base,
        )
    };
    let mut rng = Rng::new(opts.seed);
    let mut streams = Vec::with_capacity(opts.streams);
    let mut cleans = Vec::with_capacity(opts.streams);
    let mut noisys = Vec::with_capacity(opts.streams);
    for _ in 0..opts.streams {
        let (noisy, clean) = siggen::denoise_pair(&mut rng, feat * opts.frames, siggen::FS);
        let (cols, _) = frames(&noisy, feat);
        streams.push(cols);
        cleans.push(clean);
        noisys.push(noisy);
    }
    server.idle_precompute = opts.idle_precompute;
    server.batching = opts.batching;
    server.idle_poll_ms = opts.idle_poll_ms;
    // Hot reload (DESIGN.md §13): publish the boot generation and, when
    // watching, poll the artifact root for newer ones in the background —
    // workers adopt each publish at a phase-0 boundary with no stream
    // dropped, and a rejected candidate leaves the old generation live.
    let watcher = match &boot {
        Some((seq, _)) => {
            let handle = server.enable_reload(*seq);
            opts.watch.then(|| {
                GenerationWatcher::spawn(
                    rt.clone(),
                    opts.artifact_dir.clone().expect("watch implies --artifact-dir"),
                    names.clone(),
                    opts.seed,
                    handle,
                    opts.watch_ms,
                )
            })
        }
        None => None,
    };
    // Telemetry (DESIGN.md §12): install the recording root on the
    // server and the process-global hook (quant repack), and start the
    // NDJSON exporter before any frame is served.
    let exporter = match &opts.telemetry {
        Some(path) => {
            let tel = Telemetry::new(ObsConfig::default());
            tel.install_global();
            let feed = PathBuf::from(path);
            let exporter = Exporter::start(tel.clone(), &feed, opts.snapshot_ms)
                .with_context(|| format!("creating health feed {path}"))?;
            server.telemetry = Some(tel);
            Some(exporter)
        }
        None => None,
    };
    let report = if opts.pace_us > 0 {
        server.run_paced(&streams, &[opts.pace_us])?
    } else {
        server.run(&streams)?
    };
    if let Some(w) = watcher {
        w.stop();
    }
    if let Some(exporter) = exporter {
        let path = exporter.path().display().to_string();
        let stats = exporter.finish().context("finishing the health feed")?;
        Telemetry::uninstall_global();
        eprintln!(
            "telemetry: {} snapshots ({} dropped), {} lines / {} bytes -> {}",
            stats.snapshots, stats.drops, stats.lines, stats.bytes, path
        );
    }
    println!("{}", report.metrics.report());
    println!(
        "throughput: {:.0} frames/s ({:.1}x realtime across streams)",
        report.throughput_fps(),
        report.throughput_fps() / (siggen::FS / feat as f64)
    );
    // quality check over served outputs
    let mut imps = Vec::new();
    for (sid, outs) in &report.outputs {
        let est: Vec<f32> = outs.iter().flatten().copied().collect();
        let n = est.len();
        imps.push(metrics::si_snr_improvement(
            &noisys[*sid as usize][..n],
            &est,
            &cleans[*sid as usize][..n],
        ));
    }
    let (m, s) = soi::experiments::eval::mean_std(&imps);
    println!("served SI-SNRi: {m:.2} ± {s:.2} dB over {} streams", imps.len());
    // Quantization fidelity: for pinned int8 serving, replay stream 0
    // through the f32 twin (same weights — the base spec loads or
    // synthesizes the identical tensor set) and measure output SNR
    // against what the quantized server actually produced.
    let quant_snr = match &int8_base {
        Some(base) if report.outputs.contains_key(&0) => {
            let f32_cv = match &boot {
                // artifact serving: the twin runs on the same shipped tensors
                Some((_, art)) => VariantLadder::over_weights(
                    rt.clone(),
                    &art.manifest.config,
                    &art.weights,
                    &[base.as_str()],
                    opts.seed,
                )?
                .level(0)
                .clone(),
                None => Arc::new(load_variant(rt.clone(), artifacts, base)?),
            };
            let dw = Arc::new(f32_cv.device_weights()?);
            let mut sess = StreamSession::new(0, f32_cv, dw);
            let mut reference = Vec::with_capacity(feat * streams[0].len());
            for col in &streams[0] {
                reference.extend(sess.on_frame(col)?);
            }
            let served: Vec<f32> = report.outputs[&0].iter().flatten().copied().collect();
            let snr = metrics::output_snr_db(&reference, &served);
            println!("int8 output SNR vs f32 reference: {snr:.1} dB (stream 0)");
            Some(snr)
        }
        _ => None,
    };
    // machine-readable summary (DESIGN.md appendix A documents every
    // field; `variant_frames` shows which rung traffic ran on;
    // `dtype`/`snr_db`/`macs_int8` extend the PR 3 schema additively,
    // `ns_per_mac` the PR 5 schema; `schema`/`arena_peak_*` are the
    // PR 6 additions — the `schema` tag makes downstream parsers
    // version-aware, like the health feed's `soi.obs.v1`).
    // ns_per_mac is wall time over executed MACs, so it only measures
    // compute efficiency on flood runs; paced runs (--pace-us) would
    // fold the intentional dispatch gaps in, so they report null.
    let ns_per_mac = if report.metrics.macs_executed > 0.0 && opts.pace_us == 0 {
        Json::Num(report.wall_seconds * 1e9 / report.metrics.macs_executed)
    } else {
        Json::Null
    };
    let summary = Json::obj(vec![
        ("schema", Json::Str("soi.serve.v2".into())),
        ("cmd", Json::Str("serve".into())),
        (
            "mode",
            Json::Str(if opts.adaptive { "adaptive" } else { "pinned" }.into()),
        ),
        (
            "ladder",
            Json::Arr(names.iter().map(|n| Json::Str(n.clone())).collect()),
        ),
        (
            "target_p99_us",
            Json::Num(if opts.adaptive {
                opts.target_p99_us as f64
            } else {
                0.0
            }),
        ),
        ("pace_us", Json::Num(opts.pace_us as f64)),
        ("workers", Json::Num(opts.workers as f64)),
        ("streams", Json::Num(opts.streams as f64)),
        ("frames", Json::Num(report.frames as f64)),
        ("frames_per_s", Json::Num(report.throughput_fps())),
        (
            "p99_us",
            Json::Num(report.metrics.arrival_latency.p99() as f64 / 1_000.0),
        ),
        ("retain_pct", Json::Num(report.metrics.retain_pct())),
        ("mean_batch", Json::Num(report.metrics.mean_batch())),
        ("migrations", Json::Num(report.metrics.migrations as f64)),
        ("migration_macs", Json::Num(report.metrics.macs_migration)),
        // weight generation the run ended on (0 without --artifact-dir;
        // PR 7 additive field, DESIGN.md §13)
        ("generation", Json::Num(report.generation as f64)),
        ("dtype", Json::Str(dtype_label.clone())),
        ("macs_int8", Json::Num(report.metrics.macs_int8)),
        ("ns_per_mac", ns_per_mac),
        (
            "snr_db",
            match quant_snr {
                Some(v) => Json::Num(v),
                None => Json::Null,
            },
        ),
        (
            "variant_frames",
            Json::Obj(
                report
                    .metrics
                    .variant_frames
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                    .collect(),
            ),
        ),
        (
            "arena_peak_bytes",
            Json::Num(report.arena_peak_bytes as f64),
        ),
        (
            "arena_peak_by_variant",
            Json::Obj({
                // HashMap -> sorted pairs: the summary line is diffable
                let mut peaks: Vec<(String, Json)> = report
                    .arena_peak_by_variant
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                    .collect();
                peaks.sort_by(|a, b| a.0.cmp(&b.0));
                peaks
            }),
        ),
    ]);
    println!("{}", summary.to_string());
    Ok(())
}

/// Stream one utterance through a single session and report quality.
fn denoise_once(
    artifacts: &std::path::Path,
    name: &str,
    n_frames: usize,
    seed: u64,
) -> Result<()> {
    let rt = Arc::new(Runtime::cpu()?);
    let cv = Arc::new(load_variant(rt, artifacts, name)?);
    let feat = cv.manifest.config.feat;
    let dw = Arc::new(cv.device_weights()?);
    let mut sess = soi::coordinator::StreamSession::new(0, cv, dw);
    let mut rng = Rng::new(seed);
    let (noisy, clean) = siggen::denoise_pair(&mut rng, feat * n_frames, siggen::FS);
    let (cols, _) = frames(&noisy, feat);
    let mut est = Vec::with_capacity(noisy.len());
    for col in &cols {
        sess.idle()?;
        est.extend(sess.on_frame(col)?);
    }
    let n = est.len();
    println!(
        "SI-SNRi {:.2} dB | {}",
        metrics::si_snr_improvement(&noisy[..n], &est, &clean[..n]),
        sess.metrics.report()
    );
    Ok(())
}

/// Options of the `serve-shard` subcommand.
struct ShardOpts {
    /// TCP listen address (`--listen`, default `127.0.0.1:7071`).
    listen: String,
    workers: usize,
    /// Operator-assigned shard id (`--shard-id`), exported on the
    /// health feed so the cluster controller can attribute it.
    shard_id: u64,
    /// NDJSON health-feed path (`--telemetry[=PATH]`).
    telemetry: Option<String>,
    snapshot_ms: u64,
    /// Idle-worker queue-poll step, ms (`--idle-poll-ms`; only used
    /// while hot reload is enabled).
    idle_poll_ms: u64,
}

/// Run one backend shard over TCP until the front-end drains it
/// (DESIGN.md §14): a `coordinator::Server` worker pool behind a
/// `soi.wire.v1` endpoint, with §9 warm resume of migrated sessions.
fn serve_shard(artifacts: &std::path::Path, spec: &str, opts: ShardOpts) -> Result<()> {
    let rt = Arc::new(Runtime::cpu()?);
    let cv = Arc::new(load_variant(rt, artifacts, spec)?);
    let mut server = Server::new(cv, opts.workers);
    server.idle_poll_ms = opts.idle_poll_ms;
    let exporter = match &opts.telemetry {
        Some(path) => {
            let tel = Telemetry::new(ObsConfig::default());
            let feed = PathBuf::from(path);
            let exporter = Exporter::start(tel.clone(), &feed, opts.snapshot_ms)
                .with_context(|| format!("creating health feed {path}"))?;
            server.telemetry = Some(tel);
            Some(exporter)
        }
        None => None,
    };
    let port = TcpPort::bind(&opts.listen).map_err(|e| anyhow!("bind {}: {e}", opts.listen))?;
    let addr = port.local_addr().map_err(|e| anyhow!("local_addr: {e}"))?;
    println!(
        "shard {} serving '{spec}' on {addr}: {} workers (whole-shard drain stops it)",
        opts.shard_id, opts.workers
    );
    let report = run_shard(&server, &port, ShardConfig { shard_id: opts.shard_id })?;
    if let Some(exporter) = exporter {
        let path = exporter.path().display().to_string();
        let stats = exporter.finish().context("finishing the health feed")?;
        eprintln!("telemetry: {} snapshots, {} lines -> {path}", stats.snapshots, stats.lines);
    }
    println!(
        "shard {}: {} conns, {} frames in / {} out, {} resumes, {} drains, {} wire errors",
        opts.shard_id,
        report.conns,
        report.frames_in,
        report.frames_out,
        report.resumes,
        report.drains,
        report.wire_errs
    );
    Ok(())
}

/// Read `--feeds a,b,c` into named `(name, contents)` pairs for the
/// aggregator; a feed is named by its file stem (`shard-a` from
/// `/tmp/shard-a.ndjson`), falling back to the full path on a clash.
fn feed_list(args: &Args, cmd: &str) -> Result<Vec<(String, String)>> {
    let paths = feed_paths(args);
    if paths.is_empty() {
        bail!("{cmd} needs --feeds a.ndjson,b.ndjson[,..]");
    }
    let mut out: Vec<(String, String)> = Vec::with_capacity(paths.len());
    for path in &paths {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading feed {path}"))?;
        out.push((feed_name(&out, path), text));
    }
    Ok(out)
}

fn feed_paths(args: &Args) -> Vec<String> {
    args.str_or("feeds", "")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect()
}

fn feed_name(taken: &[(String, String)], path: &str) -> String {
    let stem = std::path::Path::new(path)
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or(path)
        .to_string();
    if taken.iter().any(|(n, _)| *n == stem) {
        path.to_string()
    } else {
        stem
    }
}

/// The `top` subcommand: a live cluster console over `--feeds`.
/// Each refresh re-reads and re-aggregates every feed; one that is
/// briefly unreadable (exporter not started yet) is skipped for that
/// frame.  Plain ANSI clear-and-home — no terminal library.
fn cmd_top(args: &Args, interval_ms: u64, iterations: u64) -> Result<()> {
    use std::io::Write as _;
    let paths = feed_paths(args);
    if paths.is_empty() {
        bail!("top needs --feeds a.ndjson,b.ndjson[,..]");
    }
    let mut done = 0u64;
    loop {
        let mut feeds: Vec<(String, String)> = Vec::with_capacity(paths.len());
        for path in &paths {
            if let Ok(text) = std::fs::read_to_string(path) {
                feeds.push((feed_name(&feeds, path), text));
            }
        }
        let mut frame = String::new();
        match obs::aggregate(&feeds) {
            Ok(summary) => summary.render_top(&mut frame),
            Err(e) => frame = format!("soi top: waiting for feeds ({e})\n"),
        }
        print!("\x1b[2J\x1b[H{frame}");
        std::io::stdout().flush().ok();
        done += 1;
        if iterations != 0 && done >= iterations {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_millis(interval_ms));
    }
}

/// Options of the `serve-front` subcommand.
struct FrontOpts {
    /// TCP listen address (`--listen`, default `127.0.0.1:7070`).
    listen: String,
    /// Fleet-wide session cap (`--max-sessions`).
    max_sessions: usize,
    /// Health-feed poll interval, ms (`--balance-ms`).
    balance_ms: u64,
    /// Trace every nth forwarded frame (`--trace-sample-n`, 0 = off).
    trace_sample_n: u64,
    /// Heartbeat tick interval, ms (`--heartbeat-ms`, 0 = off;
    /// DESIGN.md §16).
    heartbeat_ms: u64,
    /// Silent ticks before a shard is declared suspect
    /// (`--miss-budget`).
    miss_budget: u32,
    /// Per-session recovery resend cap (`--retry-budget`).
    retry_budget: u64,
    /// Reachable shards required to admit new sessions
    /// (`--min-live-shards`).
    min_live_shards: usize,
    /// The front's own `soi.obs.v1` feed path (`--telemetry[=PATH]`).
    telemetry: Option<String>,
    /// Snapshot cadence for that feed, ms (`--snapshot-ms`).
    snapshot_ms: u64,
}

/// Run the TCP front-end over an already-running shard fleet.  With
/// `--feeds`, poll each shard's `soi.obs.v1` health feed and let the
/// cluster controller rebalance sessions across shards by zero-drop
/// warm migration (DESIGN.md §14).
fn serve_front(shards: Vec<String>, feeds: Vec<String>, opts: FrontOpts) -> Result<()> {
    let links: Vec<ShardLink> = shards
        .iter()
        .map(|addr| ShardLink {
            name: addr.clone(),
            transport: Box::new(TcpConnector::new(addr.clone())),
        })
        .collect();
    let port = TcpPort::bind(&opts.listen).map_err(|e| anyhow!("bind {}: {e}", opts.listen))?;
    let addr = port.local_addr().map_err(|e| anyhow!("local_addr: {e}"))?;
    let policy = FrontPolicy {
        max_sessions: opts.max_sessions,
        trace_sample_n: opts.trace_sample_n,
        heartbeat_ms: opts.heartbeat_ms,
        miss_budget: opts.miss_budget,
        retry_budget: opts.retry_budget,
        min_live_shards: opts.min_live_shards,
    };
    // The front exports the same soi.obs.v1 feed a shard does; the
    // exporter runs for the life of the process (serve-front never
    // returns), so the handle is just kept alive.
    let mut telemetry = None;
    let _exporter = match &opts.telemetry {
        Some(path) => {
            let tel = Telemetry::new(ObsConfig::default());
            let exporter = Exporter::start(tel.clone(), &PathBuf::from(path), opts.snapshot_ms)
                .with_context(|| format!("creating health feed {path}"))?;
            telemetry = Some(tel);
            Some(exporter)
        }
        None => None,
    };
    let handle = spawn_front_with(Box::new(port), links, policy, telemetry)?;
    println!(
        "front on {addr}: {} shards {shards:?}, max {} sessions (ctrl-c to stop)",
        shards.len(),
        opts.max_sessions
    );
    if opts.trace_sample_n > 0 {
        println!("tracing every {}th forwarded frame (DESIGN.md \u{a7}15)", opts.trace_sample_n);
    }
    if opts.heartbeat_ms > 0 {
        println!(
            "heartbeat every {} ms, suspect after {} misses, retry budget {} \
             frames/session (DESIGN.md \u{a7}16)",
            opts.heartbeat_ms, opts.miss_budget, opts.retry_budget
        );
    }
    if feeds.is_empty() {
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    println!("balancing over {} feeds every {} ms", feeds.len(), opts.balance_ms);
    let mut controller = ClusterController::new(ClusterPolicy::default());
    loop {
        std::thread::sleep(std::time::Duration::from_millis(opts.balance_ms));
        let healths: Vec<ShardHealth> = feeds
            .iter()
            .enumerate()
            .map(|(i, path)| {
                std::fs::read_to_string(path)
                    .ok()
                    .and_then(|text| health_from_feed(i, &text).ok())
                    .unwrap_or(ShardHealth {
                        shard: i,
                        reachable: false,
                        streams: 0,
                        queue_depth: 0,
                        p99_us: 0,
                    })
            })
            .collect();
        if let Some(d) = controller.observe(&healths) {
            eprintln!(
                "front: rebalancing one session off shard {} onto {} (backlog {}, p99 {} us)",
                d.from, d.to, d.backlog, d.p99_us
            );
            handle.rebalance(d.from, d.to)?;
        }
    }
}

/// Options of the `wire-smoke` subcommand.
struct SmokeOpts {
    streams: usize,
    frames: usize,
    workers: usize,
    seed: u64,
    snapshot_ms: u64,
    /// Trace every nth forwarded frame (`--trace-sample-n`, 0 = off).
    trace_sample_n: u64,
    /// The front's own health-feed path (`--front-feed`; optional).
    front_feed: Option<String>,
    /// Per-shard NDJSON health-feed paths (`--feeds a,b`; optional).
    feeds: Vec<String>,
}

/// Collect `FrameOut`s for `sid` into `got` until it holds `upto`
/// frames; any fleet `Err`, early close, or decode fault fails.
fn collect_session_outputs(
    client: &mut WireClient,
    sid: u64,
    got: &mut Vec<Vec<f32>>,
    upto: usize,
) -> Result<()> {
    while got.len() < upto {
        match client.recv() {
            Ok(Some(Msg::FrameOut { session, samples, .. })) if session == sid => {
                got.push(samples);
            }
            Ok(Some(Msg::Err { code, detail, .. })) => {
                bail!("fleet error {}: {detail}", code.name());
            }
            Ok(Some(_)) => {}
            Ok(None) => bail!("fleet closed after {} of {upto} outputs", got.len()),
            Err(e) => bail!("recv: {e}"),
        }
    }
    Ok(())
}

/// End-to-end sharded-serving smoke (what CI runs): a front-end plus
/// two loopback shards serve deterministic synthetic streams, one
/// session warm-migrates across shards mid-stream, and every output
/// must be bit-identical to single-process serving.  Exits nonzero on
/// any mismatch, dropped frame, or missed migration (DESIGN.md §14).
fn wire_smoke(artifacts: &std::path::Path, spec: &str, opts: SmokeOpts) -> Result<()> {
    const N_SHARDS: usize = 2;
    let rt = Arc::new(Runtime::cpu()?);
    let cv = Arc::new(load_variant(rt, artifacts, spec)?);
    let feat = cv.manifest.config.feat;

    // Deterministic synthetic inputs, plus one extra stream that is
    // driven manually through a mid-stream migration.
    let mut rng = Rng::new(opts.seed);
    let mut inputs: Vec<Vec<Vec<f32>>> = Vec::with_capacity(opts.streams + 1);
    for _ in 0..opts.streams + 1 {
        let (noisy, _) = siggen::denoise_pair(&mut rng, feat * opts.frames, siggen::FS);
        let (cols, _) = frames(&noisy, feat);
        inputs.push(cols);
    }

    // Single-process reference: the exact outputs the fleet must match.
    let reference = {
        let server = Server::new(cv.clone(), opts.workers);
        let report = server.run(&inputs)?;
        let mut outs = Vec::with_capacity(inputs.len());
        for sid in 0..inputs.len() as u64 {
            outs.push(report.outputs.get(&sid).cloned().unwrap_or_default());
        }
        outs
    };

    // Two shards over loopback hubs, each with its own worker pool
    // (and, with --feeds, its own soi.obs.v1 exporter).
    let mut hubs = Vec::with_capacity(N_SHARDS);
    let mut shard_threads = Vec::with_capacity(N_SHARDS);
    let mut exporters = Vec::new();
    for i in 0..N_SHARDS {
        let hub = LoopbackHub::new();
        let mut server = Server::new(cv.clone(), opts.workers);
        if let Some(path) = opts.feeds.get(i) {
            let tel = Telemetry::new(ObsConfig::default());
            let feed = PathBuf::from(path);
            let exporter = Exporter::start(tel.clone(), &feed, opts.snapshot_ms)
                .with_context(|| format!("creating health feed {path}"))?;
            server.telemetry = Some(tel);
            exporters.push(exporter);
        }
        let shard_hub = hub.clone();
        let cfg = ShardConfig { shard_id: i as u64 + 1 };
        shard_threads.push(std::thread::spawn(move || run_shard(&server, &shard_hub, cfg)));
        hubs.push(hub);
    }

    let links: Vec<ShardLink> = hubs
        .iter()
        .enumerate()
        .map(|(i, hub)| ShardLink {
            name: format!("shard{i}"),
            transport: Box::new(hub.clone()),
        })
        .collect();
    let front_hub = LoopbackHub::new();
    let policy = FrontPolicy {
        max_sessions: opts.streams + 1,
        trace_sample_n: opts.trace_sample_n,
        ..FrontPolicy::default()
    };
    // With --front-feed the front exports its own soi.obs.v1 feed, so
    // the smoke exercises the whole cluster-observability path:
    // shard feeds + front feed -> `soi aggregate-feeds`.
    let mut front_tel = None;
    if let Some(path) = &opts.front_feed {
        let tel = Telemetry::new(ObsConfig::default());
        let exporter = Exporter::start(tel.clone(), &PathBuf::from(path), opts.snapshot_ms)
            .with_context(|| format!("creating health feed {path}"))?;
        front_tel = Some(tel);
        exporters.push(exporter);
    }
    let handle = spawn_front_with(Box::new(front_hub.clone()), links, policy, front_tel)?;

    let mut client = WireClient::connect(&front_hub)?;
    if client.feat() != feat {
        bail!("fleet serves feat {}, variant has {feat}", client.feat());
    }

    // Phase 1: the batch streams, spread across both shards.
    let batch = &inputs[..opts.streams];
    let served = client.serve_streams(batch)?;
    let mut mismatched = 0usize;
    for sid in 0..opts.streams {
        if served[sid] != reference[sid] {
            mismatched += 1;
            eprintln!("wire-smoke: session {sid} diverged from single-process serving");
        }
    }

    // Phase 2: one fresh session, warm-migrated mid-stream.  Waiting
    // for the first half's outputs first makes both nominations land
    // on a quiet session, so wherever the front homed it, nudging it
    // at both shards executes at least one real move; the outputs must
    // be unchanged by the move.
    let sid = opts.streams as u64;
    let mig = &inputs[opts.streams];
    let half = mig.len() / 2;
    for (i, samples) in mig.iter().take(half).enumerate() {
        let msg = Msg::Frame {
            session: sid,
            seq: i as u64,
            last: false,
            samples: samples.clone(),
            trace: None,
            deadline_us: None,
        };
        client.send(&msg).map_err(|e| anyhow!("send: {e}"))?;
    }
    let mut got: Vec<Vec<f32>> = Vec::with_capacity(mig.len());
    collect_session_outputs(&mut client, sid, &mut got, half)?;
    handle.migrate(sid, 0)?;
    handle.migrate(sid, 1)?;
    for (i, samples) in mig.iter().enumerate().skip(half) {
        let msg = Msg::Frame {
            session: sid,
            seq: i as u64,
            last: i + 1 == mig.len(),
            samples: samples.clone(),
            trace: None,
            deadline_us: None,
        };
        client.send(&msg).map_err(|e| anyhow!("send: {e}"))?;
    }
    collect_session_outputs(&mut client, sid, &mut got, mig.len())?;
    if got != reference[opts.streams] {
        mismatched += 1;
        eprintln!("wire-smoke: migrated session diverged from single-process serving");
    }

    client.shutdown();
    let front = handle.stop()?;
    let mut shard_frames_out = 0u64;
    let mut resumes = 0u64;
    for (i, t) in shard_threads.into_iter().enumerate() {
        let report = t.join().map_err(|_| anyhow!("shard {i} panicked"))??;
        shard_frames_out += report.frames_out;
        resumes += report.resumes;
    }
    for exporter in exporters {
        let path = exporter.path().display().to_string();
        let stats = exporter.finish().context("finishing a shard health feed")?;
        eprintln!("telemetry: {} snapshots, {} lines -> {path}", stats.snapshots, stats.lines);
    }
    println!(
        "wire-smoke: {} sessions x {} frames over {N_SHARDS} shards — {} shard frames out, \
         {} forwarded, {} migrations ({} shard resumes), {} wire errors",
        opts.streams + 1,
        opts.frames,
        shard_frames_out,
        front.frames_out,
        front.migrations,
        resumes,
        front.wire_errs
    );
    if mismatched > 0 {
        bail!("{mismatched} sessions diverged from single-process serving");
    }
    if front.migrations == 0 || resumes == 0 {
        bail!(
            "no warm migration happened (front {} migrations, shard resumes {resumes})",
            front.migrations
        );
    }
    let expected: usize = reference.iter().map(Vec::len).sum();
    if front.frames_out != expected as u64 {
        bail!("front forwarded {} of {expected} outputs — frames dropped", front.frames_out);
    }
    println!("wire-smoke: PASS — sharded serving is bit-identical to single-process serving");
    Ok(())
}

/// Options of the `chaos-smoke` subcommand.
struct ChaosSmokeOpts {
    streams: usize,
    frames: usize,
    workers: usize,
    seed: u64,
    /// Seed of the fault plan (`--chaos-seed`) — independent of the
    /// input seed so the same traffic can face different failures.
    chaos_seed: u64,
    /// Fault→heal episodes in the plan (`--events`).
    events: usize,
    /// Episode spread in ticks (`--span`).
    span: u64,
    /// Front heartbeat interval, ms (`--heartbeat-ms`).
    heartbeat_ms: u64,
    /// Silent ticks before suspect (`--miss-budget`).
    miss_budget: u32,
    /// Per-session recovery resend cap (`--retry-budget`).
    retry_budget: u64,
    /// The front's own health-feed path (`--front-feed`; optional).
    front_feed: Option<String>,
    snapshot_ms: u64,
}

/// Fleet-survival smoke (DESIGN.md §16, what CI runs): a front-end
/// plus three loopback shards behind deterministic chaos proxies
/// serve seeded streams while a seeded fault plan kills, stalls,
/// partitions and corrupts shard links.  Every stream must either
/// finish bit-identical to unfaulted single-process serving or end in
/// a typed `Overloaded`/`ShardLost` error — a wrong, duplicated or
/// reordered output, or a silently dropped accepted frame, exits
/// nonzero.
fn chaos_smoke(artifacts: &std::path::Path, spec: &str, opts: ChaosSmokeOpts) -> Result<()> {
    const N_SHARDS: usize = 3;
    let rt = Arc::new(Runtime::cpu()?);
    let cv = Arc::new(load_variant(rt, artifacts, spec)?);
    let feat = cv.manifest.config.feat;

    let mut rng = Rng::new(opts.seed);
    let mut inputs: Vec<Vec<Vec<f32>>> = Vec::with_capacity(opts.streams);
    for _ in 0..opts.streams {
        let (noisy, _) = siggen::denoise_pair(&mut rng, feat * opts.frames, siggen::FS);
        let (cols, _) = frames(&noisy, feat);
        inputs.push(cols);
    }

    // Unfaulted single-process reference: what every surviving stream
    // must match bit for bit.
    let reference = {
        let server = Server::new(cv.clone(), opts.workers);
        let report = server.run(&inputs)?;
        let mut outs = Vec::with_capacity(inputs.len());
        for sid in 0..inputs.len() as u64 {
            outs.push(report.outputs.get(&sid).cloned().unwrap_or_default());
        }
        outs
    };

    // Real shards over loopback hubs, each with its own worker pool.
    let mut shard_hubs = Vec::with_capacity(N_SHARDS);
    let mut shard_threads = Vec::with_capacity(N_SHARDS);
    for i in 0..N_SHARDS {
        let hub = LoopbackHub::new();
        let server = Server::new(cv.clone(), opts.workers);
        let shard_hub = hub.clone();
        let cfg = ShardConfig { shard_id: i as u64 + 1 };
        shard_threads.push(std::thread::spawn(move || run_shard(&server, &shard_hub, cfg)));
        shard_hubs.push(hub);
    }

    // Chaos proxies between the front and every shard, executing the
    // seeded plan on the fleet-global tick clock.
    let plan = ChaosPlan::seeded(opts.chaos_seed, N_SHARDS, opts.span, opts.events);
    println!(
        "chaos-smoke: plan seed {} — {} scheduled faults over {N_SHARDS} shards",
        opts.chaos_seed,
        plan.faults().len()
    );
    for f in plan.faults() {
        println!("  tick {:>5}  shard {}  {:?}", f.tick, f.shard, f.fault);
    }
    let backends: Vec<Arc<dyn Transport>> = shard_hubs
        .iter()
        .map(|h| Arc::new(h.clone()) as Arc<dyn Transport>)
        .collect();
    let (proxy_hubs, fleet) = ChaosFleet::wrap(backends, &plan);

    let links: Vec<ShardLink> = proxy_hubs
        .iter()
        .enumerate()
        .map(|(i, hub)| ShardLink {
            name: format!("shard{i}"),
            transport: Box::new(hub.clone()),
        })
        .collect();
    let front_hub = LoopbackHub::new();
    let policy = FrontPolicy {
        max_sessions: opts.streams,
        heartbeat_ms: opts.heartbeat_ms,
        miss_budget: opts.miss_budget,
        retry_budget: opts.retry_budget,
        ..FrontPolicy::default()
    };
    let mut front_tel = None;
    let mut exporters = Vec::new();
    if let Some(path) = &opts.front_feed {
        let tel = Telemetry::new(ObsConfig::default());
        let exporter = Exporter::start(tel.clone(), &PathBuf::from(path), opts.snapshot_ms)
            .with_context(|| format!("creating health feed {path}"))?;
        front_tel = Some(tel);
        exporters.push(exporter);
    }
    let handle = spawn_front_with(Box::new(front_hub.clone()), links, policy, front_tel)?;

    let mut client = WireClient::connect(&front_hub)?;
    if client.feat() != feat {
        bail!("fleet serves feat {}, variant has {feat}", client.feat());
    }

    // Drive every stream round-robin.  The front's reader drains the
    // client pipe continuously, so sending everything up front cannot
    // deadlock against the faults.
    let max_len = inputs.iter().map(Vec::len).max().unwrap_or(0);
    for t in 0..max_len {
        for (sid, stream) in inputs.iter().enumerate() {
            if t < stream.len() {
                let msg = Msg::Frame {
                    session: sid as u64,
                    seq: t as u64,
                    last: t + 1 == stream.len(),
                    samples: stream[t].clone(),
                    trace: None,
                    deadline_us: None,
                };
                client.send(&msg).map_err(|e| anyhow!("send: {e}"))?;
            }
        }
    }

    // Collect until every stream has either finished or been shed with
    // a typed error.  Sequence numbers are checked online, so a
    // duplicated, reordered or post-shed output fails immediately.
    let mut outs: Vec<Vec<Vec<f32>>> = vec![Vec::new(); inputs.len()];
    let mut lost: Vec<Option<String>> = vec![None; inputs.len()];
    let mut pending = inputs.len();
    while pending > 0 {
        match client.recv() {
            Ok(Some(Msg::FrameOut { session, seq, samples, .. })) => {
                let sid = session as usize;
                if sid >= outs.len() {
                    bail!("chaos-smoke: output for unknown session {session}");
                }
                if lost[sid].is_some() {
                    bail!("chaos-smoke: session {session} produced output after its typed error");
                }
                if seq != outs[sid].len() as u64 {
                    bail!(
                        "chaos-smoke: session {session} output seq {seq}, expected {} — \
                         duplicated or reordered frame",
                        outs[sid].len()
                    );
                }
                outs[sid].push(samples);
                if outs[sid].len() == reference[sid].len() {
                    pending -= 1;
                }
            }
            Ok(Some(Msg::Err { code, session, detail })) => {
                let sid = session as usize;
                if sid >= lost.len() {
                    bail!(
                        "chaos-smoke: stray {} error for session {session}: {detail}",
                        code.name()
                    );
                }
                if lost[sid].is_some() {
                    // Frames already in flight when the session was
                    // shed echo back as typed BadFrame refusals —
                    // answered, not dropped.  Anything else is stray.
                    if !matches!(code, ErrCode::BadFrame) {
                        bail!(
                            "chaos-smoke: stray {} error for shed session {session}: {detail}",
                            code.name()
                        );
                    }
                    continue;
                }
                if outs[sid].len() == reference[sid].len() {
                    bail!("chaos-smoke: session {session} errored after completing: {detail}");
                }
                if !matches!(code, ErrCode::Overloaded | ErrCode::ShardLost) {
                    bail!(
                        "chaos-smoke: unexpected {} error for session {session}: {detail}",
                        code.name()
                    );
                }
                lost[sid] = Some(format!("{}: {detail}", code.name()));
                pending -= 1;
            }
            Ok(Some(_)) => {}
            Ok(None) => bail!("chaos-smoke: fleet closed with {pending} streams outstanding"),
            Err(e) => bail!("recv: {e}"),
        }
    }

    // Quiesce: heal every switch so the shutdown drain reaches the
    // shards, then stop the front and unblock any shard still in
    // accept by closing its hub.
    for i in 0..N_SHARDS {
        fleet.switch(i).apply(Fault::Heal);
    }
    client.shutdown();
    let front = handle.stop()?;
    fleet.close();
    for hub in &shard_hubs {
        hub.close();
    }
    let mut resumes = 0u64;
    for (i, t) in shard_threads.into_iter().enumerate() {
        let report = t.join().map_err(|_| anyhow!("shard {i} panicked"))??;
        resumes += report.resumes;
    }
    for exporter in exporters {
        let path = exporter.path().display().to_string();
        let stats = exporter.finish().context("finishing the front health feed")?;
        eprintln!("telemetry: {} snapshots, {} lines -> {path}", stats.snapshots, stats.lines);
    }

    let mut survivors = 0usize;
    let mut mismatched = 0usize;
    for sid in 0..inputs.len() {
        match &lost[sid] {
            Some(why) => eprintln!("chaos-smoke: session {sid} shed ({why})"),
            None => {
                survivors += 1;
                if outs[sid] != reference[sid] {
                    mismatched += 1;
                    eprintln!("chaos-smoke: session {sid} diverged from unfaulted serving");
                }
            }
        }
    }
    for (i, rep) in fleet.reports().iter().enumerate() {
        println!(
            "chaos-smoke: shard {i} — {} ticks, {} dropped, {} injected, {} bridges",
            rep.ticks, rep.dropped, rep.injected, rep.bridges
        );
    }
    println!(
        "chaos-smoke: {} survivors / {} shed of {} streams — front: {} misses, \
         {} suspects, {} rejoins, {} retried frames, {} shed, {} migrations, {} wire errors",
        survivors,
        inputs.len() - survivors,
        inputs.len(),
        front.heartbeat_misses,
        front.shard_suspects,
        front.shard_rejoins,
        front.frames_retried,
        front.shed,
        front.migrations,
        front.wire_errs
    );
    if resumes > 0 {
        println!("chaos-smoke: {resumes} warm shard resumes replayed session history");
    }
    if mismatched > 0 {
        bail!("{mismatched} surviving streams diverged from unfaulted serving");
    }
    if survivors == 0 {
        bail!("every stream was shed — nothing survived to verify");
    }
    if front.shed != (inputs.len() - survivors) as u64 {
        bail!(
            "front shed accounting ({}) disagrees with client-observed shed streams ({})",
            front.shed,
            inputs.len() - survivors
        );
    }
    println!(
        "chaos-smoke: PASS — every surviving stream bit-identical under the fault plan, \
         every shed stream typed"
    );
    Ok(())
}

const HELP: &str = "soi — Scattered Online Inference coordinator
usage: soi <command> [options]
  list                          list built artifact variants
  info <variant>                manifest summary
  exp <table1..table10|fig4..fig11|all>   regenerate paper tables/figures
  serve <variant> [--streams N] [--frames N] [--workers N] [--no-idle-precompute]
                  [--no-batching] [--pace-us N] [--dtype f32|int8]
                  pinned int8 serving additionally reports output SNR vs
                  the f32 reference (snr_db in the JSON summary)
  serve --adaptive [--ladder v0,v1,..] [--target-p99-us N] [--pace-us N]
                  load-adaptive ladder serving (default ladder
                  stmc,scc2,sscc5); emits a JSON summary line with
                  migration and per-variant frame counts.  Ladder entries
                  accept :f32/:int8 suffixes (mixed-precision ladders:
                  --ladder stmc,stmc:int8,scc2:int8), and --dtype sets the
                  default suffix for entries without one
  serve ... --telemetry[=PATH] [--snapshot-ms N]
                  stream a live soi.obs.v1 NDJSON health feed while
                  serving (default PATH soi-feed.ndjson, snapshot every
                  200 ms): per-(rung x phase) latency histograms, FP
                  pre/rest spans, migration + controller-decision events,
                  arena_peak_bytes (DESIGN.md s12 + appendix A)
  serve ... --artifact-dir DIR [--watch-generations] [--watch-ms N] [--idle-poll-ms N]
                  serve rungs compiled over the newest soi.artifact.v1
                  generation under DIR (pinned: the positional spec;
                  adaptive: every --ladder entry).  With
                  --watch-generations, newer generations hot-reload
                  mid-run at phase-0 boundaries — zero dropped streams,
                  and a corrupt candidate is rejected while the old
                  generation keeps serving (DESIGN.md s13); the JSON
                  summary reports the final `generation`
  validate-feed <path>
                  schema-check a health feed (every record, event payloads
                  by kind, snapshot seq monotonicity) — what CI runs.
                  Detects the schema from the first line, so it accepts
                  both per-process soi.obs.v1 feeds and aggregated
                  soi.cluster.v1 feeds
  aggregate-feeds --feeds P1,P2[,..] [--out PATH]
                  losslessly merge per-process soi.obs.v1 feeds (shards
                  and front) into one versioned soi.cluster.v1 summary:
                  cluster + per-shard counters, bucket-exact merged
                  latency histograms, wire byte/msg rates, migration and
                  reload totals, drop accounting, and every trace span
                  re-tagged with its shard (DESIGN.md s15); NDJSON to
                  stdout or --out
  top --feeds P1,P2[,..] [--interval-ms N] [--iterations N]
                  live cluster console: re-aggregates the feeds every
                  interval (default 1000 ms) and redraws a per-shard
                  table, cluster p50/p99 per (rung x phase), and the
                  latest traced frame's hop chain; --iterations N exits
                  after N frames (0 = run until interrupted)
  export-artifact <spec> [--out DIR] [--generation N] [--seed S]
                  save <spec>'s weights as a versioned soi.artifact.v1
                  directory: artifact.json (per-tensor sha-256 digests)
                  + raw little-endian f32 weights.bin; default out
                  artifacts/<spec>-gen<NNNNNN>
  inspect-artifact <dir>
                  load through the verifying reader (every digest
                  checked) and print a summary; exits nonzero with a
                  typed error on any corruption — what CI runs
  serve-shard <variant> [--listen HOST:PORT] [--workers N] [--shard-id N]
                  [--telemetry[=PATH]] [--snapshot-ms N] [--dtype f32|int8]
                  [--idle-poll-ms N]
                  run one backend shard over TCP (soi.wire.v1, DESIGN.md
                  s14): a coordinator worker pool behind a wire endpoint
                  with s9 warm resume of migrated sessions; a whole-shard
                  Drain from the front stops it gracefully.  --idle-poll-ms
                  bounds how long an idle worker waits before re-checking
                  for a hot-reload publish (default 2)
  serve-front --shards HOST:PORT[,HOST:PORT..] [--listen HOST:PORT]
                  [--max-sessions N] [--feeds P1,P2..] [--balance-ms N]
                  [--telemetry[=PATH]] [--snapshot-ms N] [--trace-sample-n N]
                  [--heartbeat-ms N] [--miss-budget N] [--retry-budget N]
                  [--min-live-shards N]
                  run the front-end: admission control, session->shard
                  affinity, zero-drop warm cross-shard migration, and
                  shard-loss recovery by s9 replay.  With --feeds, polls
                  each shard's soi.obs.v1 health feed and rebalances
                  sessions off hot shards (cluster controller).  With
                  --telemetry the front exports its own soi.obs.v1 feed
                  (default PATH soi-front-feed.ndjson); --trace-sample-n N
                  traces every Nth forwarded frame end to end across the
                  fleet (DESIGN.md s15, default 0 = off).  --heartbeat-ms N
                  probes every shard with Ping each N ms (default 0 = off);
                  after --miss-budget silent ticks (default 3) a stalled
                  shard is declared suspect and its sessions migrate off,
                  and a lost shard rejoins automatically when it returns.
                  --retry-budget caps recovery resends per session
                  (default 1024) and --min-live-shards (default 1) sheds
                  new admissions with a typed Overloaded while the fleet
                  is degraded (DESIGN.md s16)
  wire-smoke [variant] [--streams N] [--frames N] [--workers N] [--seed S]
                  [--feeds P1,P2] [--front-feed P] [--snapshot-ms N]
                  [--trace-sample-n N]
                  in-process scale-out smoke (what CI runs): front + 2
                  loopback shards serve deterministic streams, one session
                  warm-migrates mid-stream, and every output must be
                  bit-identical to single-process serving; exits nonzero
                  on any mismatch, dropped frame, or missed migration.
                  --front-feed exports the front's own feed and
                  --trace-sample-n N samples cross-shard traces, so the
                  three feeds exercise `soi aggregate-feeds`
  chaos-smoke [variant] [--streams N] [--frames N] [--workers N] [--seed S]
                  [--chaos-seed S] [--events N] [--span N] [--heartbeat-ms N]
                  [--miss-budget N] [--retry-budget N] [--front-feed P]
                  [--snapshot-ms N]
                  fleet-survival smoke (DESIGN.md s16, what CI runs):
                  front + 3 loopback shards behind deterministic chaos
                  proxies; a seeded plan kills, stalls, partitions and
                  corrupts shard links on frame-count ticks while seeded
                  streams are served.  Every stream must finish
                  bit-identical to unfaulted single-process serving or
                  end in a typed Overloaded/ShardLost error; a wrong,
                  duplicated or silently dropped frame exits nonzero.
                  --chaos-seed picks the fault plan (default 7, --events
                  episodes spread over --span ticks each)
  denoise <variant> [--frames N] [--dtype f32|int8]
options: --artifacts DIR  --results DIR  --n-eval N  --seed S
serve/denoise accept preset specs (stmc, scc<p>, scc<p>_<q>, sscc<p>,
fp<p>_<q>, pred<n>, each optionally :f32|:int8) even without built
artifacts: the native backend then runs a synthesized untrained variant
(set SOI_BACKEND=pjrt with --features pjrt for the HLO/PJRT engine on
real f32 artifacts; int8 execution is native-only, DESIGN.md §10).";
