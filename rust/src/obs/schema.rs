//! Health-feed schema validation (DESIGN.md appendix A).
//!
//! One validator shared by the CLI (`soi validate-feed`), the
//! integration tests, and CI — so the documented schema is enforced by
//! the same code everywhere and CI needs no external `jq`.  Validation
//! is structural: required fields present with the right JSON types,
//! event payloads matching their `kind`, per-type `seq` monotonicity.
//!
//! Two schemas share this module: the per-process `soi.obs.v1` feed
//! ([`validate_feed`]) and the aggregated `soi.cluster.v1` summary
//! ([`validate_cluster_feed`], DESIGN.md §15).  [`detect_schema`]
//! sniffs which one a file is so the CLI needs no flag.

use crate::util::json::{parse, Json};

use super::aggregate::CLUSTER_SCHEMA;
use super::export::FEED_SCHEMA;
use super::registry::{Counter, Gauge};
use super::trace::SpanKind;

/// What one valid feed line turned out to be.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LineKind {
    /// A `snapshot` record (counters + gauges).
    Snapshot,
    /// A `hist` record (one latency/width histogram).
    Hist,
    /// An `event` record (one drained trace event).
    Event,
}

/// Totals from a validated feed.
#[derive(Debug, Clone, Copy, Default)]
pub struct FeedSummary {
    /// Total NDJSON lines.
    pub lines: u64,
    /// `snapshot` records.
    pub snapshots: u64,
    /// `hist` records.
    pub hists: u64,
    /// `event` records.
    pub events: u64,
}

fn want_u64(v: &Json, key: &str) -> Result<u64, String> {
    let n = v
        .get(key)
        .ok_or_else(|| format!("missing field '{key}'"))?
        .as_f64()
        .ok_or_else(|| format!("field '{key}' is not a number"))?;
    if n < 0.0 {
        return Err(format!("field '{key}' is negative"));
    }
    Ok(n as u64)
}

fn want_str<'a>(v: &'a Json, key: &str) -> Result<&'a str, String> {
    v.get(key)
        .ok_or_else(|| format!("missing field '{key}'"))?
        .as_str()
        .ok_or_else(|| format!("field '{key}' is not a string"))
}

fn want_counters(v: &Json, key: &str, names: &[&str]) -> Result<(), String> {
    let obj = v
        .get(key)
        .ok_or_else(|| format!("missing field '{key}'"))?;
    for name in names {
        if obj.get(name).and_then(|n| n.as_f64()).is_none() {
            return Err(format!("'{key}' missing numeric field '{name}'"));
        }
    }
    Ok(())
}

/// Shared span-record body check: `trace_id`, a known `span` name, a
/// null-or-known `parent`, and the span kind's payload fields.  Used
/// by both the `soi.obs.v1` event path and `soi.cluster.v1` span
/// records (which carry the same fields plus shard attribution).
fn validate_span_fields(v: &Json) -> Result<(), String> {
    want_u64(v, "trace_id")?;
    let span = want_str(v, "span")?;
    let Some(kind) = SpanKind::from_name(span) else {
        return Err(format!("unknown span kind '{span}'"));
    };
    let parent = v.get("parent").ok_or("missing field 'parent'")?;
    if !parent.is_null() {
        let p = parent
            .as_str()
            .ok_or("field 'parent' is neither null nor a string")?;
        if SpanKind::from_name(p).is_none() {
            return Err(format!("unknown span parent '{p}'"));
        }
    }
    let fields: &[&str] = match kind {
        SpanKind::FrontAdmit => &["session", "frame_seq", "shard"],
        SpanKind::ShardDispatch | SpanKind::FrontReply => &["session", "frame_seq"],
        SpanKind::WorkerRound => &["session", "width", "ns"],
        SpanKind::PhaseExec => &["rung", "phase", "width", "ns"],
        SpanKind::MigrateFront => &["session", "from_shard", "to_shard"],
        SpanKind::MigrateReplay => &["stream", "t", "ns"],
        SpanKind::FrontRetry => &["session", "resent", "shard"],
        SpanKind::ShardRejoin => &["shard", "attempts"],
    };
    for f in fields {
        want_u64(v, f)?;
    }
    Ok(())
}

fn validate_event(v: &Json) -> Result<(), String> {
    // worker may be null (the shared/global-hook handle)
    let w = v.get("worker").ok_or("missing field 'worker'")?;
    if !w.is_null() && w.as_f64().is_none() {
        return Err("field 'worker' is neither null nor a number".into());
    }
    want_u64(v, "t_us")?;
    let kind = want_str(v, "kind")?;
    let fields: &[&str] = match kind {
        "round" => &["served", "backlog", "streams", "ns"],
        "exec" => &["rung", "phase", "width", "ns"],
        "fp_pre" => &["stream", "phase", "ns"], // + bool 'inline'
        "fp_rest" => &["phase", "width", "ns"],
        "migration" => &["stream", "from_rung", "to_rung", "replay_frames", "ns"],
        "quant_repack" => &["panels", "bytes", "ns"],
        "ctl_decision" => &["from_rung", "to_rung", "backlog", "p99_us"], // + str 'trigger'
        "gen_reload" => &["from_gen", "to_gen", "streams", "ns"],
        "shard_migrate" => &["session", "t", "replay_frames", "ns"],
        "span" => return validate_span_fields(v),
        other => return Err(format!("unknown event kind '{other}'")),
    };
    for f in fields {
        want_u64(v, f)?;
    }
    if kind == "fp_pre" && v.get("inline").and_then(|b| b.as_bool()).is_none() {
        return Err("fp_pre event missing bool field 'inline'".into());
    }
    if kind == "ctl_decision" {
        let t = want_str(v, "trigger")?;
        if !matches!(t, "queue" | "latency" | "calm") {
            return Err(format!("unknown ctl_decision trigger '{t}'"));
        }
    }
    Ok(())
}

fn validate_hist(v: &Json) -> Result<(), String> {
    want_str(v, "name")?;
    // rung/phase are numbers or null (null for un-keyed hists)
    for key in ["rung", "phase"] {
        let f = v.get(key).ok_or_else(|| format!("missing field '{key}'"))?;
        if !f.is_null() && f.as_f64().is_none() {
            return Err(format!("field '{key}' is neither null nor a number"));
        }
    }
    let count = want_u64(v, "count")?;
    for key in ["p50", "p95", "p99", "mean"] {
        if v.get(key).and_then(|n| n.as_f64()).is_none() {
            return Err(format!("missing numeric field '{key}'"));
        }
    }
    let buckets = v
        .get("buckets")
        .and_then(|b| b.as_arr())
        .ok_or("missing array field 'buckets'")?;
    let mut total = 0u64;
    for b in buckets {
        let pair = b.as_arr().ok_or("bucket is not a [index, count] pair")?;
        if pair.len() != 2 || pair[0].as_f64().is_none() || pair[1].as_f64().is_none() {
            return Err("bucket is not a numeric [index, count] pair".into());
        }
        total += pair[1].as_f64().unwrap_or(0.0) as u64;
    }
    if total != count {
        return Err(format!(
            "bucket counts sum to {total} but 'count' says {count}"
        ));
    }
    Ok(())
}

/// Validate one feed line; returns its record type or a description of
/// the first violation.
pub fn validate_line(line: &str) -> Result<LineKind, String> {
    let v = parse(line).map_err(|e| format!("not valid JSON: {e}"))?;
    let schema = want_str(&v, "schema")?;
    if schema != FEED_SCHEMA {
        return Err(format!(
            "schema '{schema}' is not the expected '{FEED_SCHEMA}'"
        ));
    }
    want_u64(&v, "seq")?;
    match want_str(&v, "type")? {
        "snapshot" => {
            want_u64(&v, "t_ms")?;
            let counter_names: Vec<&str> = Counter::ALL.iter().map(|c| c.name()).collect();
            want_counters(&v, "counters", &counter_names)?;
            let gauge_names: Vec<&str> = Gauge::ALL.iter().map(|g| g.name()).collect();
            want_counters(&v, "gauges", &gauge_names)?;
            want_u64(&v, "ring_dropped")?;
            want_u64(&v, "feed_drops")?;
            Ok(LineKind::Snapshot)
        }
        "hist" => {
            want_u64(&v, "t_ms")?;
            validate_hist(&v)?;
            Ok(LineKind::Hist)
        }
        "event" => {
            validate_event(&v)?;
            Ok(LineKind::Event)
        }
        other => Err(format!("unknown record type '{other}'")),
    }
}

/// Validate a whole feed: every line individually, at least one
/// snapshot, and strictly increasing `seq` across snapshot records.
/// Returns per-type totals; the error message names the offending line.
pub fn validate_feed(text: &str) -> Result<FeedSummary, String> {
    let mut summary = FeedSummary::default();
    let mut last_snapshot_seq: Option<u64> = None;
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let kind = validate_line(line).map_err(|e| format!("line {}: {e}", i + 1))?;
        summary.lines += 1;
        match kind {
            LineKind::Snapshot => {
                let v = parse(line).map_err(|e| format!("line {}: {e}", i + 1))?;
                let seq = want_u64(&v, "seq").map_err(|e| format!("line {}: {e}", i + 1))?;
                if let Some(prev) = last_snapshot_seq {
                    if seq <= prev {
                        return Err(format!(
                            "line {}: snapshot seq {seq} does not increase past {prev}",
                            i + 1
                        ));
                    }
                }
                last_snapshot_seq = Some(seq);
                summary.snapshots += 1;
            }
            LineKind::Hist => summary.hists += 1,
            LineKind::Event => summary.events += 1,
        }
    }
    if summary.snapshots == 0 {
        return Err("feed contains no snapshot record".into());
    }
    Ok(summary)
}

/// Totals from a validated `soi.cluster.v1` feed.
#[derive(Debug, Clone, Copy, Default)]
pub struct ClusterFeedSummary {
    /// Total NDJSON lines.
    pub lines: u64,
    /// `cluster` head records.
    pub clusters: u64,
    /// `shard` records.
    pub shards: u64,
    /// `hist` records.
    pub hists: u64,
    /// `span` records.
    pub spans: u64,
}

fn validate_registry_objects(v: &Json) -> Result<(), String> {
    let counter_names: Vec<&str> = Counter::ALL.iter().map(|c| c.name()).collect();
    want_counters(v, "counters", &counter_names)?;
    let gauge_names: Vec<&str> = Gauge::ALL.iter().map(|g| g.name()).collect();
    want_counters(v, "gauges", &gauge_names)
}

/// Validate one `soi.cluster.v1` line (DESIGN.md appendix A).
pub fn validate_cluster_line(line: &str) -> Result<&'static str, String> {
    let v = parse(line).map_err(|e| format!("not valid JSON: {e}"))?;
    let schema = want_str(&v, "schema")?;
    if schema != CLUSTER_SCHEMA {
        return Err(format!(
            "schema '{schema}' is not the expected '{CLUSTER_SCHEMA}'"
        ));
    }
    match want_str(&v, "type")? {
        "cluster" => {
            want_u64(&v, "shards")?;
            want_u64(&v, "t_ms")?;
            validate_registry_objects(&v)?;
            let wire = v.get("wire").ok_or("missing object field 'wire'")?;
            for f in [
                "rx_msgs_per_s",
                "tx_msgs_per_s",
                "rx_bytes_per_s",
                "tx_bytes_per_s",
            ] {
                if wire.get(f).and_then(|n| n.as_f64()).is_none() {
                    return Err(format!("'wire' missing numeric field '{f}'"));
                }
            }
            want_u64(&v, "migrations")?;
            want_u64(&v, "reloads")?;
            let dropped = v.get("dropped").ok_or("missing object field 'dropped'")?;
            for f in ["snapshots", "events", "feed_drops"] {
                if dropped.get(f).and_then(|n| n.as_f64()).is_none() {
                    return Err(format!("'dropped' missing numeric field '{f}'"));
                }
            }
            want_u64(&v, "spans")?;
            Ok("cluster")
        }
        "shard" => {
            want_str(&v, "shard")?;
            want_u64(&v, "snapshot_seq")?;
            want_u64(&v, "t_ms")?;
            validate_registry_objects(&v)?;
            want_u64(&v, "feed_drops")?;
            want_u64(&v, "spans")?;
            Ok("shard")
        }
        "hist" => {
            want_str(&v, "scope")?;
            validate_hist(&v)?;
            Ok("hist")
        }
        "span" => {
            want_str(&v, "shard")?;
            want_u64(&v, "t_us")?;
            validate_span_fields(&v)?;
            Ok("span")
        }
        other => Err(format!("unknown cluster record type '{other}'")),
    }
}

/// Validate a whole aggregated feed: every line, and at least one
/// `cluster` head record.
pub fn validate_cluster_feed(text: &str) -> Result<ClusterFeedSummary, String> {
    let mut summary = ClusterFeedSummary::default();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let ty = validate_cluster_line(line).map_err(|e| format!("line {}: {e}", i + 1))?;
        summary.lines += 1;
        match ty {
            "cluster" => summary.clusters += 1,
            "shard" => summary.shards += 1,
            "hist" => summary.hists += 1,
            _ => summary.spans += 1,
        }
    }
    if summary.clusters == 0 {
        return Err("feed contains no cluster record".into());
    }
    Ok(summary)
}

/// Sniff which schema a feed file speaks from its first parseable
/// line (`soi.obs.v1` or `soi.cluster.v1`); `None` when neither.
pub fn detect_schema(text: &str) -> Option<&'static str> {
    for line in text.lines() {
        let Ok(v) = parse(line.trim()) else { continue };
        return match v.get("schema").and_then(|s| s.as_str()) {
            Some(s) if s == FEED_SCHEMA => Some(FEED_SCHEMA),
            Some(s) if s == CLUSTER_SCHEMA => Some(CLUSTER_SCHEMA),
            _ => None,
        };
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{take_snapshot, ObsConfig, Telemetry};

    #[test]
    fn real_renderer_output_validates() {
        let tel = Telemetry::new(ObsConfig {
            ring_capacity: 64,
        });
        let h = tel.worker(0);
        h.exec(0, 1, 3, 1500);
        h.fp_pre(1, 2, false, 900);
        h.fp_rest(2, 3, 1100);
        h.migration(1, 0, 1, 8, 5000);
        h.quant_repack(4, 1 << 20, 80_000);
        h.gen_reload(1, 2, 5, 40_000);
        h.with(|w| {
            w.push_event(crate::obs::EventKind::Round, 3, 0, 3, 20_000, 0);
            w.push_event(crate::obs::EventKind::CtlDecision, 0, 1, 0, 12, 800);
        });
        let mut out = String::new();
        take_snapshot(&tel).render_ndjson(0, 0, &mut out);
        let mut out2 = String::new();
        take_snapshot(&tel).render_ndjson(1, 0, &mut out2);
        out.push_str(&out2);
        let summary = validate_feed(&out).expect("rendered feed validates");
        assert_eq!(summary.snapshots, 2);
        assert!(summary.hists >= 2); // exec_ns + batch_width
        assert_eq!(summary.events, 8);
    }

    #[test]
    fn violations_are_caught() {
        assert!(validate_line("not json").is_err());
        assert!(validate_line("{\"schema\":\"bogus.v9\",\"seq\":0,\"type\":\"snapshot\"}")
            .unwrap_err()
            .contains("bogus.v9"));
        assert!(validate_line(&format!(
            "{{\"schema\":\"{FEED_SCHEMA}\",\"seq\":0,\"type\":\"event\",\"worker\":0,\"t_us\":1,\"kind\":\"exec\",\"rung\":0}}"
        ))
        .unwrap_err()
        .contains("phase"));
        // non-increasing snapshot seq
        let tel = Telemetry::new(ObsConfig::default());
        let mut a = String::new();
        take_snapshot(&tel).render_ndjson(5, 0, &mut a);
        let mut b = String::new();
        take_snapshot(&tel).render_ndjson(5, 0, &mut b);
        a.push_str(&b);
        assert!(validate_feed(&a).unwrap_err().contains("seq"));
        // empty feed has no snapshot
        assert!(validate_feed("").unwrap_err().contains("no snapshot"));
    }

    #[test]
    fn span_events_validate_per_kind() {
        use crate::obs::SpanKind;
        let tel = Telemetry::new(ObsConfig::default());
        let h = tel.worker(0);
        h.span(3, SpanKind::FrontAdmit, 0, 1, 0, 0);
        h.span(3, SpanKind::PhaseExec, SpanKind::WorkerRound as u8, 5 << 16, 2, 700);
        let mut out = String::new();
        take_snapshot(&tel).render_ndjson(0, 0, &mut out);
        let summary = validate_feed(&out).expect("span events validate");
        assert_eq!(summary.events, 2);
        // a span with a bogus kind name is rejected
        let bad = format!(
            "{{\"schema\":\"{FEED_SCHEMA}\",\"seq\":0,\"type\":\"event\",\"worker\":0,\"t_us\":1,\"kind\":\"span\",\"trace_id\":1,\"span\":\"teleport\",\"parent\":null}}"
        );
        assert!(validate_line(&bad).unwrap_err().contains("teleport"));
        // a phase_exec span missing its 'ns' payload is rejected
        let short = format!(
            "{{\"schema\":\"{FEED_SCHEMA}\",\"seq\":0,\"type\":\"event\",\"worker\":0,\"t_us\":1,\"kind\":\"span\",\"trace_id\":1,\"span\":\"phase_exec\",\"parent\":\"worker_round\",\"rung\":0,\"phase\":0,\"width\":1}}"
        );
        assert!(validate_line(&short).unwrap_err().contains("ns"));
    }

    #[test]
    fn aggregated_cluster_feed_validates_and_is_detected() {
        use crate::obs::{aggregate, SpanKind};
        let tel = Telemetry::new(ObsConfig::default());
        let h = tel.worker(0);
        h.exec(0, 1, 2, 9_000);
        h.span(1, SpanKind::MigrateReplay, SpanKind::MigrateFront as u8, 4, 7, 300);
        let mut feed = String::new();
        take_snapshot(&tel).render_ndjson(0, 0, &mut feed);
        assert_eq!(detect_schema(&feed), Some(FEED_SCHEMA));
        let cluster = aggregate(&[("s0".to_string(), feed)]).unwrap();
        let mut out = String::new();
        cluster.render_ndjson(&mut out);
        assert_eq!(detect_schema(&out), Some(CLUSTER_SCHEMA));
        let summary = validate_cluster_feed(&out).expect("cluster feed validates");
        assert_eq!(summary.clusters, 1);
        assert_eq!(summary.shards, 1);
        assert_eq!(summary.spans, 1);
        assert!(summary.hists >= 2, "cluster + shard scope");
        // a cluster feed is not a valid obs feed and vice versa
        assert!(validate_feed(&out).is_err());
        assert!(validate_cluster_feed("").unwrap_err().contains("no cluster"));
        assert_eq!(detect_schema("{\"schema\":\"x.v0\"}"), None);
    }
}
