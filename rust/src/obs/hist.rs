//! Rolling latency window over the shared log-linear histogram
//! (DESIGN.md §12).
//!
//! [`RollingHist`] replaces the controller's old sort-per-round sample
//! ring with two epoch [`Histogram`]s rotated by sample count: samples
//! land in the *active* epoch, quantiles read the merge of both, and
//! when the active epoch fills it becomes the passive one and the stale
//! passive epoch is cleared in place.  Quantiles therefore cover between
//! `window/2 + 1` and `window` of the most recent samples — the same
//! freshness contract as a true ring at a fraction of the cost (no
//! clone, no sort, no allocation after construction), and in the same
//! mergeable bucket space the health feed exports.

use crate::util::stats::Histogram;

/// A sample-count-rotated pair of epoch histograms approximating a
/// sliding window of the most recent `window` samples.
#[derive(Debug, Clone)]
pub struct RollingHist {
    epochs: [Histogram; 2],
    active: usize,
    epoch_cap: u64,
    in_active: u64,
}

impl RollingHist {
    /// A rolling window covering (window/2, window] recent samples.
    /// `window` is clamped to at least 2 (one sample per epoch).
    pub fn new(window: usize) -> RollingHist {
        RollingHist {
            epochs: [Histogram::new(), Histogram::new()],
            active: 0,
            epoch_cap: ((window as u64) / 2).max(1),
            in_active: 0,
        }
    }

    /// Record one sample, rotating epochs when the active one is full.
    /// Allocation-free after construction.
    pub fn record(&mut self, v: u64) {
        if self.in_active >= self.epoch_cap {
            self.active ^= 1;
            self.epochs[self.active].clear();
            self.in_active = 0;
        }
        self.epochs[self.active].record(v);
        self.in_active += 1;
    }

    /// Samples currently covered (both epochs).
    pub fn count(&self) -> u64 {
        self.epochs[0].count() + self.epochs[1].count()
    }

    /// Value at quantile `q` over both epochs, without materializing the
    /// merge (0 while empty).  Same bucket resolution as
    /// [`Histogram::quantile`]: <1% relative error.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0)) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for idx in 0..Histogram::BUCKETS {
            seen += self.epochs[0].count_at(idx) + self.epochs[1].count_at(idx);
            if seen >= target {
                return Histogram::bucket_bound(idx);
            }
        }
        Histogram::bucket_bound(Histogram::BUCKETS - 1)
    }

    /// 99th-percentile sample value over the window.
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Materialize the window as one mergeable [`Histogram`] (export
    /// path only — this clones; the hot path never calls it).
    pub fn merged(&self) -> Histogram {
        let mut h = self.epochs[0].clone();
        h.merge(&self.epochs[1]);
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantile_matches_single_histogram_before_rotation() {
        let mut r = RollingHist::new(1000);
        let mut h = Histogram::new();
        for v in 1..=400u64 {
            r.record(v * 1000);
            h.record(v * 1000);
        }
        assert_eq!(r.count(), 400);
        for q in [0.5, 0.95, 0.99] {
            assert_eq!(r.quantile(q), h.quantile(q));
        }
    }

    #[test]
    fn rotation_forgets_stale_samples() {
        // window 8 => epochs of 4; after 12 cheap samples the expensive
        // prefix has been fully rotated out.
        let mut r = RollingHist::new(8);
        for _ in 0..8 {
            r.record(4_000_000);
        }
        assert!(r.p99() >= 3_900_000);
        for _ in 0..12 {
            r.record(500_000);
        }
        let p99 = r.p99();
        assert!(
            (450_000..=550_000).contains(&p99),
            "stale spike still visible: p99={p99}"
        );
    }

    #[test]
    fn window_coverage_stays_in_contract() {
        let mut r = RollingHist::new(8);
        for i in 0..100 {
            r.record(i);
            assert!(r.count() <= 8, "more than `window` samples covered");
            if i >= 8 {
                assert!(r.count() > 4, "fewer than window/2+1 samples covered");
            }
        }
    }

    #[test]
    fn empty_and_merged() {
        let r = RollingHist::new(4);
        assert_eq!(r.quantile(0.99), 0);
        assert_eq!(r.count(), 0);
        let mut r = RollingHist::new(4);
        r.record(100);
        r.record(200);
        let m = r.merged();
        assert_eq!(m.count(), 2);
    }
}
