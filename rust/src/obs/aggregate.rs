//! Lossless feed aggregation: many per-process `soi.obs.v1` health
//! feeds → one versioned `soi.cluster.v1` cluster summary
//! (DESIGN.md §15; record schema in DESIGN.md appendix A).
//!
//! Each shard process (and the front-end) exports its own NDJSON feed.
//! [`aggregate`] parses every feed with the same tolerant discipline as
//! [`crate::net::balance::health_from_feed`] — skip lines that fail to
//! parse (a live feed's last line may be mid-write), take counters and
//! gauges from the **latest-seq snapshot** (they are cumulative), and
//! re-ingest the latest-seq `exec_ns` histogram lines bucket by bucket
//! ([`Histogram::add_bucket`]).  Because the feed exports the
//! histogram's own log-linear buckets, the cluster-wide merge is
//! **bucket-exact**: merging shard A's and shard B's exported buckets
//! yields the identical histogram to merging their in-process
//! registries.  Nothing is sampled away and nothing re-binned.
//!
//! Span events ([`crate::obs::ring::EventKind::Span`]) are collected
//! from *every* drain interval (events are incremental, one snapshot
//! each) and re-tagged with the shard they came from, so a sampled
//! frame's causally-linked span tree — opened at the front-end,
//! continued on whichever shard served it — reassembles from the
//! merged feed by `trace_id` alone ([`ClusterSummary::trace_spans`]).
//!
//! The summary renders back to NDJSON under the `soi.cluster.v1`
//! schema (one `cluster` head record, one `shard` record per feed,
//! `hist` records at cluster and per-shard scope, one `span` record
//! per collected span) and to a terminal dashboard for `soi top`
//! ([`ClusterSummary::render_top`]).

use super::registry::{Counter, Gauge};
use super::trace::SpanKind;
use crate::util::json::{self, Json};
use crate::util::stats::Histogram;

/// Schema tag stamped on every aggregated cluster record.
pub const CLUSTER_SCHEMA: &str = "soi.cluster.v1";

/// One span event lifted out of a shard feed, typed for tree
/// reconstruction; the full original record rides along for lossless
/// re-rendering.
#[derive(Debug, Clone)]
pub struct SpanRec {
    /// Microseconds since the *originating process's* telemetry epoch
    /// (orders spans within one process, not across processes).
    pub t_us: u64,
    /// The trace this span belongs to.
    pub trace_id: u64,
    /// The span itself.
    pub span: SpanKind,
    /// Its parent span (`None` at the root).
    pub parent: Option<SpanKind>,
    /// The original `soi.obs.v1` event record (all named payload
    /// fields preserved).
    raw: Json,
}

/// One feed's distilled state: latest cumulative counters/gauges, the
/// latest bucket-exact exec histograms, and every span event the feed
/// carried.
#[derive(Debug)]
pub struct ShardSummary {
    /// Shard name (the CLI uses the feed file stem).
    pub name: String,
    /// `seq` of the snapshot the counters/gauges came from.
    pub snapshot_seq: u64,
    /// `t_ms` of that snapshot — the process's feed window length.
    pub t_ms: u64,
    /// Cumulative counters, index order = [`Counter::ALL`]; counters a
    /// (older) feed lacks read as 0.
    pub counters: [u64; Counter::COUNT],
    /// Gauges from the same snapshot, index order = [`Gauge::ALL`].
    pub gauges: [u64; Gauge::COUNT],
    /// Exporter-side snapshot drops reported by that snapshot.
    pub feed_drops: u64,
    /// Per-(rung, phase) exec histograms from the latest seq that
    /// rendered any, re-ingested bucket-exactly; ascending key order.
    pub exec_ns: Vec<(usize, usize, Histogram)>,
    /// Every span event in the feed, in feed order.
    pub spans: Vec<SpanRec>,
    /// Non-empty NDJSON lines seen (parse failures included).
    pub lines: u64,
}

impl ShardSummary {
    /// This shard's cumulative value of counter `c`.
    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[Counter::ALL.iter().position(|x| *x == c).unwrap_or(0)]
    }

    /// This shard's latest value of gauge `g`.
    pub fn gauge(&self, g: Gauge) -> u64 {
        self.gauges[Gauge::ALL.iter().position(|x| *x == g).unwrap_or(0)]
    }

    /// Counter `c` as a per-second rate over this feed's window
    /// (0 when the window is empty).
    pub fn rate(&self, c: Counter) -> f64 {
        if self.t_ms == 0 {
            return 0.0;
        }
        self.counter(c) as f64 * 1000.0 / self.t_ms as f64
    }
}

/// The merged cluster view over every aggregated feed.
#[derive(Debug)]
pub struct ClusterSummary {
    /// One summary per input feed, in input order.
    pub shards: Vec<ShardSummary>,
}

fn get_u64(v: &Json, key: &str) -> Option<u64> {
    v.get(key).and_then(Json::as_f64).map(|f| f as u64)
}

/// Distill one `soi.obs.v1` feed.  Tolerant line-by-line (mid-write
/// tails skip), but a feed without any snapshot is an error — there is
/// nothing to aggregate.
fn parse_feed(name: &str, text: &str) -> Result<ShardSummary, String> {
    let mut s = ShardSummary {
        name: name.to_string(),
        snapshot_seq: 0,
        t_ms: 0,
        counters: [0; Counter::COUNT],
        gauges: [0; Gauge::COUNT],
        feed_drops: 0,
        exec_ns: Vec::new(),
        spans: Vec::new(),
        lines: 0,
    };
    let mut saw_snapshot = false;
    // (seq, rung, phase, bucket idx, count) of every exec_ns hist line
    let mut hist_lines: Vec<(u64, usize, usize, usize, u64)> = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        s.lines += 1;
        let Ok(v) = json::parse(line) else { continue };
        let Some(ty) = v.get("type").and_then(|t| t.as_str()) else {
            continue;
        };
        let seq = get_u64(&v, "seq").unwrap_or(0);
        match ty {
            "snapshot" => {
                if seq >= s.snapshot_seq || !saw_snapshot {
                    saw_snapshot = true;
                    s.snapshot_seq = seq;
                    s.t_ms = get_u64(&v, "t_ms").unwrap_or(0);
                    s.feed_drops = get_u64(&v, "feed_drops").unwrap_or(0);
                    if let Some(c) = v.get("counters") {
                        for (i, name) in Counter::ALL.iter().map(|c| c.name()).enumerate() {
                            s.counters[i] = get_u64(c, name).unwrap_or(0);
                        }
                    }
                    if let Some(g) = v.get("gauges") {
                        for (i, name) in Gauge::ALL.iter().map(|g| g.name()).enumerate() {
                            s.gauges[i] = get_u64(g, name).unwrap_or(0);
                        }
                    }
                }
            }
            "hist" => {
                if v.get("name").and_then(|n| n.as_str()) != Some("exec_ns") {
                    continue;
                }
                let (Some(rung), Some(phase)) = (get_u64(&v, "rung"), get_u64(&v, "phase"))
                else {
                    continue;
                };
                if let Some(buckets) = v.get("buckets").and_then(Json::as_arr) {
                    for b in buckets {
                        let Some(pair) = b.as_arr() else { continue };
                        if pair.len() == 2 {
                            if let (Some(i), Some(c)) =
                                (pair[0].as_usize(), pair[1].as_f64().map(|f| f as u64))
                            {
                                hist_lines.push((seq, rung as usize, phase as usize, i, c));
                            }
                        }
                    }
                }
            }
            "event" => {
                if v.get("kind").and_then(|k| k.as_str()) != Some("span") {
                    continue;
                }
                let (Some(t_us), Some(trace_id)) = (get_u64(&v, "t_us"), get_u64(&v, "trace_id"))
                else {
                    continue;
                };
                let Some(span) = v
                    .get("span")
                    .and_then(|x| x.as_str())
                    .and_then(SpanKind::from_name)
                else {
                    continue;
                };
                let parent = v
                    .get("parent")
                    .and_then(|x| x.as_str())
                    .and_then(SpanKind::from_name);
                s.spans.push(SpanRec {
                    t_us,
                    trace_id,
                    span,
                    parent,
                    raw: v,
                });
            }
            _ => {}
        }
    }
    if !saw_snapshot {
        return Err(format!("feed '{name}': no snapshot record"));
    }
    // Feed histograms are cumulative; the newest seq that rendered any
    // hist lines carries the totals (hists only render at seqs with
    // exec activity, so that seq may trail the newest snapshot).
    if let Some(hseq) = hist_lines.iter().map(|(s, ..)| *s).max() {
        for &(seq, rung, phase, idx, count) in &hist_lines {
            if seq != hseq {
                continue;
            }
            match s
                .exec_ns
                .iter_mut()
                .find(|(r, p, _)| (*r, *p) == (rung, phase))
            {
                Some((_, _, h)) => h.add_bucket(idx, count),
                None => {
                    let mut h = Histogram::new();
                    h.add_bucket(idx, count);
                    s.exec_ns.push((rung, phase, h));
                }
            }
        }
        s.exec_ns.sort_by_key(|(r, p, _)| (*r, *p));
    }
    Ok(s)
}

/// Merge `(name, feed text)` pairs into one [`ClusterSummary`].
/// Errors if any feed has no snapshot (name it, don't silently thin
/// the fleet) or if no feeds were given.
pub fn aggregate(feeds: &[(String, String)]) -> Result<ClusterSummary, String> {
    if feeds.is_empty() {
        return Err("no feeds to aggregate".into());
    }
    let mut shards = Vec::with_capacity(feeds.len());
    for (name, text) in feeds {
        shards.push(parse_feed(name, text)?);
    }
    Ok(ClusterSummary { shards })
}

fn num(v: u64) -> Json {
    Json::Num(v as f64)
}

impl ClusterSummary {
    /// Cluster-wide total of counter `c` (sum over shards — exact, the
    /// feeds export cumulative counters).
    pub fn counter_total(&self, c: Counter) -> u64 {
        self.shards.iter().map(|s| s.counter(c)).sum()
    }

    /// Cluster-wide sum of gauge `g` (meaningful for capacity gauges
    /// like streams / queue depth / drop totals).
    pub fn gauge_total(&self, g: Gauge) -> u64 {
        self.shards.iter().map(|s| s.gauge(g)).sum()
    }

    /// Cluster-wide per-second rate of counter `c`: the sum of each
    /// shard's rate over its own feed window.
    pub fn rate_total(&self, c: Counter) -> f64 {
        self.shards.iter().map(|s| s.rate(c)).sum()
    }

    /// Per-(rung, phase) exec histograms merged across every shard,
    /// ascending key order.  Bucket-exact: identical to merging the
    /// in-process registries (see the module docs).
    pub fn cluster_exec(&self) -> Vec<(usize, usize, Histogram)> {
        let mut out: Vec<(usize, usize, Histogram)> = Vec::new();
        for s in &self.shards {
            for (rung, phase, h) in &s.exec_ns {
                match out.iter_mut().find(|(r, p, _)| (*r, *p) == (*rung, *phase)) {
                    Some((_, _, m)) => m.merge(h),
                    None => out.push((*rung, *phase, h.clone())),
                }
            }
        }
        out.sort_by_key(|(r, p, _)| (*r, *p));
        out
    }

    /// Every span in the cluster as `(shard name, span)`.
    pub fn spans(&self) -> impl Iterator<Item = (&str, &SpanRec)> {
        self.shards
            .iter()
            .flat_map(|s| s.spans.iter().map(move |r| (s.name.as_str(), r)))
    }

    /// All distinct trace ids seen, ascending.
    pub fn trace_ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self.spans().map(|(_, r)| r.trace_id).collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// One trace's spans from every shard, sorted by span discriminant
    /// — which is causal order within a trace (DESIGN.md §15: the span
    /// id *is* the hop position, so cross-process clock skew cannot
    /// reorder the tree).
    pub fn trace_spans(&self, trace_id: u64) -> Vec<(&str, &SpanRec)> {
        let mut spans: Vec<(&str, &SpanRec)> = self
            .spans()
            .filter(|(_, r)| r.trace_id == trace_id)
            .collect();
        spans.sort_by_key(|(_, r)| r.span as u8);
        spans
    }

    /// Serialize as `soi.cluster.v1` NDJSON: one `cluster` head
    /// record, one `shard` record per feed, `hist` records at cluster
    /// scope then per-shard scope, then every `span` record re-tagged
    /// with its shard.
    pub fn render_ndjson(&self, out: &mut String) {
        let sum_counters = Json::Obj(
            Counter::ALL
                .iter()
                .map(|c| (c.name().to_string(), num(self.counter_total(*c))))
                .collect(),
        );
        let sum_gauges = Json::Obj(
            Gauge::ALL
                .iter()
                .map(|g| (g.name().to_string(), num(self.gauge_total(*g))))
                .collect(),
        );
        let wire = Json::obj(vec![
            ("rx_msgs_per_s", Json::Num(self.rate_total(Counter::WireRxMsgs))),
            ("tx_msgs_per_s", Json::Num(self.rate_total(Counter::WireTxMsgs))),
            ("rx_bytes_per_s", Json::Num(self.rate_total(Counter::WireRxBytes))),
            ("tx_bytes_per_s", Json::Num(self.rate_total(Counter::WireTxBytes))),
        ]);
        let dropped = Json::obj(vec![
            ("snapshots", num(self.gauge_total(Gauge::ObsDroppedSnapshots))),
            ("events", num(self.gauge_total(Gauge::ObsDroppedEvents))),
            (
                "feed_drops",
                num(self.shards.iter().map(|s| s.feed_drops).sum()),
            ),
        ]);
        let head = Json::obj(vec![
            ("schema", Json::Str(CLUSTER_SCHEMA.into())),
            ("type", Json::Str("cluster".into())),
            ("shards", num(self.shards.len() as u64)),
            (
                "t_ms",
                num(self.shards.iter().map(|s| s.t_ms).max().unwrap_or(0)),
            ),
            ("counters", sum_counters),
            ("gauges", sum_gauges),
            ("wire", wire),
            ("migrations", num(self.counter_total(Counter::ShardMigrates))),
            ("reloads", num(self.counter_total(Counter::GenReloads))),
            ("dropped", dropped),
            ("spans", num(self.spans().count() as u64)),
        ]);
        out.push_str(&head.to_string());
        out.push('\n');
        for s in &self.shards {
            let counters = Json::Obj(
                Counter::ALL
                    .iter()
                    .map(|c| (c.name().to_string(), num(s.counter(*c))))
                    .collect(),
            );
            let gauges = Json::Obj(
                Gauge::ALL
                    .iter()
                    .map(|g| (g.name().to_string(), num(s.gauge(*g))))
                    .collect(),
            );
            let rec = Json::obj(vec![
                ("schema", Json::Str(CLUSTER_SCHEMA.into())),
                ("type", Json::Str("shard".into())),
                ("shard", Json::Str(s.name.clone())),
                ("snapshot_seq", num(s.snapshot_seq)),
                ("t_ms", num(s.t_ms)),
                ("counters", counters),
                ("gauges", gauges),
                ("feed_drops", num(s.feed_drops)),
                ("spans", num(s.spans.len() as u64)),
            ]);
            out.push_str(&rec.to_string());
            out.push('\n');
        }
        for (rung, phase, h) in &self.cluster_exec() {
            push_hist(out, "cluster", *rung, *phase, h);
        }
        for s in &self.shards {
            for (rung, phase, h) in &s.exec_ns {
                push_hist(out, &s.name, *rung, *phase, h);
            }
        }
        for s in &self.shards {
            for r in &s.spans {
                let mut kv: Vec<(String, Json)> = vec![
                    ("schema".into(), Json::Str(CLUSTER_SCHEMA.into())),
                    ("type".into(), Json::Str("span".into())),
                    ("shard".into(), Json::Str(s.name.clone())),
                ];
                if let Some(fields) = r.raw.as_obj() {
                    for (k, v) in fields {
                        // identity lives in the new head fields; 'seq'
                        // was the source feed's snapshot seq
                        if matches!(k.as_str(), "schema" | "type" | "kind" | "seq") {
                            continue;
                        }
                        kv.push((k.clone(), v.clone()));
                    }
                }
                out.push_str(&Json::Obj(kv).to_string());
                out.push('\n');
            }
        }
    }

    /// Render the `soi top` dashboard body: per-shard vitals, cluster
    /// exec latency per (rung × phase), wire rates, drop accounting,
    /// and the most recent trace's hop chain.  Plain text — the CLI
    /// owns cursor control.
    pub fn render_top(&self, out: &mut String) {
        let t_ms = self.shards.iter().map(|s| s.t_ms).max().unwrap_or(0);
        out.push_str(&format!(
            "soi cluster — {} feed(s), window {:.1}s, {} span(s)\n",
            self.shards.len(),
            t_ms as f64 / 1000.0,
            self.spans().count(),
        ));
        out.push_str(&format!(
            "{:<12} {:>8} {:>6} {:>10} {:>10} {:>10} {:>6} {:>6} {:>6}\n",
            "shard", "streams", "queue", "frames", "rx/s", "tx/s", "errs", "migr", "drops"
        ));
        for s in &self.shards {
            out.push_str(&format!(
                "{:<12} {:>8} {:>6} {:>10} {:>10} {:>10} {:>6} {:>6} {:>6}\n",
                s.name,
                s.gauge(Gauge::StreamsLive),
                s.gauge(Gauge::QueueDepth),
                s.counter(Counter::Frames),
                fmt_bytes(s.rate(Counter::WireRxBytes)),
                fmt_bytes(s.rate(Counter::WireTxBytes)),
                s.counter(Counter::WireErrs),
                s.counter(Counter::ShardMigrates),
                s.gauge(Gauge::ObsDroppedEvents) + s.gauge(Gauge::ObsDroppedSnapshots),
            ));
        }
        let exec = self.cluster_exec();
        if !exec.is_empty() {
            out.push_str("cluster exec µs p50/p99 by rung.phase:");
            for (rung, phase, h) in &exec {
                out.push_str(&format!(
                    "  r{rung}.p{phase} {}/{}",
                    h.p50() / 1000,
                    h.p99() / 1000
                ));
            }
            out.push('\n');
        }
        let ids = self.trace_ids();
        if let Some(last) = ids.last() {
            let chain: Vec<String> = self
                .trace_spans(*last)
                .iter()
                .map(|(shard, r)| format!("{}@{}", r.span.name(), shard))
                .collect();
            out.push_str(&format!(
                "traces: {} seen; trace {last}: {}\n",
                ids.len(),
                chain.join(" -> ")
            ));
        }
    }
}

fn push_hist(out: &mut String, scope: &str, rung: usize, phase: usize, h: &Histogram) {
    if h.count() == 0 {
        return;
    }
    let buckets: Vec<Json> = h
        .nonzero()
        .map(|(i, c)| Json::Arr(vec![num(i as u64), num(c)]))
        .collect();
    let rec = Json::obj(vec![
        ("schema", Json::Str(CLUSTER_SCHEMA.into())),
        ("type", Json::Str("hist".into())),
        ("scope", Json::Str(scope.into())),
        ("name", Json::Str("exec_ns".into())),
        ("rung", num(rung as u64)),
        ("phase", num(phase as u64)),
        ("count", num(h.count())),
        ("p50", num(h.p50())),
        ("p95", num(h.p95())),
        ("p99", num(h.p99())),
        ("mean", Json::Num(h.mean())),
        ("buckets", Json::Arr(buckets)),
    ]);
    out.push_str(&rec.to_string());
    out.push('\n');
}

fn fmt_bytes(per_s: f64) -> String {
    if per_s >= 1_048_576.0 {
        format!("{:.1}MB", per_s / 1_048_576.0)
    } else if per_s >= 1024.0 {
        format!("{:.1}KB", per_s / 1024.0)
    } else {
        format!("{:.0}B", per_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{take_snapshot, ObsConfig, Telemetry};

    /// Two fake processes with overlapping (rung, phase) activity and
    /// spans; returns their rendered feeds.
    fn two_feeds() -> Vec<(String, String)> {
        let a = Telemetry::new(ObsConfig { ring_capacity: 64 });
        let ha = a.worker(0);
        for _ in 0..10 {
            ha.exec(0, 1, 2, 1_000_000);
        }
        ha.exec(1, 0, 1, 50_000);
        ha.with(|w| {
            w.count(Counter::Frames, 10);
            w.gauge_set(Gauge::StreamsLive, 3);
            w.span(7, SpanKind::ShardDispatch, SpanKind::FrontAdmit as u8, 4, 0, 0);
            w.span(7, SpanKind::PhaseExec, SpanKind::WorkerRound as u8, 1 << 16, 2, 900);
        });
        let mut fa = String::new();
        take_snapshot(&a).render_ndjson(0, 0, &mut fa);

        let b = Telemetry::new(ObsConfig { ring_capacity: 64 });
        let hb = b.worker(0);
        for _ in 0..5 {
            hb.exec(0, 1, 1, 2_000_000);
        }
        hb.with(|w| {
            w.count(Counter::Frames, 5);
            w.gauge_set(Gauge::StreamsLive, 2);
            w.span(7, SpanKind::FrontAdmit, 0, 4, 0, 1);
            w.span(9, SpanKind::MigrateFront, 0, 4, 0, 1);
        });
        let mut fb = String::new();
        take_snapshot(&b).render_ndjson(0, 0, &mut fb);
        vec![("shard-a".into(), fa), ("front".into(), fb)]
    }

    #[test]
    fn totals_sum_and_hists_merge_bucket_exactly() {
        let cluster = aggregate(&two_feeds()).unwrap();
        assert_eq!(cluster.counter_total(Counter::Frames), 15);
        assert_eq!(cluster.gauge_total(Gauge::StreamsLive), 5);
        let exec = cluster.cluster_exec();
        let h01 = exec
            .iter()
            .find(|(r, p, _)| (*r, *p) == (0, 1))
            .map(|(_, _, h)| h)
            .expect("(0,1) merged");
        assert_eq!(h01.count(), 15, "10 from shard-a + 5 from front");
        // bucket-exact: the merged cluster hist equals a hand-merged
        // registry histogram over the same recordings
        let mut hand = Histogram::new();
        for _ in 0..10 {
            hand.record(1_000_000);
        }
        for _ in 0..5 {
            hand.record(2_000_000);
        }
        let got: Vec<(usize, u64)> = h01.nonzero().collect();
        let want: Vec<(usize, u64)> = hand.nonzero().collect();
        assert_eq!(got, want, "no re-binning, no loss");
        assert_eq!(h01.p99(), hand.p99());
    }

    #[test]
    fn spans_reassemble_by_trace_with_shard_attribution() {
        let cluster = aggregate(&two_feeds()).unwrap();
        assert_eq!(cluster.trace_ids(), vec![7, 9]);
        let t7 = cluster.trace_spans(7);
        let hops: Vec<(&str, SpanKind, Option<SpanKind>)> = t7
            .iter()
            .map(|(shard, r)| (*shard, r.span, r.parent))
            .collect();
        assert_eq!(
            hops,
            vec![
                ("front", SpanKind::FrontAdmit, None),
                ("shard-a", SpanKind::ShardDispatch, Some(SpanKind::FrontAdmit)),
                ("shard-a", SpanKind::PhaseExec, Some(SpanKind::WorkerRound)),
            ],
            "causal order from span discriminants, shards attributed"
        );
    }

    #[test]
    fn rendered_cluster_feed_is_versioned_and_parses() {
        let cluster = aggregate(&two_feeds()).unwrap();
        let mut out = String::new();
        cluster.render_ndjson(&mut out);
        let mut types = std::collections::BTreeMap::new();
        for line in out.lines() {
            let v = json::parse(line).expect("every cluster line parses");
            assert_eq!(
                v.get("schema").and_then(|s| s.as_str()),
                Some(CLUSTER_SCHEMA)
            );
            *types
                .entry(v.get("type").and_then(|t| t.as_str()).unwrap().to_string())
                .or_insert(0u64) += 1;
        }
        assert_eq!(types.get("cluster"), Some(&1));
        assert_eq!(types.get("shard"), Some(&2));
        assert_eq!(types.get("span"), Some(&4));
        assert!(types.get("hist").copied().unwrap_or(0) >= 3, "cluster + per-shard scopes");
        // span records name their shard and keep payload fields
        let span_line = out
            .lines()
            .find(|l| l.contains("\"type\":\"span\"") && l.contains("migrate_front"))
            .unwrap();
        let v = json::parse(span_line).unwrap();
        assert_eq!(v.get("shard").and_then(|s| s.as_str()), Some("front"));
        assert_eq!(v.get("trace_id").and_then(|n| n.as_f64()), Some(9.0));
    }

    #[test]
    fn snapshotless_or_empty_input_errors() {
        assert!(aggregate(&[]).is_err());
        let feeds = vec![("bad".to_string(), "not json\n".to_string())];
        assert!(aggregate(&feeds).unwrap_err().contains("bad"));
    }

    #[test]
    fn top_dashboard_names_every_shard() {
        let cluster = aggregate(&two_feeds()).unwrap();
        let mut out = String::new();
        cluster.render_top(&mut out);
        assert!(out.contains("shard-a"));
        assert!(out.contains("front"));
        assert!(out.contains("trace 9"));
    }
}
