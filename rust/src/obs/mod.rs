//! Observability: a metrics registry, phase-attributed tracing spans,
//! and a live NDJSON health feed (DESIGN.md §12).
//!
//! Three layers, cheapest first:
//!
//! * **Recording** ([`registry`], [`ring`]) — workers and sessions hold
//!   an [`ObsHandle`] and record counters, gauges, per-(rung × phase)
//!   latency histograms, and fixed-size trace events into preallocated
//!   storage.  One uncontended mutex lock per logical record, zero heap
//!   allocations in the steady state (`tests/hot_path_alloc.rs` proves
//!   this with telemetry enabled).
//! * **Aggregation** ([`hist`], [`export::take_snapshot`]) — the
//!   shared log-linear [`crate::util::stats::Histogram`] is the one
//!   mergeable latency type everywhere: the controller's rolling p99
//!   window ([`RollingHist`]), the registry, and the feed all speak it,
//!   so per-worker histograms merge losslessly into per-process ones
//!   and (later) per-shard feeds merge into fleet views.
//! * **Export** ([`export`], [`schema`]) — a sampler thread snapshots
//!   the registry every `--snapshot-ms`, serializes to versioned NDJSON
//!   (`soi.obs.v1`), and hands lines to a writer thread over a bounded
//!   channel; a full channel **drops the snapshot and counts it**
//!   (`feed_drops`) rather than ever stalling the samplers or workers.
//!
//! Deep layers that cannot thread a handle through (the quantized
//! interpreter's plan repack) use the process-global hook
//! ([`Telemetry::install_global`] / [`with_global`]): a `Weak` upgrade
//! when telemetry is on, a single atomic-load no-op when off.
//!
//! The cluster layer (DESIGN.md §15) builds on the same primitives:
//! [`trace`] defines the cross-shard trace context and span taxonomy
//! (recorded through the rings as [`EventKind::Span`]), and
//! [`aggregate`] merges many `soi.obs.v1` feeds into one versioned
//! `soi.cluster.v1` summary — losslessly, because the bucket-exact
//! histogram export round-trips.

pub mod aggregate;
pub mod export;
pub mod hist;
pub mod registry;
pub mod ring;
pub mod schema;
pub mod trace;

pub use aggregate::{aggregate, ClusterSummary, ShardSummary, CLUSTER_SCHEMA};
pub use export::{take_snapshot, Exporter, FeedStats, Snapshot, FEED_SCHEMA};
pub use hist::RollingHist;
pub use registry::{Counter, Gauge, ObsHandle, WorkerObs};
pub use ring::{Event, EventKind, EventRing};
pub use trace::{SpanKind, TraceCtx, TraceSampler, TRACE_CTX_BYTES};

use std::sync::{Arc, Mutex, OnceLock, Weak};
use std::time::Instant;

/// Telemetry tuning knobs.
#[derive(Debug, Clone)]
pub struct ObsConfig {
    /// Event slots per worker ring ([`EventRing`]); overflow within one
    /// export interval drops events (counted, never silent).
    pub ring_capacity: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            ring_capacity: 4096,
        }
    }
}

/// The per-process telemetry root: owns one [`ObsHandle`] per worker
/// plus a shared handle for producers without a worker identity (the
/// global hook).  Cheap to share (`Arc`); snapshotting merges across
/// all handles.
#[derive(Debug)]
pub struct Telemetry {
    epoch: Instant,
    cfg: ObsConfig,
    workers: Mutex<Vec<ObsHandle>>,
    shared: ObsHandle,
}

impl Telemetry {
    /// A fresh telemetry root; worker handles are created lazily by
    /// [`Telemetry::worker`].
    pub fn new(cfg: ObsConfig) -> Arc<Telemetry> {
        let epoch = Instant::now();
        Arc::new(Telemetry {
            epoch,
            shared: ObsHandle::new(epoch, cfg.ring_capacity),
            cfg,
            workers: Mutex::new(Vec::new()),
        })
    }

    /// The instant event timestamps (`t_us`) count from.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// The recording handle for worker `i`, created on first request
    /// (startup only — steady state never grows the table).
    pub fn worker(&self, i: usize) -> ObsHandle {
        let mut ws = self
            .workers
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        while ws.len() <= i {
            ws.push(ObsHandle::new(self.epoch, self.cfg.ring_capacity));
        }
        ws[i].clone()
    }

    /// The shared handle for producers without a worker identity
    /// (global-hook emitters; exported with `worker: null`).
    pub fn shared(&self) -> ObsHandle {
        self.shared.clone()
    }

    /// Snapshot of all worker handles (exporter use).
    pub fn worker_handles(&self) -> Vec<ObsHandle> {
        self.workers
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .clone()
    }

    /// Make this root reachable from [`with_global`] — the hook deep
    /// layers (quant repack) emit through.  Held as a `Weak`, so
    /// dropping the last `Arc` uninstalls automatically.
    pub fn install_global(self: &Arc<Self>) {
        *global_slot()
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner) = Arc::downgrade(self);
    }

    /// Clear the global hook (tests; normal teardown is automatic via
    /// the `Weak`).
    pub fn uninstall_global() {
        *global_slot()
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner) = Weak::new();
    }
}

fn global_slot() -> &'static Mutex<Weak<Telemetry>> {
    static SLOT: OnceLock<Mutex<Weak<Telemetry>>> = OnceLock::new();
    SLOT.get_or_init(|| Mutex::new(Weak::new()))
}

/// Run `f` with the installed [`Telemetry`], if any.  A no-op (one
/// mutex lock on a rarely-touched slot plus a failed `Weak` upgrade)
/// when telemetry is off — callers on rare paths (plan repack) can emit
/// unconditionally.
pub fn with_global(f: impl FnOnce(&Telemetry)) {
    let tel = global_slot()
        .lock()
        .unwrap_or_else(std::sync::PoisonError::into_inner)
        .upgrade();
    if let Some(t) = tel {
        f(&t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_handles_are_stable_and_lazy() {
        let tel = Telemetry::new(ObsConfig::default());
        assert!(tel.worker_handles().is_empty());
        let h2 = tel.worker(2);
        assert_eq!(tel.worker_handles().len(), 3);
        h2.count(Counter::Rounds, 1);
        // same underlying store on re-request
        tel.worker(2).with(|w| assert_eq!(w.counter(Counter::Rounds), 1));
    }

    #[test]
    fn global_hook_upgrades_only_while_installed() {
        // no hook: no-op
        let mut ran = false;
        with_global(|_| ran = true);
        assert!(!ran);
        let tel = Telemetry::new(ObsConfig::default());
        tel.install_global();
        with_global(|t| t.shared().count(Counter::QuantRepacks, 1));
        tel.shared()
            .with(|w| assert_eq!(w.counter(Counter::QuantRepacks), 1));
        drop(tel);
        // weak: dropping the root uninstalls
        let mut ran = false;
        with_global(|_| ran = true);
        assert!(!ran);
        Telemetry::uninstall_global();
    }
}
