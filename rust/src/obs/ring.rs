//! Fixed-capacity tracing-event ring buffers (DESIGN.md §12).
//!
//! Every span the serving stack records becomes one fixed-size
//! [`Event`]: a timestamp, a kind tag, and five `u64` payload fields
//! whose meaning is per-kind (documented on [`EventKind`] and decoded to
//! named NDJSON fields by `obs::export`).  Events live in a per-worker
//! [`EventRing`] whose slots are allocated **once** at construction —
//! pushing, overflowing, and draining are all allocation-free on the
//! producer side, which is what lets the zero-allocation steady state of
//! `tests/hot_path_alloc.rs` hold with telemetry enabled.
//!
//! Overflow policy: when the ring is full the **incoming** event is
//! dropped and counted ([`EventRing::dropped`]); buffered events are
//! never overwritten.  Keeping the oldest events preserves causality
//! from the start of each export interval — a saturated ring tells you
//! *when* the feed went blind (the drop counter) instead of silently
//! rewriting history.

use std::time::Instant;

/// What a recorded span describes.  The five payload fields `a..e` of
/// the carrying [`Event`] are interpreted per the field list on each
/// kind; unused fields are zero.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// One serving round: `a` = frames served, `b` = backlog after the
    /// round, `c` = live streams, `d` = round wall time ns.
    Round,
    /// One phase-aligned dispatch group: `a` = rung, `b` = phase,
    /// `c` = group width (streams), `d` = exec wall time ns.
    Exec,
    /// FP precompute pass: `a` = stream id, `b` = phase, `c` = 1 when
    /// run inline on arrival (0 when run idle), `d` = ns.
    FpPre,
    /// FP rest pass: `a` = phase, `b` = group width, `d` = ns.
    FpRest,
    /// Warm migration: `a` = stream id, `b` = from rung, `c` = to rung,
    /// `d` = history frames replayed, `e` = ns.
    Migration,
    /// Quantized plan (re)pack: `a` = panels packed, `b` = packed code
    /// bytes, `d` = ns.
    QuantRepack,
    /// Controller verdict: `a` = from rung, `b` = to rung, `c` =
    /// trigger (0 queue, 1 latency, 2 calm), `d` = backlog at decision,
    /// `e` = rolling p99 µs at decision.
    CtlDecision,
    /// Weight-generation hot reload adopted by a worker (DESIGN.md §13):
    /// `a` = from generation, `b` = to generation, `c` = live streams on
    /// the worker at adoption, `d` = weight-upload wall time ns.
    GenReload,
    /// Session admitted mid-stream by cross-shard §9 replay
    /// (DESIGN.md §14): `a` = stream id, `b` = absolute frame counter
    /// resumed at, `c` = history frames replayed, `d` = replay wall
    /// time ns.
    ShardMigrate,
    /// One cross-shard trace span (DESIGN.md §15): `a` = trace id,
    /// `b` = `(span_kind << 8) | parent_kind` (the
    /// [`crate::obs::trace::SpanKind`] discriminants), and `c`/`d`/`e`
    /// are span-kind-specific (decoded to named fields by
    /// `obs::export`).
    Span,
}

impl EventKind {
    /// Stable snake_case name — the `kind` field of NDJSON event
    /// records (DESIGN.md appendix A).
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Round => "round",
            EventKind::Exec => "exec",
            EventKind::FpPre => "fp_pre",
            EventKind::FpRest => "fp_rest",
            EventKind::Migration => "migration",
            EventKind::QuantRepack => "quant_repack",
            EventKind::CtlDecision => "ctl_decision",
            EventKind::GenReload => "gen_reload",
            EventKind::ShardMigrate => "shard_migrate",
            EventKind::Span => "span",
        }
    }
}

/// One recorded span: fixed-size, `Copy`, no heap — ring slots hold
/// these by value.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// Microseconds since the owning [`crate::obs::Telemetry`] epoch.
    pub t_us: u64,
    /// What the span describes (fixes the meaning of `a..e`).
    pub kind: EventKind,
    /// First payload field (per-kind meaning; see [`EventKind`]).
    pub a: u64,
    /// Second payload field.
    pub b: u64,
    /// Third payload field.
    pub c: u64,
    /// Fourth payload field.
    pub d: u64,
    /// Fifth payload field.
    pub e: u64,
}

impl Event {
    /// Zeroed slot filler (capacity preallocation).
    fn empty() -> Event {
        Event {
            t_us: 0,
            kind: EventKind::Round,
            a: 0,
            b: 0,
            c: 0,
            d: 0,
            e: 0,
        }
    }

    /// Microseconds elapsed since `epoch`, saturating into `u64`.
    pub fn stamp(epoch: Instant) -> u64 {
        u64::try_from(epoch.elapsed().as_micros()).unwrap_or(u64::MAX)
    }
}

/// Bounded FIFO of [`Event`]s with slots allocated once at construction.
///
/// Producers push allocation-free; the exporter periodically drains.
/// When full, incoming events are dropped and counted (never silently) —
/// see the module docs for why drop-newest is the right policy here.
#[derive(Debug)]
pub struct EventRing {
    slots: Box<[Event]>,
    /// Index of the oldest buffered event.
    head: usize,
    len: usize,
    dropped: u64,
}

impl EventRing {
    /// A ring holding up to `capacity` events (clamped to at least 1).
    pub fn new(capacity: usize) -> EventRing {
        let cap = capacity.max(1);
        EventRing {
            slots: vec![Event::empty(); cap].into_boxed_slice(),
            head: 0,
            len: 0,
            dropped: 0,
        }
    }

    /// Buffered event count.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when nothing is buffered.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Slot count.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Events dropped on overflow since the last [`EventRing::drain_into`].
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Append one event; on a full ring the event is dropped and
    /// counted instead.  Never allocates.
    pub fn push(&mut self, ev: Event) {
        if self.len == self.slots.len() {
            self.dropped += 1;
            return;
        }
        let at = (self.head + self.len) % self.slots.len();
        self.slots[at] = ev;
        self.len += 1;
    }

    /// Move every buffered event into `out` (oldest first) and return
    /// the overflow-drop count since the previous drain, resetting it.
    /// Allocation happens only in `out` (the exporter's buffer), never
    /// in the ring.
    pub fn drain_into(&mut self, out: &mut Vec<Event>) -> u64 {
        for i in 0..self.len {
            out.push(self.slots[(self.head + i) % self.slots.len()]);
        }
        self.head = 0;
        self.len = 0;
        std::mem::take(&mut self.dropped)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: EventKind, a: u64) -> Event {
        Event {
            t_us: 1,
            kind,
            a,
            b: 0,
            c: 0,
            d: 0,
            e: 0,
        }
    }

    #[test]
    fn fifo_order_and_drain() {
        let mut r = EventRing::new(4);
        for i in 0..3 {
            r.push(ev(EventKind::Exec, i));
        }
        assert_eq!(r.len(), 3);
        let mut out = Vec::new();
        assert_eq!(r.drain_into(&mut out), 0);
        assert_eq!(out.iter().map(|e| e.a).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert!(r.is_empty());
    }

    #[test]
    fn overflow_drops_newest_and_counts() {
        let mut r = EventRing::new(2);
        for i in 0..5 {
            r.push(ev(EventKind::Round, i));
        }
        assert_eq!(r.len(), 2);
        assert_eq!(r.dropped(), 3);
        let mut out = Vec::new();
        assert_eq!(r.drain_into(&mut out), 3);
        // the two *oldest* events survived
        assert_eq!(out.iter().map(|e| e.a).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(r.dropped(), 0, "drain resets the drop counter");
        // wrap-around after drain still works
        for i in 10..12 {
            r.push(ev(EventKind::Round, i));
        }
        out.clear();
        r.drain_into(&mut out);
        assert_eq!(out.iter().map(|e| e.a).collect::<Vec<_>>(), vec![10, 11]);
    }
}
