//! The metrics registry: counters, gauges, and per-(rung × phase)
//! latency histograms, stored per worker (DESIGN.md §12).
//!
//! All storage is preallocated or warmed during the first schedule
//! period: counters and gauges are fixed arrays indexed by enum,
//! per-(rung, phase) histograms live in a linear-scanned `Vec` whose
//! entries are inserted on first sight of a key (warm-up) and only
//! *looked up* afterwards, and events go to the fixed-capacity
//! [`EventRing`].  One [`ObsHandle`] wraps each worker's store in a
//! `Mutex`; producers take the lock once per logical record (a dispatch
//! group, a round, a decision), so the steady-state cost is one
//! uncontended lock + a few array writes — and **zero** heap
//! allocations, as `tests/hot_path_alloc.rs` proves with the registry
//! active.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::ring::{Event, EventKind, EventRing};
use super::trace::SpanKind;
use crate::util::stats::Histogram;

/// Monotone event counters, summed across workers at snapshot time.
/// `name()` is the NDJSON field key (DESIGN.md appendix A).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    /// Stream frames delivered.
    Frames,
    /// Phase-aligned dispatch groups executed (width ≥ 1).
    Execs,
    /// Serving rounds completed.
    Rounds,
    /// FP precompute passes (idle or inline).
    FpPre,
    /// FP rest passes.
    FpRest,
    /// Warm migrations completed.
    Migrations,
    /// Quantized-plan (re)packs.
    QuantRepacks,
    /// Controller degrade verdicts (toward cheaper rungs).
    CtlDegrades,
    /// Controller recover verdicts (toward quality).
    CtlRecovers,
    /// Weight-generation hot reloads adopted by a worker (DESIGN.md §13).
    GenReloads,
    /// Wire messages received (`soi.wire.v1`, DESIGN.md §14).
    WireRxMsgs,
    /// Wire messages sent.
    WireTxMsgs,
    /// Wire bytes received (prefix + tag + payload).
    WireRxBytes,
    /// Wire bytes sent.
    WireTxBytes,
    /// Typed wire faults observed (decode errors, backpressure, peer
    /// loss — DESIGN.md §14 fault matrix).  Kept as the total across
    /// codes; the `WireErr*` counters below break it out per
    /// [`crate::net::wire::ErrCode`] (additive schema change).
    WireErrs,
    /// Sessions admitted mid-stream by cross-shard §9 replay
    /// ([`crate::coordinator::StreamSession::resume`]).
    ShardMigrates,
    /// Wire errors sent with code `version_skew`.
    WireErrVersionSkew,
    /// Wire errors sent with code `admission_denied`.
    WireErrAdmissionDenied,
    /// Wire errors sent with code `bad_frame`.
    WireErrBadFrame,
    /// Wire errors sent with code `protocol`.
    WireErrProtocol,
    /// Wire errors sent with code `shard_lost`.
    WireErrShardLost,
    /// Wire errors sent with code `backpressure`.
    WireErrBackpressure,
    /// Wire errors sent with code `overloaded` (DESIGN.md §16).
    WireErrOverloaded,
    /// Heartbeat ticks where at least one shard had an unanswered
    /// `Ping` outstanding (DESIGN.md §16).
    HeartbeatMiss,
    /// Shards declared suspect by the miss-budget detector (sessions
    /// migrated off while the socket was still open).
    ShardSuspect,
    /// Lost or suspect shards re-admitted after a successful
    /// reconnect + re-`Hello`.
    ShardRejoin,
    /// Frames re-sent to a new home during session recovery (the
    /// unacked tail replayed by a re-home).
    FramesRetried,
    /// Admissions or recoveries shed with a typed `Overloaded` reply
    /// because surviving capacity or a session's retry/deadline
    /// budget was exhausted.
    AdmissionShed,
}

impl Counter {
    /// Number of counters (sizes the per-worker array).
    pub const COUNT: usize = 28;

    /// Every counter, in array-index order.
    pub const ALL: [Counter; Counter::COUNT] = [
        Counter::Frames,
        Counter::Execs,
        Counter::Rounds,
        Counter::FpPre,
        Counter::FpRest,
        Counter::Migrations,
        Counter::QuantRepacks,
        Counter::CtlDegrades,
        Counter::CtlRecovers,
        Counter::GenReloads,
        Counter::WireRxMsgs,
        Counter::WireTxMsgs,
        Counter::WireRxBytes,
        Counter::WireTxBytes,
        Counter::WireErrs,
        Counter::ShardMigrates,
        Counter::WireErrVersionSkew,
        Counter::WireErrAdmissionDenied,
        Counter::WireErrBadFrame,
        Counter::WireErrProtocol,
        Counter::WireErrShardLost,
        Counter::WireErrBackpressure,
        Counter::WireErrOverloaded,
        Counter::HeartbeatMiss,
        Counter::ShardSuspect,
        Counter::ShardRejoin,
        Counter::FramesRetried,
        Counter::AdmissionShed,
    ];

    /// Stable snake_case name used as the NDJSON object key.
    pub fn name(self) -> &'static str {
        match self {
            Counter::Frames => "frames",
            Counter::Execs => "execs",
            Counter::Rounds => "rounds",
            Counter::FpPre => "fp_pre",
            Counter::FpRest => "fp_rest",
            Counter::Migrations => "migrations",
            Counter::QuantRepacks => "quant_repacks",
            Counter::CtlDegrades => "ctl_degrades",
            Counter::CtlRecovers => "ctl_recovers",
            Counter::GenReloads => "gen_reloads",
            Counter::WireRxMsgs => "wire_rx_msgs",
            Counter::WireTxMsgs => "wire_tx_msgs",
            Counter::WireRxBytes => "wire_rx_bytes",
            Counter::WireTxBytes => "wire_tx_bytes",
            Counter::WireErrs => "wire_errs",
            Counter::ShardMigrates => "shard_migrates",
            Counter::WireErrVersionSkew => "wire_err_version_skew",
            Counter::WireErrAdmissionDenied => "wire_err_admission_denied",
            Counter::WireErrBadFrame => "wire_err_bad_frame",
            Counter::WireErrProtocol => "wire_err_protocol",
            Counter::WireErrShardLost => "wire_err_shard_lost",
            Counter::WireErrBackpressure => "wire_err_backpressure",
            Counter::WireErrOverloaded => "wire_err_overloaded",
            Counter::HeartbeatMiss => "heartbeat_miss",
            Counter::ShardSuspect => "shard_suspect",
            Counter::ShardRejoin => "shard_rejoin",
            Counter::FramesRetried => "frames_retried",
            Counter::AdmissionShed => "admission_shed",
        }
    }

    fn idx(self) -> usize {
        Counter::ALL.iter().position(|c| *c == self).unwrap_or(0)
    }
}

/// Last-value gauges, set per worker; snapshots export the **max**
/// across workers (the hottest worker is the one a health check cares
/// about).  `name()` is the NDJSON field key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Gauge {
    /// Peak scratch-arena bytes on the worker's thread (monotone; from
    /// [`crate::kernels::arena::thread_peak_bytes`]).
    ArenaPeakBytes,
    /// Backlog (received, undelivered frames) after the latest round.
    QueueDepth,
    /// The worker's current target ladder rung.
    TargetRung,
    /// Live streams on the worker.
    StreamsLive,
    /// The weight generation the worker currently serves (0 when the
    /// server runs without hot reload — DESIGN.md §13).
    Generation,
    /// The 1-based shard id of a `serve-shard` process (0 = this
    /// process is not a network shard — DESIGN.md §14).  Lets a
    /// cluster controller attribute a merged feed line to its shard.
    ShardId,
    /// Snapshots the exporter dropped since the feed opened (its
    /// bounded queue was full — cumulative, set by the exporter so
    /// feed gaps are distinguishable from idle periods).
    ObsDroppedSnapshots,
    /// Events the rings dropped on overflow since the feed opened
    /// (cumulative across drains, set by the exporter).
    ObsDroppedEvents,
}

impl Gauge {
    /// Number of gauges (sizes the per-worker array).
    pub const COUNT: usize = 8;

    /// Every gauge, in array-index order.
    pub const ALL: [Gauge; Gauge::COUNT] = [
        Gauge::ArenaPeakBytes,
        Gauge::QueueDepth,
        Gauge::TargetRung,
        Gauge::StreamsLive,
        Gauge::Generation,
        Gauge::ShardId,
        Gauge::ObsDroppedSnapshots,
        Gauge::ObsDroppedEvents,
    ];

    /// Stable snake_case name used as the NDJSON object key.
    pub fn name(self) -> &'static str {
        match self {
            Gauge::ArenaPeakBytes => "arena_peak_bytes",
            Gauge::QueueDepth => "queue_depth",
            Gauge::TargetRung => "target_rung",
            Gauge::StreamsLive => "streams_live",
            Gauge::Generation => "generation",
            Gauge::ShardId => "shard_id",
            Gauge::ObsDroppedSnapshots => "obs_dropped_snapshots",
            Gauge::ObsDroppedEvents => "obs_dropped_events",
        }
    }

    fn idx(self) -> usize {
        Gauge::ALL.iter().position(|g| *g == self).unwrap_or(0)
    }
}

/// One worker's metric store: counter/gauge arrays, per-(rung, phase)
/// exec-latency histograms, a dispatch-width histogram, and the event
/// ring.  Always accessed through an [`ObsHandle`]'s mutex.
#[derive(Debug)]
pub struct WorkerObs {
    epoch: Instant,
    counters: [u64; Counter::COUNT],
    gauges: [u64; Gauge::COUNT],
    /// `(rung << 16 | phase, wall-ns histogram)` — linear scan; entries
    /// are created on first sight of a key (one allocation per live
    /// (rung, phase) pair, all during warm-up) and reused forever after.
    exec_ns: Vec<(u32, Histogram)>,
    /// Dispatch-group widths (streams per exec).
    batch_width: Histogram,
    ring: EventRing,
}

impl WorkerObs {
    fn new(epoch: Instant, ring_capacity: usize) -> WorkerObs {
        WorkerObs {
            epoch,
            counters: [0; Counter::COUNT],
            gauges: [0; Gauge::COUNT],
            exec_ns: Vec::new(),
            batch_width: Histogram::new(),
            ring: EventRing::new(ring_capacity),
        }
    }

    /// Increment counter `c` by `n`.
    pub fn count(&mut self, c: Counter, n: u64) {
        self.counters[c.idx()] += n;
    }

    /// Set gauge `g` to `v` (last-value semantics).
    pub fn gauge_set(&mut self, g: Gauge, v: u64) {
        self.gauges[g.idx()] = v;
    }

    /// Raise gauge `g` to at least `v` (for monotone gauges like
    /// [`Gauge::ArenaPeakBytes`]).
    pub fn gauge_max(&mut self, g: Gauge, v: u64) {
        let slot = &mut self.gauges[g.idx()];
        *slot = (*slot).max(v);
    }

    /// Current value of counter `c`.
    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c.idx()]
    }

    /// Current value of gauge `g`.
    pub fn gauge(&self, g: Gauge) -> u64 {
        self.gauges[g.idx()]
    }

    /// Append a raw event to the ring (timestamped by the caller via
    /// [`Event::stamp`] or here when `t_us` is 0 — callers inside this
    /// module always stamp).
    pub fn push_event(&mut self, kind: EventKind, a: u64, b: u64, c: u64, d: u64, e: u64) {
        self.ring.push(Event {
            t_us: Event::stamp(self.epoch),
            kind,
            a,
            b,
            c,
            d,
            e,
        });
    }

    /// Record one phase-aligned dispatch group: bumps the per-(rung,
    /// phase) latency histogram, the width histogram, the frame/exec
    /// counters, and appends an [`EventKind::Exec`] event — all under
    /// the caller's single lock.
    pub fn exec(&mut self, rung: usize, phase: usize, width: usize, ns: u64) {
        let key = ((rung as u32) << 16) | (phase as u32 & 0xFFFF);
        match self.exec_ns.iter_mut().find(|(k, _)| *k == key) {
            Some((_, h)) => h.record(ns),
            None => {
                // first sight of this (rung, phase): warm-up allocation
                let mut h = Histogram::new();
                h.record(ns);
                self.exec_ns.push((key, h));
            }
        }
        self.batch_width.record(width as u64);
        self.count(Counter::Execs, 1);
        self.count(Counter::Frames, width as u64);
        self.push_event(
            EventKind::Exec,
            rung as u64,
            phase as u64,
            width as u64,
            ns,
            0,
        );
    }

    /// Iterate the per-(rung, phase) exec histograms as
    /// `(rung, phase, hist)`.
    pub fn exec_hists(&self) -> impl Iterator<Item = (usize, usize, &Histogram)> + '_ {
        self.exec_ns
            .iter()
            .map(|(k, h)| ((*k >> 16) as usize, (*k & 0xFFFF) as usize, h))
    }

    /// The dispatch-width histogram.
    pub fn batch_width(&self) -> &Histogram {
        &self.batch_width
    }

    /// Record one cross-shard trace span (DESIGN.md §15): the span
    /// just opened is `kind`, `parent` is the discriminant of the
    /// causal parent span (0 at a trace root), and `c`/`d`/`e` are the
    /// kind-specific payload fields `obs::export` decodes to named
    /// NDJSON fields.  One ring push, no allocation.
    pub fn span(&mut self, trace_id: u64, kind: SpanKind, parent: u8, c: u64, d: u64, e: u64) {
        self.push_event(
            EventKind::Span,
            trace_id,
            ((kind as u64) << 8) | u64::from(parent),
            c,
            d,
            e,
        );
    }

    /// Drain buffered events into `out`, returning the overflow-drop
    /// count since the last drain (exporter only).
    pub fn drain_events(&mut self, out: &mut Vec<Event>) -> u64 {
        self.ring.drain_into(out)
    }
}

/// Cloneable producer handle: one worker's [`WorkerObs`] behind a
/// mutex.  Every recording method takes the lock exactly once; compound
/// updates go through [`ObsHandle::with`].
#[derive(Debug, Clone)]
pub struct ObsHandle {
    inner: Arc<Mutex<WorkerObs>>,
}

impl ObsHandle {
    /// A fresh handle with its own store (normally created by
    /// [`crate::obs::Telemetry::worker`]).
    pub fn new(epoch: Instant, ring_capacity: usize) -> ObsHandle {
        ObsHandle {
            inner: Arc::new(Mutex::new(WorkerObs::new(epoch, ring_capacity))),
        }
    }

    /// Run `f` with the locked store — one lock for a compound update
    /// (e.g. a round's event + counters + gauges together).
    pub fn with<R>(&self, f: impl FnOnce(&mut WorkerObs) -> R) -> R {
        let mut g = self
            .inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        f(&mut g)
    }

    /// Increment counter `c` by `n`.
    pub fn count(&self, c: Counter, n: u64) {
        self.with(|w| w.count(c, n));
    }

    /// Record one dispatch group (see [`WorkerObs::exec`]).
    pub fn exec(&self, rung: usize, phase: usize, width: usize, ns: u64) {
        self.with(|w| w.exec(rung, phase, width, ns));
    }

    /// Record an FP precompute pass (`inline`: on-arrival vs idle).
    pub fn fp_pre(&self, stream: u64, phase: usize, inline: bool, ns: u64) {
        self.with(|w| {
            w.count(Counter::FpPre, 1);
            w.push_event(
                EventKind::FpPre,
                stream,
                phase as u64,
                u64::from(inline),
                ns,
                0,
            );
        });
    }

    /// Record an FP rest pass over a `width`-stream group.
    pub fn fp_rest(&self, phase: usize, width: usize, ns: u64) {
        self.with(|w| {
            w.count(Counter::FpRest, 1);
            w.push_event(EventKind::FpRest, phase as u64, width as u64, 0, ns, 0);
        });
    }

    /// Record a completed warm migration.
    pub fn migration(&self, stream: u64, from: usize, to: usize, replay_frames: usize, ns: u64) {
        self.with(|w| {
            w.count(Counter::Migrations, 1);
            w.push_event(
                EventKind::Migration,
                stream,
                from as u64,
                to as u64,
                replay_frames as u64,
                ns,
            );
        });
    }

    /// Record a weight-generation hot reload adopted by this worker:
    /// bumps the counter, updates the generation gauge, and emits a
    /// [`EventKind::GenReload`] event — one lock (DESIGN.md §13).
    pub fn gen_reload(&self, from_gen: u64, to_gen: u64, streams: usize, ns: u64) {
        self.with(|w| {
            w.count(Counter::GenReloads, 1);
            w.gauge_set(Gauge::Generation, to_gen);
            w.push_event(EventKind::GenReload, from_gen, to_gen, streams as u64, ns, 0);
        });
    }

    /// Record a session admitted mid-stream by cross-shard §9 replay
    /// (a shard serving a `Migrate` message — DESIGN.md §14): bumps
    /// [`Counter::ShardMigrates`] and emits a
    /// [`EventKind::ShardMigrate`] event, one lock.
    pub fn shard_migrate(&self, stream: u64, t: u64, replay_frames: usize, ns: u64) {
        self.with(|w| {
            w.count(Counter::ShardMigrates, 1);
            w.push_event(
                EventKind::ShardMigrate,
                stream,
                t,
                replay_frames as u64,
                ns,
                0,
            );
        });
    }

    /// Record one cross-shard trace span (see [`WorkerObs::span`]).
    pub fn span(&self, trace_id: u64, kind: SpanKind, parent: u8, c: u64, d: u64, e: u64) {
        self.with(|w| w.span(trace_id, kind, parent, c, d, e));
    }

    /// Record a quantized-plan (re)pack.
    pub fn quant_repack(&self, panels: usize, bytes: usize, ns: u64) {
        self.with(|w| {
            w.count(Counter::QuantRepacks, 1);
            w.push_event(
                EventKind::QuantRepack,
                panels as u64,
                bytes as u64,
                0,
                ns,
                0,
            );
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_index_correctly() {
        let h = ObsHandle::new(Instant::now(), 8);
        for c in Counter::ALL {
            h.count(c, 2);
        }
        h.with(|w| {
            for c in Counter::ALL {
                assert_eq!(w.counter(c), 2, "{}", c.name());
            }
            for g in Gauge::ALL {
                w.gauge_set(g, 7);
                w.gauge_max(g, 3); // lower: no effect
                assert_eq!(w.gauge(g), 7, "{}", g.name());
                w.gauge_max(g, 11);
                assert_eq!(w.gauge(g), 11, "{}", g.name());
            }
        });
    }

    #[test]
    fn exec_attributes_by_rung_and_phase() {
        let h = ObsHandle::new(Instant::now(), 8);
        h.exec(1, 3, 4, 1000);
        h.exec(1, 3, 4, 2000);
        h.exec(0, 3, 1, 500);
        h.with(|w| {
            let hists: Vec<(usize, usize, u64)> =
                w.exec_hists().map(|(r, p, h)| (r, p, h.count())).collect();
            assert!(hists.contains(&(1, 3, 2)));
            assert!(hists.contains(&(0, 3, 1)));
            assert_eq!(w.counter(Counter::Execs), 3);
            assert_eq!(w.counter(Counter::Frames), 9);
            assert_eq!(w.batch_width().count(), 3);
            let mut evs = Vec::new();
            w.drain_events(&mut evs);
            assert_eq!(evs.len(), 3);
            assert!(evs.iter().all(|e| e.kind == EventKind::Exec));
        });
    }

    #[test]
    fn trace_span_packs_kind_and_parent() {
        let h = ObsHandle::new(Instant::now(), 8);
        h.span(
            42,
            SpanKind::ShardDispatch,
            SpanKind::FrontAdmit as u8,
            7,
            9,
            11,
        );
        h.with(|w| {
            let mut evs = Vec::new();
            w.drain_events(&mut evs);
            assert_eq!(evs.len(), 1);
            let e = &evs[0];
            assert_eq!(e.kind, EventKind::Span);
            assert_eq!(e.a, 42);
            assert_eq!(e.b >> 8, SpanKind::ShardDispatch as u64);
            assert_eq!(e.b & 0xFF, SpanKind::FrontAdmit as u64);
            assert_eq!((e.c, e.d, e.e), (7, 9, 11));
        });
    }

    #[test]
    fn span_helpers_count_and_emit() {
        let h = ObsHandle::new(Instant::now(), 16);
        h.fp_pre(5, 2, true, 100);
        h.fp_rest(2, 3, 200);
        h.migration(5, 0, 1, 12, 300);
        h.quant_repack(7, 4096, 400);
        h.gen_reload(3, 4, 6, 500);
        h.shard_migrate(5, 32, 12, 600);
        h.with(|w| {
            assert_eq!(w.counter(Counter::FpPre), 1);
            assert_eq!(w.counter(Counter::FpRest), 1);
            assert_eq!(w.counter(Counter::Migrations), 1);
            assert_eq!(w.counter(Counter::QuantRepacks), 1);
            assert_eq!(w.counter(Counter::GenReloads), 1);
            assert_eq!(w.counter(Counter::ShardMigrates), 1);
            assert_eq!(w.gauge(Gauge::Generation), 4);
            let mut evs = Vec::new();
            w.drain_events(&mut evs);
            let kinds: Vec<&str> = evs.iter().map(|e| e.kind.name()).collect();
            assert_eq!(
                kinds,
                vec![
                    "fp_pre",
                    "fp_rest",
                    "migration",
                    "quant_repack",
                    "gen_reload",
                    "shard_migrate"
                ]
            );
            let m = &evs[2];
            assert_eq!((m.a, m.b, m.c, m.d, m.e), (5, 0, 1, 12, 300));
            let g = &evs[4];
            assert_eq!((g.a, g.b, g.c, g.d, g.e), (3, 4, 6, 500, 0));
            let s = &evs[5];
            assert_eq!((s.a, s.b, s.c, s.d, s.e), (5, 32, 12, 600, 0));
        });
    }
}
