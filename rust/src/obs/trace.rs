//! Cross-shard frame tracing (DESIGN.md §15).
//!
//! A sampled frame carries a compact [`TraceCtx`] — trace id, the
//! span the sender just opened, and that span's parent — across the
//! `soi.wire.v1` hops (`Frame`/`FrameOut`/`Migrate`), and every
//! process on the path records its own span through the existing
//! zero-allocation `obs` event rings ([`EventKind::Span`]).  One
//! sampled frame therefore yields a causally-linked span tree that
//! spans the front-end and every shard it touched:
//!
//! ```text
//! front_admit (root)
//! └─ shard_dispatch          (shard feed)
//!    └─ worker_round         (shard feed)
//!       └─ phase_exec        (shard feed)
//!          └─ front_reply    (front feed)
//! ```
//!
//! Span ids are the [`SpanKind`] discriminants: within one trace each
//! hop happens exactly once (a trace follows a single frame, or a
//! single migration), so the kind *is* a unique span id and the tree
//! is reconstructible from `(trace_id, span, parent)` alone — no
//! allocation, no per-trace tables.
//!
//! Sampling is head-based at the front-end (`--trace-sample-n N`
//! traces every Nth admitted frame; 0 = off, the default).  When
//! sampling is off nothing is stamped on the wire — traced-off
//! encodings are byte-identical to plain `soi.wire.v1`, so old peers
//! interop untouched — and the serving hot path only ever branches on
//! an `Option` that is `None` (`tests/hot_path_alloc.rs` proves the
//! steady state stays allocation-free with the plumbing compiled in).
//!
//! [`EventKind::Span`]: crate::obs::ring::EventKind::Span

/// Bytes a [`TraceCtx`] occupies on the wire (`trace_id: u64` +
/// `kind: u8` + `parent: u8`, little-endian).
pub const TRACE_CTX_BYTES: usize = 10;

/// The span taxonomy (DESIGN.md §15).  The discriminant doubles as
/// the span id inside a trace — see the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum SpanKind {
    /// Front-end admitted + routed one sampled input frame (root).
    FrontAdmit = 1,
    /// A shard pulled the traced frame off the wire.
    ShardDispatch = 2,
    /// The owning worker served the traced frame inside a round.
    WorkerRound = 3,
    /// The per-(rung × phase) backend execution of the traced frame.
    PhaseExec = 4,
    /// The front-end forwarded the traced output back to the client.
    FrontReply = 5,
    /// The front-end initiated a warm cross-shard migration (root of
    /// a migration trace; names both shards).
    MigrateFront = 6,
    /// The destination shard replayed the migrated session's history.
    MigrateReplay = 7,
    /// The front re-homed a session after a loss/suspect verdict and
    /// replayed its unacked tail (root of a retry trace; DESIGN.md
    /// §16).
    FrontRetry = 8,
    /// The front re-admitted a recovered shard into placement after a
    /// successful reconnect + re-`Hello` (DESIGN.md §16).
    ShardRejoin = 9,
}

impl SpanKind {
    /// Every kind, in discriminant order.
    pub const ALL: [SpanKind; 9] = [
        SpanKind::FrontAdmit,
        SpanKind::ShardDispatch,
        SpanKind::WorkerRound,
        SpanKind::PhaseExec,
        SpanKind::FrontReply,
        SpanKind::MigrateFront,
        SpanKind::MigrateReplay,
        SpanKind::FrontRetry,
        SpanKind::ShardRejoin,
    ];

    /// Stable snake_case name (feed field `span`).
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::FrontAdmit => "front_admit",
            SpanKind::ShardDispatch => "shard_dispatch",
            SpanKind::WorkerRound => "worker_round",
            SpanKind::PhaseExec => "phase_exec",
            SpanKind::FrontReply => "front_reply",
            SpanKind::MigrateFront => "migrate_front",
            SpanKind::MigrateReplay => "migrate_replay",
            SpanKind::FrontRetry => "front_retry",
            SpanKind::ShardRejoin => "shard_rejoin",
        }
    }

    /// Decode a wire/feed discriminant; `None` for unknown values.
    pub fn from_u8(v: u8) -> Option<SpanKind> {
        SpanKind::ALL.get(v.wrapping_sub(1) as usize).copied()
    }

    /// Parse a feed `span` field back into the kind.
    pub fn from_name(s: &str) -> Option<SpanKind> {
        SpanKind::ALL.iter().copied().find(|k| k.name() == s)
    }
}

/// The compact trace context a sampled frame carries across the wire:
/// which trace it belongs to, the span the *sender* just opened (the
/// receiver's parent), and that span's own parent (carried so either
/// end of a hop can be validated in isolation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceCtx {
    /// Trace id, unique per sampled frame (or migration); nonzero.
    pub trace_id: u64,
    /// Discriminant of the sender's span ([`SpanKind`]).
    pub kind: u8,
    /// Discriminant of the sender's span's parent (0 at the root).
    pub parent: u8,
}

impl TraceCtx {
    /// The root context of a new trace: the opener's span is `kind`,
    /// parented to nothing.
    pub fn root(trace_id: u64, kind: SpanKind) -> TraceCtx {
        TraceCtx {
            trace_id,
            kind: kind as u8,
            parent: 0,
        }
    }

    /// The context the *next* hop forwards after opening `kind` under
    /// this context's span.
    pub fn child(self, kind: SpanKind) -> TraceCtx {
        TraceCtx {
            trace_id: self.trace_id,
            kind: kind as u8,
            parent: self.kind,
        }
    }
}

/// Head-based sampler owned by the front-end router: every `n`th
/// frame opens a trace (`n == 0` disables sampling entirely — the
/// fast path is one integer compare, no state updates).
#[derive(Debug)]
pub struct TraceSampler {
    n: u64,
    seen: u64,
    next_id: u64,
}

impl TraceSampler {
    /// A sampler tracing every `n`th frame (0 = off).
    pub fn new(n: u64) -> TraceSampler {
        TraceSampler {
            n,
            seen: 0,
            next_id: 1,
        }
    }

    /// Whether sampling is enabled at all.
    pub fn enabled(&self) -> bool {
        self.n > 0
    }

    /// Account one frame; `Some(trace_id)` iff this frame is sampled.
    pub fn sample(&mut self) -> Option<u64> {
        if self.n == 0 {
            return None;
        }
        self.seen += 1;
        if self.seen % self.n != 0 {
            return None;
        }
        let id = self.next_id;
        self.next_id += 1;
        Some(id)
    }

    /// Unconditionally allocate a trace id (used for migrations: when
    /// sampling is enabled every migration is traced — they are rare
    /// and each one is exactly the event an operator wants linked).
    pub fn force(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_kind_names_and_discriminants_roundtrip() {
        for k in SpanKind::ALL {
            assert_eq!(SpanKind::from_u8(k as u8), Some(k));
            assert_eq!(SpanKind::from_name(k.name()), Some(k));
        }
        assert_eq!(SpanKind::from_u8(0), None);
        assert_eq!(SpanKind::from_u8(SpanKind::ALL.len() as u8 + 1), None);
        assert_eq!(SpanKind::from_name("nope"), None);
    }

    #[test]
    fn child_links_to_parent() {
        let root = TraceCtx::root(9, SpanKind::FrontAdmit);
        assert_eq!(root.parent, 0);
        let next = root.child(SpanKind::ShardDispatch);
        assert_eq!(next.trace_id, 9);
        assert_eq!(next.kind, SpanKind::ShardDispatch as u8);
        assert_eq!(next.parent, SpanKind::FrontAdmit as u8);
    }

    #[test]
    fn sampler_takes_every_nth_and_ids_are_unique() {
        let mut s = TraceSampler::new(3);
        let picks: Vec<Option<u64>> = (0..9).map(|_| s.sample()).collect();
        assert_eq!(
            picks,
            vec![None, None, Some(1), None, None, Some(2), None, None, Some(3)]
        );
        assert_eq!(s.force(), 4);
        let mut off = TraceSampler::new(0);
        assert!(!off.enabled());
        assert!((0..100).all(|_| off.sample().is_none()));
    }
}
