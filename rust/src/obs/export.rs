//! Snapshot assembly and the NDJSON health-feed exporter
//! (DESIGN.md §12; record schema in DESIGN.md appendix A).
//!
//! [`take_snapshot`] merges every worker's registry (counters summed,
//! gauges maxed, histograms merged — the merge is exact because all
//! workers share one bucket space) and drains the event rings.
//! [`Exporter`] runs two threads: a **sampler** that snapshots every
//! `snapshot_ms` and serializes to NDJSON, and a **writer** that owns
//! the file.  They are joined by a bounded channel; when the writer
//! falls behind (slow disk), the sampler **drops the whole snapshot and
//! counts it** (`feed_drops` in the next snapshot record) instead of
//! blocking — telemetry must never apply backpressure to serving.
//!
//! Histograms and counters in the feed are cumulative since process
//! start (each snapshot supersedes the last; a reader can join
//! mid-stream).  Events are incremental: each appears in exactly one
//! snapshot's drain.

use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::registry::{Counter, Gauge, ObsHandle};
use super::ring::{Event, EventKind};
use super::trace::SpanKind;
use super::Telemetry;
use crate::util::json::Json;
use crate::util::stats::Histogram;

/// Schema tag stamped on every health-feed record.
pub const FEED_SCHEMA: &str = "soi.obs.v1";

/// One merged view of the whole registry plus the interval's drained
/// events.
#[derive(Debug)]
pub struct Snapshot {
    /// Milliseconds since the telemetry epoch.
    pub t_ms: u64,
    /// Counters summed across workers (index order = [`Counter::ALL`]).
    pub counters: [u64; Counter::COUNT],
    /// Gauges maxed across workers (index order = [`Gauge::ALL`]).
    pub gauges: [u64; Gauge::COUNT],
    /// Per-(rung, phase) exec wall-time histograms, merged across
    /// workers, ascending key order.
    pub exec_ns: Vec<(usize, usize, Histogram)>,
    /// Dispatch-group widths, merged across workers.
    pub batch_width: Histogram,
    /// Events drained this interval: `(worker index, event)`; `None`
    /// marks the shared (global-hook) handle.
    pub events: Vec<(Option<usize>, Event)>,
    /// Ring-overflow drops observed in this drain (all rings).
    pub ring_dropped: u64,
}

fn fold(snap: &mut Snapshot, worker: Option<usize>, h: &ObsHandle) {
    h.with(|w| {
        for c in Counter::ALL {
            snap.counters[Counter::ALL.iter().position(|x| *x == c).unwrap()] += w.counter(c);
        }
        for (i, g) in Gauge::ALL.iter().enumerate() {
            snap.gauges[i] = snap.gauges[i].max(w.gauge(*g));
        }
        for (rung, phase, hist) in w.exec_hists() {
            match snap
                .exec_ns
                .iter_mut()
                .find(|(r, p, _)| *r == rung && *p == phase)
            {
                Some((_, _, m)) => m.merge(hist),
                None => snap.exec_ns.push((rung, phase, hist.clone())),
            }
        }
        snap.batch_width.merge(w.batch_width());
        let mut buf = Vec::new();
        snap.ring_dropped += w.drain_events(&mut buf);
        snap.events.extend(buf.into_iter().map(|ev| (worker, ev)));
    });
}

/// Merge every handle of `tel` into one [`Snapshot`], draining the
/// event rings.  Runs on the sampler thread — this allocates freely;
/// only *recording* is allocation-free.
pub fn take_snapshot(tel: &Telemetry) -> Snapshot {
    let mut snap = Snapshot {
        t_ms: u64::try_from(tel.epoch().elapsed().as_millis()).unwrap_or(u64::MAX),
        counters: [0; Counter::COUNT],
        gauges: [0; Gauge::COUNT],
        exec_ns: Vec::new(),
        batch_width: Histogram::new(),
        events: Vec::new(),
        ring_dropped: 0,
    };
    for (i, h) in tel.worker_handles().iter().enumerate() {
        fold(&mut snap, Some(i), h);
    }
    fold(&mut snap, None, &tel.shared());
    snap.exec_ns.sort_by_key(|(r, p, _)| (*r, *p));
    snap
}

fn num(v: u64) -> Json {
    Json::Num(v as f64)
}

/// Overwrite one gauge slot on a merged snapshot (exporter-owned
/// gauges: workers never set these, so the folded value is 0).
fn set_gauge(snap: &mut Snapshot, g: Gauge, v: u64) {
    snap.gauges[Gauge::ALL.iter().position(|x| *x == g).unwrap_or(0)] = v;
}

fn hist_record(
    seq: u64,
    t_ms: u64,
    name: &str,
    rung: Option<usize>,
    phase: Option<usize>,
    h: &Histogram,
) -> Json {
    let buckets: Vec<Json> = h
        .nonzero()
        .map(|(i, c)| Json::Arr(vec![num(i as u64), num(c)]))
        .collect();
    Json::obj(vec![
        ("schema", Json::Str(FEED_SCHEMA.into())),
        ("type", Json::Str("hist".into())),
        ("seq", num(seq)),
        ("t_ms", num(t_ms)),
        ("name", Json::Str(name.into())),
        ("rung", rung.map_or(Json::Null, |r| num(r as u64))),
        ("phase", phase.map_or(Json::Null, |p| num(p as u64))),
        ("count", num(h.count())),
        ("p50", num(h.p50())),
        ("p95", num(h.p95())),
        ("p99", num(h.p99())),
        ("mean", Json::Num(h.mean())),
        ("buckets", Json::Arr(buckets)),
    ])
}

fn trigger_name(code: u64) -> &'static str {
    match code {
        0 => "queue",
        1 => "latency",
        _ => "calm",
    }
}

fn event_record(seq: u64, worker: Option<usize>, ev: &Event) -> Json {
    let mut kv: Vec<(&str, Json)> = vec![
        ("schema", Json::Str(FEED_SCHEMA.into())),
        ("type", Json::Str("event".into())),
        ("seq", num(seq)),
        ("worker", worker.map_or(Json::Null, |w| num(w as u64))),
        ("t_us", num(ev.t_us)),
        ("kind", Json::Str(ev.kind.name().into())),
    ];
    match ev.kind {
        EventKind::Round => kv.extend([
            ("served", num(ev.a)),
            ("backlog", num(ev.b)),
            ("streams", num(ev.c)),
            ("ns", num(ev.d)),
        ]),
        EventKind::Exec => kv.extend([
            ("rung", num(ev.a)),
            ("phase", num(ev.b)),
            ("width", num(ev.c)),
            ("ns", num(ev.d)),
        ]),
        EventKind::FpPre => kv.extend([
            ("stream", num(ev.a)),
            ("phase", num(ev.b)),
            ("inline", Json::Bool(ev.c != 0)),
            ("ns", num(ev.d)),
        ]),
        EventKind::FpRest => kv.extend([
            ("phase", num(ev.a)),
            ("width", num(ev.b)),
            ("ns", num(ev.d)),
        ]),
        EventKind::Migration => kv.extend([
            ("stream", num(ev.a)),
            ("from_rung", num(ev.b)),
            ("to_rung", num(ev.c)),
            ("replay_frames", num(ev.d)),
            ("ns", num(ev.e)),
        ]),
        EventKind::QuantRepack => kv.extend([
            ("panels", num(ev.a)),
            ("bytes", num(ev.b)),
            ("ns", num(ev.d)),
        ]),
        EventKind::CtlDecision => kv.extend([
            ("from_rung", num(ev.a)),
            ("to_rung", num(ev.b)),
            ("trigger", Json::Str(trigger_name(ev.c).into())),
            ("backlog", num(ev.d)),
            ("p99_us", num(ev.e)),
        ]),
        EventKind::GenReload => kv.extend([
            ("from_gen", num(ev.a)),
            ("to_gen", num(ev.b)),
            ("streams", num(ev.c)),
            ("ns", num(ev.d)),
        ]),
        EventKind::ShardMigrate => kv.extend([
            ("session", num(ev.a)),
            ("t", num(ev.b)),
            ("replay_frames", num(ev.c)),
            ("ns", num(ev.d)),
        ]),
        EventKind::Span => {
            let kind = SpanKind::from_u8((ev.b >> 8) as u8);
            kv.push(("trace_id", num(ev.a)));
            kv.push((
                "span",
                kind.map_or_else(|| num(ev.b >> 8), |k| Json::Str(k.name().into())),
            ));
            kv.push((
                "parent",
                SpanKind::from_u8((ev.b & 0xFF) as u8)
                    .map_or(Json::Null, |p| Json::Str(p.name().into())),
            ));
            // `frame_seq` not `seq`: the record head already carries
            // the snapshot seq
            match kind {
                Some(SpanKind::FrontAdmit) => kv.extend([
                    ("session", num(ev.c)),
                    ("frame_seq", num(ev.d)),
                    ("shard", num(ev.e)),
                ]),
                Some(SpanKind::ShardDispatch | SpanKind::FrontReply) => {
                    kv.extend([("session", num(ev.c)), ("frame_seq", num(ev.d))]);
                }
                Some(SpanKind::WorkerRound) => kv.extend([
                    ("session", num(ev.c)),
                    ("width", num(ev.d)),
                    ("ns", num(ev.e)),
                ]),
                Some(SpanKind::PhaseExec) => kv.extend([
                    ("rung", num(ev.c >> 16)),
                    ("phase", num(ev.c & 0xFFFF)),
                    ("width", num(ev.d)),
                    ("ns", num(ev.e)),
                ]),
                Some(SpanKind::MigrateFront) => kv.extend([
                    ("session", num(ev.c)),
                    ("from_shard", num(ev.d)),
                    ("to_shard", num(ev.e)),
                ]),
                Some(SpanKind::MigrateReplay) => kv.extend([
                    ("stream", num(ev.c)),
                    ("t", num(ev.d)),
                    ("ns", num(ev.e)),
                ]),
                Some(SpanKind::FrontRetry) => kv.extend([
                    ("session", num(ev.c)),
                    ("resent", num(ev.d)),
                    ("shard", num(ev.e)),
                ]),
                Some(SpanKind::ShardRejoin) => kv.extend([
                    ("shard", num(ev.c)),
                    ("attempts", num(ev.d)),
                ]),
                None => {}
            }
        }
    }
    Json::obj(kv)
}

impl Snapshot {
    /// Serialize this snapshot as NDJSON into `out`: one `snapshot`
    /// record, one `hist` record per non-empty histogram, one `event`
    /// record per drained event — all stamped with `seq` and the
    /// `soi.obs.v1` schema tag.  `feed_drops` is the exporter's
    /// cumulative count of snapshots dropped on writer backpressure.
    pub fn render_ndjson(&self, seq: u64, feed_drops: u64, out: &mut String) {
        let counters = Json::Obj(
            Counter::ALL
                .iter()
                .enumerate()
                .map(|(i, c)| (c.name().to_string(), num(self.counters[i])))
                .collect(),
        );
        let gauges = Json::Obj(
            Gauge::ALL
                .iter()
                .enumerate()
                .map(|(i, g)| (g.name().to_string(), num(self.gauges[i])))
                .collect(),
        );
        let head = Json::obj(vec![
            ("schema", Json::Str(FEED_SCHEMA.into())),
            ("type", Json::Str("snapshot".into())),
            ("seq", num(seq)),
            ("t_ms", num(self.t_ms)),
            ("counters", counters),
            ("gauges", gauges),
            ("ring_dropped", num(self.ring_dropped)),
            ("feed_drops", num(feed_drops)),
        ]);
        out.push_str(&head.to_string());
        out.push('\n');
        for (rung, phase, h) in &self.exec_ns {
            if h.count() > 0 {
                out.push_str(
                    &hist_record(seq, self.t_ms, "exec_ns", Some(*rung), Some(*phase), h)
                        .to_string(),
                );
                out.push('\n');
            }
        }
        if self.batch_width.count() > 0 {
            out.push_str(
                &hist_record(seq, self.t_ms, "batch_width", None, None, &self.batch_width)
                    .to_string(),
            );
            out.push('\n');
        }
        for (worker, ev) in &self.events {
            out.push_str(&event_record(seq, *worker, ev).to_string());
            out.push('\n');
        }
    }
}

/// Final accounting returned by [`Exporter::finish`].
#[derive(Debug, Clone, Copy)]
pub struct FeedStats {
    /// Snapshots taken (including dropped ones).
    pub snapshots: u64,
    /// NDJSON lines written to the feed.
    pub lines: u64,
    /// Bytes written to the feed.
    pub bytes: u64,
    /// Snapshots dropped because the writer was behind.
    pub drops: u64,
}

/// The periodic feed exporter: sampler thread + writer thread + the
/// bounded channel between them.  Construct with [`Exporter::start`],
/// stop with [`Exporter::finish`] (which emits one final snapshot so
/// short runs still produce a feed).  Dropping without `finish` shuts
/// both threads down but discards the stats.
#[derive(Debug)]
pub struct Exporter {
    stop: Arc<AtomicBool>,
    drops: Arc<AtomicU64>,
    snapshots: Arc<AtomicU64>,
    sampler: Option<JoinHandle<()>>,
    writer: Option<JoinHandle<std::io::Result<(u64, u64)>>>,
    path: PathBuf,
}

/// Bounded channel depth between sampler and writer (whole snapshot
/// batches; beyond this the sampler drops).
const FEED_QUEUE: usize = 8;

impl Exporter {
    /// Start exporting `tel` to the NDJSON file at `path` every
    /// `snapshot_ms` milliseconds (clamped to ≥ 1).  The file is
    /// created (truncated) eagerly so a bad path fails here, not on a
    /// background thread.
    pub fn start(tel: Arc<Telemetry>, path: &Path, snapshot_ms: u64) -> std::io::Result<Exporter> {
        let file = std::fs::File::create(path)?;
        let stop = Arc::new(AtomicBool::new(false));
        let drops = Arc::new(AtomicU64::new(0));
        let snapshots = Arc::new(AtomicU64::new(0));
        let (tx, rx) = sync_channel::<String>(FEED_QUEUE);

        let writer = std::thread::spawn(move || -> std::io::Result<(u64, u64)> {
            let mut w = std::io::BufWriter::new(file);
            let (mut lines, mut bytes) = (0u64, 0u64);
            for batch in rx {
                w.write_all(batch.as_bytes())?;
                // flush per batch: the feed is a *live* health surface
                w.flush()?;
                lines += batch.bytes().filter(|b| *b == b'\n').count() as u64;
                bytes += batch.len() as u64;
            }
            Ok((lines, bytes))
        });

        let interval = Duration::from_millis(snapshot_ms.max(1));
        let (stop2, drops2, snaps2) = (stop.clone(), drops.clone(), snapshots.clone());
        let sampler = std::thread::spawn(move || {
            let mut seq = 0u64;
            // cumulative ring-overflow drops across drains: each
            // snapshot's `ring_dropped` covers one interval only
            let mut events_dropped = 0u64;
            loop {
                // sleep in short steps so finish() returns promptly
                let mut slept = Duration::ZERO;
                while slept < interval && !stop2.load(Ordering::Relaxed) {
                    let step = Duration::from_millis(2).min(interval - slept);
                    std::thread::sleep(step);
                    slept += step;
                }
                let stopping = stop2.load(Ordering::Relaxed);
                let mut snap = take_snapshot(&tel);
                // self-observability (DESIGN.md §15): the exporter's own
                // loss shows up as first-class gauges, so a merged feed
                // can attribute drops per shard without side channels
                events_dropped += snap.ring_dropped;
                set_gauge(&mut snap, Gauge::ObsDroppedEvents, events_dropped);
                set_gauge(
                    &mut snap,
                    Gauge::ObsDroppedSnapshots,
                    drops2.load(Ordering::Relaxed),
                );
                let mut text = String::new();
                snap.render_ndjson(seq, drops2.load(Ordering::Relaxed), &mut text);
                seq += 1;
                snaps2.fetch_add(1, Ordering::Relaxed);
                if stopping {
                    // final snapshot: block until the writer takes it
                    let _ = tx.send(text);
                    break;
                }
                if let Err(TrySendError::Full(_)) = tx.try_send(text) {
                    drops2.fetch_add(1, Ordering::Relaxed);
                }
            }
            // tx drops here; the writer loop ends
        });

        Ok(Exporter {
            stop,
            drops,
            snapshots,
            sampler: Some(sampler),
            writer: Some(writer),
            path: path.to_path_buf(),
        })
    }

    /// The feed file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Stop both threads (emitting one final snapshot) and return the
    /// feed accounting.
    pub fn finish(mut self) -> std::io::Result<FeedStats> {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(s) = self.sampler.take() {
            let _ = s.join();
        }
        let (lines, bytes) = match self.writer.take() {
            Some(w) => w
                .join()
                .unwrap_or_else(|_| Err(std::io::Error::other("feed writer panicked")))?,
            None => (0, 0),
        };
        Ok(FeedStats {
            snapshots: self.snapshots.load(Ordering::Relaxed),
            lines,
            bytes,
            drops: self.drops.load(Ordering::Relaxed),
        })
    }
}

impl Drop for Exporter {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(s) = self.sampler.take() {
            let _ = s.join();
        }
        if let Some(w) = self.writer.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{ObsConfig, Telemetry};
    use crate::util::json;

    #[test]
    fn snapshot_merges_workers_and_renders_valid_ndjson() {
        let tel = Telemetry::new(ObsConfig {
            ring_capacity: 32,
        });
        let (a, b) = (tel.worker(0), tel.worker(1));
        a.exec(0, 1, 4, 1000);
        b.exec(0, 1, 2, 3000);
        b.exec(1, 0, 1, 500);
        a.with(|w| w.gauge_set(super::Gauge::QueueDepth, 3));
        b.with(|w| w.gauge_set(super::Gauge::QueueDepth, 9));
        b.migration(7, 0, 1, 16, 2000);
        let snap = take_snapshot(&tel);
        // counters summed
        let frames_i = Counter::ALL
            .iter()
            .position(|c| *c == Counter::Frames)
            .unwrap();
        assert_eq!(snap.counters[frames_i], 7);
        // gauges maxed
        let qd_i = Gauge::ALL
            .iter()
            .position(|g| *g == Gauge::QueueDepth)
            .unwrap();
        assert_eq!(snap.gauges[qd_i], 9);
        // (0,1) merged across workers
        let h01 = snap
            .exec_ns
            .iter()
            .find(|(r, p, _)| (*r, *p) == (0, 1))
            .map(|(_, _, h)| h)
            .unwrap();
        assert_eq!(h01.count(), 2);
        assert_eq!(snap.events.len(), 4);
        let mut out = String::new();
        snap.render_ndjson(0, 0, &mut out);
        for line in out.lines() {
            let v = json::parse(line).expect("every feed line parses");
            assert_eq!(v.get("schema").and_then(|s| s.as_str()), Some(FEED_SCHEMA));
        }
        // draining is destructive: a second snapshot has no events but
        // keeps the cumulative histograms
        let again = take_snapshot(&tel);
        assert!(again.events.is_empty());
        assert_eq!(again.counters[frames_i], 7);
    }

    #[test]
    fn exporter_writes_a_final_snapshot_even_for_instant_runs() {
        let tel = Telemetry::new(ObsConfig::default());
        tel.worker(0).exec(0, 0, 1, 777);
        let path = std::env::temp_dir().join(format!(
            "soi_obs_export_test_{}.ndjson",
            std::process::id()
        ));
        let ex = Exporter::start(tel, &path, 10_000).unwrap();
        let stats = ex.finish().unwrap();
        assert!(stats.snapshots >= 1);
        assert!(stats.lines >= 1);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.lines().count() as u64 == stats.lines);
        let first = json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(first.get("type").and_then(|t| t.as_str()), Some("snapshot"));
        // exporter self-observability rides in the ordinary gauges
        let gauges = first.get("gauges").expect("gauges object");
        for g in ["obs_dropped_snapshots", "obs_dropped_events"] {
            assert!(
                gauges.get(g).and_then(|v| v.as_f64()).is_some(),
                "gauge '{g}' missing from rendered snapshot"
            );
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn span_events_render_named_trace_fields() {
        use crate::obs::trace::SpanKind;
        let tel = Telemetry::new(ObsConfig::default());
        let h = tel.worker(0);
        h.span(
            41,
            SpanKind::PhaseExec,
            SpanKind::WorkerRound as u8,
            (2 << 16) | 3,
            5,
            12_000,
        );
        h.span(41, SpanKind::FrontAdmit, 0, 9, 4, 1);
        let snap = take_snapshot(&tel);
        let mut out = String::new();
        snap.render_ndjson(0, 0, &mut out);
        let exec_line = out
            .lines()
            .find(|l| l.contains("phase_exec"))
            .expect("phase_exec span rendered");
        let v = json::parse(exec_line).unwrap();
        assert_eq!(v.get("kind").and_then(|s| s.as_str()), Some("span"));
        assert_eq!(v.get("trace_id").and_then(|n| n.as_f64()), Some(41.0));
        assert_eq!(v.get("parent").and_then(|s| s.as_str()), Some("worker_round"));
        assert_eq!(v.get("rung").and_then(|n| n.as_f64()), Some(2.0));
        assert_eq!(v.get("phase").and_then(|n| n.as_f64()), Some(3.0));
        assert_eq!(v.get("ns").and_then(|n| n.as_f64()), Some(12_000.0));
        let root_line = out
            .lines()
            .find(|l| l.contains("front_admit"))
            .expect("front_admit span rendered");
        let r = json::parse(root_line).unwrap();
        assert!(r.get("parent").map(|p| p.is_null()).unwrap_or(false));
        assert_eq!(r.get("frame_seq").and_then(|n| n.as_f64()), Some(4.0));
    }
}
