//! Per-stream session: owns the partial-state cache, follows the SOI
//! schedule, tracks metrics, and (for FP variants) runs the precompute
//! pass in the idle gap between frames.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use super::metrics::StreamMetrics;
use super::scheduler::{Scheduler, StepPlan};
use crate::runtime::{CompiledVariant, DeviceWeights, StateSet};

/// MACs executed by `step_p<phase>` (layers whose rate domain ticks).
pub fn macs_at_phase(manifest: &crate::runtime::Manifest, phase: usize) -> f64 {
    manifest
        .layer_macs
        .iter()
        .filter(|l| phase as u64 % l.rate_div == 0)
        .map(|l| l.macs as f64)
        .sum()
}

/// MACs of one pure-STMC inference (every layer fires).
pub fn macs_stmc(manifest: &crate::runtime::Manifest) -> f64 {
    manifest.layer_macs.iter().map(|l| l.macs as f64).sum()
}

/// A live stream being served by one SOI variant.
pub struct StreamSession {
    /// Caller-chosen stream identifier.
    pub id: u64,
    engine: Arc<CompiledVariant>,
    weights: Arc<DeviceWeights>,
    states: StateSet,
    scheduler: Scheduler,
    /// Per-stream serving metrics.
    pub metrics: StreamMetrics,
    /// FP: has the precompute pass already run for the upcoming inference?
    precomputed: bool,
}

impl StreamSession {
    /// A fresh session (zeroed states, schedule at t = 0) over a shared
    /// compiled variant and its prepared weights.
    pub fn new(id: u64, engine: Arc<CompiledVariant>, weights: Arc<DeviceWeights>) -> Self {
        let period = engine.manifest.period;
        // Ask the backend, not the manifest: the executor knows whether it
        // can actually run the pre/rest split for this variant.
        let fp = engine.has_fp_split();
        let states = engine.init_states();
        StreamSession {
            id,
            engine,
            weights,
            states,
            scheduler: Scheduler::new(period, fp),
            metrics: StreamMetrics::new(),
            precomputed: false,
        }
    }

    /// Idle-time work: for FP variants, run the precompute pass for the
    /// *next* inference if it has not run yet.  Call whenever the stream
    /// is waiting for data.  Returns true if work was done.
    pub fn idle(&mut self) -> Result<bool> {
        if !self.scheduler.can_precompute() || self.precomputed {
            return Ok(false);
        }
        let plan = self.scheduler.peek();
        let start = Instant::now();
        self.engine
            .precompute(plan.phase, &mut self.states, &self.weights)?;
        self.metrics.record_precompute(start);
        self.precomputed = true;
        Ok(true)
    }

    /// A frame arrived: run the on-arrival work and return the output.
    ///
    /// For FP variants this is only the `rest` pass when `idle()` got to
    /// run beforehand (the serving loop guarantees it between frames); if
    /// the frame arrived before any idle time, the precompute runs inline
    /// first (counted in arrival latency — exactly the behaviour the paper
    /// describes for back-to-back arrivals).
    pub fn on_frame(&mut self, frame: &[f32]) -> Result<Vec<f32>> {
        let plan = self.scheduler.next();
        let start = Instant::now();
        let out = if plan.split {
            if !self.precomputed {
                self.engine
                    .precompute(plan.phase, &mut self.states, &self.weights)?;
            }
            self.precomputed = false;
            self.engine
                .step_rest(plan.phase, frame, &mut self.states, &self.weights)?
        } else {
            self.engine
                .step(plan.phase, frame, &mut self.states, &self.weights)?
        };
        self.metrics.record_arrival(start);
        self.metrics.record_frame(
            macs_at_phase(&self.engine.manifest, plan.phase),
            macs_stmc(&self.engine.manifest),
        );
        Ok(out)
    }

    /// The plan the next frame will execute (does not advance the
    /// schedule).  The server's worker loop uses this to group sessions
    /// into phase-aligned batches.
    pub fn next_plan(&self) -> StepPlan {
        self.scheduler.peek()
    }

    /// Serve one frame to each session of a phase-aligned group through
    /// the backend's batched execution path (DESIGN.md §8).
    ///
    /// Every session must sit at the same schedule position (the worker's
    /// phase grouping guarantees this; mismatches are an error) and share
    /// one compiled engine.  Outputs and state updates are bit-identical
    /// to calling [`StreamSession::on_frame`] once per session on the
    /// native backend; metrics additionally record the batch width.
    ///
    /// FP variants: sessions whose idle-time `precompute` has not run yet
    /// get it inline first (counted in arrival latency, exactly like the
    /// per-session path), then the whole group runs one batched rest pass.
    pub fn on_frame_batch(
        sessions: &mut [&mut StreamSession],
        frames: &[&[f32]],
    ) -> Result<Vec<Vec<f32>>> {
        let Some(first) = sessions.first() else {
            return Ok(Vec::new());
        };
        if sessions.len() != frames.len() {
            bail!(
                "on_frame_batch: {} sessions but {} frames",
                sessions.len(),
                frames.len()
            );
        }
        let plan = first.scheduler.peek();
        let engine = first.engine.clone();
        let weights = first.weights.clone();
        for sess in sessions.iter() {
            if !Arc::ptr_eq(&sess.engine, &engine) || !Arc::ptr_eq(&sess.weights, &weights) {
                bail!(
                    "on_frame_batch: stream {} serves a different compiled variant or weights",
                    sess.id
                );
            }
            let p = sess.scheduler.peek();
            if p != plan {
                bail!(
                    "on_frame_batch: stream {} at phase {} grouped with phase {}",
                    sess.id,
                    p.phase,
                    plan.phase
                );
            }
        }
        let bsz = sessions.len();
        let start = Instant::now();
        if plan.split {
            for sess in sessions.iter_mut() {
                if !sess.precomputed {
                    engine.precompute(plan.phase, &mut sess.states, &sess.weights)?;
                }
            }
        }
        let outs = {
            let mut states: Vec<&mut StateSet> =
                sessions.iter_mut().map(|s| &mut s.states).collect();
            if plan.split {
                engine.step_rest_batch(plan.phase, frames, &mut states, &weights)?
            } else {
                engine.step_batch(plan.phase, frames, &mut states, &weights)?
            }
        };
        let phase_macs = macs_at_phase(&engine.manifest, plan.phase);
        let stmc = macs_stmc(&engine.manifest);
        for sess in sessions.iter_mut() {
            sess.scheduler.next();
            sess.precomputed = false;
            sess.metrics.record_arrival(start);
            sess.metrics.record_frame(phase_macs, stmc);
            sess.metrics.record_batch(bsz as u64, phase_macs);
        }
        Ok(outs)
    }

    /// Frames consumed so far.
    pub fn frames_seen(&self) -> u64 {
        self.scheduler.t()
    }

    /// Reset stream state (e.g. utterance boundary).
    pub fn reset(&mut self) {
        self.states = self.engine.init_states();
        self.scheduler.reset();
        self.precomputed = false;
    }

    /// Peak partial-state memory for this stream, bytes.
    pub fn state_bytes(&self) -> usize {
        self.states.tensors.iter().map(|t| t.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{LayerMacs, Manifest, ModelConfig};
    use std::collections::BTreeMap;
    use std::path::PathBuf;

    fn manifest(period: usize) -> Manifest {
        Manifest {
            name: "t".into(),
            config: ModelConfig {
                feat: 4,
                channels: vec![4],
                kernel: 3,
                scc: vec![],
                shift_pos: None,
                shift: 1,
                extrap: vec![],
                interp: None,
            },
            period,
            streamable: true,
            offline_t: 16,
            packed_states: 0,
            states: vec![],
            params: vec![],
            executables: BTreeMap::new(),
            layer_macs: vec![
                LayerMacs {
                    name: "a".into(),
                    macs: 100,
                    rate_div: 1,
                },
                LayerMacs {
                    name: "b".into(),
                    macs: 300,
                    rate_div: 2,
                },
            ],
            macs_per_frame: 250.0,
            precomputed_fraction: 0.0,
            param_count: 0,
            state_bytes: 0,
            train_metrics: BTreeMap::new(),
            dir: PathBuf::from("/nonexistent"),
        }
    }

    #[test]
    fn phase_macs() {
        let m = manifest(2);
        assert_eq!(macs_at_phase(&m, 0), 400.0); // both layers fire
        assert_eq!(macs_at_phase(&m, 1), 100.0); // only rate-1 layer
        assert_eq!(macs_stmc(&m), 400.0);
    }

    #[test]
    fn average_over_period_matches_manifest() {
        let m = manifest(2);
        let avg = (macs_at_phase(&m, 0) + macs_at_phase(&m, 1)) / 2.0;
        assert_eq!(avg, m.macs_per_frame);
    }

    #[test]
    fn period4_phase_pattern() {
        // Hand-built period-4 manifest (2 x S-CC): rate divisors 1/2/4.
        let mut m = manifest(4);
        m.layer_macs.push(LayerMacs {
            name: "c".into(),
            macs: 800,
            rate_div: 4,
        });
        assert_eq!(macs_at_phase(&m, 0), 1200.0); // all fire
        assert_eq!(macs_at_phase(&m, 1), 100.0); // rate-1 only
        assert_eq!(macs_at_phase(&m, 2), 400.0); // rate-1 + rate-2
        assert_eq!(macs_at_phase(&m, 3), 100.0);
        assert_eq!(macs_stmc(&m), 1200.0);
        // phases repeat with the period
        for p in 0..4 {
            assert_eq!(macs_at_phase(&m, p), macs_at_phase(&m, p + 4));
        }
    }
}
