//! Per-stream session: owns the partial-state cache, follows the SOI
//! schedule, tracks metrics, (for FP variants) runs the precompute pass
//! in the idle gap between frames, and — when serving from a variant
//! ladder — migrates to another compiled variant at a phase-0 cycle
//! boundary with warm state re-priming (DESIGN.md §9).

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use super::metrics::StreamMetrics;
use super::scheduler::{Scheduler, StepPlan};
use crate::obs::ObsHandle;
use crate::runtime::ladder::warmup_frames;
use crate::runtime::{CompiledVariant, DeviceWeights, Dtype, StateSet};

/// MACs executed by `step_p<phase>` (layers whose rate domain ticks).
pub fn macs_at_phase(manifest: &crate::runtime::Manifest, phase: usize) -> f64 {
    manifest
        .layer_macs
        .iter()
        .filter(|l| phase as u64 % l.rate_div == 0)
        .map(|l| l.macs as f64)
        .sum()
}

/// MACs of one pure-STMC inference (every layer fires).
pub fn macs_stmc(manifest: &crate::runtime::Manifest) -> f64 {
    manifest.layer_macs.iter().map(|l| l.macs as f64).sum()
}

/// A live stream being served by one SOI variant.
pub struct StreamSession {
    /// Caller-chosen stream identifier.
    pub id: u64,
    engine: Arc<CompiledVariant>,
    weights: Arc<DeviceWeights>,
    states: StateSet,
    scheduler: Scheduler,
    /// Per-stream serving metrics.
    pub metrics: StreamMetrics,
    /// FP: has the precompute pass already run for the upcoming inference?
    precomputed: bool,
    /// Recent input frames, oldest first — the receptive-field history
    /// a warm migration replays (empty while `history_cap` is 0).
    history: VecDeque<Vec<f32>>,
    /// Frames of history to retain (0 disables retention; the adaptive
    /// server sets it to the ladder's `max_warmup`).
    history_cap: usize,
    /// Variant requested by [`StreamSession::request_switch`], applied
    /// at the next phase-0 boundary of *its* schedule.
    pending_switch: Option<Arc<CompiledVariant>>,
    /// Replacement weight upload accompanying a cross-generation switch
    /// ([`StreamSession::request_switch_with_weights`], DESIGN.md §13);
    /// `None` for ordinary same-weights rung migrations.
    pending_weights: Option<Arc<DeviceWeights>>,
    /// Telemetry recorder (the owning worker's [`ObsHandle`]); when set,
    /// FP pre/rest passes are recorded as spans.  Recording writes into
    /// preallocated slots — the steady state stays allocation-free.
    obs: Option<ObsHandle>,
}

impl StreamSession {
    /// A fresh session (zeroed states, schedule at t = 0) over a shared
    /// compiled variant and its prepared weights.
    pub fn new(id: u64, engine: Arc<CompiledVariant>, weights: Arc<DeviceWeights>) -> Self {
        let period = engine.manifest.period;
        // Ask the backend, not the manifest: the executor knows whether it
        // can actually run the pre/rest split for this variant.
        let fp = engine.has_fp_split();
        let states = engine.init_states();
        StreamSession {
            id,
            engine,
            weights,
            states,
            scheduler: Scheduler::new(period, fp),
            metrics: StreamMetrics::new(),
            precomputed: false,
            history: VecDeque::new(),
            history_cap: 0,
            pending_switch: None,
            pending_weights: None,
            obs: None,
        }
    }

    /// Reconstruct a session mid-stream from replayed history — the
    /// cross-process face of warm migration (DESIGN.md §9, §14).
    ///
    /// A shard receiving a `Migrate` message builds the session here:
    /// `t` is the absolute frame counter the stream resumes at and
    /// `history` its most recent input frames, oldest first.  The
    /// frames replay through `engine` from zeroed states at the
    /// stream's *absolute* phases (`(t - h + i) % period`), so the
    /// re-primed states — and every subsequent output — are
    /// bit-identical to a session that served the whole stream here.
    /// Same-variant resume is valid at **any** `t`: phases are
    /// absolute, so no phase-0 boundary is required (only
    /// cross-variant switches need one; see
    /// [`StreamSession::try_switch`]).
    ///
    /// Fails — constructing nothing — unless `history` is the
    /// stream's full past (`h == t`) or at least the variant's
    /// [`warmup_frames`].  The replayed frames are retained as the
    /// new session's history, so the stream can move again later.
    ///
    /// Tracing (DESIGN.md §15): when the carrying `Migrate` was
    /// sampled, the worker records the `migrate_replay` leaf span
    /// *after* this constructor succeeds — a rejected resume
    /// constructs nothing and therefore traces nothing.
    pub fn resume(
        id: u64,
        engine: Arc<CompiledVariant>,
        weights: Arc<DeviceWeights>,
        t: u64,
        history: Vec<Vec<f32>>,
    ) -> Result<Self> {
        let h = history.len() as u64;
        let warm = warmup_frames(&engine.manifest.config) as u64;
        if h > t {
            bail!(
                "stream {id}: resume carries {h} history frames for a stream at t = {t}"
            );
        }
        if h < t && h < warm {
            bail!(
                "stream {id}: {h} history frames cannot re-prime '{}' at t = {t} \
                 (needs the full history or at least {warm} frames)",
                engine.manifest.name
            );
        }
        let period = engine.manifest.period as u64;
        let mut states = engine.init_states();
        let t0 = t - h;
        let mut replay_macs = 0.0;
        for (i, frame) in history.iter().enumerate() {
            let phase = ((t0 + i as u64) % period) as usize;
            engine.step(phase, frame, &mut states, &weights)?;
            replay_macs += macs_at_phase(&engine.manifest, phase);
        }
        let mut metrics = StreamMetrics::new();
        if t > 0 {
            metrics.record_migration(replay_macs);
            if engine.manifest.dtype == Dtype::Int8 {
                metrics.record_macs_int8(replay_macs);
            }
        }
        let fp = engine.has_fp_split();
        let history_cap = history.len();
        let scheduler = Scheduler::new_at(engine.manifest.period, fp, t);
        Ok(StreamSession {
            id,
            engine,
            weights,
            states,
            scheduler,
            metrics,
            precomputed: false,
            history: history.into(),
            history_cap,
            pending_switch: None,
            pending_weights: None,
            obs: None,
        })
    }

    /// The retained receptive-field history, oldest first (what a warm
    /// migration of this session would replay).
    pub fn history_frames(&self) -> impl Iterator<Item = &[f32]> {
        self.history.iter().map(Vec::as_slice)
    }

    /// Attach (or detach) a telemetry recorder.  The serving worker
    /// passes its own [`ObsHandle`] when the server runs with
    /// `--telemetry`, so the session's FP pre/rest spans land in that
    /// worker's ring.
    pub fn set_obs(&mut self, obs: Option<ObsHandle>) {
        self.obs = obs;
    }

    /// Frames of receptive-field history currently retained (the exact
    /// count a warm migration would replay).
    pub fn history_len(&self) -> usize {
        self.history.len()
    }

    /// Retain up to `cap` recent input frames for warm migration
    /// (DESIGN.md §9).  0 (the default) disables retention; an adaptive
    /// server sets the ladder's [`crate::runtime::VariantLadder::max_warmup`]
    /// so the session can be re-primed bit-exactly on any rung.
    pub fn set_history_cap(&mut self, cap: usize) {
        self.history_cap = cap;
        while self.history.len() > cap {
            self.history.pop_front();
        }
    }

    /// Current history-retention cap, frames.
    pub fn history_cap(&self) -> usize {
        self.history_cap
    }

    /// The variant this session currently serves.
    pub fn variant_name(&self) -> &str {
        &self.engine.manifest.name
    }

    /// Execution precision of the variant this session currently serves
    /// (changes when a migration crosses precisions, DESIGN.md §10).
    pub fn dtype(&self) -> Dtype {
        self.engine.manifest.dtype
    }

    /// The compiled variant this session currently serves.
    pub fn engine(&self) -> &Arc<CompiledVariant> {
        &self.engine
    }

    fn record_history(&mut self, frame: &[f32]) {
        if self.history_cap == 0 {
            return;
        }
        if self.history.len() == self.history_cap {
            // recycle the evicted buffer — steady state allocates nothing
            let mut buf = self.history.pop_front().unwrap();
            buf.clear();
            buf.extend_from_slice(frame);
            self.history.push_back(buf);
        } else {
            self.history.push_back(frame.to_vec());
        }
    }

    /// Ask the session to move to `target` at its next phase-0 cycle
    /// boundary (see [`StreamSession::try_switch`]).  Requesting the
    /// currently served variant cancels any pending switch.
    pub fn request_switch(&mut self, target: Arc<CompiledVariant>) {
        if Arc::ptr_eq(&target, &self.engine) {
            self.pending_switch = None;
        } else {
            self.pending_switch = Some(target);
        }
        self.pending_weights = None;
    }

    /// Ask the session to move to `target` executing `weights` at its
    /// next phase-0 boundary — the cross-**generation** variant of
    /// [`StreamSession::request_switch`] (DESIGN.md §13).  Unlike a rung
    /// switch this never self-cancels: a new generation's rung is a
    /// different compiled variant (and weight upload) even when its name
    /// matches the currently served one.  On migration the retained
    /// history replays through `target` *with the new weights*, so the
    /// re-primed states — and all subsequent output — are bit-identical
    /// to a session that served the whole stream on the new generation.
    pub fn request_switch_with_weights(
        &mut self,
        target: Arc<CompiledVariant>,
        weights: Arc<DeviceWeights>,
    ) {
        self.pending_switch = Some(target);
        self.pending_weights = Some(weights);
    }

    /// Whether a requested switch is still waiting for its boundary.
    pub fn switch_pending(&self) -> bool {
        self.pending_switch.is_some()
    }

    /// Apply a pending switch if the stream sits at a phase-0 boundary
    /// of the target's schedule (`t % period == 0` — the next inference
    /// would be the target's full update).  Returns whether the
    /// migration happened.  Call between frames; the worker loop does
    /// this once per round before phase grouping.
    pub fn try_switch(&mut self) -> Result<bool> {
        let Some(target) = self.pending_switch.clone() else {
            return Ok(false);
        };
        if self.scheduler.t() % target.manifest.period as u64 != 0 {
            return Ok(false);
        }
        let weights = self.pending_weights.clone();
        self.migrate(&target, weights.as_ref())?;
        Ok(true)
    }

    /// Migrate to `target` now, with warm state re-priming.  The stream
    /// must sit at a phase-0 boundary of the target's schedule; use
    /// [`StreamSession::request_switch`] + [`StreamSession::try_switch`]
    /// to defer to the next boundary instead of failing.
    ///
    /// Re-priming replays the retained receptive-field history through
    /// the target executable (fresh states, full-update inferences at
    /// the stream's absolute phases, outputs discarded).  Because every
    /// partial state is a function of at most
    /// [`warmup_frames`]`(target)` recent inputs, the resulting states —
    /// and therefore all subsequent outputs — are bit-identical to a
    /// session that served the stream's entire life on the target
    /// (`rust/tests/adaptive_serving.rs`).  Costs
    /// `history · macs_per_frame(target)` MACs, recorded via
    /// [`StreamMetrics::record_migration`].
    ///
    /// Fails when the retained history is neither the stream's full
    /// past nor at least the target's warmup — re-priming from less
    /// would glitch the output, which migration exists to prevent.
    pub fn migrate_to(&mut self, target: &Arc<CompiledVariant>) -> Result<()> {
        if self.scheduler.t() % target.manifest.period as u64 != 0 {
            bail!(
                "stream {}: cannot migrate to '{}' at t = {} — not a phase-0 \
                 boundary of its period {}",
                self.id,
                target.manifest.name,
                self.scheduler.t(),
                target.manifest.period
            );
        }
        self.migrate(target, None)
    }

    /// `weights` selects the upload the replay executes against (and the
    /// session keeps afterwards): `None` re-primes on the current
    /// weights (rung migration), `Some` on a new generation's upload
    /// (hot reload).
    fn migrate(
        &mut self,
        target: &Arc<CompiledVariant>,
        weights: Option<&Arc<DeviceWeights>>,
    ) -> Result<()> {
        let t = self.scheduler.t();
        let h = self.history.len() as u64;
        let warm = warmup_frames(&target.manifest.config) as u64;
        if h < t && h < warm {
            bail!(
                "stream {}: {} retained frames cannot re-prime '{}' (needs the \
                 full history or at least {} frames — raise the history cap)",
                self.id,
                h,
                target.manifest.name,
                warm
            );
        }
        let period = target.manifest.period as u64;
        let weights = weights.unwrap_or(&self.weights).clone();
        let mut states = target.init_states();
        let t0 = t - h;
        let mut replay_macs = 0.0;
        for (i, frame) in self.history.iter().enumerate() {
            let phase = ((t0 + i as u64) % period) as usize;
            target.step(phase, frame, &mut states, &weights)?;
            replay_macs += macs_at_phase(&target.manifest, phase);
        }
        if t > 0 {
            // t == 0 is initial placement (nothing to re-prime), not a
            // migration — don't count it
            self.metrics.record_migration(replay_macs);
            if target.manifest.dtype == Dtype::Int8 {
                // the replay ran on the target's quantized path
                self.metrics.record_macs_int8(replay_macs);
            }
        }
        self.engine = target.clone();
        self.weights = weights;
        self.states = states;
        self.scheduler = Scheduler::new_at(target.manifest.period, target.has_fp_split(), t);
        self.precomputed = false;
        self.pending_switch = None;
        self.pending_weights = None;
        Ok(())
    }

    /// Idle-time work: for FP variants, run the precompute pass for the
    /// *next* inference if it has not run yet.  Call whenever the stream
    /// is waiting for data.  Returns true if work was done.
    pub fn idle(&mut self) -> Result<bool> {
        if !self.scheduler.can_precompute() || self.precomputed {
            return Ok(false);
        }
        let plan = self.scheduler.peek();
        let start = Instant::now();
        self.engine
            .precompute(plan.phase, &mut self.states, &self.weights)?;
        self.metrics.record_precompute(start);
        if let Some(obs) = &self.obs {
            obs.fp_pre(self.id, plan.phase, false, start.elapsed().as_nanos() as u64);
        }
        self.precomputed = true;
        Ok(true)
    }

    /// A frame arrived: run the on-arrival work and return the output.
    ///
    /// For FP variants this is only the `rest` pass when `idle()` got to
    /// run beforehand (the serving loop guarantees it between frames); if
    /// the frame arrived before any idle time, the precompute runs inline
    /// first (counted in arrival latency — exactly the behaviour the paper
    /// describes for back-to-back arrivals).
    pub fn on_frame(&mut self, frame: &[f32]) -> Result<Vec<f32>> {
        self.record_history(frame);
        let plan = self.scheduler.next();
        let start = Instant::now();
        let out = if plan.split {
            if !self.precomputed {
                self.engine
                    .precompute(plan.phase, &mut self.states, &self.weights)?;
                if let Some(obs) = &self.obs {
                    obs.fp_pre(self.id, plan.phase, true, start.elapsed().as_nanos() as u64);
                }
            }
            self.precomputed = false;
            let rest_start = Instant::now();
            let out = self
                .engine
                .step_rest(plan.phase, frame, &mut self.states, &self.weights)?;
            if let Some(obs) = &self.obs {
                obs.fp_rest(plan.phase, 1, rest_start.elapsed().as_nanos() as u64);
            }
            out
        } else {
            self.engine
                .step(plan.phase, frame, &mut self.states, &self.weights)?
        };
        self.metrics.record_arrival(start);
        let phase_macs = macs_at_phase(&self.engine.manifest, plan.phase);
        self.metrics
            .record_frame(phase_macs, macs_stmc(&self.engine.manifest));
        if self.engine.manifest.dtype == Dtype::Int8 {
            self.metrics.record_macs_int8(phase_macs);
        }
        self.metrics.record_variant_frame(&self.engine.manifest.name);
        Ok(out)
    }

    /// The plan the next frame will execute (does not advance the
    /// schedule).  The server's worker loop uses this to group sessions
    /// into phase-aligned batches.
    pub fn next_plan(&self) -> StepPlan {
        self.scheduler.peek()
    }

    /// Serve one frame to each session of a phase-aligned group through
    /// the backend's batched execution path (DESIGN.md §8).
    ///
    /// Every session must sit at the same schedule position (the worker's
    /// phase grouping guarantees this; mismatches are an error) and share
    /// one compiled engine.  Outputs and state updates are bit-identical
    /// to calling [`StreamSession::on_frame`] once per session on the
    /// native backend; metrics additionally record the batch width.
    ///
    /// FP variants: sessions whose idle-time `precompute` has not run yet
    /// get it inline first (counted in arrival latency, exactly like the
    /// per-session path), then the whole group runs one batched rest pass.
    pub fn on_frame_batch(
        sessions: &mut [&mut StreamSession],
        frames: &[&[f32]],
    ) -> Result<Vec<Vec<f32>>> {
        let mut outs = Vec::new();
        Self::on_frame_batch_into(sessions, frames, &mut outs)?;
        Ok(outs)
    }

    /// [`StreamSession::on_frame_batch`] writing into caller-owned
    /// buffers: `outs` is resized to the batch width and its buffers'
    /// capacity is reused, so a server round recycles one outer vector
    /// instead of allocating per group (the worker loop drains the
    /// frames out of it afterwards).
    pub fn on_frame_batch_into(
        sessions: &mut [&mut StreamSession],
        frames: &[&[f32]],
        outs: &mut Vec<Vec<f32>>,
    ) -> Result<()> {
        let Some(first) = sessions.first() else {
            outs.clear();
            return Ok(());
        };
        if sessions.len() != frames.len() {
            bail!(
                "on_frame_batch: {} sessions but {} frames",
                sessions.len(),
                frames.len()
            );
        }
        let plan = first.scheduler.peek();
        let engine = first.engine.clone();
        let weights = first.weights.clone();
        for sess in sessions.iter() {
            if !Arc::ptr_eq(&sess.engine, &engine) || !Arc::ptr_eq(&sess.weights, &weights) {
                bail!(
                    "on_frame_batch: stream {} serves a different compiled variant or weights",
                    sess.id
                );
            }
            let p = sess.scheduler.peek();
            if p != plan {
                bail!(
                    "on_frame_batch: stream {} at phase {} grouped with phase {}",
                    sess.id,
                    p.phase,
                    plan.phase
                );
            }
        }
        let bsz = sessions.len();
        let start = Instant::now();
        if plan.split {
            for sess in sessions.iter_mut() {
                if !sess.precomputed {
                    let pre_start = Instant::now();
                    engine.precompute(plan.phase, &mut sess.states, &sess.weights)?;
                    if let Some(obs) = &sess.obs {
                        obs.fp_pre(
                            sess.id,
                            plan.phase,
                            true,
                            pre_start.elapsed().as_nanos() as u64,
                        );
                    }
                }
            }
        }
        let rest_start = Instant::now();
        {
            let mut states: Vec<&mut StateSet> =
                sessions.iter_mut().map(|s| &mut s.states).collect();
            if plan.split {
                engine.step_rest_batch_into(plan.phase, frames, &mut states, &weights, outs)?
            } else {
                engine.step_batch_into(plan.phase, frames, &mut states, &weights, outs)?
            }
        }
        if plan.split {
            // one rest pass served the whole group — record it once, on
            // the group leader's handle (all sessions share a worker)
            if let Some(obs) = sessions.first().and_then(|s| s.obs.as_ref()) {
                obs.fp_rest(plan.phase, bsz, rest_start.elapsed().as_nanos() as u64);
            }
        }
        let phase_macs = macs_at_phase(&engine.manifest, plan.phase);
        let stmc = macs_stmc(&engine.manifest);
        let int8 = engine.manifest.dtype == Dtype::Int8;
        for (sess, frame) in sessions.iter_mut().zip(frames) {
            sess.record_history(frame);
            sess.scheduler.next();
            sess.precomputed = false;
            sess.metrics.record_arrival(start);
            sess.metrics.record_frame(phase_macs, stmc);
            sess.metrics.record_batch(bsz as u64, phase_macs);
            if int8 {
                sess.metrics.record_macs_int8(phase_macs);
            }
            sess.metrics.record_variant_frame(&engine.manifest.name);
        }
        Ok(())
    }

    /// Frames consumed so far.
    pub fn frames_seen(&self) -> u64 {
        self.scheduler.t()
    }

    /// Reset stream state (e.g. utterance boundary).
    pub fn reset(&mut self) {
        self.states = self.engine.init_states();
        self.scheduler.reset();
        self.precomputed = false;
        self.history.clear();
        self.pending_switch = None;
        self.pending_weights = None;
    }

    /// Peak partial-state memory for this stream, bytes.
    pub fn state_bytes(&self) -> usize {
        self.states.tensors.iter().map(|t| t.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{LayerMacs, Manifest, ModelConfig};
    use std::collections::BTreeMap;
    use std::path::PathBuf;

    fn manifest(period: usize) -> Manifest {
        Manifest {
            name: "t".into(),
            config: ModelConfig {
                feat: 4,
                channels: vec![4],
                kernel: 3,
                scc: vec![],
                shift_pos: None,
                shift: 1,
                extrap: vec![],
                interp: None,
            },
            dtype: crate::runtime::Dtype::F32,
            quant: None,
            period,
            streamable: true,
            offline_t: 16,
            packed_states: 0,
            states: vec![],
            params: vec![],
            executables: BTreeMap::new(),
            layer_macs: vec![
                LayerMacs {
                    name: "a".into(),
                    macs: 100,
                    rate_div: 1,
                },
                LayerMacs {
                    name: "b".into(),
                    macs: 300,
                    rate_div: 2,
                },
            ],
            macs_per_frame: 250.0,
            precomputed_fraction: 0.0,
            param_count: 0,
            state_bytes: 0,
            train_metrics: BTreeMap::new(),
            dir: PathBuf::from("/nonexistent"),
        }
    }

    #[test]
    fn phase_macs() {
        let m = manifest(2);
        assert_eq!(macs_at_phase(&m, 0), 400.0); // both layers fire
        assert_eq!(macs_at_phase(&m, 1), 100.0); // only rate-1 layer
        assert_eq!(macs_stmc(&m), 400.0);
    }

    #[test]
    fn average_over_period_matches_manifest() {
        let m = manifest(2);
        let avg = (macs_at_phase(&m, 0) + macs_at_phase(&m, 1)) / 2.0;
        assert_eq!(avg, m.macs_per_frame);
    }

    #[test]
    fn period4_phase_pattern() {
        // Hand-built period-4 manifest (2 x S-CC): rate divisors 1/2/4.
        let mut m = manifest(4);
        m.layer_macs.push(LayerMacs {
            name: "c".into(),
            macs: 800,
            rate_div: 4,
        });
        assert_eq!(macs_at_phase(&m, 0), 1200.0); // all fire
        assert_eq!(macs_at_phase(&m, 1), 100.0); // rate-1 only
        assert_eq!(macs_at_phase(&m, 2), 400.0); // rate-1 + rate-2
        assert_eq!(macs_at_phase(&m, 3), 100.0);
        assert_eq!(macs_stmc(&m), 1200.0);
        // phases repeat with the period
        for p in 0..4 {
            assert_eq!(macs_at_phase(&m, p), macs_at_phase(&m, p + 4));
        }
    }
}
