//! L3 coordinator — the paper's system contribution as a serving runtime.
//!
//! * [`scheduler`] — the SOI inference pattern (which executable per
//!   phase, FP precompute placement) as pure, testable logic.
//! * [`stream`] — per-stream session: partial-state cache, schedule
//!   execution, idle-time FP precompute, per-stream metrics, the
//!   phase-aligned batched group entry point
//!   ([`StreamSession::on_frame_batch`], DESIGN.md §8), and warm
//!   variant migration (DESIGN.md §9).
//! * [`server`] — multi-stream worker pool with id-sharding, bounded
//!   queues (backpressure), per-(variant, phase) batched dispatch,
//!   optional load-adaptive ladder serving, zero-downtime weight-
//!   generation hot reload (DESIGN.md §13), aggregated metrics, and a
//!   live mode ([`Server::start_live`]) that a network shard wraps
//!   (DESIGN.md §14).
//! * [`controller`] — the adaptive-serving load controller: per-worker
//!   queue-depth + rolling-p99 hysteresis deciding ladder moves (§9).
//! * [`metrics`] — latency histograms, executed-MAC, batch-width and
//!   migration accounting, measured precompute overlap.

pub mod controller;
pub mod metrics;
pub mod scheduler;
pub mod server;
pub mod stream;

pub use controller::{AdaptivePolicy, Decision, LoadController, Trigger};
pub use metrics::StreamMetrics;
pub use scheduler::{Scheduler, StepPlan};
pub use server::{
    FrameJob, Generation, GenerationWatcher, LiveCmd, LiveEvent, LiveServer, ReloadHandle,
    ServeReport, Server,
};
pub use stream::StreamSession;
