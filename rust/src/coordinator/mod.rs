//! L3 coordinator — the paper's system contribution as a serving runtime.
//!
//! * [`scheduler`] — the SOI inference pattern (which executable per
//!   phase, FP precompute placement) as pure, testable logic.
//! * [`stream`] — per-stream session: partial-state cache, schedule
//!   execution, idle-time FP precompute, per-stream metrics.
//! * [`server`] — multi-stream worker pool with id-sharding, bounded
//!   queues (backpressure) and aggregated metrics.
//! * [`metrics`] — latency histograms, executed-MAC accounting, measured
//!   precompute overlap.

pub mod metrics;
pub mod scheduler;
pub mod server;
pub mod stream;

pub use metrics::StreamMetrics;
pub use scheduler::{Scheduler, StepPlan};
pub use server::{ServeReport, Server};
pub use stream::StreamSession;
