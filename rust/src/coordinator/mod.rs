//! L3 coordinator — the paper's system contribution as a serving runtime.
//!
//! * [`scheduler`] — the SOI inference pattern (which executable per
//!   phase, FP precompute placement) as pure, testable logic.
//! * [`stream`] — per-stream session: partial-state cache, schedule
//!   execution, idle-time FP precompute, per-stream metrics, and the
//!   phase-aligned batched group entry point
//!   ([`StreamSession::on_frame_batch`], DESIGN.md §8).
//! * [`server`] — multi-stream worker pool with id-sharding, bounded
//!   queues (backpressure), per-phase batched dispatch and aggregated
//!   metrics.
//! * [`metrics`] — latency histograms, executed-MAC and batch-width
//!   accounting, measured precompute overlap.

pub mod metrics;
pub mod scheduler;
pub mod server;
pub mod stream;

pub use metrics::StreamMetrics;
pub use scheduler::{Scheduler, StepPlan};
pub use server::{ServeReport, Server};
pub use stream::StreamSession;
