//! Load controller for adaptive serving (DESIGN.md §9): watches each
//! worker's queue depth and rolling on-arrival p99 and decides when to
//! move that worker's streams up or down the variant ladder.
//!
//! The controller is pure decision logic — it never touches sessions or
//! the ladder.  `coordinator::server` feeds it one observation per
//! serving round and applies the [`Decision`] it returns; keeping it
//! side-effect-free is what makes the hysteresis rule directly testable
//! (`rust/tests/adaptive_serving.rs` drives a synthetic load spike
//! through it without a server).
//!
//! Each verdict carries its evidence — the [`Trigger`], the backlog and
//! the rolling p99 *at decision time* — so the serving layer can record
//! the full decision trace as obs events (`ctl_decision` in the health
//! feed) instead of decisions vanishing into a rung change.  The rolling
//! window itself is an [`crate::obs::RollingHist`]: the same mergeable
//! log-linear buckets the health feed exports, replacing the old
//! clone-and-sort sample ring (p99 reads are now allocation-free).

use crate::obs::RollingHist;

/// Tuning knobs for the adaptive-serving controller.
///
/// The hysteresis rule is three-layered so the ladder cannot flap:
/// *patience* (a signal must persist for N consecutive rounds before a
/// switch), *cooldown* (after any switch, decisions pause for M rounds
/// so the new rung's effect can show up in the signals), and *headroom*
/// (upgrading back toward quality requires p99 comfortably *below*
/// target — `headroom · target` — not merely at it, so the upgrade
/// itself cannot immediately re-trigger a downgrade).
#[derive(Debug, Clone)]
pub struct AdaptivePolicy {
    /// On-arrival p99 target, microseconds.  The controller downgrades
    /// (cheaper rungs) while the rolling p99 exceeds this.
    pub target_p99_us: u64,
    /// Queue depth (undelivered frames in the worker) treated as
    /// overload even when latency still looks fine — queue growth is
    /// the earlier signal under a burst.
    pub queue_high: usize,
    /// Queue depth at or below which the worker counts as drained
    /// (one of the two conditions for upgrading).
    pub queue_low: usize,
    /// Consecutive overloaded rounds before a downgrade.
    pub patience_down: u32,
    /// Consecutive calm rounds before an upgrade.  Deliberately much
    /// larger than `patience_down`: degrade fast, recover cautiously.
    pub patience_up: u32,
    /// Rounds after any switch during which no further decision fires.
    pub cooldown: u32,
    /// Rolling latency-window length, in served frames.  The window is
    /// epoch-rotated ([`RollingHist`]): p99 covers between `window/2 + 1`
    /// and `window` of the most recent samples.
    pub window: usize,
    /// Upgrade only while the rolling p99 is below
    /// `headroom · target_p99_us` (in (0, 1]).
    pub headroom: f64,
}

impl Default for AdaptivePolicy {
    fn default() -> Self {
        AdaptivePolicy {
            target_p99_us: 500,
            queue_high: 8,
            queue_low: 1,
            patience_down: 2,
            patience_up: 24,
            cooldown: 8,
            window: 128,
            headroom: 0.5,
        }
    }
}

impl AdaptivePolicy {
    /// The default policy with a specific p99 target (the CLI's
    /// `--target-p99-us` maps here).
    pub fn with_target_us(target_p99_us: u64) -> AdaptivePolicy {
        AdaptivePolicy {
            target_p99_us,
            ..Default::default()
        }
    }
}

/// Why a [`Decision`] fired.  Also the `trigger` field of
/// `ctl_decision` health-feed events (`name()` is the wire string).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Trigger {
    /// Downgrade: queue depth at/above `queue_high` on the deciding
    /// round (queue growth is the earlier overload signal).
    Queue,
    /// Downgrade: rolling p99 above `target_p99_us` (queue still fine).
    Latency,
    /// Upgrade: drained queue and p99 under the headroom for
    /// `patience_up` consecutive rounds.
    Calm,
}

impl Trigger {
    /// Stable snake_case name (health-feed `trigger` field).
    pub fn name(self) -> &'static str {
        match self {
            Trigger::Queue => "queue",
            Trigger::Latency => "latency",
            Trigger::Calm => "calm",
        }
    }

    /// Numeric code carried in the fixed-size obs event payload
    /// (0 queue, 1 latency, 2 calm — decoded back by `obs::export`).
    pub fn code(self) -> u64 {
        match self {
            Trigger::Queue => 0,
            Trigger::Latency => 1,
            Trigger::Calm => 2,
        }
    }
}

/// One fired controller verdict, with the evidence it fired on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Decision {
    /// Rung the worker's streams were targeting.
    pub from: usize,
    /// New target rung (`from + 1` degrade, `from - 1` recover).
    pub to: usize,
    /// Which signal fired.
    pub trigger: Trigger,
    /// Worker backlog (undelivered frames) at decision time.
    pub backlog: usize,
    /// Rolling window p99 at decision time, microseconds.
    pub p99_us: u64,
}

impl Decision {
    /// True when the verdict moves toward cheaper rungs.
    pub fn is_degrade(&self) -> bool {
        self.to > self.from
    }
}

/// Per-worker controller state: a rolling latency window plus the
/// hysteresis counters.
pub struct LoadController {
    policy: AdaptivePolicy,
    /// Rolling window of recent per-frame on-arrival latencies, ns
    /// (epoch-rotated mergeable histogram; see [`RollingHist`]).
    lat_ns: RollingHist,
    over_rounds: u32,
    calm_rounds: u32,
    cooldown_left: u32,
    /// Signal behind the most recent overloaded round (evidence for the
    /// next degrade verdict).
    last_over: Trigger,
}

impl LoadController {
    /// A controller with empty history.
    pub fn new(policy: AdaptivePolicy) -> LoadController {
        LoadController {
            lat_ns: RollingHist::new(policy.window.max(2)),
            policy,
            over_rounds: 0,
            calm_rounds: 0,
            cooldown_left: 0,
            last_over: Trigger::Queue,
        }
    }

    /// Feed one served frame's on-arrival latency (for a batched round,
    /// the batch wall time once per frame in it — what each frame
    /// actually waited for).
    pub fn record_latency_ns(&mut self, ns: u64) {
        self.lat_ns.record(ns);
    }

    /// p99 over the rolling window, microseconds (0 while empty).
    /// Bucket resolution <1%; allocation-free.
    pub fn window_p99_us(&self) -> u64 {
        self.lat_ns.p99() / 1_000
    }

    /// One control decision per serving round.
    ///
    /// `queue_depth` is the worker's backlog *after* the round (frames
    /// received but not served — 0 when the worker keeps up with
    /// arrivals, large under overload), `rung` its streams' current
    /// target rung, `max_rung` the ladder's last index.
    /// Returns the fired [`Decision`] when the hysteresis rule trips
    /// (`to = rung + 1` downgrade toward cheaper, `to = rung - 1`
    /// upgrade toward quality), `None` to stay put.
    pub fn observe_round(
        &mut self,
        queue_depth: usize,
        rung: usize,
        max_rung: usize,
    ) -> Option<Decision> {
        let p = &self.policy;
        let p99 = self.window_p99_us();
        // Cooldown gates *before* any patience accrual: rounds observed
        // while the previous switch settles count toward nothing, so a
        // recovery cannot fire the instant cooldown expires on patience
        // quietly banked inside the window.
        if self.cooldown_left > 0 {
            self.cooldown_left -= 1;
            self.over_rounds = 0;
            self.calm_rounds = 0;
            return None;
        }
        let over = queue_depth >= p.queue_high || p99 > p.target_p99_us;
        let calm =
            queue_depth <= p.queue_low && (p99 as f64) <= p.headroom * p.target_p99_us as f64;
        if over {
            self.over_rounds = self.over_rounds.saturating_add(1);
            self.calm_rounds = 0;
            // queue wins when both fire: it is the earlier signal and
            // the one the operator can act on (shed load vs retune)
            self.last_over = if queue_depth >= p.queue_high {
                Trigger::Queue
            } else {
                Trigger::Latency
            };
        } else if calm {
            self.calm_rounds = self.calm_rounds.saturating_add(1);
            self.over_rounds = 0;
        } else {
            self.over_rounds = 0;
            self.calm_rounds = 0;
        }
        if self.over_rounds >= self.policy.patience_down && rung < max_rung {
            self.over_rounds = 0;
            self.calm_rounds = 0;
            self.cooldown_left = self.policy.cooldown;
            return Some(Decision {
                from: rung,
                to: rung + 1,
                trigger: self.last_over,
                backlog: queue_depth,
                p99_us: p99,
            });
        }
        if self.calm_rounds >= self.policy.patience_up && rung > 0 {
            self.over_rounds = 0;
            self.calm_rounds = 0;
            self.cooldown_left = self.policy.cooldown;
            return Some(Decision {
                from: rung,
                to: rung - 1,
                trigger: Trigger::Calm,
                backlog: queue_depth,
                p99_us: p99,
            });
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_policy() -> AdaptivePolicy {
        AdaptivePolicy {
            target_p99_us: 1_000,
            queue_high: 4,
            queue_low: 0,
            patience_down: 2,
            patience_up: 3,
            cooldown: 2,
            window: 16,
            headroom: 0.5,
        }
    }

    #[test]
    fn window_p99_tracks_recent_samples() {
        let mut c = LoadController::new(AdaptivePolicy {
            window: 8,
            ..quick_policy()
        });
        assert_eq!(c.window_p99_us(), 0);
        for _ in 0..8 {
            c.record_latency_ns(4_000_000);
        }
        // log-bucketed: within 1% of 4 ms
        let p99 = c.window_p99_us();
        assert!((3_960..=4_040).contains(&p99), "p99={p99}");
        // epoch rotation evicts the old spike: after `window` cheaper
        // samples plus one epoch of slack, only 500 µs remains visible
        for _ in 0..12 {
            c.record_latency_ns(500_000);
        }
        let p99 = c.window_p99_us();
        assert!((495..=505).contains(&p99), "p99={p99}");
    }

    #[test]
    fn latency_above_target_counts_as_overload() {
        let mut c = LoadController::new(quick_policy());
        c.record_latency_ns(5_000_000); // 5 ms >> 1 ms target
        assert_eq!(c.observe_round(0, 0, 2), None); // patience 1/2
        let d = c.observe_round(0, 0, 2).expect("patience 2/2 fires");
        assert_eq!((d.from, d.to), (0, 1));
        assert_eq!(d.trigger, Trigger::Latency);
        assert_eq!(d.backlog, 0);
        assert!(d.p99_us > 1_000, "evidence p99 carried: {}", d.p99_us);
        assert!(d.is_degrade());
    }

    #[test]
    fn queue_pressure_wins_the_trigger_attribution() {
        let mut c = LoadController::new(quick_policy());
        c.record_latency_ns(5_000_000); // latency *also* over target
        assert_eq!(c.observe_round(10, 0, 2), None);
        let d = c.observe_round(10, 0, 2).expect("degrade fires");
        assert_eq!(d.trigger, Trigger::Queue);
        assert_eq!(d.backlog, 10);
    }

    #[test]
    fn recovery_is_attributed_to_calm() {
        let mut c = LoadController::new(quick_policy());
        for _ in 0..2 {
            c.record_latency_ns(100_000); // 100 µs, well under headroom
        }
        let mut fired = None;
        for _ in 0..10 {
            if let Some(d) = c.observe_round(0, 1, 2) {
                fired = Some(d);
                break;
            }
        }
        let d = fired.expect("calm upgrade fires within patience_up");
        assert_eq!((d.from, d.to), (1, 0));
        assert_eq!(d.trigger, Trigger::Calm);
        assert!(!d.is_degrade());
    }

    #[test]
    fn single_round_blip_is_absorbed() {
        let mut c = LoadController::new(quick_policy());
        assert_eq!(c.observe_round(10, 0, 2), None);
        assert_eq!(c.observe_round(0, 0, 2), None); // calm resets patience
        assert_eq!(c.observe_round(10, 0, 2), None); // back to 1/2
    }

    #[test]
    fn recovery_waits_out_cooldown_before_earning_patience() {
        // quick_policy: patience_down 2, patience_up 3, cooldown 2.
        let mut c = LoadController::new(quick_policy());
        assert_eq!(c.observe_round(10, 0, 2), None);
        let d = c.observe_round(10, 0, 2).expect("degrade fires");
        assert!(d.is_degrade());
        // From here every round is perfectly calm (empty queue, p99 0,
        // well under headroom).  Rounds 1-2 are cooldown, rounds 3-5
        // earn calm patience 1..3 — recovery fires exactly at round 5,
        // never inside the cooldown window.
        for round in 1..=4 {
            assert_eq!(c.observe_round(0, 1, 2), None, "round {round}");
        }
        let d = c.observe_round(0, 1, 2).expect("recovery at round 5");
        assert_eq!((d.from, d.to, d.trigger), (1, 0, Trigger::Calm));
    }

    #[test]
    fn clamps_at_ladder_ends() {
        let mut c = LoadController::new(quick_policy());
        for _ in 0..10 {
            assert_eq!(c.observe_round(10, 2, 2), None, "already at max rung");
        }
        let mut c = LoadController::new(quick_policy());
        for _ in 0..10 {
            assert_eq!(c.observe_round(0, 0, 2), None, "already at rung 0");
        }
    }
}
