//! Load controller for adaptive serving (DESIGN.md §9): watches each
//! worker's queue depth and rolling on-arrival p99 and decides when to
//! move that worker's streams up or down the variant ladder.
//!
//! The controller is pure decision logic — it never touches sessions or
//! the ladder.  `coordinator::server` feeds it one observation per
//! serving round and applies the rung it returns; keeping it
//! side-effect-free is what makes the hysteresis rule directly testable
//! (`rust/tests/adaptive_serving.rs` drives a synthetic load spike
//! through it without a server).

/// Tuning knobs for the adaptive-serving controller.
///
/// The hysteresis rule is three-layered so the ladder cannot flap:
/// *patience* (a signal must persist for N consecutive rounds before a
/// switch), *cooldown* (after any switch, decisions pause for M rounds
/// so the new rung's effect can show up in the signals), and *headroom*
/// (upgrading back toward quality requires p99 comfortably *below*
/// target — `headroom · target` — not merely at it, so the upgrade
/// itself cannot immediately re-trigger a downgrade).
#[derive(Debug, Clone)]
pub struct AdaptivePolicy {
    /// On-arrival p99 target, microseconds.  The controller downgrades
    /// (cheaper rungs) while the rolling p99 exceeds this.
    pub target_p99_us: u64,
    /// Queue depth (undelivered frames in the worker) treated as
    /// overload even when latency still looks fine — queue growth is
    /// the earlier signal under a burst.
    pub queue_high: usize,
    /// Queue depth at or below which the worker counts as drained
    /// (one of the two conditions for upgrading).
    pub queue_low: usize,
    /// Consecutive overloaded rounds before a downgrade.
    pub patience_down: u32,
    /// Consecutive calm rounds before an upgrade.  Deliberately much
    /// larger than `patience_down`: degrade fast, recover cautiously.
    pub patience_up: u32,
    /// Rounds after any switch during which no further decision fires.
    pub cooldown: u32,
    /// Rolling latency-window length, in served frames.
    pub window: usize,
    /// Upgrade only while the rolling p99 is below
    /// `headroom · target_p99_us` (in (0, 1]).
    pub headroom: f64,
}

impl Default for AdaptivePolicy {
    fn default() -> Self {
        AdaptivePolicy {
            target_p99_us: 500,
            queue_high: 8,
            queue_low: 1,
            patience_down: 2,
            patience_up: 24,
            cooldown: 8,
            window: 128,
            headroom: 0.5,
        }
    }
}

impl AdaptivePolicy {
    /// The default policy with a specific p99 target (the CLI's
    /// `--target-p99-us` maps here).
    pub fn with_target_us(target_p99_us: u64) -> AdaptivePolicy {
        AdaptivePolicy {
            target_p99_us,
            ..Default::default()
        }
    }
}

/// Per-worker controller state: a rolling latency window plus the
/// hysteresis counters.
pub struct LoadController {
    policy: AdaptivePolicy,
    /// Ring buffer of recent per-frame on-arrival latencies, ns.
    lat_ns: Vec<u64>,
    next: usize,
    over_rounds: u32,
    calm_rounds: u32,
    cooldown_left: u32,
}

impl LoadController {
    /// A controller with empty history.
    pub fn new(policy: AdaptivePolicy) -> LoadController {
        LoadController {
            lat_ns: Vec::with_capacity(policy.window.max(1)),
            policy,
            next: 0,
            over_rounds: 0,
            calm_rounds: 0,
            cooldown_left: 0,
        }
    }

    /// Feed one served frame's on-arrival latency (for a batched round,
    /// the batch wall time once per frame in it — what each frame
    /// actually waited for).
    pub fn record_latency_ns(&mut self, ns: u64) {
        let cap = self.policy.window.max(1);
        if self.lat_ns.len() < cap {
            self.lat_ns.push(ns);
        } else {
            self.lat_ns[self.next] = ns;
            self.next = (self.next + 1) % cap;
        }
    }

    /// p99 over the rolling window, microseconds (0 while empty).
    pub fn window_p99_us(&self) -> u64 {
        if self.lat_ns.is_empty() {
            return 0;
        }
        let mut v = self.lat_ns.clone();
        v.sort_unstable();
        let idx = ((v.len() as f64) * 0.99).ceil() as usize;
        v[idx.saturating_sub(1).min(v.len() - 1)] / 1_000
    }

    /// One control decision per serving round.
    ///
    /// `queue_depth` is the worker's backlog *after* the round (frames
    /// received but not served — 0 when the worker keeps up with
    /// arrivals, large under overload), `rung` its streams' current
    /// target rung, `max_rung` the ladder's last index.
    /// Returns the new target rung when the hysteresis rule fires
    /// (`rung + 1` = downgrade toward cheaper, `rung - 1` = upgrade
    /// toward quality), `None` to stay put.
    pub fn observe_round(
        &mut self,
        queue_depth: usize,
        rung: usize,
        max_rung: usize,
    ) -> Option<usize> {
        let p = &self.policy;
        let p99 = self.window_p99_us();
        let over = queue_depth >= p.queue_high || p99 > p.target_p99_us;
        let calm =
            queue_depth <= p.queue_low && (p99 as f64) <= p.headroom * p.target_p99_us as f64;
        if over {
            self.over_rounds = self.over_rounds.saturating_add(1);
            self.calm_rounds = 0;
        } else if calm {
            self.calm_rounds = self.calm_rounds.saturating_add(1);
            self.over_rounds = 0;
        } else {
            self.over_rounds = 0;
            self.calm_rounds = 0;
        }
        if self.cooldown_left > 0 {
            self.cooldown_left -= 1;
            return None;
        }
        if self.over_rounds >= self.policy.patience_down && rung < max_rung {
            self.over_rounds = 0;
            self.calm_rounds = 0;
            self.cooldown_left = self.policy.cooldown;
            return Some(rung + 1);
        }
        if self.calm_rounds >= self.policy.patience_up && rung > 0 {
            self.over_rounds = 0;
            self.calm_rounds = 0;
            self.cooldown_left = self.policy.cooldown;
            return Some(rung - 1);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_policy() -> AdaptivePolicy {
        AdaptivePolicy {
            target_p99_us: 1_000,
            queue_high: 4,
            queue_low: 0,
            patience_down: 2,
            patience_up: 3,
            cooldown: 2,
            window: 16,
            headroom: 0.5,
        }
    }

    #[test]
    fn window_p99_tracks_recent_samples() {
        let mut c = LoadController::new(AdaptivePolicy {
            window: 4,
            ..quick_policy()
        });
        assert_eq!(c.window_p99_us(), 0);
        for ns in [1_000_000, 2_000_000, 3_000_000, 4_000_000] {
            c.record_latency_ns(ns);
        }
        assert_eq!(c.window_p99_us(), 4_000);
        // the ring evicts the oldest sample
        for _ in 0..4 {
            c.record_latency_ns(500_000);
        }
        assert_eq!(c.window_p99_us(), 500);
    }

    #[test]
    fn latency_above_target_counts_as_overload() {
        let mut c = LoadController::new(quick_policy());
        c.record_latency_ns(5_000_000); // 5 ms >> 1 ms target
        assert_eq!(c.observe_round(0, 0, 2), None); // patience 1/2
        assert_eq!(c.observe_round(0, 0, 2), Some(1)); // patience 2/2
    }

    #[test]
    fn single_round_blip_is_absorbed() {
        let mut c = LoadController::new(quick_policy());
        assert_eq!(c.observe_round(10, 0, 2), None);
        assert_eq!(c.observe_round(0, 0, 2), None); // calm resets patience
        assert_eq!(c.observe_round(10, 0, 2), None); // back to 1/2
    }

    #[test]
    fn clamps_at_ladder_ends() {
        let mut c = LoadController::new(quick_policy());
        for _ in 0..10 {
            assert_eq!(c.observe_round(10, 2, 2), None, "already at max rung");
        }
        let mut c = LoadController::new(quick_policy());
        for _ in 0..10 {
            assert_eq!(c.observe_round(0, 0, 2), None, "already at rung 0");
        }
    }
}
