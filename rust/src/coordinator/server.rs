//! Multi-stream serving: a worker pool sharding streams by id, with
//! bounded queues for backpressure, phase-aligned batched dispatch,
//! optional load-adaptive variant-ladder serving (DESIGN.md §9) and
//! aggregated metrics.
//!
//! tokio is unavailable offline (DESIGN.md §5); the pool uses std threads
//! and mpsc channels, which is a good fit anyway — backend execution is
//! synchronous, so one OS thread per worker with its own stream shard is
//! the natural topology (the vLLM-router-style design scaled down to
//! frame-level requests).
//!
//! Each worker drains its queue without blocking, then serves at most one
//! pending frame per stream per round, *grouped by (ladder rung,
//! scheduler phase)* (DESIGN.md §8–9): streams on the same compiled
//! variant at the same `StepPlan` phase execute as one batched backend
//! call instead of N sequential ones.  Frames travel the queue as
//! `Arc<[f32]>`, so dispatch clones a pointer, not the samples.
//!
//! With a multi-rung [`VariantLadder`] and an [`AdaptivePolicy`], each
//! worker additionally runs a [`LoadController`]: one observation per
//! round (queue depth + rolling on-arrival p99) decides whether the
//! worker's streams should move down the ladder (overload → cheaper
//! variants) or back up (calm → quality); sessions migrate individually
//! at their next phase-0 boundary with warm state re-priming, so no
//! output glitches and no stream restarts.
//!
//! `CompiledVariant` is `Send + Sync` through the `VariantExec` trait
//! bound (the pjrt implementation asserts PJRT's thread-safety contract
//! itself), so workers share one `Arc<VariantLadder>` directly; all
//! mutation on the rust side (states, metrics) stays worker-local.

use std::collections::{HashMap, HashSet, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::controller::{AdaptivePolicy, LoadController};
use super::metrics::StreamMetrics;
use super::stream::StreamSession;
use crate::obs::{Counter, EventKind, Gauge, ObsHandle, SpanKind, Telemetry, TraceCtx};
use crate::runtime::{
    artifact, Artifact, CompiledVariant, DeviceWeights, Runtime, VariantLadder,
};

/// One frame of work for a stream.
pub struct FrameJob {
    /// Which stream the frame belongs to.
    pub stream_id: u64,
    /// The frame samples, shared with the dispatcher (an `Arc` clone per
    /// hop instead of a data copy).
    pub frame: Arc<[f32]>,
    /// Marks the last frame of the stream (flush + report).
    pub last: bool,
    /// Cross-shard trace context when this frame is sampled
    /// (DESIGN.md §15); `None` — the overwhelmingly common case — adds
    /// no work to the serving path beyond one `Option` branch.
    pub trace: Option<TraceCtx>,
}

/// One command for a live worker (DESIGN.md §14).  Batch-mode runs
/// ([`Server::run`]) only ever use [`LiveCmd::Frame`]; a shard
/// ([`crate::net::shard`]) additionally admits migrated sessions with
/// [`LiveCmd::Resume`] and retires drained ones with
/// [`LiveCmd::Forget`].
pub enum LiveCmd {
    /// Serve one frame (creates the session on first sight).
    Frame(FrameJob),
    /// Admit a session mid-stream by §9 history replay
    /// ([`StreamSession::resume`]): resume at absolute frame counter
    /// `t` from `history` (oldest first).  Failure emits
    /// [`LiveEvent::ResumeFailed`] and constructs nothing — the
    /// worker and its other sessions are unaffected.
    Resume {
        /// Stream id to admit.
        stream_id: u64,
        /// Absolute frame counter the stream resumes at.
        t: u64,
        /// Recent input frames, oldest first (`len == t` or
        /// `>= warmup`).
        history: Vec<Vec<f32>>,
        /// Trace context of the migration that carried this resume
        /// (`migrate_front` span), if the migration was traced.
        trace: Option<TraceCtx>,
    },
    /// Drop a session immediately (it migrated away or its client
    /// vanished); pending frames are discarded.
    Forget {
        /// Stream id to drop.
        stream_id: u64,
    },
}

/// What a live worker reports while running (see
/// [`Server::start_live`]).  In live mode outputs stream out as they
/// are produced instead of accumulating until the stream retires.
pub enum LiveEvent {
    /// One output frame.
    Out {
        /// Stream id.
        id: u64,
        /// Seq of the input frame this output answers (the session's
        /// frame counter before serving it).
        seq: u64,
        /// Output samples.
        frame: Vec<f32>,
        /// Trace context to echo back on the wire (`phase_exec` span)
        /// when the input frame was traced.
        trace: Option<TraceCtx>,
    },
    /// A session retired (last frame served, or [`LiveCmd::Forget`]).
    Retired {
        /// Stream id.
        id: u64,
        /// The session's final metrics.
        metrics: StreamMetrics,
        /// Ladder rung it retired on.
        rung: usize,
    },
    /// A [`LiveCmd::Resume`] was rejected; no session was created.
    ResumeFailed {
        /// Stream id of the rejected resume.
        id: u64,
        /// Why the replay was refused.
        reason: String,
    },
    /// The worker hit an unrecoverable serving error and exited.
    Fatal {
        /// Rendered error chain.
        reason: String,
    },
}

/// Serving summary returned by [`Server::run`].
pub struct ServeReport {
    /// Metrics aggregated across every served stream (includes the
    /// migration and per-variant frame counters of adaptive runs).
    pub metrics: StreamMetrics,
    /// Output frames per stream id.
    pub outputs: HashMap<u64, Vec<Vec<f32>>>,
    /// Ladder rung each stream sat on when it retired (all 0 for
    /// pinned, single-variant serving).
    pub final_levels: HashMap<u64, usize>,
    /// Wall-clock duration of the whole run.
    pub wall_seconds: f64,
    /// Total frames served.
    pub frames: u64,
    /// Peak scratch-arena bytes per variant (high-water of the per-step
    /// [`crate::kernels::StepArena`]; max across workers).  Empty for
    /// backends without an arena (pjrt).
    pub arena_peak_by_variant: HashMap<String, u64>,
    /// Peak scratch-arena bytes of the hottest worker thread (the max
    /// across workers of each worker's summed per-variant peaks).
    pub arena_peak_bytes: u64,
    /// Weight generation the run ended on (max across workers; 0 when
    /// the server ran without hot reload — DESIGN.md §13).
    pub generation: u64,
}

impl ServeReport {
    /// Aggregate throughput over the run, frames per second.
    pub fn throughput_fps(&self) -> f64 {
        if self.wall_seconds == 0.0 {
            0.0
        } else {
            self.frames as f64 / self.wall_seconds
        }
    }
}

/// One published weight generation: a compiled rung ladder over one
/// verified artifact's weight set (DESIGN.md §13).
pub struct Generation {
    /// Monotonic generation number (higher supersedes lower).
    pub seq: u64,
    /// The generation's compiled rung ladder — all rungs share the
    /// generation's weight tensors, so one upload serves every rung.
    pub ladder: Arc<VariantLadder>,
}

struct ReloadInner {
    /// Bumped on every publish; workers poll this single atomic per
    /// round and only take the slot lock when it moved.
    epoch: AtomicU64,
    slot: Mutex<Arc<Generation>>,
}

/// Shared hot-reload slot (DESIGN.md §13): a publisher (the
/// [`GenerationWatcher`], or a test) [`ReloadHandle::publish`]es a fully
/// verified new [`Generation`]; every serving worker notices via one
/// relaxed atomic read per round, uploads the new weights side by side
/// with the old, and re-primes its streams through §9 history-replay
/// migration at their next phase-0 boundary.  The old generation retires
/// when its last `Arc` drops — no stream is ever dropped or glitched.
#[derive(Clone)]
pub struct ReloadHandle(Arc<ReloadInner>);

impl ReloadHandle {
    /// A handle seeded with the generation the server starts on.
    pub fn new(initial: Generation) -> ReloadHandle {
        ReloadHandle(Arc::new(ReloadInner {
            epoch: AtomicU64::new(0),
            slot: Mutex::new(Arc::new(initial)),
        }))
    }

    /// Publish a new generation: it must already be fully verified
    /// (workers trust it — the artifact loader is the integrity
    /// boundary).  Takes effect at each worker's next round.
    pub fn publish(&self, generation: Generation) {
        let mut slot = self
            .0
            .slot
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        *slot = Arc::new(generation);
        drop(slot);
        self.0.epoch.fetch_add(1, Ordering::Release);
    }

    /// The currently published generation.
    pub fn current(&self) -> Arc<Generation> {
        self.0
            .slot
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .clone()
    }

    /// Publish sequence number (bumps by one per [`ReloadHandle::publish`]).
    pub fn epoch(&self) -> u64 {
        self.0.epoch.load(Ordering::Acquire)
    }
}

/// Background poller that turns a directory of versioned weight
/// artifacts into live generation publishes (DESIGN.md §13): every
/// `poll_ms` it lists the generation directories under `root`, and when
/// one with a higher number than the currently published generation
/// appears, loads it through the verifying [`Artifact::load`], compiles
/// the server's rung specs over its weights
/// ([`VariantLadder::over_weights`]) and publishes.  A candidate that
/// fails verification is remembered and never retried (its directory is
/// immutable once renamed into place), so the server **keeps serving the
/// old generation** — a corrupt artifact can degrade nothing but disk
/// space.
pub struct GenerationWatcher {
    stop: Arc<AtomicBool>,
    handle: Option<thread::JoinHandle<()>>,
}

impl GenerationWatcher {
    /// Start watching `root`.  `specs` are the ladder rung specs
    /// (`preset[:dtype]` grammar) compiled over each new generation's
    /// weights; `seed` feeds int8 calibration exactly as pinned serving
    /// does.
    pub fn spawn(
        rt: Arc<Runtime>,
        root: PathBuf,
        specs: Vec<String>,
        seed: u64,
        reload: ReloadHandle,
        poll_ms: u64,
    ) -> GenerationWatcher {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = thread::spawn(move || {
            let mut rejected: HashSet<PathBuf> = HashSet::new();
            while !stop2.load(Ordering::Relaxed) {
                let current = reload.current().seq;
                let candidate = artifact::list_generations(&root)
                    .unwrap_or_default()
                    .into_iter()
                    .filter(|(g, d)| *g > current && !rejected.contains(d))
                    .next_back();
                if let Some((seq, dir)) = candidate {
                    let spec_refs: Vec<&str> = specs.iter().map(|s| s.as_str()).collect();
                    let built = Artifact::load(&dir).map_err(anyhow::Error::from).and_then(
                        |art| {
                            VariantLadder::over_weights(
                                rt.clone(),
                                &art.manifest.config,
                                &art.weights,
                                &spec_refs,
                                seed,
                            )
                        },
                    );
                    match built {
                        Ok(ladder) => reload.publish(Generation {
                            seq,
                            ladder: Arc::new(ladder),
                        }),
                        Err(e) => {
                            // keep serving the old generation; remember the
                            // reject so one bad artifact cannot hot-loop
                            eprintln!(
                                "soi: rejecting artifact generation {} at {}: {e:#}",
                                seq,
                                dir.display()
                            );
                            rejected.insert(dir);
                        }
                    }
                }
                // sleep in short steps so stop() returns promptly
                let mut slept = 0u64;
                while slept < poll_ms.max(1) && !stop2.load(Ordering::Relaxed) {
                    let step = 2.min(poll_ms.max(1) - slept);
                    thread::sleep(Duration::from_millis(step));
                    slept += step;
                }
            }
        });
        GenerationWatcher {
            stop,
            handle: Some(handle),
        }
    }

    /// Stop the poller and join its thread.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for GenerationWatcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Multi-stream server over a ladder of compiled SOI variants (a
/// single pinned variant is the one-rung special case).
pub struct Server {
    ladder: Arc<VariantLadder>,
    workers: usize,
    queue_depth: usize,
    /// Run the FP idle/precompute pass between frames (on by default;
    /// turning it off measures the non-overlapped latency for Table 2).
    pub idle_precompute: bool,
    /// Group each worker's streams by (rung, scheduler phase) and execute
    /// them as batched backend calls (on by default; turning it off
    /// forces the one-frame-at-a-time path, the A/B baseline of
    /// `benches/serving`).
    pub batching: bool,
    /// Load-adaptive variant switching (DESIGN.md §9): when set and the
    /// ladder has more than one rung, each worker runs a
    /// [`LoadController`] over this policy and migrates its streams up
    /// and down the ladder with warm state re-priming.
    pub adaptive: Option<AdaptivePolicy>,
    /// Telemetry root (DESIGN.md §12): when set, each worker records
    /// dispatch rounds, per-(rung × phase) exec latencies, FP pre/rest
    /// spans, migrations and controller decisions through its own
    /// [`ObsHandle`] — into preallocated storage, so the zero-allocation
    /// steady state holds with telemetry enabled
    /// (`tests/hot_path_alloc.rs`).
    pub telemetry: Option<Arc<Telemetry>>,
    /// Live weight-generation reload (DESIGN.md §13): when set (via
    /// [`Server::enable_reload`]), each worker checks the handle once
    /// per round and migrates its streams onto newly published
    /// generations with §9 history-replay re-priming.
    pub reload: Option<ReloadHandle>,
    /// How long an idle worker blocks on its job queue per poll step
    /// (milliseconds) when hot reload is enabled — the latency bound
    /// on an idle worker noticing a publish.  Smaller values adopt
    /// generations faster at the cost of more wakeups; without
    /// reload, idle workers block indefinitely and this is unused.
    pub idle_poll_ms: u64,
}

impl Server {
    /// A server pinned to one compiled variant, with `workers` worker
    /// threads (min 1).
    pub fn new(engine: Arc<CompiledVariant>, workers: usize) -> Server {
        Self::with_ladder(Arc::new(VariantLadder::single(engine)), workers)
    }

    /// A server over a variant ladder (rung 0 serves new streams; other
    /// rungs are reachable only when [`Server::adaptive`] is set).
    pub fn with_ladder(ladder: Arc<VariantLadder>, workers: usize) -> Server {
        Server {
            ladder,
            workers: workers.max(1),
            queue_depth: 64,
            idle_precompute: true,
            batching: true,
            adaptive: None,
            telemetry: None,
            reload: None,
            idle_poll_ms: 2,
        }
    }

    /// Enable hot generation reload: wraps the server's current ladder
    /// as generation `seq` (the artifact generation it was built from,
    /// or 1 for synthesized weights) and returns the shared handle a
    /// publisher — a [`GenerationWatcher`] or a test — pushes new
    /// generations through.
    pub fn enable_reload(&mut self, seq: u64) -> ReloadHandle {
        let handle = ReloadHandle::new(Generation {
            seq,
            ladder: self.ladder.clone(),
        });
        self.reload = Some(handle.clone());
        handle
    }

    /// Serve a fixed set of streams to completion (throughput mode): every
    /// stream's frames are queued as fast as workers drain them.
    ///
    /// Streams are sharded across workers by `stream_id % workers`; each
    /// worker owns its sessions exclusively (no locks on the hot path).
    pub fn run(&self, streams: &[Vec<Vec<f32>>]) -> Result<ServeReport> {
        self.run_paced(streams, &[])
    }

    /// [`Server::run`] with paced dispatch: before dispatching round `t`
    /// (one frame per stream), the dispatcher sleeps `gap_us[t]`
    /// microseconds (`gap_us` shorter than the run repeats its last
    /// entry; empty means no pacing).  This is how `benches/serving.rs`
    /// shapes a load spike and how `soi serve --pace-us` emulates
    /// real-time arrival.
    pub fn run_paced(&self, streams: &[Vec<Vec<f32>>], gap_us: &[u64]) -> Result<ServeReport> {
        // One copy up front to share the frames; dispatch is copy-free.
        let shared: Vec<Vec<Arc<[f32]>>> = streams
            .iter()
            .map(|s| s.iter().map(|f| Arc::from(f.as_slice())).collect())
            .collect();
        self.run_shared_paced(&shared, gap_us)
    }

    /// [`Server::run`] over frames that are already shared: each queued
    /// job clones an `Arc`, never the samples.
    pub fn run_shared(&self, streams: &[Vec<Arc<[f32]>>]) -> Result<ServeReport> {
        self.run_shared_paced(streams, &[])
    }

    /// [`Server::run_paced`] over already-shared frames.
    pub fn run_shared_paced(
        &self,
        streams: &[Vec<Arc<[f32]>>],
        gap_us: &[u64],
    ) -> Result<ServeReport> {
        let t0 = std::time::Instant::now();
        let mut senders: Vec<SyncSender<LiveCmd>> = Vec::new();
        let mut handles = Vec::new();
        // Unbounded on purpose: workers retire streams mid-run, and the
        // dispatcher only drains results after dispatching every frame —
        // a bounded channel here can deadlock worker against dispatcher.
        let (out_tx, out_rx) = channel::<WorkerResult>();

        for w in 0..self.workers {
            let (tx, rx): (SyncSender<LiveCmd>, Receiver<LiveCmd>) =
                sync_channel(self.queue_depth);
            senders.push(tx);
            let ladder = self.ladder.clone();
            let out_tx = out_tx.clone();
            let cfg = WorkerCfg {
                idle_precompute: self.idle_precompute,
                batching: self.batching,
                max_pending: self.queue_depth,
                adaptive: self.adaptive.clone(),
                obs: self.telemetry.as_ref().map(|t| t.worker(w)),
                reload: self.reload.clone(),
                live: None,
                idle_poll_ms: self.idle_poll_ms,
            };
            handles.push(thread::spawn(move || {
                worker_loop(ladder, rx, out_tx, cfg);
            }));
        }
        drop(out_tx);

        // Dispatch: interleave streams round-robin frame by frame so
        // workers see concurrent traffic (not stream-after-stream).
        let max_len = streams.iter().map(|s| s.len()).max().unwrap_or(0);
        for t in 0..max_len {
            let gap = gap_us.get(t).or(gap_us.last()).copied().unwrap_or(0);
            if gap > 0 {
                thread::sleep(Duration::from_micros(gap));
            }
            for (sid, frames) in streams.iter().enumerate() {
                if t < frames.len() {
                    let job = FrameJob {
                        stream_id: sid as u64,
                        frame: frames[t].clone(),
                        last: t + 1 == frames.len(),
                        trace: None,
                    };
                    senders[sid % self.workers]
                        .send(LiveCmd::Frame(job))
                        .map_err(|_| anyhow!("worker {} died", sid % self.workers))?;
                }
            }
        }
        drop(senders);

        let mut metrics = StreamMetrics::new();
        let mut outputs = HashMap::new();
        let mut final_levels = HashMap::new();
        let mut frames = 0u64;
        let mut arena_peak_by_variant: HashMap<String, u64> = HashMap::new();
        let mut arena_peak_bytes = 0u64;
        let mut generation = 0u64;
        for res in out_rx {
            match res? {
                WorkerMsg::Stream {
                    id,
                    metrics: m,
                    outs,
                    rung,
                } => {
                    frames += m.frames;
                    metrics.merge(&m);
                    outputs.insert(id, outs);
                    final_levels.insert(id, rung);
                }
                WorkerMsg::Done {
                    arena_peaks,
                    thread_peak,
                    generation: g,
                } => {
                    for (name, bytes) in arena_peaks {
                        let slot = arena_peak_by_variant.entry(name).or_insert(0);
                        *slot = (*slot).max(bytes);
                    }
                    arena_peak_bytes = arena_peak_bytes.max(thread_peak);
                    generation = generation.max(g);
                }
            }
        }
        for h in handles {
            h.join().map_err(|_| anyhow!("worker panicked"))?;
        }
        Ok(ServeReport {
            metrics,
            outputs,
            final_levels,
            wall_seconds: t0.elapsed().as_secs_f64(),
            frames,
            arena_peak_by_variant,
            arena_peak_bytes,
            generation,
        })
    }

    /// Start the worker pool in **live mode** (DESIGN.md §14): instead
    /// of a fixed stream set driven to completion, the returned handle
    /// accepts [`LiveCmd`]s for the lifetime of the pool and streams
    /// [`LiveEvent`]s back as frames are served.  This is the engine a
    /// network shard wraps ([`crate::net::shard`]): frames arrive from
    /// the wire, outputs leave for the wire, and migrated sessions are
    /// admitted mid-stream with §9 history replay.
    ///
    /// Sharding, batching, adaptive control, telemetry and hot reload
    /// all behave exactly as in [`Server::run`] — live mode changes
    /// only how work arrives and how outputs leave.
    pub fn start_live(&self) -> LiveServer {
        let (ev_tx, ev_rx) = channel::<LiveEvent>();
        let (out_tx, out_rx) = channel::<WorkerResult>();
        let mut senders = Vec::new();
        let mut handles = Vec::new();
        for w in 0..self.workers {
            let (tx, rx): (SyncSender<LiveCmd>, Receiver<LiveCmd>) =
                sync_channel(self.queue_depth);
            senders.push(tx);
            let ladder = self.ladder.clone();
            let out_tx = out_tx.clone();
            let cfg = WorkerCfg {
                idle_precompute: self.idle_precompute,
                batching: self.batching,
                max_pending: self.queue_depth,
                adaptive: self.adaptive.clone(),
                obs: self.telemetry.as_ref().map(|t| t.worker(w)),
                reload: self.reload.clone(),
                live: Some(ev_tx.clone()),
                idle_poll_ms: self.idle_poll_ms,
            };
            handles.push(thread::spawn(move || {
                worker_loop(ladder, rx, out_tx, cfg);
            }));
        }
        LiveServer {
            senders,
            events: Some(ev_rx),
            out_rx,
            handles,
        }
    }

    /// The variant ladder this server serves (rung 0 admits new
    /// streams; other rungs are reachable via [`Server::adaptive`]).
    pub fn ladder(&self) -> &Arc<VariantLadder> {
        &self.ladder
    }
}

/// Handle to a live worker pool ([`Server::start_live`]).
pub struct LiveServer {
    senders: Vec<SyncSender<LiveCmd>>,
    events: Option<Receiver<LiveEvent>>,
    out_rx: Receiver<WorkerResult>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl LiveServer {
    /// Number of worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.senders.len()
    }

    /// The worker a stream id is sharded to (`id % workers` — the same
    /// affinity [`Server::run`] uses, so live and batch serving place
    /// streams identically).
    pub fn worker_of(&self, stream_id: u64) -> usize {
        (stream_id % self.senders.len() as u64) as usize
    }

    /// Route a command to its stream's worker.  Blocks when that
    /// worker's bounded queue is full (the same backpressure batch
    /// dispatch exerts); fails only if the worker died.
    pub fn submit(&self, cmd: LiveCmd) -> Result<()> {
        let id = match &cmd {
            LiveCmd::Frame(job) => job.stream_id,
            LiveCmd::Resume { stream_id, .. } => *stream_id,
            LiveCmd::Forget { stream_id } => *stream_id,
        };
        let w = self.worker_of(id);
        self.senders[w]
            .send(cmd)
            .map_err(|_| anyhow!("worker {w} died"))
    }

    /// Take ownership of the pool's event stream (outputs,
    /// retirements, resume rejections, fatal worker errors) so a
    /// consumer thread can drain it independently of the handle.
    /// `None` once taken.
    pub fn take_events(&mut self) -> Option<Receiver<LiveEvent>> {
        self.events.take()
    }

    /// Close the command queues, wait for every worker to exit and
    /// return the pool-wide aggregated stream metrics.
    pub fn shutdown(self) -> Result<StreamMetrics> {
        drop(self.senders);
        drop(self.events);
        let mut metrics = StreamMetrics::new();
        for res in self.out_rx {
            if let WorkerMsg::Stream { metrics: m, .. } = res? {
                metrics.merge(&m);
            }
        }
        for h in self.handles {
            h.join().map_err(|_| anyhow!("worker panicked"))?;
        }
        Ok(metrics)
    }
}

/// What a worker sends back on the result channel.
enum WorkerMsg {
    /// One retired stream: id, metrics, outputs and the ladder rung it
    /// retired on.
    Stream {
        id: u64,
        metrics: StreamMetrics,
        outs: Vec<Vec<f32>>,
        rung: usize,
    },
    /// Worker exit summary: per-variant scratch-arena high-water marks
    /// observed on the worker's thread (variant name, peak bytes), their
    /// sum, and the weight generation the worker ended on (0 without hot
    /// reload).  Arenas are thread-local, so only the worker itself can
    /// read them — sent exactly once, after the last stream retires.
    Done {
        arena_peaks: Vec<(String, u64)>,
        thread_peak: u64,
        generation: u64,
    },
}

/// Worker result-channel payload (errors abort the run).
type WorkerResult = Result<WorkerMsg>;

/// Per-worker configuration captured at spawn time.
struct WorkerCfg {
    idle_precompute: bool,
    batching: bool,
    max_pending: usize,
    adaptive: Option<AdaptivePolicy>,
    /// The worker's telemetry handle (None runs unobserved).
    obs: Option<ObsHandle>,
    /// Hot-reload slot shared with the publisher (None serves one fixed
    /// generation forever).
    reload: Option<ReloadHandle>,
    /// Live-mode event channel ([`Server::start_live`]): when set,
    /// outputs stream out as [`LiveEvent::Out`] instead of
    /// accumulating in the slot, and serving errors are reported as
    /// [`LiveEvent::Fatal`] instead of aborting a batch run.
    live: Option<Sender<LiveEvent>>,
    /// Idle-poll step (ms) while hot reload is enabled
    /// ([`Server::idle_poll_ms`]).
    idle_poll_ms: u64,
}

/// Route a worker error to whichever channel the mode uses.
fn report_err(
    live: &Option<Sender<LiveEvent>>,
    out_tx: &Sender<WorkerResult>,
    e: anyhow::Error,
) {
    if let Some(tx) = live {
        let _ = tx.send(LiveEvent::Fatal {
            reason: format!("{e:#}"),
        });
    } else {
        let _ = out_tx.send(Err(e));
    }
}

/// Record the worker-side spans of one traced frame (DESIGN.md §15):
/// `worker_round` (the round serving it, duration = round-so-far ns)
/// under the incoming context, then `phase_exec` (the dispatch group's
/// backend execution) under it.  One registry lock for both.
#[allow(clippy::too_many_arguments)]
fn record_serve_spans(
    obs: &ObsHandle,
    ctx: TraceCtx,
    session: u64,
    rung: usize,
    phase: usize,
    width: u64,
    round_ns: u64,
    exec_ns: u64,
) {
    obs.with(|w| {
        w.span(
            ctx.trace_id,
            SpanKind::WorkerRound,
            ctx.kind,
            session,
            width,
            round_ns,
        );
        w.span(
            ctx.trace_id,
            SpanKind::PhaseExec,
            SpanKind::WorkerRound as u8,
            ((rung as u64) << 16) | phase as u64,
            width,
            exec_ns,
        );
    });
}

/// Per-stream serving state owned by one worker.
struct Slot {
    sess: StreamSession,
    /// Ladder rung the session currently serves on (kept in lockstep
    /// with the session's engine: updated exactly when a switch lands).
    rung: usize,
    /// Weight generation the session currently serves on (0 without hot
    /// reload); sessions lagging the worker's adopted generation request
    /// a cross-generation switch each round until it lands.
    gen: u64,
    outs: Vec<Vec<f32>>,
    /// Frames received but not yet served (at most one is served per
    /// round so batches never reorder a stream against itself), each
    /// with its trace context (`None` for unsampled frames).
    pending: VecDeque<(Arc<[f32]>, Option<TraceCtx>)>,
    /// The stream's final frame has been enqueued.
    closing: bool,
}

/// Select disjoint `&mut` references to the slots at `idxs` (strictly
/// increasing indices) — the safe split_at_mut dance.
fn select_mut<'a>(slots: &'a mut [Slot], idxs: &[usize]) -> Vec<&'a mut Slot> {
    let mut out = Vec::with_capacity(idxs.len());
    let mut rest = slots;
    let mut base = 0usize;
    for &i in idxs {
        let (_, tail) = rest.split_at_mut(i - base);
        let (head, tail2) = tail.split_at_mut(1);
        out.push(&mut head[0]);
        rest = tail2;
        base = i + 1;
    }
    out
}

fn worker_loop(
    ladder: Arc<VariantLadder>,
    rx: Receiver<LiveCmd>,
    out_tx: Sender<WorkerResult>,
    cfg: WorkerCfg,
) {
    let WorkerCfg {
        idle_precompute,
        batching,
        max_pending,
        adaptive,
        obs,
        reload,
        live,
        idle_poll_ms,
    } = cfg;
    // With hot reload enabled, the handle's current generation is the
    // starting ladder (the server seeds it with its own ladder, so this
    // is a no-op unless a publish already happened).
    let mut ladder = ladder;
    let mut gen_seq = 0u64;
    let mut seen_epoch = 0u64;
    if let Some(rh) = &reload {
        seen_epoch = rh.epoch();
        let g = rh.current();
        ladder = g.ladder.clone();
        gen_seq = g.seq;
    }
    let mut weights: Arc<DeviceWeights> = match ladder.device_weights() {
        Ok(w) => Arc::new(w),
        Err(e) => {
            report_err(&live, &out_tx, e);
            return;
        }
    };
    let mut controller = if ladder.len() > 1 {
        adaptive.map(LoadController::new)
    } else {
        None
    };
    // Adaptive serving retains the receptive-field history every rung
    // could need for warm re-priming; generation reload needs the same
    // retention to re-prime onto new weights.  Without either, no stream
    // can ever migrate, so retain nothing.
    let history_cap = if controller.is_some() || reload.is_some() {
        ladder.max_warmup()
    } else {
        0
    };
    // The worker-wide target rung the controller steers; sessions catch
    // up to it individually at their next phase-0 boundary.
    let mut target_rung = 0usize;
    let mut slots: Vec<Slot> = Vec::new();
    let mut index: HashMap<u64, usize> = HashMap::new();
    let mut open = true;
    // Undelivered frames across all slots (kept as a running counter —
    // the drain loop checks it once per received frame).
    let mut pending_total = 0usize;
    // Round-scoped dispatch buffers, reused across every round: the
    // sorted (rung, phase, slot) key list, the current group's slot
    // indices and frames, and the batched-output holder the group
    // results land in.  (The per-group `&mut` session/frame-ref views
    // still allocate small vectors — their lifetimes are tied to the
    // group's slot borrows — so only the *exec* layer below is strictly
    // allocation-free; see tests/hot_path_alloc.rs.)
    let mut keyed: Vec<(u64, usize, usize, usize)> = Vec::new();
    let mut group: Vec<usize> = Vec::new();
    let mut group_frames: Vec<Arc<[f32]>> = Vec::new();
    let mut group_traces: Vec<Option<TraceCtx>> = Vec::new();
    let mut outs_buf: Vec<Vec<f32>> = Vec::new();

    // `ladder`/`weights`/`gen_seq` are passed per call (not captured):
    // a generation adoption swaps them mid-run, and new streams must
    // start on whatever generation the worker currently serves.
    let handle_cmd = |slots: &mut Vec<Slot>,
                      index: &mut HashMap<u64, usize>,
                      pending_total: &mut usize,
                      cmd: LiveCmd,
                      ladder: &Arc<VariantLadder>,
                      weights: &Arc<DeviceWeights>,
                      gen_seq: u64| {
        match cmd {
            LiveCmd::Frame(job) => {
                let i = *index.entry(job.stream_id).or_insert_with(|| {
                    let mut sess = StreamSession::new(
                        job.stream_id,
                        ladder.level(0).clone(),
                        weights.clone(),
                    );
                    sess.set_history_cap(history_cap);
                    sess.set_obs(obs.clone());
                    slots.push(Slot {
                        sess,
                        rung: 0,
                        gen: gen_seq,
                        outs: Vec::new(),
                        pending: VecDeque::new(),
                        closing: false,
                    });
                    slots.len() - 1
                });
                slots[i].pending.push_back((job.frame, job.trace));
                slots[i].closing |= job.last;
                *pending_total += 1;
            }
            LiveCmd::Resume {
                stream_id,
                t,
                history,
                trace,
            } => {
                // §9 replay admission (DESIGN.md §14): everything is
                // validated inside `StreamSession::resume` before any
                // state exists, so a bad migrate constructs nothing
                // and the worker's other sessions never notice.
                if index.contains_key(&stream_id) {
                    if let Some(tx) = &live {
                        let _ = tx.send(LiveEvent::ResumeFailed {
                            id: stream_id,
                            reason: "session already live on this worker".to_string(),
                        });
                    }
                    return;
                }
                let replay = history.len();
                let t_mig = Instant::now();
                match StreamSession::resume(
                    stream_id,
                    ladder.level(0).clone(),
                    weights.clone(),
                    t,
                    history,
                ) {
                    Ok(mut sess) => {
                        sess.set_history_cap(history_cap);
                        sess.set_obs(obs.clone());
                        if let Some(obs) = &obs {
                            let replay_ns = t_mig.elapsed().as_nanos() as u64;
                            obs.shard_migrate(stream_id, t, replay, replay_ns);
                            if let Some(ctx) = trace {
                                // leaf of the migration trace: the
                                // destination shard's replay
                                obs.span(
                                    ctx.trace_id,
                                    SpanKind::MigrateReplay,
                                    ctx.kind,
                                    stream_id,
                                    t,
                                    replay_ns,
                                );
                            }
                        }
                        index.insert(stream_id, slots.len());
                        slots.push(Slot {
                            sess,
                            rung: 0,
                            gen: gen_seq,
                            outs: Vec::new(),
                            pending: VecDeque::new(),
                            closing: false,
                        });
                    }
                    Err(e) => {
                        if let Some(tx) = &live {
                            let _ = tx.send(LiveEvent::ResumeFailed {
                                id: stream_id,
                                reason: format!("{e:#}"),
                            });
                        }
                    }
                }
            }
            LiveCmd::Forget { stream_id } => {
                if let Some(i) = index.remove(&stream_id) {
                    *pending_total -= slots[i].pending.len();
                    let slot = slots.swap_remove(i);
                    if let Some(moved) = slots.get(i) {
                        index.insert(moved.sess.id, i);
                    }
                    if let Some(tx) = &live {
                        let _ = tx.send(LiveEvent::Retired {
                            id: slot.sess.id,
                            metrics: slot.sess.metrics.clone(),
                            rung: slot.rung,
                        });
                    }
                    let _ = out_tx.send(Ok(WorkerMsg::Stream {
                        id: slot.sess.id,
                        metrics: slot.sess.metrics.clone(),
                        outs: slot.outs,
                        rung: slot.rung,
                    }));
                }
            }
        }
    };

    loop {
        // 0. generation adoption (DESIGN.md §13): one relaxed epoch read
        //    per round; when the publisher moved it, upload the new
        //    generation's weights side by side with the old and switch
        //    the worker's serving ladder.  Live sessions stay on their
        //    old (still-uploaded) generation until their §9 re-priming
        //    lands below — nothing glitches at adoption time.
        if let Some(rh) = &reload {
            let e = rh.epoch();
            if e != seen_epoch {
                seen_epoch = e;
                let next = rh.current();
                if next.seq != gen_seq {
                    let t_reload = Instant::now();
                    match next.ladder.device_weights() {
                        Ok(w) => {
                            let from = gen_seq;
                            gen_seq = next.seq;
                            ladder = next.ladder.clone();
                            weights = Arc::new(w);
                            // the new ladder's rung count may differ
                            target_rung = target_rung.min(ladder.len() - 1);
                            if let Some(obs) = &obs {
                                obs.gen_reload(
                                    from,
                                    gen_seq,
                                    slots.len(),
                                    t_reload.elapsed().as_nanos() as u64,
                                );
                            }
                        }
                        Err(e) => {
                            report_err(&live, &out_tx, e);
                            return;
                        }
                    }
                }
            }
        }

        // 1. drain the queue without blocking — but keep at most
        //    `max_pending` undelivered frames locally, so the bounded
        //    channel keeps exerting backpressure on the dispatcher
        while open && pending_total < max_pending {
            match rx.try_recv() {
                Ok(cmd) => handle_cmd(
                    &mut slots,
                    &mut index,
                    &mut pending_total,
                    cmd,
                    &ladder,
                    &weights,
                    gen_seq,
                ),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => open = false,
            }
        }

        // 2. nothing pending? do idle FP work, then block for the next job
        if pending_total == 0 {
            if !open {
                break;
            }
            if idle_precompute {
                let mut did = false;
                for slot in slots.iter_mut() {
                    match slot.sess.idle() {
                        Ok(worked) => did |= worked,
                        Err(e) => {
                            report_err(&live, &out_tx, e);
                            return;
                        }
                    }
                }
                if did {
                    continue; // re-poll the queue after useful work
                }
            }
            if reload.is_some() {
                // block in short steps so a publish lands promptly even
                // on a momentarily idle worker
                match rx.recv_timeout(Duration::from_millis(idle_poll_ms.max(1))) {
                    Ok(cmd) => handle_cmd(
                        &mut slots,
                        &mut index,
                        &mut pending_total,
                        cmd,
                        &ladder,
                        &weights,
                        gen_seq,
                    ),
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => open = false,
                }
            } else {
                match rx.recv() {
                    Ok(cmd) => handle_cmd(
                        &mut slots,
                        &mut index,
                        &mut pending_total,
                        cmd,
                        &ladder,
                        &weights,
                        gen_seq,
                    ),
                    Err(_) => open = false,
                }
            }
            continue;
        }

        // 3a. generation catch-up (DESIGN.md §13): sessions still on an
        //     older generation request a cross-generation switch — the
        //     current ladder's rung plus the new weight upload — and
        //     apply it at their next phase-0 boundary.  §9 re-priming
        //     replays their retained history through the new generation,
        //     so post-swap output is bit-identical to a session that
        //     lived its whole life there.
        if reload.is_some() {
            for slot in slots.iter_mut() {
                if slot.gen == gen_seq {
                    continue;
                }
                let want = target_rung.min(ladder.len() - 1);
                slot.sess
                    .request_switch_with_weights(ladder.level(want).clone(), weights.clone());
                let replay = slot.sess.history_len();
                let t_mig = Instant::now();
                match slot.sess.try_switch() {
                    Ok(true) => {
                        if let Some(obs) = &obs {
                            obs.migration(
                                slot.sess.id,
                                slot.rung,
                                want,
                                replay,
                                t_mig.elapsed().as_nanos() as u64,
                            );
                        }
                        slot.rung = want;
                        slot.gen = gen_seq;
                    }
                    Ok(false) => {}
                    Err(e) => {
                        report_err(&live, &out_tx, e);
                        return;
                    }
                }
            }
        }

        // 3. adaptive control, apply side: sessions lagging behind the
        //    controller's target rung request the switch and apply it at
        //    their next phase-0 boundary (warm re-priming inside
        //    `try_switch` — DESIGN.md §9).  Sessions still catching up
        //    to a newer generation are owned by 3a above — their pending
        //    switch carries the new weights and must not be clobbered.
        if controller.is_some() {
            for slot in slots.iter_mut() {
                if slot.gen != gen_seq {
                    continue;
                }
                if slot.rung != target_rung {
                    slot.sess.request_switch(ladder.level(target_rung).clone());
                    let replay = slot.sess.history_len();
                    let t_mig = Instant::now();
                    match slot.sess.try_switch() {
                        Ok(true) => {
                            if let Some(obs) = &obs {
                                obs.migration(
                                    slot.sess.id,
                                    slot.rung,
                                    target_rung,
                                    replay,
                                    t_mig.elapsed().as_nanos() as u64,
                                );
                            }
                            slot.rung = target_rung;
                        }
                        Ok(false) => {}
                        Err(e) => {
                            report_err(&live, &out_tx, e);
                            return;
                        }
                    }
                } else if slot.sess.switch_pending() {
                    // the controller reversed course before the boundary
                    // arrived — cancel the now-stale request
                    slot.sess.request_switch(ladder.level(slot.rung).clone());
                }
            }
        }

        // 4. serve one round: at most one pending frame per stream,
        //    grouped into (rung, phase)-aligned batches — sessions mid-
        //    switch still sit on their old rung, so every group shares
        //    one compiled variant by construction
        let t_round = Instant::now();
        let mut served = 0u64;
        if batching {
            // Group by sorting a reused (generation, rung, phase, slot)
            // key list — same visit order and ascending slot order
            // within a group as the BTreeMap this replaces, without its
            // per-round node churn.  Generation leads the key so
            // sessions mid-reload (still on the old ladder and weights)
            // never batch with sessions already on the new one.
            keyed.clear();
            for (i, slot) in slots.iter().enumerate() {
                if !slot.pending.is_empty() {
                    keyed.push((slot.gen, slot.rung, slot.sess.next_plan().phase, i));
                }
            }
            keyed.sort_unstable();
            let mut g0 = 0usize;
            while g0 < keyed.len() {
                let (gen, rung, phase, _) = keyed[g0];
                let mut g1 = g0 + 1;
                while g1 < keyed.len()
                    && keyed[g1].0 == gen
                    && keyed[g1].1 == rung
                    && keyed[g1].2 == phase
                {
                    g1 += 1;
                }
                group.clear();
                group_frames.clear();
                group_traces.clear();
                for &(_, _, _, i) in &keyed[g0..g1] {
                    group.push(i);
                    let (frame, trace) = slots[i].pending.pop_front().unwrap();
                    group_frames.push(frame);
                    group_traces.push(trace);
                    pending_total -= 1;
                }
                let frame_refs: Vec<&[f32]> = group_frames.iter().map(|f| &f[..]).collect();
                let t_exec = Instant::now();
                let res = {
                    let mut selected = select_mut(&mut slots, &group);
                    let mut sessions: Vec<&mut StreamSession> =
                        selected.iter_mut().map(|s| &mut s.sess).collect();
                    StreamSession::on_frame_batch_into(&mut sessions, &frame_refs, &mut outs_buf)
                };
                match res {
                    Ok(()) => {
                        let ns = t_exec.elapsed().as_nanos() as u64;
                        if let Some(ctl) = controller.as_mut() {
                            for _ in 0..group.len() {
                                ctl.record_latency_ns(ns);
                            }
                        }
                        if let Some(obs) = &obs {
                            obs.exec(rung, phase, group.len(), ns);
                        }
                        served += group.len() as u64;
                        for (k, (&i, out)) in
                            group.iter().zip(outs_buf.drain(..)).enumerate()
                        {
                            // traced frame: record worker_round +
                            // phase_exec spans and echo the leaf
                            // context on the output (DESIGN.md §15).
                            // Untraced frames take the `None` branch —
                            // no lock, no allocation.
                            let out_trace = group_traces[k].map(|ctx| {
                                if let Some(obs) = &obs {
                                    record_serve_spans(
                                        obs,
                                        ctx,
                                        slots[i].sess.id,
                                        rung,
                                        phase,
                                        group.len() as u64,
                                        t_round.elapsed().as_nanos() as u64,
                                        ns,
                                    );
                                }
                                ctx.child(SpanKind::WorkerRound).child(SpanKind::PhaseExec)
                            });
                            if let Some(tx) = &live {
                                let _ = tx.send(LiveEvent::Out {
                                    id: slots[i].sess.id,
                                    seq: slots[i].sess.frames_seen() - 1,
                                    frame: out,
                                    trace: out_trace,
                                });
                            } else {
                                slots[i].outs.push(out);
                            }
                        }
                    }
                    Err(e) => {
                        report_err(&live, &out_tx, e);
                        return;
                    }
                }
                g0 = g1;
            }
        } else {
            for slot in slots.iter_mut() {
                if let Some((frame, trace)) = slot.pending.pop_front() {
                    pending_total -= 1;
                    let phase = slot.sess.next_plan().phase;
                    let t_exec = Instant::now();
                    match slot.sess.on_frame(&frame) {
                        Ok(out) => {
                            let ns = t_exec.elapsed().as_nanos() as u64;
                            if let Some(ctl) = controller.as_mut() {
                                ctl.record_latency_ns(ns);
                            }
                            if let Some(obs) = &obs {
                                obs.exec(slot.rung, phase, 1, ns);
                            }
                            served += 1;
                            let out_trace = trace.map(|ctx| {
                                if let Some(obs) = &obs {
                                    record_serve_spans(
                                        obs,
                                        ctx,
                                        slot.sess.id,
                                        slot.rung,
                                        phase,
                                        1,
                                        t_round.elapsed().as_nanos() as u64,
                                        ns,
                                    );
                                }
                                ctx.child(SpanKind::WorkerRound).child(SpanKind::PhaseExec)
                            });
                            if let Some(tx) = &live {
                                let _ = tx.send(LiveEvent::Out {
                                    id: slot.sess.id,
                                    seq: slot.sess.frames_seen() - 1,
                                    frame: out,
                                    trace: out_trace,
                                });
                            } else {
                                slot.outs.push(out);
                            }
                        }
                        Err(e) => {
                            report_err(&live, &out_tx, e);
                            return;
                        }
                    }
                }
            }
        }

        // 5. adaptive control, observe side: one observation per round,
        //    *after* serving — `pending_total` is now the backlog the
        //    round could not clear (0 when the worker keeps up, large
        //    under overload), which makes the queue signal independent
        //    of how many streams happen to arrive per round
        if let Some(ctl) = controller.as_mut() {
            if let Some(d) = ctl.observe_round(pending_total, target_rung, ladder.len() - 1) {
                target_rung = d.to;
                if let Some(obs) = &obs {
                    obs.with(|w| {
                        let counter = if d.is_degrade() {
                            Counter::CtlDegrades
                        } else {
                            Counter::CtlRecovers
                        };
                        w.count(counter, 1);
                        w.push_event(
                            EventKind::CtlDecision,
                            d.from as u64,
                            d.to as u64,
                            d.trigger.code(),
                            d.backlog as u64,
                            d.p99_us,
                        );
                    });
                }
            }
        }

        // round record: counters + gauges + a Round event, one lock
        if let Some(obs) = &obs {
            let round_ns = t_round.elapsed().as_nanos() as u64;
            let arena_peak = crate::kernels::thread_peak_bytes() as u64;
            obs.with(|w| {
                w.count(Counter::Rounds, 1);
                w.push_event(
                    EventKind::Round,
                    served,
                    pending_total as u64,
                    slots.len() as u64,
                    round_ns,
                    0,
                );
                w.gauge_set(Gauge::QueueDepth, pending_total as u64);
                w.gauge_set(Gauge::TargetRung, target_rung as u64);
                w.gauge_set(Gauge::StreamsLive, slots.len() as u64);
                w.gauge_set(Gauge::Generation, gen_seq);
                w.gauge_max(Gauge::ArenaPeakBytes, arena_peak);
            });
        }

        // 6. retire streams whose last frame has been served
        let mut i = 0;
        while i < slots.len() {
            if slots[i].closing && slots[i].pending.is_empty() {
                let slot = slots.swap_remove(i);
                index.remove(&slot.sess.id);
                if let Some(moved) = slots.get(i) {
                    index.insert(moved.sess.id, i);
                }
                if let Some(tx) = &live {
                    let _ = tx.send(LiveEvent::Retired {
                        id: slot.sess.id,
                        metrics: slot.sess.metrics.clone(),
                        rung: slot.rung,
                    });
                }
                let _ = out_tx.send(Ok(WorkerMsg::Stream {
                    id: slot.sess.id,
                    metrics: slot.sess.metrics.clone(),
                    outs: slot.outs,
                    rung: slot.rung,
                }));
            } else {
                i += 1;
            }
        }
    }

    // flush any sessions that never saw a `last` marker
    for slot in slots {
        let _ = out_tx.send(Ok(WorkerMsg::Stream {
            id: slot.sess.id,
            metrics: slot.sess.metrics.clone(),
            outs: slot.outs,
            rung: slot.rung,
        }));
    }

    // exit summary: scratch arenas are thread-local, so the per-variant
    // high-water marks can only be read here, on the worker's own thread
    let mut arena_peaks: Vec<(String, u64)> = Vec::new();
    for level in 0..ladder.len() {
        let cv = ladder.level(level);
        if let Some(id) = cv.arena_id() {
            if let Some(bytes) = crate::kernels::peak_bytes_of(id) {
                arena_peaks.push((cv.manifest.name.clone(), bytes as u64));
            }
        }
    }
    let thread_peak = crate::kernels::thread_peak_bytes() as u64;
    let _ = out_tx.send(Ok(WorkerMsg::Done {
        arena_peaks,
        thread_peak,
        generation: gen_seq,
    }));
}
