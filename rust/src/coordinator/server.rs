//! Multi-stream serving: a worker pool sharding streams by id, with
//! bounded queues for backpressure and aggregated metrics.
//!
//! tokio is unavailable offline (DESIGN.md §5); the pool uses std threads
//! and mpsc channels, which is a good fit anyway — backend execution is
//! synchronous, so one OS thread per worker with its own stream shard is
//! the natural topology (the vLLM-router-style design scaled down to
//! frame-level requests).
//!
//! `CompiledVariant` is `Send + Sync` through the `VariantExec` trait
//! bound (the pjrt implementation asserts PJRT's thread-safety contract
//! itself), so workers share one `Arc<CompiledVariant>` directly; all
//! mutation on the rust side (states, metrics) stays worker-local.

use std::collections::HashMap;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread;

use anyhow::{anyhow, Result};

use super::metrics::StreamMetrics;
use super::stream::StreamSession;
use crate::runtime::CompiledVariant;

/// One frame of work for a stream.
pub struct FrameJob {
    pub stream_id: u64,
    pub frame: Vec<f32>,
    /// Marks the last frame of the stream (flush + report).
    pub last: bool,
}

/// Output frame handed back to the caller.
pub struct FrameOut {
    pub stream_id: u64,
    pub seq: u64,
    pub data: Vec<f32>,
}

/// Serving summary returned by [`Server::run`].
pub struct ServeReport {
    pub metrics: StreamMetrics,
    pub outputs: HashMap<u64, Vec<Vec<f32>>>,
    pub wall_seconds: f64,
    pub frames: u64,
}

impl ServeReport {
    pub fn throughput_fps(&self) -> f64 {
        if self.wall_seconds == 0.0 {
            0.0
        } else {
            self.frames as f64 / self.wall_seconds
        }
    }
}

/// Multi-stream server over one compiled SOI variant.
pub struct Server {
    engine: Arc<CompiledVariant>,
    workers: usize,
    queue_depth: usize,
    /// Run the FP idle/precompute pass between frames (on by default;
    /// turning it off measures the non-overlapped latency for Table 2).
    pub idle_precompute: bool,
}

impl Server {
    pub fn new(engine: Arc<CompiledVariant>, workers: usize) -> Server {
        Server {
            engine,
            workers: workers.max(1),
            queue_depth: 64,
            idle_precompute: true,
        }
    }

    /// Serve a fixed set of streams to completion (throughput mode): every
    /// stream's frames are queued as fast as workers drain them.
    ///
    /// Streams are sharded across workers by `stream_id % workers`; each
    /// worker owns its sessions exclusively (no locks on the hot path).
    pub fn run(&self, streams: &[Vec<Vec<f32>>]) -> Result<ServeReport> {
        let t0 = std::time::Instant::now();
        let mut senders: Vec<SyncSender<FrameJob>> = Vec::new();
        let mut handles = Vec::new();
        let (out_tx, out_rx) = sync_channel::<Result<(u64, StreamMetrics, Vec<Vec<f32>>)>>(
            self.workers * 4,
        );

        for w in 0..self.workers {
            let (tx, rx): (SyncSender<FrameJob>, Receiver<FrameJob>) =
                sync_channel(self.queue_depth);
            senders.push(tx);
            let engine = self.engine.clone();
            let out_tx = out_tx.clone();
            let idle = self.idle_precompute;
            handles.push(thread::spawn(move || {
                worker_loop(w, engine, rx, out_tx, idle);
            }));
        }
        drop(out_tx);

        // Dispatch: interleave streams round-robin frame by frame so
        // workers see concurrent traffic (not stream-after-stream).
        let max_len = streams.iter().map(|s| s.len()).max().unwrap_or(0);
        for t in 0..max_len {
            for (sid, frames) in streams.iter().enumerate() {
                if t < frames.len() {
                    let job = FrameJob {
                        stream_id: sid as u64,
                        frame: frames[t].clone(),
                        last: t + 1 == frames.len(),
                    };
                    senders[sid % self.workers]
                        .send(job)
                        .map_err(|_| anyhow!("worker {} died", sid % self.workers))?;
                }
            }
        }
        drop(senders);

        let mut metrics = StreamMetrics::new();
        let mut outputs = HashMap::new();
        let mut frames = 0u64;
        for res in out_rx {
            let (sid, m, outs) = res?;
            frames += m.frames;
            metrics.merge(&m);
            outputs.insert(sid, outs);
        }
        for h in handles {
            h.join().map_err(|_| anyhow!("worker panicked"))?;
        }
        Ok(ServeReport {
            metrics,
            outputs,
            wall_seconds: t0.elapsed().as_secs_f64(),
            frames,
        })
    }
}

fn worker_loop(
    _worker_id: usize,
    cv: Arc<CompiledVariant>,
    rx: Receiver<FrameJob>,
    out_tx: SyncSender<Result<(u64, StreamMetrics, Vec<Vec<f32>>)>>,
    idle_precompute: bool,
) {
    let weights = match cv.device_weights() {
        Ok(w) => Arc::new(w),
        Err(e) => {
            let _ = out_tx.send(Err(e));
            return;
        }
    };
    let mut sessions: HashMap<u64, (StreamSession, Vec<Vec<f32>>)> = HashMap::new();

    loop {
        // Idle gap: run FP precompute for any session that is waiting.
        // try_recv first so a ready frame always wins over idle work.
        let job = match rx.try_recv() {
            Ok(j) => j,
            Err(std::sync::mpsc::TryRecvError::Empty) => {
                if idle_precompute {
                    let mut did = false;
                    for (sess, _) in sessions.values_mut() {
                        match sess.idle() {
                            Ok(worked) => did |= worked,
                            Err(e) => {
                                let _ = out_tx.send(Err(e));
                                return;
                            }
                        }
                    }
                    if did {
                        continue; // re-poll the queue after useful work
                    }
                }
                match rx.recv() {
                    Ok(j) => j,
                    Err(_) => break, // channel closed: all frames dispatched
                }
            }
            Err(std::sync::mpsc::TryRecvError::Disconnected) => break,
        };

        let sid = job.stream_id;
        let entry = sessions.entry(sid).or_insert_with(|| {
            (
                StreamSession::new(sid, cv.clone(), weights.clone()),
                Vec::new(),
            )
        });
        match entry.0.on_frame(&job.frame) {
            Ok(out) => entry.1.push(out),
            Err(e) => {
                let _ = out_tx.send(Err(e));
                return;
            }
        }
        if job.last {
            let (sess, outs) = sessions.remove(&sid).unwrap();
            let _ = out_tx.send(Ok((sid, sess.metrics.clone(), outs)));
        }
    }
    // flush any sessions that never saw a `last` marker
    for (sid, (sess, outs)) in sessions {
        let _ = out_tx.send(Ok((sid, sess.metrics.clone(), outs)));
    }
}
