//! Serving metrics: latency histograms, throughput, executed-MAC
//! accounting, and the measured precompute overlap (which the Table 2
//! driver checks against the analytic "Precomputed %").

use std::collections::BTreeMap;
use std::time::Instant;

use crate::util::stats::{Histogram, Summary};

/// Metrics for one stream (or aggregated across streams via `merge`).
#[derive(Debug, Clone, Default)]
pub struct StreamMetrics {
    /// Wall latency of the on-arrival work (step or rest pass), ns.
    pub arrival_latency: Histogram,
    /// Wall time of the precompute pass (hidden from arrival latency), ns.
    pub precompute_time: Histogram,
    /// Frames processed.
    pub frames: u64,
    /// MACs actually executed (scheduler-aware analytic count).
    pub macs_executed: f64,
    /// MACs a pure STMC model would have executed.
    pub macs_stmc: f64,
    /// Batch widths seen by frames served through the phase-aligned
    /// batched path (one entry per frame, so the mean is the average
    /// batch size a frame experienced; empty when batching is off).
    pub batch_size: Histogram,
    /// Analytic MACs of the inferences whose on-arrival pass ran through
    /// batched dispatch (subset of `macs_executed`; for FP variants this
    /// includes their per-session precompute share — the whole inference
    /// is attributed to the path that served its frame).
    pub macs_batched: f64,
    /// Analytic MACs that executed on the quantized int8 path
    /// (DESIGN.md §10) — a subset of `macs_executed`, including
    /// migration replays into int8 rungs.  `macs_executed - macs_int8`
    /// ran as f32.
    pub macs_int8: f64,
    /// Output quality accumulator (SI-SNR segments), if tracked.
    pub si_snr: Summary,
    /// Warm variant migrations performed (adaptive serving, DESIGN.md
    /// §9); each one re-primed the new rung's states from retained
    /// history.
    pub migrations: u64,
    /// Analytic MACs spent replaying retained history during
    /// migrations.  Also folded into `macs_executed`, so `retain_pct`
    /// reflects the true cost of switching.
    pub macs_migration: f64,
    /// Frames served per variant name — which rung of the ladder a
    /// stream's traffic actually ran on.
    pub variant_frames: BTreeMap<String, u64>,
}

impl StreamMetrics {
    /// Empty metrics.
    pub fn new() -> Self {
        Self {
            si_snr: Summary::new(),
            ..Default::default()
        }
    }

    /// Record the on-arrival work that began at `start` (batched frames
    /// record the whole batch's wall time — what the frame waited for).
    pub fn record_arrival(&mut self, start: Instant) {
        self.arrival_latency
            .record(start.elapsed().as_nanos() as u64);
    }

    /// Record a precompute pass that began at `start`.
    pub fn record_precompute(&mut self, start: Instant) {
        self.precompute_time
            .record(start.elapsed().as_nanos() as u64);
    }

    /// Count one served frame and its analytic MAC cost.
    pub fn record_frame(&mut self, macs_executed: f64, macs_stmc: f64) {
        self.frames += 1;
        self.macs_executed += macs_executed;
        self.macs_stmc += macs_stmc;
    }

    /// Record one frame served through the batched path in a batch of
    /// `bsz` streams executing `macs` MACs for this stream's share.
    pub fn record_batch(&mut self, bsz: u64, macs: f64) {
        self.batch_size.record(bsz);
        self.macs_batched += macs;
    }

    /// Attribute `macs` already counted in `macs_executed` to the
    /// quantized int8 path (call alongside `record_frame` /
    /// `record_migration` when the serving engine's dtype is int8).
    pub fn record_macs_int8(&mut self, macs: f64) {
        self.macs_int8 += macs;
    }

    /// Fraction of executed MACs that ran as int8 (0 when all-f32).
    pub fn int8_fraction(&self) -> f64 {
        if self.macs_executed == 0.0 {
            return 0.0;
        }
        self.macs_int8 / self.macs_executed
    }

    /// Record one warm variant migration whose history replay executed
    /// `macs` analytic MACs (counted in `macs_executed` too — switching
    /// is real work the retention accounting must not hide).
    pub fn record_migration(&mut self, macs: f64) {
        self.migrations += 1;
        self.macs_migration += macs;
        self.macs_executed += macs;
    }

    /// Attribute one served frame to the named variant.
    pub fn record_variant_frame(&mut self, name: &str) {
        if let Some(c) = self.variant_frames.get_mut(name) {
            *c += 1;
        } else {
            self.variant_frames.insert(name.to_string(), 1);
        }
    }

    /// Mean batch width over the frames served by the batched path
    /// (0 when the batched path never ran).
    pub fn mean_batch(&self) -> f64 {
        self.batch_size.mean()
    }

    /// Fraction of executed MACs attributed to batch-served inferences
    /// (see [`StreamMetrics::macs_batched`] for the FP attribution rule).
    pub fn batched_fraction(&self) -> f64 {
        if self.macs_executed == 0.0 {
            return 0.0;
        }
        self.macs_batched / self.macs_executed
    }

    /// Measured complexity retention vs STMC, percent.
    pub fn retain_pct(&self) -> f64 {
        if self.macs_stmc == 0.0 {
            return 100.0;
        }
        100.0 * self.macs_executed / self.macs_stmc
    }

    /// Fraction of total inference work hidden in the precompute slot.
    pub fn hidden_fraction(&self) -> f64 {
        let pre = self.precompute_time.mean() * self.precompute_time.count() as f64;
        let arr = self.arrival_latency.mean() * self.arrival_latency.count() as f64;
        if pre + arr == 0.0 {
            return 0.0;
        }
        pre / (pre + arr)
    }

    /// Fold another stream's metrics into this aggregate.
    pub fn merge(&mut self, other: &StreamMetrics) {
        self.arrival_latency.merge(&other.arrival_latency);
        self.precompute_time.merge(&other.precompute_time);
        self.frames += other.frames;
        self.macs_executed += other.macs_executed;
        self.macs_stmc += other.macs_stmc;
        self.batch_size.merge(&other.batch_size);
        self.macs_batched += other.macs_batched;
        self.macs_int8 += other.macs_int8;
        self.migrations += other.migrations;
        self.macs_migration += other.macs_migration;
        for (name, n) in &other.variant_frames {
            if let Some(c) = self.variant_frames.get_mut(name) {
                *c += n;
            } else {
                self.variant_frames.insert(name.clone(), *n);
            }
        }
        if other.si_snr.count > 0 {
            self.si_snr.count += other.si_snr.count;
            self.si_snr.sum += other.si_snr.sum;
            self.si_snr.min = self.si_snr.min.min(other.si_snr.min);
            self.si_snr.max = self.si_snr.max.max(other.si_snr.max);
        }
    }

    /// One-line human-readable summary.
    pub fn report(&self) -> String {
        format!(
            "frames {:>7}  p50 {:>9}  p95 {:>9}  p99 {:>9}  retain {:>5.1}%  \
             hidden {:>4.1}%  batch \u{3bc} {:>4.1}  migr {:>3}  int8 {:>5.1}%",
            self.frames,
            crate::util::bench::fmt_ns(self.arrival_latency.p50() as f64),
            crate::util::bench::fmt_ns(self.arrival_latency.p95() as f64),
            crate::util::bench::fmt_ns(self.arrival_latency.p99() as f64),
            self.retain_pct(),
            100.0 * self.hidden_fraction(),
            self.mean_batch(),
            self.migrations,
            100.0 * self.int8_fraction(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retain_tracks_ratio() {
        let mut m = StreamMetrics::new();
        m.record_frame(50.0, 100.0);
        m.record_frame(100.0, 100.0);
        assert!((m.retain_pct() - 75.0).abs() < 1e-9);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = StreamMetrics::new();
        let mut b = StreamMetrics::new();
        a.record_frame(1.0, 2.0);
        b.record_frame(3.0, 4.0);
        a.merge(&b);
        assert_eq!(a.frames, 2);
        assert_eq!(a.macs_executed, 4.0);
    }

    #[test]
    fn hidden_fraction_zero_without_precompute() {
        let mut m = StreamMetrics::new();
        m.record_arrival(Instant::now());
        assert_eq!(m.hidden_fraction(), 0.0);
    }

    #[test]
    fn batch_accounting_tracks_width_and_macs() {
        let mut m = StreamMetrics::new();
        m.record_frame(100.0, 200.0);
        m.record_batch(4, 100.0);
        m.record_frame(100.0, 200.0); // unbatched frame
        assert_eq!(m.batch_size.count(), 1);
        assert!((m.mean_batch() - 4.0).abs() < 0.1);
        assert!((m.batched_fraction() - 0.5).abs() < 1e-9);
        let mut other = StreamMetrics::new();
        other.record_frame(50.0, 200.0);
        other.record_batch(8, 50.0);
        m.merge(&other);
        assert_eq!(m.batch_size.count(), 2);
        assert_eq!(m.macs_batched, 150.0);
    }

    #[test]
    fn batched_fraction_zero_when_idle() {
        let m = StreamMetrics::new();
        assert_eq!(m.batched_fraction(), 0.0);
        assert_eq!(m.mean_batch(), 0.0);
    }

    #[test]
    fn migration_accounting_counts_and_charges_macs() {
        let mut m = StreamMetrics::new();
        m.record_frame(100.0, 200.0);
        m.record_migration(40.0);
        assert_eq!(m.migrations, 1);
        assert_eq!(m.macs_migration, 40.0);
        // the replay cost lands in macs_executed: 140 / 200 = 70%
        assert!((m.retain_pct() - 70.0).abs() < 1e-9);
        let mut other = StreamMetrics::new();
        other.record_migration(10.0);
        m.merge(&other);
        assert_eq!(m.migrations, 2);
        assert_eq!(m.macs_migration, 50.0);
    }

    #[test]
    fn int8_mac_attribution_tracks_fraction_and_merges() {
        let mut m = StreamMetrics::new();
        m.record_frame(100.0, 200.0);
        assert_eq!(m.int8_fraction(), 0.0);
        m.record_frame(100.0, 200.0);
        m.record_macs_int8(100.0);
        assert!((m.int8_fraction() - 0.5).abs() < 1e-9);
        let mut other = StreamMetrics::new();
        other.record_frame(50.0, 200.0);
        other.record_macs_int8(50.0);
        m.merge(&other);
        assert_eq!(m.macs_int8, 150.0);
        assert!((m.int8_fraction() - 0.6).abs() < 1e-9);
    }

    #[test]
    fn variant_frames_accumulate_and_merge() {
        let mut a = StreamMetrics::new();
        a.record_variant_frame("stmc");
        a.record_variant_frame("stmc");
        a.record_variant_frame("scc2");
        let mut b = StreamMetrics::new();
        b.record_variant_frame("scc2");
        a.merge(&b);
        assert_eq!(a.variant_frames["stmc"], 2);
        assert_eq!(a.variant_frames["scc2"], 2);
    }
}
