//! Serving metrics: latency histograms, throughput, executed-MAC
//! accounting, and the measured precompute overlap (which the Table 2
//! driver checks against the analytic "Precomputed %").

use std::time::Instant;

use crate::util::stats::{Histogram, Summary};

/// Metrics for one stream (or aggregated across streams via `merge`).
#[derive(Debug, Clone, Default)]
pub struct StreamMetrics {
    /// Wall latency of the on-arrival work (step or rest pass), ns.
    pub arrival_latency: Histogram,
    /// Wall time of the precompute pass (hidden from arrival latency), ns.
    pub precompute_time: Histogram,
    /// Frames processed.
    pub frames: u64,
    /// MACs actually executed (scheduler-aware analytic count).
    pub macs_executed: f64,
    /// MACs a pure STMC model would have executed.
    pub macs_stmc: f64,
    /// Output quality accumulator (SI-SNR segments), if tracked.
    pub si_snr: Summary,
}

impl StreamMetrics {
    pub fn new() -> Self {
        Self {
            si_snr: Summary::new(),
            ..Default::default()
        }
    }

    pub fn record_arrival(&mut self, start: Instant) {
        self.arrival_latency
            .record(start.elapsed().as_nanos() as u64);
    }

    pub fn record_precompute(&mut self, start: Instant) {
        self.precompute_time
            .record(start.elapsed().as_nanos() as u64);
    }

    pub fn record_frame(&mut self, macs_executed: f64, macs_stmc: f64) {
        self.frames += 1;
        self.macs_executed += macs_executed;
        self.macs_stmc += macs_stmc;
    }

    /// Measured complexity retention vs STMC, percent.
    pub fn retain_pct(&self) -> f64 {
        if self.macs_stmc == 0.0 {
            return 100.0;
        }
        100.0 * self.macs_executed / self.macs_stmc
    }

    /// Fraction of total inference work hidden in the precompute slot.
    pub fn hidden_fraction(&self) -> f64 {
        let pre = self.precompute_time.mean() * self.precompute_time.count() as f64;
        let arr = self.arrival_latency.mean() * self.arrival_latency.count() as f64;
        if pre + arr == 0.0 {
            return 0.0;
        }
        pre / (pre + arr)
    }

    pub fn merge(&mut self, other: &StreamMetrics) {
        self.arrival_latency.merge(&other.arrival_latency);
        self.precompute_time.merge(&other.precompute_time);
        self.frames += other.frames;
        self.macs_executed += other.macs_executed;
        self.macs_stmc += other.macs_stmc;
        if other.si_snr.count > 0 {
            self.si_snr.count += other.si_snr.count;
            self.si_snr.sum += other.si_snr.sum;
            self.si_snr.min = self.si_snr.min.min(other.si_snr.min);
            self.si_snr.max = self.si_snr.max.max(other.si_snr.max);
        }
    }

    pub fn report(&self) -> String {
        format!(
            "frames {:>7}  p50 {:>9}  p95 {:>9}  p99 {:>9}  retain {:>5.1}%  hidden {:>4.1}%",
            self.frames,
            crate::util::bench::fmt_ns(self.arrival_latency.p50() as f64),
            crate::util::bench::fmt_ns(self.arrival_latency.p95() as f64),
            crate::util::bench::fmt_ns(self.arrival_latency.p99() as f64),
            self.retain_pct(),
            100.0 * self.hidden_fraction(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retain_tracks_ratio() {
        let mut m = StreamMetrics::new();
        m.record_frame(50.0, 100.0);
        m.record_frame(100.0, 100.0);
        assert!((m.retain_pct() - 75.0).abs() < 1e-9);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = StreamMetrics::new();
        let mut b = StreamMetrics::new();
        a.record_frame(1.0, 2.0);
        b.record_frame(3.0, 4.0);
        a.merge(&b);
        assert_eq!(a.frames, 2);
        assert_eq!(a.macs_executed, 4.0);
    }

    #[test]
    fn hidden_fraction_zero_without_precompute() {
        let mut m = StreamMetrics::new();
        m.record_arrival(Instant::now());
        assert_eq!(m.hidden_fraction(), 0.0);
    }
}
