//! The SOI inference-pattern scheduler (pure logic, no PJRT).
//!
//! The paper's contribution is an *inference pattern*: a repeating
//! schedule that decides, per incoming frame, which executable runs and
//! what may be precomputed while waiting for the frame.  This module is
//! the table-driven encoding of that pattern; the executor
//! (`coordinator::stream`) merely follows the plan.

/// What to run for one inference.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepPlan {
    /// Schedule phase `t mod period` — selects `step_p<phase>` etc.
    pub phase: usize,
    /// Whether the FP split applies: run `pre_p<phase>` *before* the frame
    /// arrives, then `rest_p<phase>` on arrival.  When false, run
    /// `step_p<phase>` on arrival.
    pub split: bool,
}

/// Scheduler for one stream.
///
/// Period-2^k SOI patterns: phase 0 is the "full" inference updating every
/// partial state (the paper's even inference); other phases skip the
/// compressed regions (the paper's eq. 4 odd branch).
#[derive(Debug, Clone)]
pub struct Scheduler {
    period: usize,
    fp_split: bool,
    t: u64,
}

impl Scheduler {
    /// A scheduler at t = 0; `period` must be a power of two.
    pub fn new(period: usize, fp_split: bool) -> Scheduler {
        Self::new_at(period, fp_split, 0)
    }

    /// A scheduler resuming at inference counter `t` — variant
    /// migration carries a stream's global frame count onto the new
    /// rung's schedule so phases stay aligned with the stream, not with
    /// the switch (DESIGN.md §9).
    pub fn new_at(period: usize, fp_split: bool, t: u64) -> Scheduler {
        assert!(period.is_power_of_two() && period > 0);
        Scheduler { period, fp_split, t }
    }

    /// Length of the repeating inference pattern.
    pub fn period(&self) -> usize {
        self.period
    }

    /// Inference counter (frames consumed so far).
    pub fn t(&self) -> u64 {
        self.t
    }

    /// The plan for the *next* inference (does not advance).
    pub fn peek(&self) -> StepPlan {
        StepPlan {
            phase: (self.t % self.period as u64) as usize,
            split: self.fp_split,
        }
    }

    /// Advance to the next inference and return its plan.
    pub fn next(&mut self) -> StepPlan {
        let plan = self.peek();
        self.t += 1;
        plan
    }

    /// Reset (stream restart).
    pub fn reset(&mut self) {
        self.t = 0;
    }

    /// Whether precompute for the upcoming inference may start now
    /// (FP variants only; callable as soon as the previous inference
    /// finished, i.e. always true between frames).
    pub fn can_precompute(&self) -> bool {
        self.fp_split
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn phases_cycle() {
        let mut s = Scheduler::new(4, false);
        let phases: Vec<usize> = (0..10).map(|_| s.next().phase).collect();
        assert_eq!(phases, vec![0, 1, 2, 3, 0, 1, 2, 3, 0, 1]);
    }

    #[test]
    fn period_one_is_always_phase_zero() {
        let mut s = Scheduler::new(1, false);
        for _ in 0..5 {
            assert_eq!(s.next().phase, 0);
        }
    }

    #[test]
    fn split_flag_propagates() {
        let mut s = Scheduler::new(2, true);
        assert!(s.next().split);
        assert!(s.can_precompute());
        let mut s2 = Scheduler::new(2, false);
        assert!(!s2.next().split);
    }

    #[test]
    fn period_2k_sequences() {
        // Every power-of-two period repeats 0..period indefinitely.
        for k in 0..4u32 {
            let period = 1usize << k;
            let mut s = Scheduler::new(period, false);
            for t in 0..(3 * period + 1) {
                assert_eq!(s.next().phase, t % period, "period {period} at t {t}");
            }
        }
    }

    #[test]
    fn fp_split_plan_covers_every_phase() {
        // FP variants: every plan in the cycle carries split=true and the
        // phase advances exactly like the non-split schedule, so the
        // pre/rest pair always runs the same executables the monolithic
        // step would have.
        let mut s = Scheduler::new(4, true);
        for t in 0..8 {
            assert!(s.can_precompute());
            let peeked = s.peek();
            let plan = s.next();
            assert_eq!(peeked, plan, "peek must not advance");
            assert_eq!(plan.phase, t % 4);
            assert!(plan.split);
        }
        assert_eq!(s.t(), 8);
    }

    #[test]
    fn new_at_resumes_mid_pattern() {
        let mut s = Scheduler::new_at(4, false, 6);
        assert_eq!(s.t(), 6);
        assert_eq!(s.next().phase, 2);
        assert_eq!(s.next().phase, 3);
        assert_eq!(s.next().phase, 0);
    }

    #[test]
    fn reset_restarts_pattern() {
        let mut s = Scheduler::new(2, false);
        s.next();
        s.reset();
        assert_eq!(s.next().phase, 0);
    }

    #[test]
    #[should_panic]
    fn rejects_non_power_of_two() {
        Scheduler::new(3, false);
    }

    #[test]
    fn property_phase_matches_counter() {
        prop::check("phase == t mod period", 50, 0xC0FFEE, |rng, _| {
            let period = 1usize << rng.below(4);
            let mut s = Scheduler::new(period, rng.chance(0.5));
            let steps = rng.below(40) + 1;
            for t in 0..steps {
                let plan = s.next();
                if plan.phase != t % period {
                    return Err(format!("phase {} at t {t} period {period}", plan.phase));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn randomized_admission_keeps_phase_groups_aligned() {
        // §8 batched serving admits streams mid-flight by resuming
        // their schedule at the *absolute* frame counter (the same
        // mechanism §9 migration and §14 cross-shard resume use).  The
        // invariant that makes per-phase batched dispatch correct is
        // that every live stream's plan is identical at every round,
        // no matter when it was admitted or which siblings retired.
        prop::check("admission keeps phase groups aligned", 40, 0xA11A, |rng, _| {
            let period = 1usize << (rng.below(3) + 1); // 2, 4, 8
            let split = rng.chance(0.5);
            let mut live = vec![Scheduler::new_at(period, split, 0)];
            let rounds = rng.below(60) + 10;
            for g in 0..rounds as u64 {
                if rng.chance(0.3) {
                    live.push(Scheduler::new_at(period, split, g));
                }
                if live.len() > 1 && rng.chance(0.2) {
                    let idx = rng.below(live.len());
                    live.swap_remove(idx);
                }
                let mut plans = live.iter_mut().map(Scheduler::next);
                let first = plans.next().expect("pool never empties");
                if first.phase != (g % period as u64) as usize {
                    return Err(format!("phase {} at t {g}, period {period}", first.phase));
                }
                for p in plans {
                    if p != first {
                        return Err(format!("divergent plans {p:?} vs {first:?} at t {g}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn mac_accounting_closes_over_complete_periods() {
        // Summing the per-phase MAC table over any whole number of
        // periods reproduces `macs_per_frame · frames` exactly — from
        // any admission phase — so a stream retired on a period
        // boundary never skews the MAC ledger, and no single phase
        // exceeds the full (STMC) inference.
        use crate::coordinator::stream::{macs_at_phase, macs_stmc};
        use crate::runtime::{Dtype, LayerMacs, Manifest, ModelConfig};
        use std::collections::BTreeMap;
        use std::path::PathBuf;

        fn manifest(period: usize) -> Manifest {
            Manifest {
                name: "t".into(),
                config: ModelConfig {
                    feat: 4,
                    channels: vec![4],
                    kernel: 3,
                    scc: vec![],
                    shift_pos: None,
                    shift: 1,
                    extrap: vec![],
                    interp: None,
                },
                dtype: Dtype::F32,
                quant: None,
                period,
                streamable: true,
                offline_t: 16,
                packed_states: 0,
                states: vec![],
                params: vec![],
                executables: BTreeMap::new(),
                layer_macs: vec![
                    LayerMacs {
                        name: "a".into(),
                        macs: 100,
                        rate_div: 1,
                    },
                    LayerMacs {
                        name: "b".into(),
                        macs: 300,
                        rate_div: 2,
                    },
                ],
                macs_per_frame: 250.0,
                precomputed_fraction: 0.0,
                param_count: 0,
                state_bytes: 0,
                train_metrics: BTreeMap::new(),
                dir: PathBuf::from("/nonexistent"),
            }
        }

        prop::check("macs close over whole periods", 40, 0x5CA1E, |rng, _| {
            let period = 1usize << (rng.below(3) + 1); // 2, 4, 8
            let m = manifest(period);
            let full = macs_stmc(&m);
            let t0 = rng.below(1000) as u64;
            let mut s = Scheduler::new_at(period, false, t0);
            let frames = (rng.below(5) + 1) * period;
            let mut total = 0.0;
            for _ in 0..frames {
                let phase_macs = macs_at_phase(&m, s.next().phase);
                if phase_macs > full {
                    return Err(format!("phase macs {phase_macs} exceed full {full}"));
                }
                total += phase_macs;
            }
            let want = m.macs_per_frame * frames as f64;
            if (total - want).abs() > 1e-9 {
                return Err(format!("{frames} frames from t0 {t0}: {total} != {want}"));
            }
            Ok(())
        });
    }
}
