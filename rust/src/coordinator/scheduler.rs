//! The SOI inference-pattern scheduler (pure logic, no PJRT).
//!
//! The paper's contribution is an *inference pattern*: a repeating
//! schedule that decides, per incoming frame, which executable runs and
//! what may be precomputed while waiting for the frame.  This module is
//! the table-driven encoding of that pattern; the executor
//! (`coordinator::stream`) merely follows the plan.

/// What to run for one inference.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepPlan {
    /// Schedule phase `t mod period` — selects `step_p<phase>` etc.
    pub phase: usize,
    /// Whether the FP split applies: run `pre_p<phase>` *before* the frame
    /// arrives, then `rest_p<phase>` on arrival.  When false, run
    /// `step_p<phase>` on arrival.
    pub split: bool,
}

/// Scheduler for one stream.
///
/// Period-2^k SOI patterns: phase 0 is the "full" inference updating every
/// partial state (the paper's even inference); other phases skip the
/// compressed regions (the paper's eq. 4 odd branch).
#[derive(Debug, Clone)]
pub struct Scheduler {
    period: usize,
    fp_split: bool,
    t: u64,
}

impl Scheduler {
    /// A scheduler at t = 0; `period` must be a power of two.
    pub fn new(period: usize, fp_split: bool) -> Scheduler {
        Self::new_at(period, fp_split, 0)
    }

    /// A scheduler resuming at inference counter `t` — variant
    /// migration carries a stream's global frame count onto the new
    /// rung's schedule so phases stay aligned with the stream, not with
    /// the switch (DESIGN.md §9).
    pub fn new_at(period: usize, fp_split: bool, t: u64) -> Scheduler {
        assert!(period.is_power_of_two() && period > 0);
        Scheduler { period, fp_split, t }
    }

    /// Length of the repeating inference pattern.
    pub fn period(&self) -> usize {
        self.period
    }

    /// Inference counter (frames consumed so far).
    pub fn t(&self) -> u64 {
        self.t
    }

    /// The plan for the *next* inference (does not advance).
    pub fn peek(&self) -> StepPlan {
        StepPlan {
            phase: (self.t % self.period as u64) as usize,
            split: self.fp_split,
        }
    }

    /// Advance to the next inference and return its plan.
    pub fn next(&mut self) -> StepPlan {
        let plan = self.peek();
        self.t += 1;
        plan
    }

    /// Reset (stream restart).
    pub fn reset(&mut self) {
        self.t = 0;
    }

    /// Whether precompute for the upcoming inference may start now
    /// (FP variants only; callable as soon as the previous inference
    /// finished, i.e. always true between frames).
    pub fn can_precompute(&self) -> bool {
        self.fp_split
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn phases_cycle() {
        let mut s = Scheduler::new(4, false);
        let phases: Vec<usize> = (0..10).map(|_| s.next().phase).collect();
        assert_eq!(phases, vec![0, 1, 2, 3, 0, 1, 2, 3, 0, 1]);
    }

    #[test]
    fn period_one_is_always_phase_zero() {
        let mut s = Scheduler::new(1, false);
        for _ in 0..5 {
            assert_eq!(s.next().phase, 0);
        }
    }

    #[test]
    fn split_flag_propagates() {
        let mut s = Scheduler::new(2, true);
        assert!(s.next().split);
        assert!(s.can_precompute());
        let mut s2 = Scheduler::new(2, false);
        assert!(!s2.next().split);
    }

    #[test]
    fn period_2k_sequences() {
        // Every power-of-two period repeats 0..period indefinitely.
        for k in 0..4u32 {
            let period = 1usize << k;
            let mut s = Scheduler::new(period, false);
            for t in 0..(3 * period + 1) {
                assert_eq!(s.next().phase, t % period, "period {period} at t {t}");
            }
        }
    }

    #[test]
    fn fp_split_plan_covers_every_phase() {
        // FP variants: every plan in the cycle carries split=true and the
        // phase advances exactly like the non-split schedule, so the
        // pre/rest pair always runs the same executables the monolithic
        // step would have.
        let mut s = Scheduler::new(4, true);
        for t in 0..8 {
            assert!(s.can_precompute());
            let peeked = s.peek();
            let plan = s.next();
            assert_eq!(peeked, plan, "peek must not advance");
            assert_eq!(plan.phase, t % 4);
            assert!(plan.split);
        }
        assert_eq!(s.t(), 8);
    }

    #[test]
    fn new_at_resumes_mid_pattern() {
        let mut s = Scheduler::new_at(4, false, 6);
        assert_eq!(s.t(), 6);
        assert_eq!(s.next().phase, 2);
        assert_eq!(s.next().phase, 3);
        assert_eq!(s.next().phase, 0);
    }

    #[test]
    fn reset_restarts_pattern() {
        let mut s = Scheduler::new(2, false);
        s.next();
        s.reset();
        assert_eq!(s.next().phase, 0);
    }

    #[test]
    #[should_panic]
    fn rejects_non_power_of_two() {
        Scheduler::new(3, false);
    }

    #[test]
    fn property_phase_matches_counter() {
        prop::check("phase == t mod period", 50, 0xC0FFEE, |rng, _| {
            let period = 1usize << rng.below(4);
            let mut s = Scheduler::new(period, rng.chance(0.5));
            let steps = rng.below(40) + 1;
            for t in 0..steps {
                let plan = s.next();
                if plan.phase != t % period {
                    return Err(format!("phase {} at t {t} period {period}", plan.phase));
                }
            }
            Ok(())
        });
    }
}
