//! PJRT execution backend (`--features pjrt`): compiles a variant's
//! HLO-text artifacts (emitted by `python/compile/aot.py`) and runs them
//! from the coordinator hot path.  Python never runs here.
//!
//! Implementation notes:
//!
//! * We execute with `execute_b` over device buffers, **not** `execute`
//!   over literals: the `xla` crate's `execute` path leaks one device
//!   buffer per argument per call (`buffer.release()` without a matching
//!   free in xla_rs.cc) — fatal for a long-running server at 500 fps.
//!   With `execute_b` we own the input buffers and they are freed on Drop.
//! * All step executables return one tuple (jax lowered with
//!   `return_tuple=True`); PJRT hands back a single tuple buffer which we
//!   copy to host and decompose.
//! * Weights are uploaded to the device once per variant
//!   ([`InferenceBackend::upload_weights`]) and shared by every stream;
//!   per-step uploads are just the frame and the per-stream states.
//!
//! Note: `rust/vendor/xla` is a compile-time stub by default — swap in
//! the real `xla` crate to execute artifacts (DESIGN.md §5).

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::{DeviceWeights, InferenceBackend, VariantExec};
use crate::runtime::engine::{StateSet, Weights};
use crate::runtime::manifest::Manifest;
use crate::util::tensor::Tensor;

/// Upload a host tensor to a device buffer.
fn upload(client: &xla::PjRtClient, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
    client
        .buffer_from_host_buffer::<f32>(data, dims, None)
        .context("uploading buffer")
}

/// Shared PJRT client (CPU).
pub struct PjrtBackend {
    client: Arc<xla::PjRtClient>,
}

// SAFETY: PJRT requires clients/executables to be usable from multiple
// threads concurrently (the CPU plugin uses an internal thread pool
// itself); the `xla` crate wrappers merely hold raw pointers without
// asserting it.  All rust-side mutation (states, metrics) is worker-local.
unsafe impl Send for PjrtBackend {}
unsafe impl Sync for PjrtBackend {}

impl PjrtBackend {
    /// A backend over a fresh PJRT CPU client.
    pub fn cpu() -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtBackend {
            client: Arc::new(client),
        })
    }

    /// Compile one HLO-text file into a loaded executable.
    fn compile_file(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe })
    }
}

impl InferenceBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn device_count(&self) -> usize {
        self.client.device_count()
    }

    fn compile_variant(&self, manifest: &Manifest) -> Result<Box<dyn VariantExec>> {
        if manifest.dtype != crate::runtime::manifest::Dtype::F32 {
            anyhow::bail!(
                "{}: the pjrt backend executes f32 artifacts only (dtype {}); \
                 quantized execution is native-backend only",
                manifest.name,
                manifest.dtype.as_str()
            );
        }
        Ok(Box::new(PjrtVariant::compile(self, manifest)?))
    }

    fn upload_weights(&self, weights: &Weights) -> Result<DeviceWeights> {
        let bufs = weights
            .tensors
            .iter()
            .map(|t| upload(&self.client, &t.data, &t.shape))
            .collect::<Result<Vec<_>>>()?;
        Ok(DeviceWeights::Pjrt(std::sync::Arc::new(bufs)))
    }
}

/// A compiled executable returning a single tuple.
struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute over device buffers; decompose the tuple into host tensors.
    fn run(&self, args: &[&xla::PjRtBuffer], out_shapes: &[Vec<usize>]) -> Result<Vec<Tensor>> {
        let results = self.exe.execute_b(args).context("execute_b")?;
        let buf = &results[0][0];
        let mut lit = buf.to_literal_sync().context("tuple to host")?;
        let parts = lit.decompose_tuple().context("decompose tuple")?;
        if parts.len() != out_shapes.len() {
            bail!(
                "executable returned {} outputs, expected {}",
                parts.len(),
                out_shapes.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (p, shape) in parts.into_iter().zip(out_shapes) {
            let data = p.to_vec::<f32>().context("tuple element to f32")?;
            out.push(Tensor::new(shape.clone(), data));
        }
        Ok(out)
    }
}

/// One variant compiled for the PJRT backend: all executables + manifest.
pub struct PjrtVariant {
    manifest: Manifest,
    // Phases with identical graphs share one compiled executable (Arc).
    step: Vec<Arc<Executable>>, // indexed by phase
    pre: Vec<Arc<Executable>>,  // empty unless FP
    rest: Vec<Arc<Executable>>, // empty unless FP
    offline: Arc<Executable>,
    client: Arc<xla::PjRtClient>,
}

// SAFETY: same argument as for PjrtBackend — the PJRT C API guarantees
// thread-safe Execute/buffer operations; streams never share StateSets.
unsafe impl Send for PjrtVariant {}
unsafe impl Sync for PjrtVariant {}

impl PjrtVariant {
    /// Compile every executable of a variant.
    ///
    /// Phases whose manifests point at the same HLO file share one
    /// compiled executable (aot.py dedupes identical graphs).
    fn compile(backend: &PjrtBackend, manifest: &Manifest) -> Result<PjrtVariant> {
        if manifest.executables.is_empty() {
            bail!(
                "{}: manifest ships no HLO executables (native-only artifact); \
                 build with aot.py or use the native backend",
                manifest.name
            );
        }
        let mut cache: std::collections::BTreeMap<String, usize> = Default::default();
        let mut exes: Vec<Executable> = Vec::new();
        let mut index_of = |key: &str| -> Result<usize> {
            let file = manifest
                .executables
                .get(key)
                .with_context(|| format!("missing executable {key}"))?
                .clone();
            if let Some(&i) = cache.get(&file) {
                return Ok(i);
            }
            let exe = backend.compile_file(&manifest.dir.join(&file))?;
            exes.push(exe);
            cache.insert(file, exes.len() - 1);
            Ok(exes.len() - 1)
        };

        let mut step_idx = Vec::new();
        let mut pre_idx = Vec::new();
        let mut rest_idx = Vec::new();
        if manifest.streamable {
            for phase in 0..manifest.period {
                step_idx.push(index_of(&format!("step_p{phase}"))?);
            }
            if manifest.executables.contains_key("pre_p0") {
                for phase in 0..manifest.period {
                    pre_idx.push(index_of(&format!("pre_p{phase}"))?);
                    rest_idx.push(index_of(&format!("rest_p{phase}"))?);
                }
            }
        }
        let off_idx = index_of("offline")?;

        let exes: Vec<Arc<Executable>> = exes.into_iter().map(Arc::new).collect();
        let pick = |idx: &[usize]| idx.iter().map(|&i| exes[i].clone()).collect::<Vec<_>>();
        Ok(PjrtVariant {
            step: pick(&step_idx),
            pre: pick(&pre_idx),
            rest: pick(&rest_idx),
            offline: exes[off_idx].clone(),
            manifest: manifest.clone(),
            client: backend.client.clone(),
        })
    }

    fn state_shapes(&self) -> Vec<Vec<usize>> {
        if self.manifest.packed_states > 0 {
            return vec![vec![self.manifest.packed_states]];
        }
        self.manifest
            .states
            .iter()
            .map(|s| s.shape.clone())
            .collect()
    }

    fn device_bufs<'a>(&self, dw: &'a DeviceWeights) -> Result<&'a [xla::PjRtBuffer]> {
        match dw {
            DeviceWeights::Pjrt(bufs) => Ok(bufs.as_slice()),
            DeviceWeights::Host(_) => bail!(
                "{}: host weights passed to the pjrt backend; upload them first",
                self.manifest.name
            ),
        }
    }

    fn run_step_like(
        &self,
        exe: &Executable,
        frame: Option<&[f32]>,
        states: &mut StateSet,
        dw: &DeviceWeights,
        has_out: bool,
    ) -> Result<Vec<f32>> {
        let feat = self.manifest.config.feat;
        let weight_bufs = self.device_bufs(dw)?;
        let mut owned: Vec<xla::PjRtBuffer> = Vec::with_capacity(1 + states.tensors.len());
        if let Some(f) = frame {
            if f.len() != feat {
                bail!("frame has {} samples, expected {feat}", f.len());
            }
            owned.push(upload(&self.client, f, &[feat, 1])?);
        }
        for t in &states.tensors {
            owned.push(upload(&self.client, &t.data, &t.shape)?);
        }
        let mut args: Vec<&xla::PjRtBuffer> = owned.iter().collect();
        for b in weight_bufs {
            args.push(b);
        }

        let mut out_shapes = Vec::new();
        if has_out {
            out_shapes.push(vec![feat, 1]);
        }
        out_shapes.extend(self.state_shapes());
        let mut outs = exe.run(&args, &out_shapes)?;

        let out_frame = if has_out {
            let f = outs.remove(0);
            f.data
        } else {
            Vec::new()
        };
        for (slot, t) in states.tensors.iter_mut().zip(outs) {
            *slot = t;
        }
        Ok(out_frame)
    }
}

impl VariantExec for PjrtVariant {
    /// Fresh zeroed per-stream states.
    ///
    /// Modern artifacts exchange one packed state vector (manifest
    /// `packed_states` > 0) — a single HBM upload per inference; legacy
    /// artifacts exchange one tensor per state spec.
    fn init_states(&self) -> StateSet {
        if self.manifest.packed_states > 0 {
            return StateSet {
                tensors: vec![Tensor::zeros(vec![self.manifest.packed_states])],
            };
        }
        StateSet {
            tensors: self
                .manifest
                .states
                .iter()
                .map(|s| Tensor::zeros(s.shape.clone()))
                .collect(),
        }
    }

    fn has_fp_split(&self) -> bool {
        !self.pre.is_empty()
    }

    fn step(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        weights: &DeviceWeights,
    ) -> Result<Vec<f32>> {
        let exe = &self.step[phase % self.manifest.period];
        self.run_step_like(exe, Some(frame), states, weights, true)
    }

    fn precompute(
        &self,
        phase: usize,
        states: &mut StateSet,
        weights: &DeviceWeights,
    ) -> Result<()> {
        if self.pre.is_empty() {
            bail!("{}: variant has no FP split", self.manifest.name);
        }
        let exe = &self.pre[phase % self.manifest.period];
        self.run_step_like(exe, None, states, weights, false)?;
        Ok(())
    }

    fn step_rest(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        weights: &DeviceWeights,
    ) -> Result<Vec<f32>> {
        if self.rest.is_empty() {
            bail!("{}: variant has no FP split", self.manifest.name);
        }
        let exe = &self.rest[phase % self.manifest.period];
        self.run_step_like(exe, Some(frame), states, weights, true)
    }

    fn offline(&self, x: &Tensor, weights: &DeviceWeights) -> Result<Tensor> {
        let feat = self.manifest.config.feat;
        let t = self.manifest.offline_t;
        if x.shape != [feat, t] {
            bail!("offline input shape {:?}, expected [{feat}, {t}]", x.shape);
        }
        let weight_bufs = self.device_bufs(weights)?;
        let xbuf = upload(&self.client, &x.data, &x.shape)?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&xbuf];
        for b in weight_bufs {
            args.push(b);
        }
        let mut outs = self.offline.run(&args, &[vec![feat, t]])?;
        Ok(outs.remove(0))
    }
}
