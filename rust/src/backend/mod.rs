//! Multi-backend execution layer (DESIGN.md §4).
//!
//! The serving stack (coordinator, experiments, benches, examples) talks
//! to an SOI variant only through two object-safe traits:
//!
//! * [`InferenceBackend`] — a device/runtime: compiles a variant
//!   [`Manifest`] into an executable form and uploads weights.
//! * [`VariantExec`] — one compiled variant: per-stream state
//!   initialisation, the phase-indexed streaming step, the FP
//!   precompute/rest split, and the full-sequence offline pass.
//!
//! Two implementations exist:
//!
//! * [`native`] — a dependency-free pure-Rust streaming interpreter of
//!   the variant manifest (causal/STMC conv1d, stride compression,
//!   extrapolation, per-layer `rate_div` phase gating matching
//!   `coordinator::scheduler` and eq. 4 of the paper).  This is the
//!   default: it runs on anything that compiles Rust, executing on the
//!   runtime-dispatched SIMD microkernels of [`crate::kernels`]
//!   (DESIGN.md §11).  Its registry is dtype-aware: an int8 manifest
//!   compiles to the quantized executable (`crate::quant::QuantVariant`,
//!   DESIGN.md §10) instead of the f32 interpreter — same trait, same
//!   weight upload, so ladders mix precisions freely.
//! * `pjrt` (`--features pjrt`) — the HLO-text/PJRT execution engine
//!   for AOT-compiled artifacts from `python/compile/aot.py` (f32 only).

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::kernels::PackedF32;
use crate::runtime::engine::{StateSet, Weights};
use crate::runtime::manifest::{Manifest, ModelConfig};
use crate::util::tensor::Tensor;

/// One phase's precompiled schedule decisions, all indexed `l - 1` —
/// the step-plan table both interpreters consult per frame instead of
/// per-layer modular arithmetic (DESIGN.md §11).  Built by
/// [`build_phase_plans`] and shared between the f32 and int8
/// executables so the schedule semantics cannot drift between them
/// (`backend::native`'s `phase_plans_mirror_rate_arithmetic` test pins
/// the builder for both).
pub(crate) struct PhasePlan {
    /// Encoder layer ticks its STMC window (`phase % r_in == 0`).
    pub enc_tick: Box<[bool]>,
    /// Encoder layer computes (S-CC layers fire every other tick).
    pub enc_fire: Box<[bool]>,
    /// Decoder layer computes (`phase % r_out == 0`); at S-CC positions
    /// this doubles as the "fresh extrapolation" flag.
    pub dec_run: Box<[bool]>,
}

/// Precompile a config's per-phase schedule decisions (one entry per
/// phase in `0..period`).
pub(crate) fn build_phase_plans(cfg: &ModelConfig) -> Vec<PhasePlan> {
    let depth = cfg.depth();
    (0..cfg.period())
        .map(|phase| PhasePlan {
            enc_tick: (1..=depth).map(|l| phase % cfg.r_in(l) == 0).collect(),
            enc_fire: (1..=depth)
                .map(|l| {
                    if cfg.scc.contains(&l) {
                        phase % (2 * cfg.r_in(l)) == 0
                    } else {
                        phase % cfg.r_in(l) == 0
                    }
                })
                .collect(),
            dec_run: (1..=depth).map(|l| phase % cfg.r_out(l) == 0).collect(),
        })
        .collect()
}

/// The packed forms of one rank-3 weight tensor, built once at upload
/// time (DESIGN.md §11).
pub struct PanelSet {
    /// The `(C_out, C_in · K)` GEMM panel every streaming/offline conv
    /// executes on.
    pub gemm: PackedF32,
    /// For 2-tap kernels only: the per-output-phase `(C_out, C_in)`
    /// panels of a stride-2 transposed conv.
    pub phases: Option<Box<[PackedF32; 2]>>,
}

/// Host-resident weights plus the packed panels the native kernels
/// execute on.  Built once per upload ([`InferenceBackend::upload_weights`])
/// and shared by every variant, stream and worker through the `Arc` in
/// [`DeviceWeights::Host`] — ladder rungs and worker threads no longer
/// deep-copy the tensor set.
pub struct HostWeights {
    weights: Weights,
    panels: Vec<Option<PanelSet>>,
}

impl HostWeights {
    /// Wrap host weights, packing every rank-3 tensor (the conv kernels)
    /// into cache-blocked panels.
    ///
    /// 2-tap tensors get *both* forms — the flat GEMM panel and the
    /// per-phase panels — on purpose: at upload time a `(C, C, 2)`
    /// tensor's role is unknown (a transposed-conv kernel executes
    /// through its phase panels, a regular `K = 2` conv through the
    /// flat one), and the duplicated packing of the small `up.w`
    /// tensors is cheaper than threading per-variant role information
    /// into the variant-agnostic upload.
    pub fn new(weights: Weights) -> HostWeights {
        let panels = weights
            .tensors
            .iter()
            .map(|t| {
                let gemm = PackedF32::from_conv(t)?;
                let phases = if t.shape.len() == 3 && t.shape[2] == 2 {
                    Some(Box::new([
                        PackedF32::from_conv_tap(t, 0)?,
                        PackedF32::from_conv_tap(t, 1)?,
                    ]))
                } else {
                    None
                };
                Some(PanelSet { gemm, phases })
            })
            .collect();
        HostWeights { weights, panels }
    }

    /// The wrapped host weight set (manifest parameter order).
    pub fn weights(&self) -> &Weights {
        &self.weights
    }

    /// The raw parameter tensors (manifest parameter order).
    pub fn tensors(&self) -> &[Tensor] {
        &self.weights.tensors
    }

    /// The GEMM panel of parameter `i`, if it is a conv kernel.
    pub fn panel(&self, i: usize) -> Option<&PackedF32> {
        self.panels.get(i)?.as_ref().map(|p| &p.gemm)
    }

    /// Output-phase `ph` panel of a 2-tap (transposed-conv) kernel.
    pub fn phase_panel(&self, i: usize, ph: usize) -> Option<&PackedF32> {
        let set = self.panels.get(i)?.as_ref()?;
        set.phases.as_ref().map(|ps| &ps[ph])
    }
}

/// Weights in whatever form a backend executes from.
///
/// The native backend computes straight from host memory (raw tensors
/// plus their packed panels); the pjrt backend holds device buffers
/// uploaded once per variant.  Both variants are cheap to clone — the
/// payload is behind an `Arc`, so sessions, workers and ladder rungs
/// share one physical copy.
#[derive(Clone)]
pub enum DeviceWeights {
    /// Host-resident tensors + packed panels, shared by reference.
    Host(Arc<HostWeights>),
    /// PJRT device buffers in manifest parameter order.
    #[cfg(feature = "pjrt")]
    Pjrt(Arc<Vec<xla::PjRtBuffer>>),
}

impl DeviceWeights {
    /// Wrap host weights (packing their conv panels) for the native
    /// backend.
    pub fn host(weights: Weights) -> DeviceWeights {
        DeviceWeights::Host(Arc::new(HostWeights::new(weights)))
    }
}

/// Where a streaming step writes its output frames (crate-internal: the
/// native interpreters fill caller-owned buffers so the steady state
/// allocates nothing).
pub(crate) enum OutSink<'a> {
    /// No output wanted (FP precompute pass).
    Discard,
    /// Single-stream output frame (`B == 1`).
    Single(&'a mut Vec<f32>),
    /// One output frame per stream of the batch.
    Batch(&'a mut Vec<Vec<f32>>),
}

impl OutSink<'_> {
    /// Write a `(c, bsz)` column-stacked output panel into the sink,
    /// reusing the destination buffers' capacity.
    pub(crate) fn write(&mut self, m: &[f32], bsz: usize, c: usize) {
        match self {
            OutSink::Discard => {}
            OutSink::Single(out) => {
                debug_assert_eq!(bsz, 1);
                out.clear();
                out.extend_from_slice(&m[..c]);
            }
            OutSink::Batch(outs) => {
                if outs.len() != bsz {
                    outs.resize_with(bsz, Vec::new);
                }
                for (si, o) in outs.iter_mut().enumerate() {
                    o.clear();
                    o.extend((0..c).map(|i| m[i * bsz + si]));
                }
            }
        }
    }
}

/// A runtime capable of executing SOI variants.
pub trait InferenceBackend: Send + Sync {
    /// Short backend name ("native", "pjrt") for logs and reports.
    fn name(&self) -> &'static str;

    /// Number of devices this backend drives (1 for native).
    fn device_count(&self) -> usize {
        1
    }

    /// Compile one variant manifest into an executable form.
    fn compile_variant(&self, manifest: &Manifest) -> Result<Box<dyn VariantExec>>;

    /// Prepare weights for execution (device upload for pjrt; wrap +
    /// panel-pack for native).  Tensors must be in manifest parameter
    /// order.  The result is cheaply clonable and shared — callers
    /// should upload once and clone the handle.
    fn upload_weights(&self, weights: &Weights) -> Result<DeviceWeights>;
}

/// One compiled SOI variant, ready to serve streams.
///
/// `phase` arguments are schedule positions in `0..period`; callers may
/// pass the raw frame counter (implementations reduce modulo the
/// period).  `states` is the per-stream partial-state cache created by
/// [`VariantExec::init_states`] and mutated in place by every step.
///
/// The `*_into` methods are the allocation-free forms: they fill
/// caller-owned output buffers (reusing capacity), and on the native
/// backends the whole step runs out of a recycled
/// [`crate::kernels::StepArena`] — `rust/tests/hot_path_alloc.rs` proves
/// zero steady-state allocations per step.  The owned-return methods
/// remain for convenience and are implemented in terms of the `_into`
/// forms (or vice versa for backends that predate them).
pub trait VariantExec: Send + Sync {
    /// Fresh zeroed per-stream partial states.
    fn init_states(&self) -> StateSet;

    /// Whether this variant supports the FP precompute/rest split.
    fn has_fp_split(&self) -> bool;

    /// One full streaming inference at schedule position `phase`:
    /// consumes the frame, updates `states`, returns the output frame.
    fn step(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        weights: &DeviceWeights,
    ) -> Result<Vec<f32>>;

    /// [`VariantExec::step`] writing into a caller-owned buffer (cleared
    /// and refilled; capacity is reused).  Default delegates to `step`.
    fn step_into(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        weights: &DeviceWeights,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        *out = self.step(phase, frame, states, weights)?;
        Ok(())
    }

    /// FP precompute: the delayed-region part of inference `phase`;
    /// consumes no input frame, only updates states.
    fn precompute(
        &self,
        phase: usize,
        states: &mut StateSet,
        weights: &DeviceWeights,
    ) -> Result<()>;

    /// FP rest pass: consumes the fresh frame after `precompute` ran.
    fn step_rest(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        weights: &DeviceWeights,
    ) -> Result<Vec<f32>>;

    /// [`VariantExec::step_rest`] writing into a caller-owned buffer.
    /// Default delegates to `step_rest`.
    fn step_rest_into(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        weights: &DeviceWeights,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        *out = self.step_rest(phase, frame, states, weights)?;
        Ok(())
    }

    /// Phase-aligned batched streaming step (DESIGN.md §8): one inference
    /// for each of `frames.len()` streams that all sit at the same
    /// schedule position `phase`.  `states[i]` belongs to stream `i` and
    /// must be mutated exactly as `frames.len()` independent
    /// [`VariantExec::step`] calls would mutate it.
    ///
    /// The default implementation *is* that sequential loop, so backends
    /// without a batched kernel (pjrt) fall back transparently; the
    /// native backend overrides it with a batch-stacked GEMM path whose
    /// outputs are bit-identical to the sequential path.
    fn step_batch(
        &self,
        phase: usize,
        frames: &[&[f32]],
        states: &mut [&mut StateSet],
        weights: &DeviceWeights,
    ) -> Result<Vec<Vec<f32>>> {
        if frames.len() != states.len() {
            bail!(
                "step_batch: {} frames for {} state sets",
                frames.len(),
                states.len()
            );
        }
        frames
            .iter()
            .zip(states.iter_mut())
            .map(|(frame, st)| self.step(phase, frame, st, weights))
            .collect()
    }

    /// [`VariantExec::step_batch`] writing into caller-owned buffers
    /// (`outs` is resized to the batch width; inner buffers are cleared
    /// and refilled, reusing capacity).  Default delegates to
    /// `step_batch`.
    fn step_batch_into(
        &self,
        phase: usize,
        frames: &[&[f32]],
        states: &mut [&mut StateSet],
        weights: &DeviceWeights,
        outs: &mut Vec<Vec<f32>>,
    ) -> Result<()> {
        *outs = self.step_batch(phase, frames, states, weights)?;
        Ok(())
    }

    /// Phase-aligned batched FP rest pass: [`VariantExec::step_rest`] for
    /// a batch of streams whose `precompute` already ran.  Defaults to
    /// the sequential loop exactly like [`VariantExec::step_batch`].
    fn step_rest_batch(
        &self,
        phase: usize,
        frames: &[&[f32]],
        states: &mut [&mut StateSet],
        weights: &DeviceWeights,
    ) -> Result<Vec<Vec<f32>>> {
        if frames.len() != states.len() {
            bail!(
                "step_rest_batch: {} frames for {} state sets",
                frames.len(),
                states.len()
            );
        }
        frames
            .iter()
            .zip(states.iter_mut())
            .map(|(frame, st)| self.step_rest(phase, frame, st, weights))
            .collect()
    }

    /// [`VariantExec::step_rest_batch`] writing into caller-owned
    /// buffers.  Default delegates to `step_rest_batch`.
    fn step_rest_batch_into(
        &self,
        phase: usize,
        frames: &[&[f32]],
        states: &mut [&mut StateSet],
        weights: &DeviceWeights,
        outs: &mut Vec<Vec<f32>>,
    ) -> Result<()> {
        *outs = self.step_rest_batch(phase, frames, states, weights)?;
        Ok(())
    }

    /// Run the offline (full-sequence) network over (feat, T) frames.
    fn offline(&self, x: &Tensor, weights: &DeviceWeights) -> Result<Tensor>;

    /// Multiply-accumulate operations executed so far, when the backend
    /// counts them (native does; pjrt reports `None`).  Used to verify
    /// the scheduler's analytic per-phase accounting against reality.
    fn executed_macs(&self) -> Option<u64> {
        None
    }

    /// Reset the MAC counter (no-op when uncounted).
    fn reset_executed_macs(&self) {}

    /// The variant's [`crate::kernels::StepArena`] registry id, when the
    /// backend steps out of a per-thread arena (both native interpreters
    /// do; pjrt reports `None`).  Lets the serving layer look up
    /// per-variant peak scratch bytes on the thread that executed the
    /// steps ([`crate::kernels::arena::peak_bytes_of`]).
    fn arena_id(&self) -> Option<u64> {
        None
    }
}
