//! Multi-backend execution layer (DESIGN.md §4).
//!
//! The serving stack (coordinator, experiments, benches, examples) talks
//! to an SOI variant only through two object-safe traits:
//!
//! * [`InferenceBackend`] — a device/runtime: compiles a variant
//!   [`Manifest`] into an executable form and uploads weights.
//! * [`VariantExec`] — one compiled variant: per-stream state
//!   initialisation, the phase-indexed streaming step, the FP
//!   precompute/rest split, and the full-sequence offline pass.
//!
//! Two implementations exist:
//!
//! * [`native`] — a dependency-free pure-Rust streaming interpreter of
//!   the variant manifest (causal/STMC conv1d, stride compression,
//!   extrapolation, per-layer `rate_div` phase gating matching
//!   `coordinator::scheduler` and eq. 4 of the paper).  This is the
//!   default: it runs on anything that compiles Rust.  Its registry is
//!   dtype-aware: an int8 manifest compiles to the quantized executable
//!   (`crate::quant::QuantVariant`, DESIGN.md §10) instead of the f32
//!   interpreter — same trait, same weight upload, so ladders mix
//!   precisions freely.
//! * `pjrt` (`--features pjrt`) — the HLO-text/PJRT execution engine
//!   for AOT-compiled artifacts from `python/compile/aot.py` (f32 only).

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use anyhow::{bail, Result};

use crate::runtime::engine::{StateSet, Weights};
use crate::runtime::manifest::Manifest;
use crate::util::tensor::Tensor;

/// Weights in whatever form a backend executes from.
///
/// The native backend computes straight from host memory; the pjrt
/// backend holds device buffers uploaded once per variant and shared by
/// every stream.
pub enum DeviceWeights {
    /// Host-resident tensors in manifest parameter order.
    Host(Weights),
    /// PJRT device buffers in manifest parameter order.
    #[cfg(feature = "pjrt")]
    Pjrt(Vec<xla::PjRtBuffer>),
}

/// A runtime capable of executing SOI variants.
pub trait InferenceBackend: Send + Sync {
    /// Short backend name ("native", "pjrt") for logs and reports.
    fn name(&self) -> &'static str;

    /// Number of devices this backend drives (1 for native).
    fn device_count(&self) -> usize {
        1
    }

    /// Compile one variant manifest into an executable form.
    fn compile_variant(&self, manifest: &Manifest) -> Result<Box<dyn VariantExec>>;

    /// Prepare weights for execution (upload for pjrt, pass-through for
    /// native).  Tensors must be in manifest parameter order.
    fn upload_weights(&self, weights: &Weights) -> Result<DeviceWeights>;
}

/// One compiled SOI variant, ready to serve streams.
///
/// `phase` arguments are schedule positions in `0..period`; callers may
/// pass the raw frame counter (implementations reduce modulo the
/// period).  `states` is the per-stream partial-state cache created by
/// [`VariantExec::init_states`] and mutated in place by every step.
pub trait VariantExec: Send + Sync {
    /// Fresh zeroed per-stream partial states.
    fn init_states(&self) -> StateSet;

    /// Whether this variant supports the FP precompute/rest split.
    fn has_fp_split(&self) -> bool;

    /// One full streaming inference at schedule position `phase`:
    /// consumes the frame, updates `states`, returns the output frame.
    fn step(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        weights: &DeviceWeights,
    ) -> Result<Vec<f32>>;

    /// FP precompute: the delayed-region part of inference `phase`;
    /// consumes no input frame, only updates states.
    fn precompute(
        &self,
        phase: usize,
        states: &mut StateSet,
        weights: &DeviceWeights,
    ) -> Result<()>;

    /// FP rest pass: consumes the fresh frame after `precompute` ran.
    fn step_rest(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        weights: &DeviceWeights,
    ) -> Result<Vec<f32>>;

    /// Phase-aligned batched streaming step (DESIGN.md §8): one inference
    /// for each of `frames.len()` streams that all sit at the same
    /// schedule position `phase`.  `states[i]` belongs to stream `i` and
    /// must be mutated exactly as `frames.len()` independent
    /// [`VariantExec::step`] calls would mutate it.
    ///
    /// The default implementation *is* that sequential loop, so backends
    /// without a batched kernel (pjrt) fall back transparently; the
    /// native backend overrides it with a batch-stacked GEMM path whose
    /// outputs are bit-identical to the sequential path.
    fn step_batch(
        &self,
        phase: usize,
        frames: &[&[f32]],
        states: &mut [&mut StateSet],
        weights: &DeviceWeights,
    ) -> Result<Vec<Vec<f32>>> {
        if frames.len() != states.len() {
            bail!(
                "step_batch: {} frames for {} state sets",
                frames.len(),
                states.len()
            );
        }
        frames
            .iter()
            .zip(states.iter_mut())
            .map(|(frame, st)| self.step(phase, frame, st, weights))
            .collect()
    }

    /// Phase-aligned batched FP rest pass: [`VariantExec::step_rest`] for
    /// a batch of streams whose `precompute` already ran.  Defaults to
    /// the sequential loop exactly like [`VariantExec::step_batch`].
    fn step_rest_batch(
        &self,
        phase: usize,
        frames: &[&[f32]],
        states: &mut [&mut StateSet],
        weights: &DeviceWeights,
    ) -> Result<Vec<Vec<f32>>> {
        if frames.len() != states.len() {
            bail!(
                "step_rest_batch: {} frames for {} state sets",
                frames.len(),
                states.len()
            );
        }
        frames
            .iter()
            .zip(states.iter_mut())
            .map(|(frame, st)| self.step_rest(phase, frame, st, weights))
            .collect()
    }

    /// Run the offline (full-sequence) network over (feat, T) frames.
    fn offline(&self, x: &Tensor, weights: &DeviceWeights) -> Result<Tensor>;

    /// Multiply-accumulate operations executed so far, when the backend
    /// counts them (native does; pjrt reports `None`).  Used to verify
    /// the scheduler's analytic per-phase accounting against reality.
    fn executed_macs(&self) -> Option<u64> {
        None
    }

    /// Reset the MAC counter (no-op when uncounted).
    fn reset_executed_macs(&self) {}
}
