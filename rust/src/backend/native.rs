//! Native pure-Rust SOI backend: interprets a variant [`Manifest`]
//! directly — no ML runtime, no codegen, no external dependencies.
//!
//! This is the executable form of `python/compile/model.py`'s streaming
//! semantics (the paper's eq. 3–7), cross-checked in
//! `tests/native_backend.rs`:
//!
//! * Encoder layer `l` *ticks* (pushes its STMC conv window) when
//!   `phase % r_in(l) == 0`; an S-CC layer `p` additionally *fires*
//!   (computes) only when `phase % (2·r_in(p)) == 0` — the paper's eq. 4
//!   odd-inference branch just updates state.
//! * Decoder layer `l` computes when `phase % r_out(l) == 0`; S-CC
//!   positions extrapolate their activation back to the `r_in` domain
//!   through a one-frame cache (duplication) or a two-phase learned
//!   transposed conv (`tconv`).
//! * An FP shift at encoder `s` reads a delay-line FIFO, making layers
//!   `s..=depth` (and the mirrored decoder region) depend on past data
//!   only; [`VariantExec::precompute`] runs exactly that region before
//!   the frame arrives and parks the boundary value in a handoff slot
//!   for [`VariantExec::step_rest`].
//!
//! Every multiply-accumulate is counted ([`VariantExec::executed_macs`])
//! so the scheduler's analytic per-phase accounting
//! (`coordinator::stream::macs_at_phase`) can be verified against what
//! actually ran.
//!
//! Streaming execution is *batched* (DESIGN.md §8): the interpreter has a
//! single code path (`NativeVariant::run_step_batch`), which runs a
//! phase-aligned group of B streams by stacking their activations into
//! (C, B) matrices and executing each conv as one blocked GEMM over the
//! batch (fused bias + ELU, thread-local scratch buffers so the steady
//! state is allocation-free).  The single-stream entry points are the
//! B == 1 case of the same path, and per-stream accumulation order is
//! batch-size-independent, so batched and sequential serving are
//! bit-identical — `tests/batch_equivalence.rs` asserts it.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use super::{DeviceWeights, InferenceBackend, VariantExec};
use crate::runtime::engine::{StateSet, Weights};
use crate::runtime::manifest::{Manifest, ModelConfig, TensorSpec};
use crate::util::tensor::Tensor;

/// The dependency-free pure-Rust backend (the default).
pub struct NativeBackend;

impl InferenceBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    /// Compile a manifest into its native executable.  The manifest's
    /// `dtype` selects the registry entry: f32 manifests get the float
    /// interpreter below, int8 manifests get the quantized executable
    /// ([`crate::quant::QuantVariant`], DESIGN.md §10).  Both implement
    /// [`VariantExec`] and execute from the same host weight upload, so
    /// one backend serves mixed-precision ladders.
    fn compile_variant(&self, manifest: &Manifest) -> Result<Box<dyn VariantExec>> {
        match manifest.dtype {
            crate::runtime::manifest::Dtype::F32 => Ok(Box::new(NativeVariant::new(manifest)?)),
            crate::runtime::manifest::Dtype::Int8 => {
                Ok(Box::new(crate::quant::QuantVariant::new(manifest)?))
            }
        }
    }

    fn upload_weights(&self, weights: &Weights) -> Result<DeviceWeights> {
        Ok(DeviceWeights::Host(weights.clone()))
    }
}

/// Per-stream partial-state inventory of a config, in canonical order
/// (mirrors `python/compile/model.py::state_specs`).
pub fn state_specs(cfg: &ModelConfig) -> Vec<TensorSpec> {
    let k = cfg.kernel;
    let mut specs = Vec::new();
    for l in 1..=cfg.depth() {
        specs.push(TensorSpec {
            name: format!("enc{l}.win"),
            shape: vec![cfg.enc_in_ch(l), k - 1],
        });
    }
    for l in (1..=cfg.depth()).rev() {
        specs.push(TensorSpec {
            name: format!("dec{l}.win"),
            shape: vec![cfg.dec_in_ch(l), k - 1],
        });
    }
    for &p in &cfg.scc {
        let width = if cfg.extrap_of(p) == "tconv" { 2 } else { 1 };
        specs.push(TensorSpec {
            name: format!("up{p}.cache"),
            shape: vec![cfg.dec_out_ch(p), width],
        });
    }
    if let Some(s) = cfg.shift_pos {
        specs.push(TensorSpec {
            name: "shift.fifo".into(),
            shape: vec![cfg.enc_in_ch(s), cfg.shift],
        });
        if !cfg.scc.contains(&s) {
            let ho = if s == 1 { cfg.feat } else { cfg.dec_out_ch(s) };
            specs.push(TensorSpec {
                name: "fp.handoff".into(),
                shape: vec![ho, 1],
            });
        }
    }
    specs
}

/// Pre-resolved tensor indices (state slots and manifest parameters).
struct Indices {
    enc_win: Vec<usize>, // state slot of enc{l}.win, indexed l-1
    dec_win: Vec<usize>, // state slot of dec{l}.win, indexed l-1
    enc_w: Vec<usize>,   // param slots, indexed l-1
    enc_b: Vec<usize>,
    dec_w: Vec<usize>,
    dec_b: Vec<usize>,
    up_cache: BTreeMap<usize, usize>, // scc position -> state slot
    up_w: BTreeMap<usize, usize>,     // scc position -> param slot (tconv)
    up_b: BTreeMap<usize, usize>,
    shift_fifo: Option<usize>,
    fp_handoff: Option<usize>,
    head_w: usize,
    head_b: usize,
    n_params: usize,
}

/// Which part of an inference to run (the FP split).
#[derive(Clone, Copy, PartialEq, Eq)]
enum Part {
    All,
    Pre,
    Rest,
}

/// One variant compiled for the native backend.
pub struct NativeVariant {
    cfg: ModelConfig,
    name: String,
    period: usize,
    depth: usize,
    r_in: Vec<usize>,  // 1-based, [0] unused
    r_out: Vec<usize>, // 1-based, [0] unused
    is_scc: Vec<bool>, // 1-based, [0] unused
    tconv: Vec<bool>,  // 1-based: extrapolation at l is a learned tconv
    specs: Vec<TensorSpec>,
    idx: Indices,
    macs: AtomicU64,
}

impl NativeVariant {
    /// Compile (validate + index) one manifest for native execution.
    pub fn new(manifest: &Manifest) -> Result<NativeVariant> {
        let cfg = manifest.config.clone();
        let depth = cfg.depth();
        let name = manifest.name.clone();
        if depth == 0 {
            bail!("{name}: config has no layers");
        }
        if cfg.kernel == 0 {
            bail!("{name}: kernel must be >= 1");
        }
        if cfg.scc.windows(2).any(|w| w[0] >= w[1]) {
            bail!("{name}: scc positions must be sorted and unique");
        }
        if cfg.scc.iter().any(|&p| p == 0 || p > depth) {
            bail!("{name}: scc position out of range 1..={depth}");
        }
        if let Some(s) = cfg.shift_pos {
            if s == 0 || s > depth {
                bail!("{name}: shift_pos out of range 1..={depth}");
            }
            if cfg.shift == 0 {
                bail!("{name}: shift must be >= 1");
            }
        }
        if manifest.period != cfg.period() {
            bail!(
                "{name}: manifest period {} != 2^|scc| = {}",
                manifest.period,
                cfg.period()
            );
        }
        for &p in &cfg.scc {
            let e = cfg.extrap_of(p);
            if e != "duplicate" && e != "tconv" {
                bail!("{name}: unknown extrapolation '{e}' at S-CC {p}");
            }
        }

        let mut r_in = vec![1usize; depth + 1];
        let mut r_out = vec![1usize; depth + 1];
        let mut is_scc = vec![false; depth + 1];
        let mut tconv = vec![false; depth + 1];
        for l in 1..=depth {
            r_in[l] = cfg.r_in(l);
            r_out[l] = cfg.r_out(l);
            is_scc[l] = cfg.scc.contains(&l);
            tconv[l] = is_scc[l] && cfg.extrap_of(l) == "tconv";
        }

        let specs = state_specs(&cfg);
        let state_slot: BTreeMap<&str, usize> = specs
            .iter()
            .enumerate()
            .map(|(i, s)| (s.name.as_str(), i))
            .collect();
        let sslot = |n: &str| -> Result<usize> {
            state_slot
                .get(n)
                .copied()
                .with_context(|| format!("{name}: missing state slot {n}"))
        };

        let param_slot: BTreeMap<&str, usize> = manifest
            .params
            .iter()
            .enumerate()
            .map(|(i, s)| (s.name.as_str(), i))
            .collect();
        let pslot = |n: &str, shape: &[usize]| -> Result<usize> {
            let i = *param_slot
                .get(n)
                .with_context(|| format!("{name}: manifest lacks parameter {n}"))?;
            if manifest.params[i].shape != shape {
                bail!(
                    "{name}: parameter {n} has shape {:?}, native backend expects {:?}",
                    manifest.params[i].shape,
                    shape
                );
            }
            Ok(i)
        };

        let k = cfg.kernel;
        let mut enc_win = Vec::new();
        let mut dec_win = Vec::new();
        let mut enc_w = Vec::new();
        let mut enc_b = Vec::new();
        let mut dec_w = Vec::new();
        let mut dec_b = Vec::new();
        for l in 1..=depth {
            enc_win.push(sslot(&format!("enc{l}.win"))?);
            dec_win.push(sslot(&format!("dec{l}.win"))?);
            enc_w.push(pslot(
                &format!("enc{l}.w"),
                &[cfg.enc_out_ch(l), cfg.enc_in_ch(l), k],
            )?);
            enc_b.push(pslot(&format!("enc{l}.b"), &[cfg.enc_out_ch(l)])?);
            dec_w.push(pslot(
                &format!("dec{l}.w"),
                &[cfg.dec_out_ch(l), cfg.dec_in_ch(l), k],
            )?);
            dec_b.push(pslot(&format!("dec{l}.b"), &[cfg.dec_out_ch(l)])?);
        }
        let mut up_cache = BTreeMap::new();
        let mut up_w = BTreeMap::new();
        let mut up_b = BTreeMap::new();
        for &p in &cfg.scc {
            up_cache.insert(p, sslot(&format!("up{p}.cache"))?);
            if tconv[p] {
                let c = cfg.dec_out_ch(p);
                up_w.insert(p, pslot(&format!("up{p}.w"), &[c, c, 2])?);
                up_b.insert(p, pslot(&format!("up{p}.b"), &[c])?);
            }
        }
        let shift_fifo = if cfg.shift_pos.is_some() {
            Some(sslot("shift.fifo")?)
        } else {
            None
        };
        let fp_handoff = match cfg.shift_pos {
            Some(s) if !cfg.scc.contains(&s) => Some(sslot("fp.handoff")?),
            _ => None,
        };
        let head_w = pslot("head.w", &[cfg.feat, cfg.dec_out_ch(1), 1])?;
        let head_b = pslot("head.b", &[cfg.feat])?;

        Ok(NativeVariant {
            period: cfg.period(),
            idx: Indices {
                enc_win,
                dec_win,
                enc_w,
                enc_b,
                dec_w,
                dec_b,
                up_cache,
                up_w,
                up_b,
                shift_fifo,
                fp_handoff,
                head_w,
                head_b,
                n_params: manifest.params.len(),
            },
            cfg,
            name,
            depth,
            r_in,
            r_out,
            is_scc,
            tconv,
            specs,
            macs: AtomicU64::new(0),
        })
    }

    /// Resolve host weights from the backend-tagged handle.
    fn host<'a>(&self, dw: &'a DeviceWeights) -> Result<&'a Weights> {
        match dw {
            DeviceWeights::Host(w) => {
                if w.tensors.len() != self.idx.n_params {
                    bail!(
                        "{}: weights hold {} tensors, manifest wants {}",
                        self.name,
                        w.tensors.len(),
                        self.idx.n_params
                    );
                }
                Ok(w)
            }
            #[cfg(feature = "pjrt")]
            DeviceWeights::Pjrt(_) => {
                bail!("{}: pjrt device weights passed to the native backend", self.name)
            }
        }
    }

    // ---- counted kernels --------------------------------------------------

    /// Batched dense step conv over column-stacked windows: `xwin` is the
    /// (C_in·K, B) matrix holding one flattened window per stream column,
    /// and the (C_out, B) result lands in `out`.
    ///
    /// The loop is a register-blocked GEMM: one weight row streams over
    /// the whole batch panel, so every weight element is loaded once per
    /// *batch* instead of once per *stream*, and the inner axpy runs over
    /// contiguous memory.  Per-stream accumulation order (bias first,
    /// then taps in row order) is exactly the B == 1 order, so batched
    /// and sequential execution agree bit-for-bit.
    fn conv_win_batch(&self, w: &Tensor, b: &Tensor, xwin: &[f32], bsz: usize, out: &mut [f32]) {
        let c_out = w.shape[0];
        let n = xwin.len() / bsz;
        debug_assert_eq!(w.data.len(), c_out * n);
        debug_assert_eq!(out.len(), c_out * bsz);
        let mut acc = scratch_take(bsz);
        for o in 0..c_out {
            let row = &w.data[o * n..(o + 1) * n];
            acc.fill(b.data[o]);
            for (j, &wv) in row.iter().enumerate() {
                let xs = &xwin[j * bsz..(j + 1) * bsz];
                for (a, &x) in acc.iter_mut().zip(xs.iter()) {
                    *a += wv * x;
                }
            }
            out[o * bsz..(o + 1) * bsz].copy_from_slice(&acc);
        }
        scratch_put(acc);
        self.macs.fetch_add((c_out * n * bsz) as u64, Ordering::Relaxed);
    }

    /// Batched stride-2 transposed-conv phase: `w[:, :, ph] @ x + b` for
    /// a (C_in, B) activation panel `x`, writing (C_out, B) into `out`.
    /// Same blocked-GEMM shape and bit-exactness argument as
    /// [`NativeVariant::conv_win_batch`].
    fn tconv_phase_batch(
        &self,
        w: &Tensor,
        b: &Tensor,
        ph: usize,
        x: &[f32],
        bsz: usize,
        out: &mut [f32],
    ) {
        let c_out = w.shape[0];
        let c_in = w.shape[1];
        debug_assert_eq!(x.len(), c_in * bsz);
        let mut acc = scratch_take(bsz);
        for o in 0..c_out {
            acc.fill(b.data[o]);
            for i in 0..c_in {
                let wv = w.data[o * c_in * 2 + i * 2 + ph];
                let xs = &x[i * bsz..(i + 1) * bsz];
                for (a, &xv) in acc.iter_mut().zip(xs.iter()) {
                    *a += wv * xv;
                }
            }
            out[o * bsz..(o + 1) * bsz].copy_from_slice(&acc);
        }
        scratch_put(acc);
        self.macs
            .fetch_add((c_out * c_in * bsz) as u64, Ordering::Relaxed);
    }

    /// One output phase of a stride-2 transposed conv for a single
    /// stream: `w[:, :, ph] @ x + b` (offline path).
    fn tconv_phase(&self, w: &Tensor, b: &Tensor, ph: usize, x: &[f32]) -> Vec<f32> {
        let c_out = w.shape[0];
        let c_in = w.shape[1];
        let mut out = Vec::with_capacity(c_out);
        for o in 0..c_out {
            let mut acc = b.data[o];
            for (i, xv) in x.iter().enumerate() {
                acc += w.data[o * c_in * 2 + i * 2 + ph] * xv;
            }
            out.push(acc);
        }
        self.macs.fetch_add((c_out * c_in) as u64, Ordering::Relaxed);
        out
    }

    /// Causal stride-1 conv over a whole (C_in, T) sequence.
    fn conv_full(&self, x: &Tensor, w: &Tensor, b: &Tensor) -> Tensor {
        let c_in = x.shape[0];
        let t = x.shape[1];
        let c_out = w.shape[0];
        let k = w.shape[2];
        let mut out = Tensor::zeros(vec![c_out, t]);
        for o in 0..c_out {
            for tt in 0..t {
                let mut acc = b.data[o];
                for i in 0..c_in {
                    let wrow = &w.data[(o * c_in + i) * k..(o * c_in + i + 1) * k];
                    for (j, wv) in wrow.iter().enumerate() {
                        let src = tt as isize + j as isize - (k as isize - 1);
                        if src >= 0 {
                            acc += wv * x.at2(i, src as usize);
                        }
                    }
                }
                out.set2(o, tt, acc);
            }
        }
        self.macs
            .fetch_add((c_out * c_in * k * t) as u64, Ordering::Relaxed);
        out
    }

    // ---- streaming step (batched; B == 1 is the single-stream case) -------

    /// One inference (or one FP part of it) at schedule position `phase`
    /// for a phase-aligned batch of `states.len()` streams.
    ///
    /// This is the *only* streaming code path: [`VariantExec::step`],
    /// [`VariantExec::precompute`] and [`VariantExec::step_rest`] all run
    /// it with B == 1, so the batched and sequential paths cannot diverge
    /// in schedule logic — only the kernels see the batch, and those
    /// preserve per-stream accumulation order bit-for-bit.
    ///
    /// Every batch-wide activation is a (C, B) matrix flattened row-major
    /// (`buf[c * B + s]` = channel `c` of stream `s`), so the GEMM inner
    /// loop runs contiguously across the batch.  All intermediates come
    /// from a thread-local scratch pool: the serving steady state
    /// allocates nothing but the returned output frames.
    fn run_step_batch(
        &self,
        phase: usize,
        frames: Option<&[&[f32]]>,
        states: &mut [&mut StateSet],
        dw: &DeviceWeights,
        part: Part,
    ) -> Result<Option<Vec<Vec<f32>>>> {
        let bsz = states.len();
        if self.cfg.interp.is_some() {
            bail!(
                "{}: interpolation variants are offline-only (App. D adds a \
                 frame of latency online); use offline()",
                self.name
            );
        }
        for st in states.iter() {
            if st.tensors.len() != self.specs.len() {
                bail!(
                    "{}: state set holds {} tensors, expected {}",
                    self.name,
                    st.tensors.len(),
                    self.specs.len()
                );
            }
        }
        if let Some(fr) = frames {
            if fr.len() != bsz {
                bail!(
                    "{}: {} frames for {} state sets",
                    self.name,
                    fr.len(),
                    bsz
                );
            }
            for f in fr.iter() {
                if f.len() != self.cfg.feat {
                    bail!(
                        "{}: frame has {} samples, expected {}",
                        self.name,
                        f.len(),
                        self.cfg.feat
                    );
                }
            }
        }
        if bsz == 0 {
            return Ok(Some(Vec::new()));
        }
        let w = self.host(dw)?;
        let phase = phase % self.period;
        let depth = self.depth;
        let s = self.cfg.shift_pos;
        let delayed = |l: usize| s.map_or(false, |sp| l >= sp);
        let in_part = |l: usize| match part {
            Part::All => true,
            Part::Pre => delayed(l),
            Part::Rest => !delayed(l),
        };

        // ---- encoder ----
        let mut enc_out: Vec<Option<Vec<f32>>> = vec![None; depth + 1];
        let mut cur: Option<Vec<f32>> = match part {
            Part::Pre => None,
            _ => {
                let fr = frames.with_context(|| format!("{}: step needs frames", self.name))?;
                let mut x0 = scratch_take(self.cfg.feat * bsz);
                for (si, f) in fr.iter().enumerate() {
                    for (i, &v) in f.iter().enumerate() {
                        x0[i * bsz + si] = v;
                    }
                }
                Some(x0)
            }
        };
        for l in 1..=depth {
            if phase % self.r_in[l] != 0 {
                release(&mut cur);
                continue;
            }
            // FP delay line at the input of layer s: read the oldest entry
            // before pushing (the pre pass reads, the rest pass pushes).
            if s == Some(l) {
                let fifo_slot = self.idx.shift_fifo.unwrap();
                let c_in = self.cfg.enc_in_ch(l);
                let mut delayed_in = scratch_take(c_in * bsz);
                if part != Part::Pre {
                    let c = cur
                        .as_ref()
                        .with_context(|| format!("{}: enc{l} missing input", self.name))?;
                    for (si, st) in states.iter_mut().enumerate() {
                        let fifo = &mut st.tensors[fifo_slot];
                        gather_state_col(fifo, 0, bsz, si, &mut delayed_in);
                        push_fifo_col(fifo, c, bsz, si);
                    }
                } else {
                    for (si, st) in states.iter().enumerate() {
                        gather_state_col(&st.tensors[fifo_slot], 0, bsz, si, &mut delayed_in);
                    }
                }
                release(&mut cur);
                cur = if in_part(l) {
                    Some(delayed_in)
                } else {
                    scratch_put(delayed_in);
                    None
                };
            }
            if !in_part(l) {
                release(&mut cur);
                continue;
            }
            let c = cur
                .take()
                .with_context(|| format!("{}: enc{l} has no input at phase {phase}", self.name))?;
            let fires = if self.is_scc[l] {
                phase % (2 * self.r_in[l]) == 0
            } else {
                true
            };
            let c_in = self.cfg.enc_in_ch(l);
            let k = self.cfg.kernel;
            let mut xwin = scratch_take(c_in * k * bsz);
            for (si, st) in states.iter_mut().enumerate() {
                push_window_col(&mut st.tensors[self.idx.enc_win[l - 1]], &c, bsz, si, &mut xwin);
            }
            scratch_put(c);
            cur = if fires {
                let wt = &w.tensors[self.idx.enc_w[l - 1]];
                let bt = &w.tensors[self.idx.enc_b[l - 1]];
                let mut y = scratch_take(wt.shape[0] * bsz);
                self.conv_win_batch(wt, bt, &xwin, bsz, &mut y);
                elu(&mut y);
                // keep a copy for the decoder's skip connection
                let mut keep = scratch_take(y.len());
                keep.copy_from_slice(&y);
                enc_out[l] = Some(keep);
                Some(y)
            } else {
                None
            };
            scratch_put(xwin);
        }
        release(&mut cur);

        // ---- decoder ----
        let mut d: Option<Vec<f32>> = None;
        for l in (1..=depth).rev() {
            let mut computed_here = false;
            if phase % self.r_out[l] == 0 {
                if !in_part(l) {
                    release(&mut d);
                } else {
                    let inp: Vec<f32> = if l == depth {
                        let src = enc_out[l]
                            .as_ref()
                            .with_context(|| format!("{}: dec{l} missing input", self.name))?;
                        let mut v = scratch_take(src.len());
                        v.copy_from_slice(src);
                        v
                    } else {
                        let mut upper = d.take();
                        if part == Part::Rest && delayed(l + 1) && !self.is_scc[l + 1] {
                            // Boundary: the delayed d_{l+1} was produced by
                            // the pre pass and parked in the handoff slot.
                            release(&mut upper);
                            let slot = self.idx.fp_handoff.unwrap();
                            let c_h = states[0].tensors[slot].shape[0];
                            let mut h = scratch_take(c_h * bsz);
                            for (si, st) in states.iter().enumerate() {
                                gather_state_col(&st.tensors[slot], 0, bsz, si, &mut h);
                            }
                            upper = Some(h);
                        }
                        let v = upper
                            .with_context(|| format!("{}: dec{l} missing deep input", self.name))?;
                        let skip = enc_out[l]
                            .as_ref()
                            .with_context(|| format!("{}: dec{l} missing skip", self.name))?;
                        // stack deep rows over skip rows (channel concat)
                        let mut inp = scratch_take(v.len() + skip.len());
                        inp[..v.len()].copy_from_slice(&v);
                        inp[v.len()..].copy_from_slice(skip);
                        scratch_put(v);
                        inp
                    };
                    let c_in = self.cfg.dec_in_ch(l);
                    let k = self.cfg.kernel;
                    debug_assert_eq!(inp.len(), c_in * bsz);
                    let mut xwin = scratch_take(c_in * k * bsz);
                    for (si, st) in states.iter_mut().enumerate() {
                        push_window_col(
                            &mut st.tensors[self.idx.dec_win[l - 1]],
                            &inp,
                            bsz,
                            si,
                            &mut xwin,
                        );
                    }
                    scratch_put(inp);
                    let wt = &w.tensors[self.idx.dec_w[l - 1]];
                    let bt = &w.tensors[self.idx.dec_b[l - 1]];
                    let mut y = scratch_take(wt.shape[0] * bsz);
                    self.conv_win_batch(wt, bt, &xwin, bsz, &mut y);
                    scratch_put(xwin);
                    elu(&mut y);
                    release(&mut d);
                    d = Some(y);
                    computed_here = true;
                }
            }
            // Extrapolation back to the r_in(l) domain.  The *write*
            // belongs to whichever pass computed the fresh d_l; the *read*
            // to the pass computing d_{l-1} (or the head for l == 1).
            if self.is_scc[l] && phase % self.r_in[l] == 0 {
                let cache_slot = self.idx.up_cache[&l];
                let fresh = phase % self.r_out[l] == 0;
                if fresh && computed_here {
                    let dv = d.as_ref().unwrap();
                    if self.tconv[l] {
                        let wt = &w.tensors[self.idx.up_w[&l]];
                        let bt = &w.tensors[self.idx.up_b[&l]];
                        let mut ph0 = scratch_take(wt.shape[0] * bsz);
                        let mut ph1 = scratch_take(wt.shape[0] * bsz);
                        self.tconv_phase_batch(wt, bt, 0, dv, bsz, &mut ph0);
                        self.tconv_phase_batch(wt, bt, 1, dv, bsz, &mut ph1);
                        for (si, st) in states.iter_mut().enumerate() {
                            let cache = &mut st.tensors[cache_slot];
                            scatter_state_col(cache, 0, &ph0, bsz, si);
                            scatter_state_col(cache, 1, &ph1, bsz, si);
                        }
                        scratch_put(ph0);
                        scratch_put(ph1);
                    } else {
                        for (si, st) in states.iter_mut().enumerate() {
                            scatter_state_col(&mut st.tensors[cache_slot], 0, dv, bsz, si);
                        }
                    }
                }
                let reader_delayed = (l >= 2 && delayed(l - 1)) || (l == 1 && s == Some(1));
                let reads_here = part == Part::All
                    || (reader_delayed && part == Part::Pre)
                    || (!reader_delayed && part == Part::Rest);
                release(&mut d);
                d = if reads_here {
                    let col = if self.tconv[l] && !fresh { 1 } else { 0 };
                    let c_c = states[0].tensors[cache_slot].shape[0];
                    let mut v = scratch_take(c_c * bsz);
                    for (si, st) in states.iter().enumerate() {
                        gather_state_col(&st.tensors[cache_slot], col, bsz, si, &mut v);
                    }
                    Some(v)
                } else {
                    None
                };
            }
            // FP boundary handoff (pre pass writes; rest pass reads above).
            if part == Part::Pre
                && s == Some(l)
                && !self.is_scc[l]
                && phase % self.r_out[l] == 0
                && l != 1
            {
                if let Some(dv) = &d {
                    let slot = self.idx.fp_handoff.unwrap();
                    for (si, st) in states.iter_mut().enumerate() {
                        scatter_state_col(&mut st.tensors[slot], 0, dv, bsz, si);
                    }
                }
            }
        }

        // ---- head ----
        let head_w = &w.tensors[self.idx.head_w];
        let head_b = &w.tensors[self.idx.head_b];
        let feat = self.cfg.feat;
        let result = match part {
            Part::Pre => {
                if s == Some(1) {
                    // Whole network delayed: the head output is the handoff.
                    let dv = d
                        .take()
                        .with_context(|| format!("{}: pre pass lost the head input", self.name))?;
                    let mut out = scratch_take(feat * bsz);
                    self.conv_win_batch(head_w, head_b, &dv, bsz, &mut out);
                    scratch_put(dv);
                    let slot = self.idx.fp_handoff.unwrap();
                    for (si, st) in states.iter_mut().enumerate() {
                        scatter_state_col(&mut st.tensors[slot], 0, &out, bsz, si);
                    }
                    scratch_put(out);
                }
                None
            }
            Part::Rest if s == Some(1) => {
                let slot = self.idx.fp_handoff.unwrap();
                let mut out = scratch_take(feat * bsz);
                for (si, st) in states.iter().enumerate() {
                    gather_state_col(&st.tensors[slot], 0, bsz, si, &mut out);
                }
                let frames_out = split_columns(&out, bsz, feat);
                scratch_put(out);
                Some(frames_out)
            }
            _ => {
                let dv = d
                    .take()
                    .with_context(|| format!("{}: no decoder output at phase {phase}", self.name))?;
                let mut out = scratch_take(feat * bsz);
                self.conv_win_batch(head_w, head_b, &dv, bsz, &mut out);
                scratch_put(dv);
                let frames_out = split_columns(&out, bsz, feat);
                scratch_put(out);
                Some(frames_out)
            }
        };
        release(&mut d);
        for e in enc_out.iter_mut() {
            release(e);
        }
        Ok(result)
    }

    // ---- offline (full-sequence) interpreter ------------------------------

    fn offline_forward(&self, x: &Tensor, w: &Weights) -> Result<Tensor> {
        let cfg = &self.cfg;
        if x.shape.len() != 2 || x.shape[0] != cfg.feat {
            bail!(
                "{}: offline input shape {:?}, expected [{}, T]",
                self.name,
                x.shape,
                cfg.feat
            );
        }
        if x.shape[1] == 0 || x.shape[1] % self.period != 0 {
            bail!(
                "{}: offline T = {} must be a positive multiple of the period {}",
                self.name,
                x.shape[1],
                self.period
            );
        }
        let depth = self.depth;
        let mut enc: Vec<Tensor> = Vec::with_capacity(depth + 1);
        enc.push(x.clone());
        let mut cur = x.clone();
        for l in 1..=depth {
            if cfg.shift_pos == Some(l) {
                cur = delay_cols(&cur, cfg.shift);
            }
            let mut y = self.conv_full(
                &cur,
                &w.tensors[self.idx.enc_w[l - 1]],
                &w.tensors[self.idx.enc_b[l - 1]],
            );
            if self.is_scc[l] {
                y = stride2(&y);
            }
            elu(&mut y.data);
            cur = y.clone();
            enc.push(y);
        }

        let mut d: Option<Tensor> = None;
        for l in (1..=depth).rev() {
            let inp = if l == depth {
                enc[depth].clone()
            } else {
                concat_rows(d.as_ref().unwrap(), &enc[l])
            };
            let mut y = self.conv_full(
                &inp,
                &w.tensors[self.idx.dec_w[l - 1]],
                &w.tensors[self.idx.dec_b[l - 1]],
            );
            elu(&mut y.data);
            let mut dl = y;
            if self.is_scc[l] {
                let t_out = enc[l - 1].shape[1];
                dl = if let Some(kind) = &cfg.interp {
                    interp_upsample(&dl, t_out, kind)
                        .with_context(|| format!("{}: up{l}", self.name))?
                } else if self.tconv[l] {
                    self.tconv_upsample(
                        &dl,
                        &w.tensors[self.idx.up_w[&l]],
                        &w.tensors[self.idx.up_b[&l]],
                        t_out,
                    )
                } else {
                    duplicate_upsample(&dl, t_out)
                };
            }
            d = Some(dl);
        }
        Ok(self.conv_full(
            &d.unwrap(),
            &w.tensors[self.idx.head_w],
            &w.tensors[self.idx.head_b],
        ))
    }

    /// Stride-2 transposed conv over a whole sequence: phase 0 lands on
    /// even output times, phase 1 on odd ones.
    fn tconv_upsample(&self, y: &Tensor, w: &Tensor, b: &Tensor, t_out: usize) -> Tensor {
        let c_out = w.shape[0];
        let s = y.shape[1];
        let mut out = Tensor::zeros(vec![c_out, t_out]);
        for src in 0..s {
            let col = column(y, src);
            let ph0 = self.tconv_phase(w, b, 0, &col);
            let ph1 = self.tconv_phase(w, b, 1, &col);
            if 2 * src < t_out {
                set_column(&mut out, 2 * src, &ph0);
            }
            if 2 * src + 1 < t_out {
                set_column(&mut out, 2 * src + 1, &ph1);
            }
        }
        out
    }
}

impl VariantExec for NativeVariant {
    fn init_states(&self) -> StateSet {
        StateSet {
            tensors: self
                .specs
                .iter()
                .map(|s| Tensor::zeros(s.shape.clone()))
                .collect(),
        }
    }

    fn has_fp_split(&self) -> bool {
        // An FP shift at layer 1 that is *also* an S-CC position has no
        // handoff slot (the head boundary value has nowhere to park) —
        // the reference model cannot split that configuration either;
        // the paper's SS-CC table starts at position 2.
        match self.cfg.shift_pos {
            Some(1) => !self.cfg.scc.contains(&1),
            Some(_) => true,
            None => false,
        }
    }

    fn step(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        weights: &DeviceWeights,
    ) -> Result<Vec<f32>> {
        let frames = [frame];
        let mut sts = [states];
        let out =
            self.run_step_batch(phase, Some(&frames[..]), &mut sts[..], weights, Part::All)?;
        let mut out = out.with_context(|| format!("{}: step produced no output", self.name))?;
        Ok(out.remove(0))
    }

    fn precompute(
        &self,
        phase: usize,
        states: &mut StateSet,
        weights: &DeviceWeights,
    ) -> Result<()> {
        if !self.has_fp_split() {
            bail!("{}: variant has no FP split", self.name);
        }
        let mut sts = [states];
        self.run_step_batch(phase, None, &mut sts[..], weights, Part::Pre)?;
        Ok(())
    }

    fn step_rest(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        weights: &DeviceWeights,
    ) -> Result<Vec<f32>> {
        if !self.has_fp_split() {
            bail!("{}: variant has no FP split", self.name);
        }
        let frames = [frame];
        let mut sts = [states];
        let out =
            self.run_step_batch(phase, Some(&frames[..]), &mut sts[..], weights, Part::Rest)?;
        let mut out =
            out.with_context(|| format!("{}: rest pass produced no output", self.name))?;
        Ok(out.remove(0))
    }

    fn step_batch(
        &self,
        phase: usize,
        frames: &[&[f32]],
        states: &mut [&mut StateSet],
        weights: &DeviceWeights,
    ) -> Result<Vec<Vec<f32>>> {
        // run_step_batch validates frame/state arity and frame sizes
        let out = self.run_step_batch(phase, Some(frames), states, weights, Part::All)?;
        out.with_context(|| format!("{}: batched step produced no output", self.name))
    }

    fn step_rest_batch(
        &self,
        phase: usize,
        frames: &[&[f32]],
        states: &mut [&mut StateSet],
        weights: &DeviceWeights,
    ) -> Result<Vec<Vec<f32>>> {
        if !self.has_fp_split() {
            bail!("{}: variant has no FP split", self.name);
        }
        let out = self.run_step_batch(phase, Some(frames), states, weights, Part::Rest)?;
        out.with_context(|| format!("{}: batched rest pass produced no output", self.name))
    }

    fn offline(&self, x: &Tensor, weights: &DeviceWeights) -> Result<Tensor> {
        let w = self.host(weights)?;
        self.offline_forward(x, w)
    }

    fn executed_macs(&self) -> Option<u64> {
        Some(self.macs.load(Ordering::Relaxed))
    }

    fn reset_executed_macs(&self) {
        self.macs.store(0, Ordering::Relaxed);
    }
}

// ---- scratch pool ----------------------------------------------------------

thread_local! {
    /// Per-thread free list of batch scratch buffers.  Sizes stabilise
    /// after the first step through a variant, so the serving worker's
    /// steady state is allocation-free.
    static SCRATCH: RefCell<Vec<Vec<f32>>> = RefCell::new(Vec::new());
}

/// Take a zeroed length-`n` buffer from the thread-local scratch pool.
fn scratch_take(n: usize) -> Vec<f32> {
    SCRATCH.with(|p| {
        let mut v = p.borrow_mut().pop().unwrap_or_default();
        v.clear();
        v.resize(n, 0.0);
        v
    })
}

/// Return a buffer to the thread-local scratch pool for reuse.
fn scratch_put(v: Vec<f32>) {
    SCRATCH.with(|p| p.borrow_mut().push(v));
}

/// Return an optional batch buffer to the pool and leave `None` behind.
fn release(v: &mut Option<Vec<f32>>) {
    if let Some(buf) = v.take() {
        scratch_put(buf);
    }
}

// ---- column/window primitives ---------------------------------------------
//
// Per-stream states stay row-major (C, W) tensors; batch-wide activations
// are (C, B) matrices.  The helpers below move one stream's column
// between the two layouts.

/// ELU activation in place.
fn elu(v: &mut [f32]) {
    for x in v.iter_mut() {
        if *x < 0.0 {
            *x = x.exp_m1();
        }
    }
}

/// Extract column `j` of a (C, W) tensor (offline path).
fn column(t: &Tensor, j: usize) -> Vec<f32> {
    let w = t.shape[1];
    (0..t.shape[0]).map(|i| t.data[i * w + j]).collect()
}

/// Overwrite column `j` of a (C, W) tensor (offline path).
fn set_column(t: &mut Tensor, j: usize, v: &[f32]) {
    let w = t.shape[1];
    for (i, &x) in v.iter().enumerate() {
        t.data[i * w + j] = x;
    }
}

/// Read column `col` of stream `si`'s (C, W) state tensor into column
/// `si` of a (C, B) batch matrix.
fn gather_state_col(t: &Tensor, col: usize, bsz: usize, si: usize, dst: &mut [f32]) {
    let w = t.shape[1];
    for i in 0..t.shape[0] {
        dst[i * bsz + si] = t.data[i * w + col];
    }
}

/// Write column `si` of a (C, B) batch matrix into column `col` of
/// stream `si`'s (C, W) state tensor.
fn scatter_state_col(t: &mut Tensor, col: usize, src: &[f32], bsz: usize, si: usize) {
    let w = t.shape[1];
    for i in 0..t.shape[0] {
        t.data[i * w + col] = src[i * bsz + si];
    }
}

/// STMC window tick for stream `si`: writes that stream's full (C, K)
/// window `[state | cur]` into column `si` of the (C·K, B) matrix `dst`
/// and advances the per-stream window state to `window[:, 1:]`.
fn push_window_col(state: &mut Tensor, cur: &[f32], bsz: usize, si: usize, dst: &mut [f32]) {
    let c = state.shape[0];
    let wlen = state.shape[1]; // K - 1
    let k = wlen + 1;
    for i in 0..c {
        let row = &mut state.data[i * wlen..(i + 1) * wlen];
        for (j, &v) in row.iter().enumerate() {
            dst[(i * k + j) * bsz + si] = v;
        }
        let x = cur[i * bsz + si];
        dst[(i * k + wlen) * bsz + si] = x;
        if wlen > 0 {
            row.copy_within(1.., 0);
            row[wlen - 1] = x;
        }
    }
}

/// FIFO tick for stream `si`: drop the oldest column, append that
/// stream's current value (column `si` of the (C, B) matrix `cur`).
fn push_fifo_col(state: &mut Tensor, cur: &[f32], bsz: usize, si: usize) {
    let w = state.shape[1];
    for i in 0..state.shape[0] {
        let row = &mut state.data[i * w..(i + 1) * w];
        row.copy_within(1.., 0);
        row[w - 1] = cur[i * bsz + si];
    }
}

/// Split a (C, B) batch matrix into per-stream output frames.
fn split_columns(m: &[f32], bsz: usize, c: usize) -> Vec<Vec<f32>> {
    (0..bsz)
        .map(|si| (0..c).map(|i| m[i * bsz + si]).collect())
        .collect()
}

// ---- offline sequence primitives ------------------------------------------

/// Right-shift along time by `d` frames (zeros in front), same length.
fn delay_cols(x: &Tensor, d: usize) -> Tensor {
    let (c, t) = (x.shape[0], x.shape[1]);
    let mut out = Tensor::zeros(vec![c, t]);
    for i in 0..c {
        for tt in d..t {
            out.set2(i, tt, x.at2(i, tt - d));
        }
    }
    out
}

/// Keep even time steps: `out[:, s] = x[:, 2 s]`.
fn stride2(x: &Tensor) -> Tensor {
    let (c, t) = (x.shape[0], x.shape[1]);
    let t2 = (t + 1) / 2;
    let mut out = Tensor::zeros(vec![c, t2]);
    for i in 0..c {
        for s in 0..t2 {
            out.set2(i, s, x.at2(i, 2 * s));
        }
    }
    out
}

/// Stack `a` over `b` along the channel axis.
fn concat_rows(a: &Tensor, b: &Tensor) -> Tensor {
    debug_assert_eq!(a.shape[1], b.shape[1]);
    let t = a.shape[1];
    let c = a.shape[0] + b.shape[0];
    let mut data = Vec::with_capacity(c * t);
    data.extend_from_slice(&a.data);
    data.extend_from_slice(&b.data);
    Tensor::new(vec![c, t], data)
}

/// Duplication extrapolation (PP alignment): `up[:, t] = y[:, t / 2]`.
fn duplicate_upsample(y: &Tensor, t_out: usize) -> Tensor {
    let c = y.shape[0];
    let last = y.shape[1] - 1;
    let mut out = Tensor::zeros(vec![c, t_out]);
    for i in 0..c {
        for tt in 0..t_out {
            out.set2(i, tt, y.at2(i, (tt / 2).min(last)));
        }
    }
    out
}

/// Interpolation reconstruction (App. D, offline-only).
fn interp_upsample(y: &Tensor, t_out: usize, kind: &str) -> Result<Tensor> {
    let c = y.shape[0];
    let last = y.shape[1] as isize - 1;
    let tap = |i: usize, j: isize| y.at2(i, j.clamp(0, last) as usize);
    let mut out = Tensor::zeros(vec![c, t_out]);
    for tt in 0..t_out {
        let s0 = (tt / 2) as isize;
        let odd = tt % 2 == 1;
        let frac: f32 = if odd { 0.5 } else { 0.0 };
        for i in 0..c {
            let v = match kind {
                "nearest" => tap(i, s0 + if odd { 1 } else { 0 }),
                "linear" => tap(i, s0) * (1.0 - frac) + tap(i, s0 + 1) * frac,
                "cubic" => {
                    // Catmull-Rom with u = frac
                    let (p0, p1, p2, p3) =
                        (tap(i, s0 - 1), tap(i, s0), tap(i, s0 + 1), tap(i, s0 + 2));
                    let u = frac;
                    0.5 * ((2.0 * p1)
                        + (-p0 + p2) * u
                        + (2.0 * p0 - 5.0 * p1 + 4.0 * p2 - p3) * u * u
                        + (-p0 + 3.0 * p1 - 3.0 * p2 + p3) * u * u * u)
                }
                other => bail!("unknown interpolation kind '{other}'"),
            };
            out.set2(i, tt, v);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_specs_mirror_python_inventory() {
        let cfg = ModelConfig {
            feat: 4,
            channels: vec![6, 8],
            kernel: 3,
            scc: vec![2],
            shift_pos: Some(2),
            shift: 1,
            extrap: vec!["duplicate".into()],
            interp: None,
        };
        let specs = state_specs(&cfg);
        let names: Vec<&str> = specs.iter().map(|s| s.name.as_str()).collect();
        // s == p (SS-CC): no fp.handoff slot.
        assert_eq!(
            names,
            ["enc1.win", "enc2.win", "dec2.win", "dec1.win", "up2.cache", "shift.fifo"]
        );
        assert_eq!(specs[0].shape, vec![4, 2]); // enc1: feat x (k-1)
        assert_eq!(specs[2].shape, vec![8, 2]); // dec2 in = channels[1]
        assert_eq!(specs[3].shape, vec![6 + 6, 2]); // dec1 in = dec_out(2)+ch[0]
        assert_eq!(specs[4].shape, vec![6, 1]); // up2 cache = dec_out(2)
        assert_eq!(specs[5].shape, vec![6, 1]); // fifo at enc2 input
    }

    #[test]
    fn hybrid_fp_gets_handoff_slot() {
        let cfg = ModelConfig {
            feat: 4,
            channels: vec![5, 6, 7],
            kernel: 3,
            scc: vec![3],
            shift_pos: Some(2),
            shift: 1,
            extrap: vec!["duplicate".into()],
            interp: None,
        };
        let names: Vec<String> = state_specs(&cfg).iter().map(|s| s.name.clone()).collect();
        assert!(names.contains(&"fp.handoff".to_string()));
        assert!(names.contains(&"shift.fifo".to_string()));
    }

    #[test]
    fn push_window_col_shifts_by_one() {
        // Stream 1 of a 2-wide batch: C = 2 channels, kernel 3.
        let mut st = Tensor::new(vec![2, 2], vec![1.0, 2.0, 10.0, 20.0]);
        let bsz = 2;
        // cur is a (2, 2) batch matrix; stream 1's column is [3, 30].
        let cur = vec![-1.0, 3.0, -1.0, 30.0];
        let mut dst = vec![0.0f32; 2 * 3 * bsz];
        push_window_col(&mut st, &cur, bsz, 1, &mut dst);
        // column 1 of dst holds the stream's flattened (C, K) window
        let win: Vec<f32> = (0..6).map(|r| dst[r * bsz + 1]).collect();
        assert_eq!(win, vec![1.0, 2.0, 3.0, 10.0, 20.0, 30.0]);
        assert_eq!(st.data, vec![2.0, 3.0, 20.0, 30.0]);
        // stream 0's column was left untouched
        assert!((0..6).all(|r| dst[r * bsz] == 0.0));
    }

    #[test]
    fn fifo_col_drops_oldest() {
        let mut st = Tensor::new(vec![1, 3], vec![1.0, 2.0, 3.0]);
        push_fifo_col(&mut st, &[4.0], 1, 0);
        assert_eq!(st.data, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn gather_scatter_roundtrip_state_columns() {
        let mut st = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let bsz = 3;
        let mut panel = vec![0.0f32; 2 * bsz];
        gather_state_col(&st, 1, bsz, 2, &mut panel);
        assert_eq!(panel, vec![0.0, 0.0, 2.0, 0.0, 0.0, 4.0]);
        scatter_state_col(&mut st, 0, &panel, bsz, 2);
        assert_eq!(st.data, vec![2.0, 2.0, 4.0, 4.0]);
    }

    #[test]
    fn split_columns_transposes_batch() {
        // (C = 2, B = 2) matrix [[1, 2], [3, 4]] -> streams [1,3], [2,4]
        let m = vec![1.0, 2.0, 3.0, 4.0];
        let frames = split_columns(&m, 2, 2);
        assert_eq!(frames, vec![vec![1.0, 3.0], vec![2.0, 4.0]]);
    }

    #[test]
    fn scratch_pool_reuses_buffers() {
        let a = scratch_take(8);
        let pa = a.as_ptr();
        scratch_put(a);
        let b = scratch_take(4); // smaller fits the recycled allocation
        assert_eq!(b.as_ptr(), pa);
        assert!(b.iter().all(|&v| v == 0.0));
        scratch_put(b);
    }

    #[test]
    fn duplicate_upsample_repeats_frames() {
        let y = Tensor::new(vec![1, 2], vec![5.0, 7.0]);
        let up = duplicate_upsample(&y, 4);
        assert_eq!(up.data, vec![5.0, 5.0, 7.0, 7.0]);
    }
}
