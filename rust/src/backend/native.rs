//! Native pure-Rust SOI backend: interprets a variant [`Manifest`]
//! directly — no ML runtime, no codegen, no external dependencies.
//!
//! This is the executable form of `python/compile/model.py`'s streaming
//! semantics (the paper's eq. 3–7), cross-checked in
//! `tests/native_backend.rs`:
//!
//! * Encoder layer `l` *ticks* (pushes its STMC conv window) when
//!   `phase % r_in(l) == 0`; an S-CC layer `p` additionally *fires*
//!   (computes) only when `phase % (2·r_in(p)) == 0` — the paper's eq. 4
//!   odd-inference branch just updates state.
//! * Decoder layer `l` computes when `phase % r_out(l) == 0`; S-CC
//!   positions extrapolate their activation back to the `r_in` domain
//!   through a one-frame cache (duplication) or a two-phase learned
//!   transposed conv (`tconv`).
//! * An FP shift at encoder `s` reads a delay-line FIFO, making layers
//!   `s..=depth` (and the mirrored decoder region) depend on past data
//!   only; [`VariantExec::precompute`] runs exactly that region before
//!   the frame arrives and parks the boundary value in a handoff slot
//!   for [`VariantExec::step_rest`].
//!
//! Every multiply-accumulate is counted ([`VariantExec::executed_macs`])
//! so the scheduler's analytic per-phase accounting
//! (`coordinator::stream::macs_at_phase`) can be verified against what
//! actually ran.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use super::{DeviceWeights, InferenceBackend, VariantExec};
use crate::runtime::engine::{StateSet, Weights};
use crate::runtime::manifest::{Manifest, ModelConfig, TensorSpec};
use crate::util::tensor::Tensor;

/// The dependency-free pure-Rust backend (the default).
pub struct NativeBackend;

impl InferenceBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn compile_variant(&self, manifest: &Manifest) -> Result<Box<dyn VariantExec>> {
        Ok(Box::new(NativeVariant::new(manifest)?))
    }

    fn upload_weights(&self, weights: &Weights) -> Result<DeviceWeights> {
        Ok(DeviceWeights::Host(weights.clone()))
    }
}

/// Per-stream partial-state inventory of a config, in canonical order
/// (mirrors `python/compile/model.py::state_specs`).
pub fn state_specs(cfg: &ModelConfig) -> Vec<TensorSpec> {
    let k = cfg.kernel;
    let mut specs = Vec::new();
    for l in 1..=cfg.depth() {
        specs.push(TensorSpec {
            name: format!("enc{l}.win"),
            shape: vec![cfg.enc_in_ch(l), k - 1],
        });
    }
    for l in (1..=cfg.depth()).rev() {
        specs.push(TensorSpec {
            name: format!("dec{l}.win"),
            shape: vec![cfg.dec_in_ch(l), k - 1],
        });
    }
    for &p in &cfg.scc {
        let width = if cfg.extrap_of(p) == "tconv" { 2 } else { 1 };
        specs.push(TensorSpec {
            name: format!("up{p}.cache"),
            shape: vec![cfg.dec_out_ch(p), width],
        });
    }
    if let Some(s) = cfg.shift_pos {
        specs.push(TensorSpec {
            name: "shift.fifo".into(),
            shape: vec![cfg.enc_in_ch(s), cfg.shift],
        });
        if !cfg.scc.contains(&s) {
            let ho = if s == 1 { cfg.feat } else { cfg.dec_out_ch(s) };
            specs.push(TensorSpec {
                name: "fp.handoff".into(),
                shape: vec![ho, 1],
            });
        }
    }
    specs
}

/// Pre-resolved tensor indices (state slots and manifest parameters).
struct Indices {
    enc_win: Vec<usize>, // state slot of enc{l}.win, indexed l-1
    dec_win: Vec<usize>, // state slot of dec{l}.win, indexed l-1
    enc_w: Vec<usize>,   // param slots, indexed l-1
    enc_b: Vec<usize>,
    dec_w: Vec<usize>,
    dec_b: Vec<usize>,
    up_cache: BTreeMap<usize, usize>, // scc position -> state slot
    up_w: BTreeMap<usize, usize>,     // scc position -> param slot (tconv)
    up_b: BTreeMap<usize, usize>,
    shift_fifo: Option<usize>,
    fp_handoff: Option<usize>,
    head_w: usize,
    head_b: usize,
    n_params: usize,
}

/// Which part of an inference to run (the FP split).
#[derive(Clone, Copy, PartialEq, Eq)]
enum Part {
    All,
    Pre,
    Rest,
}

/// One variant compiled for the native backend.
pub struct NativeVariant {
    cfg: ModelConfig,
    name: String,
    period: usize,
    depth: usize,
    r_in: Vec<usize>,  // 1-based, [0] unused
    r_out: Vec<usize>, // 1-based, [0] unused
    is_scc: Vec<bool>, // 1-based, [0] unused
    tconv: Vec<bool>,  // 1-based: extrapolation at l is a learned tconv
    specs: Vec<TensorSpec>,
    idx: Indices,
    macs: AtomicU64,
}

impl NativeVariant {
    pub fn new(manifest: &Manifest) -> Result<NativeVariant> {
        let cfg = manifest.config.clone();
        let depth = cfg.depth();
        let name = manifest.name.clone();
        if depth == 0 {
            bail!("{name}: config has no layers");
        }
        if cfg.kernel == 0 {
            bail!("{name}: kernel must be >= 1");
        }
        if cfg.scc.windows(2).any(|w| w[0] >= w[1]) {
            bail!("{name}: scc positions must be sorted and unique");
        }
        if cfg.scc.iter().any(|&p| p == 0 || p > depth) {
            bail!("{name}: scc position out of range 1..={depth}");
        }
        if let Some(s) = cfg.shift_pos {
            if s == 0 || s > depth {
                bail!("{name}: shift_pos out of range 1..={depth}");
            }
            if cfg.shift == 0 {
                bail!("{name}: shift must be >= 1");
            }
        }
        if manifest.period != cfg.period() {
            bail!(
                "{name}: manifest period {} != 2^|scc| = {}",
                manifest.period,
                cfg.period()
            );
        }
        for &p in &cfg.scc {
            let e = cfg.extrap_of(p);
            if e != "duplicate" && e != "tconv" {
                bail!("{name}: unknown extrapolation '{e}' at S-CC {p}");
            }
        }

        let mut r_in = vec![1usize; depth + 1];
        let mut r_out = vec![1usize; depth + 1];
        let mut is_scc = vec![false; depth + 1];
        let mut tconv = vec![false; depth + 1];
        for l in 1..=depth {
            r_in[l] = cfg.r_in(l);
            r_out[l] = cfg.r_out(l);
            is_scc[l] = cfg.scc.contains(&l);
            tconv[l] = is_scc[l] && cfg.extrap_of(l) == "tconv";
        }

        let specs = state_specs(&cfg);
        let state_slot: BTreeMap<&str, usize> = specs
            .iter()
            .enumerate()
            .map(|(i, s)| (s.name.as_str(), i))
            .collect();
        let sslot = |n: &str| -> Result<usize> {
            state_slot
                .get(n)
                .copied()
                .with_context(|| format!("{name}: missing state slot {n}"))
        };

        let param_slot: BTreeMap<&str, usize> = manifest
            .params
            .iter()
            .enumerate()
            .map(|(i, s)| (s.name.as_str(), i))
            .collect();
        let pslot = |n: &str, shape: &[usize]| -> Result<usize> {
            let i = *param_slot
                .get(n)
                .with_context(|| format!("{name}: manifest lacks parameter {n}"))?;
            if manifest.params[i].shape != shape {
                bail!(
                    "{name}: parameter {n} has shape {:?}, native backend expects {:?}",
                    manifest.params[i].shape,
                    shape
                );
            }
            Ok(i)
        };

        let k = cfg.kernel;
        let mut enc_win = Vec::new();
        let mut dec_win = Vec::new();
        let mut enc_w = Vec::new();
        let mut enc_b = Vec::new();
        let mut dec_w = Vec::new();
        let mut dec_b = Vec::new();
        for l in 1..=depth {
            enc_win.push(sslot(&format!("enc{l}.win"))?);
            dec_win.push(sslot(&format!("dec{l}.win"))?);
            enc_w.push(pslot(
                &format!("enc{l}.w"),
                &[cfg.enc_out_ch(l), cfg.enc_in_ch(l), k],
            )?);
            enc_b.push(pslot(&format!("enc{l}.b"), &[cfg.enc_out_ch(l)])?);
            dec_w.push(pslot(
                &format!("dec{l}.w"),
                &[cfg.dec_out_ch(l), cfg.dec_in_ch(l), k],
            )?);
            dec_b.push(pslot(&format!("dec{l}.b"), &[cfg.dec_out_ch(l)])?);
        }
        let mut up_cache = BTreeMap::new();
        let mut up_w = BTreeMap::new();
        let mut up_b = BTreeMap::new();
        for &p in &cfg.scc {
            up_cache.insert(p, sslot(&format!("up{p}.cache"))?);
            if tconv[p] {
                let c = cfg.dec_out_ch(p);
                up_w.insert(p, pslot(&format!("up{p}.w"), &[c, c, 2])?);
                up_b.insert(p, pslot(&format!("up{p}.b"), &[c])?);
            }
        }
        let shift_fifo = if cfg.shift_pos.is_some() {
            Some(sslot("shift.fifo")?)
        } else {
            None
        };
        let fp_handoff = match cfg.shift_pos {
            Some(s) if !cfg.scc.contains(&s) => Some(sslot("fp.handoff")?),
            _ => None,
        };
        let head_w = pslot("head.w", &[cfg.feat, cfg.dec_out_ch(1), 1])?;
        let head_b = pslot("head.b", &[cfg.feat])?;

        Ok(NativeVariant {
            period: cfg.period(),
            idx: Indices {
                enc_win,
                dec_win,
                enc_w,
                enc_b,
                dec_w,
                dec_b,
                up_cache,
                up_w,
                up_b,
                shift_fifo,
                fp_handoff,
                head_w,
                head_b,
                n_params: manifest.params.len(),
            },
            cfg,
            name,
            depth,
            r_in,
            r_out,
            is_scc,
            tconv,
            specs,
            macs: AtomicU64::new(0),
        })
    }

    /// Resolve host weights from the backend-tagged handle.
    fn host<'a>(&self, dw: &'a DeviceWeights) -> Result<&'a Weights> {
        match dw {
            DeviceWeights::Host(w) => {
                if w.tensors.len() != self.idx.n_params {
                    bail!(
                        "{}: weights hold {} tensors, manifest wants {}",
                        self.name,
                        w.tensors.len(),
                        self.idx.n_params
                    );
                }
                Ok(w)
            }
            #[cfg(feature = "pjrt")]
            DeviceWeights::Pjrt(_) => {
                bail!("{}: pjrt device weights passed to the native backend", self.name)
            }
        }
    }

    // ---- counted kernels --------------------------------------------------

    /// Dense step conv over a flattened (C_in, K) window.
    fn conv_win(&self, w: &Tensor, b: &Tensor, win: &[f32]) -> Vec<f32> {
        let c_out = w.shape[0];
        let n = win.len();
        let mut out = Vec::with_capacity(c_out);
        for o in 0..c_out {
            let row = &w.data[o * n..(o + 1) * n];
            let mut acc = b.data[o];
            for (wv, xv) in row.iter().zip(win) {
                acc += wv * xv;
            }
            out.push(acc);
        }
        self.macs.fetch_add((c_out * n) as u64, Ordering::Relaxed);
        out
    }

    /// One output phase of a stride-2 transposed conv: `w[:, :, ph] @ x + b`.
    fn tconv_phase(&self, w: &Tensor, b: &Tensor, ph: usize, x: &[f32]) -> Vec<f32> {
        let c_out = w.shape[0];
        let c_in = w.shape[1];
        let mut out = Vec::with_capacity(c_out);
        for o in 0..c_out {
            let mut acc = b.data[o];
            for (i, xv) in x.iter().enumerate() {
                acc += w.data[o * c_in * 2 + i * 2 + ph] * xv;
            }
            out.push(acc);
        }
        self.macs.fetch_add((c_out * c_in) as u64, Ordering::Relaxed);
        out
    }

    /// Causal stride-1 conv over a whole (C_in, T) sequence.
    fn conv_full(&self, x: &Tensor, w: &Tensor, b: &Tensor) -> Tensor {
        let c_in = x.shape[0];
        let t = x.shape[1];
        let c_out = w.shape[0];
        let k = w.shape[2];
        let mut out = Tensor::zeros(vec![c_out, t]);
        for o in 0..c_out {
            for tt in 0..t {
                let mut acc = b.data[o];
                for i in 0..c_in {
                    let wrow = &w.data[(o * c_in + i) * k..(o * c_in + i + 1) * k];
                    for (j, wv) in wrow.iter().enumerate() {
                        let src = tt as isize + j as isize - (k as isize - 1);
                        if src >= 0 {
                            acc += wv * x.at2(i, src as usize);
                        }
                    }
                }
                out.set2(o, tt, acc);
            }
        }
        self.macs
            .fetch_add((c_out * c_in * k * t) as u64, Ordering::Relaxed);
        out
    }

    // ---- streaming step ---------------------------------------------------

    /// One inference (or one FP part of it) at schedule position `phase`.
    fn run_step(
        &self,
        phase: usize,
        frame: Option<&[f32]>,
        states: &mut StateSet,
        dw: &DeviceWeights,
        part: Part,
    ) -> Result<Option<Vec<f32>>> {
        if self.cfg.interp.is_some() {
            bail!(
                "{}: interpolation variants are offline-only (App. D adds a \
                 frame of latency online); use offline()",
                self.name
            );
        }
        if states.tensors.len() != self.specs.len() {
            bail!(
                "{}: state set holds {} tensors, expected {}",
                self.name,
                states.tensors.len(),
                self.specs.len()
            );
        }
        let w = self.host(dw)?;
        let phase = phase % self.period;
        let depth = self.depth;
        let s = self.cfg.shift_pos;
        let delayed = |l: usize| s.map_or(false, |sp| l >= sp);
        let in_part = |l: usize| match part {
            Part::All => true,
            Part::Pre => delayed(l),
            Part::Rest => !delayed(l),
        };

        // ---- encoder ----
        let mut enc_out: Vec<Option<Vec<f32>>> = vec![None; depth + 1];
        let mut cur: Option<Vec<f32>> = match part {
            Part::Pre => None,
            _ => Some(
                frame
                    .with_context(|| format!("{}: step needs a frame", self.name))?
                    .to_vec(),
            ),
        };
        for l in 1..=depth {
            if phase % self.r_in[l] != 0 {
                cur = None;
                continue;
            }
            // FP delay line at the input of layer s: read the oldest entry
            // before pushing (the pre pass reads, the rest pass pushes).
            if s == Some(l) {
                let fifo = &mut states.tensors[self.idx.shift_fifo.unwrap()];
                let delayed_in = column(fifo, 0);
                if part != Part::Pre {
                    let c = cur
                        .as_ref()
                        .with_context(|| format!("{}: enc{l} missing input", self.name))?;
                    push_fifo(fifo, c);
                }
                cur = if in_part(l) { Some(delayed_in) } else { None };
            }
            if !in_part(l) {
                cur = None;
                continue;
            }
            let c = cur
                .take()
                .with_context(|| format!("{}: enc{l} has no input at phase {phase}", self.name))?;
            let fires = if self.is_scc[l] {
                phase % (2 * self.r_in[l]) == 0
            } else {
                true
            };
            let win = push_window(&mut states.tensors[self.idx.enc_win[l - 1]], &c);
            cur = if fires {
                let mut y = self.conv_win(
                    &w.tensors[self.idx.enc_w[l - 1]],
                    &w.tensors[self.idx.enc_b[l - 1]],
                    &win,
                );
                elu(&mut y);
                Some(y)
            } else {
                None
            };
            enc_out[l] = cur.clone();
        }

        // ---- decoder ----
        let mut d: Option<Vec<f32>> = None;
        for l in (1..=depth).rev() {
            let mut computed_here = false;
            if phase % self.r_out[l] == 0 {
                if !in_part(l) {
                    d = None;
                } else {
                    let inp: Vec<f32> = if l == depth {
                        enc_out[l]
                            .clone()
                            .with_context(|| format!("{}: dec{l} missing input", self.name))?
                    } else {
                        let mut upper = d.take();
                        if part == Part::Rest && delayed(l + 1) && !self.is_scc[l + 1] {
                            // Boundary: the delayed d_{l+1} was produced by
                            // the pre pass and parked in the handoff slot.
                            upper = Some(column(
                                &states.tensors[self.idx.fp_handoff.unwrap()],
                                0,
                            ));
                        }
                        let mut v = upper
                            .with_context(|| format!("{}: dec{l} missing deep input", self.name))?;
                        let skip = enc_out[l]
                            .as_ref()
                            .with_context(|| format!("{}: dec{l} missing skip", self.name))?;
                        v.extend_from_slice(skip);
                        v
                    };
                    let win = push_window(&mut states.tensors[self.idx.dec_win[l - 1]], &inp);
                    let mut y = self.conv_win(
                        &w.tensors[self.idx.dec_w[l - 1]],
                        &w.tensors[self.idx.dec_b[l - 1]],
                        &win,
                    );
                    elu(&mut y);
                    d = Some(y);
                    computed_here = true;
                }
            }
            // Extrapolation back to the r_in(l) domain.  The *write*
            // belongs to whichever pass computed the fresh d_l; the *read*
            // to the pass computing d_{l-1} (or the head for l == 1).
            if self.is_scc[l] && phase % self.r_in[l] == 0 {
                let cache_slot = self.idx.up_cache[&l];
                let fresh = phase % self.r_out[l] == 0;
                if fresh && computed_here {
                    let dv = d.as_ref().unwrap();
                    if self.tconv[l] {
                        let ph0 = self.tconv_phase(
                            &w.tensors[self.idx.up_w[&l]],
                            &w.tensors[self.idx.up_b[&l]],
                            0,
                            dv,
                        );
                        let ph1 = self.tconv_phase(
                            &w.tensors[self.idx.up_w[&l]],
                            &w.tensors[self.idx.up_b[&l]],
                            1,
                            dv,
                        );
                        let cache = &mut states.tensors[cache_slot];
                        set_column(cache, 0, &ph0);
                        set_column(cache, 1, &ph1);
                    } else {
                        set_column(&mut states.tensors[cache_slot], 0, dv);
                    }
                }
                let reader_delayed = (l >= 2 && delayed(l - 1)) || (l == 1 && s == Some(1));
                let reads_here = part == Part::All
                    || (reader_delayed && part == Part::Pre)
                    || (!reader_delayed && part == Part::Rest);
                d = if reads_here {
                    let cache = &states.tensors[cache_slot];
                    let col = if self.tconv[l] && !fresh { 1 } else { 0 };
                    Some(column(cache, col))
                } else {
                    None
                };
            }
            // FP boundary handoff (pre pass writes; rest pass reads above).
            if part == Part::Pre
                && s == Some(l)
                && !self.is_scc[l]
                && phase % self.r_out[l] == 0
                && l != 1
            {
                if let Some(dv) = &d {
                    set_column(&mut states.tensors[self.idx.fp_handoff.unwrap()], 0, dv);
                }
            }
        }

        // ---- head ----
        let head_w = &w.tensors[self.idx.head_w];
        let head_b = &w.tensors[self.idx.head_b];
        match part {
            Part::Pre => {
                if s == Some(1) {
                    // Whole network delayed: the head output is the handoff.
                    let dv = d
                        .with_context(|| format!("{}: pre pass lost the head input", self.name))?;
                    let out = self.conv_win(head_w, head_b, &dv);
                    set_column(&mut states.tensors[self.idx.fp_handoff.unwrap()], 0, &out);
                }
                Ok(None)
            }
            Part::Rest if s == Some(1) => Ok(Some(column(
                &states.tensors[self.idx.fp_handoff.unwrap()],
                0,
            ))),
            _ => {
                let dv = d
                    .with_context(|| format!("{}: no decoder output at phase {phase}", self.name))?;
                Ok(Some(self.conv_win(head_w, head_b, &dv)))
            }
        }
    }

    // ---- offline (full-sequence) interpreter ------------------------------

    fn offline_forward(&self, x: &Tensor, w: &Weights) -> Result<Tensor> {
        let cfg = &self.cfg;
        if x.shape.len() != 2 || x.shape[0] != cfg.feat {
            bail!(
                "{}: offline input shape {:?}, expected [{}, T]",
                self.name,
                x.shape,
                cfg.feat
            );
        }
        if x.shape[1] == 0 || x.shape[1] % self.period != 0 {
            bail!(
                "{}: offline T = {} must be a positive multiple of the period {}",
                self.name,
                x.shape[1],
                self.period
            );
        }
        let depth = self.depth;
        let mut enc: Vec<Tensor> = Vec::with_capacity(depth + 1);
        enc.push(x.clone());
        let mut cur = x.clone();
        for l in 1..=depth {
            if cfg.shift_pos == Some(l) {
                cur = delay_cols(&cur, cfg.shift);
            }
            let mut y = self.conv_full(
                &cur,
                &w.tensors[self.idx.enc_w[l - 1]],
                &w.tensors[self.idx.enc_b[l - 1]],
            );
            if self.is_scc[l] {
                y = stride2(&y);
            }
            elu(&mut y.data);
            cur = y.clone();
            enc.push(y);
        }

        let mut d: Option<Tensor> = None;
        for l in (1..=depth).rev() {
            let inp = if l == depth {
                enc[depth].clone()
            } else {
                concat_rows(d.as_ref().unwrap(), &enc[l])
            };
            let mut y = self.conv_full(
                &inp,
                &w.tensors[self.idx.dec_w[l - 1]],
                &w.tensors[self.idx.dec_b[l - 1]],
            );
            elu(&mut y.data);
            let mut dl = y;
            if self.is_scc[l] {
                let t_out = enc[l - 1].shape[1];
                dl = if let Some(kind) = &cfg.interp {
                    interp_upsample(&dl, t_out, kind)
                        .with_context(|| format!("{}: up{l}", self.name))?
                } else if self.tconv[l] {
                    self.tconv_upsample(
                        &dl,
                        &w.tensors[self.idx.up_w[&l]],
                        &w.tensors[self.idx.up_b[&l]],
                        t_out,
                    )
                } else {
                    duplicate_upsample(&dl, t_out)
                };
            }
            d = Some(dl);
        }
        Ok(self.conv_full(
            &d.unwrap(),
            &w.tensors[self.idx.head_w],
            &w.tensors[self.idx.head_b],
        ))
    }

    /// Stride-2 transposed conv over a whole sequence: phase 0 lands on
    /// even output times, phase 1 on odd ones.
    fn tconv_upsample(&self, y: &Tensor, w: &Tensor, b: &Tensor, t_out: usize) -> Tensor {
        let c_out = w.shape[0];
        let s = y.shape[1];
        let mut out = Tensor::zeros(vec![c_out, t_out]);
        for src in 0..s {
            let col = column(y, src);
            let ph0 = self.tconv_phase(w, b, 0, &col);
            let ph1 = self.tconv_phase(w, b, 1, &col);
            if 2 * src < t_out {
                set_column(&mut out, 2 * src, &ph0);
            }
            if 2 * src + 1 < t_out {
                set_column(&mut out, 2 * src + 1, &ph1);
            }
        }
        out
    }
}

impl VariantExec for NativeVariant {
    fn init_states(&self) -> StateSet {
        StateSet {
            tensors: self
                .specs
                .iter()
                .map(|s| Tensor::zeros(s.shape.clone()))
                .collect(),
        }
    }

    fn has_fp_split(&self) -> bool {
        // An FP shift at layer 1 that is *also* an S-CC position has no
        // handoff slot (the head boundary value has nowhere to park) —
        // the reference model cannot split that configuration either;
        // the paper's SS-CC table starts at position 2.
        match self.cfg.shift_pos {
            Some(1) => !self.cfg.scc.contains(&1),
            Some(_) => true,
            None => false,
        }
    }

    fn step(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        weights: &DeviceWeights,
    ) -> Result<Vec<f32>> {
        let out = self.run_step(phase, Some(frame), states, weights, Part::All)?;
        out.with_context(|| format!("{}: step produced no output", self.name))
    }

    fn precompute(
        &self,
        phase: usize,
        states: &mut StateSet,
        weights: &DeviceWeights,
    ) -> Result<()> {
        if !self.has_fp_split() {
            bail!("{}: variant has no FP split", self.name);
        }
        self.run_step(phase, None, states, weights, Part::Pre)?;
        Ok(())
    }

    fn step_rest(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        weights: &DeviceWeights,
    ) -> Result<Vec<f32>> {
        if !self.has_fp_split() {
            bail!("{}: variant has no FP split", self.name);
        }
        let out = self.run_step(phase, Some(frame), states, weights, Part::Rest)?;
        out.with_context(|| format!("{}: rest pass produced no output", self.name))
    }

    fn offline(&self, x: &Tensor, weights: &DeviceWeights) -> Result<Tensor> {
        let w = self.host(weights)?;
        self.offline_forward(x, w)
    }

    fn executed_macs(&self) -> Option<u64> {
        Some(self.macs.load(Ordering::Relaxed))
    }

    fn reset_executed_macs(&self) {
        self.macs.store(0, Ordering::Relaxed);
    }
}

// ---- column/window primitives (row-major (C, W) tensors) ------------------

/// ELU activation in place.
fn elu(v: &mut [f32]) {
    for x in v.iter_mut() {
        if *x < 0.0 {
            *x = x.exp_m1();
        }
    }
}

/// Extract column `j` of a (C, W) tensor.
fn column(t: &Tensor, j: usize) -> Vec<f32> {
    let w = t.shape[1];
    (0..t.shape[0]).map(|i| t.data[i * w + j]).collect()
}

/// Overwrite column `j` of a (C, W) tensor.
fn set_column(t: &mut Tensor, j: usize, v: &[f32]) {
    let w = t.shape[1];
    for (i, &x) in v.iter().enumerate() {
        t.data[i * w + j] = x;
    }
}

/// STMC window tick: returns the full (C, K) window `[state | cur]`
/// flattened row-major and advances the state to `window[:, 1:]`.
fn push_window(state: &mut Tensor, cur: &[f32]) -> Vec<f32> {
    let c = state.shape[0];
    let w = state.shape[1]; // K - 1
    let k = w + 1;
    let mut win = vec![0.0f32; c * k];
    for i in 0..c {
        win[i * k..i * k + w].copy_from_slice(&state.data[i * w..(i + 1) * w]);
        win[i * k + w] = cur[i];
    }
    for i in 0..c {
        state.data[i * w..(i + 1) * w].copy_from_slice(&win[i * k + 1..(i + 1) * k]);
    }
    win
}

/// FIFO tick: drop the oldest column, append `cur`.
fn push_fifo(state: &mut Tensor, cur: &[f32]) {
    let w = state.shape[1];
    for i in 0..state.shape[0] {
        let row = &mut state.data[i * w..(i + 1) * w];
        row.copy_within(1.., 0);
        row[w - 1] = cur[i];
    }
}

// ---- offline sequence primitives ------------------------------------------

/// Right-shift along time by `d` frames (zeros in front), same length.
fn delay_cols(x: &Tensor, d: usize) -> Tensor {
    let (c, t) = (x.shape[0], x.shape[1]);
    let mut out = Tensor::zeros(vec![c, t]);
    for i in 0..c {
        for tt in d..t {
            out.set2(i, tt, x.at2(i, tt - d));
        }
    }
    out
}

/// Keep even time steps: `out[:, s] = x[:, 2 s]`.
fn stride2(x: &Tensor) -> Tensor {
    let (c, t) = (x.shape[0], x.shape[1]);
    let t2 = (t + 1) / 2;
    let mut out = Tensor::zeros(vec![c, t2]);
    for i in 0..c {
        for s in 0..t2 {
            out.set2(i, s, x.at2(i, 2 * s));
        }
    }
    out
}

/// Stack `a` over `b` along the channel axis.
fn concat_rows(a: &Tensor, b: &Tensor) -> Tensor {
    debug_assert_eq!(a.shape[1], b.shape[1]);
    let t = a.shape[1];
    let c = a.shape[0] + b.shape[0];
    let mut data = Vec::with_capacity(c * t);
    data.extend_from_slice(&a.data);
    data.extend_from_slice(&b.data);
    Tensor::new(vec![c, t], data)
}

/// Duplication extrapolation (PP alignment): `up[:, t] = y[:, t / 2]`.
fn duplicate_upsample(y: &Tensor, t_out: usize) -> Tensor {
    let c = y.shape[0];
    let last = y.shape[1] - 1;
    let mut out = Tensor::zeros(vec![c, t_out]);
    for i in 0..c {
        for tt in 0..t_out {
            out.set2(i, tt, y.at2(i, (tt / 2).min(last)));
        }
    }
    out
}

/// Interpolation reconstruction (App. D, offline-only).
fn interp_upsample(y: &Tensor, t_out: usize, kind: &str) -> Result<Tensor> {
    let c = y.shape[0];
    let last = y.shape[1] as isize - 1;
    let tap = |i: usize, j: isize| y.at2(i, j.clamp(0, last) as usize);
    let mut out = Tensor::zeros(vec![c, t_out]);
    for tt in 0..t_out {
        let s0 = (tt / 2) as isize;
        let odd = tt % 2 == 1;
        let frac: f32 = if odd { 0.5 } else { 0.0 };
        for i in 0..c {
            let v = match kind {
                "nearest" => tap(i, s0 + if odd { 1 } else { 0 }),
                "linear" => tap(i, s0) * (1.0 - frac) + tap(i, s0 + 1) * frac,
                "cubic" => {
                    // Catmull-Rom with u = frac
                    let (p0, p1, p2, p3) =
                        (tap(i, s0 - 1), tap(i, s0), tap(i, s0 + 1), tap(i, s0 + 2));
                    let u = frac;
                    0.5 * ((2.0 * p1)
                        + (-p0 + p2) * u
                        + (2.0 * p0 - 5.0 * p1 + 4.0 * p2 - p3) * u * u
                        + (-p0 + 3.0 * p1 - 3.0 * p2 + p3) * u * u * u)
                }
                other => bail!("unknown interpolation kind '{other}'"),
            };
            out.set2(i, tt, v);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_specs_mirror_python_inventory() {
        let cfg = ModelConfig {
            feat: 4,
            channels: vec![6, 8],
            kernel: 3,
            scc: vec![2],
            shift_pos: Some(2),
            shift: 1,
            extrap: vec!["duplicate".into()],
            interp: None,
        };
        let specs = state_specs(&cfg);
        let names: Vec<&str> = specs.iter().map(|s| s.name.as_str()).collect();
        // s == p (SS-CC): no fp.handoff slot.
        assert_eq!(
            names,
            ["enc1.win", "enc2.win", "dec2.win", "dec1.win", "up2.cache", "shift.fifo"]
        );
        assert_eq!(specs[0].shape, vec![4, 2]); // enc1: feat x (k-1)
        assert_eq!(specs[2].shape, vec![8, 2]); // dec2 in = channels[1]
        assert_eq!(specs[3].shape, vec![6 + 6, 2]); // dec1 in = dec_out(2)+ch[0]
        assert_eq!(specs[4].shape, vec![6, 1]); // up2 cache = dec_out(2)
        assert_eq!(specs[5].shape, vec![6, 1]); // fifo at enc2 input
    }

    #[test]
    fn hybrid_fp_gets_handoff_slot() {
        let cfg = ModelConfig {
            feat: 4,
            channels: vec![5, 6, 7],
            kernel: 3,
            scc: vec![3],
            shift_pos: Some(2),
            shift: 1,
            extrap: vec!["duplicate".into()],
            interp: None,
        };
        let names: Vec<String> = state_specs(&cfg).iter().map(|s| s.name.clone()).collect();
        assert!(names.contains(&"fp.handoff".to_string()));
        assert!(names.contains(&"shift.fifo".to_string()));
    }

    #[test]
    fn push_window_shifts_by_one() {
        let mut st = Tensor::new(vec![2, 2], vec![1.0, 2.0, 10.0, 20.0]);
        let win = push_window(&mut st, &[3.0, 30.0]);
        assert_eq!(win, vec![1.0, 2.0, 3.0, 10.0, 20.0, 30.0]);
        assert_eq!(st.data, vec![2.0, 3.0, 20.0, 30.0]);
    }

    #[test]
    fn fifo_drops_oldest() {
        let mut st = Tensor::new(vec![1, 3], vec![1.0, 2.0, 3.0]);
        push_fifo(&mut st, &[4.0]);
        assert_eq!(st.data, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn duplicate_upsample_repeats_frames() {
        let y = Tensor::new(vec![1, 2], vec![5.0, 7.0]);
        let up = duplicate_upsample(&y, 4);
        assert_eq!(up.data, vec![5.0, 5.0, 7.0, 7.0]);
    }
}
