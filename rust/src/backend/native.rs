//! Native pure-Rust SOI backend: interprets a variant [`Manifest`]
//! directly — no ML runtime, no codegen, no external dependencies.
//!
//! This is the executable form of `python/compile/model.py`'s streaming
//! semantics (the paper's eq. 3–7), cross-checked in
//! `tests/native_backend.rs`:
//!
//! * Encoder layer `l` *ticks* (pushes its STMC conv window) when
//!   `phase % r_in(l) == 0`; an S-CC layer `p` additionally *fires*
//!   (computes) only when `phase % (2·r_in(p)) == 0` — the paper's eq. 4
//!   odd-inference branch just updates state.
//! * Decoder layer `l` computes when `phase % r_out(l) == 0`; S-CC
//!   positions extrapolate their activation back to the `r_in` domain
//!   through a one-frame cache (duplication) or a two-phase learned
//!   transposed conv (`tconv`).
//! * An FP shift at encoder `s` reads a delay-line FIFO, making layers
//!   `s..=depth` (and the mirrored decoder region) depend on past data
//!   only; [`VariantExec::precompute`] runs exactly that region before
//!   the frame arrives and parks the boundary value in a handoff slot
//!   for [`VariantExec::step_rest`].
//!
//! Every multiply-accumulate is counted ([`VariantExec::executed_macs`])
//! so the scheduler's analytic per-phase accounting
//! (`coordinator::stream::macs_at_phase`) can be verified against what
//! actually ran.
//!
//! Execution runs on the SIMD microkernel substrate (DESIGN.md §11):
//! conv weights are repacked once at upload time into cache-blocked
//! [`crate::kernels::PackedF32`] panels (inside [`HostWeights`]), every
//! conv — streaming, FP pre/rest, *and* offline — is one
//! [`crate::kernels::gemm_f32`] call with a fused bias + ELU epilogue,
//! and all intermediates come from the variant's recycled
//! [`crate::kernels::StepArena`], so the steady state allocates nothing
//! (`rust/tests/hot_path_alloc.rs`).  The per-phase schedule decisions
//! (which layers tick/fire/compute) are precompiled into `PhasePlan`
//! tables at variant-compile time, so the hot loop does no modular
//! arithmetic.
//!
//! Streaming execution is *batched* (DESIGN.md §8): the interpreter has a
//! single code path (`NativeVariant::exec_step`), which runs a
//! phase-aligned group of B streams by stacking their activations into
//! (C, B) matrices and executing each conv as one panel GEMM over the
//! batch.  The single-stream entry points are the B == 1 case of the
//! same path, and the kernels' per-stream accumulation order is
//! batch-size-independent, so batched and sequential serving are
//! bit-identical — `tests/batch_equivalence.rs` asserts it.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use super::{
    build_phase_plans, DeviceWeights, HostWeights, InferenceBackend, OutSink, PhasePlan,
    VariantExec,
};
use crate::kernels::{
    gemm_f32, next_arena_id, offline_put, offline_take, with_arena, ArenaSpec, PackedF32,
    StepArena,
};
use crate::runtime::engine::{StateSet, Weights};
use crate::runtime::manifest::{Manifest, ModelConfig, TensorSpec};
use crate::util::tensor::Tensor;

/// The dependency-free pure-Rust backend (the default).
pub struct NativeBackend;

impl InferenceBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    /// Compile a manifest into its native executable.  The manifest's
    /// `dtype` selects the registry entry: f32 manifests get the float
    /// interpreter below, int8 manifests get the quantized executable
    /// ([`crate::quant::QuantVariant`], DESIGN.md §10).  Both implement
    /// [`VariantExec`] and execute from the same host weight upload, so
    /// one backend serves mixed-precision ladders.
    fn compile_variant(&self, manifest: &Manifest) -> Result<Box<dyn VariantExec>> {
        match manifest.dtype {
            crate::runtime::manifest::Dtype::F32 => Ok(Box::new(NativeVariant::new(manifest)?)),
            crate::runtime::manifest::Dtype::Int8 => {
                Ok(Box::new(crate::quant::QuantVariant::new(manifest)?))
            }
        }
    }

    /// Wrap host weights for execution, packing every conv kernel into
    /// its cache-blocked panels exactly once; the returned handle is
    /// `Arc`-shared, so variants, streams and workers never duplicate
    /// the tensor set.
    fn upload_weights(&self, weights: &Weights) -> Result<DeviceWeights> {
        Ok(DeviceWeights::host(weights.clone()))
    }
}

/// Per-stream partial-state inventory of a config, in canonical order
/// (mirrors `python/compile/model.py::state_specs`).
pub fn state_specs(cfg: &ModelConfig) -> Vec<TensorSpec> {
    let k = cfg.kernel;
    let mut specs = Vec::new();
    for l in 1..=cfg.depth() {
        specs.push(TensorSpec {
            name: format!("enc{l}.win"),
            shape: vec![cfg.enc_in_ch(l), k - 1],
        });
    }
    for l in (1..=cfg.depth()).rev() {
        specs.push(TensorSpec {
            name: format!("dec{l}.win"),
            shape: vec![cfg.dec_in_ch(l), k - 1],
        });
    }
    for &p in &cfg.scc {
        let width = if cfg.extrap_of(p) == "tconv" { 2 } else { 1 };
        specs.push(TensorSpec {
            name: format!("up{p}.cache"),
            shape: vec![cfg.dec_out_ch(p), width],
        });
    }
    if let Some(s) = cfg.shift_pos {
        specs.push(TensorSpec {
            name: "shift.fifo".into(),
            shape: vec![cfg.enc_in_ch(s), cfg.shift],
        });
        if !cfg.scc.contains(&s) {
            let ho = if s == 1 { cfg.feat } else { cfg.dec_out_ch(s) };
            specs.push(TensorSpec {
                name: "fp.handoff".into(),
                shape: vec![ho, 1],
            });
        }
    }
    specs
}

/// Pre-resolved tensor indices (state slots and manifest parameters).
struct Indices {
    enc_win: Vec<usize>, // state slot of enc{l}.win, indexed l-1
    dec_win: Vec<usize>, // state slot of dec{l}.win, indexed l-1
    enc_w: Vec<usize>,   // param slots, indexed l-1
    enc_b: Vec<usize>,
    dec_w: Vec<usize>,
    dec_b: Vec<usize>,
    up_cache: BTreeMap<usize, usize>, // scc position -> state slot
    up_w: BTreeMap<usize, usize>,     // scc position -> param slot (tconv)
    up_b: BTreeMap<usize, usize>,
    shift_fifo: Option<usize>,
    fp_handoff: Option<usize>,
    head_w: usize,
    head_b: usize,
    n_params: usize,
}

/// Which part of an inference to run (the FP split).
#[derive(Clone, Copy, PartialEq, Eq)]
enum Part {
    All,
    Pre,
    Rest,
}

/// Per-layer channel dimensions, resolved once at compile time so the
/// hot loop never walks the config's channel list.
struct LayerDims {
    enc_ci: usize,
    enc_co: usize,
    dec_ci: usize,
    dec_co: usize,
}

/// One variant compiled for the native backend.
pub struct NativeVariant {
    cfg: ModelConfig,
    name: String,
    period: usize,
    depth: usize,
    r_in: Vec<usize>,  // 1-based, [0] unused
    r_out: Vec<usize>, // 1-based, [0] unused
    is_scc: Vec<bool>, // 1-based, [0] unused
    tconv: Vec<bool>,  // 1-based: extrapolation at l is a learned tconv
    specs: Vec<TensorSpec>,
    idx: Indices,
    dims: Vec<LayerDims>,  // indexed l-1
    plans: Vec<PhasePlan>, // indexed by phase
    arena_id: u64,
    arena_spec: ArenaSpec,
    macs: AtomicU64,
}

impl NativeVariant {
    /// Compile (validate + index + plan) one manifest for native
    /// execution.
    pub fn new(manifest: &Manifest) -> Result<NativeVariant> {
        let cfg = manifest.config.clone();
        let depth = cfg.depth();
        let name = manifest.name.clone();
        if depth == 0 {
            bail!("{name}: config has no layers");
        }
        if cfg.kernel == 0 {
            bail!("{name}: kernel must be >= 1");
        }
        if cfg.scc.windows(2).any(|w| w[0] >= w[1]) {
            bail!("{name}: scc positions must be sorted and unique");
        }
        if cfg.scc.iter().any(|&p| p == 0 || p > depth) {
            bail!("{name}: scc position out of range 1..={depth}");
        }
        if let Some(s) = cfg.shift_pos {
            if s == 0 || s > depth {
                bail!("{name}: shift_pos out of range 1..={depth}");
            }
            if cfg.shift == 0 {
                bail!("{name}: shift must be >= 1");
            }
        }
        if manifest.period != cfg.period() {
            bail!(
                "{name}: manifest period {} != 2^|scc| = {}",
                manifest.period,
                cfg.period()
            );
        }
        for &p in &cfg.scc {
            let e = cfg.extrap_of(p);
            if e != "duplicate" && e != "tconv" {
                bail!("{name}: unknown extrapolation '{e}' at S-CC {p}");
            }
        }

        let mut r_in = vec![1usize; depth + 1];
        let mut r_out = vec![1usize; depth + 1];
        let mut is_scc = vec![false; depth + 1];
        let mut tconv = vec![false; depth + 1];
        for l in 1..=depth {
            r_in[l] = cfg.r_in(l);
            r_out[l] = cfg.r_out(l);
            is_scc[l] = cfg.scc.contains(&l);
            tconv[l] = is_scc[l] && cfg.extrap_of(l) == "tconv";
        }

        let specs = state_specs(&cfg);
        let state_slot: BTreeMap<&str, usize> = specs
            .iter()
            .enumerate()
            .map(|(i, s)| (s.name.as_str(), i))
            .collect();
        let sslot = |n: &str| -> Result<usize> {
            state_slot
                .get(n)
                .copied()
                .with_context(|| format!("{name}: missing state slot {n}"))
        };

        let param_slot: BTreeMap<&str, usize> = manifest
            .params
            .iter()
            .enumerate()
            .map(|(i, s)| (s.name.as_str(), i))
            .collect();
        let pslot = |n: &str, shape: &[usize]| -> Result<usize> {
            let i = *param_slot
                .get(n)
                .with_context(|| format!("{name}: manifest lacks parameter {n}"))?;
            if manifest.params[i].shape != shape {
                bail!(
                    "{name}: parameter {n} has shape {:?}, native backend expects {:?}",
                    manifest.params[i].shape,
                    shape
                );
            }
            Ok(i)
        };

        let k = cfg.kernel;
        let mut enc_win = Vec::new();
        let mut dec_win = Vec::new();
        let mut enc_w = Vec::new();
        let mut enc_b = Vec::new();
        let mut dec_w = Vec::new();
        let mut dec_b = Vec::new();
        for l in 1..=depth {
            enc_win.push(sslot(&format!("enc{l}.win"))?);
            dec_win.push(sslot(&format!("dec{l}.win"))?);
            enc_w.push(pslot(
                &format!("enc{l}.w"),
                &[cfg.enc_out_ch(l), cfg.enc_in_ch(l), k],
            )?);
            enc_b.push(pslot(&format!("enc{l}.b"), &[cfg.enc_out_ch(l)])?);
            dec_w.push(pslot(
                &format!("dec{l}.w"),
                &[cfg.dec_out_ch(l), cfg.dec_in_ch(l), k],
            )?);
            dec_b.push(pslot(&format!("dec{l}.b"), &[cfg.dec_out_ch(l)])?);
        }
        let mut up_cache = BTreeMap::new();
        let mut up_w = BTreeMap::new();
        let mut up_b = BTreeMap::new();
        for &p in &cfg.scc {
            up_cache.insert(p, sslot(&format!("up{p}.cache"))?);
            if tconv[p] {
                let c = cfg.dec_out_ch(p);
                up_w.insert(p, pslot(&format!("up{p}.w"), &[c, c, 2])?);
                up_b.insert(p, pslot(&format!("up{p}.b"), &[c])?);
            }
        }
        let shift_fifo = if cfg.shift_pos.is_some() {
            Some(sslot("shift.fifo")?)
        } else {
            None
        };
        let fp_handoff = match cfg.shift_pos {
            Some(s) if !cfg.scc.contains(&s) => Some(sslot("fp.handoff")?),
            _ => None,
        };
        let head_w = pslot("head.w", &[cfg.feat, cfg.dec_out_ch(1), 1])?;
        let head_b = pslot("head.b", &[cfg.feat])?;

        // ---- precompiled per-layer dims, phase plans, arena spec ----
        let mut dims = Vec::with_capacity(depth);
        let mut sizes = vec![cfg.feat];
        for l in 1..=depth {
            let d = LayerDims {
                enc_ci: cfg.enc_in_ch(l),
                enc_co: cfg.enc_out_ch(l),
                dec_ci: cfg.dec_in_ch(l),
                dec_co: cfg.dec_out_ch(l),
            };
            sizes.extend([d.enc_ci, d.enc_ci * k, d.enc_co, d.dec_ci, d.dec_ci * k, d.dec_co]);
            dims.push(d);
        }
        let period = cfg.period();
        let plans = build_phase_plans(&cfg);

        Ok(NativeVariant {
            period,
            idx: Indices {
                enc_win,
                dec_win,
                enc_w,
                enc_b,
                dec_w,
                dec_b,
                up_cache,
                up_w,
                up_b,
                shift_fifo,
                fp_handoff,
                head_w,
                head_b,
                n_params: manifest.params.len(),
            },
            cfg,
            name,
            depth,
            r_in,
            r_out,
            is_scc,
            tconv,
            specs,
            dims,
            plans,
            arena_id: next_arena_id(),
            arena_spec: ArenaSpec::new(sizes, Vec::new()),
            macs: AtomicU64::new(0),
        })
    }

    /// Resolve host weights (tensors + panels) from the backend-tagged
    /// handle.
    fn host<'a>(&self, dw: &'a DeviceWeights) -> Result<&'a HostWeights> {
        match dw {
            DeviceWeights::Host(hw) => {
                if hw.tensors().len() != self.idx.n_params {
                    bail!(
                        "{}: weights hold {} tensors, manifest wants {}",
                        self.name,
                        hw.tensors().len(),
                        self.idx.n_params
                    );
                }
                Ok(hw)
            }
            #[cfg(feature = "pjrt")]
            DeviceWeights::Pjrt(_) => {
                bail!("{}: pjrt device weights passed to the native backend", self.name)
            }
        }
    }

    /// The packed GEMM panel of conv parameter `i`.
    fn panel<'a>(&self, hw: &'a HostWeights, i: usize) -> Result<&'a PackedF32> {
        hw.panel(i)
            .with_context(|| format!("{}: parameter {i} carries no packed panel", self.name))
    }

    /// The output-phase `ph` panel of 2-tap (transposed-conv) parameter
    /// `i`.
    fn phase_panel<'a>(&self, hw: &'a HostWeights, i: usize, ph: usize) -> Result<&'a PackedF32> {
        hw.phase_panel(i, ph)
            .with_context(|| format!("{}: parameter {i} carries no phase panels", self.name))
    }

    // ---- streaming step (batched; B == 1 is the single-stream case) -------

    /// Validate a step request, then execute it inside this variant's
    /// per-thread [`StepArena`].  Returns whether an output was written
    /// to the sink.
    fn run_step_batch(
        &self,
        phase: usize,
        frames: Option<&[&[f32]]>,
        states: &mut [&mut StateSet],
        dw: &DeviceWeights,
        part: Part,
        sink: &mut OutSink,
    ) -> Result<bool> {
        let bsz = states.len();
        if self.cfg.interp.is_some() {
            bail!(
                "{}: interpolation variants are offline-only (App. D adds a \
                 frame of latency online); use offline()",
                self.name
            );
        }
        for st in states.iter() {
            if st.tensors.len() != self.specs.len() {
                bail!(
                    "{}: state set holds {} tensors, expected {}",
                    self.name,
                    st.tensors.len(),
                    self.specs.len()
                );
            }
        }
        if let Some(fr) = frames {
            if fr.len() != bsz {
                bail!("{}: {} frames for {} state sets", self.name, fr.len(), bsz);
            }
            for f in fr.iter() {
                if f.len() != self.cfg.feat {
                    bail!(
                        "{}: frame has {} samples, expected {}",
                        self.name,
                        f.len(),
                        self.cfg.feat
                    );
                }
            }
        }
        if bsz == 0 {
            if let OutSink::Batch(outs) = sink {
                outs.clear();
            }
            return Ok(true);
        }
        let hw = self.host(dw)?;
        with_arena(self.arena_id, &self.arena_spec, |arena| {
            self.exec_step(phase % self.period, frames, states, hw, part, arena, sink)
        })
    }

    /// One inference (or one FP part of it) at schedule position `phase`
    /// for a phase-aligned batch of `states.len()` streams.
    ///
    /// This is the *only* streaming code path: [`VariantExec::step`],
    /// [`VariantExec::precompute`] and [`VariantExec::step_rest`] all run
    /// it with B == 1, so the batched and sequential paths cannot diverge
    /// in schedule logic — only the kernels see the batch, and those
    /// preserve per-stream accumulation order bit-for-bit.
    ///
    /// Every batch-wide activation is a (C, B) matrix flattened row-major
    /// (`buf[c * B + s]` = channel `c` of stream `s`), so the GEMM inner
    /// loop runs contiguously across the batch.  All intermediates come
    /// from the variant's [`StepArena`]: after warm-up the serving steady
    /// state allocates nothing at all (`tests/hot_path_alloc.rs`).
    #[allow(clippy::too_many_arguments)]
    fn exec_step(
        &self,
        phase: usize,
        frames: Option<&[&[f32]]>,
        states: &mut [&mut StateSet],
        hw: &HostWeights,
        part: Part,
        arena: &mut StepArena,
        sink: &mut OutSink,
    ) -> Result<bool> {
        let bsz = states.len();
        let pp = &self.plans[phase];
        let depth = self.depth;
        let k = self.cfg.kernel;
        let s = self.cfg.shift_pos;
        let delayed = |l: usize| s.map_or(false, |sp| l >= sp);
        let in_part = |l: usize| match part {
            Part::All => true,
            Part::Pre => delayed(l),
            Part::Rest => !delayed(l),
        };

        // ---- encoder ----
        let mut enc_out = arena.take_opts_f32(depth + 1);
        let mut cur: Option<Vec<f32>> = match part {
            Part::Pre => None,
            _ => {
                let fr = frames.with_context(|| format!("{}: step needs frames", self.name))?;
                let mut x0 = arena.take_f32(self.cfg.feat, bsz);
                for (si, f) in fr.iter().enumerate() {
                    for (i, &v) in f.iter().enumerate() {
                        x0[i * bsz + si] = v;
                    }
                }
                Some(x0)
            }
        };
        for l in 1..=depth {
            let ld = &self.dims[l - 1];
            if !pp.enc_tick[l - 1] {
                arena.release_f32(&mut cur);
                continue;
            }
            // FP delay line at the input of layer s: read the oldest entry
            // before pushing (the pre pass reads, the rest pass pushes).
            if s == Some(l) {
                let fifo_slot = self.idx.shift_fifo.unwrap();
                let mut delayed_in = arena.take_f32(ld.enc_ci, bsz);
                if part != Part::Pre {
                    let c = cur
                        .as_ref()
                        .with_context(|| format!("{}: enc{l} missing input", self.name))?;
                    for (si, st) in states.iter_mut().enumerate() {
                        let fifo = &mut st.tensors[fifo_slot];
                        gather_state_col(fifo, 0, bsz, si, &mut delayed_in);
                        push_fifo_col(fifo, c, bsz, si);
                    }
                } else {
                    for (si, st) in states.iter().enumerate() {
                        gather_state_col(&st.tensors[fifo_slot], 0, bsz, si, &mut delayed_in);
                    }
                }
                arena.release_f32(&mut cur);
                if in_part(l) {
                    cur = Some(delayed_in);
                } else {
                    arena.put_f32(delayed_in);
                }
            }
            if !in_part(l) {
                arena.release_f32(&mut cur);
                continue;
            }
            let c = cur
                .take()
                .with_context(|| format!("{}: enc{l} has no input at phase {phase}", self.name))?;
            let mut xwin = arena.take_f32(ld.enc_ci * k, bsz);
            for (si, st) in states.iter_mut().enumerate() {
                push_window_col(&mut st.tensors[self.idx.enc_win[l - 1]], &c, bsz, si, &mut xwin);
            }
            arena.put_f32(c);
            cur = if pp.enc_fire[l - 1] {
                let panel = self.panel(hw, self.idx.enc_w[l - 1])?;
                let bias = &hw.tensors()[self.idx.enc_b[l - 1]].data;
                let mut y = arena.take_f32(ld.enc_co, bsz);
                gemm_f32(panel, bias, &xwin, bsz, &mut y, true);
                self.macs
                    .fetch_add((ld.enc_co * ld.enc_ci * k * bsz) as u64, Ordering::Relaxed);
                // keep a copy for the decoder's skip connection
                let mut keep = arena.take_f32(ld.enc_co, bsz);
                keep.copy_from_slice(&y);
                enc_out[l] = Some(keep);
                Some(y)
            } else {
                None
            };
            arena.put_f32(xwin);
        }
        arena.release_f32(&mut cur);

        // ---- decoder ----
        let mut d: Option<Vec<f32>> = None;
        for l in (1..=depth).rev() {
            let ld = &self.dims[l - 1];
            let mut computed_here = false;
            if pp.dec_run[l - 1] {
                if !in_part(l) {
                    arena.release_f32(&mut d);
                } else {
                    let inp: Vec<f32> = if l == depth {
                        let src = enc_out[l]
                            .as_ref()
                            .with_context(|| format!("{}: dec{l} missing input", self.name))?;
                        let mut v = arena.take_f32(ld.enc_co, bsz);
                        v.copy_from_slice(src);
                        v
                    } else {
                        let mut upper = d.take();
                        if part == Part::Rest && delayed(l + 1) && !self.is_scc[l + 1] {
                            // Boundary: the delayed d_{l+1} was produced by
                            // the pre pass and parked in the handoff slot.
                            arena.release_f32(&mut upper);
                            let slot = self.idx.fp_handoff.unwrap();
                            let c_h = states[0].tensors[slot].shape[0];
                            let mut h = arena.take_f32(c_h, bsz);
                            for (si, st) in states.iter().enumerate() {
                                gather_state_col(&st.tensors[slot], 0, bsz, si, &mut h);
                            }
                            upper = Some(h);
                        }
                        let v = upper
                            .with_context(|| format!("{}: dec{l} missing deep input", self.name))?;
                        let skip = enc_out[l]
                            .as_ref()
                            .with_context(|| format!("{}: dec{l} missing skip", self.name))?;
                        // stack deep rows over skip rows (channel concat)
                        let mut inp = arena.take_f32(ld.dec_ci, bsz);
                        inp[..v.len()].copy_from_slice(&v);
                        inp[v.len()..].copy_from_slice(skip);
                        arena.put_f32(v);
                        inp
                    };
                    debug_assert_eq!(inp.len(), ld.dec_ci * bsz);
                    let mut xwin = arena.take_f32(ld.dec_ci * k, bsz);
                    for (si, st) in states.iter_mut().enumerate() {
                        push_window_col(
                            &mut st.tensors[self.idx.dec_win[l - 1]],
                            &inp,
                            bsz,
                            si,
                            &mut xwin,
                        );
                    }
                    arena.put_f32(inp);
                    let panel = self.panel(hw, self.idx.dec_w[l - 1])?;
                    let bias = &hw.tensors()[self.idx.dec_b[l - 1]].data;
                    let mut y = arena.take_f32(ld.dec_co, bsz);
                    gemm_f32(panel, bias, &xwin, bsz, &mut y, true);
                    self.macs
                        .fetch_add((ld.dec_co * ld.dec_ci * k * bsz) as u64, Ordering::Relaxed);
                    arena.put_f32(xwin);
                    arena.release_f32(&mut d);
                    d = Some(y);
                    computed_here = true;
                }
            }
            // Extrapolation back to the r_in(l) domain.  The *write*
            // belongs to whichever pass computed the fresh d_l; the *read*
            // to the pass computing d_{l-1} (or the head for l == 1).
            if self.is_scc[l] && pp.enc_tick[l - 1] {
                let cache_slot = self.idx.up_cache[&l];
                let fresh = pp.dec_run[l - 1];
                if fresh && computed_here {
                    let dv = d.as_ref().unwrap();
                    if self.tconv[l] {
                        let widx = self.idx.up_w[&l];
                        let bias = &hw.tensors()[self.idx.up_b[&l]].data;
                        let p0 = self.phase_panel(hw, widx, 0)?;
                        let p1 = self.phase_panel(hw, widx, 1)?;
                        let mut ph0 = arena.take_f32(p0.c_out, bsz);
                        let mut ph1 = arena.take_f32(p1.c_out, bsz);
                        gemm_f32(p0, bias, dv, bsz, &mut ph0, false);
                        gemm_f32(p1, bias, dv, bsz, &mut ph1, false);
                        self.macs
                            .fetch_add((2 * p0.c_out * p0.n * bsz) as u64, Ordering::Relaxed);
                        for (si, st) in states.iter_mut().enumerate() {
                            let cache = &mut st.tensors[cache_slot];
                            scatter_state_col(cache, 0, &ph0, bsz, si);
                            scatter_state_col(cache, 1, &ph1, bsz, si);
                        }
                        arena.put_f32(ph0);
                        arena.put_f32(ph1);
                    } else {
                        for (si, st) in states.iter_mut().enumerate() {
                            scatter_state_col(&mut st.tensors[cache_slot], 0, dv, bsz, si);
                        }
                    }
                }
                let reader_delayed = (l >= 2 && delayed(l - 1)) || (l == 1 && s == Some(1));
                let reads_here = part == Part::All
                    || (reader_delayed && part == Part::Pre)
                    || (!reader_delayed && part == Part::Rest);
                arena.release_f32(&mut d);
                d = if reads_here {
                    let col = if self.tconv[l] && !fresh { 1 } else { 0 };
                    let c_c = states[0].tensors[cache_slot].shape[0];
                    let mut v = arena.take_f32(c_c, bsz);
                    for (si, st) in states.iter().enumerate() {
                        gather_state_col(&st.tensors[cache_slot], col, bsz, si, &mut v);
                    }
                    Some(v)
                } else {
                    None
                };
            }
            // FP boundary handoff (pre pass writes; rest pass reads above).
            if part == Part::Pre
                && s == Some(l)
                && !self.is_scc[l]
                && pp.dec_run[l - 1]
                && l != 1
            {
                if let Some(dv) = &d {
                    let slot = self.idx.fp_handoff.unwrap();
                    for (si, st) in states.iter_mut().enumerate() {
                        scatter_state_col(&mut st.tensors[slot], 0, dv, bsz, si);
                    }
                }
            }
        }

        // ---- head ----
        let head_panel = self.panel(hw, self.idx.head_w)?;
        let head_bias = &hw.tensors()[self.idx.head_b].data;
        let feat = self.cfg.feat;
        let produced = match part {
            Part::Pre => {
                if s == Some(1) {
                    // Whole network delayed: the head output is the handoff.
                    let dv = d
                        .take()
                        .with_context(|| format!("{}: pre pass lost the head input", self.name))?;
                    let mut out = arena.take_f32(feat, bsz);
                    gemm_f32(head_panel, head_bias, &dv, bsz, &mut out, false);
                    self.macs
                        .fetch_add((feat * head_panel.n * bsz) as u64, Ordering::Relaxed);
                    arena.put_f32(dv);
                    let slot = self.idx.fp_handoff.unwrap();
                    for (si, st) in states.iter_mut().enumerate() {
                        scatter_state_col(&mut st.tensors[slot], 0, &out, bsz, si);
                    }
                    arena.put_f32(out);
                }
                false
            }
            Part::Rest if s == Some(1) => {
                let slot = self.idx.fp_handoff.unwrap();
                let mut out = arena.take_f32(feat, bsz);
                for (si, st) in states.iter().enumerate() {
                    gather_state_col(&st.tensors[slot], 0, bsz, si, &mut out);
                }
                sink.write(&out, bsz, feat);
                arena.put_f32(out);
                true
            }
            _ => {
                let dv = d
                    .take()
                    .with_context(|| format!("{}: no decoder output at phase {phase}", self.name))?;
                let mut out = arena.take_f32(feat, bsz);
                gemm_f32(head_panel, head_bias, &dv, bsz, &mut out, false);
                self.macs
                    .fetch_add((feat * head_panel.n * bsz) as u64, Ordering::Relaxed);
                arena.put_f32(dv);
                sink.write(&out, bsz, feat);
                arena.put_f32(out);
                true
            }
        };
        arena.release_f32(&mut d);
        arena.put_opts_f32(enc_out);
        Ok(produced)
    }

    // ---- offline (full-sequence) interpreter ------------------------------

    fn offline_forward(&self, x: &Tensor, hw: &HostWeights) -> Result<Tensor> {
        let cfg = &self.cfg;
        if x.shape.len() != 2 || x.shape[0] != cfg.feat {
            bail!(
                "{}: offline input shape {:?}, expected [{}, T]",
                self.name,
                x.shape,
                cfg.feat
            );
        }
        if x.shape[1] == 0 || x.shape[1] % self.period != 0 {
            bail!(
                "{}: offline T = {} must be a positive multiple of the period {}",
                self.name,
                x.shape[1],
                self.period
            );
        }
        let depth = self.depth;
        // enc[l - 1] holds the post-activation output of encoder layer l
        // (no clone of the input, no per-layer `cur` copies).
        let mut enc: Vec<Tensor> = Vec::with_capacity(depth);
        for l in 1..=depth {
            let prev: &Tensor = if l == 1 { x } else { &enc[l - 2] };
            let shifted;
            let inp: &Tensor = if cfg.shift_pos == Some(l) {
                shifted = delay_cols(prev, cfg.shift);
                &shifted
            } else {
                prev
            };
            let mut y = self.conv_full(
                inp,
                self.panel(hw, self.idx.enc_w[l - 1])?,
                &hw.tensors()[self.idx.enc_b[l - 1]].data,
                true,
            );
            if self.is_scc[l] {
                y = stride2(&y);
            }
            enc.push(y);
        }

        let mut d: Option<Tensor> = None;
        for l in (1..=depth).rev() {
            let concat;
            let inp: &Tensor = if l == depth {
                &enc[depth - 1]
            } else {
                concat = concat_rows(d.as_ref().unwrap(), &enc[l - 1]);
                &concat
            };
            let mut dl = self.conv_full(
                inp,
                self.panel(hw, self.idx.dec_w[l - 1])?,
                &hw.tensors()[self.idx.dec_b[l - 1]].data,
                true,
            );
            if self.is_scc[l] {
                let t_out = if l == 1 { x.shape[1] } else { enc[l - 2].shape[1] };
                dl = if let Some(kind) = &cfg.interp {
                    interp_upsample(&dl, t_out, kind)
                        .with_context(|| format!("{}: up{l}", self.name))?
                } else if self.tconv[l] {
                    self.tconv_upsample(&dl, hw, l, t_out)?
                } else {
                    duplicate_upsample(&dl, t_out)
                };
            }
            d = Some(dl);
        }
        Ok(self.conv_full(
            &d.unwrap(),
            self.panel(hw, self.idx.head_w)?,
            &hw.tensors()[self.idx.head_b].data,
            false,
        ))
    }

    /// Causal stride-1 conv over a whole (C_in, T) sequence, executed as
    /// one panel GEMM with T as the batch axis.  The window gather's
    /// zero left-padding reproduces the zero-initialised streaming
    /// window states, and the kernel and per-element accumulation order
    /// are exactly the streaming step's — offline and streaming agree by
    /// construction.
    fn conv_full(&self, x: &Tensor, panel: &PackedF32, bias: &[f32], elu: bool) -> Tensor {
        let c_in = x.shape[0];
        let t = x.shape[1];
        let c_out = panel.c_out;
        let k = if c_in == 0 { 1 } else { panel.n / c_in };
        debug_assert_eq!(panel.n, c_in * k);
        let mut xwin = offline_take(c_in * k * t);
        for i in 0..c_in {
            for j in 0..k {
                let shift = k - 1 - j;
                let n = t.saturating_sub(shift);
                if n > 0 {
                    let row = (i * k + j) * t;
                    xwin[row + shift..row + shift + n].copy_from_slice(&x.data[i * t..i * t + n]);
                }
            }
        }
        let mut out = Tensor::zeros(vec![c_out, t]);
        gemm_f32(panel, bias, &xwin, t, &mut out.data, elu);
        offline_put(xwin);
        self.macs
            .fetch_add((c_out * c_in * k * t) as u64, Ordering::Relaxed);
        out
    }

    /// Stride-2 transposed conv over a whole sequence via the per-phase
    /// packed panels: phase 0 lands on even output times, phase 1 on odd
    /// ones.
    fn tconv_upsample(
        &self,
        y: &Tensor,
        hw: &HostWeights,
        l: usize,
        t_out: usize,
    ) -> Result<Tensor> {
        let widx = self.idx.up_w[&l];
        let bias = &hw.tensors()[self.idx.up_b[&l]].data;
        let s = y.shape[1];
        let c_out = self.phase_panel(hw, widx, 0)?.c_out;
        let mut out = Tensor::zeros(vec![c_out, t_out]);
        let mut ph = offline_take(c_out * s);
        for phx in 0..2usize {
            let panel = self.phase_panel(hw, widx, phx)?;
            gemm_f32(panel, bias, &y.data, s, &mut ph, false);
            self.macs
                .fetch_add((c_out * panel.n * s) as u64, Ordering::Relaxed);
            for src in 0..s {
                let tt = 2 * src + phx;
                if tt < t_out {
                    for o in 0..c_out {
                        out.set2(o, tt, ph[o * s + src]);
                    }
                }
            }
        }
        offline_put(ph);
        Ok(out)
    }
}

impl VariantExec for NativeVariant {
    fn init_states(&self) -> StateSet {
        StateSet {
            tensors: self
                .specs
                .iter()
                .map(|s| Tensor::zeros(s.shape.clone()))
                .collect(),
        }
    }

    fn has_fp_split(&self) -> bool {
        // An FP shift at layer 1 that is *also* an S-CC position has no
        // handoff slot (the head boundary value has nowhere to park) —
        // the reference model cannot split that configuration either;
        // the paper's SS-CC table starts at position 2.
        match self.cfg.shift_pos {
            Some(1) => !self.cfg.scc.contains(&1),
            Some(_) => true,
            None => false,
        }
    }

    fn step(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        weights: &DeviceWeights,
    ) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.step_into(phase, frame, states, weights, &mut out)?;
        Ok(out)
    }

    fn step_into(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        weights: &DeviceWeights,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let frames = [frame];
        let mut sts = [states];
        let mut sink = OutSink::Single(out);
        let produced = self.run_step_batch(
            phase,
            Some(&frames[..]),
            &mut sts[..],
            weights,
            Part::All,
            &mut sink,
        )?;
        if !produced {
            bail!("{}: step produced no output", self.name);
        }
        Ok(())
    }

    fn precompute(
        &self,
        phase: usize,
        states: &mut StateSet,
        weights: &DeviceWeights,
    ) -> Result<()> {
        if !self.has_fp_split() {
            bail!("{}: variant has no FP split", self.name);
        }
        let mut sts = [states];
        let mut sink = OutSink::Discard;
        self.run_step_batch(phase, None, &mut sts[..], weights, Part::Pre, &mut sink)?;
        Ok(())
    }

    fn step_rest(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        weights: &DeviceWeights,
    ) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.step_rest_into(phase, frame, states, weights, &mut out)?;
        Ok(out)
    }

    fn step_rest_into(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        weights: &DeviceWeights,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        if !self.has_fp_split() {
            bail!("{}: variant has no FP split", self.name);
        }
        let frames = [frame];
        let mut sts = [states];
        let mut sink = OutSink::Single(out);
        let produced = self.run_step_batch(
            phase,
            Some(&frames[..]),
            &mut sts[..],
            weights,
            Part::Rest,
            &mut sink,
        )?;
        if !produced {
            bail!("{}: rest pass produced no output", self.name);
        }
        Ok(())
    }

    fn step_batch(
        &self,
        phase: usize,
        frames: &[&[f32]],
        states: &mut [&mut StateSet],
        weights: &DeviceWeights,
    ) -> Result<Vec<Vec<f32>>> {
        let mut outs = Vec::new();
        self.step_batch_into(phase, frames, states, weights, &mut outs)?;
        Ok(outs)
    }

    fn step_batch_into(
        &self,
        phase: usize,
        frames: &[&[f32]],
        states: &mut [&mut StateSet],
        weights: &DeviceWeights,
        outs: &mut Vec<Vec<f32>>,
    ) -> Result<()> {
        // run_step_batch validates frame/state arity and frame sizes
        let mut sink = OutSink::Batch(outs);
        let produced =
            self.run_step_batch(phase, Some(frames), states, weights, Part::All, &mut sink)?;
        if !produced {
            bail!("{}: batched step produced no output", self.name);
        }
        Ok(())
    }

    fn step_rest_batch(
        &self,
        phase: usize,
        frames: &[&[f32]],
        states: &mut [&mut StateSet],
        weights: &DeviceWeights,
    ) -> Result<Vec<Vec<f32>>> {
        let mut outs = Vec::new();
        self.step_rest_batch_into(phase, frames, states, weights, &mut outs)?;
        Ok(outs)
    }

    fn step_rest_batch_into(
        &self,
        phase: usize,
        frames: &[&[f32]],
        states: &mut [&mut StateSet],
        weights: &DeviceWeights,
        outs: &mut Vec<Vec<f32>>,
    ) -> Result<()> {
        if !self.has_fp_split() {
            bail!("{}: variant has no FP split", self.name);
        }
        let mut sink = OutSink::Batch(outs);
        let produced =
            self.run_step_batch(phase, Some(frames), states, weights, Part::Rest, &mut sink)?;
        if !produced {
            bail!("{}: batched rest pass produced no output", self.name);
        }
        Ok(())
    }

    fn offline(&self, x: &Tensor, weights: &DeviceWeights) -> Result<Tensor> {
        let hw = self.host(weights)?;
        self.offline_forward(x, hw)
    }

    fn executed_macs(&self) -> Option<u64> {
        Some(self.macs.load(Ordering::Relaxed))
    }

    fn reset_executed_macs(&self) {
        self.macs.store(0, Ordering::Relaxed);
    }

    fn arena_id(&self) -> Option<u64> {
        Some(self.arena_id)
    }
}

// ---- column/window primitives ---------------------------------------------
//
// Per-stream states stay row-major (C, W) tensors; batch-wide activations
// are (C, B) matrices.  The helpers below move one stream's column
// between the two layouts.

/// Read column `col` of a (C, W) state tensor into column `si` of a
/// (C, B) batch matrix.
fn gather_state_col(t: &Tensor, col: usize, bsz: usize, si: usize, dst: &mut [f32]) {
    let w = t.shape[1];
    for i in 0..t.shape[0] {
        dst[i * bsz + si] = t.data[i * w + col];
    }
}

/// Write column `si` of a (C, B) batch matrix into column `col` of
/// stream `si`'s (C, W) state tensor.
fn scatter_state_col(t: &mut Tensor, col: usize, src: &[f32], bsz: usize, si: usize) {
    let w = t.shape[1];
    for i in 0..t.shape[0] {
        t.data[i * w + col] = src[i * bsz + si];
    }
}

/// STMC window tick for stream `si`: writes that stream's full (C, K)
/// window `[state | cur]` into column `si` of the (C·K, B) matrix `dst`
/// and advances the per-stream window state to `window[:, 1:]`.
fn push_window_col(state: &mut Tensor, cur: &[f32], bsz: usize, si: usize, dst: &mut [f32]) {
    let c = state.shape[0];
    let wlen = state.shape[1]; // K - 1
    let k = wlen + 1;
    for i in 0..c {
        let row = &mut state.data[i * wlen..(i + 1) * wlen];
        for (j, &v) in row.iter().enumerate() {
            dst[(i * k + j) * bsz + si] = v;
        }
        let x = cur[i * bsz + si];
        dst[(i * k + wlen) * bsz + si] = x;
        if wlen > 0 {
            row.copy_within(1.., 0);
            row[wlen - 1] = x;
        }
    }
}

/// FIFO tick for stream `si`: drop the oldest column, append that
/// stream's current value (column `si` of the (C, B) matrix `cur`).
fn push_fifo_col(state: &mut Tensor, cur: &[f32], bsz: usize, si: usize) {
    let w = state.shape[1];
    for i in 0..state.shape[0] {
        let row = &mut state.data[i * w..(i + 1) * w];
        row.copy_within(1.., 0);
        row[w - 1] = cur[i * bsz + si];
    }
}

// ---- offline sequence primitives ------------------------------------------

/// Right-shift along time by `d` frames (zeros in front), same length.
fn delay_cols(x: &Tensor, d: usize) -> Tensor {
    let (c, t) = (x.shape[0], x.shape[1]);
    let mut out = Tensor::zeros(vec![c, t]);
    for i in 0..c {
        for tt in d..t {
            out.set2(i, tt, x.at2(i, tt - d));
        }
    }
    out
}

/// Keep even time steps: `out[:, s] = x[:, 2 s]`.
fn stride2(x: &Tensor) -> Tensor {
    let (c, t) = (x.shape[0], x.shape[1]);
    let t2 = (t + 1) / 2;
    let mut out = Tensor::zeros(vec![c, t2]);
    for i in 0..c {
        for s in 0..t2 {
            out.set2(i, s, x.at2(i, 2 * s));
        }
    }
    out
}

/// Stack `a` over `b` along the channel axis.
fn concat_rows(a: &Tensor, b: &Tensor) -> Tensor {
    debug_assert_eq!(a.shape[1], b.shape[1]);
    let t = a.shape[1];
    let c = a.shape[0] + b.shape[0];
    let mut data = Vec::with_capacity(c * t);
    data.extend_from_slice(&a.data);
    data.extend_from_slice(&b.data);
    Tensor::new(vec![c, t], data)
}

/// Duplication extrapolation (PP alignment): `up[:, t] = y[:, t / 2]`.
fn duplicate_upsample(y: &Tensor, t_out: usize) -> Tensor {
    let c = y.shape[0];
    let last = y.shape[1] - 1;
    let mut out = Tensor::zeros(vec![c, t_out]);
    for i in 0..c {
        for tt in 0..t_out {
            out.set2(i, tt, y.at2(i, (tt / 2).min(last)));
        }
    }
    out
}

/// Interpolation reconstruction (App. D, offline-only).
fn interp_upsample(y: &Tensor, t_out: usize, kind: &str) -> Result<Tensor> {
    let c = y.shape[0];
    let last = y.shape[1] as isize - 1;
    let tap = |i: usize, j: isize| y.at2(i, j.clamp(0, last) as usize);
    let mut out = Tensor::zeros(vec![c, t_out]);
    for tt in 0..t_out {
        let s0 = (tt / 2) as isize;
        let odd = tt % 2 == 1;
        let frac: f32 = if odd { 0.5 } else { 0.0 };
        for i in 0..c {
            let v = match kind {
                "nearest" => tap(i, s0 + if odd { 1 } else { 0 }),
                "linear" => tap(i, s0) * (1.0 - frac) + tap(i, s0 + 1) * frac,
                "cubic" => {
                    // Catmull-Rom with u = frac
                    let (p0, p1, p2, p3) =
                        (tap(i, s0 - 1), tap(i, s0), tap(i, s0 + 1), tap(i, s0 + 2));
                    let u = frac;
                    0.5 * ((2.0 * p1)
                        + (-p0 + p2) * u
                        + (2.0 * p0 - 5.0 * p1 + 4.0 * p2 - p3) * u * u
                        + (-p0 + 3.0 * p1 - 3.0 * p2 + p3) * u * u * u)
                }
                other => bail!("unknown interpolation kind '{other}'"),
            };
            out.set2(i, tt, v);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_specs_mirror_python_inventory() {
        let cfg = ModelConfig {
            feat: 4,
            channels: vec![6, 8],
            kernel: 3,
            scc: vec![2],
            shift_pos: Some(2),
            shift: 1,
            extrap: vec!["duplicate".into()],
            interp: None,
        };
        let specs = state_specs(&cfg);
        let names: Vec<&str> = specs.iter().map(|s| s.name.as_str()).collect();
        // s == p (SS-CC): no fp.handoff slot.
        assert_eq!(
            names,
            ["enc1.win", "enc2.win", "dec2.win", "dec1.win", "up2.cache", "shift.fifo"]
        );
        assert_eq!(specs[0].shape, vec![4, 2]); // enc1: feat x (k-1)
        assert_eq!(specs[2].shape, vec![8, 2]); // dec2 in = channels[1]
        assert_eq!(specs[3].shape, vec![6 + 6, 2]); // dec1 in = dec_out(2)+ch[0]
        assert_eq!(specs[4].shape, vec![6, 1]); // up2 cache = dec_out(2)
        assert_eq!(specs[5].shape, vec![6, 1]); // fifo at enc2 input
    }

    #[test]
    fn hybrid_fp_gets_handoff_slot() {
        let cfg = ModelConfig {
            feat: 4,
            channels: vec![5, 6, 7],
            kernel: 3,
            scc: vec![3],
            shift_pos: Some(2),
            shift: 1,
            extrap: vec!["duplicate".into()],
            interp: None,
        };
        let names: Vec<String> = state_specs(&cfg).iter().map(|s| s.name.clone()).collect();
        assert!(names.contains(&"fp.handoff".to_string()));
        assert!(names.contains(&"shift.fifo".to_string()));
    }

    #[test]
    fn push_window_col_shifts_by_one() {
        // Stream 1 of a 2-wide batch: C = 2 channels, kernel 3.
        let mut st = Tensor::new(vec![2, 2], vec![1.0, 2.0, 10.0, 20.0]);
        let bsz = 2;
        // cur is a (2, 2) batch matrix; stream 1's column is [3, 30].
        let cur = vec![-1.0, 3.0, -1.0, 30.0];
        let mut dst = vec![0.0f32; 2 * 3 * bsz];
        push_window_col(&mut st, &cur, bsz, 1, &mut dst);
        // column 1 of dst holds the stream's flattened (C, K) window
        let win: Vec<f32> = (0..6).map(|r| dst[r * bsz + 1]).collect();
        assert_eq!(win, vec![1.0, 2.0, 3.0, 10.0, 20.0, 30.0]);
        assert_eq!(st.data, vec![2.0, 3.0, 20.0, 30.0]);
        // stream 0's column was left untouched
        assert!((0..6).all(|r| dst[r * bsz] == 0.0));
    }

    #[test]
    fn fifo_col_drops_oldest() {
        let mut st = Tensor::new(vec![1, 3], vec![1.0, 2.0, 3.0]);
        push_fifo_col(&mut st, &[4.0], 1, 0);
        assert_eq!(st.data, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn gather_scatter_roundtrip_state_columns() {
        let mut st = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let bsz = 3;
        let mut panel = vec![0.0f32; 2 * bsz];
        gather_state_col(&st, 1, bsz, 2, &mut panel);
        assert_eq!(panel, vec![0.0, 0.0, 2.0, 0.0, 0.0, 4.0]);
        scatter_state_col(&mut st, 0, &panel, bsz, 2);
        assert_eq!(st.data, vec![2.0, 2.0, 4.0, 4.0]);
    }

    #[test]
    fn duplicate_upsample_repeats_frames() {
        let y = Tensor::new(vec![1, 2], vec![5.0, 7.0]);
        let up = duplicate_upsample(&y, 4);
        assert_eq!(up.data, vec![5.0, 5.0, 7.0, 7.0]);
    }

    #[test]
    fn phase_plans_mirror_rate_arithmetic() {
        let cfg = ModelConfig {
            feat: 4,
            channels: vec![5, 6, 7],
            kernel: 3,
            scc: vec![2],
            shift_pos: None,
            shift: 1,
            extrap: vec!["duplicate".into()],
            interp: None,
        };
        let m = crate::runtime::synth::manifest(&cfg, "t", 16);
        let v = NativeVariant::new(&m).unwrap();
        assert_eq!(v.plans.len(), v.period);
        for (phase, pp) in v.plans.iter().enumerate() {
            for l in 1..=v.depth {
                assert_eq!(pp.enc_tick[l - 1], phase % v.r_in[l] == 0, "tick l={l} p={phase}");
                let fire = if v.is_scc[l] {
                    phase % (2 * v.r_in[l]) == 0
                } else {
                    phase % v.r_in[l] == 0
                };
                assert_eq!(pp.enc_fire[l - 1], fire, "fire l={l} p={phase}");
                assert_eq!(pp.dec_run[l - 1], phase % v.r_out[l] == 0, "dec l={l} p={phase}");
            }
        }
    }
}
