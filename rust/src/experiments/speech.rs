//! Speech-separation experiment drivers: Tables 1/2/3/5/6/7/8/9 and the
//! corresponding figures (4/5/7/8/9/10/11) — the paper's §4.1 + App. B–E.

use anyhow::Result;

use super::eval::{arced, load_variant, si_snri_offline};
use super::{f1, f2, Ctx, Table};
use crate::complexity::paper;
use crate::coordinator::StreamSession;
use crate::dsp::{frames, metrics, resample, siggen};
use crate::util::rng::Rng;

/// Measured row for one variant: SI-SNRi (mean±std), retain %, MMAC/s.
struct Row {
    label: String,
    si_snri: f64,
    si_std: f64,
    retain: f64,
    mmacs: f64,
    precomp: f64,
}

fn measure(ctx: &Ctx, name: &str, label: &str, stmc_macs: f64) -> Result<Row> {
    let cv = load_variant(ctx, name)?;
    let dw = cv.device_weights()?;
    let (m, s) = si_snri_offline(&cv, &dw, ctx.n_eval, ctx.seed)?;
    let fps = siggen::FS / cv.manifest.config.feat as f64;
    // recompute precomputed % analytically via the complexity engine
    let net = crate::complexity::unet::network(&cv.manifest.config, 256, fps);
    Ok(Row {
        label: label.to_string(),
        si_snri: m,
        si_std: s,
        retain: 100.0 * cv.manifest.macs_per_frame / stmc_macs,
        mmacs: cv.manifest.macs_per_frame * fps / 1e6,
        precomp: net.precomputed_pct(),
    })
}

fn stmc_macs_per_frame(ctx: &Ctx) -> Result<f64> {
    Ok(load_variant(ctx, "stmc")?.manifest.macs_per_frame)
}

// ---------------------------------------------------------------------------
// Table 1 / Figure 4 — PP SOI
// ---------------------------------------------------------------------------

/// Table 1 / Fig. 4: PP SOI — complexity retain and SI-SNRi per S-CC placement.
pub fn table1(ctx: &Ctx) -> Result<()> {
    let base = stmc_macs_per_frame(ctx)?;
    let mut t = Table::new(
        "Table 1 — Partially predictive SOI (speech separation)",
        &[
            "Model", "SI-SNRi (dB)", "±", "retain %", "MMAC/s", "paper SI-SNRi",
            "paper retain %",
        ],
    );
    let spec: Vec<(&str, String, Option<(f64, f64)>)> = vec![
        ("stmc", "STMC".into(), Some((paper::STMC_SISNRI, 100.0))),
        ("pred1", "Predictive 1".into(), Some((7.41, 100.0))),
        ("pred2", "Predictive 2".into(), Some((6.51, 100.0))),
        ("scc1", "S-CC 1".into(), Some((7.15, 50.1))),
        ("scc2", "S-CC 2".into(), Some((7.23, 51.4))),
        ("scc3", "S-CC 3".into(), Some((7.28, 58.1))),
        ("scc4", "S-CC 4".into(), Some((7.43, 61.5))),
        ("scc5", "S-CC 5".into(), Some((7.47, 64.8))),
        ("scc6", "S-CC 6".into(), Some((7.56, 71.3))),
        ("scc7", "S-CC 7".into(), Some((7.55, 83.8))),
    ];
    let mut rows = Vec::new();
    for (name, label, paper_ref) in &spec {
        let r = measure(ctx, name, label, base)?;
        let (psnr, pret) = paper_ref.unwrap_or((f64::NAN, f64::NAN));
        t.row(vec![
            r.label.clone(),
            f2(r.si_snri),
            f2(r.si_std),
            f1(r.retain),
            f1(r.mmacs),
            f2(psnr),
            f1(pret),
        ]);
        rows.push(r);
    }
    for &(p, q, psnr, pret) in &paper::TABLE1_2SCC {
        let name = format!("scc{p}_{q}");
        if !ctx.artifacts.join(&name).exists() {
            continue;
        }
        let r = measure(ctx, &name, &format!("2xS-CC {p}|{q}"), base)?;
        t.row(vec![
            r.label.clone(),
            f2(r.si_snri),
            f2(r.si_std),
            f1(r.retain),
            f1(r.mmacs),
            f2(psnr),
            f1(pret),
        ]);
        rows.push(r);
    }
    let mut body = t.render();
    body.push_str(&shape_checks_pp(&rows));
    ctx.emit("table1", &body)
}

/// The qualitative claims Table 1 makes, asserted on our measurements.
fn shape_checks_pp(rows: &[Row]) -> String {
    let find = |l: &str| rows.iter().find(|r| r.label == l);
    let mut out = String::from("\nShape checks (paper's qualitative claims on our data):\n");
    let mut check = |name: &str, ok: bool| {
        out.push_str(&format!("- [{}] {}\n", if ok { "x" } else { " " }, name));
    };
    if let (Some(stmc), Some(s1), Some(s5), Some(s7)) =
        (find("STMC"), find("S-CC 1"), find("S-CC 5"), find("S-CC 7"))
    {
        check("earlier S-CC ⇒ lower quality (S-CC1 < S-CC5)", s1.si_snri < s5.si_snri);
        check("earlier S-CC ⇒ bigger savings (retain1 < retain5)", s1.retain < s5.retain);
        check("late S-CC ~ STMC quality (S-CC7 ≥ STMC − 1 dB)", s7.si_snri >= stmc.si_snri - 1.0);
        check("all SOI variants cheaper than STMC", rows.iter().all(|r| r.retain <= 100.01));
    }
    out
}

// ---------------------------------------------------------------------------
// Table 2 / Figure 5 — FP SOI
// ---------------------------------------------------------------------------

/// Table 2 / Fig. 5: FP SOI — precomputed fraction and hidden latency.
pub fn table2(ctx: &Ctx) -> Result<()> {
    let base = stmc_macs_per_frame(ctx)?;
    let mut t = Table::new(
        "Table 2 — Fully predictive SOI (speech separation)",
        &[
            "Model", "SI-SNRi (dB)", "±", "retain %", "MMAC/s", "Precomp %",
            "measured hidden %", "paper SI-SNRi", "paper precomp %",
        ],
    );
    let spec: Vec<(String, String, f64, f64)> = vec![
        ("stmc".into(), "STMC".into(), paper::STMC_SISNRI, 0.0),
        ("pred1".into(), "Predictive 1".into(), 7.41, 100.0),
        ("pred2".into(), "Predictive 2".into(), 6.51, 100.0),
        ("sscc2".into(), "SS-CC 2".into(), 6.64, 97.2),
        ("sscc5".into(), "SS-CC 5".into(), 7.24, 70.4),
        ("sscc7".into(), "SS-CC 7".into(), 7.52, 32.4),
        ("fp1_3".into(), "S-CC 1|3".into(), 6.82, 83.7),
        ("fp1_6".into(), "S-CC 1|6".into(), 7.06, 57.4),
        ("fp2_5".into(), "S-CC 2|5".into(), 6.93, 70.4),
        ("fp3_6".into(), "S-CC 3|6".into(), 7.10, 57.4),
        ("fp4_6".into(), "S-CC 4|6".into(), 7.30, 57.4),
        ("fp5_6".into(), "S-CC 5|6".into(), 7.23, 57.4),
        ("fp6_7".into(), "S-CC 6|7".into(), 7.39, 32.4),
    ];
    for (name, label, psnr, ppre) in &spec {
        if !ctx.artifacts.join(name).exists() {
            continue;
        }
        let r = measure(ctx, name, label, base)?;
        let hidden = measured_hidden_pct(ctx, name)?;
        t.row(vec![
            r.label.clone(),
            f2(r.si_snri),
            f2(r.si_std),
            f1(r.retain),
            f1(r.mmacs),
            f1(r.precomp),
            f1(hidden),
            f2(*psnr),
            f1(*ppre),
        ]);
    }
    let mut body = t.render();
    body.push_str(
        "\n'Precomp %' is analytic (fraction of full-rate MACs depending on past \
         data only); 'measured hidden %' is the wall-clock share of each inference \
         actually executed in the idle gap by the coordinator's FP scheduler.\n",
    );
    ctx.emit("table2", &body)
}

/// Run a short stream through the coordinator and report the fraction of
/// inference wall time hidden in the precompute slot.
fn measured_hidden_pct(ctx: &Ctx, name: &str) -> Result<f64> {
    let cv = arced(load_variant(ctx, name)?);
    if !cv.has_fp_split() {
        return Ok(0.0);
    }
    let dw = std::sync::Arc::new(cv.device_weights()?);
    let feat = cv.manifest.config.feat;
    let mut sess = StreamSession::new(0, cv, dw);
    let mut rng = Rng::new(ctx.seed ^ 0x51de);
    let (noisy, _) = siggen::denoise_pair(&mut rng, feat * 256, siggen::FS);
    let (cols, _) = frames(&noisy, feat);
    for col in &cols {
        sess.idle()?; // the idle gap between frames
        sess.on_frame(col)?;
    }
    Ok(100.0 * sess.metrics.hidden_fraction())
}

// ---------------------------------------------------------------------------
// Table 3 — resampling baselines
// ---------------------------------------------------------------------------

/// Table 3: resampling baselines vs SOI.
pub fn table3(ctx: &Ctx) -> Result<()> {
    let base = stmc_macs_per_frame(ctx)?;
    let cv = load_variant(ctx, "stmc")?;
    let dw = cv.device_weights()?;
    let feat = cv.manifest.config.feat;
    let t_frames = cv.manifest.offline_t;
    let fps = siggen::FS / feat as f64;
    let stmc_mmacs = base * fps / 1e6;

    let mut t = Table::new(
        "Table 3 — SOI vs resampling (denoising through a 16k→8k→16k round trip)",
        &["Method", "SI-SNRi (dB)", "MMAC/s", "paper SI-SNRi", "paper MMAC/s"],
    );
    // STMC reference
    let (m0, _) = si_snri_offline(&cv, &dw, ctx.n_eval, ctx.seed)?;
    t.row(vec![
        "STMC".into(),
        f2(m0),
        f1(stmc_mmacs),
        f2(paper::STMC_SISNRI),
        f1(paper::STMC_MMACS),
    ]);

    // Resampling baselines: model runs on the 8 kHz stream (half the
    // frames per second => half the MMAC/s), output upsampled back.
    for (method, (plabel, psnr, pmm)) in resample::Method::ALL.iter().zip([
        ("Linear", 3.49, 909.6),
        ("Polyphase", 5.69, 909.6),
        ("Kaiser", 5.83, 909.6),
        ("SoX", 5.77, 909.6),
    ]) {
        let mut rng = Rng::new(ctx.seed);
        let mut imps = Vec::new();
        for _ in 0..ctx.n_eval {
            let n = feat * t_frames * 2; // 2x samples so 8 kHz yields t_frames
            let (noisy, clean) = siggen::denoise_pair(&mut rng, n, siggen::FS);
            let down = resample::downsample2(&noisy, *method);
            let (cols, nt) = frames(&down, feat);
            let nt = nt.min(t_frames);
            let mut data = vec![0.0f32; feat * t_frames];
            for (tt, col) in cols.iter().take(nt).enumerate() {
                for (i, &v) in col.iter().enumerate() {
                    data[i * t_frames + tt] = v;
                }
            }
            let x = crate::util::tensor::Tensor::new(vec![feat, t_frames], data);
            let out = cv.offline(&x, &dw)?;
            let est8 = super::eval::output_to_wave(&out);
            let est16 = resample::upsample2(&est8[..nt * feat], *method);
            let n_s = est16.len().min(clean.len());
            imps.push(metrics::si_snr_improvement(
                &noisy[..n_s],
                &est16[..n_s],
                &clean[..n_s],
            ));
        }
        let (m, _) = super::eval::mean_std(&imps);
        t.row(vec![
            method.name().into(),
            f2(m),
            f1(stmc_mmacs / 2.0),
            f2(psnr),
            f1(pmm),
        ]);
        let _ = plabel;
    }

    // SOI comparison points (same rows the paper lists)
    for (name, label, psnr, pmm) in [
        ("scc5", "S-CC 5", 7.47, 1178.7),
        ("scc2", "S-CC 2", 7.23, 935.2),
        ("scc1_3", "S-CC 1|3", 6.27, 528.8),
    ] {
        if !ctx.artifacts.join(name).exists() {
            continue;
        }
        let r = measure(ctx, name, label, base)?;
        t.row(vec![label.into(), f2(r.si_snri), f1(r.mmacs), f2(psnr), f1(pmm)]);
    }
    let mut body = t.render();
    body.push_str(
        "\nShape check: SOI variants must dominate resampling at comparable \
         complexity (the paper's headline for Table 3).\n",
    );
    ctx.emit("table3", &body)
}

// ---------------------------------------------------------------------------
// Table 5 / Figure 7 — prediction length (App. B)
// ---------------------------------------------------------------------------

/// Table 5 / Fig. 7: prediction-length sweep.
pub fn table5(ctx: &Ctx) -> Result<()> {
    let mut t = Table::new(
        "Table 5 — Strided convolutions are better for longer predictions",
        &[
            "Len", "Predictive (dB)", "±", "Strided pred (dB)", "±",
            "paper pred", "paper strided",
        ],
    );
    let base = stmc_macs_per_frame(ctx)?;
    let mut ours: Vec<(f64, f64)> = Vec::new();
    for (n, ppred, pstr) in paper::TABLE5_PREDICTION {
        let p = measure(ctx, &format!("pred{n}"), "p", base)?;
        let s = measure(ctx, &format!("spred{n}"), "s", base)?;
        t.row(vec![
            n.to_string(),
            f2(p.si_snri),
            f2(p.si_std),
            f2(s.si_snri),
            f2(s.si_std),
            f2(ppred),
            f2(pstr),
        ]);
        ours.push((p.si_snri, s.si_snri));
    }
    let mut body = t.render();
    let degrades = ours.windows(2).all(|w| w[1].0 <= w[0].0 + 0.3);
    body.push_str(&format!(
        "\nShape checks:\n- [{}] longer prediction degrades quality (monotone trend)\n- [{}] strided catches up or wins at longer predictions (paper's App. B claim)\n",
        if degrades { "x" } else { " " },
        if ours.last().map_or(false, |l| l.1 >= l.0 - 0.3) { "x" } else { " " },
    ));
    ctx.emit("table5", &body)
}

// ---------------------------------------------------------------------------
// Table 6 / Figure 8 — inference time + peak memory (REAL measurements)
// ---------------------------------------------------------------------------

/// Table 6 / Fig. 8: inference time and partial-state memory.
pub fn table6(ctx: &Ctx) -> Result<()> {
    let base = stmc_macs_per_frame(ctx)?;
    let mut t = Table::new(
        "Table 6 — measured average inference time and peak state memory",
        &[
            "Model", "SI-SNRi (dB)", "retain %", "avg step (µs)", "p95 (µs)",
            "state KB", "paper ms", "paper MB",
        ],
    );
    let names: Vec<(String, String)> = std::iter::once(("stmc".into(), "STMC".into()))
        .chain((1..=7).map(|p| (format!("scc{p}"), format!("S-CC {p}"))))
        .collect();
    for ((name, label), (plabel, pms, pmb)) in names.iter().zip(paper::TABLE6_TIME_MEM) {
        let _ = plabel;
        let r = measure(ctx, name, label, base)?;
        let cv = arced(load_variant(ctx, name)?);
        let dw = std::sync::Arc::new(cv.device_weights()?);
        let feat = cv.manifest.config.feat;
        let mut sess = StreamSession::new(0, cv, dw);
        let mut rng = Rng::new(ctx.seed ^ 0xBEEF);
        let (noisy, _) = siggen::denoise_pair(&mut rng, feat * 512, siggen::FS);
        let (cols, _) = frames(&noisy, feat);
        for col in &cols {
            sess.on_frame(col)?;
        }
        let mean_us = sess.metrics.arrival_latency.mean() / 1e3;
        let p95_us = sess.metrics.arrival_latency.p95() as f64 / 1e3;
        let state_kb = sess.state_bytes() as f64 / 1024.0;
        t.row(vec![
            label.clone(),
            f2(r.si_snri),
            f1(r.retain),
            f1(mean_us),
            f1(p95_us),
            f2(state_kb),
            f2(pms),
            f1(pmb),
        ]);
    }
    let mut body = t.render();
    body.push_str(
        "\nTiming is the measured on-arrival wall time per frame through the \
         coordinator + PJRT CPU path (µs here vs the paper's ms on an MCU-class \
         target); 'state KB' is the per-stream partial-state cache — the memory \
         the paper's Table 6 tracks.\n",
    );
    ctx.emit("table6", &body)
}

// ---------------------------------------------------------------------------
// Table 7 / Figure 9 — interpolation (App. D)
// ---------------------------------------------------------------------------

/// Table 7 / Fig. 9: interpolation reconstruction (offline-only).
pub fn table7(ctx: &Ctx) -> Result<()> {
    let base = stmc_macs_per_frame(ctx)?;
    let mut t = Table::new(
        "Table 7 — duplication vs interpolation for PP SOI (App. D)",
        &["Model", "Duplication", "Nearest", "Linear", "Cubic", "paper dup", "paper bilinear"],
    );
    for (p, pdup, pbil) in [(2usize, 7.23, 7.42), (5usize, 7.47, 7.63)] {
        let dup = measure(ctx, &format!("scc{p}"), "d", base)?;
        let near = measure(ctx, &format!("scc{p}_inearest"), "n", base)?;
        let lin = measure(ctx, &format!("scc{p}_ilinear"), "l", base)?;
        let cub = measure(ctx, &format!("scc{p}_icubic"), "c", base)?;
        t.row(vec![
            format!("S-CC {p}"),
            f2(dup.si_snri),
            f2(near.si_snri),
            f2(lin.si_snri),
            f2(cub.si_snri),
            f2(pdup),
            f2(pbil),
        ]);
    }
    let mut body = t.render();
    body.push_str(
        "\nInterpolation waits one extra compressed frame (higher latency) — \
         evaluated through the offline artifacts, matching App. D's setup.\n",
    );
    ctx.emit("table7", &body)
}

// ---------------------------------------------------------------------------
// Tables 8/9 / Figures 10/11 — duplication vs transposed conv (App. E)
// ---------------------------------------------------------------------------

/// Table 8 / Fig. 10: extrapolation kinds, single S-CC.
pub fn table8(ctx: &Ctx) -> Result<()> {
    let base = stmc_macs_per_frame(ctx)?;
    let mut t = Table::new(
        "Table 8 — extrapolation: duplication vs transposed conv (PP)",
        &["Model", "Duplication", "Tconv", "Hybrid", "paper dup", "paper tconv"],
    );
    for (p, pdup, ptc) in [(2usize, 6.25, 6.29), (5usize, 7.14, 7.15)] {
        let dup = measure(ctx, &format!("scc{p}"), "d", base)?;
        let tc = measure(ctx, &format!("scc{p}_tconv"), "t", base)?;
        let hybrid = if p == 2 && ctx.artifacts.join("scc2_5_tconv").exists() {
            let h = measure(ctx, "scc2_5_tconv", "h", base)?;
            f2(h.si_snri)
        } else {
            "-".into()
        };
        t.row(vec![
            format!("S-CC {p}"),
            f2(dup.si_snri),
            f2(tc.si_snri),
            hybrid,
            f2(pdup),
            f2(ptc),
        ]);
    }
    let mut body = t.render();
    body.push_str("\nPaper's conclusion (App. E): neither method dominates; duplication wins on simplicity.\n");
    ctx.emit("table8", &body)
}

/// Table 9 / Fig. 11: extrapolation kinds, double S-CC.
pub fn table9(ctx: &Ctx) -> Result<()> {
    let base = stmc_macs_per_frame(ctx)?;
    let mut t = Table::new(
        "Table 9 — extrapolation: duplication vs transposed conv (FP)",
        &["Model", "Duplication", "Tconv", "paper dup", "paper tconv"],
    );
    for (p, pdup, ptc) in [(2usize, 6.64, 6.73), (5usize, 7.24, 7.15)] {
        let dup = measure(ctx, &format!("sscc{p}"), "d", base)?;
        let tc = measure(ctx, &format!("sscc{p}_tconv"), "t", base)?;
        t.row(vec![
            format!("SS-CC {p}"),
            f2(dup.si_snri),
            f2(tc.si_snri),
            f2(pdup),
            f2(ptc),
        ]);
    }
    ctx.emit("table9", &t.render())
}
