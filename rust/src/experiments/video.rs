//! Tables 10 & 11 — video action recognition (App. F) and ResNet ASC
//! (App. G): analytic complexity reproduction; accuracy columns quote the
//! paper (the 37 h A100 trainings are substituted per DESIGN.md §5).

use anyhow::Result;

use super::{f1, f2, Ctx, Table};
use crate::complexity::paper;
use crate::complexity::resnet;

/// Tables 10-11: video / ResNet ASC complexity-only rows.
pub fn table10_11(ctx: &Ctx) -> Result<()> {
    // ---- Table 10: video ----
    let mut t = Table::new(
        "Table 10 — video action recognition (complexity reproduction)",
        &[
            "Model", "GMAC/s (ours, reg)", "GMAC/s (ours, SOI)", "reduction %",
            "paper reg", "paper SOI", "paper acc reg", "paper acc SOI",
        ],
    );
    let fps = 24.0;
    let window = 24u64;
    let models: Vec<(&str, Box<dyn Fn(bool) -> crate::complexity::Network>)> = vec![
        ("ResNet-10", Box::new(move |s| resnet::resnet10_video(1.0, s, window, fps))),
        ("ResNet-10 small", Box::new(move |s| resnet::resnet10_video(0.5, s, window, fps))),
        ("ResNet-10 tiny", Box::new(move |s| resnet::resnet10_video(0.25, s, window, fps))),
        ("MoViNet A0", Box::new(move |s| resnet::movinet(0, s, window, fps))),
        ("MoViNet A1", Box::new(move |s| resnet::movinet(1, s, window, fps))),
    ];
    for ((label, build), &(_, pacc, preg, pacc_soi, psoi)) in
        models.iter().zip(&paper::TABLE10_VIDEO)
    {
        let reg = build(false);
        let soi = build(true);
        let g_reg = reg.mmac_per_s(reg.stmc_macs_per_frame()) / 1e3;
        let g_soi = soi.mmac_per_s(soi.soi_macs_per_frame()) / 1e3;
        t.row(vec![
            label.to_string(),
            f2(g_reg),
            f2(g_soi),
            f1(100.0 * (1.0 - g_soi / g_reg)),
            f2(preg),
            f2(psoi),
            f2(pacc),
            f2(pacc_soi),
        ]);
    }
    let mut body = t.render();
    body.push_str(
        "\nShape targets (paper App. F): ResNet-10 family reduction 10–17%, \
         MoViNet reduction 23–30%.\n\n",
    );

    // ---- Table 11: ResNet ASC ----
    let mut t11 = Table::new(
        "Table 11 — ASC with ResNet (complexity reproduction)",
        &[
            "Depth", "GMAC/s base (ours)", "GMAC/s STMC (ours)", "GMAC/s SOI (ours)",
            "SOI/STMC %", "paper STMC", "paper SOI", "params",
        ],
    );
    let window = 100u64;
    let fps = 100.0;
    for &(depth, _pbase, pstmc, psoi, _acc_stmc, _acc_soi) in &paper::TABLE11_RESNET {
        let stmc = resnet::resnet_asc(depth, false, window, fps);
        let soi = resnet::resnet_asc(depth, true, window, fps);
        let g_base = stmc.mmac_per_s(stmc.baseline_macs_per_frame()) / 1e3;
        let g_stmc = stmc.mmac_per_s(stmc.stmc_macs_per_frame()) / 1e3;
        let g_soi = soi.mmac_per_s(soi.soi_macs_per_frame()) / 1e3;
        t11.row(vec![
            depth.to_string(),
            f2(g_base),
            f2(g_stmc),
            f2(g_soi),
            f1(100.0 * g_soi / g_stmc),
            f2(pstmc),
            f2(psoi),
            format!("{:.1}M", resnet::resnet_params(depth) as f64 / 1e6),
        ]);
    }
    body.push_str(&t11.render());
    body.push_str(
        "\nPaper SOI/STMC ratios: 79.4% / 81.0% / 84.6% / 84.9% — ours must land \
         in the same band (middle-stage compression).\n",
    );
    ctx.emit("table10_11", &body)
}
