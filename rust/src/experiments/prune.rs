//! Figure 6 — pruning × SOI: unstructured global magnitude pruning swept
//! over STMC, "SOI 1" (S-CC 1) and "SOI 2|6" (2×S-CC)-style variants,
//! showing that SOI+pruning dominates pruning alone at equal complexity.

use anyhow::Result;

use super::eval::{load_variant, si_snri_with_weights};
use super::{f1, f2, Ctx, Table};
use crate::dsp::siggen;
use crate::pruning;
use crate::runtime::Weights;

/// Fig. 6: pruning sweep — SOI x global magnitude pruning compose.
pub fn fig6(ctx: &Ctx) -> Result<()> {
    let mut t = Table::new(
        "Figure 6 — pruning sweep over STMC and SOI variants",
        &[
            "Model", "pruned %", "SI-SNRi (dB)", "eff. MMAC/s (sparse)",
            "dense MMAC/s",
        ],
    );
    // paper prunes 4096 weights/step on a ~large model; ours has ~33k
    // params, so we prune 8% per step for a comparable sweep resolution.
    let models = [("stmc", "STMC"), ("scc1", "SOI 1"), ("scc2_5", "SOI 2|5")];
    for (name, label) in models {
        if !ctx.artifacts.join(name).exists() {
            continue;
        }
        let cv = load_variant(ctx, name)?;
        let fps = siggen::FS / cv.manifest.config.feat as f64;
        let dense_mmacs = cv.manifest.macs_per_frame * fps / 1e6;
        let total = cv.weights.total_params();
        let chunk = total / 12;
        let mut weights: Weights = cv.weights.clone();
        for step in 0..=6 {
            if step > 0 {
                pruning::prune_global_magnitude(&mut weights, chunk);
            }
            let (m, _) = si_snri_with_weights(ctx, &cv, &weights, ctx.n_eval, ctx.seed)?;
            let sparsity = pruning::sparsity(&weights);
            t.row(vec![
                label.to_string(),
                f1(100.0 * sparsity),
                f2(m),
                f1(pruning::effective_macs(dense_mmacs, &weights)),
                f1(dense_mmacs),
            ]);
        }
    }
    let mut body = t.render();
    body.push_str(
        "\n'eff. MMAC/s' assumes an idealized sparse kernel (zero weights cost \
         nothing); the paper's point is that SOI reaches the same effective \
         complexity without sparse kernels, and composes with pruning — compare \
         rows at equal eff. MMAC/s.\n",
    );
    ctx.emit("fig6", &body)
}
