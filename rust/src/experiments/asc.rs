//! Table 4 — acoustic scene classification with GhostNet: Baseline vs
//! STMC vs SOI across seven model sizes.
//!
//! Complexity columns are analytic for all seven sizes
//! (`complexity::ghostnet`); accuracy columns come from the build-time
//! synthetic-scene trainings (sizes I–III; `artifacts/asc_results.json`),
//! with the paper's accuracies quoted for reference.  Baseline accuracy ==
//! STMC accuracy by construction (STMC is an exact transformation).

use anyhow::{Context, Result};

use super::{f1, f2, Ctx, Table};
use crate::complexity::ghostnet;
use crate::complexity::paper;
use crate::util::json;

struct AscMeasured {
    top1: f64,
    std: f64,
}

fn load_measured(ctx: &Ctx) -> Result<Vec<(String, String, AscMeasured)>> {
    let path = ctx.artifacts.join("asc_results.json");
    if !path.exists() {
        return Ok(Vec::new());
    }
    let text = std::fs::read_to_string(&path)?;
    let v = json::parse(&text).context("parsing asc_results.json")?;
    let mut out = Vec::new();
    for e in v.req("results").map_err(anyhow::Error::from)?.as_arr().unwrap_or(&[]) {
        out.push((
            e.get("size").and_then(|s| s.as_str()).unwrap_or("?").to_string(),
            e.get("method").and_then(|s| s.as_str()).unwrap_or("?").to_string(),
            AscMeasured {
                top1: e.get("top1_mean").and_then(|x| x.as_f64()).unwrap_or(f64::NAN),
                std: e.get("top1_std").and_then(|x| x.as_f64()).unwrap_or(f64::NAN),
            },
        ));
    }
    Ok(out)
}

/// Table 4: GhostNet acoustic-scene-classification complexity rows.
pub fn table4(ctx: &Ctx) -> Result<()> {
    let measured = load_measured(ctx)?;
    let find = |size: &str, method: &str| {
        measured
            .iter()
            .find(|(s, m, _)| s == size && m == method)
            .map(|(_, _, a)| a)
    };
    let mut t = Table::new(
        "Table 4 — ASC with GhostNet: Baseline / STMC / SOI across 7 sizes",
        &[
            "Size", "Method", "top-1 % (measured)", "±", "MMAC/s", "params",
            "paper top-1 %", "paper MMAC/s",
        ],
    );
    let window = 100u64; // 1 s of 100 fps spectral frames
    let fps = 100.0;
    for (i, &(label, mult)) in ghostnet::SIZES.iter().enumerate() {
        let (_, pbase, pstmc, psoi, pacc_base, pacc_soi) = paper::TABLE4_ASC[i];
        let stmc_net = ghostnet::network(mult, false, window, fps);
        let soi_net = ghostnet::network(mult, true, window, fps);
        let rows = [
            (
                "Baseline",
                stmc_net.mmac_per_s(stmc_net.baseline_macs_per_frame()),
                ghostnet::param_count(mult, false),
                find(label, "STMC"),
                pacc_base,
                pbase,
            ),
            (
                "STMC",
                stmc_net.mmac_per_s(stmc_net.stmc_macs_per_frame()),
                ghostnet::param_count(mult, false),
                find(label, "STMC"),
                pacc_base,
                pstmc,
            ),
            (
                "SOI",
                soi_net.mmac_per_s(soi_net.soi_macs_per_frame()),
                ghostnet::param_count(mult, true),
                find(label, "SOI"),
                pacc_soi,
                psoi,
            ),
        ];
        for (method, mmacs, params, acc, pacc, pmm) in rows {
            let (a, s) = acc.map_or((f64::NAN, f64::NAN), |m| (100.0 * m.top1, 100.0 * m.std));
            t.row(vec![
                label.to_string(),
                method.to_string(),
                if a.is_nan() { "-".into() } else { f1(a) },
                if s.is_nan() { "-".into() } else { f1(s) },
                f2(mmacs),
                params.to_string(),
                f1(pacc),
                f2(pmm),
            ]);
        }
    }
    let mut body = t.render();
    body.push_str(
        "\nSizes IV–VII are complexity-only (the paper's 5×500-epoch P40 budget is \
         substituted per DESIGN.md §5); Baseline top-1 == STMC top-1 by \
         construction.  Shape targets: STMC ≈ 1000× cheaper than Baseline; SOI \
         10–20% cheaper than STMC with ~unchanged accuracy.\n",
    );
    ctx.emit("table4", &body)
}
