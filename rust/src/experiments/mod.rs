//! Experiment harness: one driver per table/figure of the paper
//! (DESIGN.md §3 experiment index).  Every driver prints a markdown table
//! (paper numbers side-by-side with ours) and writes it under `results/`.
//!
//! Conventions:
//! * Complexity columns are analytic (`complexity::*`), quality columns
//!   are measured on the synthetic substitution tasks, timing/memory
//!   columns are real measurements of this implementation.
//! * "paper" columns quote `complexity::paper` for shape comparison; we
//!   reproduce *orderings and ratios*, not absolute dB (DESIGN.md §5).

pub mod asc;
pub mod eval;
pub mod prune;
pub mod speech;
pub mod video;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::runtime::Runtime;

/// Execution context shared by all drivers.
///
/// `rt` is the backend-agnostic runtime facade: experiments run on the
/// native backend by default and on PJRT with `--features pjrt` +
/// `SOI_BACKEND=pjrt` — drivers never see the difference (DESIGN.md §4).
pub struct Ctx {
    /// Artifact root directory (variant subdirectories).
    pub artifacts: PathBuf,
    /// Output directory for rendered tables.
    pub results: PathBuf,
    /// Backend-agnostic runtime shared by every driver.
    pub rt: Arc<Runtime>,
    /// Evaluation effort (number of utterances per variant).
    pub n_eval: usize,
    /// Base RNG seed for the synthetic evaluation data.
    pub seed: u64,
}

impl Ctx {
    /// A context over an existing artifacts directory; creates `results`.
    pub fn new(artifacts: &Path, results: &Path, n_eval: usize, seed: u64) -> Result<Ctx> {
        if !artifacts.exists() {
            bail!(
                "artifacts directory {} not found — run `make artifacts` first",
                artifacts.display()
            );
        }
        std::fs::create_dir_all(results)
            .with_context(|| format!("creating {}", results.display()))?;
        Ok(Ctx {
            artifacts: artifacts.to_path_buf(),
            results: results.to_path_buf(),
            rt: Arc::new(Runtime::cpu()?),
            n_eval,
            seed,
        })
    }

    /// Write a result table to `results/<name>.md` and echo it to stdout.
    pub fn emit(&self, name: &str, body: &str) -> Result<()> {
        let path = self.results.join(format!("{name}.md"));
        std::fs::write(&path, body).with_context(|| format!("writing {}", path.display()))?;
        println!("{body}");
        println!("[written to {}]", path.display());
        Ok(())
    }
}

/// All experiments in paper order.
pub const ALL: [&str; 11] = [
    "table1", "table2", "table3", "fig6", "table4", "table5", "table6", "table7",
    "table8", "table9", "table10",
];

/// Run one experiment by name ("table11" is an alias within table10's
/// family; "all" runs everything).
pub fn run(ctx: &Ctx, name: &str) -> Result<()> {
    match name {
        "table1" | "fig4" => speech::table1(ctx),
        "table2" | "fig5" => speech::table2(ctx),
        "table3" => speech::table3(ctx),
        "fig6" => prune::fig6(ctx),
        "table4" => asc::table4(ctx),
        "table5" | "fig7" => speech::table5(ctx),
        "table6" | "fig8" => speech::table6(ctx),
        "table7" | "fig9" => speech::table7(ctx),
        "table8" | "fig10" => speech::table8(ctx),
        "table9" | "fig11" => speech::table9(ctx),
        "table10" | "table11" => video::table10_11(ctx),
        "all" => {
            for n in ALL {
                println!("\n===== {n} =====");
                run(ctx, n)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment '{other}'; known: {ALL:?} or 'all'"),
    }
}

/// Markdown table builder.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    /// An empty table with a title and column headers.
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    /// Append one row; panics when the arity differs from the header.
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render the table as aligned markdown.
    pub fn render(&self) -> String {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = format!("## {}\n\n", self.title);
        let line = |cells: &[String], w: &[usize]| {
            let mut s = String::from("|");
            for (c, width) in cells.iter().zip(w) {
                s.push_str(&format!(" {c:<width$} |"));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.header, &w));
        out.push('|');
        for width in &w {
            out.push_str(&format!("{}|", "-".repeat(width + 2)));
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r, &w));
        }
        out
    }
}

/// Format with one decimal place (table cells).
pub fn f1(v: f64) -> String {
    format!("{v:.1}")
}

/// Format with two decimal places (table cells).
pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned_markdown() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(vec!["x".into(), "1".into()]);
        let s = t.render();
        assert!(s.contains("## T"));
        assert!(s.contains("| a "));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_checks_arity() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["x".into(), "y".into()]);
    }
}
