//! Shared evaluation helpers: load a variant, run it over rust-generated
//! synthetic utterances, and report SI-SNRi — the measured quality column
//! of every speech table.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::dsp::{frames, metrics, siggen};
use crate::runtime::{CompiledVariant, DeviceWeights, Weights};
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;

use super::Ctx;

/// Load + compile one artifact variant by name.
pub fn load_variant(ctx: &Ctx, name: &str) -> Result<CompiledVariant> {
    CompiledVariant::load(ctx.rt.clone(), &ctx.artifacts.join(name))
        .with_context(|| format!("loading variant '{name}'"))
}

/// A (noisy, clean) evaluation utterance shaped for the offline artifact:
/// exactly `offline_t` frames of `feat` samples.
pub fn eval_utterance(
    rng: &mut Rng,
    feat: usize,
    t_frames: usize,
) -> (Tensor, Vec<f32>, Vec<f32>) {
    let n = feat * t_frames;
    let (noisy, clean) = siggen::denoise_pair(rng, n, siggen::FS);
    let (cols, _) = frames(&noisy, feat);
    // (feat, T) column-major frames -> row-major tensor
    let mut data = vec![0.0f32; feat * t_frames];
    for (t, col) in cols.iter().enumerate() {
        for (i, &v) in col.iter().enumerate() {
            data[i * t_frames + t] = v;
        }
    }
    (Tensor::new(vec![feat, t_frames], data), noisy, clean)
}

/// Flatten an offline output (feat, T) back to a waveform.
pub fn output_to_wave(out: &Tensor) -> Vec<f32> {
    let (feat, t) = (out.shape[0], out.shape[1]);
    let mut wave = vec![0.0f32; feat * t];
    for tt in 0..t {
        for i in 0..feat {
            wave[tt * feat + i] = out.at2(i, tt);
        }
    }
    wave
}

/// Measured SI-SNRi of a variant over `n` synthetic utterances, using the
/// offline executable (identical numerics to streaming; proven by the
/// integration tests).  Returns (mean, std).
pub fn si_snri_offline(
    cv: &CompiledVariant,
    dw: &DeviceWeights,
    n: usize,
    seed: u64,
) -> Result<(f64, f64)> {
    let feat = cv.manifest.config.feat;
    let t = cv.manifest.offline_t;
    let mut rng = Rng::new(seed);
    let mut imps = Vec::with_capacity(n);
    for _ in 0..n {
        let (x, noisy, clean) = eval_utterance(&mut rng, feat, t);
        let out = cv.offline(&x, dw)?;
        let est = output_to_wave(&out);
        let n_samp = est.len();
        imps.push(metrics::si_snr_improvement(
            &noisy[..n_samp],
            &est,
            &clean[..n_samp],
        ));
    }
    Ok(mean_std(&imps))
}

/// Same measurement but with custom (possibly pruned) weights.
pub fn si_snri_with_weights(
    ctx: &Ctx,
    cv: &CompiledVariant,
    weights: &Weights,
    n: usize,
    seed: u64,
) -> Result<(f64, f64)> {
    let dw = weights.to_device(&ctx.rt)?;
    si_snri_offline(cv, &dw, n, seed)
}

/// Mean and (population) standard deviation of a sample.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let m = xs.iter().sum::<f64>() / xs.len() as f64;
    let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
    (m, v.sqrt())
}

/// Arc-wrap a loaded variant for the serving APIs.
pub fn arced(cv: CompiledVariant) -> Arc<CompiledVariant> {
    Arc::new(cv)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[1.0, 3.0]);
        assert_eq!(m, 2.0);
        assert_eq!(s, 1.0);
    }

    #[test]
    fn eval_utterance_shapes() {
        let mut rng = Rng::new(1);
        let (x, noisy, clean) = eval_utterance(&mut rng, 8, 32);
        assert_eq!(x.shape, vec![8, 32]);
        assert_eq!(noisy.len(), 256);
        assert_eq!(clean.len(), 256);
        // column layout: x[:, 0] == noisy[0..8]
        for i in 0..8 {
            assert_eq!(x.at2(i, 0), noisy[i]);
        }
    }

    #[test]
    fn wave_roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        // columns: [1,4], [2,5], [3,6]
        assert_eq!(output_to_wave(&t), vec![1., 4., 2., 5., 3., 6.]);
    }
}
