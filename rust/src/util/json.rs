//! Minimal JSON parser/writer.
//!
//! serde is not available in this offline environment (DESIGN.md §5), so the
//! runtime parses artifact manifests with this hand-rolled recursive-descent
//! parser.  It supports the full JSON grammar (objects, arrays, strings with
//! escapes, numbers, bools, null); object key order is preserved because the
//! weights file is laid out in manifest order.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// Insertion-ordered object (order matters for weight manifests).
    Obj(Vec<(String, Json)>),
}

/// Error with byte offset into the input.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset into the parsed input (0 for accessor errors).
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- accessors --------------------------------------------------------

    /// Object field by key (None for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name (manifest fields are mandatory).
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            msg: format!("missing key '{key}'"),
            offset: 0,
        })
    }

    /// The string value, when this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, when this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as an integer, when this is a whole number.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    /// The value as a usize, when this is a whole non-negative number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    /// The boolean value, when this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, when this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// True for `null`.
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// The key/value pairs in document order, when this is an object.
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(kv) => Some(kv),
            _ => None,
        }
    }

    // ---- constructors ------------------------------------------------------

    /// An object from `(key, value)` pairs, preserving their order.
    pub fn obj(kv: Vec<(&str, Json)>) -> Json {
        Json::Obj(kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// An object from a sorted map (keys end up in map order).
    pub fn map(kv: BTreeMap<String, Json>) -> Json {
        Json::Obj(kv.into_iter().collect())
    }

    // ---- serialization ------------------------------------------------------

    /// Compact single-line serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Two-space-indented serialization with a trailing newline.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !kv.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document (must consume the whole input bar whitespace).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.i,
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected character '{}'", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.i + 2..self.i + 6)
                                        .ok_or_else(|| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(
                                        std::str::from_utf8(hex2)
                                            .map_err(|_| self.err("bad surrogate"))?,
                                        16,
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 6;
                                    let c =
                                        0x10000 + ((code - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(code)
                            };
                            s.push(ch.ok_or_else(|| self.err("invalid codepoint"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // multi-byte UTF-8: copy the remaining continuation bytes
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    let chunk = self
                        .b
                        .get(start..end)
                        .ok_or_else(|| self.err("truncated utf-8"))?;
                    s.push_str(
                        std::str::from_utf8(chunk).map_err(|_| self.err("bad utf-8"))?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_i64(), Some(1));
        assert!(arr[1].get("b").unwrap().is_null());
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"\\q\"").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        assert_eq!(parse("\"żółć\"").unwrap(), Json::Str("żółć".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"x","arr":[1,2.5,true,null],"nested":{"k":"v"}}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }
}
