//! Infrastructure substrates built in-repo because the usual crates
//! (serde, clap, rand, criterion, proptest, hdrhistogram) are unavailable
//! in this offline environment — see DESIGN.md §5.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod sha256;
pub mod stats;
pub mod tensor;
