//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and drive this module:
//! warmup, then timed iterations until both a minimum wall-time and a
//! minimum iteration count are reached; reports mean / p50 / p95 per
//! iteration.

use std::time::{Duration, Instant};

use super::stats::percentile;

/// Timing summary of one benchmarked closure.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Timed iterations taken.
    pub iters: usize,
    /// Mean wall time per iteration, ns.
    pub mean_ns: f64,
    /// Median wall time per iteration, ns.
    pub p50_ns: f64,
    /// 95th-percentile wall time per iteration, ns.
    pub p95_ns: f64,
    /// Fastest iteration, ns.
    pub min_ns: f64,
}

impl BenchResult {
    /// Iterations per second implied by the mean.
    pub fn throughput_per_sec(&self) -> f64 {
        if self.mean_ns == 0.0 {
            0.0
        } else {
            1e9 / self.mean_ns
        }
    }

    /// One-line human-readable summary.
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
        )
    }
}

/// Format a nanosecond count with a human-scale unit (ns/µs/ms/s).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark a closure: warm up for `warmup`, then time iterations until
/// `min_time` has elapsed and at least `min_iters` samples were taken.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_config(name, Duration::from_millis(200), Duration::from_secs(1), 10, &mut f)
}

/// [`bench`] with explicit warmup/min-time/min-iteration settings.
pub fn bench_config<F: FnMut()>(
    name: &str,
    warmup: Duration,
    min_time: Duration,
    min_iters: usize,
    f: &mut F,
) -> BenchResult {
    // Warmup
    let w0 = Instant::now();
    while w0.elapsed() < warmup {
        f();
    }
    // Timed
    let mut samples: Vec<f64> = Vec::new();
    let t0 = Instant::now();
    while t0.elapsed() < min_time || samples.len() < min_iters {
        let s = Instant::now();
        f();
        samples.push(s.elapsed().as_nanos() as f64);
        if samples.len() > 5_000_000 {
            break; // safety valve for ns-scale bodies
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_ns: mean,
        p50_ns: percentile(&samples, 0.50),
        p95_ns: percentile(&samples, 0.95),
        min_ns: samples[0],
    }
}

/// Prevent the optimizer from discarding a value (ports of
/// `std::hint::black_box` exist on stable now; keep an alias for clarity).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_a_closure() {
        let mut acc = 0u64;
        let r = bench_config(
            "noop",
            Duration::from_millis(1),
            Duration::from_millis(10),
            5,
            &mut || {
                acc = black_box(acc.wrapping_add(1));
            },
        );
        assert!(r.iters >= 5);
        assert!(r.mean_ns >= 0.0);
        assert!(r.p95_ns >= r.p50_ns);
    }

    #[test]
    fn formats_ns() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(1500.0).contains("µs"));
        assert!(fmt_ns(2.5e6).contains("ms"));
        assert!(fmt_ns(3.0e9).contains(" s"));
    }
}
