//! Hand-rolled property-test harness (proptest is unavailable offline).
//!
//! `check` runs a property over `n` seeded random cases; on failure it
//! reports the failing case index and seed so the case can be replayed
//! deterministically with `replay`.

use super::rng::Rng;

/// Run `prop(rng, case_index)` for `n` cases; panic with the seed on the
/// first failure (the property should panic or return Err to fail).
pub fn check<F>(name: &str, n: usize, base_seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..n {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, case) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}\n\
                 replay with prop::replay({seed:#x}, ...)"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F>(seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    prop(&mut rng, 0).expect("replayed property still failing");
}

/// Assert two f64 are within rtol/atol (helper for numeric properties).
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> Result<(), String> {
    let diff = (a - b).abs();
    let bound = atol + rtol * b.abs().max(a.abs());
    if diff <= bound {
        Ok(())
    } else {
        Err(format!("|{a} - {b}| = {diff} > {bound}"))
    }
}

/// Assert two f32 slices are element-wise close.
pub fn slices_close(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let diff = (x - y).abs();
        let bound = atol + rtol * y.abs().max(x.abs());
        if diff > bound {
            return Err(format!("at [{i}]: |{x} - {y}| = {diff} > {bound}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("u64 parity", 50, 1, |rng, _| {
            let v = rng.next_u64();
            if v % 2 == 0 || v % 2 == 1 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failures() {
        check("always fails", 5, 2, |_, _| Err("nope".into()));
    }

    #[test]
    fn close_helper() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6, 0.0).is_ok());
        assert!(close(1.0, 2.0, 1e-6, 0.0).is_err());
    }

    #[test]
    fn slices_close_helper() {
        assert!(slices_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-6).is_ok());
        assert!(slices_close(&[1.0], &[1.0, 2.0], 1e-5, 1e-6).is_err());
        assert!(slices_close(&[1.0], &[1.5], 1e-5, 1e-6).is_err());
    }
}
