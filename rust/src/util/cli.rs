//! Tiny argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments.  Each experiment driver declares its options up front so
//! `--help` output stays accurate.

use std::collections::BTreeMap;

/// Parsed command line: `--key value` flags plus positionals.
#[derive(Debug, Clone)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse a raw argv (without the program name).
    ///
    /// `bool_flags` lists options that take no value.
    pub fn parse(argv: &[String], bool_flags: &[&str]) -> Result<Args, String> {
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&stripped) {
                    flags.insert(stripped.to_string(), "true".to_string());
                } else {
                    i += 1;
                    let v = argv
                        .get(i)
                        .ok_or_else(|| format!("--{stripped} expects a value"))?;
                    flags.insert(stripped.to_string(), v.clone());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { flags, positional })
    }

    /// Positional arguments in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Raw value of `--key`, when given.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// String value of `--key`, or `default`.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Integer value of `--key`, or `default`; errors on non-integers.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad integer '{v}'")),
        }
    }

    /// `u64` value of `--key`, or `default`; errors on non-integers.
    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad integer '{v}'")),
        }
    }

    /// Float value of `--key`, or `default`; errors on non-floats.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad float '{v}'")),
        }
    }

    /// True when the boolean `--key` flag was passed.
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_kv_and_positional() {
        let a = Args::parse(&argv(&["run", "--n", "5", "--mode=fast", "x"]), &[]).unwrap();
        assert_eq!(a.positional(), &["run", "x"]);
        assert_eq!(a.usize_or("n", 0).unwrap(), 5);
        assert_eq!(a.str_or("mode", ""), "fast");
    }

    #[test]
    fn bool_flags() {
        let a = Args::parse(&argv(&["--verbose", "--n", "2"]), &["verbose"]).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 2);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&argv(&["--n"]), &[]).is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(&argv(&["--n", "abc"]), &[]).unwrap();
        assert!(a.usize_or("n", 0).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&[], &[]).unwrap();
        assert_eq!(a.usize_or("n", 7).unwrap(), 7);
        assert_eq!(a.f64_or("x", 1.5).unwrap(), 1.5);
        assert!(!a.flag("v"));
    }
}
