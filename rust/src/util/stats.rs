//! Latency/throughput statistics: online summaries and fixed-bucket
//! histograms (hdrhistogram is unavailable offline; this log-bucketed
//! histogram gives <1% quantile error over the ns..s range, which is all
//! the serving benches need).

/// Online mean/min/max/count accumulator.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    /// Number of samples added.
    pub count: u64,
    /// Sum of all samples.
    pub sum: f64,
    /// Smallest sample seen (`+inf` when empty).
    pub min: f64,
    /// Largest sample seen (`-inf` when empty).
    pub max: f64,
}

impl Summary {
    /// An empty accumulator.
    pub fn new() -> Self {
        Summary {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold one sample in.
    pub fn add(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Mean of the samples (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

/// Log-bucketed histogram over (0, ~18e18) ns with ~1% resolution.
///
/// Buckets: 64 octaves x `SUB` log-linear sub-buckets per octave.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
}

const SUB: usize = 128; // sub-buckets per power of two => <0.8% bucket width

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Histogram {
            counts: vec![0; 64 * SUB],
            total: 0,
        }
    }

    fn index(v: u64) -> usize {
        if v == 0 {
            return 0;
        }
        let oct = 63 - v.leading_zeros() as usize;
        let sub = if oct == 0 {
            0
        } else {
            // position within the octave, scaled to SUB
            ((v - (1 << oct)) as u128 * SUB as u128 >> oct) as usize
        };
        (oct * SUB + sub).min(64 * SUB - 1)
    }

    fn bucket_value(idx: usize) -> u64 {
        let oct = idx / SUB;
        let sub = idx % SUB;
        let base = 1u64 << oct;
        base + ((base as u128 * sub as u128) / SUB as u128) as u64
    }

    /// Record one sample.
    pub fn record(&mut self, v: u64) {
        self.counts[Self::index(v)] += 1;
        self.total += 1;
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Value at quantile q in [0, 1].
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0)) * self.total as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bucket_value(i);
            }
        }
        Self::bucket_value(64 * SUB - 1)
    }

    /// Median sample value.
    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }
    /// 95th-percentile sample value.
    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }
    /// 99th-percentile sample value.
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Approximate mean (bucket midpoint weighted; 0 when empty).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let mut s = 0.0;
        for (i, c) in self.counts.iter().enumerate() {
            if *c > 0 {
                s += Self::bucket_value(i) as f64 * *c as f64;
            }
        }
        s / self.total as f64
    }

    /// Fold another histogram's samples into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
    }
}

/// Exact percentile over a collected sample (for small benches).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for v in [1.0, 2.0, 3.0] {
            s.add(v);
        }
        assert_eq!(s.count, 3);
        assert_eq!(s.mean(), 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn histogram_quantiles_accurate() {
        let mut h = Histogram::new();
        for v in 1..=10_000u64 {
            h.record(v * 1000); // 1µs .. 10ms in ns
        }
        let p50 = h.p50() as f64;
        assert!((p50 - 5_000_000.0).abs() / 5_000_000.0 < 0.02, "p50={p50}");
        let p99 = h.p99() as f64;
        assert!((p99 - 9_900_000.0).abs() / 9_900_000.0 < 0.02, "p99={p99}");
    }

    #[test]
    fn histogram_mean_close() {
        let mut h = Histogram::new();
        for v in [100u64, 200, 300, 400] {
            h.record(v);
        }
        assert!((h.mean() - 250.0).abs() / 250.0 < 0.02);
    }

    #[test]
    fn histogram_zero_and_extremes() {
        let mut h = Histogram::new();
        h.record(0);
        h.record(u64::MAX);
        assert_eq!(h.count(), 2);
        assert!(h.quantile(0.0) <= 1);
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(10);
        b.record(1000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }

    #[test]
    fn exact_percentile() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
        assert_eq!(percentile(&v, 0.5), 2.5);
    }
}
