//! Latency/throughput statistics: online summaries and fixed-bucket
//! histograms (hdrhistogram is unavailable offline; this log-bucketed
//! histogram gives <1% quantile error over the ns..s range, which is all
//! the serving benches need).

/// Online mean/min/max/count accumulator.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    /// Number of samples added.
    pub count: u64,
    /// Sum of all samples.
    pub sum: f64,
    /// Smallest sample seen (`+inf` when empty).
    pub min: f64,
    /// Largest sample seen (`-inf` when empty).
    pub max: f64,
}

impl Summary {
    /// An empty accumulator.
    pub fn new() -> Self {
        Summary {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold one sample in.
    pub fn add(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Mean of the samples (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

/// Log-bucketed histogram over (0, ~18e18) ns with ~1% resolution.
///
/// Buckets: 64 octaves x `SUB` log-linear sub-buckets per octave.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
}

const SUB: usize = 128; // sub-buckets per power of two => <0.8% bucket width

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Total number of buckets (`64` octaves × `SUB` sub-buckets).
    pub const BUCKETS: usize = 64 * SUB;

    /// An empty histogram.
    pub fn new() -> Self {
        Histogram {
            counts: vec![0; Self::BUCKETS],
            total: 0,
        }
    }

    fn index(v: u64) -> usize {
        if v == 0 {
            return 0;
        }
        let oct = 63 - v.leading_zeros() as usize;
        let sub = if oct == 0 {
            0
        } else {
            // position within the octave, scaled to SUB
            ((v - (1 << oct)) as u128 * SUB as u128 >> oct) as usize
        };
        (oct * SUB + sub).min(64 * SUB - 1)
    }

    fn bucket_value(idx: usize) -> u64 {
        let oct = idx / SUB;
        let sub = idx % SUB;
        let base = 1u64 << oct;
        base + ((base as u128 * sub as u128) / SUB as u128) as u64
    }

    /// Representative value of bucket `idx` (the bucket's lower bound;
    /// the same value `quantile` reports when the quantile lands there).
    /// Exposed so exported sparse buckets can be re-ingested losslessly
    /// via [`Histogram::add_bucket`].
    pub fn bucket_bound(idx: usize) -> u64 {
        Self::bucket_value(idx.min(Self::BUCKETS - 1))
    }

    /// Record one sample.
    pub fn record(&mut self, v: u64) {
        self.counts[Self::index(v)] += 1;
        self.total += 1;
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Count in bucket `idx` (0 for out-of-range indices).
    pub fn count_at(&self, idx: usize) -> u64 {
        self.counts.get(idx).copied().unwrap_or(0)
    }

    /// Add `count` samples directly into bucket `idx` — the inverse of
    /// [`Histogram::nonzero`], used to reconstruct a histogram from an
    /// exported sparse bucket list.  Reconstruction is exact: bucket
    /// indices round-trip, so quantiles and counts are identical.
    pub fn add_bucket(&mut self, idx: usize, count: u64) {
        self.counts[idx.min(Self::BUCKETS - 1)] += count;
        self.total += count;
    }

    /// Iterate `(bucket_index, count)` over non-empty buckets, in
    /// ascending value order.  Allocation-free; the sparse form is what
    /// the NDJSON health feed serializes.
    pub fn nonzero(&self) -> impl Iterator<Item = (usize, u64)> + '_ {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, c)| **c > 0)
            .map(|(i, c)| (i, *c))
    }

    /// Forget all samples, keeping the allocation (epoch rotation in
    /// `obs::RollingHist` reuses buffers this way).
    pub fn clear(&mut self) {
        self.counts.iter_mut().for_each(|c| *c = 0);
        self.total = 0;
    }

    /// Value at quantile q in [0, 1].
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0)) * self.total as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bucket_value(i);
            }
        }
        Self::bucket_value(64 * SUB - 1)
    }

    /// Median sample value.
    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }
    /// 95th-percentile sample value.
    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }
    /// 99th-percentile sample value.
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Approximate mean (bucket midpoint weighted; 0 when empty).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let mut s = 0.0;
        for (i, c) in self.counts.iter().enumerate() {
            if *c > 0 {
                s += Self::bucket_value(i) as f64 * *c as f64;
            }
        }
        s / self.total as f64
    }

    /// Fold another histogram's samples into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
    }
}

/// Exact percentile over a collected sample (for small benches).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for v in [1.0, 2.0, 3.0] {
            s.add(v);
        }
        assert_eq!(s.count, 3);
        assert_eq!(s.mean(), 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn histogram_quantiles_accurate() {
        let mut h = Histogram::new();
        for v in 1..=10_000u64 {
            h.record(v * 1000); // 1µs .. 10ms in ns
        }
        let p50 = h.p50() as f64;
        assert!((p50 - 5_000_000.0).abs() / 5_000_000.0 < 0.02, "p50={p50}");
        let p99 = h.p99() as f64;
        assert!((p99 - 9_900_000.0).abs() / 9_900_000.0 < 0.02, "p99={p99}");
    }

    #[test]
    fn histogram_mean_close() {
        let mut h = Histogram::new();
        for v in [100u64, 200, 300, 400] {
            h.record(v);
        }
        assert!((h.mean() - 250.0).abs() / 250.0 < 0.02);
    }

    #[test]
    fn histogram_zero_and_extremes() {
        let mut h = Histogram::new();
        h.record(0);
        h.record(u64::MAX);
        assert_eq!(h.count(), 2);
        assert!(h.quantile(0.0) <= 1);
    }

    #[test]
    fn histogram_sparse_round_trip_is_exact() {
        let mut h = Histogram::new();
        for v in [0u64, 1, 999, 1_000_000, u64::MAX] {
            h.record(v);
            h.record(v);
        }
        let mut r = Histogram::new();
        for (idx, c) in h.nonzero() {
            r.add_bucket(idx, c);
        }
        assert_eq!(r.count(), h.count());
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(r.quantile(q), h.quantile(q));
        }
        for i in 0..Histogram::BUCKETS {
            assert_eq!(r.count_at(i), h.count_at(i));
        }
        r.clear();
        assert_eq!(r.count(), 0);
        assert_eq!(r.quantile(0.99), 0);
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(10);
        b.record(1000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }

    #[test]
    fn exact_percentile() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
        assert_eq!(percentile(&v, 0.5), 2.5);
    }
}
