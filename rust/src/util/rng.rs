//! Deterministic xorshift/SplitMix RNG.
//!
//! The `rand` crate is unavailable offline; this is the standard
//! splitmix64 + xoshiro256++ combination — deterministic, seedable, good
//! enough for synthetic-signal generation and property-test case
//! generation (it is *not* cryptographic).

/// xoshiro256++ seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// A generator whose whole stream is determined by `seed`.
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed into the 256-bit state
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Next raw 64-bit output of the generator.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-12 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fork a stream-independent child RNG (for per-stream generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
