//! Minimal dense f32 tensor (host-side) used for weights, states and
//! frames flowing between the coordinator and the PJRT runtime.

/// Row-major dense f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Dimension sizes, outermost first.
    pub shape: Vec<usize>,
    /// Elements, flattened row-major.
    pub data: Vec<f32>,
}

impl Tensor {
    /// A tensor from parts; panics when `data` does not fill `shape`.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match {} elements",
            shape,
            data.len()
        );
        Tensor { shape, data }
    }

    /// An all-zero tensor of the given shape.
    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![0.0; n],
        }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Size of the payload in bytes (f32 elements).
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// 2-D accessor (row-major).
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// 2-D setter (row-major).
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j] = v;
    }
}

/// Read little-endian f32s from raw bytes.
pub fn f32s_from_le_bytes(bytes: &[u8]) -> Vec<f32> {
    assert_eq!(bytes.len() % 4, 0, "byte length not a multiple of 4");
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Write f32s as little-endian raw bytes.
pub fn f32s_to_le_bytes(vals: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checked() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.bytes(), 24);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn accessors() {
        let mut t = Tensor::zeros(vec![2, 3]);
        t.set2(1, 2, 5.0);
        assert_eq!(t.at2(1, 2), 5.0);
        assert_eq!(t.at2(0, 0), 0.0);
    }

    #[test]
    fn le_roundtrip() {
        let vals = vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE];
        let bytes = f32s_to_le_bytes(&vals);
        assert_eq!(f32s_from_le_bytes(&bytes), vals);
    }
}
