//! U-Net architecture descriptor — mirrors `python/compile/model.py`'s
//! topology exactly (cross-checked against the `layer_macs` table every
//! artifact manifest embeds; see `tests/complexity_cross_check.rs`).

use super::{LayerCost, Network};
use crate::runtime::ModelConfig;

/// Frames per second at 16 kHz with `feat` samples per frame.
pub fn frame_rate(feat: usize, sample_rate: f64) -> f64 {
    sample_rate / feat as f64
}

fn r_out(cfg: &ModelConfig, l: usize) -> u64 {
    1u64 << cfg.scc.iter().filter(|&&p| p <= l).count()
}

fn enc_in_ch(cfg: &ModelConfig, l: usize) -> usize {
    if l == 1 {
        cfg.feat
    } else {
        cfg.channels[l - 2]
    }
}

fn enc_out_ch(cfg: &ModelConfig, l: usize) -> usize {
    cfg.channels[l - 1]
}

fn dec_out_ch(cfg: &ModelConfig, l: usize) -> usize {
    cfg.channels[l.saturating_sub(2).max(0)]
}

fn dec_in_ch(cfg: &ModelConfig, l: usize) -> usize {
    let d = cfg.depth();
    if l == d {
        cfg.channels[d - 1]
    } else {
        dec_out_ch(cfg, l + 1) + cfg.channels[l - 1]
    }
}

fn extrap_of(cfg: &ModelConfig, p: usize) -> &str {
    cfg.scc
        .iter()
        .position(|&q| q == p)
        .map(|i| cfg.extrap[i].as_str())
        .unwrap_or("duplicate")
}

/// Build the cost model for one SOI U-Net variant.
///
/// `window_len` (Baseline recompute length) is the layer's output-domain
/// length for a `window_frames`-frame input buffer.
pub fn network(cfg: &ModelConfig, window_frames: u64, fps: f64) -> Network {
    let depth = cfg.depth();
    let s = cfg.shift_pos;
    let delayed_enc = |l: usize| s.map_or(false, |sp| l >= sp);
    let delayed_dec = |l: usize| s.map_or(false, |sp| l >= sp);
    let mut layers = Vec::new();

    for l in 1..=depth {
        layers.push(LayerCost {
            name: format!("enc{l}"),
            macs_per_out: (enc_in_ch(cfg, l) * enc_out_ch(cfg, l) * cfg.kernel) as u64,
            rate_div: r_out(cfg, l),
            window_len: window_frames / r_out(cfg, l),
            delayed: delayed_enc(l),
        });
    }
    for l in (1..=depth).rev() {
        layers.push(LayerCost {
            name: format!("dec{l}"),
            macs_per_out: (dec_in_ch(cfg, l) * dec_out_ch(cfg, l) * cfg.kernel) as u64,
            rate_div: r_out(cfg, l),
            window_len: window_frames / r_out(cfg, l),
            delayed: delayed_dec(l),
        });
    }
    for &p in &cfg.scc {
        if extrap_of(cfg, p) == "tconv" {
            layers.push(LayerCost {
                name: format!("up{p}"),
                macs_per_out: (dec_out_ch(cfg, p) * dec_out_ch(cfg, p) * 2) as u64,
                rate_div: r_out(cfg, p),
                window_len: window_frames / r_out(cfg, p),
                delayed: delayed_dec(p),
            });
        }
    }
    layers.push(LayerCost {
        name: "head".into(),
        macs_per_out: (dec_out_ch(cfg, 1) * cfg.feat) as u64,
        rate_div: 1,
        window_len: window_frames,
        delayed: s == Some(1),
    });

    Network {
        name: "unet".into(),
        layers,
        frame_rate: fps,
    }
}

/// Convenience: the default artifact config (feat 16, 7 layers, 16 kHz).
pub fn default_config(scc: Vec<usize>, shift_pos: Option<usize>) -> ModelConfig {
    ModelConfig {
        feat: 16,
        channels: vec![12, 16, 20, 24, 28, 32, 40],
        kernel: 3,
        extrap: vec!["duplicate".into(); scc.len()],
        scc,
        shift_pos,
        shift: 1,
        interp: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fps() -> f64 {
        frame_rate(16, 16_000.0)
    }

    #[test]
    fn stmc_equals_soi_without_scc() {
        let n = network(&default_config(vec![], None), 256, fps());
        assert_eq!(n.stmc_macs_per_frame(), n.soi_macs_per_frame());
    }

    #[test]
    fn scc_halves_deep_layers() {
        let n0 = network(&default_config(vec![], None), 256, fps());
        let n1 = network(&default_config(vec![1], None), 256, fps());
        // S-CC 1 halves everything except the head
        let head: f64 = 12.0 * 16.0;
        let expected = (n0.stmc_macs_per_frame() - head) / 2.0 + head;
        assert!((n1.soi_macs_per_frame() - expected).abs() < 1e-9);
    }

    #[test]
    fn deeper_scc_retains_more() {
        let fps = fps();
        let mut prev = 0.0;
        for p in 1..=7 {
            let n = network(&default_config(vec![p], None), 256, fps);
            let r = n.soi_retain_pct();
            assert!(r > prev, "retain must grow with p: {r} at {p}");
            assert!(r < 100.0);
            prev = r;
        }
    }

    #[test]
    fn double_scc_compounds() {
        // retain(p, q) == 1 - (h(p) - h(q))/2 - 3 h(q)/4  (DESIGN.md §3)
        let fps = fps();
        let h = |p: usize| {
            let n = network(&default_config(vec![p], None), 256, fps);
            2.0 * (1.0 - n.soi_retain_pct() / 100.0)
        };
        for (p, q) in [(1usize, 3usize), (2, 5), (5, 7)] {
            let n = network(&default_config(vec![p, q], None), 256, fps);
            let got = n.soi_retain_pct() / 100.0;
            let want = 1.0 - (h(p) - h(q)) / 2.0 - 0.75 * h(q);
            assert!(
                (got - want).abs() < 1e-9,
                "compound rule broken at ({p},{q}): {got} vs {want}"
            );
        }
    }

    #[test]
    fn sscc_precomputed_matches_h() {
        // Precomputed % of SS-CC p == h(p) of the same S-CC position
        let fps = fps();
        for p in [2usize, 5, 7] {
            let pp = network(&default_config(vec![p], None), 256, fps);
            let h = 2.0 * (1.0 - pp.soi_retain_pct() / 100.0);
            let f = network(&default_config(vec![p], Some(p)), 256, fps);
            assert!(
                (f.precomputed_pct() / 100.0 - h).abs() < 1e-9,
                "SS-CC {p}: precomp {} vs h {h}",
                f.precomputed_pct() / 100.0
            );
        }
    }

    #[test]
    fn predictive_is_fully_precomputed() {
        let n = network(&default_config(vec![], Some(1)), 256, fps());
        assert!((n.precomputed_pct() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn baseline_dominates_stmc() {
        let n = network(&default_config(vec![], None), 256, fps());
        assert!(n.baseline_macs_per_frame() > 100.0 * n.stmc_macs_per_frame());
    }

    #[test]
    fn tconv_adds_cost() {
        let mut cfg = default_config(vec![3], None);
        let dup = network(&cfg, 256, fps());
        cfg.extrap = vec!["tconv".into()];
        let tc = network(&cfg, 256, fps());
        assert!(tc.soi_macs_per_frame() > dup.soi_macs_per_frame());
    }
}
