//! ResNet / MoViNet cost descriptors (paper App. F Table 10 and App. G
//! Table 11).
//!
//! * `resnet_asc` — 1-D streaming adaptations of ResNet-18/34/50/101 for
//!   acoustic scene classification (Table 11).  Basic blocks for 18/34,
//!   bottlenecks for 50/101.
//! * `resnet10_video` / `movinet` — 3-D (2+1D-style) video descriptors for
//!   Table 10; the time axis is the streaming axis, spatial convs count
//!   into `macs_per_out`.
//!
//! SOI placement follows the paper: ResNet ASC optimizes the middle stage;
//! video ResNet-10 optimizes block 3; MoViNets optimize blocks 4 and 5.

use super::{LayerCost, Network};

/// Stage widths of the classic ResNets.
const STAGE_CH: [usize; 4] = [64, 128, 256, 512];

/// Blocks per stage for each depth.
fn stage_blocks(depth: usize) -> ([usize; 4], bool) {
    match depth {
        18 => ([2, 2, 2, 2], false),
        34 => ([3, 4, 6, 3], false),
        50 => ([3, 4, 6, 3], true),
        101 => ([3, 4, 23, 3], true),
        _ => panic!("unsupported resnet depth {depth}"),
    }
}

/// MACs of one residual block producing one output frame with stage width
/// `c` (1-D over time, kernel 3).  Basic blocks are two 3-convs at width
/// `c`; bottlenecks follow the standard 4x expansion (block I/O channels
/// are `4c`, the 3-conv runs at `c`): 1x1 reduce + 3 conv + 1x1 expand.
fn block_macs(c: usize, bottleneck: bool) -> u64 {
    if bottleneck {
        ((4 * c * c) + (c * c * 3) + (c * 4 * c)) as u64
    } else {
        (c * c * 3 + c * c * 3) as u64
    }
}

/// Table 11 networks: 1-D streaming ResNet for ASC.
///
/// `soi`: compress before stage 3, extrapolate after it (the middle-stage
/// optimization the paper applies).
pub fn resnet_asc(depth: usize, soi: bool, window_frames: u64, fps: f64) -> Network {
    let (blocks, bottleneck) = stage_blocks(depth);
    let mut layers = Vec::new();
    // stem
    layers.push(LayerCost {
        name: "stem".into(),
        macs_per_out: (20 * 64 * 7) as u64,
        rate_div: 1,
        window_len: window_frames,
        delayed: false,
    });
    for (s, &nb) in blocks.iter().enumerate() {
        let c = STAGE_CH[s];
        // paper optimizes the 3rd stage (index 2)
        let compressed = soi && s == 2;
        let rate_div = if compressed { 2 } else { 1 };
        for b in 0..nb {
            layers.push(LayerCost {
                name: format!("s{s}b{b}"),
                macs_per_out: block_macs(c, bottleneck),
                rate_div,
                window_len: window_frames / rate_div,
                delayed: false,
            });
        }
    }
    layers.push(LayerCost {
        name: "head".into(),
        macs_per_out: (512 * 10) as u64,
        rate_div: 1,
        window_len: 1,
        delayed: false,
    });
    Network {
        name: format!("resnet{depth}{}", if soi { "-soi" } else { "" }),
        layers,
        frame_rate: fps,
    }
}

/// Table 11 parameter counts (from the paper; architecture-determined, not
/// affected by SOI there).
pub fn resnet_params(depth: usize) -> u64 {
    match depth {
        18 => 11_700_000,
        34 => 21_800_000,
        50 => 25_600_000,
        101 => 44_500_000,
        _ => panic!("unsupported resnet depth {depth}"),
    }
}

/// Table 10: 3-D ResNet-10 for video (channel multiplier 1.0 / 0.5 / 0.25
/// for regular / small / tiny).  `macs_per_out` counts a whole spatial
/// feature map per time step (112x112 input, halving per stage).
pub fn resnet10_video(ch_mult: f64, soi: bool, window_frames: u64, fps: f64) -> Network {
    let widths = [64usize, 128, 256, 512];
    let spatial = [784usize, 196, 49, 16]; // (112/4)^2 etc. per stage
    let mut layers = Vec::new();
    layers.push(LayerCost {
        name: "stem".into(),
        macs_per_out: (3 * 64 * 49) as u64 * 3136,
        rate_div: 1,
        window_len: window_frames,
        delayed: false,
    });
    for s in 0..4 {
        let c = ((widths[s] as f64 * ch_mult) as usize).max(4);
        // SOI optimizes block 3 (stage index 2)
        let compressed = soi && s == 2;
        let rate_div = if compressed { 2 } else { 1 };
        // one basic block (two 3x3x3 convs) per stage in ResNet-10
        layers.push(LayerCost {
            name: format!("block{}", s + 1),
            macs_per_out: (2 * c * c * 27) as u64 * spatial[s] as u64,
            rate_div,
            window_len: window_frames / rate_div,
            delayed: false,
        });
    }
    layers.push(LayerCost {
        name: "head".into(),
        macs_per_out: (512.0 * ch_mult) as u64 * 51,
        rate_div: 1,
        window_len: 1,
        delayed: false,
    });
    Network {
        name: format!("resnet10-video x{ch_mult}"),
        layers,
        frame_rate: fps,
    }
}

/// Table 10: MoViNet A0/A1 approximation (5 block groups; SOI optimizes
/// groups 4 and 5, giving the paper's larger 23-30% reduction).
pub fn movinet(variant: usize, soi: bool, window_frames: u64, fps: f64) -> Network {
    let (widths, spatial): (&[usize], &[usize]) = match variant {
        0 => (&[16, 24, 48, 88, 144], &[3136, 784, 196, 196, 49]),
        1 => (&[24, 40, 64, 112, 184], &[3136, 784, 196, 196, 49]),
        _ => panic!("unsupported movinet variant A{variant}"),
    };
    let mut layers = Vec::new();
    let mut c_in = 3;
    for (g, (&c, &sp)) in widths.iter().zip(spatial).enumerate() {
        let compressed = soi && g >= 3; // blocks 4 and 5
        let rate_div = if compressed { 2 } else { 1 };
        layers.push(LayerCost {
            name: format!("g{}", g + 1),
            macs_per_out: (c_in * c * 9 + c * c * 9) as u64 * sp as u64,
            rate_div,
            window_len: window_frames / rate_div,
            delayed: false,
        });
        c_in = c;
    }
    layers.push(LayerCost {
        name: "head".into(),
        macs_per_out: (c_in * 51) as u64,
        rate_div: 1,
        window_len: 1,
        delayed: false,
    });
    Network {
        name: format!("movinet-a{variant}"),
        layers,
        frame_rate: fps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet_asc_soi_saves_10_to_25_pct() {
        for depth in [18usize, 34, 50, 101] {
            let stmc = resnet_asc(depth, false, 100, 100.0);
            let soi = resnet_asc(depth, true, 100, 100.0);
            let ratio = soi.soi_macs_per_frame() / stmc.stmc_macs_per_frame();
            assert!(
                (0.60..0.95).contains(&ratio),
                "resnet{depth}: ratio {ratio}"
            );
        }
    }

    #[test]
    fn resnet_depths_monotone() {
        let mut prev = 0.0;
        for depth in [18usize, 34, 50, 101] {
            let c = resnet_asc(depth, false, 100, 100.0).stmc_macs_per_frame();
            assert!(c > prev, "resnet{depth}");
            prev = c;
        }
    }

    #[test]
    fn video_soi_reduction_matches_paper_band() {
        // paper: 10-17% for ResNet-10 family
        for m in [1.0, 0.5, 0.25] {
            let reg = resnet10_video(m, false, 24, 24.0);
            let soi = resnet10_video(m, true, 24, 24.0);
            let red = 1.0 - soi.soi_macs_per_frame() / reg.stmc_macs_per_frame();
            assert!((0.05..0.30).contains(&red), "x{m}: reduction {red}");
        }
    }

    #[test]
    fn movinet_soi_reduction_larger_than_resnet10() {
        let r_red = {
            let reg = resnet10_video(1.0, false, 24, 24.0);
            let soi = resnet10_video(1.0, true, 24, 24.0);
            1.0 - soi.soi_macs_per_frame() / reg.stmc_macs_per_frame()
        };
        let m_red = {
            let reg = movinet(0, false, 24, 24.0);
            let soi = movinet(0, true, 24, 24.0);
            1.0 - soi.soi_macs_per_frame() / reg.stmc_macs_per_frame()
        };
        assert!(m_red > r_red, "movinet {m_red} vs resnet {r_red}");
    }

    #[test]
    fn movinet_a1_bigger_than_a0() {
        let a0 = movinet(0, false, 24, 24.0).stmc_macs_per_frame();
        let a1 = movinet(1, false, 24, 24.0).stmc_macs_per_frame();
        assert!(a1 > a0);
    }
}
