//! Analytic computational-complexity engine (MAC accounting).
//!
//! Reproduces the complexity columns of every table in the paper.  A
//! network is a list of [`LayerCost`] entries; the three inference methods
//! of the paper map to three ways of accumulating them:
//!
//! * **Baseline** — the offline model is re-run over its whole input
//!   window at every inference (the paper's GhostNet "Baseline" rows):
//!   each layer recomputes `window_len` output frames per inference.
//! * **STMC** — incremental inference: every layer computes exactly one
//!   new output frame per inference (window cost 1).
//! * **SOI** — STMC plus the scattered schedule: a layer below `k`
//!   compression stages computes a new frame only every `2^k` inferences,
//!   so its average cost is divided by `rate_div`.
//!
//! The engine is validated two ways (DESIGN.md §3): against the paper's
//! own closed-form identities (`paper::` module) and against the
//! `layer_macs` tables the python side embeds in every artifact manifest.

pub mod ghostnet;
pub mod paper;
pub mod resnet;
pub mod unet;

/// Cost of one layer of a streaming network.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerCost {
    /// Layer label ("enc3", "dec1", "head", ...).
    pub name: String,
    /// MACs to produce one output frame in the layer's own rate domain.
    pub macs_per_out: u64,
    /// SOI rate divisor: the layer computes a new frame every `rate_div`
    /// input frames (1 for layers above the first compression stage).
    pub rate_div: u64,
    /// Output frames recomputed per inference under Baseline (offline
    /// re-run) — the length of the layer's output window.
    pub window_len: u64,
    /// True when the layer belongs to the FP-delayed region (depends only
    /// on past data and is precomputable).
    pub delayed: bool,
}

/// A whole network plus its inference rate.
#[derive(Debug, Clone)]
pub struct Network {
    /// Network label ("unet", "ghostnet-III", ...).
    pub name: String,
    /// Per-layer costs, in forward order.
    pub layers: Vec<LayerCost>,
    /// Inferences per second (frame rate of the input).
    pub frame_rate: f64,
}

impl Network {
    /// Average MACs per inference under STMC (every layer incremental).
    pub fn stmc_macs_per_frame(&self) -> f64 {
        self.layers.iter().map(|l| l.macs_per_out as f64).sum()
    }

    /// Average MACs per inference under the SOI schedule.
    pub fn soi_macs_per_frame(&self) -> f64 {
        self.layers
            .iter()
            .map(|l| l.macs_per_out as f64 / l.rate_div as f64)
            .sum()
    }

    /// MACs per inference when the offline model recomputes its window.
    pub fn baseline_macs_per_frame(&self) -> f64 {
        self.layers
            .iter()
            .map(|l| (l.macs_per_out * l.window_len) as f64)
            .sum()
    }

    /// Convert MACs/frame to the paper's MMAC/s unit.
    pub fn mmac_per_s(&self, macs_per_frame: f64) -> f64 {
        macs_per_frame * self.frame_rate / 1e6
    }

    /// SOI complexity retention vs STMC, in percent (the paper's
    /// "Complexity retain" column).
    pub fn soi_retain_pct(&self) -> f64 {
        100.0 * self.soi_macs_per_frame() / self.stmc_macs_per_frame()
    }

    /// The paper's "Precomputed %": the fraction of the *network* (at full
    /// rate, i.e. of the original STMC cost) that depends on past data
    /// only.  Table 2's published rows equal the halved-cost fraction
    /// `h(shift_pos)`, which is exactly this full-rate definition — not a
    /// fraction of the reduced SOI average.
    pub fn precomputed_pct(&self) -> f64 {
        let total = self.stmc_macs_per_frame();
        if total == 0.0 {
            return 0.0;
        }
        let pre: f64 = self
            .layers
            .iter()
            .filter(|l| l.delayed)
            .map(|l| l.macs_per_out as f64)
            .sum();
        100.0 * pre / total
    }

    /// Number of layers in the cost model.
    pub fn total_layers(&self) -> usize {
        self.layers.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Network {
        Network {
            name: "toy".into(),
            frame_rate: 100.0,
            layers: vec![
                LayerCost {
                    name: "a".into(),
                    macs_per_out: 100,
                    rate_div: 1,
                    window_len: 10,
                    delayed: false,
                },
                LayerCost {
                    name: "b".into(),
                    macs_per_out: 300,
                    rate_div: 2,
                    window_len: 10,
                    delayed: true,
                },
            ],
        }
    }

    #[test]
    fn stmc_sums_all_layers() {
        assert_eq!(toy().stmc_macs_per_frame(), 400.0);
    }

    #[test]
    fn soi_divides_by_rate() {
        assert_eq!(toy().soi_macs_per_frame(), 100.0 + 150.0);
    }

    #[test]
    fn baseline_multiplies_by_window() {
        assert_eq!(toy().baseline_macs_per_frame(), 4000.0);
    }

    #[test]
    fn retain_pct() {
        assert!((toy().soi_retain_pct() - 62.5).abs() < 1e-9);
    }

    #[test]
    fn precomputed_pct_is_full_rate_fraction() {
        // layer b (300 of 400 full-rate MACs) is delayed -> 75%
        assert!((toy().precomputed_pct() - 75.0).abs() < 1e-9);
    }

    #[test]
    fn mmac_per_s() {
        let n = toy();
        assert!((n.mmac_per_s(n.stmc_macs_per_frame()) - 0.04).abs() < 1e-12);
    }
}
