//! The paper's published numbers, kept as data so every experiment driver
//! can print "paper vs measured" side by side, plus the closed-form
//! identities recoverable from them (DESIGN.md §3).
//!
//! From Table 1, with S-CC at position p the halved-cost fraction is
//! `h(p) = 2 (1 - retain(p))`; the paper's own rows then obey
//!
//!   retain(p, q)  = 1 - (h(p) - h(q))/2 - 3/4 h(q)      (2×S-CC rows)
//!   precomp(s)    = h(s)                                 (Table 2)
//!
//! These identities are unit-tested against the published rows below and
//! against our analytic engine (`unet::tests`), which is how we know the
//! engine implements the same cost semantics as the paper.

/// Paper Table 1/6: complexity retain % for a single S-CC at p=1..7.
pub const RETAIN_1SCC: [f64; 7] = [50.1, 51.4, 58.1, 61.5, 64.8, 71.3, 83.8];

/// Paper Table 1: SI-SNRi (dB) for a single S-CC at p=1..7 (Table 6 row 1).
pub const SISNRI_1SCC: [f64; 7] = [7.15, 7.23, 7.28, 7.43, 7.47, 7.56, 7.55];

/// Paper STMC reference SI-SNRi, dB.
pub const STMC_SISNRI: f64 = 7.69;
/// Paper STMC reference complexity, MMAC/s.
pub const STMC_MMACS: f64 = 1819.2;

/// Paper Table 1: 2×S-CC rows (p, q, SI-SNRi, retain %).
pub const TABLE1_2SCC: [(usize, usize, f64, f64); 7] = [
    (1, 3, 6.27, 29.1),
    (1, 6, 6.94, 35.6),
    (2, 5, 6.67, 33.8),
    (3, 6, 7.02, 43.8),
    (4, 6, 7.14, 47.1),
    (5, 7, 7.30, 56.7),
    (6, 7, 7.40, 63.2),
];

/// Paper Table 2: FP rows (label, SI-SNRi, retain %, precomputed %).
pub const TABLE2_FP: [(&str, f64, f64, f64); 10] = [
    ("SS-CC 2", 6.64, 51.4, 97.2),
    ("SS-CC 5", 7.24, 64.8, 70.4),
    ("SS-CC 7", 7.52, 83.8, 32.4),
    ("S-CC 1|3", 6.82, 50.0, 83.7),
    ("S-CC 1|6", 7.06, 50.0, 57.4),
    ("S-CC 2|5", 6.93, 51.4, 70.4),
    ("S-CC 3|6", 7.10, 58.1, 57.4),
    ("S-CC 4|6", 7.30, 61.5, 57.4),
    ("S-CC 5|6", 7.23, 64.8, 57.4),
    ("S-CC 6|7", 7.39, 71.3, 32.4),
];

/// Paper Table 3: resampling baselines (method, SI-SNRi, MMAC/s).
pub const TABLE3_RESAMPLING: [(&str, f64, f64); 5] = [
    ("STMC", 7.69, 1819.2),
    ("Linear", 3.49, 909.6),
    ("Polyphase", 5.69, 909.6),
    ("Kaiser", 5.83, 909.6),
    ("SoX", 5.77, 909.6),
];

/// Paper Table 4: ASC GhostNet (size, baseline MMAC/s, STMC MMAC/s,
/// SOI MMAC/s, baseline top-1 %, SOI top-1 %).
pub const TABLE4_ASC: [(&str, f64, f64, f64, f64, f64); 7] = [
    ("I", 423.07, 0.41, 0.37, 55.68, 55.90),
    ("II", 959.67, 0.94, 0.80, 64.18, 61.98),
    ("III", 1624.11, 1.59, 1.37, 66.45, 68.14),
    ("IV", 2405.09, 2.35, 1.97, 70.57, 70.32),
    ("V", 6769.78, 6.61, 5.54, 76.91, 76.42),
    ("VI", 13187.40, 12.78, 10.75, 81.66, 80.73),
    ("VII", 21395.26, 20.87, 17.59, 83.07, 83.35),
];

/// Paper Table 5 / App. B: prediction length vs SI-SNRi.
pub const TABLE5_PREDICTION: [(usize, f64, f64); 4] = [
    // (length, predictive, strided predictive)
    (1, 7.41, 7.24),
    (2, 6.51, 6.70),
    (3, 4.61, 5.47),
    (4, 3.59, 4.00),
];

/// Paper Table 6 extras: avg inference time (ms) and peak memory (MB)
/// for STMC + single S-CC (p = 1..7).
pub const TABLE6_TIME_MEM: [(&str, f64, f64); 8] = [
    ("STMC", 9.93, 27.2),
    ("S-CC 1", 5.28, 14.6),
    ("S-CC 2", 5.63, 18.7),
    ("S-CC 3", 6.27, 24.0),
    ("S-CC 4", 6.67, 25.1),
    ("S-CC 5", 6.98, 25.6),
    ("S-CC 6", 7.50, 26.1),
    ("S-CC 7", 8.43, 26.6),
];

/// Paper Table 10: video action recognition (model, regular top-1,
/// regular GMAC/s, SOI top-1, SOI GMAC/s).
pub const TABLE10_VIDEO: [(&str, f64, f64, f64, f64); 5] = [
    ("ResNet-10", 32.63, 48.54, 33.34, 40.69),
    ("ResNet-10 small", 31.24, 15.05, 31.41, 13.09),
    ("ResNet-10 tiny", 30.46, 5.23, 30.90, 4.73),
    ("MoViNet A0", 34.40, 33.15, 31.88, 24.26),
    ("MoViNet A1", 35.96, 69.77, 32.73, 53.92),
];

/// Paper Table 11: ASC with ResNet (depth, baseline GMAC/s, STMC GMAC/s,
/// SOI GMAC/s, STMC top-1 %, SOI top-1 %).
pub const TABLE11_RESNET: [(usize, f64, f64, f64, f64, f64); 4] = [
    (18, 143.65, 15.56, 12.35, 85.13, 91.55),
    (34, 686.96, 32.65, 26.46, 86.03, 92.01),
    (50, 794.34, 33.10, 27.99, 89.66, 91.43),
    (101, 2168.81, 112.84, 95.83, 94.74, 96.22),
];

/// Halved-cost fraction h(p) implied by the published single-S-CC retains.
pub fn h(p: usize) -> f64 {
    assert!((1..=7).contains(&p));
    2.0 * (1.0 - RETAIN_1SCC[p - 1] / 100.0)
}

/// Closed-form retain for two S-CC positions (fraction, not %).
pub fn retain2(p: usize, q: usize) -> f64 {
    1.0 - (h(p) - h(q)) / 2.0 - 0.75 * h(q)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn double_scc_identity_holds_on_published_rows() {
        for &(p, q, _snr, retain_pct) in &TABLE1_2SCC {
            let pred = 100.0 * retain2(p, q);
            assert!(
                (pred - retain_pct).abs() < 0.75,
                "paper identity broken at ({p},{q}): predicted {pred:.1}, published {retain_pct}"
            );
        }
    }

    #[test]
    fn precomputed_identity_holds_on_published_rows() {
        // SS-CC p rows: precomputed % == h(p)
        for &(label, _snr, _ret, pre) in TABLE2_FP.iter().take(3) {
            let p: usize = label.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(
                (100.0 * h(p) - pre).abs() < 0.8,
                "{label}: h={:.1} vs published {pre}",
                100.0 * h(p)
            );
        }
        // hybrid rows: precomputed % == h(shift position)
        for &(label, _snr, _ret, pre) in TABLE2_FP.iter().skip(3) {
            let s: usize = label.rsplit('|').next().unwrap().parse().unwrap();
            assert!(
                (100.0 * h(s) - pre).abs() < 0.8,
                "{label}: h({s})={:.1} vs published {pre}",
                100.0 * h(s)
            );
        }
    }

    #[test]
    fn h_is_decreasing() {
        for p in 1..7 {
            assert!(h(p) > h(p + 1));
        }
    }

    #[test]
    fn ghostnet_soi_saves_vs_stmc() {
        for &(_, _base, stmc, soi, _, _) in &TABLE4_ASC {
            assert!(soi < stmc);
        }
    }
}
