//! GhostNet-style ASC classifier descriptor (paper §3.2 / Table 4).
//!
//! GhostNet's ghost module makes half the feature maps with a full conv
//! ("primary") and the other half with a cheap depthwise conv.  Our
//! streaming adaptation is 1-D over time (spectrogram-frame input); 7
//! model sizes mirror the paper's I..VII via a width multiplier.
//!
//! Three methods per size (Table 4 rows):
//! * Baseline — offline net re-run over the whole 1 s window per frame,
//! * STMC     — incremental,
//! * SOI      — compression before the middle block group, extrapolation
//!              after it (skip connections around), halving those blocks.

use super::{LayerCost, Network};

/// One ghost block's shape.
#[derive(Debug, Clone)]
pub struct GhostBlock {
    /// Input channels.
    pub c_in: usize,
    /// Output channels.
    pub c_out: usize,
    /// Temporal kernel width of the primary conv.
    pub kernel: usize,
    /// Part of the SOI-compressed region?
    pub compressed: bool,
}

/// MACs per output frame of a ghost module (primary half + cheap half).
pub fn ghost_module_macs(b: &GhostBlock) -> u64 {
    let half = b.c_out / 2;
    let primary = b.c_in * half * b.kernel;
    let cheap = half * 3; // depthwise k=3 over the primary half
    (primary + cheap) as u64
}

/// Width multipliers for the seven sizes (I..VII).
pub const SIZES: [(&str, f64); 7] = [
    ("I", 0.25),
    ("II", 0.40),
    ("III", 0.55),
    ("IV", 0.70),
    ("V", 1.20),
    ("VI", 1.75),
    ("VII", 2.30),
];

fn ch(base: usize, mult: f64) -> usize {
    ((base as f64 * mult).round() as usize).max(2)
}

/// Build the block list for one width multiplier.
///
/// `soi` marks the middle blocks as compressed (stride before block 3,
/// extrapolation after block 6 — the variant whose measured reduction is
/// ~16%, matching the paper's GhostNet numbers).
pub fn blocks(mult: f64, soi: bool) -> Vec<GhostBlock> {
    let widths = [16, 24, 40, 40, 64, 64, 80, 96];
    let mut out = Vec::new();
    let mut c_in = 20; // spectral frame features
    for (i, w) in widths.iter().enumerate() {
        let c_out = ch(*w, mult);
        out.push(GhostBlock {
            c_in,
            c_out,
            kernel: 3,
            compressed: soi && (2..=5).contains(&i),
        });
        c_in = c_out;
    }
    out
}

/// Rough parameter count (for the Table 4 "# params" column).
pub fn param_count(mult: f64, soi: bool) -> u64 {
    let mut n = 0u64;
    for b in blocks(mult, soi) {
        let half = b.c_out / 2;
        n += (b.c_in * half * b.kernel + half * 3 + b.c_out) as u64;
    }
    // classifier head: global pool -> 10 classes
    let last = ch(96, mult);
    n += (last * 10 + 10) as u64;
    // SOI adds skip-connection concat convs around the compressed region
    if soi {
        let c = ch(40, mult);
        n += (c * c) as u64;
    }
    n
}

/// Cost model for one (size, method) cell of Table 4.
///
/// `window_frames`: offline input length (1 s of 100 fps spectral frames).
pub fn network(mult: f64, soi: bool, window_frames: u64, fps: f64) -> Network {
    let mut layers = Vec::new();
    for (i, b) in blocks(mult, soi).iter().enumerate() {
        let rate_div = if b.compressed { 2 } else { 1 };
        layers.push(LayerCost {
            name: format!("ghost{i}"),
            macs_per_out: ghost_module_macs(b),
            rate_div,
            window_len: window_frames / rate_div,
            delayed: false,
        });
    }
    // SOI skip-connection merge after the compressed region
    if soi {
        let c = ch(40, mult);
        layers.push(LayerCost {
            name: "soi_skip".into(),
            macs_per_out: (c * c) as u64,
            rate_div: 1,
            window_len: window_frames,
            delayed: false,
        });
    }
    let last = ch(96, mult);
    layers.push(LayerCost {
        name: "head".into(),
        macs_per_out: (last * 10) as u64,
        rate_div: 1,
        window_len: 1, // pooled head runs once per window offline
        delayed: false,
    });
    Network {
        name: format!("ghostnet x{mult}"),
        layers,
        frame_rate: fps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn soi_reduces_complexity_10_to_25_pct() {
        for &(_, mult) in &SIZES {
            let stmc = network(mult, false, 100, 100.0);
            let soi = network(mult, true, 100, 100.0);
            let ratio = soi.soi_macs_per_frame() / stmc.stmc_macs_per_frame();
            assert!(
                (0.75..=0.92).contains(&ratio),
                "x{mult}: SOI/STMC ratio {ratio}"
            );
        }
    }

    #[test]
    fn baseline_is_orders_of_magnitude_bigger() {
        let n = network(1.0, false, 100, 100.0);
        let ratio = n.baseline_macs_per_frame() / n.stmc_macs_per_frame();
        assert!(ratio > 50.0, "ratio {ratio}");
    }

    #[test]
    fn sizes_are_monotone() {
        let mut prev = 0.0;
        for &(_, mult) in &SIZES {
            let n = network(mult, false, 100, 100.0);
            let c = n.stmc_macs_per_frame();
            assert!(c > prev);
            prev = c;
        }
    }

    #[test]
    fn params_grow_with_size() {
        let mut prev = 0;
        for &(_, mult) in &SIZES {
            let p = param_count(mult, false);
            assert!(p > prev);
            prev = p;
        }
    }

    #[test]
    fn ghost_module_cheaper_than_full_conv() {
        let b = GhostBlock {
            c_in: 32,
            c_out: 64,
            kernel: 3,
            compressed: false,
        };
        let full = (b.c_in * b.c_out * b.kernel) as u64;
        assert!(ghost_module_macs(&b) < full * 6 / 10);
    }
}
