//! NEON microkernels (aarch64; NEON is baseline on that architecture —
//! DESIGN.md §11).
//!
//! A packed panel's [`MR`] = 8 lanes are processed as two 4-lane
//! `float32x4`/`int32x4` halves.  The f32 GEMM uses `vfmaq_f32` (fused,
//! same rounding class as the AVX2 path — within the documented ULP
//! envelope of the scalar oracle); the int8 GEMM uses exact integer
//! `vmlaq_s32` dots and the *unfused* f32 fold, making it bit-identical
//! to the scalar kernel.  Per-element accumulation order matches the
//! scalar kernels (bias first, reduction indices ascending), so results
//! are independent of the batch width on this ISA too.

#![cfg(target_arch = "aarch64")]

use core::arch::aarch64::*;

use super::elu_scalar;
use super::pack::{PackedF32, PackedI8, MR};

/// # Safety
/// NEON must be available (always true on aarch64 targets; the
/// dispatcher only routes here on that architecture).
#[target_feature(enable = "neon")]
pub(super) unsafe fn gemm_f32(
    p: &PackedF32,
    bias: &[f32],
    x: &[f32],
    bsz: usize,
    out: &mut [f32],
    elu: bool,
) {
    debug_assert_eq!(MR, 8);
    let n = p.n;
    let mut tile = [0.0f32; MR];
    for pi in 0..p.panels() {
        let o0 = pi * MR;
        let rows = MR.min(p.c_out - o0);
        let pd = p.data[pi * n * MR..(pi + 1) * n * MR].as_ptr();
        let mut btmp = [0.0f32; MR];
        btmp[..rows].copy_from_slice(&bias[o0..o0 + rows]);
        let bl = vld1q_f32(btmp.as_ptr());
        let bh = vld1q_f32(btmp.as_ptr().add(4));
        for b in 0..bsz {
            let mut al = bl;
            let mut ah = bh;
            for j in 0..n {
                let xv = vdupq_n_f32(*x.as_ptr().add(j * bsz + b));
                al = vfmaq_f32(al, vld1q_f32(pd.add(j * MR)), xv);
                ah = vfmaq_f32(ah, vld1q_f32(pd.add(j * MR + 4)), xv);
            }
            vst1q_f32(tile.as_mut_ptr(), al);
            vst1q_f32(tile.as_mut_ptr().add(4), ah);
            for m in 0..rows {
                let v = tile[m];
                out[(o0 + m) * bsz + b] = if elu { elu_scalar(v) } else { v };
            }
        }
    }
}

/// # Safety
/// NEON must be available (always true on aarch64 targets; the
/// dispatcher only routes here on that architecture).
#[target_feature(enable = "neon")]
pub(super) unsafe fn gemm_i8(p: &PackedI8, x: &[i32], bsz: usize, out: &mut [f32]) {
    debug_assert_eq!(MR, 8);
    let (c_in, k) = (p.c_in, p.k);
    let mut tile = [0.0f32; MR];
    for pi in 0..p.panels() {
        let o0 = pi * MR;
        let rows = MR.min(p.c_out - o0);
        let bl = vld1q_f32(p.bias.as_ptr().add(pi * MR));
        let bh = vld1q_f32(p.bias.as_ptr().add(pi * MR + 4));
        for b in 0..bsz {
            let mut pre_l = vdupq_n_f32(0.0);
            let mut pre_h = vdupq_n_f32(0.0);
            for i in 0..c_in {
                let mut acc_l = vdupq_n_s32(0);
                let mut acc_h = vdupq_n_s32(0);
                for j in 0..k {
                    let wp = p.data.as_ptr().add(((pi * c_in + i) * k + j) * MR);
                    let w16 = vmovl_s8(vld1_s8(wp));
                    let wl = vmovl_s16(vget_low_s16(w16));
                    let wh = vmovl_s16(vget_high_s16(w16));
                    let xv = vdupq_n_s32(*x.as_ptr().add((i * k + j) * bsz + b));
                    acc_l = vmlaq_s32(acc_l, wl, xv);
                    acc_h = vmlaq_s32(acc_h, wh, xv);
                }
                let gl = vld1q_f32(p.g.as_ptr().add((pi * c_in + i) * MR));
                let gh = vld1q_f32(p.g.as_ptr().add((pi * c_in + i) * MR + 4));
                // unfused mul + add: bit-identical to the scalar fold
                pre_l = vaddq_f32(pre_l, vmulq_f32(gl, vcvtq_f32_s32(acc_l)));
                pre_h = vaddq_f32(pre_h, vmulq_f32(gh, vcvtq_f32_s32(acc_h)));
            }
            vst1q_f32(tile.as_mut_ptr(), vaddq_f32(pre_l, bl));
            vst1q_f32(tile.as_mut_ptr().add(4), vaddq_f32(pre_h, bh));
            for m in 0..rows {
                out[(o0 + m) * bsz + b] = tile[m];
            }
        }
    }
}
