//! AVX2 + FMA microkernels (x86_64, selected at runtime via
//! `is_x86_feature_detected!` — DESIGN.md §11).
//!
//! The f32 GEMM vectorizes over the [`MR`] = 8 output-channel lanes of a
//! packed panel and register-blocks 4 batch columns per tile; every
//! `(o, b)` element still accumulates *bias first, then reduction
//! indices in ascending order*, one `fmadd` per index, so results are
//! independent of the batch width (batched == sequential bit-for-bit).
//! Against the scalar oracle the only difference is the fused rounding
//! of FMA — bounded by the documented ULP envelope and asserted by
//! `rust/tests/properties.rs`.
//!
//! The int8 GEMM keeps integer dots (exact) and folds groups with
//! *unfused* `mul` + `add` — per-lane the identical operation sequence
//! as the scalar kernel, hence bit-identical output on every ISA.

#![cfg(target_arch = "x86_64")]

use core::arch::x86_64::*;

use super::elu_scalar;
use super::pack::{PackedF32, PackedI8, MR};

/// # Safety
/// Caller must ensure the CPU supports AVX2 and FMA (the dispatcher
/// checks `is_x86_feature_detected!` before routing here).
#[target_feature(enable = "avx2", enable = "fma")]
pub(super) unsafe fn gemm_f32(
    p: &PackedF32,
    bias: &[f32],
    x: &[f32],
    bsz: usize,
    out: &mut [f32],
    elu: bool,
) {
    debug_assert_eq!(MR, 8);
    let n = p.n;
    let mut tile = [0.0f32; MR];
    for pi in 0..p.panels() {
        let o0 = pi * MR;
        let rows = MR.min(p.c_out - o0);
        let pd = p.data[pi * n * MR..(pi + 1) * n * MR].as_ptr();
        // zero-padded bias vector for the (possibly partial) panel
        let mut btmp = [0.0f32; MR];
        btmp[..rows].copy_from_slice(&bias[o0..o0 + rows]);
        let bv = _mm256_loadu_ps(btmp.as_ptr());
        let mut b = 0usize;
        while b + 4 <= bsz {
            let mut a0 = bv;
            let mut a1 = bv;
            let mut a2 = bv;
            let mut a3 = bv;
            for j in 0..n {
                let wv = _mm256_loadu_ps(pd.add(j * MR));
                let xr = x.as_ptr().add(j * bsz + b);
                a0 = _mm256_fmadd_ps(wv, _mm256_set1_ps(*xr), a0);
                a1 = _mm256_fmadd_ps(wv, _mm256_set1_ps(*xr.add(1)), a1);
                a2 = _mm256_fmadd_ps(wv, _mm256_set1_ps(*xr.add(2)), a2);
                a3 = _mm256_fmadd_ps(wv, _mm256_set1_ps(*xr.add(3)), a3);
            }
            for (c, acc) in [a0, a1, a2, a3].into_iter().enumerate() {
                _mm256_storeu_ps(tile.as_mut_ptr(), acc);
                for m in 0..rows {
                    let v = tile[m];
                    out[(o0 + m) * bsz + b + c] = if elu { elu_scalar(v) } else { v };
                }
            }
            b += 4;
        }
        while b < bsz {
            let mut acc = bv;
            for j in 0..n {
                let wv = _mm256_loadu_ps(pd.add(j * MR));
                acc = _mm256_fmadd_ps(wv, _mm256_set1_ps(*x.as_ptr().add(j * bsz + b)), acc);
            }
            _mm256_storeu_ps(tile.as_mut_ptr(), acc);
            for m in 0..rows {
                let v = tile[m];
                out[(o0 + m) * bsz + b] = if elu { elu_scalar(v) } else { v };
            }
            b += 1;
        }
    }
}

/// # Safety
/// Caller must ensure the CPU supports AVX2 (the dispatcher checks
/// `is_x86_feature_detected!` before routing here).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn gemm_i8(p: &PackedI8, x: &[i32], bsz: usize, out: &mut [f32]) {
    debug_assert_eq!(MR, 8);
    let (c_in, k) = (p.c_in, p.k);
    let mut tile = [0.0f32; MR];
    for pi in 0..p.panels() {
        let o0 = pi * MR;
        let rows = MR.min(p.c_out - o0);
        // bias is stored lane-padded, so the vector load is direct
        let bv = _mm256_loadu_ps(p.bias.as_ptr().add(pi * MR));
        for b in 0..bsz {
            let mut pre = _mm256_setzero_ps();
            for i in 0..c_in {
                let mut acc = _mm256_setzero_si256();
                for j in 0..k {
                    let wp = p.data.as_ptr().add(((pi * c_in + i) * k + j) * MR);
                    let wv = _mm256_cvtepi8_epi32(_mm_loadl_epi64(wp as *const __m128i));
                    let xv = _mm256_set1_epi32(*x.as_ptr().add((i * k + j) * bsz + b));
                    acc = _mm256_add_epi32(acc, _mm256_mullo_epi32(wv, xv));
                }
                let gv = _mm256_loadu_ps(p.g.as_ptr().add((pi * c_in + i) * MR));
                // unfused mul + add: bit-identical to the scalar fold
                pre = _mm256_add_ps(pre, _mm256_mul_ps(gv, _mm256_cvtepi32_ps(acc)));
            }
            _mm256_storeu_ps(tile.as_mut_ptr(), _mm256_add_ps(pre, bv));
            for m in 0..rows {
                out[(o0 + m) * bsz + b] = tile[m];
            }
        }
    }
}
