//! SIMD microkernel substrate (DESIGN.md §11): the single compute layer
//! both interpreters execute on.
//!
//! * [`pack`] — [`PackedF32`]/[`PackedI8`] cache-blocked, pre-transposed
//!   weight panels, built **once at upload time** (f32, in
//!   `crate::backend::HostWeights`) or at quantized-plan preparation
//!   (int8, in `crate::quant`).
//! * [`gemm_f32`]/[`gemm_i8`] — runtime-dispatched panel GEMMs with
//!   fused bias (+ ELU for f32) epilogues: AVX2+FMA on x86_64 (behind
//!   `is_x86_feature_detected!`), NEON on aarch64, and a scalar fallback
//!   that doubles as the correctness oracle everywhere else.
//! * [`arena`] — the per-variant [`StepArena`] scratch slabs and the
//!   bounded offline pool behind the interpreters' allocation-free
//!   steady state.
//!
//! Numeric contract: every implementation accumulates each output
//! element as *bias first, then reduction indices in ascending order* —
//! independent of batch width — so batched and sequential execution are
//! bit-identical on any single ISA.  Across ISAs, int8 results are
//! bit-identical everywhere (exact integer dots, unfused per-lane
//! folds); f32 results differ from the scalar oracle only by FMA's fused
//! rounding, within the ULP envelope documented in DESIGN.md §11 and
//! asserted by `rust/tests/properties.rs`.

pub mod arena;
pub mod pack;

mod scalar;
#[cfg(target_arch = "x86_64")]
mod x86;
#[cfg(target_arch = "aarch64")]
mod neon;

pub use arena::{
    next_arena_id, offline_put, offline_take, peak_bytes_of, thread_peak_bytes, with_arena,
    ArenaSpec, StepArena,
};
pub use pack::{PackedF32, PackedI8, MR};

use std::sync::OnceLock;

/// An instruction-set family a microkernel can execute on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar fallback (also the correctness oracle).
    Scalar,
    /// x86_64 AVX2 + FMA (runtime-detected).
    Avx2Fma,
    /// aarch64 NEON (baseline on that architecture).
    Neon,
}

impl Isa {
    /// Short name for logs and bench rows.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2Fma => "avx2fma",
            Isa::Neon => "neon",
        }
    }
}

#[allow(unreachable_code)] // per-arch early returns make the tail arch-dependent
fn detect() -> Isa {
    #[cfg(target_arch = "x86_64")]
    {
        // AVX2 and FMA are required as a unit: every mainstream AVX2 CPU
        // ships FMA, and a finer-grained tier for the hypothetical
        // avx2-without-fma case (which only the int8 kernel could use)
        // is not worth a fourth dispatch family.
        if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
        {
            return Isa::Avx2Fma;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return Isa::Neon;
    }
    Isa::Scalar
}

/// The ISA the dispatched kernels run on, detected once per process.
pub fn active_isa() -> Isa {
    static ISA: OnceLock<Isa> = OnceLock::new();
    *ISA.get_or_init(detect)
}

/// ELU applied to one element — shared by every kernel's epilogue so the
/// nonlinearity is identical math on every ISA.
#[inline]
pub(crate) fn elu_scalar(v: f32) -> f32 {
    if v < 0.0 {
        v.exp_m1()
    } else {
        v
    }
}

/// Panel GEMM with fused bias (+ optional ELU) epilogue over a
/// column-stacked `(n, bsz)` activation panel `x`, writing the
/// `(c_out, bsz)` result row-major into `out`.  Dispatches to the
/// [`active_isa`] implementation.
pub fn gemm_f32(p: &PackedF32, bias: &[f32], x: &[f32], bsz: usize, out: &mut [f32], elu: bool) {
    gemm_f32_on(active_isa(), p, bias, x, bsz, out, elu);
}

/// [`gemm_f32`] on an explicit ISA (bench A/B legs, oracle tests).
/// Falls back to scalar when the requested ISA is unavailable on this
/// CPU, so the call is always safe.
pub fn gemm_f32_on(
    isa: Isa,
    p: &PackedF32,
    bias: &[f32],
    x: &[f32],
    bsz: usize,
    out: &mut [f32],
    elu: bool,
) {
    assert_eq!(x.len(), p.n * bsz, "activation panel shape mismatch");
    assert_eq!(out.len(), p.c_out * bsz, "output panel shape mismatch");
    assert_eq!(bias.len(), p.c_out, "bias shape mismatch");
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma if active_isa() == Isa::Avx2Fma => {
            // SAFETY: AVX2 + FMA availability was runtime-checked.
            unsafe { x86::gemm_f32(p, bias, x, bsz, out, elu) }
        }
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => {
            // SAFETY: NEON is baseline on aarch64.
            unsafe { neon::gemm_f32(p, bias, x, bsz, out, elu) }
        }
        _ => scalar::gemm_f32(p, bias, x, bsz, out, elu),
    }
}

/// Quantized panel GEMM: i32 group dots over a column-stacked
/// `(c_in · k, bsz)` panel of s16 activation codes, per-(out, in) f32
/// scale folds in fixed order, bias added last; writes f32
/// pre-activations `(c_out, bsz)` row-major.  Bit-identical across every
/// ISA.  Dispatches to the [`active_isa`] implementation.
pub fn gemm_i8(p: &PackedI8, x: &[i32], bsz: usize, out: &mut [f32]) {
    gemm_i8_on(active_isa(), p, x, bsz, out);
}

/// [`gemm_i8`] on an explicit ISA (bench A/B legs, oracle tests); falls
/// back to scalar when the requested ISA is unavailable.
pub fn gemm_i8_on(isa: Isa, p: &PackedI8, x: &[i32], bsz: usize, out: &mut [f32]) {
    assert_eq!(x.len(), p.c_in * p.k * bsz, "code panel shape mismatch");
    assert_eq!(out.len(), p.c_out * bsz, "output panel shape mismatch");
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma if active_isa() == Isa::Avx2Fma => {
            // SAFETY: AVX2 availability was runtime-checked.
            unsafe { x86::gemm_i8(p, x, bsz, out) }
        }
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => {
            // SAFETY: NEON is baseline on aarch64.
            unsafe { neon::gemm_i8(p, x, bsz, out) }
        }
        _ => scalar::gemm_i8(p, x, bsz, out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Unpacked reference: the exact pre-panel accumulation order.
    fn naive_f32(
        w: &[f32],
        c_out: usize,
        n: usize,
        bias: &[f32],
        x: &[f32],
        bsz: usize,
        elu: bool,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; c_out * bsz];
        for o in 0..c_out {
            for b in 0..bsz {
                let mut acc = bias[o];
                for j in 0..n {
                    acc += w[o * n + j] * x[j * bsz + b];
                }
                out[o * bsz + b] = if elu { elu_scalar(acc) } else { acc };
            }
        }
        out
    }

    #[test]
    fn scalar_gemm_matches_naive_bitwise() {
        let (c_out, n, bsz) = (11, 7, 3); // partial panel on purpose
        let w: Vec<f32> = (0..c_out * n)
            .map(|i| ((i * 37 % 19) as f32 - 9.0) * 0.13)
            .collect();
        let bias: Vec<f32> = (0..c_out).map(|i| i as f32 * 0.01 - 0.05).collect();
        let x: Vec<f32> = (0..n * bsz)
            .map(|i| ((i * 11 % 23) as f32 - 11.0) * 0.07)
            .collect();
        let p = PackedF32::pack(&w, c_out, n);
        for elu in [false, true] {
            let mut out = vec![0.0f32; c_out * bsz];
            gemm_f32_on(Isa::Scalar, &p, &bias, &x, bsz, &mut out, elu);
            let want = naive_f32(&w, c_out, n, &bias, &x, bsz, elu);
            for (a, b) in out.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn dispatched_gemm_is_batch_invariant() {
        let (c_out, n, bsz) = (9, 12, 5);
        let w: Vec<f32> = (0..c_out * n)
            .map(|i| ((i * 7 % 29) as f32 - 14.0) * 0.21)
            .collect();
        let bias: Vec<f32> = (0..c_out).map(|i| (i as f32 - 4.0) * 0.3).collect();
        let x: Vec<f32> = (0..n * bsz)
            .map(|i| ((i * 13 % 31) as f32 - 15.0) * 0.09)
            .collect();
        let p = PackedF32::pack(&w, c_out, n);
        let mut out = vec![0.0f32; c_out * bsz];
        gemm_f32(&p, &bias, &x, bsz, &mut out, true);
        for b in 0..bsz {
            let col: Vec<f32> = (0..n).map(|j| x[j * bsz + b]).collect();
            let mut one = vec![0.0f32; c_out];
            gemm_f32(&p, &bias, &col, 1, &mut one, true);
            for o in 0..c_out {
                assert_eq!(
                    one[o].to_bits(),
                    out[o * bsz + b].to_bits(),
                    "col {b} row {o}"
                );
            }
        }
    }

    #[test]
    fn i8_gemm_bit_identical_across_isa_and_batch() {
        let (c_out, c_in, k, bsz) = (10, 3, 3, 4);
        let codes: Vec<i8> = (0..c_out * c_in * k)
            .map(|i| ((i * 41 % 255) as i32 - 127) as i8)
            .collect();
        let g: Vec<f32> = (0..c_out * c_in)
            .map(|i| 1e-4 * ((i % 7) + 1) as f32)
            .collect();
        let bias: Vec<f32> = (0..c_out).map(|i| (i as f32 - 5.0) * 0.02).collect();
        let x: Vec<i32> = (0..c_in * k * bsz)
            .map(|i| (i as i32 * 977 % 60001) - 30000)
            .collect();
        let p = PackedI8::pack(&codes, c_out, c_in, k, &g, &bias);
        let mut simd = vec![0.0f32; c_out * bsz];
        let mut sc = vec![0.0f32; c_out * bsz];
        gemm_i8(&p, &x, bsz, &mut simd);
        gemm_i8_on(Isa::Scalar, &p, &x, bsz, &mut sc);
        for (a, b) in simd.iter().zip(&sc) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // batch invariance too
        for b in 0..bsz {
            let col: Vec<i32> = (0..c_in * k).map(|j| x[j * bsz + b]).collect();
            let mut one = vec![0.0f32; c_out];
            gemm_i8(&p, &col, 1, &mut one);
            for o in 0..c_out {
                assert_eq!(one[o].to_bits(), simd[o * bsz + b].to_bits());
            }
        }
    }

    #[test]
    fn isa_detection_is_stable_and_named() {
        let isa = active_isa();
        assert_eq!(isa, active_isa());
        assert!(!isa.name().is_empty());
    }
}
