//! Per-variant step arenas: persistent, size-classed activation slabs
//! that make the interpreters' steady state allocation-free
//! (DESIGN.md §11).
//!
//! Every buffer the streaming step of a variant can ever need is a
//! `(C, B)` panel whose per-stream element count `C` comes from the
//! manifest — so each variant computes its [`ArenaSpec`] (the sorted set
//! of distinct per-stream sizes) **at compile time**.  At execution time
//! the [`StepArena`] hands out slabs from capacity-sorted free lists:
//! a request is served by the smallest recycled slab that fits (best
//! fit), and a miss allocates at the *class* capacity
//! (`class_size · batch_capacity`), never the exact request — so after
//! one warm-up pass per phase the multiset of slab capacities covers
//! every request the schedule can make and `take` never allocates again.
//! `tests/hot_path_alloc.rs` proves this with a counting global
//! allocator for every variant family at both precisions.
//!
//! Arenas are thread-local and keyed by variant id ([`with_arena`]):
//! workers never contend, and a variant served from several threads gets
//! one arena per thread.  The registry is bounded (LRU beyond
//! [`MAX_ARENAS`] entries is dropped), as is each free list, so scratch
//! memory cannot grow without bound — the fix for the unbounded
//! `thread_local SCRATCH` pool this module replaces.
//!
//! [`offline_take`]/[`offline_put`] are the surviving general-purpose
//! pool for the *offline* (full-sequence) paths, whose buffer sizes
//! scale with `T` rather than the manifest: bounded in count and bytes,
//! with power-of-two size classes so differing sequence lengths still
//! recycle.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};

/// Maximum per-thread arenas retained; the least-recently-used one is
/// dropped beyond this (a backstop for tests that compile many
/// variants).
pub const MAX_ARENAS: usize = 32;

/// Maximum recycled slabs per free list (far above any schedule's live
/// set; purely a safety bound).
const MAX_FREE: usize = 64;

/// Per-stream buffer sizes a variant's step can request, computed from
/// the manifest at variant-compile time (sorted, deduplicated).
#[derive(Debug, Clone, Default)]
pub struct ArenaSpec {
    /// Distinct per-stream f32 panel heights.
    pub f32_sizes: Vec<usize>,
    /// Distinct per-stream i32 panel heights (quantized path).
    pub i32_sizes: Vec<usize>,
}

impl ArenaSpec {
    /// Build a spec from raw size lists (sorted + deduplicated here).
    pub fn new(mut f32_sizes: Vec<usize>, mut i32_sizes: Vec<usize>) -> ArenaSpec {
        f32_sizes.retain(|&s| s > 0);
        i32_sizes.retain(|&s| s > 0);
        f32_sizes.sort_unstable();
        f32_sizes.dedup();
        i32_sizes.sort_unstable();
        i32_sizes.dedup();
        ArenaSpec {
            f32_sizes,
            i32_sizes,
        }
    }
}

/// One element-typed pool of capacity-sorted recycled slabs.
#[derive(Debug, Default)]
struct Pool<T> {
    /// Size classes (per-stream element counts), ascending.
    sizes: Vec<usize>,
    /// Recycled slabs, ascending capacity.
    free: Vec<Vec<T>>,
    /// Capacity bytes currently lent out (taken, not yet returned).
    out_bytes: usize,
    /// Capacity bytes parked on the free list.
    free_bytes: usize,
    /// High-water mark of `out_bytes + free_bytes` — the pool's peak
    /// scratch footprint (the RAM axis the health feed reports).
    peak_bytes: usize,
}

impl<T: Copy + Default> Pool<T> {
    fn take(&mut self, per_stream: usize, bsz: usize, bcap: usize) -> Vec<T> {
        let n = per_stream * bsz;
        let mut v = match self.free.iter().position(|v| v.capacity() >= n) {
            Some(i) => {
                let v = self.free.remove(i);
                self.free_bytes = self
                    .free_bytes
                    .saturating_sub(v.capacity() * std::mem::size_of::<T>());
                v
            }
            None => {
                // allocate at class capacity so the slab serves every
                // future request of this class at full batch capacity
                let class = self
                    .sizes
                    .iter()
                    .copied()
                    .find(|&c| c >= per_stream)
                    .unwrap_or(per_stream);
                Vec::with_capacity(class * bcap)
            }
        };
        v.clear();
        v.resize(n, T::default());
        self.out_bytes += v.capacity() * std::mem::size_of::<T>();
        self.peak_bytes = self.peak_bytes.max(self.out_bytes + self.free_bytes);
        v
    }

    fn put(&mut self, v: Vec<T>) {
        self.out_bytes = self
            .out_bytes
            .saturating_sub(v.capacity() * std::mem::size_of::<T>());
        if v.capacity() == 0 || self.free.len() >= MAX_FREE {
            return;
        }
        let cap = v.capacity();
        self.free_bytes += cap * std::mem::size_of::<T>();
        // caller-allocated slabs entering through `put` can raise the
        // footprint without a `take` (they join the free list)
        self.peak_bytes = self.peak_bytes.max(self.out_bytes + self.free_bytes);
        let at = self
            .free
            .iter()
            .position(|u| u.capacity() >= cap)
            .unwrap_or(self.free.len());
        self.free.insert(at, v);
    }
}

/// The per-(thread, variant) scratch arena of the streaming step:
/// recycled `(C, B)` activation slabs plus reusable `Vec<Option<_>>`
/// holders for the per-layer encoder outputs.
#[derive(Debug)]
pub struct StepArena {
    /// Largest batch width seen so far; slab classes are sized to it.
    bcap: usize,
    f: Pool<f32>,
    i: Pool<i32>,
    opts_f: Vec<Vec<Option<Vec<f32>>>>,
    opts_i: Vec<Vec<Option<Vec<i32>>>>,
}

impl StepArena {
    /// A fresh arena for a variant's [`ArenaSpec`].
    pub fn new(spec: &ArenaSpec) -> StepArena {
        StepArena {
            bcap: 1,
            f: Pool {
                sizes: spec.f32_sizes.clone(),
                free: Vec::new(),
            },
            i: Pool {
                sizes: spec.i32_sizes.clone(),
                free: Vec::new(),
            },
            opts_f: Vec::new(),
            opts_i: Vec::new(),
        }
    }

    /// A zeroed `(per_stream, bsz)` f32 panel.
    pub fn take_f32(&mut self, per_stream: usize, bsz: usize) -> Vec<f32> {
        self.bcap = self.bcap.max(bsz);
        self.f.take(per_stream, bsz, self.bcap)
    }

    /// Return an f32 panel for reuse.
    pub fn put_f32(&mut self, v: Vec<f32>) {
        self.f.put(v);
    }

    /// Return an optional f32 panel for reuse, leaving `None` behind.
    pub fn release_f32(&mut self, o: &mut Option<Vec<f32>>) {
        if let Some(v) = o.take() {
            self.f.put(v);
        }
    }

    /// A zeroed `(per_stream, bsz)` i32 code panel (quantized path).
    pub fn take_i32(&mut self, per_stream: usize, bsz: usize) -> Vec<i32> {
        self.bcap = self.bcap.max(bsz);
        self.i.take(per_stream, bsz, self.bcap)
    }

    /// Return an i32 panel for reuse.
    pub fn put_i32(&mut self, v: Vec<i32>) {
        self.i.put(v);
    }

    /// Return an optional i32 panel for reuse, leaving `None` behind.
    pub fn release_i32(&mut self, o: &mut Option<Vec<i32>>) {
        if let Some(v) = o.take() {
            self.i.put(v);
        }
    }

    /// A reusable `n`-slot `Vec<Option<Vec<f32>>>` (all `None`) — the
    /// per-layer encoder-output holder.
    pub fn take_opts_f32(&mut self, n: usize) -> Vec<Option<Vec<f32>>> {
        let mut v = self.opts_f.pop().unwrap_or_default();
        v.clear();
        v.resize_with(n, || None);
        v
    }

    /// Return an opts holder; inner panels drain back into the pool.
    pub fn put_opts_f32(&mut self, mut v: Vec<Option<Vec<f32>>>) {
        for o in v.iter_mut() {
            self.release_f32(o);
        }
        v.clear();
        if self.opts_f.len() < 4 {
            self.opts_f.push(v);
        }
    }

    /// i32 twin of [`StepArena::take_opts_f32`].
    pub fn take_opts_i32(&mut self, n: usize) -> Vec<Option<Vec<i32>>> {
        let mut v = self.opts_i.pop().unwrap_or_default();
        v.clear();
        v.resize_with(n, || None);
        v
    }

    /// i32 twin of [`StepArena::put_opts_f32`].
    pub fn put_opts_i32(&mut self, mut v: Vec<Option<Vec<i32>>>) {
        for o in v.iter_mut() {
            self.release_i32(o);
        }
        v.clear();
        if self.opts_i.len() < 4 {
            self.opts_i.push(v);
        }
    }

    /// Peak scratch footprint of this arena in bytes: the high-water
    /// mark of slab capacity lent out plus slab capacity parked on the
    /// free lists, across both element types.  (The small `Vec<Option>`
    /// holders are not counted — they hold pointers, not panels.)
    /// Monotone over the arena's lifetime; allocation-free to read.
    pub fn peak_bytes(&self) -> usize {
        self.f.peak_bytes + self.i.peak_bytes
    }
}

/// Process-unique arena id for one compiled variant (assigned at
/// variant-compile time; keys the per-thread arena registry).
pub fn next_arena_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

thread_local! {
    /// Per-thread arena registry, linear-scanned by variant id (a
    /// handful of live variants per worker; no hashing, no allocation
    /// on the hot path).
    static ARENAS: RefCell<Vec<(u64, StepArena)>> = const { RefCell::new(Vec::new()) };
}

/// Run `f` with this thread's arena for variant `id`, creating it from
/// `spec` on first use.  Reentrant use (calling `with_arena` from inside
/// `f`) is a programming error and panics — the interpreters never nest
/// steps on one thread.
pub fn with_arena<R>(id: u64, spec: &ArenaSpec, f: impl FnOnce(&mut StepArena) -> R) -> R {
    ARENAS.with(|cell| {
        let mut arenas = cell.borrow_mut();
        let idx = match arenas.iter().position(|(k, _)| *k == id) {
            Some(i) => i,
            None => {
                if arenas.len() >= MAX_ARENAS {
                    arenas.remove(0);
                }
                arenas.push((id, StepArena::new(spec)));
                arenas.len() - 1
            }
        };
        // Keep the registry in least-recently-used order (front =
        // eviction candidate).  A steady single-variant worker finds its
        // arena already at the back, so this rotates nothing.
        let last = arenas.len() - 1;
        if idx != last {
            arenas[idx..].rotate_left(1);
        }
        f(&mut arenas[last].1)
    })
}

/// Peak scratch bytes of *this thread's* arena for variant `id`
/// ([`StepArena::peak_bytes`]); `None` if the thread never stepped the
/// variant or the arena was LRU-evicted (evicted peaks are forgotten —
/// the registry is bounded, and so is this gauge's memory).
/// Allocation-free: a linear scan of the thread's arena registry.
pub fn peak_bytes_of(id: u64) -> Option<usize> {
    ARENAS.with(|cell| {
        cell.borrow()
            .iter()
            .find(|(k, _)| *k == id)
            .map(|(_, a)| a.peak_bytes())
    })
}

/// Sum of [`StepArena::peak_bytes`] over all of this thread's live
/// arenas — an upper bound on the thread's peak scratch RAM (individual
/// peaks need not be simultaneous).  Allocation-free; serving workers
/// poll this once per round into the `arena_peak_bytes` gauge.
pub fn thread_peak_bytes() -> usize {
    ARENAS.with(|cell| cell.borrow().iter().map(|(_, a)| a.peak_bytes()).sum())
}

// ---- bounded offline pool --------------------------------------------------

/// Max buffers retained by the offline pool per thread.
const OFFLINE_MAX_BUFS: usize = 8;
/// Max total f32 elements retained by the offline pool per thread (16 MB).
const OFFLINE_MAX_ELEMS: usize = 1 << 22;

thread_local! {
    static OFFLINE: RefCell<Vec<Vec<f32>>> = const { RefCell::new(Vec::new()) };
}

/// Take a zeroed length-`n` buffer from the bounded per-thread offline
/// pool (full-sequence paths, whose sizes scale with `T` rather than the
/// manifest).  Capacities are power-of-two classes so varying sequence
/// lengths still recycle.
pub fn offline_take(n: usize) -> Vec<f32> {
    OFFLINE.with(|p| {
        let mut pool = p.borrow_mut();
        let mut v = match pool.iter().position(|v| v.capacity() >= n) {
            Some(i) => pool.remove(i),
            None => Vec::with_capacity(n.next_power_of_two()),
        };
        v.clear();
        v.resize(n, 0.0);
        v
    })
}

/// Return a buffer to the offline pool; buffers beyond the pool's count
/// or byte bound are dropped instead of retained.
pub fn offline_put(v: Vec<f32>) {
    OFFLINE.with(|p| {
        let mut pool = p.borrow_mut();
        let held: usize = pool.iter().map(|b| b.capacity()).sum();
        if pool.len() >= OFFLINE_MAX_BUFS || held + v.capacity() > OFFLINE_MAX_ELEMS {
            return;
        }
        let cap = v.capacity();
        let at = pool
            .iter()
            .position(|u| u.capacity() >= cap)
            .unwrap_or(pool.len());
        pool.insert(at, v);
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_and_class_sized() {
        let spec = ArenaSpec::new(vec![16, 4, 16, 0], vec![8]);
        assert_eq!(spec.f32_sizes, vec![4, 16]);
        let mut a = StepArena::new(&spec);
        let v = a.take_f32(3, 2);
        assert_eq!(v.len(), 6);
        assert!(v.iter().all(|&x| x == 0.0));
        // allocated at class capacity: smallest class >= 3 is 4, bcap 2
        assert!(v.capacity() >= 8);
        a.put_f32(v);
    }

    #[test]
    fn steady_state_reuses_slabs() {
        let spec = ArenaSpec::new(vec![4, 8], vec![]);
        let mut a = StepArena::new(&spec);
        // warm up at batch 4
        let w = a.take_f32(8, 4);
        let ptr = w.as_ptr();
        a.put_f32(w);
        // smaller request reuses the same slab (best fit finds it)
        let v = a.take_f32(4, 4);
        assert_eq!(v.as_ptr(), ptr);
        assert!(v.iter().all(|&x| x == 0.0));
        a.put_f32(v);
    }

    #[test]
    fn batch_capacity_ratchets_up() {
        let spec = ArenaSpec::new(vec![4], vec![]);
        let mut a = StepArena::new(&spec);
        let first = a.take_f32(4, 1);
        a.put_f32(first);
        let v = a.take_f32(4, 16); // larger batch: slab must grow
        assert_eq!(v.len(), 64);
        assert!(v.capacity() >= 64);
        a.put_f32(v);
        // new capacity class now serves batch-1 requests too
        let w = a.take_f32(4, 1);
        assert!(w.capacity() >= 64);
        a.put_f32(w);
    }

    #[test]
    fn opts_holder_recycles_inner_buffers() {
        let spec = ArenaSpec::new(vec![4], vec![4]);
        let mut a = StepArena::new(&spec);
        let mut opts = a.take_opts_f32(3);
        assert_eq!(opts.len(), 3);
        opts[1] = Some(a.take_f32(4, 1));
        let inner = opts[1].as_ref().unwrap().as_ptr();
        a.put_opts_f32(opts);
        // the inner buffer went back to the pool
        let v = a.take_f32(4, 1);
        assert_eq!(v.as_ptr(), inner);
        a.put_f32(v);
    }

    #[test]
    fn with_arena_is_keyed_by_id() {
        let spec = ArenaSpec::new(vec![2], vec![]);
        let (a, b) = (next_arena_id(), next_arena_id());
        assert_ne!(a, b);
        let pa = with_arena(a, &spec, |ar| {
            let v = ar.take_f32(2, 1);
            let p = v.as_ptr();
            ar.put_f32(v);
            p
        });
        // same id, same thread: the slab is still there
        let pa2 = with_arena(a, &spec, |ar| {
            let v = ar.take_f32(2, 1);
            let p = v.as_ptr();
            ar.put_f32(v);
            p
        });
        assert_eq!(pa, pa2);
        // different id: fresh arena, fresh slab
        let pb = with_arena(b, &spec, |ar| {
            let v = ar.take_f32(2, 1);
            let p = v.as_ptr();
            ar.put_f32(v);
            p
        });
        let _ = pb;
    }

    #[test]
    fn peak_bytes_is_a_monotone_high_water_mark() {
        let spec = ArenaSpec::new(vec![4], vec![4]);
        let mut a = StepArena::new(&spec);
        assert_eq!(a.peak_bytes(), 0);
        let v = a.take_f32(4, 2);
        let expect = v.capacity() * std::mem::size_of::<f32>();
        assert_eq!(a.peak_bytes(), expect);
        a.put_f32(v);
        // returning a slab never lowers the peak
        assert_eq!(a.peak_bytes(), expect);
        // reusing the same slab never raises it
        let v = a.take_f32(4, 2);
        assert_eq!(a.peak_bytes(), expect);
        // two slabs live at once: the peak ratchets up
        let w = a.take_f32(4, 2);
        assert!(a.peak_bytes() >= 2 * expect);
        let peak = a.peak_bytes();
        a.put_f32(v);
        a.put_f32(w);
        assert_eq!(a.peak_bytes(), peak);
        // i32 pool contributes too
        let z = a.take_i32(4, 1);
        assert!(a.peak_bytes() > peak);
        a.put_i32(z);
    }

    #[test]
    fn thread_peak_queries_see_with_arena_state() {
        let spec = ArenaSpec::new(vec![8], vec![]);
        let id = next_arena_id();
        assert_eq!(peak_bytes_of(id), None);
        let inner = with_arena(id, &spec, |ar| {
            let v = ar.take_f32(8, 1);
            ar.put_f32(v);
            ar.peak_bytes()
        });
        assert!(inner > 0);
        assert_eq!(peak_bytes_of(id), Some(inner));
        assert!(thread_peak_bytes() >= inner);
    }

    #[test]
    fn offline_pool_recycles_and_bounds() {
        let v = offline_take(100);
        assert_eq!(v.len(), 100);
        assert!(v.capacity() >= 128); // power-of-two class
        let p = v.as_ptr();
        offline_put(v);
        let w = offline_take(64);
        assert_eq!(w.as_ptr(), p);
        offline_put(w);
        // oversized buffers are dropped, not retained
        offline_put(Vec::with_capacity(OFFLINE_MAX_ELEMS + 1));
    }
}
