//! Packed weight panels: the cache-blocked, pre-transposed weight layout
//! every GEMM microkernel in this module consumes (DESIGN.md §11).
//!
//! A conv weight `(C_out, C_in, K)` is repacked **once at upload time**
//! into panels of [`MR`] output channels: within a panel, the `MR` lanes
//! of each reduction index `j` sit contiguously, so the inner GEMM loop
//! is one contiguous vector load per `j` — no strides, no gathers —
//! regardless of the batch width.  Panels whose last rows run past
//! `C_out` are zero-padded; kernels compute the padded lanes and store
//! only the valid ones.
//!
//! Layout (f32): `data[(p · N + j) · MR + m] = w[(p · MR + m) · N + j]`
//! for panel `p`, reduction index `j in 0..N`, lane `m in 0..MR`.
//!
//! The int8 variant additionally carries the per-(out, in) combine
//! factors `g(o, i) = s_x(i) · s_w(o, i)` and the f32 bias in the same
//! lane-padded layout, so the quantized kernel's group fold is also one
//! contiguous load per lane group.

use crate::util::tensor::Tensor;

/// Panel height: output channels per packed panel (AVX2 f32 lane count;
/// NEON kernels process a panel as two 4-lane halves, scalar as a loop).
pub const MR: usize = 8;

/// A conv weight repacked into [`MR`]-row, pre-transposed f32 panels.
#[derive(Debug, Clone)]
pub struct PackedF32 {
    /// Output channels (valid rows; the last panel may be padded).
    pub c_out: usize,
    /// Reduction length (`C_in · K` for a flattened conv kernel).
    pub n: usize,
    /// Panel-major packed weights, `panels() · n · MR` elements.
    pub(crate) data: Vec<f32>,
}

impl PackedF32 {
    /// Pack a row-major `(c_out, n)` weight matrix into panels.
    pub fn pack(w: &[f32], c_out: usize, n: usize) -> PackedF32 {
        assert_eq!(w.len(), c_out * n, "weight matrix shape mismatch");
        let panels = c_out.div_ceil(MR);
        let mut data = vec![0.0f32; panels * n * MR];
        for o in 0..c_out {
            let (p, m) = (o / MR, o % MR);
            for j in 0..n {
                data[(p * n + j) * MR + m] = w[o * n + j];
            }
        }
        PackedF32 { c_out, n, data }
    }

    /// Pack a rank-3 conv kernel `(C_out, C_in, K)` as the GEMM matrix
    /// `(C_out, C_in · K)` (the layout of the streaming window panels).
    /// Returns `None` for tensors that are not rank-3.
    pub fn from_conv(t: &Tensor) -> Option<PackedF32> {
        if t.shape.len() != 3 {
            return None;
        }
        Some(Self::pack(&t.data, t.shape[0], t.shape[1] * t.shape[2]))
    }

    /// Pack one tap `j = tap` of a rank-3 kernel `(C_out, C_in, K)` as a
    /// `(C_out, C_in)` matrix — the per-phase matrix of a stride-2
    /// transposed conv.  Returns `None` unless the tensor is rank-3 and
    /// `tap < K`.
    pub fn from_conv_tap(t: &Tensor, tap: usize) -> Option<PackedF32> {
        if t.shape.len() != 3 || tap >= t.shape[2] {
            return None;
        }
        let (c_out, c_in, k) = (t.shape[0], t.shape[1], t.shape[2]);
        let panels = c_out.div_ceil(MR);
        let mut data = vec![0.0f32; panels * c_in * MR];
        for o in 0..c_out {
            let (p, m) = (o / MR, o % MR);
            for i in 0..c_in {
                data[(p * c_in + i) * MR + m] = t.data[(o * c_in + i) * k + tap];
            }
        }
        Some(PackedF32 {
            c_out,
            n: c_in,
            data,
        })
    }

    /// Number of [`MR`]-row panels (the last may be partial).
    pub fn panels(&self) -> usize {
        self.c_out.div_ceil(MR)
    }

    /// Reconstruct the row-major `(c_out, n)` matrix this packing holds
    /// (tests and the pack-roundtrip property).
    pub fn unpack(&self) -> Vec<f32> {
        let mut w = vec![0.0f32; self.c_out * self.n];
        for o in 0..self.c_out {
            let (p, m) = (o / MR, o % MR);
            for j in 0..self.n {
                w[o * self.n + j] = self.data[(p * self.n + j) * MR + m];
            }
        }
        w
    }
}

/// An int8 conv kernel repacked into [`MR`]-row panels, with the f32
/// combine factors and bias pre-padded into the same lane layout.
///
/// Layout: codes `data[((p · C_in + i) · K + j) · MR + m]`, factors
/// `g[(p · C_in + i) · MR + m]`, bias `bias[p · MR + m]` — every slice a
/// kernel touches is a contiguous [`MR`]-lane group.
#[derive(Debug, Clone)]
pub struct PackedI8 {
    /// Output channels (valid rows; the last panel may be padded).
    pub c_out: usize,
    /// Input channels (one combine-factor group per input channel).
    pub c_in: usize,
    /// Taps per (out, in) group — the integer dot length.
    pub k: usize,
    pub(crate) data: Vec<i8>,
    pub(crate) g: Vec<f32>,
    pub(crate) bias: Vec<f32>,
}

impl PackedI8 {
    /// Total packed footprint, bytes (codes + combine factors + bias) —
    /// what a quantized (re)pack materializes, reported in the
    /// `quant_repack` health-feed event.
    pub fn bytes(&self) -> usize {
        self.data.len() + (self.g.len() + self.bias.len()) * std::mem::size_of::<f32>()
    }

    /// Pack row-major int8 codes `(c_out, c_in, k)` with per-(out, in)
    /// combine factors `g` (row-major `(c_out, c_in)`) and per-channel
    /// f32 `bias`.
    pub fn pack(
        codes: &[i8],
        c_out: usize,
        c_in: usize,
        k: usize,
        g: &[f32],
        bias: &[f32],
    ) -> PackedI8 {
        assert_eq!(codes.len(), c_out * c_in * k, "code tensor shape mismatch");
        assert_eq!(g.len(), c_out * c_in, "combine factor shape mismatch");
        assert_eq!(bias.len(), c_out, "bias shape mismatch");
        let panels = c_out.div_ceil(MR);
        let mut pdata = vec![0i8; panels * c_in * k * MR];
        let mut pg = vec![0.0f32; panels * c_in * MR];
        let mut pbias = vec![0.0f32; panels * MR];
        for o in 0..c_out {
            let (p, m) = (o / MR, o % MR);
            pbias[p * MR + m] = bias[o];
            for i in 0..c_in {
                pg[(p * c_in + i) * MR + m] = g[o * c_in + i];
                for j in 0..k {
                    pdata[((p * c_in + i) * k + j) * MR + m] = codes[(o * c_in + i) * k + j];
                }
            }
        }
        PackedI8 {
            c_out,
            c_in,
            k,
            data: pdata,
            g: pg,
            bias: pbias,
        }
    }

    /// Pack one tap `j = tap` of row-major codes `(c_out, c_in, k_total)`
    /// as a 1-tap panel — the per-phase kernel of a quantized stride-2
    /// transposed conv (`k == 1`, same `g`/`bias`).
    pub fn pack_tap(
        codes: &[i8],
        c_out: usize,
        c_in: usize,
        k_total: usize,
        tap: usize,
        g: &[f32],
        bias: &[f32],
    ) -> PackedI8 {
        assert!(tap < k_total, "tap {tap} out of range 0..{k_total}");
        assert_eq!(
            codes.len(),
            c_out * c_in * k_total,
            "code tensor shape mismatch"
        );
        let slice: Vec<i8> = (0..c_out * c_in)
            .map(|oi| codes[oi * k_total + tap])
            .collect();
        Self::pack(&slice, c_out, c_in, 1, g, bias)
    }

    /// Number of [`MR`]-row panels (the last may be partial).
    pub fn panels(&self) -> usize {
        self.c_out.div_ceil(MR)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_pack_roundtrips_and_pads() {
        // 10 output rows -> 2 panels, last padded to 16 lanes
        let c_out = 10;
        let n = 3;
        let w: Vec<f32> = (0..c_out * n).map(|i| i as f32 * 0.5 - 7.0).collect();
        let p = PackedF32::pack(&w, c_out, n);
        assert_eq!(p.panels(), 2);
        assert_eq!(p.data.len(), 2 * n * MR);
        assert_eq!(p.unpack(), w);
        // padded lanes are zero
        for j in 0..n {
            for m in 2..MR {
                assert_eq!(p.data[(n + j) * MR + m], 0.0);
            }
        }
    }

    #[test]
    fn f32_conv_tap_selects_phase_matrix() {
        // (2, 2, 2) kernel: tap 1 keeps w[o][i][1]
        let t = Tensor::new(vec![2, 2, 2], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let p = PackedF32::from_conv_tap(&t, 1).unwrap();
        assert_eq!(p.c_out, 2);
        assert_eq!(p.n, 2);
        assert_eq!(p.unpack(), vec![2.0, 4.0, 6.0, 8.0]);
        assert!(PackedF32::from_conv_tap(&t, 2).is_none());
    }

    #[test]
    fn i8_pack_lanes_hold_codes_factors_and_bias() {
        let (c_out, c_in, k) = (3, 2, 2);
        let codes: Vec<i8> = (0..c_out * c_in * k).map(|i| i as i8 - 5).collect();
        let g: Vec<f32> = (0..c_out * c_in).map(|i| 0.1 * (i + 1) as f32).collect();
        let bias = [1.0f32, -2.0, 3.0];
        let p = PackedI8::pack(&codes, c_out, c_in, k, &g, &bias);
        assert_eq!(p.panels(), 1);
        for o in 0..c_out {
            assert_eq!(p.bias[o], bias[o]);
            for i in 0..c_in {
                assert_eq!(p.g[i * MR + o], g[o * c_in + i]);
                for j in 0..k {
                    assert_eq!(p.data[(i * k + j) * MR + o], codes[(o * c_in + i) * k + j]);
                }
            }
        }
        // padded lanes stay zero
        assert_eq!(p.bias[3], 0.0);
        assert_eq!(p.g[3], 0.0);
    }

    #[test]
    fn i8_pack_tap_is_one_tap_panel() {
        let (c_out, c_in, k) = (2, 2, 2);
        let codes: Vec<i8> = vec![1, 2, 3, 4, 5, 6, 7, 8];
        let g = vec![1.0f32; 4];
        let bias = vec![0.0f32; 2];
        let p = PackedI8::pack_tap(&codes, c_out, c_in, k, 1, &g, &bias);
        assert_eq!(p.k, 1);
        // tap 1 of (o, i): 2, 4, 6, 8
        assert_eq!(p.data[0], 2); // o=0, i=0
        assert_eq!(p.data[MR], 4); // o=0, i=1
        assert_eq!(p.data[1], 6); // o=1, i=0
        assert_eq!(p.data[MR + 1], 8); // o=1, i=1
    }
}
