//! Portable scalar microkernels — the fallback on ISAs without a SIMD
//! implementation and the correctness oracle every SIMD path is tested
//! against (`rust/tests/properties.rs`).
//!
//! Numeric contract (DESIGN.md §11): for every output element `(o, b)`
//! the accumulation is *bias first, then reduction indices in ascending
//! order*, one multiply and one add per index (no fusing).  This is
//! exactly the order the pre-panel interpreter used, so the scalar
//! kernels reproduce its results bit-for-bit; it is also independent of
//! the batch width, so batched and sequential execution agree
//! bit-for-bit on every ISA family.

use super::elu_scalar;
use super::pack::{PackedF32, PackedI8, MR};

/// Scalar panel GEMM: `out = [elu](P · x + bias)` over a column-stacked
/// `(n, bsz)` activation panel, writing `(c_out, bsz)` row-major.
pub(super) fn gemm_f32(
    p: &PackedF32,
    bias: &[f32],
    x: &[f32],
    bsz: usize,
    out: &mut [f32],
    elu: bool,
) {
    let n = p.n;
    for pi in 0..p.panels() {
        let o0 = pi * MR;
        let rows = MR.min(p.c_out - o0);
        let pd = &p.data[pi * n * MR..(pi + 1) * n * MR];
        for b in 0..bsz {
            let mut acc = [0.0f32; MR];
            acc[..rows].copy_from_slice(&bias[o0..o0 + rows]);
            for j in 0..n {
                let xv = x[j * bsz + b];
                let w = &pd[j * MR..j * MR + MR];
                for m in 0..MR {
                    acc[m] += w[m] * xv;
                }
            }
            for m in 0..rows {
                let v = acc[m];
                out[(o0 + m) * bsz + b] = if elu { elu_scalar(v) } else { v };
            }
        }
    }
}

/// Scalar quantized panel GEMM: i32 group dots over s16 activation codes
/// with the fixed-order f32 fold `pre += g(o, i) · acc` and the bias
/// added last — the exact per-element order of the reference kernel
/// `crate::quant::kernels::conv_win_batch_q`, so results are
/// bit-identical to it (and to the SIMD implementations, which use the
/// same unfused per-lane operations).
pub(super) fn gemm_i8(p: &PackedI8, x: &[i32], bsz: usize, out: &mut [f32]) {
    let (c_in, k) = (p.c_in, p.k);
    for pi in 0..p.panels() {
        let o0 = pi * MR;
        let rows = MR.min(p.c_out - o0);
        for b in 0..bsz {
            let mut pre = [0.0f32; MR];
            for i in 0..c_in {
                let mut acc = [0i32; MR];
                for j in 0..k {
                    let w = &p.data[((pi * c_in + i) * k + j) * MR..][..MR];
                    let xv = x[(i * k + j) * bsz + b];
                    for m in 0..MR {
                        acc[m] += w[m] as i32 * xv;
                    }
                }
                let g = &p.g[(pi * c_in + i) * MR..][..MR];
                for m in 0..MR {
                    pre[m] += g[m] * acc[m] as f32;
                }
            }
            for m in 0..rows {
                out[(o0 + m) * bsz + b] = pre[m] + p.bias[pi * MR + m];
            }
        }
    }
}
