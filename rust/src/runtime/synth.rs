//! Synthesized variants: build a [`Manifest`] + deterministic
//! He-initialised [`Weights`] for any [`ModelConfig`] entirely in Rust —
//! no Python, no artifacts directory, no network.
//!
//! This powers the offline path of the examples, benches and the
//! native-backend cross-check tests: `soi serve scc5`, `cargo bench` and
//! `cargo test` all work on a fresh clone.  Synthesized weights are
//! *untrained* — latency, throughput, complexity accounting and
//! streaming/offline equivalence are all meaningful; SI-SNRi quality
//! numbers are not (train real artifacts with `python/compile` for
//! those).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Result};

use super::engine::{CompiledVariant, Runtime, Weights};
use super::manifest::{Dtype, LayerMacs, Manifest, ModelConfig, TensorSpec};
use crate::backend::native::state_specs;
use crate::complexity::unet;
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;

/// Calibration frames used when synthesizing an int8 variant's baked
/// quant params (`quant::calibrate` over synthesized activations).
pub const CALIBRATION_FRAMES: usize = 512;

/// Parameter inventory of a config, in canonical (manifest/weights.bin)
/// order — mirrors `python/compile/model.py::init_params`.
pub fn param_specs(cfg: &ModelConfig) -> Vec<TensorSpec> {
    let k = cfg.kernel;
    let mut specs = Vec::new();
    let mut conv = |name: String, c_out: usize, c_in: usize, kk: usize| {
        specs.push(TensorSpec {
            name: format!("{name}.w"),
            shape: vec![c_out, c_in, kk],
        });
        specs.push(TensorSpec {
            name: format!("{name}.b"),
            shape: vec![c_out],
        });
    };
    for l in 1..=cfg.depth() {
        conv(format!("enc{l}"), cfg.enc_out_ch(l), cfg.enc_in_ch(l), k);
    }
    for l in (1..=cfg.depth()).rev() {
        conv(format!("dec{l}"), cfg.dec_out_ch(l), cfg.dec_in_ch(l), k);
    }
    for &p in &cfg.scc {
        if cfg.extrap_of(p) == "tconv" {
            conv(format!("up{p}"), cfg.dec_out_ch(p), cfg.dec_out_ch(p), 2);
        }
    }
    conv("head".to_string(), cfg.feat, cfg.dec_out_ch(1), 1);
    specs
}

/// Build a complete in-memory manifest for a config: state/param specs,
/// the `layer_macs` table (from the analytic complexity engine, so the
/// two accountings agree by construction), and aggregate stats.  The
/// executables map is empty — this manifest is native-backend only.
pub fn manifest(cfg: &ModelConfig, name: &str, offline_t: usize) -> Manifest {
    let fps = unet::frame_rate(cfg.feat, 16_000.0);
    let net = unet::network(cfg, offline_t as u64, fps);
    let states = state_specs(cfg);
    let params = param_specs(cfg);
    let param_count = params.iter().map(|p| p.elements()).sum();
    let state_bytes = states.iter().map(|s| s.elements() * 4).sum();
    Manifest {
        name: name.to_string(),
        config: cfg.clone(),
        dtype: Dtype::F32,
        quant: None,
        period: cfg.period(),
        streamable: cfg.interp.is_none(),
        offline_t,
        packed_states: 0,
        states,
        params,
        executables: BTreeMap::new(),
        layer_macs: net
            .layers
            .iter()
            .map(|l| LayerMacs {
                name: l.name.clone(),
                macs: l.macs_per_out,
                rate_div: l.rate_div,
            })
            .collect(),
        macs_per_frame: net.soi_macs_per_frame(),
        precomputed_fraction: net.precomputed_pct() / 100.0,
        param_count,
        state_bytes,
        train_metrics: BTreeMap::new(),
        dir: PathBuf::new(),
    }
}

/// Deterministic He-initialised weights for a manifest: conv kernels are
/// `normal * sqrt(2 / fan_in)`, biases zero — the same init scheme as
/// `python/compile/model.py`, driven by `util::rng` so every build of the
/// same (manifest, seed) pair yields identical tensors.
pub fn he_weights(manifest: &Manifest, seed: u64) -> Weights {
    let mut rng = Rng::new(seed);
    let tensors = manifest
        .params
        .iter()
        .map(|spec| {
            let n = spec.elements();
            let data = if spec.shape.len() == 1 {
                vec![0.0f32; n] // bias
            } else {
                let fan_in: usize = spec.shape[1..].iter().product();
                let scale = (2.0 / fan_in as f64).sqrt();
                (0..n).map(|_| (rng.normal() * scale) as f32).collect()
            };
            Tensor::new(spec.shape.clone(), data)
        })
        .collect();
    Weights { tensors }
}

/// Synthesize and compile a variant in one call (f32 execution).
pub fn variant(
    rt: Arc<Runtime>,
    cfg: &ModelConfig,
    name: &str,
    seed: u64,
) -> Result<CompiledVariant> {
    variant_with_dtype(rt, cfg, name, seed, Dtype::F32)
}

/// Synthesize and compile a variant at an explicit precision.
///
/// `Dtype::Int8` additionally bakes quant params into the manifest:
/// `quant::calibrate` ranges the f32 reference over
/// [`CALIBRATION_FRAMES`] synthesized frames (seeded deterministically
/// from `seed`), so the same `(cfg, name, seed)` triple always yields
/// the same quantized executable.  The weight tensors themselves are the
/// same He-initialised f32 set either way — an f32 and an int8 variant
/// of one config are weight-compatible ladder rungs.
pub fn variant_with_dtype(
    rt: Arc<Runtime>,
    cfg: &ModelConfig,
    name: &str,
    seed: u64,
    dtype: Dtype,
) -> Result<CompiledVariant> {
    let mut m = manifest(cfg, name, 256);
    let w = he_weights(&m, seed);
    if dtype == Dtype::Int8 {
        m.dtype = Dtype::Int8;
        m.quant = Some(crate::quant::calibrate(
            &m,
            &w,
            CALIBRATION_FRAMES,
            seed ^ 0x5EED_CA1B,
        )?);
    }
    CompiledVariant::with_weights(rt, m, w)
}

/// Split a `name[:dtype]` variant spec ("scc2", "stmc:int8") into its
/// base name and execution precision (f32 when no suffix is given).
pub fn parse_spec(spec: &str) -> Result<(&str, Dtype)> {
    match spec.split_once(':') {
        None => Ok((spec, Dtype::F32)),
        Some((base, d)) => Ok((base, Dtype::parse(d)?)),
    }
}

/// Map an artifact-style variant name to its config, using the default
/// 7-layer U-Net topology (`complexity::unet::default_config`).  The
/// name grammar matches the artifact registry in `python/compile/aot.py`
/// so synthesized and built variants of the same name share a topology:
///
/// * `stmc` — pure STMC (no compression)
/// * `scc<p>` — single S-CC at encoder position p (1..=7)
/// * `scc<p>_<q>` — double S-CC at positions p < q
/// * `sscc<p>` — SS-CC: S-CC at p with the FP shift at p
/// * `fp<p>_<q>` — S-CC at p with the FP shift above it at q (p < q)
/// * `pred<n>` — fully predictive: no compression, shift n at layer 1
/// * `spred<n>` — strided-predictive (App. B): S-CC 4, shift n at layer 1
///
/// Any spec may carry a `:<dtype>` suffix (`scc2:int8`) selecting the
/// execution precision; [`parse_spec`] splits it off, `preset` itself
/// takes base names only.
pub fn preset(name: &str) -> Option<ModelConfig> {
    preset_over(&unet::default_config(vec![], None), name)
}

/// [`preset`] generalized over an arbitrary base topology: the same name
/// grammar, but `feat` / `channels` / `kernel` come from `base` (so the
/// valid position range is `1..=base.depth()`, not the default 7).  This
/// is how ladder rung specs are resolved against a loaded weight
/// artifact (DESIGN.md §13): every rung reshapes the *schedule* of the
/// artifact's topology, never its parameter inventory, so all rungs stay
/// weight-compatible with the shipped tensors.  The base's own schedule
/// fields (`scc` / `shift_pos` / `shift` / `interp`) are ignored — the
/// rung name alone defines them.
pub fn preset_over(base: &ModelConfig, name: &str) -> Option<ModelConfig> {
    let depth = base.depth();
    let pos = |s: &str| -> Option<usize> {
        let p: usize = s.parse().ok()?;
        (1..=depth).contains(&p).then_some(p)
    };
    let pair = |s: &str| -> Option<(usize, usize)> {
        let (a, b) = s.split_once('_')?;
        let (p, q) = (pos(a)?, pos(b)?);
        (p < q).then_some((p, q))
    };
    let shift_len = |s: &str| -> Option<usize> {
        let n: usize = s.parse().ok()?;
        (1..=4).contains(&n).then_some(n)
    };
    let build = |scc: Vec<usize>, shift_pos: Option<usize>, shift: usize| -> ModelConfig {
        ModelConfig {
            feat: base.feat,
            channels: base.channels.clone(),
            kernel: base.kernel,
            extrap: vec!["duplicate".into(); scc.len()],
            scc,
            shift_pos,
            shift,
            interp: None,
        }
    };
    if name == "stmc" {
        return Some(build(vec![], None, 1));
    }
    if let Some(rest) = name.strip_prefix("sscc") {
        let p = pos(rest)?;
        return Some(build(vec![p], Some(p), 1));
    }
    if let Some(rest) = name.strip_prefix("scc") {
        if let Some((p, q)) = pair(rest) {
            return Some(build(vec![p, q], None, 1));
        }
        return Some(build(vec![pos(rest)?], None, 1));
    }
    if let Some(rest) = name.strip_prefix("fp") {
        let (p, q) = pair(rest)?;
        return Some(build(vec![p], Some(q), 1));
    }
    if let Some(rest) = name.strip_prefix("spred") {
        let shift = shift_len(rest)?;
        if depth < 4 {
            return None; // the strided-predictive preset compresses at 4
        }
        return Some(build(vec![4], Some(1), shift));
    }
    if let Some(rest) = name.strip_prefix("pred") {
        return Some(build(vec![], Some(1), shift_len(rest)?));
    }
    None
}

/// Load a variant from `artifacts/<spec>` when built, otherwise
/// synthesize it from its preset config (untrained weights).  Returns
/// `(variant, synthesized)`.
///
/// `spec` follows the `name[:dtype]` grammar; a suffixed spec whose
/// exact directory is not built resolves to the *base* artifact
/// (`artifacts/scc2` for both `scc2:f32` and `scc2:int8`).  An int8
/// spec loading a built f32 base gets its quant params calibrated on
/// the fly — trained artifacts quantize without a separate build step;
/// an explicit `:f32` spec loads the base artifact verbatim.
pub fn load_or_synth(
    rt: Arc<Runtime>,
    artifacts: &std::path::Path,
    spec: &str,
    seed: u64,
) -> Result<(CompiledVariant, bool)> {
    let dir = artifacts.join(spec);
    if dir.join("manifest.json").exists() {
        return Ok((CompiledVariant::load(rt, &dir)?, false));
    }
    let (base, dtype) = parse_spec(spec)?;
    if base != spec {
        let base_dir = artifacts.join(base);
        if base_dir.join("manifest.json").exists() {
            let mut m = Manifest::load(&base_dir)?;
            let w = Weights::load(&m)?;
            if dtype == Dtype::Int8 && m.dtype != Dtype::Int8 {
                m.name = spec.to_string();
                m.dtype = Dtype::Int8;
                m.quant = Some(crate::quant::calibrate(
                    &m,
                    &w,
                    CALIBRATION_FRAMES,
                    seed ^ 0x5EED_CA1B,
                )?);
            }
            return Ok((CompiledVariant::with_weights(rt, m, w)?, false));
        }
    }
    let Some(cfg) = preset(base) else {
        bail!(
            "artifacts/{base} not built and '{base}' is not a known preset \
             (stmc | scc<p> | scc<p>_<q> | sscc<p> | fp<p>_<q> | pred<n>, \
             optionally suffixed :f32 | :int8)"
        );
    };
    Ok((variant_with_dtype(rt, &cfg, spec, seed, dtype)?, true))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_parse() {
        assert_eq!(preset("stmc").unwrap().scc, Vec::<usize>::new());
        assert_eq!(preset("scc5").unwrap().scc, vec![5]);
        assert_eq!(preset("scc2_5").unwrap().scc, vec![2, 5]);
        let ss = preset("sscc3").unwrap();
        assert_eq!(ss.scc, vec![3]);
        assert_eq!(ss.shift_pos, Some(3));
        // fp<p>_<q> matches aot.py: S-CC at p, shift above it at q.
        let fp = preset("fp1_3").unwrap();
        assert_eq!(fp.scc, vec![1]);
        assert_eq!(fp.shift_pos, Some(3));
        let pred = preset("pred2").unwrap();
        assert_eq!(pred.shift, 2);
        assert_eq!(pred.shift_pos, Some(1));
        assert_eq!(pred.scc, Vec::<usize>::new());
        let spred = preset("spred3").unwrap();
        assert_eq!(spred.scc, vec![4]);
        assert_eq!(spred.shift_pos, Some(1));
        assert_eq!(spred.shift, 3);
        assert!(preset("scc9").is_none());
        assert!(preset("scc5_2").is_none());
        assert!(preset("pred9").is_none());
        assert!(preset("bogus").is_none());
    }

    #[test]
    fn spec_grammar_splits_dtype() {
        assert_eq!(parse_spec("stmc").unwrap(), ("stmc", Dtype::F32));
        assert_eq!(parse_spec("scc2:int8").unwrap(), ("scc2", Dtype::Int8));
        assert_eq!(parse_spec("sscc5:f32").unwrap(), ("sscc5", Dtype::F32));
        assert!(parse_spec("stmc:fp16").is_err());
    }

    #[test]
    fn int8_synthesis_bakes_quant_params() {
        let rt = Arc::new(crate::runtime::Runtime::native());
        let cfg = ModelConfig {
            feat: 4,
            channels: vec![5, 6],
            kernel: 3,
            scc: vec![2],
            shift_pos: None,
            shift: 1,
            extrap: vec!["duplicate".into()],
            interp: None,
        };
        let cv = variant_with_dtype(rt.clone(), &cfg, "scc2:int8", 7, Dtype::Int8).unwrap();
        assert_eq!(cv.manifest.dtype, Dtype::Int8);
        assert!(cv.manifest.quant.is_some());
        // same seed ⇒ weight-compatible with the f32 twin
        let f32_cv = variant(rt, &cfg, "scc2", 7).unwrap();
        for (a, b) in cv.weights.tensors.iter().zip(&f32_cv.weights.tensors) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn manifest_matches_complexity_engine() {
        let cfg = unet::default_config(vec![2, 5], None);
        let m = manifest(&cfg, "scc2_5", 256);
        assert_eq!(m.period, 4);
        assert!(m.macs_per_frame > 0.0);
        // layer_macs must sum (rate-weighted) to macs_per_frame
        let avg: f64 = m
            .layer_macs
            .iter()
            .map(|l| l.macs as f64 / l.rate_div as f64)
            .sum();
        assert!((avg - m.macs_per_frame).abs() < 1e-9);
        assert_eq!(
            m.param_count,
            m.params.iter().map(|p| p.elements()).sum::<usize>()
        );
    }

    #[test]
    fn he_weights_are_deterministic_and_shaped() {
        let cfg = unet::default_config(vec![2], Some(2));
        let m = manifest(&cfg, "sscc2", 256);
        let a = he_weights(&m, 7);
        let b = he_weights(&m, 7);
        assert_eq!(a.total_params(), m.param_count);
        for (x, y) in a.tensors.iter().zip(&b.tensors) {
            assert_eq!(x.data, y.data);
        }
        // biases zero, kernels not
        let names: Vec<&str> = m.params.iter().map(|p| p.name.as_str()).collect();
        let bi = names.iter().position(|n| n.ends_with(".b")).unwrap();
        assert!(a.tensors[bi].data.iter().all(|&v| v == 0.0));
        assert!(a.tensors[0].data.iter().any(|&v| v != 0.0));
    }
}
