//! PJRT execution engine: compiles a variant's HLO-text artifacts and runs
//! them from the coordinator hot path.
//!
//! Implementation notes:
//!
//! * We execute with `execute_b` over device buffers, **not** `execute`
//!   over literals: the `xla` crate's `execute` path leaks one device
//!   buffer per argument per call (`buffer.release()` without a matching
//!   free in xla_rs.cc) — fatal for a long-running server at 500 fps.
//!   With `execute_b` we own the input buffers and they are freed on Drop.
//! * All step executables return one tuple (jax lowered with
//!   `return_tuple=True`); PJRT hands back a single tuple buffer which we
//!   copy to host and decompose.
//! * Weights are uploaded to the device once per variant (`DeviceWeights`)
//!   and shared by every stream; per-step uploads are just the frame and
//!   the per-stream partial states.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::manifest::Manifest;
use crate::util::tensor::{f32s_from_le_bytes, Tensor};

/// Shared PJRT client (CPU).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Compile one HLO-text file into a loaded executable.
    pub fn compile_file(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe })
    }

    /// Upload a host tensor to a device buffer.
    pub fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
            .context("uploading tensor")
    }

    /// Upload raw f32 data with explicit dims.
    pub fn upload_raw(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(data, dims, None)
            .context("uploading raw buffer")
    }
}

/// A compiled executable returning a single tuple.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute over device buffers; decompose the tuple into host tensors.
    pub fn run(&self, args: &[&xla::PjRtBuffer], out_shapes: &[Vec<usize>]) -> Result<Vec<Tensor>> {
        let results = self.exe.execute_b(args).context("execute_b")?;
        let buf = &results[0][0];
        let mut lit = buf.to_literal_sync().context("tuple to host")?;
        let parts = lit.decompose_tuple().context("decompose tuple")?;
        if parts.len() != out_shapes.len() {
            bail!(
                "executable returned {} outputs, expected {}",
                parts.len(),
                out_shapes.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (p, shape) in parts.into_iter().zip(out_shapes) {
            let data = p.to_vec::<f32>().context("tuple element to f32")?;
            out.push(Tensor::new(shape.clone(), data));
        }
        Ok(out)
    }
}

/// Host-side weights in manifest order (prunable).
#[derive(Debug, Clone)]
pub struct Weights {
    pub tensors: Vec<Tensor>,
}

impl Weights {
    /// Read `weights.bin` laid out per the manifest param specs.
    pub fn load(manifest: &Manifest) -> Result<Weights> {
        let path = manifest.dir.join("weights.bin");
        let bytes =
            std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        let vals = f32s_from_le_bytes(&bytes);
        let want: usize = manifest.params.iter().map(|p| p.elements()).sum();
        if vals.len() != want {
            bail!(
                "{}: weights.bin holds {} f32s, manifest wants {}",
                manifest.name,
                vals.len(),
                want
            );
        }
        let mut tensors = Vec::with_capacity(manifest.params.len());
        let mut off = 0;
        for spec in &manifest.params {
            let n = spec.elements();
            tensors.push(Tensor::new(spec.shape.clone(), vals[off..off + n].to_vec()));
            off += n;
        }
        Ok(Weights { tensors })
    }

    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Upload all weights once; shared across streams.
    pub fn to_device(&self, rt: &Runtime) -> Result<DeviceWeights> {
        let bufs = self
            .tensors
            .iter()
            .map(|t| rt.upload(t))
            .collect::<Result<Vec<_>>>()?;
        Ok(DeviceWeights { bufs })
    }
}

/// Device-resident weights.
pub struct DeviceWeights {
    pub bufs: Vec<xla::PjRtBuffer>,
}

/// One compiled SOI variant: all executables + manifest + weights.
pub struct CompiledVariant {
    pub manifest: Manifest,
    pub weights: Weights,
    // Phases with identical graphs share one compiled executable (Arc).
    step: Vec<Arc<Executable>>, // indexed by phase
    pre: Vec<Arc<Executable>>,  // empty unless FP
    rest: Vec<Arc<Executable>>, // empty unless FP
    offline: Arc<Executable>,
    rt: Arc<Runtime>,
}

/// Per-stream partial states (host side).
#[derive(Debug, Clone)]
pub struct StateSet {
    pub tensors: Vec<Tensor>,
}

impl CompiledVariant {
    /// Load manifest + weights and compile every executable.
    ///
    /// Phases whose manifests point at the same HLO file share one
    /// compiled executable (aot.py dedupes identical graphs).
    pub fn load(rt: Arc<Runtime>, dir: &Path) -> Result<CompiledVariant> {
        let manifest = Manifest::load(dir)?;
        let weights = Weights::load(&manifest)?;
        Self::with_weights(rt, manifest, weights)
    }

    pub fn with_weights(
        rt: Arc<Runtime>,
        manifest: Manifest,
        weights: Weights,
    ) -> Result<CompiledVariant> {
        let mut cache: std::collections::BTreeMap<String, usize> = Default::default();
        let mut exes: Vec<Executable> = Vec::new();
        let mut index_of = |key: &str| -> Result<usize> {
            let file = manifest
                .executables
                .get(key)
                .with_context(|| format!("missing executable {key}"))?
                .clone();
            if let Some(&i) = cache.get(&file) {
                return Ok(i);
            }
            let exe = rt.compile_file(&manifest.dir.join(&file))?;
            exes.push(exe);
            cache.insert(file, exes.len() - 1);
            Ok(exes.len() - 1)
        };

        let mut step_idx = Vec::new();
        let mut pre_idx = Vec::new();
        let mut rest_idx = Vec::new();
        if manifest.streamable {
            for phase in 0..manifest.period {
                step_idx.push(index_of(&format!("step_p{phase}"))?);
            }
            if manifest.has_fp_split() {
                for phase in 0..manifest.period {
                    pre_idx.push(index_of(&format!("pre_p{phase}"))?);
                    rest_idx.push(index_of(&format!("rest_p{phase}"))?);
                }
            }
        }
        let off_idx = index_of("offline")?;

        let exes: Vec<Arc<Executable>> = exes.into_iter().map(Arc::new).collect();
        let pick = |idx: &[usize]| idx.iter().map(|&i| exes[i].clone()).collect::<Vec<_>>();
        Ok(CompiledVariant {
            step: pick(&step_idx),
            pre: pick(&pre_idx),
            rest: pick(&rest_idx),
            offline: exes[off_idx].clone(),
            manifest,
            weights,
            rt,
        })
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }

    pub fn device_weights(&self) -> Result<DeviceWeights> {
        self.weights.to_device(&self.rt)
    }

    /// Fresh zeroed per-stream states.
    ///
    /// Modern artifacts exchange one packed state vector (manifest
    /// `packed_states` > 0) — a single HBM upload per inference; legacy
    /// artifacts exchange one tensor per state spec.
    pub fn init_states(&self) -> StateSet {
        if self.manifest.packed_states > 0 {
            return StateSet {
                tensors: vec![Tensor::zeros(vec![self.manifest.packed_states])],
            };
        }
        StateSet {
            tensors: self
                .manifest
                .states
                .iter()
                .map(|s| Tensor::zeros(s.shape.clone()))
                .collect(),
        }
    }

    fn state_shapes(&self) -> Vec<Vec<usize>> {
        if self.manifest.packed_states > 0 {
            return vec![vec![self.manifest.packed_states]];
        }
        self.manifest.states.iter().map(|s| s.shape.clone()).collect()
    }

    /// One full streaming inference at schedule position `phase`.
    ///
    /// Uploads the frame + states, executes `step_p<phase>`, writes the new
    /// states back into `states`, returns the output frame.
    pub fn step(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        dev_weights: &DeviceWeights,
    ) -> Result<Vec<f32>> {
        let exe = &self.step[phase % self.manifest.period];
        self.run_step_like(exe, Some(frame), states, dev_weights, true)
    }

    /// FP precompute: the delayed-region part of inference `phase`;
    /// consumes no input frame, only updates states.
    pub fn precompute(
        &self,
        phase: usize,
        states: &mut StateSet,
        dev_weights: &DeviceWeights,
    ) -> Result<()> {
        if self.pre.is_empty() {
            bail!("{}: variant has no FP split", self.manifest.name);
        }
        let exe = &self.pre[phase % self.manifest.period];
        self.run_step_like(exe, None, states, dev_weights, false)?;
        Ok(())
    }

    /// FP rest pass: consumes the fresh frame after `precompute` ran.
    pub fn step_rest(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        dev_weights: &DeviceWeights,
    ) -> Result<Vec<f32>> {
        if self.rest.is_empty() {
            bail!("{}: variant has no FP split", self.manifest.name);
        }
        let exe = &self.rest[phase % self.manifest.period];
        self.run_step_like(exe, Some(frame), states, dev_weights, true)
    }

    fn run_step_like(
        &self,
        exe: &Executable,
        frame: Option<&[f32]>,
        states: &mut StateSet,
        dev_weights: &DeviceWeights,
        has_out: bool,
    ) -> Result<Vec<f32>> {
        let feat = self.manifest.config.feat;
        let mut owned: Vec<xla::PjRtBuffer> = Vec::with_capacity(1 + states.tensors.len());
        if let Some(f) = frame {
            if f.len() != feat {
                bail!("frame has {} samples, expected {feat}", f.len());
            }
            owned.push(self.rt.upload_raw(f, &[feat, 1])?);
        }
        for t in &states.tensors {
            owned.push(self.rt.upload(t)?);
        }
        let mut args: Vec<&xla::PjRtBuffer> = owned.iter().collect();
        for b in &dev_weights.bufs {
            args.push(b);
        }

        let mut out_shapes = Vec::new();
        if has_out {
            out_shapes.push(vec![feat, 1]);
        }
        out_shapes.extend(self.state_shapes());
        let mut outs = exe.run(&args, &out_shapes)?;

        let out_frame = if has_out {
            let f = outs.remove(0);
            f.data
        } else {
            Vec::new()
        };
        for (slot, t) in states.tensors.iter_mut().zip(outs) {
            *slot = t;
        }
        Ok(out_frame)
    }

    /// Run the offline (full-sequence) network over (feat, T) frames.
    /// `x` must have exactly `offline_t` columns.
    pub fn offline(&self, x: &Tensor, dev_weights: &DeviceWeights) -> Result<Tensor> {
        let feat = self.manifest.config.feat;
        let t = self.manifest.offline_t;
        if x.shape != [feat, t] {
            bail!(
                "offline input shape {:?}, expected [{feat}, {t}]",
                x.shape
            );
        }
        let xbuf = self.rt.upload(x)?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&xbuf];
        for b in &dev_weights.bufs {
            args.push(b);
        }
        let mut outs = self.offline.run(&args, &[vec![feat, t]])?;
        Ok(outs.remove(0))
    }
}

