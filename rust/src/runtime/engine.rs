//! Backend-agnostic runtime facade.
//!
//! [`Runtime`] selects an [`InferenceBackend`] (native by default; PJRT
//! with `--features pjrt` and `SOI_BACKEND=pjrt`); [`CompiledVariant`]
//! binds one variant manifest + weights to a backend-compiled executor.
//! The coordinator, experiments, benches and examples only ever talk to
//! these two types — the backend is swappable per DESIGN.md §4.

use std::path::Path;
use std::sync::{Arc, OnceLock};

use anyhow::{bail, Context, Result};

use super::manifest::Manifest;
use crate::backend::{DeviceWeights, InferenceBackend, VariantExec};
use crate::util::tensor::{f32s_from_le_bytes, Tensor};

/// A runtime bound to one inference backend.
pub struct Runtime {
    backend: Arc<dyn InferenceBackend>,
}

impl Runtime {
    /// The default CPU runtime.
    ///
    /// Uses the pure-Rust native backend unless `SOI_BACKEND=pjrt` is set
    /// (which requires building with `--features pjrt`).
    pub fn cpu() -> Result<Runtime> {
        match std::env::var("SOI_BACKEND").as_deref() {
            Ok("pjrt") => Self::pjrt_or_err(),
            Ok("native") | Ok("") | Err(_) => Ok(Self::native()),
            Ok(other) => bail!("unknown SOI_BACKEND '{other}' (native|pjrt)"),
        }
    }

    /// The dependency-free pure-Rust backend.
    pub fn native() -> Runtime {
        Runtime {
            backend: Arc::new(crate::backend::native::NativeBackend),
        }
    }

    /// The PJRT HLO-text backend (feature `pjrt`).
    #[cfg(feature = "pjrt")]
    pub fn pjrt() -> Result<Runtime> {
        Ok(Runtime {
            backend: Arc::new(crate::backend::pjrt::PjrtBackend::cpu()?),
        })
    }

    fn pjrt_or_err() -> Result<Runtime> {
        #[cfg(feature = "pjrt")]
        return Self::pjrt();
        #[cfg(not(feature = "pjrt"))]
        bail!("SOI_BACKEND=pjrt requires building with `--features pjrt`")
    }

    /// Wrap an externally constructed backend (tests, future backends).
    pub fn with_backend(backend: Arc<dyn InferenceBackend>) -> Runtime {
        Runtime { backend }
    }

    /// Backend name ("native", "pjrt").
    pub fn platform(&self) -> String {
        self.backend.name().to_string()
    }

    /// Number of devices the backend drives (1 for native).
    pub fn device_count(&self) -> usize {
        self.backend.device_count()
    }

    /// Prepare weights for execution on this runtime's backend.
    pub fn upload_weights(&self, weights: &Weights) -> Result<DeviceWeights> {
        self.backend.upload_weights(weights)
    }

    /// Compile one variant manifest for this runtime's backend.
    pub fn compile_variant(&self, manifest: &Manifest) -> Result<Box<dyn VariantExec>> {
        self.backend.compile_variant(manifest)
    }
}

/// Host-side weights in manifest order (prunable).
#[derive(Debug, Clone)]
pub struct Weights {
    /// Parameter tensors, in manifest `params` order.
    pub tensors: Vec<Tensor>,
}

impl Weights {
    /// Read `weights.bin` laid out per the manifest param specs.
    pub fn load(manifest: &Manifest) -> Result<Weights> {
        let path = manifest.dir.join("weights.bin");
        let bytes =
            std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        let vals = f32s_from_le_bytes(&bytes);
        let want: usize = manifest.params.iter().map(|p| p.elements()).sum();
        if vals.len() != want {
            bail!(
                "{}: weights.bin holds {} f32s, manifest wants {}",
                manifest.name,
                vals.len(),
                want
            );
        }
        let mut tensors = Vec::with_capacity(manifest.params.len());
        let mut off = 0;
        for spec in &manifest.params {
            let n = spec.elements();
            tensors.push(Tensor::new(spec.shape.clone(), vals[off..off + n].to_vec()));
            off += n;
        }
        Ok(Weights { tensors })
    }

    /// Total parameter count across all tensors.
    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Prepare these weights for execution on `rt`'s backend (device
    /// upload for pjrt, pass-through for native).
    pub fn to_device(&self, rt: &Runtime) -> Result<DeviceWeights> {
        rt.upload_weights(self)
    }
}

/// Per-stream partial states (host side).
#[derive(Debug, Clone)]
pub struct StateSet {
    /// State tensors, in manifest `states` order.
    pub tensors: Vec<Tensor>,
}

/// One compiled SOI variant: manifest + weights + backend executor.
pub struct CompiledVariant {
    /// The variant's parsed manifest.
    pub manifest: Manifest,
    /// The variant's host-side weights.
    pub weights: Weights,
    exec: Box<dyn VariantExec>,
    rt: Arc<Runtime>,
    /// The cached upload: prepared once, then shared by every caller
    /// through [`DeviceWeights`]'s internal `Arc` (ladder rungs and
    /// worker threads used to deep-copy the full tensor set per
    /// `device_weights()` call).
    upload: OnceLock<DeviceWeights>,
}

impl CompiledVariant {
    /// Load manifest + weights from an artifact directory and compile for
    /// the runtime's backend.
    pub fn load(rt: Arc<Runtime>, dir: &Path) -> Result<CompiledVariant> {
        let manifest = Manifest::load(dir)?;
        let weights = Weights::load(&manifest)?;
        Self::with_weights(rt, manifest, weights)
    }

    /// Compile from an in-memory manifest + weights (synthesized variants,
    /// pruning sweeps).
    pub fn with_weights(
        rt: Arc<Runtime>,
        manifest: Manifest,
        weights: Weights,
    ) -> Result<CompiledVariant> {
        let exec = rt
            .compile_variant(&manifest)
            .with_context(|| format!("compiling variant '{}'", manifest.name))?;
        Ok(CompiledVariant {
            manifest,
            weights,
            exec,
            rt,
            upload: OnceLock::new(),
        })
    }

    /// The runtime this variant was compiled for.
    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }

    /// Prepare this variant's own weights for execution.
    ///
    /// The upload (host-side panel packing for native, device transfer
    /// for pjrt) happens once per variant; every subsequent call clones
    /// the shared handle.  Mutate a *clone* of [`CompiledVariant::weights`]
    /// and recompile (as the pruning flows do) to execute different
    /// tensors — in-place edits after the first upload are not observed.
    pub fn device_weights(&self) -> Result<DeviceWeights> {
        if let Some(dw) = self.upload.get() {
            return Ok(dw.clone());
        }
        let dw = self.rt.upload_weights(&self.weights)?;
        Ok(self.upload.get_or_init(|| dw).clone())
    }

    /// Fresh zeroed per-stream states.
    pub fn init_states(&self) -> StateSet {
        self.exec.init_states()
    }

    /// Whether the backend can run the FP precompute/rest split.
    pub fn has_fp_split(&self) -> bool {
        self.exec.has_fp_split()
    }

    /// One full streaming inference at schedule position `phase`.
    pub fn step(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        dev_weights: &DeviceWeights,
    ) -> Result<Vec<f32>> {
        let feat = self.manifest.config.feat;
        if frame.len() != feat {
            bail!("frame has {} samples, expected {feat}", frame.len());
        }
        self.exec
            .step(phase % self.manifest.period, frame, states, dev_weights)
    }

    /// FP precompute: the delayed-region part of inference `phase`;
    /// consumes no input frame, only updates states.
    pub fn precompute(
        &self,
        phase: usize,
        states: &mut StateSet,
        dev_weights: &DeviceWeights,
    ) -> Result<()> {
        self.exec
            .precompute(phase % self.manifest.period, states, dev_weights)
    }

    /// FP rest pass: consumes the fresh frame after `precompute` ran.
    pub fn step_rest(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        dev_weights: &DeviceWeights,
    ) -> Result<Vec<f32>> {
        let feat = self.manifest.config.feat;
        if frame.len() != feat {
            bail!("frame has {} samples, expected {feat}", frame.len());
        }
        self.exec
            .step_rest(phase % self.manifest.period, frame, states, dev_weights)
    }

    /// Phase-aligned batched streaming step (DESIGN.md §8): one inference
    /// for every stream in the batch, all at schedule position `phase`.
    /// Backends without a batched kernel fall back to the sequential loop.
    pub fn step_batch(
        &self,
        phase: usize,
        frames: &[&[f32]],
        states: &mut [&mut StateSet],
        dev_weights: &DeviceWeights,
    ) -> Result<Vec<Vec<f32>>> {
        self.check_batch(frames, states.len())?;
        self.exec
            .step_batch(phase % self.manifest.period, frames, states, dev_weights)
    }

    /// Phase-aligned batched FP rest pass (each stream's `precompute`
    /// must already have run for this phase).
    pub fn step_rest_batch(
        &self,
        phase: usize,
        frames: &[&[f32]],
        states: &mut [&mut StateSet],
        dev_weights: &DeviceWeights,
    ) -> Result<Vec<Vec<f32>>> {
        self.check_batch(frames, states.len())?;
        self.exec
            .step_rest_batch(phase % self.manifest.period, frames, states, dev_weights)
    }

    /// [`CompiledVariant::step_batch`] writing into caller-owned buffers
    /// (capacity reused across rounds — the server's batched dispatch
    /// path).
    pub fn step_batch_into(
        &self,
        phase: usize,
        frames: &[&[f32]],
        states: &mut [&mut StateSet],
        dev_weights: &DeviceWeights,
        outs: &mut Vec<Vec<f32>>,
    ) -> Result<()> {
        self.check_batch(frames, states.len())?;
        self.exec
            .step_batch_into(phase % self.manifest.period, frames, states, dev_weights, outs)
    }

    /// [`CompiledVariant::step_rest_batch`] writing into caller-owned
    /// buffers.
    pub fn step_rest_batch_into(
        &self,
        phase: usize,
        frames: &[&[f32]],
        states: &mut [&mut StateSet],
        dev_weights: &DeviceWeights,
        outs: &mut Vec<Vec<f32>>,
    ) -> Result<()> {
        self.check_batch(frames, states.len())?;
        self.exec.step_rest_batch_into(
            phase % self.manifest.period,
            frames,
            states,
            dev_weights,
            outs,
        )
    }

    fn check_batch(&self, frames: &[&[f32]], n_states: usize) -> Result<()> {
        if frames.len() != n_states {
            bail!(
                "batched step: {} frames for {} state sets",
                frames.len(),
                n_states
            );
        }
        let feat = self.manifest.config.feat;
        for frame in frames {
            if frame.len() != feat {
                bail!("frame has {} samples, expected {feat}", frame.len());
            }
        }
        Ok(())
    }

    /// Run the offline (full-sequence) network over (feat, T) frames.
    pub fn offline(&self, x: &Tensor, dev_weights: &DeviceWeights) -> Result<Tensor> {
        self.exec.offline(x, dev_weights)
    }

    /// MACs executed so far, when the backend counts them (native only).
    pub fn executed_macs(&self) -> Option<u64> {
        self.exec.executed_macs()
    }

    /// Reset the MAC counter (no-op for uncounted backends).
    pub fn reset_executed_macs(&self) {
        self.exec.reset_executed_macs()
    }

    /// The variant's per-thread scratch-arena id, when the backend steps
    /// out of one (native interpreters only).  Keys the serving layer's
    /// per-variant `arena_peak_bytes` lookups.
    pub fn arena_id(&self) -> Option<u64> {
        self.exec.arena_id()
    }
}
