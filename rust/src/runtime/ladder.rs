//! Variant ladder: the ordered set of compiled SOI variants an adaptive
//! server switches between at runtime (DESIGN.md §9).
//!
//! The paper's compression depth (how many S-CC stages, whether an FP
//! shift hides work before arrival) is a *compile-time* knob in the
//! artifact flow — but every variant of one base model shares the same
//! parameter inventory (S-CC and the FP shift change the schedule and
//! the state layout, never the conv weights), so a serving process can
//! hold several compiled executables over **one** weight set and move a
//! live stream between them.  [`VariantLadder`] is that set: rung 0 is
//! the quality anchor (typically pure STMC), later rungs trade output
//! quality for cheaper on-arrival work under load.
//!
//! [`warmup_frames`] is the other half of the migration contract: the
//! number of most-recent input frames that fully determine every partial
//! state of a variant (conv windows, S-CC extrapolation caches, the FP
//! delay line).  A stream that retains that many frames can be re-primed
//! on a different rung with *no* output glitch — replaying them through
//! the new executable reproduces, bit for bit, the states a session
//! serving the whole stream on that rung would hold
//! (`rust/tests/adaptive_serving.rs` proves it).

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::engine::{CompiledVariant, Runtime, Weights};
use super::manifest::{Dtype, ModelConfig};
use crate::backend::DeviceWeights;

/// Frames of input history that fully determine a variant's partial
/// states (its streaming receptive field, conservatively rounded up).
///
/// Derivation: along the encoder, each layer's STMC window needs
/// `kernel` ticks of clean input at its rate `r_in(l)`, the FP delay
/// line adds `shift · r_in(s)`, and an S-CC layer's first *fresh* fire
/// after its window settles adds up to one firing interval
/// (`2 · r_in(l)`); the decoder mirrors this at `r_out(l)` with the
/// extrapolation cache adding one more fresh compute.  The per-layer
/// settle times telescope (a layer is clean one window after its input
/// is clean), so the total is the sum plus one period of margin.
///
/// The bound is deliberately loose (`kernel` ticks where `kernel - 1`
/// suffice): replaying a few extra frames costs microseconds, while an
/// under-estimate would break the bit-exactness guarantee migration is
/// built on.
pub fn warmup_frames(cfg: &ModelConfig) -> usize {
    let k = cfg.kernel;
    let mut frames = 0usize;
    for l in 1..=cfg.depth() {
        let r_in = cfg.r_in(l);
        if cfg.shift_pos == Some(l) {
            frames += cfg.shift * r_in;
        }
        frames += k * r_in;
        let r_out = cfg.r_out(l);
        frames += k * r_out;
        if cfg.scc.contains(&l) {
            // first fresh fire (encoder) + one extrapolation-cache
            // refresh (decoder) after the windows settle
            frames += 2 * r_in + 2 * r_out;
        }
    }
    frames + cfg.period()
}

/// An ordered set of compiled SOI variants sharing one weight set.
///
/// Rung 0 is the quality anchor; each later rung should be cheaper on
/// arrival (deeper S-CC compression, an FP split that hides work in the
/// idle gap, or — since precision became a rung axis (DESIGN.md §10) —
/// quantized int8 execution of the same topology).  The ladder validates
/// at construction that every rung is weight-compatible — identical
/// parameter inventories (names and shapes, in `weights.bin` order),
/// same frame size, same backend — so one [`DeviceWeights`] upload
/// (rung 0's) serves every rung, and a stream can migrate between rungs
/// without touching the weights.
///
/// **Cross-precision rungs** are explicitly valid: an int8 rung executes
/// from the *same f32 upload* (the quantized executable packs its codes
/// lazily from it), so `stmc:f32 → stmc:int8 → scc2:int8` needs no
/// second weight set.  Migration *into* a quantized rung replays the
/// stream's retained f32 input history through the int8 executable,
/// re-priming its code-valued states under the int8 path's own
/// determinism contract — bit-identical to a session that served the
/// whole stream quantized (`rust/tests/quant_backend.rs`).
///
/// ```
/// use std::sync::Arc;
/// use soi::runtime::{Dtype, Runtime, VariantLadder};
///
/// let rt = Arc::new(Runtime::native());
/// let ladder =
///     VariantLadder::synth(rt, &["stmc", "stmc:int8", "scc2:int8"], 0xC0DE).unwrap();
/// assert_eq!(ladder.names(), ["stmc", "stmc:int8", "scc2:int8"]);
/// assert_eq!(ladder.dtypes(), [Dtype::F32, Dtype::Int8, Dtype::Int8]);
/// // every rung can be re-primed from this many retained input frames
/// assert!(ladder.max_warmup() > 0);
/// ```
pub struct VariantLadder {
    variants: Vec<Arc<CompiledVariant>>,
}

impl VariantLadder {
    /// A ladder over already-compiled variants, ordered best quality
    /// first.  Fails unless every rung is weight-compatible with rung 0
    /// (see the type docs) and streamable, and names are unique.
    pub fn new(variants: Vec<Arc<CompiledVariant>>) -> Result<VariantLadder> {
        let Some(first) = variants.first() else {
            bail!("variant ladder needs at least one rung");
        };
        for cv in &variants {
            let m = &cv.manifest;
            if !m.streamable {
                bail!("ladder rung '{}' is offline-only (not streamable)", m.name);
            }
            if m.dtype == Dtype::Int8 && m.quant.is_none() {
                bail!(
                    "ladder rung '{}' is int8 but carries no baked quant params",
                    m.name
                );
            }
            if m.config.feat != first.manifest.config.feat {
                bail!(
                    "ladder rung '{}' has frame size {}, rung 0 ('{}') has {}",
                    m.name,
                    m.config.feat,
                    first.manifest.name,
                    first.manifest.config.feat
                );
            }
            if m.params != first.manifest.params {
                bail!(
                    "ladder rung '{}' has a different parameter inventory than \
                     rung 0 ('{}'); rungs must share one weight set",
                    m.name,
                    first.manifest.name
                );
            }
            if !Arc::ptr_eq(cv.runtime(), first.runtime()) {
                bail!(
                    "ladder rung '{}' was compiled for a different runtime than rung 0",
                    m.name
                );
            }
        }
        for (i, cv) in variants.iter().enumerate() {
            if variants[..i]
                .iter()
                .any(|o| o.manifest.name == cv.manifest.name)
            {
                bail!("ladder lists variant '{}' twice", cv.manifest.name);
            }
        }
        Ok(VariantLadder { variants })
    }

    /// A trivial one-rung ladder (pinned serving — no validation, so
    /// `Server::new` keeps accepting every variant it accepted before).
    pub fn single(variant: Arc<CompiledVariant>) -> VariantLadder {
        VariantLadder {
            variants: vec![variant],
        }
    }

    /// Synthesize and compile a ladder from preset specs
    /// ([`crate::runtime::synth::preset`] grammar, optionally suffixed
    /// `:f32` | `:int8`), sharing one deterministic He-initialised
    /// weight set (untrained).  Mixed-precision ladders fall out of the
    /// grammar: `["stmc", "stmc:int8", "scc2:int8"]`.
    pub fn synth(rt: Arc<Runtime>, names: &[&str], seed: u64) -> Result<VariantLadder> {
        let mut variants = Vec::with_capacity(names.len());
        for name in names {
            let (base, dtype) = super::synth::parse_spec(name)?;
            let cfg = super::synth::preset(base)
                .with_context(|| format!("'{base}' is not a known preset variant name"))?;
            variants.push(Arc::new(super::synth::variant_with_dtype(
                rt.clone(),
                &cfg,
                name,
                seed,
                dtype,
            )?));
        }
        Self::new(variants)
    }

    /// Compile a ladder of preset rungs **over a shipped weight set**
    /// (DESIGN.md §13): each spec reshapes the schedule of `base`'s
    /// topology via [`crate::runtime::synth::preset_over`] — never its
    /// parameter inventory — so every rung executes the same `weights`
    /// (an artifact's verified tensors).  Int8 rungs calibrate their
    /// activation scales against these weights with the same derived
    /// seed the synth path uses, keeping quantized execution
    /// deterministic per `(artifact, spec, seed)`.
    pub fn over_weights(
        rt: Arc<Runtime>,
        base: &ModelConfig,
        weights: &Weights,
        specs: &[&str],
        seed: u64,
    ) -> Result<VariantLadder> {
        let mut variants = Vec::with_capacity(specs.len());
        for spec in specs {
            let (name, dtype) = super::synth::parse_spec(spec)?;
            let cfg = super::synth::preset_over(base, name).with_context(|| {
                format!("'{name}' is not a preset rung of a depth-{} base", base.depth())
            })?;
            let mut m = super::synth::manifest(&cfg, spec, 256);
            if dtype == Dtype::Int8 {
                m.dtype = Dtype::Int8;
                m.quant = Some(crate::quant::calibrate(
                    &m,
                    weights,
                    super::synth::CALIBRATION_FRAMES,
                    seed ^ 0x5EED_CA1B,
                )?);
            }
            variants.push(Arc::new(CompiledVariant::with_weights(
                rt.clone(),
                m,
                weights.clone(),
            )?));
        }
        Self::new(variants)
    }

    /// Number of rungs.
    pub fn len(&self) -> usize {
        self.variants.len()
    }

    /// Whether the ladder has no rungs (never true for a constructed
    /// ladder; provided for clippy's `len_without_is_empty`).
    pub fn is_empty(&self) -> bool {
        self.variants.is_empty()
    }

    /// The compiled variant at rung `i` (0 = best quality).
    pub fn level(&self, i: usize) -> &Arc<CompiledVariant> {
        &self.variants[i]
    }

    /// All rungs, best quality first.
    pub fn variants(&self) -> &[Arc<CompiledVariant>] {
        &self.variants
    }

    /// Variant names, rung order.
    pub fn names(&self) -> Vec<&str> {
        self.variants
            .iter()
            .map(|v| v.manifest.name.as_str())
            .collect()
    }

    /// Execution precision per rung, rung order (DESIGN.md §10).
    pub fn dtypes(&self) -> Vec<Dtype> {
        self.variants.iter().map(|v| v.manifest.dtype).collect()
    }

    /// Whether any rung executes quantized (int8).
    pub fn has_int8(&self) -> bool {
        self.variants
            .iter()
            .any(|v| v.manifest.dtype == Dtype::Int8)
    }

    /// Rung index of a variant by name.
    pub fn position(&self, name: &str) -> Option<usize> {
        self.variants.iter().position(|v| v.manifest.name == name)
    }

    /// The shared weights, prepared for execution (rung 0's upload —
    /// valid for every rung by the construction-time inventory check).
    pub fn device_weights(&self) -> Result<DeviceWeights> {
        self.variants[0].device_weights()
    }

    /// Largest [`warmup_frames`] across all rungs: a stream retaining
    /// this many recent input frames can migrate to *any* rung with
    /// bit-exact re-priming.
    pub fn max_warmup(&self) -> usize {
        self.variants
            .iter()
            .map(|v| warmup_frames(&v.manifest.config))
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::complexity::unet;

    #[test]
    fn warmup_grows_with_compression_depth() {
        let stmc = warmup_frames(&unet::default_config(vec![], None));
        let scc2 = warmup_frames(&unet::default_config(vec![2], None));
        let scc2_5 = warmup_frames(&unet::default_config(vec![2, 5], None));
        assert!(stmc > 0);
        assert!(scc2 > stmc, "S-CC widens the receptive field");
        assert!(scc2_5 > scc2);
    }

    #[test]
    fn warmup_counts_the_fp_delay_line() {
        let mut fp = unet::default_config(vec![], Some(1));
        fp.shift = 4;
        let base = warmup_frames(&unet::default_config(vec![], None));
        assert_eq!(warmup_frames(&fp), base + 4);
    }

    #[test]
    fn preset_ladder_synthesizes_and_validates() {
        let rt = Arc::new(Runtime::native());
        let ladder = VariantLadder::synth(rt, &["stmc", "scc2", "sscc5"], 7).unwrap();
        assert_eq!(ladder.len(), 3);
        assert_eq!(ladder.position("sscc5"), Some(2));
        assert!(ladder.position("scc3").is_none());
        assert!(ladder.max_warmup() >= warmup_frames(&ladder.level(1).manifest.config));
        assert!(!ladder.is_empty());
        ladder.device_weights().unwrap();
    }

    #[test]
    fn rejects_unknown_preset_and_empty() {
        let rt = Arc::new(Runtime::native());
        assert!(VariantLadder::synth(rt, &["stmc", "bogus"], 7).is_err());
        assert!(VariantLadder::new(Vec::new()).is_err());
    }

    #[test]
    fn over_weights_builds_rungs_on_shipped_tensors() {
        use crate::runtime::synth;
        let rt = Arc::new(Runtime::native());
        let base = unet::default_config(vec![], None);
        let m = synth::manifest(&base, "stmc", 256);
        let w = synth::he_weights(&m, 99);
        let ladder =
            VariantLadder::over_weights(rt, &base, &w, &["stmc", "scc2:int8"], 99).unwrap();
        assert_eq!(ladder.names(), ["stmc", "scc2:int8"]);
        // every rung executes the tensors it was handed, bit for bit
        for rung in 0..2 {
            for (a, b) in w.tensors.iter().zip(&ladder.level(rung).weights.tensors) {
                assert_eq!(a.data, b.data);
            }
        }
        // unknown rung names fail with context, not a panic
        assert!(VariantLadder::over_weights(
            Arc::new(Runtime::native()),
            &base,
            &w,
            &["scc99"],
            99
        )
        .is_err());
    }

    #[test]
    fn mixed_precision_ladder_shares_one_weight_set() {
        let rt = Arc::new(Runtime::native());
        let ladder =
            VariantLadder::synth(rt, &["stmc", "stmc:int8", "scc2:int8"], 0xC0DE).unwrap();
        assert_eq!(ladder.dtypes(), vec![Dtype::F32, Dtype::Int8, Dtype::Int8]);
        assert!(ladder.has_int8());
        // the int8 rungs share rung 0's f32 tensors bit-for-bit
        for rung in 1..3 {
            for (a, b) in ladder
                .level(0)
                .weights
                .tensors
                .iter()
                .zip(&ladder.level(rung).weights.tensors)
            {
                assert_eq!(a.data, b.data);
            }
        }
        // one upload (rung 0's) is valid for every rung
        ladder.device_weights().unwrap();
        // same base at two precisions is not a duplicate name
        assert_eq!(ladder.position("stmc:int8"), Some(1));
    }
}
